"""Fleet-scale planning benchmark — the repo's end-to-end scaling story.

Sections:

  * ``fleet/parity``   — plans the SAME >=64-device fleet twice per solver:
    batched vs the per-device NumPy oracle —
      - vmapped AMR^2 vs the sequential simplex (accuracy gap <= 1e-6 and
        the paper's 2T makespan guarantee per device),
      - vmapped `dual_schedule_batch` vs the NumPy `dual_schedule`
        (bit-identical assignments),
      - vmapped `amdp_batch` vs the scalar CCKP DP on identical-job
        devices (bit-identical assignments),
    and reports batched-vs-sequential planning throughput.
  * ``fleet/scale/B``  — runs the full serving engine (Poisson queue, ES
    pool, stragglers, outages) at increasing fleet sizes (through the
    256/1024-device points) and reports devices-planned/sec plus aggregate
    accuracy / violation numbers.
  * ``fleet/speedup``  — the vectorized `run_period` (amr2 and dual
    policies) against the PR-1 per-device `run_period_reference` loop at
    the 256-device point.

Every section also folds its numbers into ``BENCH_fleet.json`` (repo root;
override with ``BENCH_FLEET_JSON``) so the perf trajectory accumulates
across hosts/PRs.  ``FLEET_BENCH_SIZES`` / ``FLEET_BENCH_PERIODS`` /
``FLEET_BENCH_SPEEDUP_DEVICES`` shrink the run for CI smoke jobs.

Standalone:  PYTHONPATH=src python benchmarks/fleet_bench.py
CSV via the harness:  python benchmarks/run.py fleet
"""
from __future__ import annotations

import json
import os
import platform
import time

import numpy as np

PARITY_DEVICES = 64
PARITY_JOBS = 12
SCALE_PERIODS = 20
_BIG = 256            # scale points from here down run fewer periods

_JSON_PATH = os.environ.get(
    "BENCH_FLEET_JSON",
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 "BENCH_fleet.json"))
_RESULTS: dict = {}


def _record(section: str, payload) -> None:
    """Fold one section's numbers into BENCH_fleet.json.

    Merges into the existing document (a partial run — e.g. the CI smoke
    job, which only runs some sections — updates its sections and leaves
    the rest intact) and rewrites after every section so an interrupted run
    still leaves a valid file."""
    _RESULTS[section] = payload
    doc = {}
    try:
        with open(_JSON_PATH) as fh:
            doc = json.load(fh)
    except (OSError, ValueError):
        pass
    doc.update({"host": platform.node(), "platform": platform.platform(),
                "unix_time": time.time(), **_RESULTS})
    with open(_JSON_PATH, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")


def _scale_sizes():
    env = os.environ.get("FLEET_BENCH_SIZES")
    if env:
        return tuple(int(x) for x in env.split(","))
    return (8, 16, 32, 64, 256, 1024)


def _periods(n_devices: int) -> int:
    cap = int(os.environ.get("FLEET_BENCH_PERIODS", SCALE_PERIODS))
    return min(cap, 5 if n_devices >= _BIG else SCALE_PERIODS)


def _parity_instances(n_devices=PARITY_DEVICES, n_jobs=PARITY_JOBS, seed=0):
    from repro.serving.fleet import make_fleet
    rng = np.random.default_rng(seed)
    specs = make_fleet(n_devices, seed=seed, straggler_frac=0.0,
                       outage_frac=0.0)
    T = 1.2
    insts = []
    for spec in specs:
        classes = rng.choice(spec.profile.classes, size=n_jobs)
        insts.append(spec.profile.instance(classes, T))
    return insts, T


def parity():
    """Batched registry solves vs per-device NumPy/scalar oracles — every
    path goes through `repro.api.solve`, the single front door."""
    from repro import api
    from repro.core import InstanceBatch, identical_instance

    insts, T = _parity_instances()
    fp = api.FleetProblem.from_batch(InstanceBatch.stack(insts))
    api.solve(fp, policy="amr2")                        # compile once
    t0 = time.perf_counter()
    sol = api.solve(fp, policy="amr2")                  # ONE jit call
    batched_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    oracle = api.solve(fp, policy="amr2", backend="numpy")  # seq. simplex
    oracle_s = time.perf_counter() - t0

    gaps = np.abs(sol.accuracy - oracle.accuracy)
    max_gap = float(gaps.max())
    assert max_gap <= 1e-6, \
        f"batched/oracle accuracy mismatch: {max_gap:.2e}"
    assert float(np.max(sol.makespan)) <= 2 * T + 1e-9, \
        f"2T guarantee violated: {float(np.max(sol.makespan)):.3f} > {2 * T}"

    # --- dual: batched jitted bisection vs NumPy oracle, bit-identical ---
    api.solve(fp, policy="dual")                        # compile once
    t0 = time.perf_counter()
    dual_sol = api.solve(fp, policy="dual")
    dual_batched_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    dual_oracle = api.solve(fp, policy="dual", backend="numpy")
    dual_oracle_s = time.perf_counter() - t0
    np.testing.assert_array_equal(dual_sol.assignment,
                                  dual_oracle.assignment)

    # --- amdp: vmapped CCKP DP vs scalar DP, bit-identical ---------------
    ident = [identical_instance(PARITY_JOBS, 2, T=1.0 + 0.05 * (s % 8),
                                seed=s) for s in range(PARITY_DEVICES)]
    ident_fp = api.FleetProblem.from_batch(InstanceBatch.stack(ident))
    api.solve(ident_fp, policy="amdp")                  # compile once
    t0 = time.perf_counter()
    amdp_sol = api.solve(ident_fp, policy="amdp")
    amdp_batched_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    amdp_oracle = api.solve(ident_fp, policy="amdp", backend="numpy")
    amdp_oracle_s = time.perf_counter() - t0
    assert (np.atleast_1d(amdp_sol.solver) == "amdp").all()
    np.testing.assert_array_equal(np.asarray(amdp_sol.status),
                                  np.asarray(amdp_oracle.status))
    np.testing.assert_array_equal(amdp_sol.assignment,
                                  amdp_oracle.assignment)

    n = len(insts)
    _record("parity", {
        "devices": n, "jobs_per_device": PARITY_JOBS,
        "amr2_max_acc_gap": max_gap,
        "amr2_batched_devices_per_s": n / batched_s,
        "amr2_oracle_devices_per_s": n / oracle_s,
        "dual_batched_devices_per_s": n / dual_batched_s,
        "dual_oracle_devices_per_s": n / dual_oracle_s,
        "amdp_batched_devices_per_s": len(ident) / amdp_batched_s,
        "amdp_oracle_devices_per_s": len(ident) / amdp_oracle_s,
        "assertions": "passed",
    })
    return [
        ("fleet/parity/batched", batched_s / n * 1e6,
         f"devices={n};devices_per_s={n / batched_s:.0f};"
         f"max_acc_gap={max_gap:.1e};single_jit_call=1"),
        ("fleet/parity/numpy_oracle", oracle_s / n * 1e6,
         f"devices={n};devices_per_s={n / oracle_s:.0f};"
         f"speedup={oracle_s / batched_s:.1f}x"),
        ("fleet/parity/dual_batched", dual_batched_s / n * 1e6,
         f"devices={n};devices_per_s={n / dual_batched_s:.0f};"
         f"speedup_vs_numpy={dual_oracle_s / dual_batched_s:.1f}x;"
         f"assignments=bit_identical"),
        ("fleet/parity/amdp_batched", amdp_batched_s / len(ident) * 1e6,
         f"devices={len(ident)};"
         f"devices_per_s={len(ident) / amdp_batched_s:.0f};"
         f"speedup_vs_scalar={amdp_oracle_s / amdp_batched_s:.1f}x;"
         f"assignments=bit_identical"),
    ]


def _engine(n_devices: int, *, policy: str = "auto", seed: int = 7):
    from repro.serving import FleetConfig, FleetEngine
    return FleetEngine.from_config(FleetConfig(
        n_devices=n_devices, T=1.2, n_servers=max(1, n_devices // 16),
        policy=policy, rate=10.0, batch_max=PARITY_JOBS,
        horizon=SCALE_PERIODS, seed=seed))


def scaling():
    """End-to-end engine throughput + accuracy/violation vs fleet size."""
    out = []
    entries = []
    for n_devices in _scale_sizes():
        periods = _periods(n_devices)
        policies = ("auto", "dual") if n_devices >= _BIG else ("auto",)
        for policy in policies:
            engine = _engine(n_devices, policy=policy)
            engine.run_period()                         # compile once
            engine.history.clear()  # keep jit warmup out of the averages
            t0 = time.perf_counter()
            engine.run(periods)
            wall = time.perf_counter() - t0
            s = engine.summary()
            entry = {
                "devices": n_devices, "policy": policy, "periods": periods,
                "jobs": s["jobs"],
                "devices_per_s_plan": s["devices_per_second"],
                "devices_per_s_wall": n_devices * periods / wall,
                "mean_job_accuracy": s["mean_job_accuracy"],
                "violation_rate": s["violation_rate"],
                "backpressure_rate": s["backpressure_rate"],
            }
            entries.append(entry)
            tag = f"fleet/scale/{n_devices}" + (
                "" if policy == "auto" else f"/{policy}")
            out.append((
                tag, s["plan_seconds_per_period"] / n_devices * 1e6,
                f"periods={periods};jobs={s['jobs']};"
                f"devices_per_s={s['devices_per_second']:.0f};"
                f"acc_per_job={s['mean_job_accuracy']:.4f};"
                f"violation_rate={s['violation_rate']:.4f};"
                f"backpressure_rate={s['backpressure_rate']:.4f};"
                f"sim_wall_s={wall:.2f}"))
    _record("scale", entries)
    return out


def speedup():
    """Vectorized engine vs the PR-1 per-device reference loop at the
    256-device scale point (or FLEET_BENCH_SPEEDUP_DEVICES).

    Two kinds of comparison, kept separate so the loop gain is not
    conflated with a solver/policy change:

      * *loop speedup* — `run_period` vs `run_period_reference` under the
        SAME policy (amr2/amr2 and dual/dual), isolating the array-resident
        assembly/replan/audit against the per-device Python loop;
      * *path speedup* — the new hot path (vectorized engine, amr2 or
        dual) against the PR-1 serving configuration
        (`run_period_reference`, policy "auto"), the number the ROADMAP
        tracks.  The reference loop's `solve_many` itself already benefits
        from the batched solvers, so this UNDERSTATES the gain over the
        literal PR-1 code.
    """
    n = int(os.environ.get("FLEET_BENCH_SPEEDUP_DEVICES", _BIG))
    periods = _periods(n)

    def _run(policy: str, reference: bool):
        engine = _engine(n, policy=policy)
        step = (engine.run_period_reference if reference
                else engine.run_period)
        step()                                          # compile once
        engine.history.clear()
        t0 = time.perf_counter()
        for _ in range(periods):
            step()
        wall = time.perf_counter() - t0
        s = engine.summary()
        return {
            "devices_per_s_plan": s["devices_per_second"],
            "devices_per_s_wall": n * periods / wall,
            "mean_job_accuracy": s["mean_job_accuracy"],
            "violation_rate": s["violation_rate"],
        }

    pr1 = _run("auto", reference=True)        # the PR-1 serving config
    ref_amr2 = _run("amr2", reference=True)
    ref_dual = _run("dual", reference=True)
    new_amr2 = _run("amr2", reference=False)
    new_dual = _run("dual", reference=False)

    def _ratio(a, b, key):
        return a[key] / max(b[key], 1e-12)

    entry = {
        "devices": n, "periods": periods,
        "pr1_reference_auto": pr1,
        "reference_amr2": ref_amr2,
        "reference_dual": ref_dual,
        "vectorized_amr2": new_amr2,
        "vectorized_dual": new_dual,
        # same-policy pairs: the array-resident loop in isolation
        "amr2_loop_speedup_wall": _ratio(new_amr2, ref_amr2,
                                         "devices_per_s_wall"),
        "dual_loop_speedup_wall": _ratio(new_dual, ref_dual,
                                         "devices_per_s_wall"),
        # hot path vs the PR-1 serving configuration
        "amr2_speedup_plan": _ratio(new_amr2, pr1, "devices_per_s_plan"),
        "amr2_speedup_wall": _ratio(new_amr2, pr1, "devices_per_s_wall"),
        "dual_speedup_plan": _ratio(new_dual, pr1, "devices_per_s_plan"),
        "dual_speedup_wall": _ratio(new_dual, pr1, "devices_per_s_wall"),
        "dual_accuracy_delta": (new_dual["mean_job_accuracy"]
                                - pr1["mean_job_accuracy"]),
    }
    _record("speedup", entry)
    return [
        ("fleet/speedup/pr1_reference", 1e6
         / max(pr1["devices_per_s_wall"], 1e-9),
         f"devices={n};devices_per_s={pr1['devices_per_s_wall']:.0f};"
         f"policy=auto;path=per_device"),
        ("fleet/speedup/vectorized_amr2", 1e6
         / max(new_amr2["devices_per_s_wall"], 1e-9),
         f"devices={n};devices_per_s={new_amr2['devices_per_s_wall']:.0f};"
         f"loop_speedup={entry['amr2_loop_speedup_wall']:.1f}x;"
         f"vs_pr1={entry['amr2_speedup_wall']:.1f}x"),
        ("fleet/speedup/vectorized_dual", 1e6
         / max(new_dual["devices_per_s_wall"], 1e-9),
         f"devices={n};devices_per_s={new_dual['devices_per_s_wall']:.0f};"
         f"loop_speedup={entry['dual_loop_speedup_wall']:.1f}x;"
         f"vs_pr1={entry['dual_speedup_wall']:.1f}x;"
         f"acc_delta={entry['dual_accuracy_delta']:+.4f}"),
    ]


ALL = [parity, scaling, speedup]


def main():
    print("name,us_per_call,derived")
    for fn in ALL:
        for name, us, derived in fn():
            print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
