"""Fleet-scale planning benchmark — the repo's end-to-end scaling story.

Sections:

  * ``fleet/parity/B`` — plans the SAME fleet twice per solver at the 64-
    AND 256-device points (``FLEET_BENCH_PARITY_SIZES``), batched vs the
    per-device NumPy oracle —
      - vmapped AMR^2 vs the sequential simplex (accuracy gap <= 1e-6 and
        the paper's 2T makespan guarantee per device),
      - vmapped `dual_schedule_batch` vs the NumPy `dual_schedule`
        (bit-identical assignments),
      - vmapped `amdp_batch` vs the scalar CCKP DP on identical-job
        devices (bit-identical assignments),
    and reports batched-vs-sequential planning throughput.  Results merge
    into ``BENCH_fleet.json`` keyed by device count, so the documented
    256-device baseline is reproduced by the benchmark itself.
  * ``fleet/warm_cold/B`` — consecutive-period LP re-solves at 64/256/1024
    devices (``FLEET_BENCH_WARM_SIZES``): period t's optimal bases warm-
    start period t+1's batched AMR^2 solve (`solve(..., warm_start=)`),
    asserting bit-tight warm/cold LP-objective parity plus a bounded
    rounded-accuracy gap vs the per-device NumPy oracle, and reporting
    warm-vs-cold throughput plus warm-basis acceptance rates.
  * ``fleet/scale/B``  — engine-v2 `rollout()` (ONE lax.scan per point,
    state buffers donated, amr2 on the reduced-tableau
    ``method="revised"`` simplex) at increasing fleet sizes through the
    CI-feasible 16k point, with the 100k point opt-in via
    ``FLEET_BENCH_SCALE_SIZES=102400``; reports devices/sec plus
    aggregate accuracy / violation numbers and gates >= 16k points on
    not scaling worse than the smallest amr2 point (plus the absolute
    ``FLEET_BENCH_MIN_DEVICES_PER_S`` floor when set).
  * ``fleet/speedup``  — the scanned `engine.rollout` hot path (amr2 and
    dual policies) against the PR-1 per-device `run_period_reference`
    loop at the 256-device point.
  * ``fleet/chaos/*`` — the fault-injection subsystem under load
    (``FLEET_BENCH_CHAOS_DEVICES`` / ``FLEET_BENCH_CHAOS_PERIODS``):
    pins the armed-null rollout bitwise against the fault-free engine,
    sweeps the offload loss rate through 40% on ONE compiled rollout
    (fault rates are pytree leaves), and asserts graceful degradation —
    per-period offload accounting closes exactly, realized makespans
    respect the 2T + retry-budget bound, and the 10%-loss point keeps
    >= 90% of the fault-free accuracy (no cliff) — plus a harsh
    crash+degrade+straggler entry for the documented worst case.
  * ``fleet/grad/B`` — the differentiable serving stack
    (``FLEET_BENCH_GRAD_DEVICES``, default 256): ONE
    `rollout_value_and_grad` backward sweep (implicit-gradient simplex +
    smoothed rounding/admission, soft mode) vs 2-point finite
    differences over every continuous knob, gated >= 5x and FD
    spot-checked to rtol 1e-4; also records the reverse-mode overhead
    vs the plain forward rollout.
  * ``fleet/hi/B`` — online hierarchical inference
    (``FLEET_BENCH_HI_DEVICES`` / ``FLEET_BENCH_HI_PERIODS``): every
    decision rule rolls the IDENTICAL replayed confidence stream over a
    fleet with heterogeneous per-device ES accuracies — a 9-point
    fixed-threshold sweep on ONE compiled rollout (``theta0`` is a
    leaf), the OGD threshold learner, UCB/EXP3 — and records cumulative
    pseudo-regret trajectories against the offline clairvoyant (gated
    exactly 0.0); at horizons >= 32 periods the learner must beat the
    best fixed grid point.

Every section also folds its numbers into ``BENCH_fleet.json`` (repo root;
override with ``BENCH_FLEET_JSON``).  Sections merge dict-into-dict (one
level per nesting), so a partial run — e.g. the CI smoke job, which only
runs the small device counts — updates its keys and leaves every
previously-recorded key intact (`scripts/check_bench_keys.py` enforces
this in CI).  ``FLEET_BENCH_SCALE_SIZES`` (or the legacy
``FLEET_BENCH_SIZES``) / ``FLEET_BENCH_PERIODS`` /
``FLEET_BENCH_SPEEDUP_DEVICES`` / ``FLEET_BENCH_PARITY_SIZES`` /
``FLEET_BENCH_WARM_SIZES`` shrink (or, for the 100k scale point, grow)
the run for CI smoke jobs.

Standalone:  PYTHONPATH=src python benchmarks/fleet_bench.py
CSV via the harness:  python benchmarks/run.py fleet
"""
from __future__ import annotations

import json
import os
import platform
import time

import numpy as np

PARITY_DEVICES = 64
PARITY_JOBS = 12
SCALE_PERIODS = 20
_BIG = 256            # scale points from here down run fewer periods

_JSON_PATH = os.environ.get(
    "BENCH_FLEET_JSON",
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 "BENCH_fleet.json"))
_RESULTS: dict = {}


def _merge(old, new):
    """Dict-into-dict merge, recursing so a partial run (one device count,
    one policy) never drops previously-recorded sibling keys."""
    if isinstance(old, dict) and isinstance(new, dict):
        out = dict(old)
        for k, v in new.items():
            out[k] = _merge(old.get(k), v) if k in old else v
        return out
    return new


def _record(section: str, payload) -> None:
    """Fold one section's numbers into BENCH_fleet.json.

    Merges into the existing document — recursively for dict payloads, so
    e.g. a 64-device-only smoke run updates ``parity["64"]`` and leaves
    ``parity["256"]`` intact — and rewrites after every section so an
    interrupted run still leaves a valid file.  The in-process accumulator
    merges too (not assigns): a section recorded in several calls — e.g.
    ``scaling()`` re-run for extra sizes in one process — keeps its
    earlier keys even when the on-disk document is unreadable at rewrite
    time (the case where merge-on-write alone cannot recover them)."""
    if isinstance(payload, dict):
        _RESULTS[section] = _merge(_RESULTS.get(section, {}), payload)
    else:
        _RESULTS[section] = payload
    doc = {}
    try:
        with open(_JSON_PATH) as fh:
            doc = json.load(fh)
    except (OSError, ValueError):
        pass
    doc = _merge(doc, {"host": platform.node(),
                       "platform": platform.platform(),
                       "unix_time": time.time(), **_RESULTS})
    with open(_JSON_PATH, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")


def _scale_sizes():
    """Scale-section fleet sizes.  ``FLEET_BENCH_SCALE_SIZES`` wins (the
    opt-in 100k+ knob), then the legacy ``FLEET_BENCH_SIZES`` (the CI
    smoke job's), then the default through the 16k point."""
    for var in ("FLEET_BENCH_SCALE_SIZES", "FLEET_BENCH_SIZES"):
        env = os.environ.get(var)
        if env:
            return tuple(int(x) for x in env.split(","))
    return (256, 1024, 4096, 16384)


def _periods(n_devices: int) -> int:
    cap = int(os.environ.get("FLEET_BENCH_PERIODS", SCALE_PERIODS))
    return min(cap, 5 if n_devices >= _BIG else SCALE_PERIODS)


def _parity_instances(n_devices=PARITY_DEVICES, n_jobs=PARITY_JOBS, seed=0,
                      periods=1):
    """One fleet, `periods` consecutive arrival draws: a list of
    per-period instance lists sharing the same device profiles (the
    warm-start scenario: only the job classes change period to period)."""
    from repro.serving.fleet import make_fleet
    rng = np.random.default_rng(seed)
    specs = make_fleet(n_devices, seed=seed, straggler_frac=0.0,
                       outage_frac=0.0)
    T = 1.2
    rounds = []
    for _ in range(periods):
        insts = []
        for spec in specs:
            classes = rng.choice(spec.profile.classes, size=n_jobs)
            insts.append(spec.profile.instance(classes, T))
        rounds.append(insts)
    if periods == 1:
        return rounds[0], T
    return rounds, T


def _parity_sizes():
    env = os.environ.get("FLEET_BENCH_PARITY_SIZES")
    if env:
        return tuple(int(x) for x in env.split(","))
    return (64, 256)


def _warm_sizes():
    env = os.environ.get("FLEET_BENCH_WARM_SIZES")
    if env:
        return tuple(int(x) for x in env.split(","))
    return (64, 256, 1024)


def _parity_at(n_devices: int):
    """One parity round at a given device count.  Returns (entry, rows)."""
    from repro import api
    from repro.core import InstanceBatch, identical_instance

    insts, T = _parity_instances(n_devices)
    fp = api.FleetProblem.from_batch(InstanceBatch.stack(insts))
    api.solve(fp, policy="amr2")                        # compile once
    t0 = time.perf_counter()
    sol = api.solve(fp, policy="amr2")                  # ONE jit call
    batched_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    oracle = api.solve(fp, policy="amr2", backend="numpy")  # seq. simplex
    oracle_s = time.perf_counter() - t0

    gaps = np.abs(sol.accuracy - oracle.accuracy)
    max_gap = float(gaps.max())
    assert max_gap <= 1e-6, \
        f"batched/oracle accuracy mismatch: {max_gap:.2e}"
    assert float(np.max(sol.makespan)) <= 2 * T + 1e-9, \
        f"2T guarantee violated: {float(np.max(sol.makespan)):.3f} > {2 * T}"

    # --- dual: batched jitted bisection vs NumPy oracle, bit-identical ---
    api.solve(fp, policy="dual")                        # compile once
    t0 = time.perf_counter()
    dual_sol = api.solve(fp, policy="dual")
    dual_batched_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    dual_oracle = api.solve(fp, policy="dual", backend="numpy")
    dual_oracle_s = time.perf_counter() - t0
    np.testing.assert_array_equal(dual_sol.assignment,
                                  dual_oracle.assignment)

    # --- amdp: vmapped CCKP DP vs scalar DP, bit-identical ---------------
    n_ident = min(n_devices, PARITY_DEVICES)  # scalar DP oracle is slow
    ident = [identical_instance(PARITY_JOBS, 2, T=1.0 + 0.05 * (s % 8),
                                seed=s) for s in range(n_ident)]
    ident_fp = api.FleetProblem.from_batch(InstanceBatch.stack(ident))
    api.solve(ident_fp, policy="amdp")                  # compile once
    t0 = time.perf_counter()
    amdp_sol = api.solve(ident_fp, policy="amdp")
    amdp_batched_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    amdp_oracle = api.solve(ident_fp, policy="amdp", backend="numpy")
    amdp_oracle_s = time.perf_counter() - t0
    assert (np.atleast_1d(amdp_sol.solver) == "amdp").all()
    np.testing.assert_array_equal(np.asarray(amdp_sol.status),
                                  np.asarray(amdp_oracle.status))
    np.testing.assert_array_equal(amdp_sol.assignment,
                                  amdp_oracle.assignment)

    n = len(insts)
    entry = {
        "devices": n, "jobs_per_device": PARITY_JOBS,
        "amr2_max_acc_gap": max_gap,
        "amr2_batched_devices_per_s": n / batched_s,
        "amr2_oracle_devices_per_s": n / oracle_s,
        "dual_batched_devices_per_s": n / dual_batched_s,
        "dual_oracle_devices_per_s": n / dual_oracle_s,
        "amdp_batched_devices_per_s": len(ident) / amdp_batched_s,
        "amdp_oracle_devices_per_s": len(ident) / amdp_oracle_s,
        "assertions": "passed",
    }
    rows = [
        (f"fleet/parity/{n}/batched", batched_s / n * 1e6,
         f"devices={n};devices_per_s={n / batched_s:.0f};"
         f"max_acc_gap={max_gap:.1e};single_jit_call=1"),
        (f"fleet/parity/{n}/numpy_oracle", oracle_s / n * 1e6,
         f"devices={n};devices_per_s={n / oracle_s:.0f};"
         f"speedup={oracle_s / batched_s:.1f}x"),
        (f"fleet/parity/{n}/dual_batched", dual_batched_s / n * 1e6,
         f"devices={n};devices_per_s={n / dual_batched_s:.0f};"
         f"speedup_vs_numpy={dual_oracle_s / dual_batched_s:.1f}x;"
         f"assignments=bit_identical"),
        (f"fleet/parity/{n}/amdp_batched", amdp_batched_s / len(ident) * 1e6,
         f"devices={len(ident)};"
         f"devices_per_s={len(ident) / amdp_batched_s:.0f};"
         f"speedup_vs_scalar={amdp_oracle_s / amdp_batched_s:.1f}x;"
         f"assignments=bit_identical"),
    ]
    return entry, rows


def parity():
    """Batched registry solves vs per-device NumPy/scalar oracles — every
    path goes through `repro.api.solve`, the single front door.  Runs at
    BOTH the 64- and 256-device points (the device count is part of the
    BENCH_fleet.json merge key) so the documented 256-device baseline is
    actually reproduced here, not extrapolated from the 64-device run."""
    entries = {}
    out = []
    for n_devices in _parity_sizes():
        entry, rows = _parity_at(n_devices)
        entries[str(n_devices)] = entry
        out.extend(rows)
    _record("parity", entries)
    return out


def warm_cold():
    """Warm-started vs cold batched LP across consecutive fleet periods.

    Period t is solved cold; its per-device optimal bases
    (`Solution.basis`) warm-start period t+1, whose profiles are identical
    but whose arrival classes are freshly drawn — exactly the fleet
    engine's period-to-period situation.  Asserts (a) bit-tight warm/cold
    parity on the LP OBJECTIVE (vertex-invariant), (b) the rounded
    accuracy within AMR^2's own rounding bound of the per-device NumPy
    oracle (warm and cold may land on different optimal vertices of a
    degenerate LP, so exact assignment parity is not guaranteed — the
    observed gap is recorded), and (c) the 2T makespan guarantee; then
    reports warm-vs-cold throughput and the warm-basis acceptance rate."""
    from repro import api
    from repro.core import InstanceBatch
    from repro.core.amr2 import build_lp_arrays_batch
    from repro.core.lp import solve_lp_batch

    entries = {}
    out = []
    reps = 5                    # min-of-reps: the CPU dev hosts time-share
    for n_devices in _warm_sizes():
        (prev, cur), T = _parity_instances(n_devices, periods=2)
        fp_prev = api.FleetProblem.from_batch(InstanceBatch.stack(prev))
        fp = api.FleetProblem.from_batch(InstanceBatch.stack(cur))
        sol_prev = api.solve(fp_prev, policy="amr2")    # period t (cold)
        basis = sol_prev.basis

        api.solve(fp, policy="amr2")                    # compile cold
        api.solve(fp, policy="amr2", warm_start=basis)  # compile warm
        cold_s = min(_timed(lambda: api.solve(fp, policy="amr2"))
                     for _ in range(reps))
        warm_s = min(_timed(lambda: api.solve(
            fp, policy="amr2", warm_start=basis)) for _ in range(reps))
        warm_sol = api.solve(fp, policy="amr2", warm_start=basis)

        oracle = api.solve(fp, policy="amr2", backend="numpy")
        gap = float(np.abs(warm_sol.accuracy - oracle.accuracy).max())
        # rounded accuracies from two optimal vertices of a degenerate LP
        # can legitimately differ (different fractional-job sets), but
        # never by more than AMR^2's own rounding slack per device
        acc = np.asarray(fp.acc)
        round_bound = float((2 * (acc.max(axis=1) - acc.min(axis=1))).max())
        assert gap <= round_bound + 1e-9, \
            f"warm/oracle accuracy gap {gap:.3e} exceeds the AMR2 " \
            f"rounding bound {round_bound:.3e}"
        assert float(np.max(warm_sol.makespan)) <= 2 * T + 1e-9

        # warm acceptance, pivot counts, and timing straight from the LP
        # layer (isolates the simplex gain from the fixed api-side costs:
        # LP-array assembly, canonicalization, rounding)
        c, A_ub, b_ub, A_eq, b_eq = build_lp_arrays_batch(
            InstanceBatch.stack(cur))
        res_w = solve_lp_batch(c, A_ub, b_ub, A_eq, b_eq, warm_basis=basis)
        res_c = solve_lp_batch(c, A_ub, b_ub, A_eq, b_eq)
        # the vertex-invariant check: warm and cold must agree on the LP
        # OBJECTIVE bit-tight even when they sit on different optimal
        # vertices of a degenerate instance
        obj_gap = float(np.abs(res_w.fun - res_c.fun).max())
        assert obj_gap <= 1e-6, \
            f"warm/cold LP objective mismatch: {obj_gap:.3e}"
        lp_warm_s = min(_timed(lambda: solve_lp_batch(
            c, A_ub, b_ub, A_eq, b_eq, warm_basis=basis))
            for _ in range(reps))
        lp_cold_s = min(_timed(lambda: solve_lp_batch(
            c, A_ub, b_ub, A_eq, b_eq)) for _ in range(reps))
        warm_rate = float(np.asarray(res_w.warm).mean())
        n = n_devices
        entry = {
            "devices": n, "jobs_per_device": PARITY_JOBS,
            "warm_max_acc_gap": gap,
            "warm_cold_obj_gap": obj_gap,
            "amr2_cold_devices_per_s": n / cold_s,
            "amr2_warm_devices_per_s": n / warm_s,
            "warm_speedup": cold_s / warm_s,
            "lp_cold_devices_per_s": n / lp_cold_s,
            "lp_warm_devices_per_s": n / lp_warm_s,
            "lp_warm_speedup": lp_cold_s / lp_warm_s,
            "warm_accept_rate": warm_rate,
            "warm_mean_pivots": float(np.asarray(res_w.niter).mean()),
            "cold_mean_pivots": float(np.asarray(res_c.niter).mean()),
            "assertions": "passed",
        }
        entries[str(n)] = entry
        out.append((
            f"fleet/warm_cold/{n}", warm_s / n * 1e6,
            f"devices={n};warm_devices_per_s={n / warm_s:.0f};"
            f"cold_devices_per_s={n / cold_s:.0f};"
            f"speedup={cold_s / warm_s:.1f}x;"
            f"warm_accept_rate={warm_rate:.2f};"
            f"pivots_warm={entry['warm_mean_pivots']:.1f};"
            f"pivots_cold={entry['cold_mean_pivots']:.1f};"
            f"max_acc_gap={gap:.1e}"))
    _record("warm_cold", entries)
    return out


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _engine(n_devices: int, *, policy: str = "auto", seed: int = 7):
    from repro.serving import FleetConfig, FleetEngine
    return FleetEngine.from_config(FleetConfig(
        n_devices=n_devices, T=1.2, n_servers=max(1, n_devices // 16),
        policy=policy, rate=10.0, batch_max=PARITY_JOBS,
        horizon=SCALE_PERIODS, seed=seed))


def _scale_params(n_devices: int, policy: str, periods: int):
    """Engine-v2 params for one scale point: Poisson arrivals (no D x S
    replay trace to materialize at 100k devices) and the reduced-tableau
    LP path for amr2 (the memory shape that admits 100k lanes)."""
    from repro.api import engine as E
    from repro.serving import RequestQueue
    from repro.serving.fleet import make_fleet

    specs = make_fleet(n_devices, seed=7, horizon=max(4, periods))
    queue = RequestQueue(n_devices, (128, 512, 1024), rate=10.0,
                         batch_max=PARITY_JOBS, seed=7)
    params = E.EngineParams.from_fleet(
        specs, queue, T=1.2, n_servers=max(1, n_devices // 16),
        policy=policy, horizon=max(4, periods), arrivals="poisson",
        lp_method="revised" if policy == "amr2" else "tableau")
    return params


def scaling():
    """Engine-v2 `rollout()` throughput + accuracy/violation vs fleet
    size: each point is ONE `lax.scan` over the jitted period step with
    the input state's buffers DONATED (`rollout(..., donate=True)`), amr2
    on the reduced-tableau (``method="revised"``) simplex — the
    100k-lane shape.  Default sizes run through the 16k point (CI-feasible
    on a shared runner); the 100k point is opt-in via
    ``FLEET_BENCH_SCALE_SIZES=102400``.

    Gates: every amr2 point must clear the absolute
    ``FLEET_BENCH_MIN_DEVICES_PER_S`` floor when set (the CI 16k smoke
    pins one), and the 16384-device amr2 point must additionally clear
    ``FLEET_BENCH_SCALE_ANCHOR`` devices/s — default 9900, the
    256-device amr2 rollout anchor the dense-tableau engine measured on
    the 1-core dev host: per-device LP work is constant across fleet
    sizes, so a 64x-larger fleet that can't sustain the small-fleet
    throughput means the planner stopped scaling.  Set it to 0 on
    slower hosts (shared CI runners use the absolute floor instead).
    The opt-in 100k point is recorded but NOT anchored: its admission
    scan is O(n_devices * n_servers) sequential first-fit work (the
    server pool grows with the fleet), which dominates past ~50k
    devices and is outside what the anchor measures.  Each point is
    recorded into BENCH_fleet.json as soon as it is measured, so a
    tripped gate never discards earlier points."""
    import jax

    from repro.api import engine as E

    out = []
    entries: dict = {}  # per-size slices, mirrors what _record has seen
    floor = float(os.environ.get("FLEET_BENCH_MIN_DEVICES_PER_S", 0))
    anchor = float(os.environ.get("FLEET_BENCH_SCALE_ANCHOR", 9900)) or None
    for n_devices in _scale_sizes():
        periods = _periods(n_devices)
        for policy in ("amr2", "dual"):
            params = _scale_params(n_devices, policy, periods)
            # compile the DONATED jit variant (its own cache entry)
            _, M = E.rollout(E.init_state(params), params, periods,
                             donate=True)
            jax.block_until_ready(np.asarray(M.total_accuracy))
            t0 = time.perf_counter()
            # donate a fresh state's buffers: the steady-state rollout
            # shape (the old and new fleet state never coexist)
            _, M = E.rollout(E.init_state(params), params, periods,
                             donate=True)
            acc = np.asarray(M.total_accuracy)
            jax.block_until_ready(acc)
            wall = time.perf_counter() - t0
            n_jobs = int(np.asarray(M.n_jobs).sum())
            dps = n_devices * periods / wall
            entry = {
                "devices": n_devices, "policy": policy, "periods": periods,
                "path": "rollout_scan_donated",
                "lp_method": params.lp_method,
                "jobs": n_jobs,
                "devices_per_s_plan": dps,
                "devices_per_s_wall": dps,
                "mean_job_accuracy": float(acc.sum()) / max(n_jobs, 1),
                "violation_rate": float(np.asarray(M.n_violations).sum())
                / (n_devices * periods),
                "backpressure_rate":
                float(np.asarray(M.n_backpressured).sum())
                / (n_devices * periods),
            }
            # record BEFORE the gates so a tripped assert still leaves
            # the measured point in BENCH_fleet.json
            entries.setdefault(str(n_devices), {})[policy] = entry
            _record("scale", {str(n_devices): {policy: entry}})
            if policy == "amr2" and n_devices == max(_scale_sizes()):
                out.extend(_scale_chaos_point(params, n_devices, periods,
                                              M, wall))
            if policy == "amr2":
                assert int(np.asarray(M.n_unsolved).sum()) == 0, \
                    f"{n_devices}-device rollout left LPs unsolved"
                if floor:
                    assert dps >= floor, \
                        f"{n_devices}-device rollout at {dps:.0f} " \
                        f"devices/s is under the {floor:.0f} floor"
                if anchor is not None and n_devices == 16384:
                    assert dps >= anchor, \
                        f"{n_devices}-device rollout at {dps:.0f} " \
                        f"devices/s is under the 256-device scale " \
                        f"anchor ({anchor:.0f}; FLEET_BENCH_SCALE_ANCHOR)"
            tag = f"fleet/scale/{n_devices}" + (
                "" if policy == "amr2" else f"/{policy}")
            out.append((
                tag, wall / (n_devices * periods) * 1e6,
                f"periods={periods};jobs={n_jobs};"
                f"devices_per_s={dps:.0f};"
                f"lp_method={params.lp_method};donate=1;"
                f"acc_per_job={entry['mean_job_accuracy']:.4f};"
                f"violation_rate={entry['violation_rate']:.4f};"
                f"backpressure_rate={entry['backpressure_rate']:.4f};"
                f"sim_wall_s={wall:.2f}"))
    return out


def _scale_chaos_point(params, n_devices: int, periods: int, M_free,
                       free_wall: float):
    """Armed-chaos companion to the largest scale point: prices the fault
    trace AT SCALE instead of extrapolating from the 64-device chaos
    section.  Armed-null is GATED bitwise-free (same trajectory as the
    fault-free rollout — arming buys only the traced fault block, whose
    overhead is recorded); armed-hot records the full ladder's cost."""
    import dataclasses

    import jax

    from repro.api import engine as E
    from repro.serving import FaultModel

    out = []
    entry: dict = {"devices": n_devices, "periods": periods}
    for tag, fm in (("armed_null", FaultModel.none()),
                    ("armed_hot", FaultModel.make(
                        link_degrade_prob=0.2, link_degrade_mag=0.6,
                        straggler_prob=0.15, straggler_mult=1.8,
                        loss_rate=0.05))):
        p = dataclasses.replace(params, faults=fm, chaos=True,
                                fault_seed=11)
        _, M = E.rollout(E.init_state(p), p, periods,
                         donate=True)                      # compile
        jax.block_until_ready(np.asarray(M.total_accuracy))
        t0 = time.perf_counter()
        _, M = E.rollout(E.init_state(p), p, periods, donate=True)
        jax.block_until_ready(np.asarray(M.total_accuracy))
        wall = time.perf_counter() - t0
        dps = n_devices * periods / wall
        if tag == "armed_null":
            for f in ("total_accuracy", "n_jobs", "n_violations",
                      "n_offloading", "n_backpressured", "backlog",
                      "es_utilization"):
                assert np.array_equal(np.asarray(getattr(M, f)),
                                      np.asarray(getattr(M_free, f))), \
                    f"armed-null chaos at {n_devices} devices diverged " \
                    f"from the fault-free rollout on {f}"
            entry[tag] = {
                "devices_per_s_wall": dps,
                "overhead_vs_fault_free": free_wall / wall,
                "parity": "bitwise_vs_fault_free",
            }
        else:
            entry[tag] = {
                "devices_per_s_wall": dps,
                "overhead_vs_fault_free": free_wall / wall,
                "n_retries": int(np.asarray(M.n_retries).sum()),
                "n_fallback_local":
                    int(np.asarray(M.n_fallback_local).sum()),
                "n_dropped": int(np.asarray(M.n_dropped).sum()),
                "n_deadline_miss":
                    int(np.asarray(M.n_deadline_miss).sum()),
                "n_es_audit_updates":
                    int(np.asarray(M.n_es_audit_updates).sum()),
                "worst_realized_makespan":
                    float(np.asarray(M.realized_makespan).max()),
            }
        out.append((
            f"fleet/scale/{n_devices}/chaos_{tag.split('_')[1]}",
            wall / (n_devices * periods) * 1e6,
            f"devices={n_devices};devices_per_s={dps:.0f};"
            f"free_ratio={free_wall / wall:.2f}" + (
                ";parity=bitwise" if tag == "armed_null" else
                f";es_audit_updates={entry[tag]['n_es_audit_updates']}")))
    _record("scale", {str(n_devices): {"chaos": entry}})
    return out


def speedup():
    """Vectorized engine vs the PR-1 per-device reference loop at the
    256-device scale point (or FLEET_BENCH_SPEEDUP_DEVICES).

    Two kinds of comparison, kept separate so the loop gain is not
    conflated with a solver/policy change:

      * *loop speedup* — the scanned `engine.rollout` vs
        `run_period_reference` under the SAME policy (amr2/amr2 and
        dual/dual), isolating the array-resident single-scan path against
        the per-device Python loop;
      * *path speedup* — the new hot path (`engine.rollout`, ONE lax.scan
        with donated state buffers; amr2 on the reduced-tableau simplex)
        against the PR-1 serving configuration (`run_period_reference`,
        policy "auto"), the number the ROADMAP tracks.  The reference
        loop's `solve_many` itself already benefits from the batched
        solvers, so this UNDERSTATES the gain over the literal PR-1 code.

    The scan path has no separate per-period planning phase, so its
    ``devices_per_s_plan`` equals its wall number.
    """
    import jax

    from repro.api import engine as E

    n = int(os.environ.get("FLEET_BENCH_SPEEDUP_DEVICES", _BIG))
    periods = _periods(n)

    def _run(policy: str, reference: bool):
        engine = _engine(n, policy=policy)
        step = (engine.run_period_reference if reference
                else engine.run_period)
        step()                                          # compile once
        engine.history.clear()
        t0 = time.perf_counter()
        for _ in range(periods):
            step()
        wall = time.perf_counter() - t0
        s = engine.summary()
        return {
            "devices_per_s_plan": s["devices_per_second"],
            "devices_per_s_wall": n * periods / wall,
            "mean_job_accuracy": s["mean_job_accuracy"],
            "violation_rate": s["violation_rate"],
        }

    def _run_scan(policy: str):
        params = _scale_params(n, policy, periods)
        _, M = E.rollout(E.init_state(params), params, periods,
                         donate=True)              # compile (donated jit)
        jax.block_until_ready(np.asarray(M.total_accuracy))
        t0 = time.perf_counter()
        _, M = E.rollout(E.init_state(params), params, periods,
                         donate=True)
        acc = np.asarray(M.total_accuracy)
        jax.block_until_ready(acc)
        wall = time.perf_counter() - t0
        n_jobs = int(np.asarray(M.n_jobs).sum())
        dps = n * periods / wall
        return {
            "devices_per_s_plan": dps,      # scan: plan == wall (one call)
            "devices_per_s_wall": dps,
            "mean_job_accuracy": float(acc.sum()) / max(n_jobs, 1),
            "violation_rate": float(np.asarray(M.n_violations).sum())
            / (n * periods),
        }

    pr1 = _run("auto", reference=True)        # the PR-1 serving config
    ref_amr2 = _run("amr2", reference=True)
    ref_dual = _run("dual", reference=True)
    new_amr2 = _run_scan("amr2")
    new_dual = _run_scan("dual")

    def _ratio(a, b, key):
        return a[key] / max(b[key], 1e-12)

    entry = {
        "devices": n, "periods": periods,
        "pr1_reference_auto": pr1,
        "reference_amr2": ref_amr2,
        "reference_dual": ref_dual,
        "vectorized_amr2": new_amr2,
        "vectorized_dual": new_dual,
        # same-policy pairs: the array-resident loop in isolation
        "amr2_loop_speedup_wall": _ratio(new_amr2, ref_amr2,
                                         "devices_per_s_wall"),
        "dual_loop_speedup_wall": _ratio(new_dual, ref_dual,
                                         "devices_per_s_wall"),
        # hot path vs the PR-1 serving configuration
        "amr2_speedup_plan": _ratio(new_amr2, pr1, "devices_per_s_plan"),
        "amr2_speedup_wall": _ratio(new_amr2, pr1, "devices_per_s_wall"),
        "dual_speedup_plan": _ratio(new_dual, pr1, "devices_per_s_plan"),
        "dual_speedup_wall": _ratio(new_dual, pr1, "devices_per_s_wall"),
        "dual_accuracy_delta": (new_dual["mean_job_accuracy"]
                                - pr1["mean_job_accuracy"]),
    }
    _record("speedup", {str(n): entry})
    return [
        ("fleet/speedup/pr1_reference", 1e6
         / max(pr1["devices_per_s_wall"], 1e-9),
         f"devices={n};devices_per_s={pr1['devices_per_s_wall']:.0f};"
         f"policy=auto;path=per_device"),
        ("fleet/speedup/vectorized_amr2", 1e6
         / max(new_amr2["devices_per_s_wall"], 1e-9),
         f"devices={n};devices_per_s={new_amr2['devices_per_s_wall']:.0f};"
         f"loop_speedup={entry['amr2_loop_speedup_wall']:.1f}x;"
         f"vs_pr1={entry['amr2_speedup_wall']:.1f}x"),
        ("fleet/speedup/vectorized_dual", 1e6
         / max(new_dual["devices_per_s_wall"], 1e-9),
         f"devices={n};devices_per_s={new_dual['devices_per_s_wall']:.0f};"
         f"loop_speedup={entry['dual_loop_speedup_wall']:.1f}x;"
         f"vs_pr1={entry['dual_speedup_wall']:.1f}x;"
         f"acc_delta={entry['dual_accuracy_delta']:+.4f}"),
    ]


def rollout():
    """Engine-v2 rollout (ONE lax.scan over the jitted period step) vs the
    per-period `run()` loop at the 256-device point
    (``FLEET_BENCH_ROLLOUT_DEVICES`` / ``FLEET_BENCH_ROLLOUT_PERIODS``),
    for both traceable policies.

    Three timed paths per policy over the same replayed arrival trace:

      * *host_loop* — `run()` with engine-v2 delegation disabled: the
        pre-v2 per-period pipeline (batched api solves + host
        admission/replan/audit), the baseline the >= 2x acceptance gate
        is against;
      * *delegated* — `run()` as shipped: per-period calls into the same
        jitted core the scan uses (host queue + stats bookkeeping per
        period);
      * *scan* — `engine.rollout`: the whole epoch in one traced call,
        zero per-period host sync.

    The scan and the delegated loop are first pinned BIT-IDENTICAL on
    every trajectory (the engine-v2 parity contract), then timed (min
    over ``reps``).  The >= 2x gate binds on the dual policy, where the
    planner is cheap and the loop's per-period host work dominates; for
    amr2 the step is LP-compute-bound on CPU, so removing the host loop
    buys ~1.3-1.7x steady-state — both numbers are recorded."""
    import jax
    import numpy as np

    from repro.api import engine as E
    from repro.serving import FleetConfig, FleetEngine

    n = int(os.environ.get("FLEET_BENCH_ROLLOUT_DEVICES", _BIG))
    periods = int(os.environ.get("FLEET_BENCH_ROLLOUT_PERIODS", 32))
    reps = 3
    entries = {}
    out = []

    for policy in ("amr2", "dual"):
        def mkcfg():
            return FleetConfig(
                n_devices=n, T=1.2, n_servers=max(1, n // 16),
                policy=policy, rate=10.0, batch_max=PARITY_JOBS,
                horizon=periods + 2, seed=7)

        params = E.EngineParams.from_config(mkcfg(), horizon=periods + 2)
        state = E.init_state(params)

        # --- parity pin: scan == per-period delegated loop, bit for bit -
        _, metrics = E.rollout(state, params, periods)    # also compiles
        eng = FleetEngine.from_config(mkcfg())
        stats = eng.run(periods)
        for f in ("n_jobs", "n_violations", "n_offloading",
                  "n_backpressured", "n_outage", "n_straggler_updates",
                  "backlog"):
            got = np.asarray(getattr(metrics, f))
            want = np.array([getattr(s, f) for s in stats])
            assert np.array_equal(got, want), \
                f"rollout/run() {policy} trajectory mismatch on {f}"
        acc_gap = float(np.abs(
            np.asarray(metrics.total_accuracy)
            - np.array([s.total_accuracy for s in stats])).max())
        assert acc_gap == 0.0, \
            f"rollout/run() {policy} accuracy gap {acc_gap}"

        def _time_scan():
            t0 = time.perf_counter()
            _, M = E.rollout(state, params, periods)
            jax.block_until_ready(np.asarray(M.total_accuracy))
            return time.perf_counter() - t0

        def _time_run(disable_delegation):
            best = np.inf
            for _ in range(reps):
                import dataclasses
                e = FleetEngine.from_config(dataclasses.replace(
                    mkcfg(), delegate=not disable_delegation))
                e.run_period()              # compile / warm caches
                e.history.clear()
                t0 = time.perf_counter()
                e.run(periods)
                best = min(best, time.perf_counter() - t0)
            return best

        scan_s = min(_time_scan() for _ in range(reps))
        delegated_s = _time_run(False)
        host_loop_s = _time_run(True)

        dps = lambda s: n * periods / s
        entry = {
            "devices": n, "periods": periods, "policy": policy,
            "parity": "bit_identical_vs_delegated_run",
            "scan_devices_per_s_wall": dps(scan_s),
            "delegated_loop_devices_per_s_wall": dps(delegated_s),
            "host_loop_devices_per_s_wall": dps(host_loop_s),
            "scan_speedup_vs_host_loop": host_loop_s / scan_s,
            "scan_speedup_vs_delegated_loop": delegated_s / scan_s,
        }
        if policy == "dual":
            assert entry["scan_speedup_vs_host_loop"] >= 2.0, \
                f"dual rollout scan only " \
                f"{entry['scan_speedup_vs_host_loop']:.2f}x over the " \
                f"per-period host run() loop (acceptance floor: 2x)"
        entries[policy] = entry
        out.extend([
            (f"fleet/rollout/{n}/{policy}/scan",
             scan_s / (n * periods) * 1e6,
             f"devices={n};periods={periods};"
             f"devices_per_s={dps(scan_s):.0f};"
             f"single_lax_scan=1;parity=bit_identical"),
            (f"fleet/rollout/{n}/{policy}/delegated_loop",
             delegated_s / (n * periods) * 1e6,
             f"devices={n};devices_per_s={dps(delegated_s):.0f};"
             f"scan_speedup="
             f"{entry['scan_speedup_vs_delegated_loop']:.2f}x"),
            (f"fleet/rollout/{n}/{policy}/host_loop",
             host_loop_s / (n * periods) * 1e6,
             f"devices={n};devices_per_s={dps(host_loop_s):.0f};"
             f"scan_speedup={entry['scan_speedup_vs_host_loop']:.2f}x"),
        ])
    _record("rollout", {str(n): entries})
    return out


def sharded():
    """`rollout_sharded` (shard_map over the fleet axis) vs the unsharded
    scan, keyed by shard x device count.  Needs > 1 jax device — spawn
    host-platform devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
    sharded smoke job does); on a single-device host the section reports
    a skip and records nothing (merge-on-write keeps any previously
    recorded keys)."""
    import jax
    import numpy as np

    from repro.api import engine as E
    from repro.serving import FleetConfig

    n_shards = len(jax.devices())
    if n_shards < 2:
        return [("fleet/sharded/skipped", 0.0,
                 "reason=single_jax_device;hint=XLA_FLAGS="
                 "--xla_force_host_platform_device_count=8")]
    n = int(os.environ.get("FLEET_BENCH_SHARD_DEVICES", 64))
    periods = int(os.environ.get("FLEET_BENCH_ROLLOUT_PERIODS", 32))
    reps = 3

    cfg = FleetConfig(
        n_devices=n, T=1.2, n_servers=max(1, n // 16), policy="amr2",
        rate=10.0, batch_max=PARITY_JOBS, horizon=periods + 2, seed=7)
    params = E.EngineParams.from_config(cfg, horizon=periods + 2)
    state = E.init_state(params)
    mesh = E.fleet_mesh(n_shards)
    sstate, sparams = E.shard(state, params, mesh)

    _, MU = E.rollout(state, params, periods)             # compile
    _, MS = E.rollout_sharded(sstate, sparams, periods, mesh)
    for f in ("n_jobs", "n_violations", "n_offloading", "n_backpressured",
              "backlog"):
        assert np.array_equal(np.asarray(getattr(MS, f)),
                              np.asarray(getattr(MU, f))), \
            f"sharded/unsharded mismatch on {f}"
    acc_gap = float(np.abs(np.asarray(MS.total_accuracy)
                           - np.asarray(MU.total_accuracy)).max())
    assert acc_gap <= 1e-9 * max(
        1.0, float(np.abs(np.asarray(MU.total_accuracy)).max())), \
        f"sharded accuracy gap {acc_gap:.2e}"

    def _timed_roll(fn):
        best = np.inf
        for _ in range(reps):
            t0 = time.perf_counter()
            _, M = fn()
            jax.block_until_ready(np.asarray(M.total_accuracy))
            best = min(best, time.perf_counter() - t0)
        return best

    unsharded_s = _timed_roll(lambda: E.rollout(state, params, periods))
    sharded_s = _timed_roll(
        lambda: E.rollout_sharded(sstate, sparams, periods, mesh))
    dps = lambda s: n * periods / s
    entry = {
        "devices": n, "periods": periods, "n_shards": n_shards,
        "parity": "matches_unsharded",
        "max_accuracy_gap": acc_gap,
        "unsharded_devices_per_s_wall": dps(unsharded_s),
        "sharded_devices_per_s_wall": dps(sharded_s),
        "shard_speedup": unsharded_s / sharded_s,
    }
    _record("sharded", {f"{n_shards}x{n}": entry})
    return [
        (f"fleet/sharded/{n_shards}x{n}", sharded_s / (n * periods) * 1e6,
         f"devices={n};shards={n_shards};"
         f"devices_per_s={dps(sharded_s):.0f};"
         f"speedup_vs_unsharded={unsharded_s / sharded_s:.2f}x;"
         f"max_acc_gap={acc_gap:.1e}"),
    ]


def chaos():
    """Graceful degradation under injected faults, at the 64-device point
    (``FLEET_BENCH_CHAOS_DEVICES`` / ``FLEET_BENCH_CHAOS_PERIODS``).

    Three pieces, all on the scanned `engine.rollout` path:

      * *armed-null parity* — chaos=True with the all-zero FaultModel
        must reproduce the fault-free rollout BIT for BIT (identity
        factors and zero losses are exact in float64), so arming the
        subsystem costs nothing but the traced fault block;
      * *loss sweep* — offload loss 0% -> 40% on ONE compiled rollout
        (rates are leaves, only the armed trace compiles once).  Gates:
        the per-period accounting identity ``admitted == completed +
        fallback + dropped`` closes exactly at every point, realized
        makespans stay under ``2T + backoff_cap + one retransmission of
        the worst admitted demand``, and the 10%-loss point retains
        >= 90% of the fault-free accuracy — the retry + local-fallback
        ladder flattens the loss cliff instead of dropping work;
      * *harsh* — crash + link-degrade + straggler + loss all armed at
        once: the worst-case regime the README documents (deadline
        misses are EXPECTED here — the point is they are counted, not
        hidden)."""
    import dataclasses

    import jax

    from repro.api import engine as E
    from repro.serving import FaultModel, FleetConfig

    n = int(os.environ.get("FLEET_BENCH_CHAOS_DEVICES", 64))
    periods = int(os.environ.get("FLEET_BENCH_CHAOS_PERIODS", 12))
    T = 1.2
    cfg = FleetConfig(
        n_devices=n, T=T, n_servers=max(1, n // 16), policy="amr2",
        rate=10.0, batch_max=PARITY_JOBS, horizon=periods + 2, seed=7,
        fault_seed=11)
    base = E.EngineParams.from_config(cfg, horizon=periods + 2)
    assert not base.chaos
    out = []

    # --- armed-null bitwise parity -------------------------------------
    _, m0 = E.rollout(E.init_state(base), base, periods)
    armed = dataclasses.replace(base, faults=FaultModel.none(), chaos=True)
    t0 = time.perf_counter()
    _, m1 = E.rollout(E.init_state(armed), armed, periods)
    jax.block_until_ready(np.asarray(m1.total_accuracy))
    armed_s = time.perf_counter() - t0
    for f in ("total_accuracy", "n_jobs", "n_violations", "n_offloading",
              "backlog", "realized_makespan"):
        assert np.array_equal(np.asarray(getattr(m0, f)),
                              np.asarray(getattr(m1, f))), \
            f"armed-null chaos rollout diverged from fault-free on {f}"
    acc0 = float(np.asarray(m0.total_accuracy).sum())
    jobs0 = int(np.asarray(m0.n_jobs).sum())

    # realized-makespan bound for loss-only models: no link degradation,
    # so one retry round retransmits at most the worst admitted demand
    demand_cap = float(np.asarray(base.p_es).max()) * base.batch_max

    def _gated_run(params, worst_link):
        _, M = E.rollout(E.init_state(params), params, periods)
        n_off = np.asarray(M.n_offload_samples)
        closed = (n_off == np.asarray(M.n_offload_ok)
                  + np.asarray(M.n_fallback_local)
                  + np.asarray(M.n_dropped))
        assert closed.all(), "per-period offload accounting did not close"
        cap = float(params.faults.backoff_cap)
        bound = 2.0 * T + cap + demand_cap * worst_link
        worst = float(np.asarray(M.realized_makespan).max())
        assert worst <= bound + 1e-9, \
            f"realized makespan {worst:.3f} exceeds the ladder bound " \
            f"{bound:.3f} (2T + backoff cap + one retransmission)"
        return M, worst

    # --- offload-loss sweep on the one armed trace ---------------------
    sweep = {}
    for loss in (0.0, 0.05, 0.1, 0.2, 0.4):
        p = dataclasses.replace(armed,
                                faults=FaultModel.make(loss_rate=loss))
        M, worst = _gated_run(p, worst_link=1.0)
        acc = float(np.asarray(M.total_accuracy).sum())
        entry = {
            "loss_rate": loss,
            "accuracy_vs_fault_free": acc / max(acc0, 1e-12),
            "total_accuracy": acc,
            "n_retries": int(np.asarray(M.n_retries).sum()),
            "n_fallback_local": int(np.asarray(M.n_fallback_local).sum()),
            "n_dropped": int(np.asarray(M.n_dropped).sum()),
            "n_deadline_miss": int(np.asarray(M.n_deadline_miss).sum()),
            "worst_realized_makespan": worst,
        }
        sweep[f"{loss:g}"] = entry
        out.append((
            f"fleet/chaos/loss_{loss:g}", 0.0,
            f"devices={n};acc_ratio={entry['accuracy_vs_fault_free']:.4f};"
            f"retries={entry['n_retries']};"
            f"fallback={entry['n_fallback_local']};"
            f"dropped={entry['n_dropped']};"
            f"worst_makespan={worst:.3f}"))
    assert sweep["0"]["accuracy_vs_fault_free"] == 1.0, \
        "zero-rate sweep point must reproduce the fault-free accuracy"
    assert sweep["0.1"]["accuracy_vs_fault_free"] >= 0.90, \
        f"10% offload loss dropped accuracy to " \
        f"{sweep['0.1']['accuracy_vs_fault_free']:.3f}x fault-free — " \
        f"the degradation ladder should hold >= 0.90x (no cliff)"

    # --- harsh regime: everything armed at once ------------------------
    harsh_fm = FaultModel.make(es_crash_prob=0.08, link_degrade_prob=0.25,
                               link_degrade_mag=0.6, straggler_prob=0.2,
                               straggler_mult=1.8, loss_rate=0.15)
    M, worst = _gated_run(
        dataclasses.replace(armed, faults=harsh_fm),
        worst_link=1.0 + float(harsh_fm.link_degrade_mag))
    acc = float(np.asarray(M.total_accuracy).sum())
    harsh = {
        "accuracy_vs_fault_free": acc / max(acc0, 1e-12),
        "n_retries": int(np.asarray(M.n_retries).sum()),
        "n_fallback_local": int(np.asarray(M.n_fallback_local).sum()),
        "n_dropped": int(np.asarray(M.n_dropped).sum()),
        "n_deadline_miss": int(np.asarray(M.n_deadline_miss).sum()),
        "deadline_miss_rate": int(np.asarray(M.n_deadline_miss).sum())
        / max(jobs0, 1),
        "worst_realized_makespan": worst,
    }
    assert harsh["n_retries"] + harsh["n_fallback_local"] \
        + harsh["n_dropped"] > 0, "harsh fault model never fired"

    _record("chaos", {
        "devices": n, "periods": periods, "jobs": jobs0,
        "armed_null_parity": "bitwise",
        "armed_null_devices_per_s": n * periods / armed_s,
        "loss_sweep": sweep, "harsh": harsh,
        "assertions": "passed",
    })
    out.append((
        f"fleet/chaos/harsh", 0.0,
        f"devices={n};acc_ratio={harsh['accuracy_vs_fault_free']:.4f};"
        f"miss_rate={harsh['deadline_miss_rate']:.4f};"
        f"dropped={harsh['n_dropped']};worst_makespan={worst:.3f}"))
    return out


def _mobility_sizes():
    env = os.environ.get("FLEET_BENCH_MOBILITY_SIZES")
    if env:
        return tuple(int(x) for x in env.split(","))
    return (4096, 16384)


def mobility():
    """The multi-cell mobility subsystem at scale (`core.mobility`).

    Two pieces per device count (``FLEET_BENCH_MOBILITY_SIZES``; the
    102400 point is opt-in, like the scale section's):

      * *admission microbench* — the OLD global sequential first-fit scan
        (`admit_mask_jnp`: one `lax.scan` step per device, each step an
        argmin over `n_servers` — the O(D x S) wall the ROADMAP names as
        the entire 100k gap) against the NEW segmented per-cell
        formulation (`admit_mask_segmented`: sorts + cumsums, no
        sequential pass) on the same demand vector.  Both jitted, both
        admitting into ``D // 16`` servers.  Gated: at >= 16384 devices
        the segmented scan must beat the global scan.
      * *mobility-armed rollout* — the full engine with a replayed
        3-cell-per-128-device trace (routing + handover + segmented
        admission + ES-belief plumbing) at the LARGEST size, reported as
        devices/s alongside the scale section's single-pool number.  The
        opt-in 102400 point is gated on beating the recorded single-pool
        scan there (``FLEET_BENCH_MOBILITY_ANCHOR`` devices/s, default
        8100 — the ~8.1k devices/s the global-admission engine measured),
        closing the ROADMAP's "segmented/hierarchical admission scan"
        rung."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.api import engine as E
    from repro.core.mobility import MobilityModel, admit_mask_segmented

    out = []
    entries: dict = {}
    sizes = _mobility_sizes()
    anchor = float(os.environ.get("FLEET_BENCH_MOBILITY_ANCHOR", 8100))
    reps = 3
    rng = np.random.default_rng(0)
    T = 1.2
    with enable_x64():
        for n in sizes:
            n_servers = max(1, n // 16)
            S = 16 if n_servers % 16 == 0 else 1
            k = n_servers // S
            demands = jnp.asarray(np.where(
                rng.random(n) < 0.3, 0.0, rng.uniform(0.0, 1.5, n)))
            cell = jnp.asarray(rng.integers(0, S, n).astype(np.int32))
            glob = jax.jit(lambda d: E.admit_mask_jnp(d, T, n_servers))
            seg = jax.jit(lambda d, c: admit_mask_segmented(
                d, c, T, S, k))
            jax.block_until_ready(glob(demands))           # compile
            jax.block_until_ready(seg(demands, cell))
            glob_s = min(_timed(lambda: jax.block_until_ready(
                glob(demands))) for _ in range(reps))
            seg_s = min(_timed(lambda: jax.block_until_ready(
                seg(demands, cell))) for _ in range(reps))
            speedup_x = glob_s / seg_s
            entry = {
                "devices": n, "n_servers": n_servers, "n_cells": S,
                "global_scan_s": glob_s, "segmented_s": seg_s,
                "segmented_speedup": speedup_x,
            }
            if n >= 16384:
                assert speedup_x > 1.0, \
                    f"segmented admission ({seg_s * 1e3:.1f} ms) did not " \
                    f"beat the global sequential scan " \
                    f"({glob_s * 1e3:.1f} ms) at {n} devices"
            entries[str(n)] = {"admission": entry}
            _record("mobility", {str(n): {"admission": entry}})
            out.append((
                f"fleet/mobility/admission/{n}", seg_s / n * 1e6,
                f"devices={n};cells={S};servers={n_servers};"
                f"segmented_ms={seg_s * 1e3:.2f};"
                f"global_scan_ms={glob_s * 1e3:.2f};"
                f"speedup={speedup_x:.1f}x"))

    # --- mobility-armed rollout at the largest point ---------------------
    n = max(sizes)
    periods = _periods(n)
    params = _scale_params(n, "amr2", periods)
    n_servers = params.n_servers
    S = 16 if n_servers % 16 == 0 else 1
    cxy = np.stack([20.0 * np.array([i % 4, i // 4]) for i in range(S)])
    dev_home = cxy[rng.integers(0, S, n)]
    trace = (rng.normal(scale=6.0, size=(max(4, periods), n, 2))
             + dev_home)
    mob = MobilityModel.make(cell_xy=cxy, trace=trace, radius=30.0,
                             link_alpha=0.2)
    armed = params.with_mobility(mob, routing="nearest")
    _, M = E.rollout(E.init_state(armed), armed, periods,
                     donate=True)                          # compile
    jax.block_until_ready(np.asarray(M.total_accuracy))
    t0 = time.perf_counter()
    _, M = E.rollout(E.init_state(armed), armed, periods, donate=True)
    jax.block_until_ready(np.asarray(M.total_accuracy))
    wall = time.perf_counter() - t0
    dps = n * periods / wall
    n_jobs = int(np.asarray(M.n_jobs).sum())
    entry = {
        "devices": n, "periods": periods, "n_cells": S,
        "policy": "amr2", "routing": "nearest", "path":
        "rollout_scan_donated_segmented_admission",
        "devices_per_s_wall": dps,
        "n_handover": int(np.asarray(M.n_handover).sum()),
        "mean_job_accuracy": float(np.asarray(M.total_accuracy).sum())
        / max(n_jobs, 1),
        "violation_rate": float(np.asarray(M.n_violations).sum())
        / (n * periods),
    }
    _record("mobility", {str(n): {"rollout": entry}})
    if n >= 102400:
        assert dps > anchor, \
            f"102400-device mobility rollout at {dps:.0f} devices/s did " \
            f"not improve on the recorded global-admission engine " \
            f"(~{anchor:.0f} devices/s; FLEET_BENCH_MOBILITY_ANCHOR)"
    out.append((
        f"fleet/mobility/rollout/{n}", wall / (n * periods) * 1e6,
        f"devices={n};cells={S};periods={periods};"
        f"devices_per_s={dps:.0f};"
        f"handovers={entry['n_handover']};"
        f"acc_per_job={entry['mean_job_accuracy']:.4f};"
        f"violation_rate={entry['violation_rate']:.4f}"))
    return out


def grad():
    """The differentiable serving stack at the 256-device point
    (``FLEET_BENCH_GRAD_DEVICES`` / ``FLEET_BENCH_GRAD_PERIODS``).

    One `rollout_value_and_grad` pass (soft mode, implicit-gradient
    simplex + smoothed rounding/admission) returns d(total accuracy)/d
    for EVERY continuous knob — all of ``p_es``, ``T``, and ``acc`` — in
    a single backward sweep.  The honest baseline is central (2-point)
    finite differences, which needs TWO rollouts per scalar knob; the
    recorded ``speedup_vs_fd`` is ``2 * n_knobs * forward_wall /
    grad_wall`` and is gated >= 5x (it lands orders of magnitude higher
    — the gate just keeps the mechanism honest if the knob set ever
    shrinks to a handful).  Also records the reverse-mode overhead
    (``grad_wall / forward_wall``, the classic 2-5x band for a scanned
    epoch) and a 3-coordinate FD spot-check at rtol 1e-4 so the recorded
    gradient is demonstrably the right one, not just a fast one."""
    import dataclasses

    import jax

    from repro.api import engine as E
    from repro.serving import FleetConfig

    n = int(os.environ.get("FLEET_BENCH_GRAD_DEVICES", _BIG))
    periods = int(os.environ.get("FLEET_BENCH_GRAD_PERIODS", 5))
    reps = 3
    cfg = FleetConfig(
        n_devices=n, T=1.2, n_servers=max(1, n // 16), policy="amr2",
        rate=10.0, batch_max=PARITY_JOBS, horizon=periods + 2, seed=7)
    base = E.EngineParams.from_config(cfg, horizon=periods + 2)
    # jitter p_es off the LP vertex kinks (see tests/test_grad.py): FD
    # and the implicit gradient must measure the same linearity region
    rng = np.random.default_rng(7)
    arr = np.asarray(base.p_es, np.float64)
    nudge = (rng.uniform(1e-3, 3e-3, size=arr.shape)
             * rng.choice([-1.0, 1.0], size=arr.shape))
    params = dataclasses.replace(base, p_es=arr + nudge
                                 ).with_differentiable(smooth_mode="soft")
    wrt = ("p_es", "T", "acc")
    n_knobs = int(np.asarray(params.p_es).size
                  + np.asarray(params.acc).size + 1)

    def fwd():
        _, M = E.rollout(E.init_state(params), params, periods)
        jax.block_until_ready(np.asarray(M.total_accuracy))
        return float(np.asarray(M.total_accuracy).sum())

    def vag():
        val, g = E.rollout_value_and_grad(
            E.init_state(params), params, periods, wrt=wrt)
        jax.block_until_ready(np.asarray(g["p_es"]))
        return val, g

    fwd()                                                  # compile
    val, grads = vag()                                     # compile
    fwd_s = min(_timed(fwd) for _ in range(reps))
    grad_s = min(_timed(vag) for _ in range(reps))
    speedup_x = 2 * n_knobs * fwd_s / grad_s
    assert speedup_x >= 5.0, \
        f"value_and_grad at {grad_s * 1e3:.0f} ms is only {speedup_x:.1f}x " \
        f"over 2-point FD of all {n_knobs} knobs (acceptance floor: 5x)"

    # FD spot-check: the recorded gradient is correct, not just fast
    def _value_at(leaf, idx, eps):
        a = np.asarray(getattr(params, leaf), np.float64)
        flat = np.atleast_1d(a).ravel().copy()
        flat[idx] += eps
        rep = flat.reshape(np.shape(a)) if np.shape(a) else float(flat[0])
        p = dataclasses.replace(params, **{leaf: rep})
        _, M = E.rollout(E.init_state(p), p, periods)
        return float(np.asarray(M.total_accuracy).sum())

    checked = 0
    for leaf, idx in (("p_es", int(rng.integers(arr.size))), ("T", 0),
                      ("acc", int(rng.integers(
                          np.asarray(params.acc).size)))):
        an = float(np.atleast_1d(
            np.asarray(grads[leaf], np.float64)).ravel()[idx])
        eps = 1e-5
        fd_v = (_value_at(leaf, idx, eps)
                - _value_at(leaf, idx, -eps)) / (2 * eps)
        err = abs(fd_v - an)
        assert err < 1e-6 or err / max(abs(fd_v), abs(an)) < 1e-4, \
            f"grad({leaf}[{idx}]) = {an} but central FD = {fd_v}"
        checked += 1

    entry = {
        "devices": n, "periods": periods, "n_knobs": n_knobs,
        "smooth_mode": "soft", "wrt": list(wrt),
        "value": float(val),
        "grad_norm_p_es": float(np.linalg.norm(
            np.asarray(grads["p_es"], np.float64))),
        "forward_wall_s": fwd_s,
        "grad_wall_s": grad_s,
        "grad_overhead_vs_forward": grad_s / fwd_s,
        "speedup_vs_fd": speedup_x,
        "fd_spot_checks_passed": checked,
        "assertions": "passed",
    }
    _record("grad", {str(n): entry})
    return [(
        f"fleet/grad/{n}", grad_s / (n * periods) * 1e6,
        f"devices={n};periods={periods};knobs={n_knobs};"
        f"grad_ms={grad_s * 1e3:.0f};fwd_ms={fwd_s * 1e3:.0f};"
        f"overhead={grad_s / fwd_s:.2f}x;"
        f"speedup_vs_fd={speedup_x:.0f}x;fd_checks={checked}")]


def hi():
    """Online hierarchical inference vs the offline clairvoyant
    (``FLEET_BENCH_HI_DEVICES`` / ``FLEET_BENCH_HI_PERIODS``, default
    256 x 64).

    The fleet gets HETEROGENEOUS per-device ES accuracies (drawn in
    [0.65, 0.92] — the regime of the online problem, where no shared
    threshold can be right for every device), and every rule replays the
    IDENTICAL confidence stream (one ``hi_seed``; the stream folds its
    own key, so rules differ only in their decisions):

      * a fixed-threshold sweep over the 9-point bandit grid — scalar
        ``theta0`` is a pytree leaf, so all 9 points reuse ONE compiled
        rollout;
      * the OGD threshold learner, UCB, and EXP3;
      * the clairvoyant (rule="fixed" with per-device ``theta0 =
        clip(acc_es - beta, 0, 1)``), whose cumulative pseudo-regret is
        gated EXACTLY 0.0 — the regret metric's floor is the offline
        per-sample optimum, the role AMR^2 plays for the planned path.

    Gates: the clairvoyant floor, the per-period serving identity
    (n_hi_offloaded + n_hi_local_final == n_jobs), and — at any horizon
    >= 32 periods — the threshold learner's cumulative regret beating
    the BEST fixed grid point's (sublinear vs linear growth; the learner
    converges per device, a shared threshold cannot)."""
    import dataclasses

    from repro.api import engine as E
    from repro.core.hi import HIModel
    from repro.serving import FleetConfig

    n = int(os.environ.get("FLEET_BENCH_HI_DEVICES", _BIG))
    periods = int(os.environ.get("FLEET_BENCH_HI_PERIODS", 64))
    beta, hi_seed = 0.15, 7
    cfg = FleetConfig(
        n_devices=n, T=1.2, n_servers=max(1, n // 16), policy="amr2",
        rate=10.0, batch_max=PARITY_JOBS, horizon=periods + 2, seed=7)
    base = E.EngineParams.from_config(cfg, horizon=periods + 2)
    acc = np.asarray(base.acc, np.float64).copy()
    rng = np.random.default_rng(7)
    acc[:, base.m] = rng.uniform(0.65, 0.92, n)
    het = dataclasses.replace(base, acc=acc)
    theta_star = np.clip(acc[:, base.m] - beta, 0.0, 1.0)
    ck = sorted({max(0, p - 1) for p in (8, 16, 32, periods)
                 if p <= periods})

    def _roll(params):
        t0 = time.perf_counter()
        state, M = E.rollout(E.init_state(params), params, periods)
        reg = np.asarray(M.hi_regret, np.float64)
        off = np.asarray(M.n_hi_offloaded, np.int64)
        loc = np.asarray(M.n_hi_local_final, np.int64)
        jobs = np.asarray(M.n_jobs, np.int64)
        assert np.array_equal(off + loc, jobs), \
            "per-period HI serving identity broke"
        return {
            "regret": float(reg[-1]),
            "regret_trajectory": {str(t + 1): float(reg[t]) for t in ck},
            "offload_rate": float(off.sum() / max(jobs.sum(), 1)),
            "acc_per_job": float(
                np.asarray(M.total_accuracy).sum() / max(jobs.sum(), 1)),
            "wall_s": time.perf_counter() - t0,
        }, state

    grid = np.linspace(0.1, 0.9, 9)
    sweep = {}
    for th in grid:                       # one compiled rollout, 9 leaves
        p = het.with_hi(HIModel.make(theta0=float(th),
                                     offload_cost=beta),
                        rule="fixed", hi_seed=hi_seed)
        sweep[f"{th:.1f}"], _ = _roll(p)
    best_th, best_fixed = min(((k, v["regret"]) for k, v in sweep.items()),
                              key=lambda kv: kv[1])

    rules = {}
    theta_err = None
    for rule in ("threshold", "ucb", "exp3"):
        p = het.with_hi(HIModel.make(offload_cost=beta), rule=rule,
                        hi_seed=hi_seed)
        rules[rule], state = _roll(p)
        if rule == "threshold":
            theta_err = float(np.abs(
                np.asarray(state.hi.theta) - theta_star).mean())

    clair = het.with_hi(HIModel.make(theta0=theta_star,
                                     offload_cost=beta),
                        rule="fixed", hi_seed=hi_seed)
    rules["clairvoyant"], _ = _roll(clair)
    assert rules["clairvoyant"]["regret"] == 0.0, \
        f"the clairvoyant fixed rule accrued nonzero pseudo-regret " \
        f"{rules['clairvoyant']['regret']} (floor broken)"

    learner = rules["threshold"]["regret"]
    if periods >= 32:
        assert learner < best_fixed, \
            f"threshold learner regret {learner:.1f} did not beat the " \
            f"best fixed grid point (theta={best_th}: {best_fixed:.1f}) " \
            f"at a {periods}-period horizon"

    wall = rules["threshold"]["wall_s"]
    entry = {
        "devices": n, "periods": periods, "hi_seed": hi_seed,
        "offload_cost": beta,
        "acc_es_range": [float(acc[:, base.m].min()),
                         float(acc[:, base.m].max())],
        "fixed_sweep": sweep,
        "best_fixed_theta": float(best_th),
        "best_fixed_regret": best_fixed,
        "rules": rules,
        "learner_theta_abs_err": theta_err,
        "learner_beats_best_fixed": bool(learner < best_fixed),
        "assertions": "passed",
    }
    _record("hi", {str(n): entry})
    return [(
        f"fleet/hi/{n}", wall / (n * periods) * 1e6,
        f"devices={n};periods={periods};"
        f"learner_regret={learner:.1f};best_fixed={best_fixed:.1f}"
        f"@{best_th};ucb={rules['ucb']['regret']:.1f};"
        f"exp3={rules['exp3']['regret']:.1f};clairvoyant=0;"
        f"theta_err={theta_err:.3f}")]


ALL = [parity, warm_cold, scaling, speedup, rollout, sharded, chaos,
       mobility, grad, hi]


def main():
    print("name,us_per_call,derived")
    for fn in ALL:
        for name, us, derived in fn():
            print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
