"""Fleet-scale planning benchmark — the repo's first end-to-end scaling story.

Two sections:

  * ``fleet/parity``   — plans the SAME >=64-device fleet twice: once with
    the vmapped batched AMR^2 (one jit call) and once with the per-device
    NumPy simplex oracle, asserting identical accuracy totals (<=1e-6) and
    the paper's 2T makespan guarantee per device, then reports the
    batched-vs-sequential planning throughput.
  * ``fleet/scale/B``  — runs the full serving engine (Poisson queue, ES
    pool, stragglers, outages) for >=20 periods at increasing fleet sizes
    and reports devices-planned/sec plus aggregate accuracy / violation
    numbers.

Standalone:  PYTHONPATH=src python benchmarks/fleet_bench.py
CSV via the harness:  python benchmarks/run.py fleet
"""
from __future__ import annotations

import time

import numpy as np

PARITY_DEVICES = 64
PARITY_JOBS = 12
SCALE_SIZES = (8, 16, 32, 64)
SCALE_PERIODS = 20


def _parity_instances(n_devices=PARITY_DEVICES, n_jobs=PARITY_JOBS, seed=0):
    from repro.serving.fleet import make_fleet
    rng = np.random.default_rng(seed)
    specs = make_fleet(n_devices, seed=seed, straggler_frac=0.0,
                       outage_frac=0.0)
    T = 1.2
    insts = []
    for spec in specs:
        classes = rng.choice(spec.profile.classes, size=n_jobs)
        insts.append(spec.profile.instance(classes, T))
    return insts, T


def parity():
    """Batched vmapped planner vs per-device NumPy oracle on one fleet."""
    from repro.core import InstanceBatch, amr2_batch
    from repro.serving import plan_batch

    insts, T = _parity_instances()
    batch = InstanceBatch.stack(insts)
    amr2_batch(batch)                                   # compile once
    t0 = time.perf_counter()
    scheds = amr2_batch(batch)                          # ONE jit call
    batched_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    oracle = plan_batch(insts, backend="numpy")         # sequential simplex
    oracle_s = time.perf_counter() - t0

    max_gap = 0.0
    for sched, op in zip(scheds, oracle):
        gap = abs(sched.total_accuracy - op.schedule.total_accuracy)
        max_gap = max(max_gap, gap)
        assert gap <= 1e-6, \
            f"batched/oracle accuracy mismatch: {gap:.2e}"
        assert sched.makespan <= 2 * T + 1e-9, \
            f"2T guarantee violated: {sched.makespan:.3f} > {2 * T}"
    n = len(insts)
    return [
        ("fleet/parity/batched", batched_s / n * 1e6,
         f"devices={n};devices_per_s={n / batched_s:.0f};"
         f"max_acc_gap={max_gap:.1e};single_jit_call=1"),
        ("fleet/parity/numpy_oracle", oracle_s / n * 1e6,
         f"devices={n};devices_per_s={n / oracle_s:.0f};"
         f"speedup={oracle_s / batched_s:.1f}x"),
    ]


def scaling():
    """End-to-end engine throughput + accuracy/violation vs fleet size."""
    from repro.serving import FleetEngine, RequestQueue, make_fleet

    out = []
    for n_devices in SCALE_SIZES:
        specs = make_fleet(n_devices, seed=7, horizon=SCALE_PERIODS)
        queue = RequestQueue(n_devices, (128, 512, 1024), rate=10.0,
                             batch_max=PARITY_JOBS, seed=7)
        engine = FleetEngine(specs, queue,
                             n_servers=max(1, n_devices // 16), T=1.2)
        engine.run_period()                             # compile once
        engine.history.clear()  # keep the jit warmup out of the averages
        t0 = time.perf_counter()
        engine.run(SCALE_PERIODS)
        wall = time.perf_counter() - t0
        s = engine.summary()
        out.append((
            f"fleet/scale/{n_devices}",
            s["plan_seconds_per_period"] / n_devices * 1e6,
            f"periods={SCALE_PERIODS};jobs={s['jobs']};"
            f"devices_per_s={s['devices_per_second']:.0f};"
            f"acc_per_job={s['mean_job_accuracy']:.4f};"
            f"violation_rate={s['violation_rate']:.4f};"
            f"backpressure_rate={s['backpressure_rate']:.4f};"
            f"sim_wall_s={wall:.2f}"))
    return out


ALL = [parity, scaling]


def main():
    print("name,us_per_call,derived")
    for fn in ALL:
        for name, us, derived in fn():
            print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
