"""Micro-benchmarks of the compute layers' CPU-reference paths (the pure
jnp implementations the dry-run lowers; the Pallas kernels are TPU-target
and validated in interpret mode — timing interpret mode is meaningless, so
what's timed here is the jnp math at small shapes for regression tracking).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _timed_jit(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def attention_bench():
    from repro.kernels.flash_attention.ref import attention_ref
    from repro.models.layers import _chunked_attention
    rows = []
    key = jax.random.key(0)
    for (bh, s, d) in [(8, 512, 64), (8, 1024, 64)]:
        q = jax.random.normal(key, (bh, s, d), jnp.bfloat16)
        k = jax.random.normal(key, (bh, s, d), jnp.bfloat16)
        v = jax.random.normal(key, (bh, s, d), jnp.bfloat16)
        us_ref = _timed_jit(jax.jit(
            lambda q, k, v: attention_ref(q, k, v, mask_kind="causal")),
            q, k, v)
        qq = q[:, :, None, :].reshape(1, s, bh, d)
        pos = jnp.arange(s)
        us_chunk = _timed_jit(jax.jit(
            lambda q, k, v: _chunked_attention(
                q, k, v, pos, pos, "causal", 0, 256)),
            qq, qq, qq)
        flops = 4 * bh * s * s * d
        rows.append((f"attn_dense/bhsd={bh}x{s}x{d}", us_ref,
                     f"gflops_s={flops / us_ref / 1e3:.1f}"))
        rows.append((f"attn_chunked/bhsd={bh}x{s}x{d}", us_chunk,
                     f"gflops_s={flops / us_chunk / 1e3:.1f}"))
    return rows


def ssd_bench():
    from repro.models.layers import ssd_scan_chunked
    rows = []
    key = jax.random.key(1)
    B, S, H, P, N = 2, 1024, 8, 64, 64
    x = jax.random.normal(key, (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(key, (B, S, H)))
    A = -jnp.exp(jax.random.normal(key, (H,)))
    B_ = jax.random.normal(key, (B, S, N))
    C_ = jax.random.normal(key, (B, S, N))
    f = jax.jit(lambda *a: ssd_scan_chunked(*a, 128)[0])
    us = _timed_jit(f, x, dt, A, B_, C_)
    rows.append((f"ssd_chunked/BSHPN={B}x{S}x{H}x{P}x{N}", us,
                 f"tokens_s={B * S / us * 1e6:.0f}"))
    return rows


def cckp_bench():
    from repro.core.amdp import solve_cckp
    rows = []
    for (m, T_int, n_l) in [(2, 2000, 100), (3, 4000, 300)]:
        rng = np.random.default_rng(0)
        p = rng.integers(5, 50, size=m)
        a = np.sort(rng.uniform(0.3, 0.8, size=m))
        t0 = time.perf_counter()
        solve_cckp(p, a, T_int, n_l)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"cckp_dp/m={m}/T={T_int}/n={n_l}", us,
                     f"cells_s={(m * n_l * T_int * n_l) / us:.0f}M"))
    return rows


ALL = [attention_bench, ssd_bench, cckp_bench]
