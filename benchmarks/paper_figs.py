"""Benchmarks reproducing each paper table/figure (§VII).

Each function returns a list of CSV rows (name, us_per_call, derived).
The instances use the paper's measured constants (Tables I/II, Fig 2) via
core.instances.paper_instance.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (OffloadInstance, amdp, amr2, dual_schedule,
                        greedy_rra, paper_instance, solve_lp_relaxation)


def _timed(fn, *args, reps=3, **kw):
    outs = None
    t0 = time.perf_counter()
    for _ in range(reps):
        outs = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / reps
    return outs, dt * 1e6


def fig3_assignment():
    """Fig 3: jobs per model under AMR^2 as T grows (n=40)."""
    rows = []
    n = 40
    for T in (0.5, 1.0, 2.0, 4.0, 8.0):
        inst = paper_instance(n, T=T, seed=0)
        sched, us = _timed(amr2, inst)
        counts = sched.counts()
        rows.append((f"fig3/T={T}", us,
                     f"jobs_m1={counts[0]};jobs_m2={counts[1]};"
                     f"jobs_es={counts[2]}"))
    return rows


def fig4_accuracy_vs_T():
    """Fig 4: total accuracy vs T for n in {30, 60}; AMR^2 ~ LP bound and
    beats Greedy-RRA (paper: ~20-60% gains)."""
    rows = []
    for n in (30, 60):
        for T in (0.5, 1.0, 2.0, 4.0):
            inst = paper_instance(n, T=T, seed=1)
            a, us = _timed(amr2, inst)
            if a.status == "infeasible":
                # matches the paper: "for n=60, no LP-relaxed solution
                # exists for T=0.5 sec"
                rows.append((f"fig4/n={n}/T={T}", us, "infeasible"))
                continue
            g = greedy_rra(inst)
            gain = (a.total_accuracy / max(g.total_accuracy, 1e-9) - 1)
            rows.append((f"fig4/n={n}/T={T}", us,
                         f"A_amr2={a.total_accuracy:.3f};"
                         f"A_lp={a.lp_accuracy:.3f};"
                         f"A_greedy={g.total_accuracy:.3f};"
                         f"gain_pct={100 * gain:.1f}"))
    return rows


def fig5_accuracy_vs_n():
    """Fig 5: total accuracy vs n at T in {0.5, 4}."""
    rows = []
    for T in (0.5, 4.0):
        for n in (10, 20, 40, 60):
            inst = paper_instance(n, T=T, seed=2)
            a, us = _timed(amr2, inst)
            g = greedy_rra(inst)
            rows.append((f"fig5/T={T}/n={n}", us,
                         f"A_amr2={a.total_accuracy:.3f};"
                         f"A_greedy={g.total_accuracy:.3f}"))
    return rows


def fig6_makespan():
    """Fig 6: makespan and violation saturate with n (Lemma 1: <=2
    fractional jobs regardless of n => bounded violation)."""
    rows = []
    for T in (0.5, 4.0):
        for n in (10, 20, 40, 60):
            inst = paper_instance(n, T=T, seed=3)
            a, us = _timed(amr2, inst)
            rows.append((f"fig6/T={T}/n={n}", us,
                         f"makespan={a.makespan:.3f};"
                         f"violation_pct={100 * a.violation:.1f};"
                         f"n_frac={a.n_fractional}"))
    return rows


def table_runtime():
    """Scheduler runtimes (paper: AMR^2 50 ms at n=40 on a Pi; AMDP <1 ms
    in C at n=300) + the beyond-paper dual fast path."""
    rows = []
    for n in (40, 128, 512, 1024):
        inst = paper_instance(n, T=max(0.05 * n, 2.0), seed=4)
        _, us_amr2 = _timed(amr2, inst, reps=1)
        _, us_dual = _timed(dual_schedule, inst)
        _, us_greedy = _timed(greedy_rra, inst)
        rows.append((f"runtime/amr2/n={n}", us_amr2, "lp_simplex"))
        rows.append((f"runtime/dual/n={n}", us_dual,
                     f"speedup_vs_amr2={us_amr2 / max(us_dual, 1e-9):.0f}x"))
        rows.append((f"runtime/greedy/n={n}", us_greedy, "baseline"))
    # AMDP identical jobs
    for n in (100, 300):
        p_ed = np.array([0.010, 0.045])
        inst = OffloadInstance(p_ed=np.tile(p_ed, (n, 1)),
                               p_es=np.full(n, 0.35),
                               acc=np.array([0.395, 0.559, 0.771]),
                               T=0.02 * n)
        _, us = _timed(amdp, inst, reps=1)
        rows.append((f"runtime/amdp/n={n}", us, "cckp_dp_jnp"))
    return rows


def theorem_bounds():
    """Empirical check of Thm 2 / Cor 1 bounds across seeds."""
    rows = []
    worst = 0.0
    for seed in range(20):
        inst = paper_instance(24, T=1.5, seed=seed)
        a = amr2(inst)
        gap = (a.lp_accuracy or 0) - a.total_accuracy
        worst = max(worst, gap)
    bound = inst.acc[-1] - inst.acc[0]        # Cor 1 (all p_es <= T here)
    rows.append(("thm2/worst_gap_vs_cor1", 0.0,
                 f"worst_gap={worst:.4f};cor1_bound={bound:.4f};"
                 f"holds={worst <= bound + 1e-9}"))
    return rows


ALL = [fig3_assignment, fig4_accuracy_vs_T, fig5_accuracy_vs_n,
       fig6_makespan, table_runtime, theorem_bounds]
