"""Roofline table from the dry-run artifacts (results/dryrun.jsonl).

Reads every recorded (arch x shape x mesh) cell and emits the three terms,
the dominant bottleneck, and MODEL_FLOPS/HLO_FLOPs — the source of
EXPERIMENTS.md §Roofline.  Run `python -m repro.launch.dryrun --all
--both-meshes --out results/dryrun.jsonl` first (CI keeps the committed
artifact current).
"""
from __future__ import annotations

import json
import os

PATH = os.environ.get("DRYRUN_JSONL", "results/dryrun.jsonl")


def rows():
    if not os.path.exists(PATH):
        return [("roofline/missing", 0.0,
                 f"no {PATH}; run repro.launch.dryrun --all first")]
    out = []
    for line in open(PATH):
        r = json.loads(line)
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r["status"] != "ok":
            out.append((name, 0.0, f"status={r['status']}"))
            continue
        t = r["terms"]
        step_us = t["step_lower_bound_s"] * 1e6
        out.append((name, step_us,
                    f"compute_ms={t['compute_s'] * 1e3:.2f};"
                    f"memory_ms={t['memory_s'] * 1e3:.2f};"
                    f"collective_ms={t['collective_s'] * 1e3:.2f};"
                    f"dominant={t['dominant']};"
                    f"roofline_frac={t['roofline_fraction']:.3f};"
                    f"useful_flops={r['useful_flop_ratio']:.3f}"))
    return out


ALL = [rows]
