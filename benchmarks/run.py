"""Benchmark harness — one section per paper table/figure plus the kernel
micro-benches and the roofline report.  Prints ``name,us_per_call,derived``
CSV (the format tests/CI consume)."""
from __future__ import annotations

import os
import sys

# `python benchmarks/run.py` puts benchmarks/ (not the repo root) on
# sys.path; make the `benchmarks` package importable either way.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> None:
    from benchmarks import fleet_bench, kernel_bench, paper_figs, \
        roofline_report

    sections = (paper_figs.ALL + kernel_bench.ALL + roofline_report.ALL
                + fleet_bench.ALL)
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for fn in sections:
        if only and only not in fn.__module__ + "." + fn.__name__:
            continue
        for name, us, derived in fn():
            print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
