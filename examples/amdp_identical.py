"""AMDP for identical jobs (paper §VI): optimal DP schedule vs AMR^2 and
Greedy-RRA when every request is the same shape — the periodic-sensing
workload (e.g. fixed-resolution frames every period).

Also demos the §VI-B remark: identical processing but heterogeneous
communication times (sort-by-c_j greedy ES fill + CCKP), and the Pallas
TPU kernel path for the DP (interpret mode on CPU).

    PYTHONPATH=src python examples/amdp_identical.py
"""
import time

import numpy as np

from repro.core import (OffloadInstance, amdp, amdp_hetero_comm, amr2,
                        brute_force, greedy_rra)


def main():
    # ladder timings in the paper's range (Table II-like), identical jobs
    p_ed = np.array([0.010, 0.045])        # two ED models
    p_es = 0.35                            # comm + ES compute
    acc = np.array([0.395, 0.559, 0.771])  # Table I

    print(f"{'n':>5} {'T':>6} {'A_amdp':>8} {'A_amr2':>8} {'A_greedy':>9} "
          f"{'amdp_ms':>8} {'amr2_ms':>8}")
    for n, T in [(30, 2.0), (100, 4.0), (300, 8.0)]:
        inst = OffloadInstance(p_ed=np.tile(p_ed, (n, 1)),
                               p_es=np.full(n, p_es), acc=acc, T=T)
        t0 = time.perf_counter()
        d = amdp(inst)
        t1 = time.perf_counter()
        a = amr2(inst)
        t2 = time.perf_counter()
        g = greedy_rra(inst)
        print(f"{n:5d} {T:6.1f} {d.total_accuracy:8.2f} "
              f"{a.total_accuracy:8.2f} {g.total_accuracy:9.2f} "
              f"{1e3*(t1-t0):8.1f} {1e3*(t2-t1):8.1f}"
              + (f"   (amr2 viol {100*a.violation:.0f}%)"
                 if a.violation > 0 else ""))
        # AMDP is optimal among T-FEASIBLE schedules; AMR^2 may beat it
        # only by exceeding T (its 2T allowance, Thm 1).
        if a.violation == 0:
            assert d.total_accuracy >= a.total_accuracy - 1e-6
        assert d.violation == 0

    # optimality spot-check vs brute force
    inst = OffloadInstance(p_ed=np.tile(p_ed, (7, 1)),
                           p_es=np.full(7, p_es), acc=acc, T=1.0)
    opt = brute_force(inst)
    d = amdp(inst)
    print(f"\nn=7 brute force: {opt.total_accuracy:.3f} == "
          f"AMDP {d.total_accuracy:.3f}")

    # Pallas kernel path for the DP (the paper's C reimplementation,
    # TPU-style; interpret mode on CPU)
    inst = OffloadInstance(p_ed=np.tile(p_ed, (50, 1)),
                           p_es=np.full(50, p_es), acc=acc, T=2.0)
    d_pallas = amdp(inst, impl="pallas")
    d_jnp = amdp(inst)
    print(f"pallas CCKP kernel: A={d_pallas.total_accuracy:.3f} "
          f"(jnp path {d_jnp.total_accuracy:.3f})")

    # heterogeneous comm times (paper §VI-B remark)
    rng = np.random.default_rng(0)
    comm = rng.uniform(0.05, 0.6, size=40)
    h = amdp_hetero_comm(p_ed, p_es_proc=0.3, comm=comm, acc=acc, T=3.0)
    print(f"hetero-comm: A={h.total_accuracy:.2f} "
          f"offloaded={int((h.assignment == 2).sum())}/40 "
          f"ed={h.ed_makespan:.2f}s es={h.es_makespan:.2f}s (T=3.0)")


if __name__ == "__main__":
    main()
