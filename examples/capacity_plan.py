"""Gradient-based capacity planning vs grid search, on the same budget.

    PYTHONPATH=src python examples/capacity_plan.py [--devices 64]
        [--periods 6] [--slo-margin 1.02] [--budget 49] [--seed 0]

The operator question: how much edge-server capacity (and how aggressive
a model-ladder mix) does this fleet need to hit an accuracy SLO?  Two
knobs reparameterize the engine's continuous leaves:

  * ``log_cap``  — server-capacity scale: ``p_es * exp(-log_cap)``
    (bigger knob = faster ES = more admitted offloads);
  * ``mix``      — ladder-mix logit: ``acc * 2 * sigmoid(mix)`` rescales
    the accuracy ladder (a stand-in for shifting load toward larger
    server-side models).

Both planners search the SAME 2-D knob space for the cheapest point
meeting the SLO (mean served accuracy per device-period):

  * *grid search* — the classic operator move: a budget-bounded lattice
    scan, one full rollout per point (the only option when the serving
    stack is a black box);
  * *gradient descent* — Adam on a penalized SLO loss, fed by
    `rollout_value_and_grad` (`EngineParams.with_differentiable`): the
    whole epoch — implicit-gradient simplex, smoothed rounding,
    sigmoid-relaxed admission — differentiates in ONE backward sweep
    that costs ~1.3x a forward rollout, so every step is one "eval" on
    the shared budget.  Straight-through mode reports the HARD rollout's
    value, so SLO attainment is measured on the real metric, not the
    relaxation.

The script prints both trajectories and exits 1 unless the gradient
planner reaches the SLO in FEWER rollout evals than the grid scan.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))


def sigmoid(x):
    import numpy as np
    return 1.0 / (1.0 + np.exp(-x))


def main() -> int:
    import numpy as np

    import optax

    from repro.api import engine as E
    from repro.serving import FleetConfig

    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=64)
    ap.add_argument("--periods", type=int, default=6)
    ap.add_argument("--slo-margin", type=float, default=1.02,
                    help="SLO = margin * base mean accuracy")
    ap.add_argument("--budget", type=int, default=49,
                    help="rollout-eval budget (grid points)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = FleetConfig(n_devices=args.devices, T=1.2,
                      n_servers=max(1, args.devices // 16), policy="amr2",
                      backend="jax", rate=9.0, batch_max=8,
                      horizon=args.periods + 2, seed=args.seed,
                      straggler_frac=0.25, outage_frac=0.1)
    base = E.EngineParams.from_config(cfg, horizon=args.periods + 2)
    armed = base.with_differentiable(smooth_mode="st")
    base_es = np.asarray(base.p_es, np.float64)
    base_acc = np.asarray(base.acc, np.float64)
    N = args.devices * args.periods

    def at_knobs(log_cap, mix, p=None):
        return dataclasses.replace(
            p if p is not None else base,
            p_es=base_es * np.exp(-log_cap),
            acc=base_acc * 2.0 * sigmoid(mix))

    def mean_acc(log_cap, mix):
        p = at_knobs(log_cap, mix)
        _, m = E.rollout(E.init_state(p), p, args.periods)
        return float(np.sum(np.asarray(m.total_accuracy))) / N

    base_acc_mean = mean_acc(0.0, 0.0)
    slo = args.slo_margin * base_acc_mean
    # capacity is not free: the penalty keeps both planners looking for
    # the CHEAPEST feasible point instead of maxing the knob
    lam = 0.02 * slo

    def objective(log_cap, mix, acc_mean):
        short = max(0.0, slo - acc_mean)
        return short * short / (slo * slo) + lam * max(0.0, log_cap) / slo

    print(f"fleet: {args.devices} devices x {args.periods} periods, "
          f"base mean acc {base_acc_mean:.4f}, SLO {slo:.4f} "
          f"({args.slo_margin:.2f}x)")

    # ---- grid search ----------------------------------------------------
    side = max(2, int(round(args.budget ** 0.5)))
    caps = np.linspace(0.0, 0.5, side)
    mixes = np.linspace(-1.0, 1.0, side)
    grid_evals, grid_hit, grid_best = 0, None, (np.inf, None)
    for lc in caps:                       # cheapest capacity first
        for mx in mixes:
            acc = mean_acc(float(lc), float(mx))
            grid_evals += 1
            obj = objective(float(lc), float(mx), acc)
            if obj < grid_best[0]:
                grid_best = (obj, (float(lc), float(mx), acc))
            if acc >= slo and grid_hit is None:
                grid_hit = grid_evals
                print(f"[grid] SLO met at eval {grid_evals}: "
                      f"log_cap={lc:.3f} mix={mx:.3f} acc={acc:.4f}")
        if grid_hit is not None:
            break
    if grid_hit is None:
        grid_hit = grid_evals + 1         # never met within budget
        print(f"[grid] SLO not met in {grid_evals} evals; "
              f"best acc {grid_best[1][2]:.4f}")

    # ---- gradient descent -----------------------------------------------
    knobs = {"log_cap": np.float64(0.0), "mix": np.float64(0.0)}
    opt = optax.adam(0.12)
    opt_state = opt.init(knobs)
    gd_evals, gd_hit = 0, None
    for it in range(args.budget):
        p = at_knobs(knobs["log_cap"], knobs["mix"], armed)
        val, g = E.rollout_value_and_grad(
            E.init_state(p), p, args.periods, wrt=("p_es", "acc"))
        gd_evals += 1
        acc = float(val) / N
        # knob-space chain rule through the two reparameterizations
        d_cap = float(np.sum(np.asarray(g["p_es"], np.float64)
                             * base_es * -np.exp(-knobs["log_cap"])))
        s = sigmoid(knobs["mix"])
        d_mix = float(np.sum(np.asarray(g["acc"], np.float64)
                             * base_acc * 2.0 * s * (1.0 - s)))
        short = max(0.0, slo - acc)
        dv = -2.0 * short / (slo * slo * N)       # d(objective)/d(value)
        grads = {"log_cap": dv * d_cap
                 + (lam / slo if knobs["log_cap"] > 0 else 0.0),
                 "mix": dv * d_mix}
        print(f"[grad] eval {gd_evals}: log_cap={knobs['log_cap']:.3f} "
              f"mix={knobs['mix']:.3f} acc={acc:.4f}"
              + (" (SLO met)" if acc >= slo else ""))
        if acc >= slo:
            gd_hit = gd_evals
            break
        updates, opt_state = opt.update(grads, opt_state, knobs)
        knobs = {k: np.float64(knobs[k] + updates[k]) for k in knobs}

    # ---- verdict --------------------------------------------------------
    print(f"\ngrid search:      SLO at eval {grid_hit} "
          f"(budget {args.budget})")
    print(f"gradient descent: SLO at eval {gd_hit if gd_hit else '-'}")
    if gd_hit is None:
        print("FAIL: gradient planner did not reach the SLO")
        return 1
    if gd_hit >= grid_hit:
        print("FAIL: gradient planner needed no fewer evals than grid")
        return 1
    print(f"OK: gradient planner reached the SLO in {gd_hit} rollout "
          f"evals vs {grid_hit} for grid search "
          f"({grid_hit / gd_hit:.1f}x fewer)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
