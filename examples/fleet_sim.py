"""Fleet serving demo: N edge devices, a small ES pool, Poisson traffic.

    PYTHONPATH=src python examples/fleet_sim.py --devices 64 --periods 20 \
        [--servers 2] [--rate 10] [--batch-max 12] [--t 1.2] [--seed 0] \
        [--rollout] [--chaos [LOSS_RATE]] [--fault-seed 0]

The whole run is described by ONE declarative `FleetConfig`
(`FleetEngine.from_config`): every period the fleet is planned by a
handful of batched registry solves (`repro.api.solve` on per-shape-group
`FleetProblem`s); devices that lose the ES-capacity admission race replan
onto their local model ladder in one batched ES-disabled solve, drifting
devices trigger the EMA straggler audit, and per-device ES-link outages
are planned around.

``--rollout`` runs the same epoch through the pure-functional engine
instead (`repro.serving.engine_v2`): the whole multi-period simulation is
ONE `lax.scan` over the jitted period step, zero per-period host
round-trips.  With ``--policy amr2`` or ``--policy dual`` the
trajectories are bit-identical to the loop above on the replayed arrival
trace; the default ``auto`` resolves to amr2 in the rollout engine (the
loop's auto additionally gives identical-job devices the exact DP, so
those per-period numbers may differ slightly).

``--chaos [LOSS_RATE]`` arms the fault-injection subsystem (requires the
delegated/rollout engine): mid-period ES crashes, link degradation,
injected stragglers, and per-sample offload loss, resolved by the traced
degradation ladder (retry with capped backoff -> largest local model
fitting the residual 2T deadline -> drop).  The per-period lines grow
retry/fallback/drop/miss counters and the realized makespan; the fault
trace is replayed from ``--fault-seed``, so runs are reproducible.
"""
from __future__ import annotations

import argparse


def _fault_model(args):
    """The demo fault mix: the requested offload-loss rate plus moderate
    crash / link-degradation / straggler probabilities."""
    from repro.serving import FaultModel
    if args.chaos is None:
        return None
    return FaultModel.make(loss_rate=args.chaos, es_crash_prob=0.05,
                           link_degrade_prob=0.2, link_degrade_mag=0.5,
                           straggler_prob=0.15, straggler_mult=2.0)


def _chaos_cols(retries, fallback, dropped, miss, makespan, T):
    return (f"retry={retries:>3} fb={fallback:>2} drop={dropped:>2} "
            f"miss={miss:>2} realized={makespan / T:4.2f}T ")


def _main_rollout(args) -> None:
    import numpy as np

    from repro.serving import FleetConfig, engine_v2

    config = FleetConfig(
        n_devices=args.devices, T=args.t, n_servers=args.servers,
        policy=args.policy, rate=args.rate, batch_max=args.batch_max,
        horizon=max(args.periods, 2), seed=args.seed,
        faults=_fault_model(args), fault_seed=args.fault_seed)
    params = engine_v2.EngineParams.from_config(config,
                                                horizon=args.periods)
    state, m = engine_v2.rollout(engine_v2.init_state(params), params,
                                 args.periods)
    chaos_tag = (f", chaos armed: loss={args.chaos:g} "
                 f"fault_seed={args.fault_seed}" if params.chaos else "")
    print(f"[fleet] engine-v2 rollout: {args.periods} periods as one "
          f"lax.scan over {args.devices} devices (policy "
          f"{params.policy}{chaos_tag})")
    for i in range(args.periods):
        jobs = int(np.asarray(m.n_jobs)[i])
        chaos_cols = "" if not params.chaos else _chaos_cols(
            int(np.asarray(m.n_retries)[i]),
            int(np.asarray(m.n_fallback_local)[i]),
            int(np.asarray(m.n_dropped)[i]),
            int(np.asarray(m.n_deadline_miss)[i]),
            float(np.asarray(m.realized_makespan)[i]), args.t)
        print(f"[fleet] t={i:>3} jobs={jobs:>4} "
              f"acc/job={float(np.asarray(m.mean_job_accuracy)[i]):.3f} "
              f"offload={int(np.asarray(m.n_offloading)[i]):>3} "
              f"bumped={int(np.asarray(m.n_backpressured)[i]):>3} "
              f"outage={int(np.asarray(m.n_outage)[i]):>2} "
              f"straggler_upd={int(np.asarray(m.n_straggler_updates)[i])} "
              f"es_util={float(np.asarray(m.es_utilization)[i]):4.0%} "
              f"viol={int(np.asarray(m.n_violations)[i]):>2} "
              f"{chaos_cols}"
              f"backlog={int(np.asarray(m.backlog)[i])}")
    jobs = int(np.asarray(m.n_jobs).sum())
    acc = float(np.asarray(m.total_accuracy).sum())
    chaos_sum = "" if not params.chaos else (
        f"retries={int(np.asarray(m.n_retries).sum())}, "
        f"fallback_local={int(np.asarray(m.n_fallback_local).sum())}, "
        f"dropped={int(np.asarray(m.n_dropped).sum())}, "
        f"deadline_miss={int(np.asarray(m.n_deadline_miss).sum())}, "
        f"worst_makespan="
        f"{float(np.asarray(m.realized_makespan).max()) / args.t:.2f}T, ")
    print(f"[fleet] done: {jobs} jobs, "
          f"acc/job={acc / max(jobs, 1):.3f}, "
          f"violation_rate="
          f"{np.asarray(m.n_violations).sum() / (args.periods * args.devices):.1%}, "
          f"{chaos_sum}"
          f"final_backlog={int(np.asarray(m.backlog)[-1])}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=64)
    ap.add_argument("--periods", type=int, default=20)
    ap.add_argument("--servers", type=int, default=2)
    ap.add_argument("--rate", type=float, default=10.0)
    ap.add_argument("--batch-max", type=int, default=12)
    ap.add_argument("--t", type=float, default=1.2, help="period budget T")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--policy", default="auto")
    ap.add_argument("--rollout", action="store_true",
                    help="run the epoch as one engine-v2 lax.scan rollout")
    ap.add_argument("--chaos", type=float, nargs="?", const=0.1,
                    default=None, metavar="LOSS_RATE",
                    help="arm fault injection at this offload-loss rate "
                    "(default 0.1 when the flag is given bare)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="replayed fault-trace seed (chaos runs are "
                    "reproducible under a fixed seed)")
    args = ap.parse_args(argv)

    if args.chaos is not None and args.policy == "auto":
        # fault injection needs the traced engine core; "auto" in the
        # loop engine routes identical-job devices to the host DP path
        args.policy = "amr2"

    if args.rollout:
        return _main_rollout(args)

    from repro.serving import FleetConfig, FleetEngine

    config = FleetConfig(
        n_devices=args.devices, T=args.t, n_servers=args.servers,
        policy=args.policy, rate=args.rate, batch_max=args.batch_max,
        horizon=max(args.periods, 2), seed=args.seed,
        faults=_fault_model(args), fault_seed=args.fault_seed)
    engine = FleetEngine.from_config(config)

    specs = [st.spec for st in engine.devices]
    print(f"[fleet] {args.devices} devices ({sum(1 for s in specs if s.drift is not None)}"
          f" stragglers, {sum(1 for s in specs if s.outage is not None)} flaky links)"
          f" | {args.servers} ES servers | T={args.t}s")
    chaos = args.chaos is not None
    for _ in range(args.periods):
        s = engine.run_period()
        chaos_cols = "" if not chaos else _chaos_cols(
            s.n_retries, s.n_fallback_local, s.n_dropped,
            s.n_deadline_miss, s.realized_makespan, args.t)
        print(f"[fleet] t={s.period:>3} jobs={s.n_jobs:>4} "
              f"acc/job={s.mean_job_accuracy:.3f} "
              f"offload={s.n_offloading:>3} bumped={s.n_backpressured:>3} "
              f"outage={s.n_outage:>2} straggler_upd={s.n_straggler_updates} "
              f"es_util={s.es_utilization:4.0%} viol={s.n_violations:>2} "
              f"{chaos_cols}"
              f"plan={s.plan_seconds * 1e3:6.1f}ms backlog={s.backlog}")
    summ = engine.summary()
    print(f"[fleet] done: {summ['jobs']} jobs, "
          f"acc/job={summ['mean_job_accuracy']:.3f}, "
          f"violation_rate={summ['violation_rate']:.1%}, "
          f"backpressure_rate={summ['backpressure_rate']:.1%}, "
          f"planning throughput={summ['devices_per_second']:.0f} devices/s")


if __name__ == "__main__":
    main()
