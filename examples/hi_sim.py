"""Online hierarchical inference: threshold learners vs the clairvoyant.

    PYTHONPATH=src python examples/hi_sim.py [--devices 64]
        [--periods 64] [--offload-cost 0.15] [--hi-seed 11] [--seed 0]

The paper's AMR^2 plans offloading from a KNOWN accuracy table; the
online twin (Moothedath & Champati, arXiv 2304.00891) must learn WHEN to
consult the edge server per sample, from calibrated local-model
confidences alone.  This script rolls the same fleet — heterogeneous
per-device ES accuracies, one shared confidence stream — under every
decision rule the engine implements:

  * ``fixed``     — a shared constant threshold (theta0 = 0.5);
  * ``threshold`` — the OGD online threshold learner;
  * ``ucb`` / ``exp3`` — bandits over a discretized threshold grid;
  * the *clairvoyant* — rule "fixed" armed with the per-device optimum
    ``theta* = clip(acc_es - beta, 0, 1)``, which accrues exactly zero
    pseudo-regret (the online problem's AMR^2-with-the-answer-key).

Because ``HIModel`` is an all-leaf pytree, all five sweeps reuse ONE
compiled `rollout` (two trace shapes: scalar vs per-device ``theta0``).
The script prints a cumulative-regret table over the horizon and exits 1
unless (a) the clairvoyant's regret is exactly 0, (b) the learner beats
the fixed baseline it starts from, and (c) the learner's regret growth
is sublinear (second-half increment < first-half increment).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))


def main() -> int:
    import numpy as np

    from repro.api import engine as E
    from repro.core.hi import HIModel
    from repro.serving import FleetConfig

    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=64)
    ap.add_argument("--periods", type=int, default=64)
    ap.add_argument("--offload-cost", type=float, default=0.15)
    ap.add_argument("--hi-seed", type=int, default=11)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    beta = args.offload_cost

    cfg = FleetConfig(n_devices=args.devices, T=1.2,
                      n_servers=max(1, args.devices // 16), policy="amr2",
                      backend="jax", rate=9.0, batch_max=8,
                      horizon=args.periods + 2, seed=args.seed,
                      straggler_frac=0.25, outage_frac=0.1)
    base = E.EngineParams.from_config(cfg, horizon=args.periods + 2)
    acc = np.asarray(base.acc, np.float64).copy()
    acc[:, base.m] = np.random.default_rng(7).uniform(
        0.65, 0.92, args.devices)
    het = dataclasses.replace(base, acc=acc)
    theta_star = np.clip(acc[:, base.m] - beta, 0.0, 1.0)

    def roll(rule, theta0=0.5):
        hm = HIModel.make(theta0=theta0, offload_cost=beta)
        p = het.with_hi(hm, rule=rule, hi_seed=args.hi_seed)
        state, m = E.rollout(E.init_state(p), p, args.periods)
        jobs = int(np.asarray(m.n_jobs).sum())
        return {"regret": np.asarray(m.hi_regret, np.float64),
                "acc": float(np.asarray(m.total_accuracy).sum())
                / max(jobs, 1),
                "off": int(np.asarray(m.n_hi_offloaded).sum())
                / max(jobs, 1),
                "theta": np.asarray(state.hi.theta, np.float64)}

    runs = {
        "fixed(0.5)": roll("fixed"),
        "threshold": roll("threshold"),
        "ucb": roll("ucb"),
        "exp3": roll("exp3"),
        "clairvoyant": roll("fixed", theta0=theta_star),
    }

    marks = sorted({p for p in (8, 16, 32, args.periods)
                    if p <= args.periods})
    print(f"fleet: {args.devices} devices x {args.periods} periods, "
          f"beta={beta}, acc_es in "
          f"[{acc[:, base.m].min():.2f}, {acc[:, base.m].max():.2f}], "
          f"stream seed {args.hi_seed} (shared by every rule)\n")
    head = "cumulative regret".ljust(14) + "".join(
        f"@{p}".rjust(11) for p in marks) + "  acc/job  offload%"
    print(head)
    for name, r in runs.items():
        row = name.ljust(14) + "".join(
            f"{r['regret'][p - 1]:11.1f}" for p in marks)
        print(f"{row}  {r['acc']:.4f}   {100 * r['off']:5.1f}%")
    err = np.abs(runs["threshold"]["theta"] - theta_star)
    print(f"\nlearner |theta - theta*|: mean {err.mean():.3f}, "
          f"max {err.max():.3f}")

    failures = []
    if runs["clairvoyant"]["regret"][-1] != 0.0:
        failures.append(
            f"clairvoyant regret {runs['clairvoyant']['regret'][-1]} != 0")
    reg_l = runs["threshold"]["regret"]
    if not reg_l[-1] < runs["fixed(0.5)"]["regret"][-1]:
        failures.append("learner did not beat the fixed(0.5) baseline")
    half = args.periods // 2 - 1
    if not reg_l[-1] - reg_l[half] < reg_l[half] - reg_l[0]:
        failures.append("learner regret growth is not sublinear")
    if failures:
        print("\nFAIL:", "; ".join(failures))
        return 1
    print("\nOK: clairvoyant floor exact, learner beat the fixed "
          "baseline with sublinear regret")
    return 0


if __name__ == "__main__":
    sys.exit(main())
