"""Multi-cell mobility demo: a fleet roaming a 4-cell grid, planned by the
pure-functional engine with traced routing, per-cell segmented admission,
and handover (warm-basis + ES-belief migration).

Three runs over the same replayed trace:

  * single-pool baseline — mobility off (today's one-ES engine);
  * nearest-cell routing — devices attach to the closest covered cell;
  * min-response-time routing — cells are load- and link-aware, so a
    congested or slow-linked cell sheds devices to its neighbours.

Also shows the `routed` registry policy: the host-level one-shot planner
that routes a FleetProblem's lanes by position before delegating to amr2.

    PYTHONPATH=src python examples/mobility_sim.py
"""
import numpy as np

from repro.api import engine as E
from repro.core.mobility import MobilityModel
from repro.serving import FleetConfig


def main():
    D, periods = 64, 16
    cfg = FleetConfig(n_devices=D, T=1.2, n_servers=8, policy="amr2",
                      rate=9.0, batch_max=8, horizon=periods + 2, seed=0)
    params = E.EngineParams.from_config(cfg, horizon=periods + 2)

    # a 2x2 grid of cells, 30 apart; devices random-walk around homes
    # drawn near cell centres, so coverage edges and handovers both occur
    rng = np.random.default_rng(7)
    cxy = 30.0 * np.array([[0., 0.], [1., 0.], [0., 1.], [1., 1.]])
    home = cxy[rng.integers(0, 4, D)]
    steps = rng.normal(scale=5.0, size=(periods + 2, D, 2)).cumsum(axis=0)
    trace = home + steps - steps[:1]                    # start at home
    mob = MobilityModel.make(cell_xy=cxy, trace=trace,
                             cell_rate=np.array([1.0, 0.7, 1.3, 1.0]),
                             radius=28.0, link_alpha=0.6)

    def run(tag, p):
        _, m = E.rollout(E.init_state(p), p, periods)
        acc = float(np.asarray(m.total_accuracy).sum())
        jobs = int(np.asarray(m.n_jobs).sum())
        print(f"  {tag:<22} acc/job {acc / max(jobs, 1):.4f}   "
              f"offloading {int(np.asarray(m.n_offloading).sum()):4d}   "
              f"handovers {int(np.asarray(m.n_handover).sum()):4d}   "
              f"outage-periods {int(np.asarray(m.n_outage).sum()):4d}")
        return acc / max(jobs, 1)

    print(f"{D} devices x {periods} periods, 4 cells "
          f"(rates {np.asarray(mob.cell_rate).tolist()}, radius 28):")
    run("single-pool (off)", params)
    run("nearest cell", params.with_mobility(mob, routing="nearest"))
    run("min response time",
        params.with_mobility(mob, routing="min_time"))

    # ---- the `routed` registry policy: one-shot host-level planning ----
    from repro import api
    from repro.core import InstanceBatch, paper_instance

    fp = api.FleetProblem.from_batch(InstanceBatch.stack(
        [paper_instance(8, T=1.2, seed=s) for s in range(D)]))
    sol = api.get_solver("routed").solve_fleet(
        fp, positions=trace[0], mobility=mob, routing="nearest")
    att = np.bincount(sol.cell[sol.cell >= 0], minlength=4)
    print(f"\nrouted policy (one-shot): cells {att.tolist()} attached, "
          f"{int((sol.cell < 0).sum())} uncovered (local-only); "
          f"accuracy {float(sol.accuracy.sum()):.2f}")


if __name__ == "__main__":
    main()
