"""Quickstart tour: model -> train step -> prefill/decode -> offload plan.

Runs in ~1 min on CPU:
    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import paper_instance
from repro.launch.steps import make_train_step
from repro.models import decode_step, init_params, prefill
from repro.api import solve
from repro.optim import adamw_init


def main():
    # 1. a reduced internlm2-family model (same code path as the 20B)
    cfg = get_smoke_config("internlm2_20b")
    key = jax.random.key(0)
    params = init_params(cfg, key)
    print(f"model: {cfg.name}  params={cfg.param_count():,} (analytic, "
          f"full config would be {cfg.param_count():,})")

    # 2. a couple of train steps
    step = jax.jit(make_train_step(cfg, lr=1e-2))
    opt = adamw_init(params)
    batch = {"tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab_size)}
    for i in range(3):
        params, opt, loss = step(params, opt, batch)
        print(f"train step {i}: loss {float(loss):.4f}")

    # 3. prefill + a few decode steps
    cache, logits = prefill(params, {"tokens": batch["tokens"][:, :24]},
                            cfg, max_seq=32)
    toks = jnp.argmax(logits, -1)
    for _ in range(4):
        logits, cache = decode_step(params, toks, cache, cfg)
        toks = jnp.argmax(logits, -1)
    print(f"decoded to index {int(cache['index'])}")

    # 4. the paper: plan a batch of 30 inference jobs under a 2 s budget
    inst = paper_instance(30, T=2.0, seed=0)
    sol = solve(inst)                   # registry front door, policy="auto"
    print(f"offload plan [{sol.solver}]: {sol.to_schedule().summary()}")
    print(f"jobs per model: {sol.to_schedule().counts()}  "
          f"(last = offloaded to ES tier)")


if __name__ == "__main__":
    main()
