"""End-to-end tiered serving — the paper's experiment (§VII) on a model
ladder: two reduced-width LM variants as the "ED tier" (MobileNet-alpha
analogue) and the full model as the "ES tier" (ResNet50 analogue), with
REAL measured latencies and REAL per-job top-1 next-token accuracy.

Reproduces the shape of the paper's Figs 3-6:
  * job assignment vs T (Fig 3),
  * total accuracy: AMR^2 vs LP bound vs Greedy-RRA vs dual (Figs 4/5),
  * predicted vs wall-clock makespan + violation (Fig 6),
plus the fault-tolerance story: an ES outage period (replanned onto the ED
ladder) and a straggler period (profile re-measured).

    PYTHONPATH=src python examples/serve_offload.py [--periods 6] [--n 24]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_edge import CONFIG as ES_CFG, ED_VARIANTS
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.launch.steps import make_train_step
from repro.models import forward, init_params, logits_from_h
from repro.optim import adamw_init
from repro.api import solve
from repro.serving import ServingRuntime, TierProfile, measure_latency


def build_models(seed: int = 0, train_steps: int = 30):
    """Train the ladder briefly on the synthetic stream so accuracy is
    ordered by capacity (a_1 <= a_2 <= a_es), like Table I."""
    import dataclasses
    models = []
    for i, cfg in enumerate(list(ED_VARIANTS) + [ES_CFG]):
        cfg = dataclasses.replace(cfg, attn_impl="dense")
        key = jax.random.key(seed + i)
        params = init_params(cfg, key)
        step = jax.jit(make_train_step(cfg, lr=3e-3))
        opt = adamw_init(params)
        pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=64, global_batch=8,
                                        seed=seed))
        # more steps for bigger models -> ordered accuracies
        for s in range(train_steps * (i + 1)):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
            params, opt, _ = step(params, opt, batch)
        models.append((cfg, params))
    return models


def make_apply(cfg, params):
    @jax.jit
    def fwd(tokens):
        h = forward(params, {"tokens": tokens}, cfg)
        logits = logits_from_h(params, h, cfg)
        pred = jnp.argmax(logits[:, :-1], -1)
        return (pred == tokens[:, 1:]).mean(axis=1)  # per-job top-1

    def apply(jobs):
        # bucket batch to the next power of two: stable jit shapes across
        # plan periods (otherwise every distinct group size recompiles)
        toks = jnp.stack([jnp.asarray(j) for j in jobs])
        n = toks.shape[0]
        bucket = 1 << (n - 1).bit_length()
        toks = jnp.pad(toks, ((0, bucket - n), (0, 0)))
        acc = fwd(toks)[:n]
        return [float(x) for x in acc]
    return apply


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=24)
    ap.add_argument("--periods", type=int, default=6)
    ap.add_argument("--train-steps", type=int, default=30)
    ap.add_argument("--iters", type=int, default=10)
    args = ap.parse_args()

    print("== training the model ladder (ED x2 + ES) ==")
    models = build_models(train_steps=args.train_steps)
    applies = [make_apply(c, p) for c, p in models]

    # measured test accuracy per model (Table I analogue)
    pipe = TokenPipeline(DataConfig(vocab_size=ES_CFG.vocab_size, seq_len=64,
                                    global_batch=16, seed=99))
    test_jobs = [pipe.batch_at(0)["tokens"][i] for i in range(16)]
    accs = [float(np.mean(app(test_jobs))) for app in applies]
    print(f"ladder accuracies (a_1..a_m, a_es): {[round(a,3) for a in accs]}")

    # measured per-job latency (Table II analogue): single size class
    lats = [measure_latency(lambda b=app: b(test_jobs[:1]), (),
                            iters=args.iters) for app in applies]
    comm = 0.2 * lats[-1]          # payload upload ~ fraction of ES compute
    print(f"ladder latencies (s/job): {[round(l,4) for l in lats]}, "
          f"comm {comm:.4f}")

    profile = TierProfile(
        name="lm-ladder",
        p_ed=np.array([[lats[0], lats[1]]]),
        p_es=np.array([lats[2] + comm]),
        acc=np.array(accs), classes=[64])

    # a T sweep: job assignment (Fig 3) + accuracy vs policies (Fig 4)
    n = args.n
    base_T = n * lats[1]
    print(f"\n== T sweep (n={n}) ==")
    print(f"{'T':>8} {'policy':>7} {'A_pred':>7} {'A_LP':>7} "
          f"{'A_greedy':>8} {'A_dual':>7}  jobs/model")
    for tf in (0.3, 0.6, 1.0, 1.6):
        T = base_T * tf
        inst = profile.instance(np.full(n, 64), T)
        p = solve(inst, policy="amr2")
        g = solve(inst, policy="greedy")
        d = solve(inst, policy="dual")
        print(f"{T:8.3f} {p.solver:>7} {p.accuracy:7.2f} "
              f"{float(p.lp_accuracy or 0):7.2f} "
              f"{g.accuracy:8.2f} "
              f"{d.accuracy:7.2f}  "
              f"{p.to_schedule().counts().tolist()}")

    # the serving loop with failures + stragglers (Fig 6 + fault story)
    print(f"\n== period-T serving loop ==")
    rt = ServingRuntime(profile, applies[:2], applies[2],
                        T=base_T * 0.8, policy="auto")
    rng = np.random.default_rng(0)
    for period in range(args.periods):
        jobs = [pipe.batch_at(100 + period)["tokens"][i] for i in range(n)]
        es_fail = period == 2
        if period == 4:
            # inject a straggler: wrap ED applies with a delay
            slow = [lambda js, a=a: (time.sleep(0.05 * len(js)), a(js))[1]
                    for a in applies[:2]]
            rt.apply_ed = slow
        stats = rt.run_period(jobs, np.full(n, 64), es_fail=es_fail)
        print(f"period {period}: policy={stats.policy} "
              f"A={stats.total_accuracy:.2f} pred={stats.predicted_makespan:.3f}s "
              f"wall={stats.wall_makespan:.3f}s viol={100*stats.violation:.0f}% "
              f"plan={1e3*stats.plan_seconds:.1f}ms "
              f"{'ES-FAIL->replanned ' if stats.replanned else ''}"
              f"{'profile-updated' if stats.profile_updated else ''}")
    print("done.")


if __name__ == "__main__":
    main()
