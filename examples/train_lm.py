"""End-to-end training driver example: a ~10M-param mamba2-family model for
a few hundred steps on the synthetic pipeline, with async checkpoints,
grad compression, and a mid-run preemption + resume — the full
fault-tolerance path exercised on CPU.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

(The same driver launches the full assigned configs on a real fleet:
 `python -m repro.launch.train --arch internlm2-20b --steps ...`.)
"""
import argparse
import os
import shutil
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)


def run(args, extra):
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "mamba2-130m", "--smoke",
           "--steps", str(args.steps),
           "--global-batch", "8", "--seq", "64",
           "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "25",
           "--compress-grads"] + extra
    return subprocess.run(cmd, env=env).returncode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    # phase 1: run and "preempt" by touching the sentinel after a while
    sentinel = os.path.join(args.ckpt_dir, "PREEMPT")
    os.makedirs(args.ckpt_dir, exist_ok=True)
    import threading
    import time

    def preempt_later():
        time.sleep(30)
        open(sentinel, "w").close()

    threading.Thread(target=preempt_later, daemon=True).start()
    rc = run(args, ["--preempt-file", sentinel])
    print(f"[example] first run exited rc={rc} (42 = preempted+saved)")

    # phase 2: resume to completion
    os.remove(sentinel)
    rc = run(args, ["--resume"])
    print(f"[example] resumed run exited rc={rc}")


if __name__ == "__main__":
    main()
