"""BENCH_fleet.json merge-on-write guard for CI.

The fleet bench merges each section dict-into-dict so a partial run (the
CI smoke job only exercises the small device counts) must never drop
previously-recorded keys — e.g. the committed 256-device parity baseline
must survive a 64-device smoke run.  Usage:

    python scripts/check_bench_keys.py snapshot BENCH_fleet.json keys.json
    ... run the bench ...
    python scripts/check_bench_keys.py verify BENCH_fleet.json keys.json \
        [--require SECTION ...]

``verify`` exits 1 if any recursively-collected dict key path from the
snapshot is missing from the current document, or if a ``--require``d
top-level section (e.g. ``chaos``) is absent — the snapshot mechanism
alone cannot catch a section that was never recorded in the first place.
"""
from __future__ import annotations

import json
import sys


def key_paths(doc, prefix=""):
    """Every nested dict key path, e.g. 'parity/256/amr2_max_acc_gap'."""
    paths = []
    if isinstance(doc, dict):
        for k, v in doc.items():
            p = f"{prefix}/{k}" if prefix else str(k)
            paths.append(p)
            paths.extend(key_paths(v, p))
    return paths


def main(argv) -> int:
    required = []
    if "--require" in argv:
        i = argv.index("--require")
        argv, required = argv[:i], argv[i + 1:]
    if len(argv) != 4 or argv[1] not in ("snapshot", "verify") \
            or (required and argv[1] != "verify"):
        print(__doc__, file=sys.stderr)
        return 2
    mode, bench_path, keys_path = argv[1], argv[2], argv[3]
    try:
        with open(bench_path) as fh:
            doc = json.load(fh)
    except (OSError, ValueError) as e:
        print(f"cannot read {bench_path}: {e}", file=sys.stderr)
        return 1

    if mode == "snapshot":
        with open(keys_path, "w") as fh:
            json.dump(sorted(key_paths(doc)), fh, indent=1)
        print(f"[check_bench_keys] snapshot: {len(key_paths(doc))} key "
              f"paths from {bench_path}")
        return 0

    with open(keys_path) as fh:
        before = set(json.load(fh))
    after = set(key_paths(doc))
    missing = [s for s in required if s not in doc]
    if missing:
        print(f"FAIL: required BENCH section(s) absent: {missing}",
              file=sys.stderr)
        return 1
    lost = sorted(before - after)
    if lost:
        print(f"FAIL: {len(lost)} previously-recorded BENCH key path(s) "
              f"lost on merge-on-write:", file=sys.stderr)
        for p in lost[:40]:
            print(f"  {p}", file=sys.stderr)
        return 1
    print(f"[check_bench_keys] ok: all {len(before)} recorded key paths "
          f"survived the merge ({len(after) - len(before)} new)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
