"""Render EXPERIMENTS.md's roofline table from results/dryrun.jsonl."""
import json
import sys

SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def main(path="results/dryrun.jsonl"):
    recs = {}
    for line in open(path):
        r = json.loads(line)
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    lines = [
        "| arch | shape | dominant | compute | memory | collective | "
        "roofline frac | useful flops | peak GiB | multi-pod |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    archs = sorted({a for (a, _, _) in recs})
    for a in archs:
        for s in SHAPES:
            r = recs.get((a, s, "16x16"))
            if r is None:
                continue
            mp = recs.get((a, s, "2x16x16"), {})
            mp_status = "✓" if mp.get("status") == "ok" else mp.get(
                "status", "—")
            if r["status"] == "skipped":
                lines.append(f"| {a} | {s} | — | — | — | — | — | — | — | "
                             f"skip ({r['reason'][:28]}…) |")
                continue
            t = r["terms"]
            m = r["memory"]
            peak = (m["argument_bytes"] + m["output_bytes"]
                    - m["alias_bytes"] + m["temp_bytes"]) / 2**30
            lines.append(
                f"| {a} | {s} | **{t['dominant']}** | "
                f"{t['compute_s']*1e3:.1f} ms | {t['memory_s']*1e3:.1f} ms | "
                f"{t['collective_s']*1e3:.1f} ms | "
                f"{100*t['roofline_fraction']:.1f}% | "
                f"{r['useful_flop_ratio']:.2f} | {peak:.1f} | {mp_status} |")
    print("\n".join(lines))
    # patch EXPERIMENTS.md in place
    exp = open("EXPERIMENTS.md").read()
    marker = "<!-- ROOFLINE_TABLE -->"
    if marker in exp:
        exp = exp.replace(marker, "\n".join(lines))
        open("EXPERIMENTS.md", "w").write(exp)
        print("\n[patched EXPERIMENTS.md]", file=sys.stderr)


if __name__ == "__main__":
    main(*sys.argv[1:])
