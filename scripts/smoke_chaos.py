"""Chaos-subsystem CI smoke: the fault-injection path must be armed,
deterministic, and bitwise-invisible when null.

Three gates on a 64-device fleet (``CHAOS_SMOKE_DEVICES`` /
``CHAOS_SMOKE_PERIODS`` shrink for CI) with a fixed fault seed:

  1. *armed-null parity* — ``chaos=True`` with the all-zero `FaultModel`
     reproduces the fault-free rollout BIT for BIT (identity factors and
     zero losses are exact in float64);
  2. *the ladder fires* — a harsh fault model produces nonzero retry /
     fallback / drop-or-miss counters (a chaos run that never walks the
     ladder is vacuously green);
  3. *accounting closes* — ``n_offload_samples == n_offload_ok +
     n_fallback_local + n_dropped`` exactly, every period, and the
     realized makespan respects the documented
     ``2T + backoff_cap + one retransmission`` bound.

Standalone:  PYTHONPATH=src python scripts/smoke_chaos.py
"""
from __future__ import annotations

import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))


def main() -> int:
    import dataclasses

    import numpy as np

    from repro.api import engine as E
    from repro.serving import FaultModel, FleetConfig

    n_devices = int(os.environ.get("CHAOS_SMOKE_DEVICES", 64))
    periods = int(os.environ.get("CHAOS_SMOKE_PERIODS", 8))
    T = 1.2
    cfg = FleetConfig(n_devices=n_devices, T=T,
                      n_servers=max(1, n_devices // 16), policy="amr2",
                      rate=9.0, batch_max=8, horizon=periods + 2, seed=0,
                      fault_seed=11)
    base = E.EngineParams.from_config(cfg, horizon=periods + 2)
    failures = []

    # gate 1: armed-null bitwise parity -----------------------------------
    _, m0 = E.rollout(E.init_state(base), base, periods)
    armed = dataclasses.replace(base, faults=FaultModel.none(), chaos=True)
    _, m1 = E.rollout(E.init_state(armed), armed, periods)
    for f in [x.name for x in dataclasses.fields(type(m0))]:
        a, b = np.asarray(getattr(m0, f)), np.asarray(getattr(m1, f))
        if not np.array_equal(a, b):
            failures.append(f"armed-null parity broken on {f}: {b} != {a}")

    # gates 2 + 3: harsh model fires and accounts for every sample --------
    fm = FaultModel.make(es_crash_prob=0.08, link_degrade_prob=0.25,
                         link_degrade_mag=0.6, straggler_prob=0.2,
                         straggler_mult=1.8, loss_rate=0.15)
    params = base.with_faults(fm, fault_seed=11)
    _, M = E.rollout(E.init_state(params), params, periods)
    ladder = (int(np.asarray(M.n_retries).sum())
              + int(np.asarray(M.n_fallback_local).sum())
              + int(np.asarray(M.n_dropped).sum())
              + int(np.asarray(M.n_deadline_miss).sum()))
    if ladder == 0:
        failures.append("harsh fault model never fired (vacuous smoke)")
    n_off = np.asarray(M.n_offload_samples)
    closed = n_off == (np.asarray(M.n_offload_ok)
                       + np.asarray(M.n_fallback_local)
                       + np.asarray(M.n_dropped))
    if not closed.all():
        failures.append("offload accounting identity broken in period(s) "
                        f"{np.nonzero(~closed)[0].tolist()}")
    demand_cap = float(np.asarray(base.p_es).max()) * base.batch_max
    bound = 2.0 * T + float(fm.backoff_cap) \
        + demand_cap * (1.0 + float(fm.link_degrade_mag))
    worst = float(np.asarray(M.realized_makespan).max())
    if worst > bound + 1e-9:
        failures.append(f"realized makespan {worst:.3f} exceeds the "
                        f"ladder bound {bound:.3f}")
    # determinism under the fixed fault seed
    _, M2 = E.rollout(E.init_state(params), params, periods)
    for f in ("total_accuracy", "n_retries", "n_dropped",
              "realized_makespan"):
        if not np.array_equal(np.asarray(getattr(M, f)),
                              np.asarray(getattr(M2, f))):
            failures.append(f"chaos rollout not deterministic on {f}")

    if failures:
        print("FAIL: chaos smoke:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    acc0 = float(np.asarray(m0.total_accuracy).sum())
    acc = float(np.asarray(M.total_accuracy).sum())
    print(f"[chaos-smoke] ok: {n_devices} devices x {periods} periods — "
          f"armed-null bitwise parity, ladder fired "
          f"(retries={int(np.asarray(M.n_retries).sum())}, "
          f"fallback={int(np.asarray(M.n_fallback_local).sum())}, "
          f"dropped={int(np.asarray(M.n_dropped).sum())}, "
          f"miss={int(np.asarray(M.n_deadline_miss).sum())}), "
          f"accounting closed, accuracy {acc / max(acc0, 1e-12):.4f}x "
          f"fault-free")
    return 0


if __name__ == "__main__":
    sys.exit(main())
