"""CI smoke: run `examples/fleet_sim.py` against the unified `repro.api`
surface and fail if any DeprecationWarning originates from a repo-internal
call site.

External callers may keep using the `serving.plan*` shims (they warn and
delegate), but every internal path — the fleet engine, the executor, the
runtime, the examples — must be on `repro.api` directly.  A warning whose
frame lives under this repository therefore means a migration regression.

    PYTHONPATH=src python scripts/smoke_fleet_api.py
"""
from __future__ import annotations

import os
import sys
import warnings

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))
sys.path.insert(0, os.path.join(REPO, "examples"))


def main() -> int:
    import fleet_sim

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always", DeprecationWarning)
        fleet_sim.main(["--devices", "16", "--periods", "4",
                        "--servers", "1"])
        fleet_sim.main(["--devices", "8", "--periods", "2",
                        "--policy", "dual"])
        fleet_sim.main(["--devices", "8", "--periods", "3",
                        "--rollout"])

    # Only the repo's own code trees count as internal — an in-repo venv or
    # vendored site-packages must not fail the gate on third-party warnings.
    internal_trees = tuple(os.path.join(REPO, d) + os.sep
                           for d in ("src", "examples", "benchmarks",
                                     "scripts"))
    internal = [
        w for w in caught
        if issubclass(w.category, DeprecationWarning)
        and os.path.abspath(str(w.filename)).startswith(internal_trees)
    ]
    if internal:
        print("\nFAIL: DeprecationWarning raised from repo-internal "
              "call sites:", file=sys.stderr)
        for w in internal:
            print(f"  {w.filename}:{w.lineno}: {w.message}",
                  file=sys.stderr)
        return 1
    print("\n[smoke] fleet_sim ran clean on repro.api "
          f"({len(caught)} external/unrelated warnings ignored)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
