"""Differentiable-engine smoke: grad-vs-FD gate + one optax SLO step.

    PYTHONPATH=src python scripts/smoke_grad.py

Environment knobs: ``GRAD_SMOKE_DEVICES`` (fleet size, default 64),
``GRAD_SMOKE_PERIODS`` (default 6).  Three legs, exit 1 on any failure:

  * *forward pin* — with ``differentiable=False`` (and with the
    straight-through twin's forward) the rollout's served accuracy
    matches the hard engine to roundoff;
  * *grad vs FD* — `rollout_value_and_grad` in soft mode matches central
    finite differences to rtol 1e-4 on probed coordinates of ``p_es``,
    ``T``, and ``acc`` (jittered base points: the ladder generator's
    p_es sits exactly on LP vertex kinks where central FD averages the
    two one-sided derivatives);
  * *optax step* — one Adam step on (server-capacity scale, ladder-mix
    logits) strictly decreases an accuracy-SLO loss, i.e. the gradients
    point somewhere useful, not just somewhere finite.
"""
from __future__ import annotations

import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))


def main() -> int:
    import dataclasses

    import numpy as np

    import jax
    import jax.numpy as jnp
    import optax

    from repro.api import engine as E
    from repro.serving import FleetConfig

    n_devices = int(os.environ.get("GRAD_SMOKE_DEVICES", 64))
    periods = int(os.environ.get("GRAD_SMOKE_PERIODS", 6))
    failures = []

    cfg = FleetConfig(n_devices=n_devices, T=1.2, n_servers=4,
                      policy="amr2", backend="jax", rate=9.0, batch_max=8,
                      horizon=periods + 2, seed=0, straggler_frac=0.25,
                      outage_frac=0.1)
    params = E.EngineParams.from_config(cfg, horizon=periods + 2)

    def value(p):
        _, m = E.rollout(E.init_state(p), p, periods)
        return float(np.sum(np.asarray(m.total_accuracy)))

    # ---- leg 1: forward pins -------------------------------------------
    hard = value(params)
    st = params.with_differentiable(smooth_mode="st")
    v_st, _ = E.rollout_value_and_grad(E.init_state(st), st, periods)
    if not np.isclose(float(v_st), hard, rtol=0, atol=1e-8):
        failures.append(f"st forward {float(v_st)!r} != hard {hard!r}")
    print(f"[forward] hard={hard:.6f} st={float(v_st):.6f}")

    # ---- leg 2: grad vs central FD (soft mode, jittered base) ----------
    rng = np.random.default_rng(7)
    arr = np.asarray(params.p_es, np.float64)
    nudge = (rng.uniform(1e-3, 3e-3, size=arr.shape)
             * rng.choice([-1.0, 1.0], size=arr.shape))
    soft = dataclasses.replace(params, p_es=arr + nudge
                               ).with_differentiable(smooth_mode="soft")
    val, grads = E.rollout_value_and_grad(
        E.init_state(soft), soft, periods, wrt=("p_es", "T", "acc"))

    def fd(leaf, idx, eps=1e-5):
        base = np.asarray(getattr(soft, leaf), np.float64)
        flat = np.atleast_1d(base).ravel()
        shape = np.shape(base)
        out = []
        for sgn in (+1.0, -1.0):
            pert = flat.copy()
            pert[idx] += sgn * eps
            rep = pert.reshape(shape) if shape else float(pert[0])
            out.append(value(dataclasses.replace(soft, **{leaf: rep})))
        return (out[0] - out[1]) / (2 * eps)

    probes = [("p_es", i) for i in rng.choice(arr.size, 3, replace=False)]
    probes += [("T", 0), ("acc", int(rng.integers(
        np.asarray(soft.acc).size)))]
    for leaf, idx in probes:
        an = float(np.atleast_1d(
            np.asarray(grads[leaf], np.float64)).ravel()[idx])
        num = fd(leaf, idx)
        rel = abs(num - an) / max(abs(num), abs(an), 1e-8)
        ok = rel < 1e-4 or abs(num - an) < 1e-6
        print(f"[fd] {leaf}[{idx}]: fd={num:+.6f} grad={an:+.6f} "
              f"rel={rel:.2e} {'ok' if ok else 'FAIL'}")
        if not ok:
            failures.append(f"fd {leaf}[{idx}]: {num} vs {an}")

    # ---- leg 3: one optax step decreases the SLO loss ------------------
    # knobs: log server-capacity scale on p_es, ladder-mix logits on acc.
    # The knob math is plain f64 NumPy (the engine rejects anything an
    # unscoped jnp op would have downcast to f32).
    slo = 0.98 * val / (n_devices * periods)    # per-request accuracy SLO
    base_es = np.asarray(soft.p_es, np.float64)
    base_acc = np.asarray(soft.acc, np.float64)

    def sigmoid(x):
        return 1.0 / (1.0 + np.exp(-x))

    def loss_fn(knobs):
        p = dataclasses.replace(
            soft, p_es=base_es * np.exp(-knobs["log_cap"]),
            acc=base_acc * sigmoid(knobs["mix"]) * 2.0)
        lv, g = E.rollout_value_and_grad(E.init_state(p), p, periods,
                                         wrt=("p_es", "acc"))
        # chain rule by hand through the two reparameterizations (the
        # engine returns leaf-space grads; knob-space is a cheap VJP)
        d_cap = float(np.sum(np.asarray(g["p_es"], np.float64)
                             * base_es * -np.exp(-knobs["log_cap"])))
        s = sigmoid(knobs["mix"])
        d_mix = float(np.sum(np.asarray(g["acc"], np.float64)
                             * base_acc * 2.0 * s * (1 - s)))
        mean_acc = float(lv) / (n_devices * periods)
        # loss = shortfall^2; d(loss)/d(value) = -2 shortfall / N
        n = n_devices * periods
        short = max(0.0, slo - mean_acc)
        dv = -2.0 * short / n
        return short ** 2, {"log_cap": dv * d_cap, "mix": dv * d_mix}

    knobs = {"log_cap": np.float64(0.15), "mix": np.float64(-0.5)}
    opt = optax.adam(5e-2)
    opt_state = opt.init(knobs)
    l0, g0 = loss_fn(knobs)
    updates, opt_state = opt.update(g0, opt_state, knobs)
    knobs = jax.tree_util.tree_map(
        lambda k, u: np.float64(k) + np.float64(u), knobs, updates)
    l1, _ = loss_fn(knobs)
    print(f"[optax] slo_loss {l0:.3e} -> {l1:.3e}")
    if not (l1 < l0):
        failures.append(f"optax step did not decrease SLO loss: "
                        f"{l0} -> {l1}")

    if failures:
        print("\nFAIL:\n  " + "\n  ".join(failures))
        return 1
    print("\ngrad smoke OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
