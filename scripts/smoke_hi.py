"""Online-hierarchical-inference CI smoke: the confidence-gated path
must be armed, learning, and bitwise-invisible when disarmed.

Three gates on a 64-device fleet (``HI_SMOKE_DEVICES`` /
``HI_SMOKE_PERIODS`` shrink for CI) with a fixed stream seed:

  1. *disarm parity* — a params value round-tripped through
     ``with_hi(...)`` then ``with_hi(None)`` reproduces the default
     rollout BIT for BIT on every metric (the subsystem is out of the
     trace while ``hi_rule == "off"``), and the HI counters are exact
     zeros;
  2. *the learner learns* — on a fleet with heterogeneous per-device ES
     accuracies, the OGD threshold learner's cumulative pseudo-regret
     undercuts the miscalibrated fixed-threshold baseline it starts
     from (theta0 = 0.5 shared), and its regret growth is sublinear
     (second-half increment < first-half increment);
  3. *accounting closes* — ``n_hi_offloaded + n_hi_local_final ==
     n_jobs`` exactly, every period, and the armed rollout is
     deterministic under the fixed ``hi_seed``.

Standalone:  PYTHONPATH=src python scripts/smoke_hi.py
"""
from __future__ import annotations

import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))


def main() -> int:
    import dataclasses

    import numpy as np

    from repro.api import engine as E
    from repro.core.hi import HIModel
    from repro.serving import FleetConfig

    n_devices = int(os.environ.get("HI_SMOKE_DEVICES", 64))
    periods = int(os.environ.get("HI_SMOKE_PERIODS", 64))
    beta, hi_seed = 0.15, 11
    cfg = FleetConfig(n_devices=n_devices, T=1.2,
                      n_servers=max(1, n_devices // 16), policy="amr2",
                      rate=9.0, batch_max=8, horizon=periods + 2, seed=0)
    base = E.EngineParams.from_config(cfg, horizon=periods + 2)
    # heterogeneous per-device ES accuracies: the online regime, where
    # no shared threshold is right for every device (see fleet_bench.hi)
    acc = np.asarray(base.acc, np.float64).copy()
    acc[:, base.m] = np.random.default_rng(7).uniform(
        0.65, 0.92, n_devices)
    het = dataclasses.replace(base, acc=acc)
    failures = []

    # gate 1: disarm parity -----------------------------------------------
    _, m0 = E.rollout(E.init_state(base), base, periods)
    off = base.with_hi(HIModel.make(), rule="threshold").with_hi(None)
    _, m1 = E.rollout(E.init_state(off), off, periods)
    for f in E._METRIC_FIELDS:
        a, b = np.asarray(getattr(m0, f)), np.asarray(getattr(m1, f))
        if not np.array_equal(a, b):
            failures.append(f"disarm parity broken on {f}")
    for f in ("n_hi_offloaded", "n_hi_local_final", "hi_regret"):
        if np.asarray(getattr(m0, f)).sum() != 0:
            failures.append(f"disarmed rollout booked nonzero {f}")

    # gate 2: the learner beats the fixed threshold it starts from --------
    fixed = het.with_hi(HIModel.make(offload_cost=beta), rule="fixed",
                        hi_seed=hi_seed)
    learn = het.with_hi(HIModel.make(offload_cost=beta), rule="threshold",
                        hi_seed=hi_seed)
    _, mf = E.rollout(E.init_state(fixed), fixed, periods)
    _, ml = E.rollout(E.init_state(learn), learn, periods)
    reg_f = float(np.asarray(mf.hi_regret)[-1])
    reg_l = np.asarray(ml.hi_regret)
    if not reg_l[-1] < reg_f:
        failures.append(f"threshold learner regret {reg_l[-1]:.1f} did "
                        f"not undercut the fixed baseline {reg_f:.1f}")
    first = reg_l[periods // 2 - 1] - reg_l[0]
    second = reg_l[-1] - reg_l[periods // 2 - 1]
    if not second < first:
        failures.append(f"regret growth not sublinear: second half "
                        f"{second:.1f} >= first half {first:.1f}")

    # gate 3: accounting closes + determinism -----------------------------
    for tag, m in (("fixed", mf), ("threshold", ml)):
        closed = (np.asarray(m.n_hi_offloaded)
                  + np.asarray(m.n_hi_local_final)
                  == np.asarray(m.n_jobs))
        if not closed.all():
            failures.append(
                f"{tag}: serving identity broken in period(s) "
                f"{np.nonzero(~closed)[0].tolist()}")
    _, ml2 = E.rollout(E.init_state(learn), learn, periods)
    for f in ("total_accuracy", "n_hi_offloaded", "hi_regret"):
        if not np.array_equal(np.asarray(getattr(ml, f)),
                              np.asarray(getattr(ml2, f))):
            failures.append(f"armed rollout not deterministic on {f}")

    if failures:
        print("FAIL: hi smoke:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    n_off = int(np.asarray(ml.n_hi_offloaded).sum())
    n_jobs = int(np.asarray(ml.n_jobs).sum())
    print(f"[hi-smoke] ok: {n_devices} devices x {periods} periods — "
          f"disarm bitwise parity, learner regret {reg_l[-1]:.1f} < "
          f"fixed {reg_f:.1f} (sublinear: {second:.1f} < {first:.1f}), "
          f"accounting closed ({n_off}/{n_jobs} samples offloaded), "
          f"deterministic under hi_seed={hi_seed}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
