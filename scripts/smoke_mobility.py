"""Multi-cell mobility smoke: routing determinism, the S=1 bitwise
reduction, and sharded-by-cell parity on a replayed trace.

Launch with host-platform devices spawned BEFORE jax initialises (the
sharded leg needs > 1 jax device; without it that leg is skipped):

    XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \
        python scripts/smoke_mobility.py

Environment knobs: ``MOBILITY_SMOKE_DEVICES`` (fleet size, default 32),
``MOBILITY_SMOKE_PERIODS`` (default 8), ``MOBILITY_SMOKE_SHARDS``
(default all jax devices).  Three legs, exit 1 on any failure:

  * *determinism* — two rollouts of the same replayed-trace multi-cell
    params are BITWISE identical (routing, admission, and handover are
    pure functions of the trace), and every period's routed cell
    respects the coverage radius;
  * *S=1 reduction* — one cell at the origin with an infinite radius
    reproduces the single-pool engine bit for bit (the acceptance pin);
  * *sharded-by-cell* — a geographically-local fleet (each shard's
    devices roam only its own cell pair) under ``shard_by_cell=True``
    (local segmented admission, the all_gather elided) matches the
    unsharded rollout, and the plain sharded path (global segmented
    admission over the gathered demand) matches too.
"""
from __future__ import annotations

import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))


def main() -> int:
    import jax
    import numpy as np

    from repro.api import engine as E
    from repro.core.mobility import MobilityModel, route_cells
    from repro.serving import FleetConfig

    n_devices = int(os.environ.get("MOBILITY_SMOKE_DEVICES", 32))
    periods = int(os.environ.get("MOBILITY_SMOKE_PERIODS", 8))
    failures = []

    def check(tag, got, want, exact=True):
        got, want = np.asarray(got), np.asarray(want)
        ok = (np.array_equal(got, want) if exact
              else np.allclose(got, want, rtol=1e-9, atol=1e-12))
        if not ok:
            failures.append(f"{tag}: {got} != {want}")

    cfg = FleetConfig(n_devices=n_devices, T=1.2, n_servers=8,
                      policy="amr2", rate=8.0, batch_max=8,
                      horizon=periods + 2, seed=0)
    params = E.EngineParams.from_config(cfg, horizon=periods + 2)

    # 8 cells in 4 close pairs (spacing 10 within a pair, 40 between):
    # devices roam around their pair's midpoint, so handovers happen
    # WITHIN a pair — each shard of the sharded leg owns one pair, so
    # geographic locality holds for shard_by_cell
    S = 8
    rng = np.random.default_rng(1)
    cxy = np.stack([40.0 * (np.arange(S) // 2) + 10.0 * (np.arange(S) % 2),
                    np.zeros(S)], axis=1)
    mid = 0.5 * (cxy[0::2] + cxy[1::2])              # (4, 2) pair centres
    home = mid[np.arange(n_devices) % 4]
    trace = rng.normal(scale=6.0, size=(periods + 2, n_devices, 2)) + home
    mob = MobilityModel.make(cell_xy=cxy, trace=trace, radius=25.0,
                             link_alpha=0.3)
    armed = params.with_mobility(mob, routing="min_time")

    # --- leg 1: routing determinism ------------------------------------
    s_a, m_a = E.rollout(E.init_state(armed), armed, periods)
    s_b, m_b = E.rollout(E.init_state(armed), armed, periods)
    for f in E._METRIC_FIELDS:
        check(f"determinism/{f}", getattr(m_a, f), getattr(m_b, f))
    for f in E._STATE_FIELDS:
        for i, (a, b) in enumerate(zip(jax.tree.leaves(getattr(s_a, f)),
                                       jax.tree.leaves(getattr(s_b, f)))):
            check(f"determinism/state/{f}[{i}]", a, b)
    if int(np.asarray(m_a.n_handover).sum()) == 0:
        failures.append("no handovers fired (vacuous mobility smoke); "
                        "loosen the trace")
    # routed cells respect the coverage radius at every period
    for t in range(periods):
        cell, covered, _ = (np.asarray(a) for a in route_cells(
            trace[t], mob, np.zeros(S), "min_time"))
        dist = np.linalg.norm(trace[t][:, None] - cxy[None], axis=2)
        ok = covered.nonzero()[0]
        if not (dist[ok, cell[ok]] <= float(mob.radius)).all():
            failures.append(f"period {t}: a device was routed to a cell "
                            f"outside the coverage radius")
            break

    # --- leg 2: the S=1 / infinite-radius bitwise reduction -------------
    null_mob = MobilityModel.make(cell_xy=np.zeros((1, 2)),
                                  trace=np.zeros((periods + 2,
                                                  n_devices, 2)))
    reduced = params.with_mobility(null_mob)
    s_off, m_off = E.rollout(E.init_state(params), params, periods)
    s_red, m_red = E.rollout(E.init_state(reduced), reduced, periods)
    for f in E._METRIC_FIELDS:
        check(f"s1_reduction/{f}", getattr(m_red, f), getattr(m_off, f))
    for f in ("key", "p_ed", "pending", "head", "warm_basis", "n_updates"):
        check(f"s1_reduction/state/{f}", getattr(s_red, f),
              getattr(s_off, f))

    # --- leg 3: sharded-by-cell parity ----------------------------------
    import jax
    n_shards = int(os.environ.get("MOBILITY_SMOKE_SHARDS",
                                  len(jax.devices())))
    if len(jax.devices()) < 2:
        print("[mobility-smoke] single jax device; sharded leg skipped "
              "(launch with XLA_FLAGS="
              "--xla_force_host_platform_device_count=4)")
        n_shards = 0
    elif n_devices % n_shards or (n_devices // n_shards) % 4:
        failures.append(f"{n_devices} devices do not split into "
                        f"{n_shards} shards of whole cell-pair groups")
        n_shards = 0
    if n_shards:
        # geographic locality for shard_by_cell: shard i's devices roam
        # pair (i % 4) — regroup the fleet so contiguous shard slices
        # hold one pair each (4 shards x pair = the home layout above
        # reordered device-major)
        order = np.argsort(np.arange(n_devices) % 4, kind="stable")
        tr_local = trace[:, order]
        mob_local = MobilityModel.make(cell_xy=cxy, trace=tr_local,
                                       radius=25.0, link_alpha=0.3)
        mesh = E.fleet_mesh(min(n_shards, 4))
        for sbc in (False, True):
            p = params.with_mobility(mob_local, routing="min_time",
                                     shard_by_cell=sbc)
            uf, MU = E.rollout(E.init_state(p), p, periods)
            sstate, sparams = E.shard(E.init_state(p), p, mesh)
            sf, MS = E.rollout_sharded(sstate, sparams, periods, mesh)
            tag = f"sharded{'_by_cell' if sbc else ''}"
            for f in ("n_jobs", "n_violations", "n_offloading",
                      "n_backpressured", "n_outage", "backlog",
                      "n_handover"):
                check(f"{tag}/{f}", getattr(MS, f), getattr(MU, f))
            for f in ("total_accuracy", "es_utilization",
                      "worst_violation"):
                check(f"{tag}/{f}", getattr(MS, f), getattr(MU, f),
                      exact=False)
            check(f"{tag}/final/warm_basis", sf.warm_basis, uf.warm_basis)
            check(f"{tag}/final/cell", sf.cell, uf.cell)
            check(f"{tag}/final/cell_load", sf.cell_load, uf.cell_load,
                  exact=False)

    if failures:
        print("FAIL: mobility smoke:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    acc = float(np.asarray(m_a.total_accuracy).sum())
    print(f"[mobility-smoke] ok: {n_devices} devices x {periods} periods, "
          f"{S} cells, {int(np.asarray(m_a.n_handover).sum())} handovers; "
          f"determinism + S=1 reduction + sharded parity hold "
          f"(total accuracy {acc:.3f})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
