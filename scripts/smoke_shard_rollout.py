"""Sharded engine-v2 smoke: `step`/`rollout` under `shard_map` must match
the unsharded pure-functional engine.

Launch with host-platform devices spawned BEFORE jax initialises:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python scripts/smoke_shard_rollout.py

Environment knobs: ``SHARD_SMOKE_DEVICES`` (fleet size, default 64),
``SHARD_SMOKE_SHARDS`` (mesh size, default all jax devices),
``SHARD_SMOKE_PERIODS`` (default 8), ``SHARD_SMOKE_CHAOS=1`` (arm the
fault-injection subsystem with a replayed fault trace AND flip a quarter
of the fleet's outage schedule mid-horizon — the stale-warm-basis guard
and the per-device folded fault draws must both hold under sharding).
Exits 1 on any parity failure — integer metrics (including the ladder
counters) and the final pytree state must match exactly, float metrics
to 1e-9 (per-shard partial sums + psum reassociate the float64
reductions).
"""
from __future__ import annotations

import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))


def main() -> int:
    import jax
    import numpy as np

    n_shards = int(os.environ.get("SHARD_SMOKE_SHARDS",
                                  len(jax.devices())))
    if len(jax.devices()) < max(n_shards, 2):
        print(f"FAIL: {len(jax.devices())} jax device(s); launch with "
              f"XLA_FLAGS=--xla_force_host_platform_device_count="
              f"{max(n_shards, 8)}", file=sys.stderr)
        return 1
    n_devices = int(os.environ.get("SHARD_SMOKE_DEVICES", 64))
    periods = int(os.environ.get("SHARD_SMOKE_PERIODS", 8))

    from repro.api import engine as E
    from repro.serving import FleetConfig

    cfg = FleetConfig(n_devices=n_devices, T=1.2,
                      n_servers=max(1, n_devices // 16), policy="amr2",
                      rate=8.0, batch_max=8, horizon=periods + 2, seed=0)
    params = E.EngineParams.from_config(cfg, horizon=periods + 2)
    chaos = os.environ.get("SHARD_SMOKE_CHAOS", "0") == "1"
    if chaos:
        import dataclasses

        from repro.serving import FaultModel

        # mid-horizon outage flip on every 4th device: the stale-warm-
        # basis cold-start (PR 6) must agree across shards with the
        # fault path armed
        outage = np.array(params.outage)
        outage[::4, max(1, periods // 2):] = \
            ~outage[::4, max(1, periods // 2):]
        params = dataclasses.replace(params, outage=outage)
        params = params.with_faults(
            FaultModel.make(loss_rate=0.1, straggler_prob=0.15,
                            straggler_mult=2.0, link_degrade_prob=0.2,
                            link_degrade_mag=0.5, es_crash_prob=0.05),
            fault_seed=3)
    state = E.init_state(params)
    mesh = E.fleet_mesh(n_shards)
    sstate, sparams = E.shard(state, params, mesh)

    failures = []

    def check(tag, got, want, exact):
        got, want = np.asarray(got), np.asarray(want)
        ok = (np.array_equal(got, want) if exact
              else np.allclose(got, want, rtol=1e-9, atol=1e-12))
        if not ok:
            failures.append(f"{tag}: sharded {got} != unsharded {want}")

    ladder_ints = ("n_offload_samples", "n_offload_ok", "n_deadline_miss",
                   "n_retries", "n_fallback_local", "n_dropped")

    # one sharded step vs unsharded
    u1, mu = E.step(state, params)
    s1, ms = E.step_sharded(sstate, sparams, mesh)
    for f in ("n_jobs", "n_violations", "n_offloading", "n_backpressured",
              "n_outage", "n_straggler_updates", "backlog") + ladder_ints:
        check(f"step/{f}", getattr(ms, f), getattr(mu, f), exact=True)
    for f in ("total_accuracy", "worst_violation", "es_utilization",
              "realized_makespan"):
        check(f"step/{f}", getattr(ms, f), getattr(mu, f), exact=False)

    # whole sharded rollout vs unsharded rollout
    uf, MU = E.rollout(state, params, periods)
    sf, MS = E.rollout_sharded(sstate, sparams, periods, mesh)
    for f in ("n_jobs", "n_violations", "n_offloading", "n_backpressured",
              "n_outage", "backlog") + ladder_ints:
        check(f"rollout/{f}", getattr(MS, f), getattr(MU, f), exact=True)
    for f in ("total_accuracy", "realized_makespan"):
        check(f"rollout/{f}", getattr(MS, f), getattr(MU, f), exact=False)
    if chaos and int(np.asarray(MU.n_retries).sum()) \
            + int(np.asarray(MU.n_fallback_local).sum()) \
            + int(np.asarray(MU.n_dropped).sum()) == 0:
        failures.append("chaos armed but the ladder never fired "
                        "(vacuous parity)")
    check("final/warm_basis", sf.warm_basis, uf.warm_basis, exact=True)
    check("final/pending", sf.pending, uf.pending, exact=True)
    check("final/p_ed", sf.p_ed, uf.p_ed, exact=False)

    if failures:
        print("FAIL: sharded engine diverged from unsharded:",
              file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    acc = float(np.asarray(MS.total_accuracy).sum())
    print(f"[shard-smoke] ok: {n_devices} devices x {periods} periods on "
          f"a {n_shards}-shard mesh match the unsharded engine "
          f"(total accuracy {acc:.3f})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
