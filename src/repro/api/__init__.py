"""`repro.api` — the unified solver surface.

One front door (`solve` / `solve_many`), one problem vocabulary
(`Problem`, `FleetProblem` — JAX pytrees), one result type (`Solution`),
and a capability-declaring registry (`register_solver`, `solvers`) that
every planning algorithm plugs into:

    >>> from repro import api
    >>> sol = api.solve(api.Problem(p_ed, p_es, acc, T))        # auto
    >>> sol = api.solve(fleet_problem, policy="dual")           # batched
    >>> sol = api.solve(fleet_problem, es_disabled=True)        # replan
    >>> api.solver_names()
    ['amdp', 'amr2', 'dual', 'greedy', 'lp']

The legacy `serving.plan*` entry points are deprecation shims over this
module; new code (and every repo-internal call site) uses `api` directly.

The differentiable serving stack rides on the same surface: arm a params
value with ``EngineParams.with_differentiable()`` and the epoch becomes a
`jax.grad`-able function of the continuous knobs (ES capacity ``p_es``,
deadline ``T``, ladder mix ``acc``):

    >>> armed = params.with_differentiable(smooth_mode="soft")
    >>> val, g = api.rollout_value_and_grad(engine.init_state(armed),
    ...                                     armed, periods)
    >>> g["p_es"].shape == params.p_es.shape

Online hierarchical inference (the ``online`` registry capability) rides
there too: arm with ``EngineParams.with_hi()`` and the rollout runs
per-sample confidence-gated offloading with the learner inside the scan:

    >>> armed = params.with_hi(HIModel.from_profiles(params.base_p_ed),
    ...                        rule="threshold")
    >>> _, metrics = engine.rollout(engine.init_state(armed), armed, 64)
    >>> metrics.hi_regret[-1]         # cumulative pseudo-regret vs theta*
"""
from ..core.problem import (ES_DISABLED_SENTINEL, ST_UNSOLVED,
                            SOLUTION_STATUS_NAMES, FleetProblem, Problem,
                            Solution)
from .front import batched_policies, solve, solve_many
from .registry import (Solver, SolverInfo, get_solver, register_solver,
                       solver_names, solver_table, solvers)
from . import solvers as _builtin_solvers  # noqa: F401  (register entries)
from . import engine  # pure-functional EngineState/step/rollout/shard
from .engine import (GRAD_LEAVES, combine_diff, partition_diff,
                     rollout_grad, rollout_value_and_grad)
from ..core.hi import HILearnerState, HIModel

__all__ = [
    "Problem", "FleetProblem", "Solution",
    "SOLUTION_STATUS_NAMES", "ST_UNSOLVED", "ES_DISABLED_SENTINEL",
    "solve", "solve_many", "batched_policies",
    "Solver", "SolverInfo", "register_solver", "get_solver",
    "solver_names", "solvers", "solver_table",
    "engine",
    "GRAD_LEAVES", "rollout_grad", "rollout_value_and_grad",
    "partition_diff", "combine_diff",
    "HIModel", "HILearnerState",
]
