"""Pure-functional fleet engine: `EngineState` pytree + `step`/`rollout`/
`shard`.

`FleetEngine` (PR 1-4) plans each period in a handful of jitted calls, but
the period LOOP — queue arrivals, ES-pool admission, drift/outage,
straggler audit, warm-basis carry — is host Python over NumPy state, so a
multi-period rollout pays one host round-trip per period and cannot be
`lax.scan`-ed or `shard_map`-ed.  This module redesigns the serving API
around a pure state machine:

  * ``EngineParams`` — everything static over a rollout, as one registered
    pytree: per-device latency/accuracy tables (re-indexed to the queue's
    class table), precomputed drift/outage schedules, the arrival model
    (a replayed count/class-stream trace with bit-parity to the host
    `RequestQueue`, or array-native Poisson sampling with `jax.random`),
    and the solver configuration as static aux data.
  * ``EngineState`` — everything that evolves, as one pytree of arrays:
    the belief latency tables (EMA straggler audit state), per-device
    backlog counts and stream cursors, the PRNG key, and the previous
    period's warm simplex bases (PR 4).
  * ``step(state, params) -> (state, PeriodMetrics)`` — ONE pure traced
    period: release arrivals, assemble the padded `FleetProblem`
    (`FleetProblem.from_arrays_unchecked` — the same stacked pytree the
    host engine solves), plan it with the traceable warm-or-cold batched
    simplex + AMR^2 rounding (`lp.simplex_batch_core`,
    `amr2.round_relaxation_jnp`) or the vmapped dual solver, run the
    vectorized ES-pool admission scan, replan bumped devices ES-disabled
    (a lane-masked second solve: non-bumped lanes cost zero pivots),
    price/audit, and emit scalar metrics.
  * ``rollout(state, params, periods)`` — a whole fleet epoch as ONE
    `jax.lax.scan` over jitted `step`: no per-period host sync.
  * ``shard(state, params, mesh)`` + ``step_sharded``/``rollout_sharded``
    — `device_put` the stacked fleet axis across a mesh and run the same
    step under `shard_map`; the only cross-device traffic is one
    `all_gather` of the (D,) ES-demand vector for the global admission
    scan plus scalar `psum`s for the metrics.  CPU-validated with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

Everything runs in float64 (`jax.experimental.enable_x64` around every
public entry point, like `solve_lp_batch`), so `step` is bit-comparable
with the host `FleetEngine.run_period` — which now *delegates* to the same
jitted period core on the jax backend (see `serving.fleet`).

Typical use::

    from repro.api import engine
    params = engine.EngineParams.from_config(cfg, horizon=64)
    state = engine.init_state(params)
    state, metrics = engine.rollout(state, params, periods=64)
    # metrics.total_accuracy is a (64,) array, one entry per period

The dtype discipline inside the scan: every integer state leaf is int32
and every new value is explicitly cast back, so the `lax.scan` carry
structure is stable.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.amr2 import (build_lp_arrays_jnp, round_relaxation_jnp,
                         soft_assignment_weights, straight_through_weights)
from ..core.dual import _dual_one
from ..core.faults import (FaultModel, greedy_local_fill,
                           realize_execution, sample_realization)
from ..core.hi import (HILearnerState, HIModel, hi_period,
                       sample_confidence, validate_hi)
from ..core.lp import (_bucket_maxiter, simplex_batch_core,
                       simplex_batch_grad)
from ..core.mobility import (MobilityModel, admit_mask_pool,
                             admit_mask_segmented, route_cells,
                             validate_mobility)
from ..core.problem import (ES_DISABLED_SENTINEL, ST_UNSOLVED as
                            _ST_UNSOLVED, FleetProblem)

# Policies with a fully-traceable batched path (the scan/shard requirement;
# "auto"/"amdp" need host-side identical-job dispatch and stay on the host
# engine).
TRACEABLE_POLICIES = ("amr2", "dual")
FLEET_AXIS = "fleet"


def _register(cls, leaf_fields: Tuple[str, ...],
              aux_fields: Tuple[str, ...] = ()) -> None:
    """Register a frozen dataclass pytree: ``leaf_fields`` are children,
    ``aux_fields`` ride along as (hashable) static aux data.  Unflatten
    bypasses ``__init__`` so tracers survive the round-trip."""
    def flatten(obj):
        return (tuple(getattr(obj, f) for f in leaf_fields),
                tuple(getattr(obj, f) for f in aux_fields))

    def unflatten(aux, children):
        obj = object.__new__(cls)
        for f, v in zip(leaf_fields, children):
            object.__setattr__(obj, f, v)
        for f, v in zip(aux_fields, aux):
            object.__setattr__(obj, f, v)
        return obj

    jax.tree_util.register_pytree_node(cls, flatten, unflatten)


@dataclasses.dataclass(frozen=True)
class EngineParams:
    """Rollout-invariant fleet description (pytree; solver config is aux).

    All per-class tables are indexed by the QUEUE class table (the arrival
    streams sample class indices, not values), re-indexed from each
    device's profile at construction.  ``drift``/``outage`` are
    precomputed per-period schedules; periods beyond their horizon cycle.

    Arrival models (``arrivals`` aux):
      * ``"replay"`` — ``counts`` (H, D) and ``stream`` (D, S) hold a
        presampled arrival trace (`RequestQueue.presample`), giving
        BIT-IDENTICAL arrivals to the host queue for the same seed: the
        parity mode.
      * ``"poisson"`` — arrival counts are drawn inside the traced step
        with `jax.random.poisson` (per-device folded keys, so sharded and
        unsharded sampling agree) and job classes with `jax.random.choice`
        at release time; backlogged jobs re-sample their class at release,
        which is distributionally identical for i.i.d. classes.  The
        no-host-data mode for 10k+-device fleets.
    """

    # ---- pytree leaves --------------------------------------------------
    classes: np.ndarray     # (c,) queue class labels (reference only)
    base_p_ed: np.ndarray   # (D, c, m) ground-truth ED latencies
    p_es: np.ndarray        # (D, c) ES latencies (comm incl.)
    acc: np.ndarray         # (D, m+1) accuracies
    T: np.ndarray           # ()  period budget
    rate: np.ndarray        # (D,) Poisson arrival rates
    class_probs: np.ndarray  # (c,) class sampling distribution
    drift: np.ndarray       # (D, H) true per-period ED slowdown factors
    outage: np.ndarray      # (D, H) bool, ES link down
    counts: np.ndarray      # (Hc, D) replayed arrival counts (replay mode)
    stream: np.ndarray      # (D, S) replayed class indices (replay mode)
    # chaos: the fault distribution sampled inside the traced step (all
    # float64 scalar leaves — sweeping fault rates reuses one compiled
    # rollout).  Only consulted when the static ``chaos`` aux is True;
    # the fault-free trace carries the leaves but never reads them.
    faults: FaultModel = dataclasses.field(default_factory=FaultModel.none)
    # multi-cell mobility: cell geometry + device motion (all-float64-leaf
    # pytree like `faults`; only consulted when the static
    # ``mobility_mode`` aux is not "off" — the single-pool trace carries
    # the leaves but never reads them)
    mobility: MobilityModel = dataclasses.field(
        default_factory=MobilityModel.none)
    # online hierarchical inference: calibration curves + learner
    # hyper-parameters (all-float64-leaf pytree like `faults`; only
    # consulted when the static ``hi_rule`` aux is not "off" — the
    # planned trace carries the leaves but never reads them)
    hi: HIModel = dataclasses.field(default_factory=HIModel.none)
    # ---- static aux -----------------------------------------------------
    policy: str = "amr2"
    arrivals: str = "replay"
    n_servers: int = 1
    batch_max: int = 12
    straggler_threshold: float = 1.5
    ema: float = 0.5
    frac_tol: float = 1e-4
    iters: int = 40            # dual bisection iterations
    maxiter: Optional[int] = None
    tol: float = 1e-7
    # simplex pivot representation: "tableau" (dense, bit-compatible with
    # the PR-5 pins) or "revised" (reduced-tableau eta-factor path — the
    # 100k-lane memory/throughput shape; see core.lp.simplex_batch_core)
    lp_method: str = "tableau"
    # chaos (static, so the fault-free trace is byte-identical to an
    # engine without the fault subsystem): ``chaos`` arms the realized-
    # execution pass, ``max_retries`` bounds the unrolled retry rounds of
    # the degradation ladder, ``fault_seed`` seeds the replayed fault
    # stream (independent of the arrival PRNG — arming chaos never
    # perturbs arrivals)
    chaos: bool = False
    max_retries: int = 2
    fault_seed: int = 0
    # multi-cell mobility (static): "off" keeps the byte-identical
    # single-pool trace; "replay" reads positions from ``mobility.trace``;
    # "walk" integrates Gaussian steps from the folded ``mobility_seed``
    # stream (independent of the arrival PRNG, like ``fault_seed``).
    # ``n_cells`` partitions the ``n_servers`` pool evenly across cells;
    # ``routing`` picks the serving cell ("nearest" / "min_time");
    # ``shard_by_cell`` elides the admission all_gather under shard_map
    # (valid when each shard's devices route only to its own cells)
    mobility_mode: str = "off"
    routing: str = "nearest"
    n_cells: int = 1
    mobility_seed: int = 0
    shard_by_cell: bool = False
    # online hierarchical inference (static): ``hi_rule`` "off" keeps the
    # byte-identical planned trace; "fixed"/"threshold"/"ucb"/"exp3"
    # replace the LP plan with the per-sample confidence gate
    # (`core.hi`).  ``hi_stream`` picks fold-keyed ("fold", from
    # ``hi_seed`` — independent of the arrival PRNG, like ``fault_seed``)
    # or replayed ("replay", from ``hi.conf_trace``) confidences;
    # ``hi_arms`` sizes the bandit rules' threshold grid; ``hi_local``
    # names the local model every sample runs on.
    hi_rule: str = "off"
    hi_stream: str = "fold"
    hi_arms: int = 9
    hi_seed: int = 0
    hi_local: int = 0
    # differentiable rollout (static; False keeps the forward trace
    # byte-identical to an engine without the gradient subsystem).
    # ``smooth_mode`` picks the relaxation of the two discrete stages:
    # "st" (straight-through: forward = the hard Algorithm-2 rounding +
    # first-fit admission, backward = the smoothed Jacobians) or "soft"
    # (forward itself runs the temperature-softened blend — the mode
    # finite-difference checks validate, since the hard forward is
    # piecewise constant).  ``smooth_tau`` tempers the assignment softmax
    # (`core.amr2.soft_assignment_weights`), ``admit_tau`` the sigmoid
    # capacity test (in units of T).  ``grad_leaves`` names the default
    # EngineParams leaves `rollout_grad` differentiates.
    differentiable: bool = False
    smooth_mode: str = "st"
    smooth_tau: float = 0.25
    admit_tau: float = 0.05
    grad_leaves: Tuple[str, ...] = ("p_es", "T", "acc")

    @property
    def n_devices(self) -> int:
        return self.base_p_ed.shape[0]

    @property
    def m(self) -> int:
        return self.base_p_ed.shape[2]

    @property
    def n_basis_rows(self) -> int:
        """Simplex rows R = batch_max + 2 (warm-basis width)."""
        return self.batch_max + 2

    @property
    def servers_per_cell(self) -> int:
        """ES tiers fronted by each cell (the whole pool when S=1)."""
        return self.n_servers // max(self.n_cells, 1)

    @property
    def hi_armed(self) -> bool:
        """Online hierarchical inference replaces the LP plan."""
        return self.hi_rule != "off"

    # ---- constructors ----------------------------------------------------
    @classmethod
    def from_fleet(cls, devices, queue, *, T: float, n_servers: int = 1,
                   policy: str = "amr2", horizon: int = 64,
                   arrivals: str = "replay",
                   straggler_threshold: float = 1.5, ema: float = 0.5,
                   frac_tol: float = 1e-4, iters: int = 40,
                   maxiter: Optional[int] = None,
                   tol: float = 1e-7,
                   lp_method: str = "tableau",
                   faults: Optional[FaultModel] = None,
                   max_retries: int = 2,
                   fault_seed: int = 0,
                   mobility: Optional[MobilityModel] = None,
                   mobility_mode: str = "replay",
                   routing: str = "nearest",
                   mobility_seed: int = 0) -> "EngineParams":
        """Build params from `DeviceSpec`s + a `RequestQueue` (the host
        engine's vocabulary).  Requires one shape group — every profile
        sharing a class table and model count — which is what
        `make_fleet`/`FleetConfig` fleets always are."""
        if policy == "auto":
            policy = "amr2"     # the traceable LP path; the DP dispatch
            #                     of "auto" is a host-engine feature
        if policy not in TRACEABLE_POLICIES:
            raise ValueError(
                f"policy={policy!r} has no traceable batched path; the "
                f"pure-functional engine supports {TRACEABLE_POLICIES}")
        if arrivals not in ("replay", "poisson"):
            raise ValueError(f"unknown arrivals mode {arrivals!r}")
        if lp_method not in ("tableau", "revised"):
            raise ValueError(f"unknown lp_method {lp_method!r}; expected "
                             f"'tableau' or 'revised'")
        if horizon <= 0:
            raise ValueError("horizon must be positive")
        if max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if queue.n_devices != len(devices):
            raise ValueError("queue.n_devices must match the fleet size")
        mob = mobility if mobility is not None else MobilityModel.none()
        mob_mode = mobility_mode if mobility is not None else "off"
        validate_mobility(mob, n_devices=len(devices), n_servers=n_servers,
                          mode=mob_mode, routing=routing)
        qcls = np.asarray(queue.classes)
        key0 = None
        for d, spec in enumerate(devices):
            pcls = np.asarray(spec.profile.classes)
            if pcls.size > 1 and np.any(np.diff(pcls) <= 0):
                # the searchsorted re-indexing below silently mis-prices
                # (or IndexErrors) on an unsorted table — same guard as
                # FleetEngine.__init__, needed here too because
                # FleetConfig(devices=...) can reach this path directly
                raise ValueError(
                    f"device {d} ({spec.profile.name}) profile classes "
                    f"{pcls.tolist()} must be strictly ascending")
            key = (tuple(pcls.tolist()), spec.profile.p_ed.shape[1])
            if key0 is None:
                key0 = key
            elif key != key0:
                raise ValueError(
                    "EngineParams.from_fleet needs a single shape group "
                    "(one class table and model count across the fleet); "
                    f"device {d} has {key}, device 0 has {key0}")
            missing = set(qcls.tolist()) - set(pcls.tolist())
            if missing:
                raise ValueError(
                    f"device {d} has no profile entry for queue classes "
                    f"{sorted(missing)}")
        # re-index every per-class table to the queue's class axis
        pcls = np.asarray(devices[0].profile.classes)
        lut = np.searchsorted(pcls, qcls)
        base_p_ed = np.stack([d.profile.p_ed[lut] for d in devices]
                             ).astype(np.float64)
        p_es = np.stack([d.profile.p_es[lut] for d in devices]
                        ).astype(np.float64)
        acc = np.stack([d.profile.acc for d in devices]).astype(np.float64)
        drift = np.stack([[d.drift_at(t) for t in range(horizon)]
                          for d in devices]).astype(np.float64)
        outage = np.stack([[d.outage_at(t) for t in range(horizon)]
                           for d in devices]).astype(bool)
        if arrivals == "replay":
            counts, stream = queue.presample(horizon)
        else:
            counts = np.zeros((1, len(devices)), dtype=np.int64)
            stream = np.zeros((len(devices), 1), dtype=np.int32)
        probs = (np.full(len(qcls), 1.0 / len(qcls))
                 if queue.class_probs is None
                 else np.asarray(queue.class_probs, np.float64))
        return cls(
            classes=qcls.astype(np.int64),
            base_p_ed=base_p_ed, p_es=p_es, acc=acc,
            T=np.float64(T),
            rate=np.asarray(queue.rate, np.float64),
            class_probs=probs, drift=drift, outage=outage,
            counts=counts.astype(np.int32), stream=stream,
            faults=faults if faults is not None else FaultModel.none(),
            mobility=mob, mobility_mode=mob_mode, routing=routing,
            n_cells=mob.n_cells if mob_mode != "off" else 1,
            mobility_seed=mobility_seed,
            policy=policy, arrivals=arrivals, n_servers=n_servers,
            batch_max=queue.batch_max,
            straggler_threshold=straggler_threshold, ema=ema,
            frac_tol=frac_tol, iters=iters, maxiter=maxiter, tol=tol,
            lp_method=lp_method,
            chaos=faults is not None and not faults.is_null(),
            max_retries=max_retries, fault_seed=fault_seed)

    @classmethod
    def from_config(cls, config, *, horizon: Optional[int] = None,
                    arrivals: str = "replay",
                    policy: Optional[str] = None,
                    lp_method: str = "tableau") -> "EngineParams":
        """Build params from a declarative `serving.FleetConfig` — the
        engine-v2 twin of `FleetEngine.from_config`.  The replayed arrival
        trace covers ``horizon`` periods (default: the config's
        straggler/outage ``horizon``)."""
        horizon = horizon if horizon is not None else config.horizon
        return cls.from_fleet(
            config.build_devices(), config.build_queue(), T=config.T,
            n_servers=config.n_servers,
            policy=policy if policy is not None else config.policy,
            horizon=horizon, arrivals=arrivals,
            straggler_threshold=config.straggler_threshold, ema=config.ema,
            lp_method=lp_method,
            faults=getattr(config, "faults", None),
            max_retries=getattr(config, "max_retries", 2),
            fault_seed=getattr(config, "fault_seed", 0),
            mobility=getattr(config, "mobility", None),
            mobility_mode=getattr(config, "mobility_mode", "replay"),
            routing=getattr(config, "routing", "nearest"),
            mobility_seed=getattr(config, "mobility_seed", 0)).with_hi(
                getattr(config, "hi", None),
                rule=getattr(config, "hi_rule", "threshold"),
                stream=getattr(config, "hi_stream", "fold"),
                n_arms=getattr(config, "hi_arms", 9),
                hi_seed=getattr(config, "hi_seed", 0),
                local_model=getattr(config, "hi_local", 0))

    def with_faults(self, faults: Optional[FaultModel], *,
                    max_retries: Optional[int] = None,
                    fault_seed: Optional[int] = None) -> "EngineParams":
        """Arm (or disarm, with ``None``/`FaultModel.none()`) chaos on an
        existing params value, keeping the static ``chaos`` flag
        consistent with the model's nullness."""
        fm = faults if faults is not None else FaultModel.none()
        if self.hi_armed and not fm.is_null():
            raise ValueError(
                "chaos needs HI disarmed (hi_rule='off'): the realized-"
                "execution ladder re-decides admitted samples and would "
                "corrupt the learner's feedback; disarm with "
                "with_hi(None) first")
        return dataclasses.replace(
            self, faults=fm, chaos=not fm.is_null(),
            max_retries=(self.max_retries if max_retries is None
                         else max_retries),
            fault_seed=(self.fault_seed if fault_seed is None
                        else fault_seed))

    def with_mobility(self, mobility: Optional[MobilityModel], *,
                      mode: str = "replay", routing: str = "nearest",
                      mobility_seed: Optional[int] = None,
                      shard_by_cell: bool = False) -> "EngineParams":
        """Arm (or disarm, with ``None``) the multi-cell mobility
        subsystem on an existing params value.  Validates the geometry
        (`core.mobility.validate_mobility`) and keeps the static
        ``mobility_mode``/``n_cells`` aux consistent with the model."""
        mob = mobility if mobility is not None else MobilityModel.none()
        mob_mode = mode if mobility is not None else "off"
        if self.hi_armed and mob_mode != "off":
            raise ValueError(
                "mobility needs HI disarmed (hi_rule='off'): per-cell "
                "admission of confidence-gated offloads is a later rung; "
                "disarm with with_hi(None) first")
        validate_mobility(mob, n_devices=self.n_devices,
                          n_servers=self.n_servers, mode=mob_mode,
                          routing=routing)
        return dataclasses.replace(
            self, mobility=mob, mobility_mode=mob_mode, routing=routing,
            n_cells=mob.n_cells if mob_mode != "off" else 1,
            mobility_seed=(self.mobility_seed if mobility_seed is None
                           else mobility_seed),
            shard_by_cell=shard_by_cell)

    def with_differentiable(self, enabled: bool = True, *,
                            smooth_mode: str = "st",
                            smooth_tau: float = 0.25,
                            admit_tau: float = 0.05,
                            grad_leaves: Optional[Tuple[str, ...]] = None
                            ) -> "EngineParams":
        """Arm (or disarm) the differentiable rollout on an existing
        params value.  Differentiability needs the traced amr2 LP path
        (the implicit VJP lives at the simplex's converged basis) and a
        deterministic accuracy pipeline, so chaos and mobility must be
        disarmed; the sharded entry points reject it (gradients run on
        the single-host trace).  See the class docstring for the
        ``smooth_mode``/``smooth_tau``/``admit_tau`` knobs."""
        if enabled:
            if self.policy != "amr2":
                raise ValueError(
                    f"differentiable rollouts need policy='amr2' (the LP "
                    f"relaxation carries the gradient); got "
                    f"{self.policy!r}")
            if self.chaos:
                raise ValueError(
                    "differentiable rollouts need chaos disarmed: the "
                    "fault ladder's retry/drop counters are discrete and "
                    "the realized-execution pass is not relaxed")
            if self.mobility_mode != "off":
                raise ValueError(
                    "differentiable rollouts need mobility off: routing "
                    "and the per-cell admission are not relaxed yet")
            if self.hi_armed:
                raise ValueError(
                    "differentiable rollouts need HI disarmed "
                    "(hi_rule='off'): the per-sample threshold gate and "
                    "the learner's argmax/draw updates are discrete and "
                    "not relaxed; disarm with with_hi(None) first")
            if smooth_mode not in ("st", "soft"):
                raise ValueError(f"unknown smooth_mode {smooth_mode!r}; "
                                 f"expected 'st' or 'soft'")
            if not (smooth_tau > 0 and admit_tau > 0):
                raise ValueError("smooth_tau and admit_tau must be > 0")
            gl = tuple(grad_leaves) if grad_leaves is not None \
                else self.grad_leaves
            bad = [f for f in gl if f not in GRAD_LEAVES]
            if bad:
                raise ValueError(
                    f"grad_leaves {bad} not differentiable; the "
                    f"continuous EngineParams knobs are {GRAD_LEAVES}")
        else:
            gl = self.grad_leaves
        return dataclasses.replace(
            self, differentiable=enabled, smooth_mode=smooth_mode,
            smooth_tau=smooth_tau, admit_tau=admit_tau, grad_leaves=gl)

    def with_hi(self, hi: Optional[HIModel], *, rule: str = "threshold",
                stream: str = "fold", n_arms: int = 9,
                hi_seed: Optional[int] = None,
                local_model: int = 0) -> "EngineParams":
        """Arm (or disarm, with ``None``) online hierarchical inference
        on an existing params value.  Armed, the per-sample confidence
        gate REPLACES the LP plan: every sample runs the ``local_model``
        on-device and is additionally offloaded iff its calibrated
        confidence falls below the rule's threshold (`core.hi`); the
        learner state rides along as an `EngineState` leaf.  HI composes
        with drift/outage and the ES-pool admission but not (yet) with
        chaos, mobility, or the differentiable relaxation — arming
        raises while any of those is armed, mirroring their own guards."""
        if hi is None:
            return dataclasses.replace(
                self, hi=HIModel.none(), hi_rule="off", hi_stream="fold")
        if self.chaos:
            raise ValueError(
                "HI needs chaos disarmed: the realized-execution ladder "
                "re-decides admitted samples and would corrupt the "
                "learner's feedback; disarm with with_faults(None) first")
        if self.mobility_mode != "off":
            raise ValueError(
                "HI needs mobility off: per-cell admission of confidence-"
                "gated offloads is a later rung; disarm with "
                "with_mobility(None) first")
        if self.differentiable:
            raise ValueError(
                "HI needs the differentiable relaxation disarmed: the "
                "threshold gate and learner updates are discrete; disarm "
                "with with_differentiable(False) first")
        validate_hi(hi, n_devices=self.n_devices,
                    n_classes=self.base_p_ed.shape[1], n_models=self.m,
                    rule=rule, stream=stream, n_arms=n_arms,
                    local_model=local_model, batch_max=self.batch_max)
        return dataclasses.replace(
            self, hi=hi, hi_rule=rule, hi_stream=stream, hi_arms=n_arms,
            hi_seed=self.hi_seed if hi_seed is None else hi_seed,
            hi_local=local_model)


@dataclasses.dataclass(frozen=True)
class EngineState:
    """Everything a period mutates, as one pytree of arrays."""

    period: jnp.ndarray       # ()   int32
    key: jnp.ndarray          # (2,) uint32 PRNG key (poisson arrivals)
    p_ed: jnp.ndarray         # (D, c, m) belief latencies (audit state)
    pending: jnp.ndarray      # (D,) int32 backlog counts
    head: jnp.ndarray         # (D,) int32 replay-stream cursors
    warm_basis: jnp.ndarray   # (D, R) int32 previous optimal bases (-1 cold)
    n_updates: jnp.ndarray    # (D,) int32 straggler-audit update counts
    # multi-cell mobility (inert zeros while mobility_mode == "off")
    pos: jnp.ndarray          # (D, 2) device positions
    cell: jnp.ndarray         # (D,) int32 serving cell (-1: uncovered)
    cell_load: jnp.ndarray    # (S,) last period's admitted load per cell
    # ES-latency belief (chaos audit state; == params.p_es until the
    # realized-execution audit inflates it, handover resets rows)
    p_es_belief: jnp.ndarray  # (D, c)
    # online hierarchical inference: the learner's evolving state
    # (threshold / per-arm statistics / cumulative regret, `core.hi`).
    # Always populated by `init_state`; carried untouched while
    # ``hi_rule == "off"`` so the planned trace is unchanged.
    hi: HILearnerState = None


@dataclasses.dataclass(frozen=True)
class PeriodMetrics:
    """One period's fleet-level numbers (each a scalar; `rollout` stacks
    them into (periods,) arrays).  Field names match `FleetPeriodStats`."""

    period: jnp.ndarray
    n_jobs: jnp.ndarray
    total_accuracy: jnp.ndarray
    mean_job_accuracy: jnp.ndarray
    n_violations: jnp.ndarray
    worst_violation: jnp.ndarray
    n_offloading: jnp.ndarray
    n_backpressured: jnp.ndarray
    n_outage: jnp.ndarray
    n_straggler_updates: jnp.ndarray
    # solves that hit the simplex iteration cap / went unbounded: their
    # assignments are best-effort argmax roundings, not certified optima
    # (the host solve() raised under strict=True; a traced step cannot
    # raise, so the count is surfaced here — and the delegating
    # FleetEngine.run_period re-raises when it is nonzero)
    n_unsolved: jnp.ndarray
    es_utilization: jnp.ndarray
    backlog: jnp.ndarray
    # realized execution (the chaos subsystem, serving.faults): admitted
    # offloaded samples and how each one resolved — the per-period
    # accounting identity ``n_offload_samples == n_offload_ok +
    # n_fallback_local + n_dropped`` holds by construction.  With chaos
    # off, the ladder counters are exact zeros, ``n_offload_ok ==
    # n_offload_samples``, and ``realized_makespan`` equals the priced
    # fleet makespan.
    n_offload_samples: jnp.ndarray
    n_offload_ok: jnp.ndarray
    n_deadline_miss: jnp.ndarray
    n_retries: jnp.ndarray
    n_fallback_local: jnp.ndarray
    n_dropped: jnp.ndarray
    realized_makespan: jnp.ndarray
    # chaos -> planner feedback: devices whose REALIZED ES time blew past
    # the priced demand (or missed the 2T deadline) and had their
    # `p_es_belief` EMA-inflated this period.  Exact zero with chaos off.
    n_es_audit_updates: jnp.ndarray
    # mobility: devices that switched serving cells this period (handover
    # count; exact zero while mobility is off or S=1)
    n_handover: jnp.ndarray
    # online hierarchical inference (`core.hi`): samples that actually
    # consulted the ES (admitted offloads) vs samples served by the local
    # model alone — every sample runs the local model, so the accounting
    # identity ``n_hi_offloaded + n_hi_local_final == n_jobs`` holds per
    # period by construction (admission-bumped intended offloads land in
    # the local count) — plus the fleet's cumulative pseudo-regret vs the
    # clairvoyant threshold.  Exact zeros while HI is off.
    n_hi_offloaded: jnp.ndarray
    n_hi_local_final: jnp.ndarray
    hi_regret: jnp.ndarray


_STATE_FIELDS = ("period", "key", "p_ed", "pending", "head", "warm_basis",
                 "n_updates", "pos", "cell", "cell_load", "p_es_belief",
                 "hi")
_METRIC_FIELDS = tuple(f.name for f in dataclasses.fields(PeriodMetrics))
_PARAM_LEAVES = ("classes", "base_p_ed", "p_es", "acc", "T", "rate",
                 "class_probs", "drift", "outage", "counts", "stream",
                 "faults", "mobility", "hi")
_PARAM_AUX = ("policy", "arrivals", "n_servers", "batch_max",
              "straggler_threshold", "ema", "frac_tol", "iters", "maxiter",
              "tol", "lp_method", "chaos", "max_retries", "fault_seed",
              "mobility_mode", "routing", "n_cells", "mobility_seed",
              "shard_by_cell", "hi_rule", "hi_stream", "hi_arms",
              "hi_seed", "hi_local", "differentiable", "smooth_mode",
              "smooth_tau", "admit_tau", "grad_leaves")

# EngineParams leaves `rollout_grad` may differentiate: the continuous
# fleet knobs.  Integer/bool leaves (counts, stream, outage, classes) and
# the replayed schedules are bookkeeping — `partition_diff` fences them.
GRAD_LEAVES = ("p_es", "base_p_ed", "acc", "T")

_register(EngineParams, _PARAM_LEAVES, _PARAM_AUX)
_register(EngineState, _STATE_FIELDS)
_register(PeriodMetrics, _METRIC_FIELDS)


def init_state(params: EngineParams, *, seed: int = 0) -> EngineState:
    """A fresh fleet: beliefs = profiles, empty backlog, cold bases."""
    D = params.n_devices
    S = max(params.n_cells, 1)
    armed = params.mobility_mode != "off"
    return EngineState(
        period=np.zeros((), np.int32),
        key=np.asarray(jax.random.PRNGKey(seed)),
        p_ed=np.array(params.base_p_ed, np.float64),
        pending=np.zeros(D, np.int32),
        head=np.zeros(D, np.int32),
        warm_basis=np.full((D, params.n_basis_rows), -1, np.int32),
        n_updates=np.zeros(D, np.int32),
        pos=(np.array(params.mobility.trace[0], np.float64) if armed
             else np.zeros((D, 2), np.float64)),
        cell=np.full(D, -1 if armed else 0, np.int32),
        cell_load=np.zeros(S, np.float64),
        p_es_belief=np.array(params.p_es, np.float64),
        hi=HILearnerState.init(D, params.hi_arms, params.hi.theta0))


# --------------------------------------------------------------------------
# traced building blocks
# --------------------------------------------------------------------------
def admit_mask_jnp(demands, T, n_servers: int):
    """Traced `EdgeServerPool.admit`: ascending-demand (device id on
    ties), least-loaded-server-first first-fit as a `lax.scan` over the
    sorted device order.  ``demands`` (D,) with <= 0 marking
    non-offloaders.  Returns ``(admitted (D,) bool, loads (n_servers,))``
    — identical decisions to the host `admit`/`admit_mask`."""
    D = demands.shape[0]
    eff = jnp.where(demands > 0, demands, jnp.inf)
    order = jnp.argsort(eff, stable=True)

    def body(carry, d):
        loads, mask = carry
        need = demands[d]
        slot = jnp.argmin(loads)
        ok = (need > 0) & (loads[slot] + need <= T + 1e-12)
        loads = loads.at[slot].add(jnp.where(ok, need, 0.0))
        mask = mask.at[d].set(ok)
        return (loads, mask), None

    (loads, mask), _ = jax.lax.scan(
        body, (jnp.zeros(n_servers, demands.dtype),
               jnp.zeros(D, dtype=bool)), order)
    return mask, loads


# Lane-chunk width for the per-period plan: fleets larger than this are
# planned as `lax.map` over chunks of lanes so the whole build -> factor ->
# pivot -> round pipeline stays cache-resident per chunk.  Every lane's
# arithmetic is independent, so chunking is BIT-IDENTICAL to the flat plan
# (pinned by the rollout parity gates) — it only changes memory traffic: a
# flat 16k+-lane pivot loop streams the full (D, R, C0) working set from
# DRAM every iteration and runs ~2.5x slower per lane than the 256-lane
# point.  0 disables; fleets not divisible by the chunk run flat.
_PLAN_LANE_CHUNK = int(os.environ.get("REPRO_PLAN_LANE_CHUNK", "1024"))


def _plan(params: EngineParams, fp: FleetProblem, warm_basis,
          lane_mask=None):
    """Chunked wrapper over `_plan_flat` (see `_PLAN_LANE_CHUNK`)."""
    D = fp.p_es.shape[0]
    chunk = _PLAN_LANE_CHUNK
    if not chunk or D <= chunk or D % chunk:
        return _plan_flat(params, fp, warm_basis, lane_mask)
    nc = D // chunk

    def resh(x):
        return x.reshape((nc, chunk) + x.shape[1:])

    xs = (jax.tree.map(resh, fp),
          None if warm_basis is None else resh(warm_basis),
          None if lane_mask is None else resh(lane_mask))
    out = jax.lax.map(
        lambda a: _plan_flat(params, a[0], a[1], a[2]), xs)
    return jax.tree.map(lambda x: x.reshape((D,) + x.shape[2:]), out)


def _plan_flat(params: EngineParams, fp: FleetProblem, warm_basis,
               lane_mask=None):
    """One traced batched solve of a (padded) `FleetProblem`.

    amr2: warm-or-cold batched simplex + vectorized rounding — per-lane
    bit-comparable with the host `solve(..., policy="amr2")` dispatch.
    dual: the vmapped bisection (`core.dual._dual_one`).  Returns
    ``(assignment (D, n) int32, status (D,) int32, basis (D, R) int32)``
    — plus the LP relaxation ``xbar (D, n, m+1)`` as a fourth element
    when the ``differentiable`` aux is armed (amr2 only): the smoothed
    accuracy blend needs the fractional solution, and the solve routes
    through `lp.simplex_batch_grad` so cotangents reach ``A/b/c`` via
    the implicit KKT solve instead of dying at the pivot while_loop.
    """
    D, n = fp.p_es.shape
    m = fp.p_ed.shape[2]
    if params.policy == "amr2":
        A, b, c_full = build_lp_arrays_jnp(fp.p_ed, fp.p_es, fp.acc, fp.T)
        maxiter = params.maxiter if params.maxiter is not None else \
            _bucket_maxiter(50 * (A.shape[1] + 2))
        solve = simplex_batch_grad if params.differentiable \
            else simplex_batch_core
        x, _fun, st, _ni, basis, _ok = solve(
            A, b, c_full, warm_basis, nv=n * (m + 1), maxiter=maxiter,
            tol=params.tol, lane_mask=lane_mask,
            method=params.lp_method)
        xbar = x.reshape(D, n, m + 1)
        assign, sched_status, _nf = round_relaxation_jnp(
            fp.p_ed, fp.p_es, fp.acc, fp.T, xbar, st,
            frac_tol=params.frac_tol)
        out = (assign.astype(jnp.int32), sched_status.astype(jnp.int32),
               basis.astype(jnp.int32))
        return out + (xbar,) if params.differentiable else out
    # dual: no basis to carry; status 0 = ok / 1 = fallback (the shared
    # SOLUTION_STATUS_NAMES codes)
    assign, st = jax.vmap(partial(_dual_one, iters=params.iters))(
        fp.p_ed, fp.p_es, fp.acc, fp.T)
    basis = (jnp.asarray(warm_basis, jnp.int32) if warm_basis is not None
             else jnp.full((D, params.n_basis_rows), -1, jnp.int32))
    return assign.astype(jnp.int32), st.astype(jnp.int32), basis


def _recover_unsolved(assign, unsolved, p_ed_jobs, mask, acc, T):
    """Greedy local-only recovery for ``unsolved`` lanes: a lane whose
    simplex hit the iteration cap (or went unbounded) used to ship a
    best-effort argmax rounding that could oversubscribe the ES pool and
    poison the whole period's admission; instead, re-assign its samples
    with the same greedy masked-argmax fill the degradation ladder uses
    (largest local model fitting the residual budget, job order), and
    give no-fit samples the fastest local model (the infeasible-rounding
    convention).  Solved lanes pass through untouched (`jnp.where`), so
    unsolved-free periods are bitwise-unchanged.  The lane still counts
    in ``n_unsolved`` — recovery is damage control, not certification."""
    D, _n, m = p_ed_jobs.shape
    eligible = unsolved[:, None] & mask
    choice, fit, _ = greedy_local_fill(
        p_ed_jobs, acc[:, :m], jnp.broadcast_to(T, (D,)), eligible)
    cheapest = jnp.argmin(p_ed_jobs, axis=2).astype(jnp.int32)
    local = jnp.where(fit, choice, cheapest)
    return jnp.where(eligible, local, assign).astype(jnp.int32)


def _period_impl(belief_p_ed, warm_basis, ci, take, drift_t, outage_t,
                 params: EngineParams, axis_name: Optional[str] = None,
                 fault_key=None, es_belief=None, link_factor=None,
                 covered=None, cell=None, hi_key=None, hi_state=None,
                 hi_t=None):
    """The pure period core shared by `step`, the sharded step, and the
    host `FleetEngine.run_period` delegation: everything AFTER arrivals
    (the released job-class indices ``ci`` (D, n) + counts ``take`` (D,))
    and BEFORE state/stats bookkeeping.

    Under ``axis_name`` (inside `shard_map`) the ES-pool admission runs on
    the `all_gather`-ed global demand vector and every metric scalar is
    `psum`/`pmax`-reduced, so sharded and unsharded outputs agree.

    Mobility plumbing (all optional, None = single-pool semantics):
    ``es_belief`` (D, c) replaces `params.p_es` as the PRICED ES-latency
    table (the chaos audit inflates it; realized execution always prices
    from the true `params.p_es`); ``link_factor`` (D,) scales each
    device's ES latencies by its link to the serving cell; ``covered``
    (D,) False disables a device's ES column like an outage; ``cell``
    (D,) int32 routes admission through the segmented per-cell scan when
    the static ``n_cells`` aux is > 1.

    HI plumbing (consulted only when the static ``hi_rule`` aux is not
    "off"): ``hi_key`` is the period's confidence/arm key
    (`fold_in(PRNGKey(hi_seed), period)` — independent of the arrival
    PRNG), ``hi_state`` the incoming `HILearnerState`, ``hi_t`` the
    period index (step-size decay + replay-trace cursor).

    Returns ``(new_belief_p_ed, new_warm_basis, upd (D,) bool,
    audit_factor (D,), new_es_belief (D, c), cell_load (S,),
    new_hi_state, metrics)``
    with ``metrics`` a dict of scalars (no period/backlog — the callers
    own those).  ``audit_factor`` is the EMA rescale each updated
    device's belief was multiplied by — the host `FleetEngine` delegation
    applies it to its profile-space tables (which may cover more classes
    than the queue's).
    """
    D, _c, m = belief_p_ed.shape
    n = params.batch_max
    mask = jnp.arange(n)[None, :] < take[:, None]
    rows = jnp.arange(D)[:, None]
    ci = jnp.clip(ci, 0, params.p_es.shape[1] - 1)
    p_ed_jobs = jnp.where(mask[..., None], belief_p_ed[rows, ci], 0.0)
    base_jobs = jnp.where(mask[..., None], params.base_p_ed[rows, ci], 0.0)
    if covered is not None:
        # out-of-coverage == ES link down for this period
        outage_t = outage_t | ~covered

    def _es_jobs(tbl):
        e = jnp.where(mask, tbl[rows, ci], 0.0)
        if link_factor is not None:
            e = e * link_factor[:, None]
        return jnp.where(outage_t[:, None] & mask, ES_DISABLED_SENTINEL, e)

    es_tbl = params.p_es if es_belief is None else es_belief
    p_es_jobs = _es_jobs(es_tbl)
    Tvec = jnp.broadcast_to(params.T, (D,))
    fp = FleetProblem.from_arrays_unchecked(p_ed_jobs, p_es_jobs,
                                            params.acc, Tvec, mask)

    # ---- plan the whole (local) fleet in one traced solve ---------------
    diff = params.differentiable and params.policy == "amr2"
    hi_armed = params.hi_armed
    if hi_armed:
        # ---- online hierarchical inference: the confidence gate IS the
        # plan (core.hi).  Every sample runs ``hi_local`` on-device; the
        # gate additionally offloads the low-confidence ones.  The LP
        # never runs — there is no accuracy table to plan from in the
        # online problem — so basis/unsolved are inert passthroughs.
        lm = params.hi_local
        acc_es_col = params.acc[:, m]
        kc, ka = jax.random.split(hi_key)
        uni = (jnp.take(params.hi.conf_trace,
                        hi_t % params.hi.conf_trace.shape[0], axis=0)
               if params.hi_stream == "replay" else None)
        conf, correct_local, correct_es = sample_confidence(
            kc, params.hi, params.acc[:, lm], acc_es_col, ci,
            uniforms=uni, axis_name=axis_name)
        offload_int, _theta_t, new_hi, _reg = hi_period(
            params.hi_rule, params.hi, hi_state, conf, correct_local,
            correct_es, mask, acc_es_col, hi_t, ka, params.hi_arms,
            axis_name=axis_name)
        assign = jnp.where(offload_int, jnp.int32(m),
                           jnp.int32(lm)).astype(jnp.int32)
        basis = (jnp.asarray(warm_basis, jnp.int32)
                 if warm_basis is not None
                 else jnp.full((D, params.n_basis_rows), -1, jnp.int32))
        n_unsolved = jnp.zeros(D, jnp.int32)
        # an outage period needs no special-casing: the ES column prices
        # at the disabled sentinel, so intended offloads carry infeasible
        # demand, lose admission, and fall back local below
    else:
        new_hi = hi_state
        plan_out = _plan(params, fp, warm_basis)
        assign, status, basis = plan_out[:3]
        xbar = plan_out[3] if diff else None
        unsolved_lane = status == _ST_UNSOLVED
        n_unsolved = unsolved_lane.astype(jnp.int32)
        # per-lane recovery: unsolved lanes fall back to a greedy
        # local-only plan (no ES demand) instead of racing uncertified
        # roundings into the admission scan
        assign = _recover_unsolved(assign, unsolved_lane, p_ed_jobs, mask,
                                   params.acc, params.T)

    # ---- ES-pool admission on the GLOBAL demand vector ------------------
    # S=1 runs the one-cell fast path of the segmented admission
    # (`core.mobility.admit_mask_pool` — bitwise-pinned to the retired
    # sequential `admit_mask_jnp` scan, ceil(D/k) scan steps instead of
    # D); multi-cell fleets run the segmented per-cell formulation — pure
    # sort/cumsum work, no O(D) sequential pass (core.mobility).  Under
    # `shard_by_cell` the all_gather is elided outright: each shard admits
    # its own cells locally and only the per-cell loads are psum-merged.
    demand = jnp.where(mask & (assign == m), p_es_jobs, 0.0).sum(axis=1)
    use_cells = params.mobility_mode != "off" and params.n_cells > 1
    inc = None          # inclusive chain loads (the admission relaxation)
    if axis_name is None:
        if use_cells:
            admitted, cloads = admit_mask_segmented(
                demand, cell, params.T, params.n_cells,
                params.servers_per_cell)
        else:
            admitted, loads, inc = admit_mask_pool(demand, params.T,
                                                   params.n_servers)
    elif use_cells and params.shard_by_cell:
        admitted, cloads = admit_mask_segmented(
            demand, cell, params.T, params.n_cells,
            params.servers_per_cell)
        cloads = jax.lax.psum(cloads, axis_name)
    elif use_cells:
        demand_g = jax.lax.all_gather(demand, axis_name, tiled=True)
        cell_g = jax.lax.all_gather(cell, axis_name, tiled=True)
        admitted_g, cloads = admit_mask_segmented(
            demand_g, cell_g, params.T, params.n_cells,
            params.servers_per_cell)
        idx = jax.lax.axis_index(axis_name)
        admitted = jax.lax.dynamic_slice_in_dim(admitted_g, idx * D, D)
    else:
        demand_g = jax.lax.all_gather(demand, axis_name, tiled=True)
        admitted_g, loads, _inc_g = admit_mask_pool(demand_g, params.T,
                                                    params.n_servers)
        idx = jax.lax.axis_index(axis_name)
        admitted = jax.lax.dynamic_slice_in_dim(admitted_g, idx * D, D)
    if use_cells:
        cell_load_out = cloads.sum(axis=1)              # (S,) global
        loads_total = jnp.sum(cloads)
    else:
        cell_load_out = jnp.sum(loads)[None]            # (1,)
        loads_total = jnp.sum(loads)
    offl = demand > 0
    bumped = offl & ~admitted

    # ---- backpressure: lane-masked ES-disabled replan -------------------
    # Skipped entirely (lax.cond) on no-bump periods; otherwise known-cold
    # (warm_basis=None skips the basis factorization) and non-bumped lanes
    # get a zeroed tableau (amr2) — zero pivots — so the second solve only
    # pays for the devices that actually lost the race.  The predicate is
    # a per-shard scalar, so sharded and unsharded runs agree: a shard
    # with no bumped devices skips a solve whose result its jnp.where
    # would have discarded anyway.
    def _bp_problem():
        p_es_crippled = jnp.where(mask, ES_DISABLED_SENTINEL, 0.0)
        return FleetProblem.from_arrays_unchecked(
            p_ed_jobs, p_es_crippled, params.acc, Tvec, mask)

    if hi_armed:
        # backpressure under HI needs no second LP: a bumped device's
        # intended offloads simply stay on the local model (the sample
        # already ran it — hierarchical inference's graceful fallback)
        assign = jnp.where(bumped[:, None] & mask, jnp.int32(params.hi_local),
                           assign)
    elif diff and axis_name is None:
        # Differentiable mode: the smoothed admission gives EVERY
        # offloader partial weight on its ES-disabled alternative, so the
        # replan runs unconditionally (lane_mask widened from `bumped` to
        # `offl`) — the hard assignment merge below still only reads the
        # bumped lanes, so the hard forward numbers are unchanged.
        bp4 = _plan(params, _bp_problem(), None, lane_mask=offl)
        assign_bp, st_bp, _bas_bp, xbar_bp = bp4
        unsolved_bp = bumped & (st_bp == _ST_UNSOLVED)
        assign_bp = _recover_unsolved(assign_bp, unsolved_bp, p_ed_jobs,
                                      mask, params.acc, params.T)
        assign_pre = assign                     # primary plan, post-recovery
        assign = jnp.where(bumped[:, None], assign_bp, assign)
        n_unsolved = n_unsolved + unsolved_bp.astype(jnp.int32)
    else:
        def _replan(assign):
            assign_bp, st_bp = _plan(
                params, _bp_problem(), None,
                lane_mask=bumped if params.policy == "amr2" else None)[:2]
            unsolved_bp_lane = bumped & (st_bp == _ST_UNSOLVED)
            assign_bp = _recover_unsolved(assign_bp, unsolved_bp_lane,
                                          p_ed_jobs, mask, params.acc,
                                          params.T)
            return (jnp.where(bumped[:, None], assign_bp, assign),
                    unsolved_bp_lane.astype(jnp.int32))

        assign, unsolved_bp = jax.lax.cond(
            bumped.any(), _replan,
            lambda a: (a, jnp.zeros_like(n_unsolved)), assign)
        n_unsolved = n_unsolved + unsolved_bp

    # ---- pricing, violations, straggler audit ---------------------------
    def _sum(x):
        s = jnp.sum(x)
        return jax.lax.psum(s, axis_name) if axis_name else s

    def _max(x):
        v = jnp.max(x, initial=0.0)
        return jax.lax.pmax(v, axis_name) if axis_name else v

    acc_jobs = params.acc[rows, assign]
    n_jobs = _sum(mask.astype(jnp.int32))

    if hi_armed:
        # hierarchical: EVERY masked sample runs the local model (the
        # offloaded ones too), so the ED load prices the full batch at
        # ``hi_local`` regardless of the final assignment
        ed_pred = p_ed_jobs[..., params.hi_local].sum(axis=1)
        ed_wall = base_jobs[..., params.hi_local].sum(axis=1) * drift_t
    else:
        on_ed = mask & (assign < m)
        picked = jnp.clip(assign, 0, m - 1)[..., None]
        ed_pred = jnp.where(
            on_ed, jnp.take_along_axis(p_ed_jobs, picked, axis=2)[..., 0],
            0.0).sum(axis=1)
        ed_wall = jnp.where(
            on_ed, jnp.take_along_axis(base_jobs, picked, axis=2)[..., 0],
            0.0).sum(axis=1) * drift_t
    es_wall = jnp.where(admitted, demand, 0.0)
    es_samp = mask & (assign == m)       # admitted offloads (post-replan)

    # ---- realized execution (chaos): inject faults, walk the ladder -----
    # `params.chaos` is static aux, so the fault-free trace below is the
    # byte-identical pre-chaos graph; armed with a zero-rate FaultModel,
    # every factor is exactly 1.0 / every mask empty, and the realized
    # quantities reproduce the priced ones bit for bit.
    if params.chaos:
        real = sample_realization(fault_key, params.faults, D, n,
                                  params.max_retries + 1,
                                  axis_name=axis_name)
        lat_local = base_jobs * (drift_t * real.straggler_factor
                                 )[:, None, None]
        # realized execution prices from the TRUE ES table — the audit's
        # inflated belief steers planning/admission, not physics
        true_es_jobs = p_es_jobs if es_belief is None \
            else _es_jobs(params.p_es)
        rx = realize_execution(
            params.faults, real, mask=mask, es_samp=es_samp,
            acc_jobs=acc_jobs, p_es_jobs=true_es_jobs, ed_wall=ed_wall,
            lat_local=lat_local, acc=params.acc, T=params.T,
            max_retries=params.max_retries)
        total_acc = _sum(jnp.where(mask, rx.acc, 0.0))
        wall = rx.wall
        ed_audit = rx.ed_audit       # excl. fallback compute: the audit
        #                              tracks per-op slowdown, not load
        # chaos -> planner feedback: a device whose realized ES time blew
        # past its priced demand (or whose offloads got dropped) has its
        # ES-latency belief EMA-inflated, so next period's plan offloads
        # less / demands more conservatively.  Null faults realize the
        # priced times bit for bit -> ratio == 1 -> no updates.
        es_ratio = rx.es_wall / jnp.maximum(es_wall, 1e-9)
        es_upd = (es_wall > 0) & ((es_ratio > params.straggler_threshold)
                                  | (rx.n_dropped > 0))
        es_factor = (1.0 - params.ema) + params.ema * jnp.maximum(
            es_ratio, params.straggler_threshold)
        new_es_belief = jnp.where(es_upd[:, None],
                                  es_tbl * es_factor[:, None], es_tbl)
        ladder = {
            "n_offload_samples": _sum(rx.n_offload),
            "n_offload_ok": _sum(rx.n_offload_ok),
            "n_deadline_miss": _sum(rx.n_deadline_miss),
            "n_retries": _sum(rx.n_retries),
            "n_fallback_local": _sum(rx.n_fallback_local),
            "n_dropped": _sum(rx.n_dropped),
            "n_es_audit_updates": _sum(es_upd.astype(jnp.int32)),
        }
    else:
        if diff and axis_name is None:
            # ---- smoothed accuracy: the differentiable twin -------------
            # Two discrete stages get relaxed: Algorithm-2 rounding
            # (temperature-softened assignment weights over the LP
            # relaxation) and first-fit admission (a sigmoid capacity
            # test on each offloader's inclusive chain load `inc` — the
            # EXACT value the hard first-fit compared against T).  Per
            # device: accP from the primary plan, accBP from the
            # ES-disabled replan, blended by the admission weight; the
            # "st" mode forwards the HARD decisions (one-hot weights,
            # boolean admission) and routes gradients through the soft
            # ones, so served numbers match the hard path while the
            # cotangents stay alive.
            if params.smooth_mode == "st":
                wP = straight_through_weights(xbar, assign_pre,
                                              tau=params.smooth_tau)
                wBP = straight_through_weights(xbar_bp, assign_bp,
                                               tau=params.smooth_tau)
            else:
                wP = soft_assignment_weights(xbar, tau=params.smooth_tau)
                wBP = soft_assignment_weights(xbar_bp,
                                              tau=params.smooth_tau)
            accP = jnp.where(mask, jnp.einsum("dsi,di->ds", wP,
                                              params.acc), 0.0).sum(axis=1)
            accBP = jnp.where(mask, jnp.einsum("dsi,di->ds", wBP,
                                               params.acc), 0.0).sum(axis=1)
            adm_soft = jax.nn.sigmoid(
                (params.T + 1e-12 - inc) / (params.admit_tau * params.T))
            if params.smooth_mode == "st":
                adm_use = adm_soft + jax.lax.stop_gradient(
                    admitted.astype(adm_soft.dtype) - adm_soft)
            else:
                adm_use = adm_soft
            dev_acc = jnp.where(offl, adm_use * accP
                                + (1.0 - adm_use) * accBP, accP)
            total_acc = jnp.sum(dev_acc)
        elif hi_armed:
            # expected served accuracy under perfect calibration: an
            # admitted offload scores the ES accuracy, a locally-served
            # sample its own confidence (E[correct | conf] == conf)
            total_acc = _sum(jnp.where(
                mask, jnp.where(es_samp, acc_es_col[:, None], conf), 0.0))
        else:
            total_acc = _sum(jnp.where(mask, acc_jobs, 0.0))
        wall = jnp.maximum(ed_wall, es_wall)
        ed_audit = ed_wall
        new_es_belief = es_tbl
        n_off = _sum(es_samp.astype(jnp.int32))
        zero = jnp.zeros((), jnp.int32)
        ladder = {
            "n_offload_samples": n_off, "n_offload_ok": n_off,
            "n_deadline_miss": zero, "n_retries": zero,
            "n_fallback_local": zero, "n_dropped": zero,
            "n_es_audit_updates": zero,
        }
    viol = jnp.maximum(0.0, wall / params.T - 1.0)

    ratio = ed_audit / jnp.maximum(ed_pred, 1e-9)
    upd = (ed_pred > 0) & (ratio > params.straggler_threshold)
    factor = (1.0 - params.ema) + params.ema * ratio
    new_belief = jnp.where(upd[:, None, None],
                           belief_p_ed * factor[:, None, None],
                           belief_p_ed)
    new_warm = basis if params.policy == "amr2" else warm_basis

    metrics = {
        "n_jobs": n_jobs,
        "total_accuracy": total_acc,
        "n_violations": _sum((viol > 0).astype(jnp.int32)),
        "worst_violation": _max(viol),
        "n_offloading": _sum(offl.astype(jnp.int32)),
        "n_backpressured": _sum(bumped.astype(jnp.int32)),
        "n_outage": _sum(outage_t.astype(jnp.int32)),
        "n_straggler_updates": _sum(upd.astype(jnp.int32)),
        "n_unsolved": _sum(n_unsolved),
        "es_utilization": loads_total / (params.n_servers * params.T),
        "realized_makespan": _max(wall),
        **ladder,
    }
    if hi_armed:
        metrics.update(
            n_hi_offloaded=_sum(es_samp.astype(jnp.int32)),
            n_hi_local_final=_sum((mask & (assign != m)
                                   ).astype(jnp.int32)),
            hi_regret=_sum(new_hi.cum_regret))
    else:
        metrics.update(n_hi_offloaded=jnp.zeros((), jnp.int32),
                       n_hi_local_final=jnp.zeros((), jnp.int32),
                       hi_regret=jnp.zeros((), jnp.float64))
    return (new_belief, new_warm.astype(jnp.int32), upd, factor,
            new_es_belief, cell_load_out, new_hi, metrics)


def _arrivals(state: EngineState, params: EngineParams,
              axis_name: Optional[str] = None):
    """Release this period's jobs: ``(ci (D, n) int32 class indices,
    take (D,) int32, pending' , head', key')``."""
    D = state.pending.shape[0]
    n = params.batch_max
    t = state.period
    if params.arrivals == "replay":
        counts_t = jnp.take(params.counts, t % params.counts.shape[0],
                            axis=0).astype(jnp.int32)
        key = state.key
    else:
        k_counts, k_classes, key = jax.random.split(state.key, 3)
        offset = (jax.lax.axis_index(axis_name) * D
                  if axis_name else jnp.int32(0))
        gid = offset + jnp.arange(D, dtype=jnp.int32)
        # per-device folded keys: sharded and unsharded sampling agree
        kd = jax.vmap(lambda g: jax.random.fold_in(k_counts, g))(gid)
        counts_t = jax.vmap(
            lambda k, lam: jax.random.poisson(k, lam))(
                kd, params.rate).astype(jnp.int32)
        kc = jax.vmap(lambda g: jax.random.fold_in(k_classes, g))(gid)
    avail = state.pending + counts_t
    take = jnp.minimum(avail, n).astype(jnp.int32)
    if params.arrivals == "replay":
        S = params.stream.shape[1]
        idx = state.head[:, None] + jnp.arange(n, dtype=jnp.int32)[None, :]
        ci = jnp.take_along_axis(params.stream,
                                 jnp.clip(idx, 0, S - 1), axis=1)
        head = (state.head + take).astype(jnp.int32)
    else:
        c = params.class_probs.shape[0]
        ci = jax.vmap(lambda k: jax.random.choice(
            k, c, shape=(n,), p=params.class_probs))(kc)
        head = state.head
    return (ci.astype(jnp.int32), take,
            (avail - take).astype(jnp.int32), head, key)


def _step_impl(state: EngineState, params: EngineParams,
               axis_name: Optional[str] = None
               ) -> Tuple[EngineState, PeriodMetrics]:
    """One pure period: arrivals + `_period_impl` + state/metric assembly."""
    t = state.period
    D = state.pending.shape[0]
    H = params.drift.shape[1]
    drift_t = jnp.take(params.drift, t % H, axis=1)
    outage_t = jnp.take(params.outage, t % H, axis=1)
    # A basis optimal for last period's LP is meaningless when the ES
    # column set changed underneath it (outage flipping on/off swaps the
    # offload columns for the disabled sentinel): mask those lanes back to
    # -1 so `_warm_init` cold-starts them instead of factoring a basis of
    # the wrong problem.
    outage_prev = jnp.take(params.outage, (t - 1) % H, axis=1)
    stale = (t > 0) & (outage_prev != outage_t)
    # ---- mobility: move, route, detect handover -------------------------
    if params.mobility_mode != "off":
        mob = params.mobility
        if params.mobility_mode == "replay":
            pos_t = jnp.take(mob.trace, t % mob.trace.shape[0], axis=0)
        else:                                               # random walk
            # folded replayed stream (the fault_seed idiom): per-device
            # GLOBAL-id folds, so sharded and unsharded walks agree and
            # arming mobility never perturbs the arrival PRNG
            kw = jax.random.fold_in(
                jax.random.PRNGKey(params.mobility_seed), t)
            offset = (jax.lax.axis_index(axis_name) * D
                      if axis_name else jnp.int32(0))
            gid = offset + jnp.arange(D, dtype=jnp.int32)
            kd = jax.vmap(lambda g: jax.random.fold_in(kw, g))(gid)
            steps = jax.vmap(
                lambda k: jax.random.normal(k, (2,), jnp.float64))(kd)
            pos_t = state.pos + mob.walk_sigma * steps
        load_frac = state.cell_load / (params.servers_per_cell * params.T)
        cell_t, covered, link_factor = route_cells(
            pos_t, mob, load_frac, params.routing)
        # handover: the previous cell's basis labels an LP whose ES
        # column was priced for a different link — cold-start it, and
        # migrate the ES belief back to the new cell's nominal table
        switched = (t > 0) & (cell_t != state.cell)
        stale = stale | switched
        es_belief0 = jnp.where(switched[:, None], params.p_es,
                               state.p_es_belief)
        n_handover = jnp.sum(switched.astype(jnp.int32))
    else:
        pos_t, cell_t = state.pos, state.cell
        covered = link_factor = None
        es_belief0 = state.p_es_belief
        n_handover = jnp.zeros((), jnp.int32)
    warm0 = jnp.where(stale[:, None], jnp.int32(-1), state.warm_basis)
    ci, take, pending, head, key = _arrivals(state, params, axis_name)
    # the fault stream is replayed — folded from a dedicated seed, never
    # drawn from state.key — so arming chaos leaves the arrival (and
    # fault-free metric) trajectory bitwise-untouched, and the host
    # delegation can reproduce the exact same draw per period
    fkey = (jax.random.fold_in(jax.random.PRNGKey(params.fault_seed), t)
            if params.chaos else None)
    # the confidence stream is replayed the same way — folded from its
    # own seed — so arming HI never perturbs arrivals either
    hikey = (jax.random.fold_in(jax.random.PRNGKey(params.hi_seed), t)
             if params.hi_armed else None)
    (new_belief, new_warm, upd, _factor, new_es_belief, cell_load,
     new_hi, m) = _period_impl(
        state.p_ed, warm0, ci, take, drift_t, outage_t, params,
        axis_name=axis_name, fault_key=fkey, es_belief=es_belief0,
        link_factor=link_factor, covered=covered, cell=cell_t,
        hi_key=hikey, hi_state=state.hi, hi_t=t)
    backlog = jnp.sum(pending)
    if axis_name:
        backlog = jax.lax.psum(backlog, axis_name)
        n_handover = jax.lax.psum(n_handover, axis_name)
    n_jobs = m["n_jobs"]
    metrics = PeriodMetrics(
        period=t,
        mean_job_accuracy=jnp.where(
            n_jobs > 0, m["total_accuracy"] / jnp.maximum(n_jobs, 1), 0.0),
        backlog=backlog.astype(jnp.int32),
        n_handover=n_handover.astype(jnp.int32), **m)
    new_state = EngineState(
        period=(t + 1).astype(jnp.int32), key=key, p_ed=new_belief,
        pending=pending, head=head, warm_basis=new_warm,
        n_updates=(state.n_updates + upd.astype(jnp.int32)),
        pos=pos_t, cell=cell_t.astype(jnp.int32), cell_load=cell_load,
        p_es_belief=new_es_belief, hi=new_hi)
    return new_state, metrics


@jax.jit
def _step_jit(state, params):
    return _step_impl(state, params)


@jax.jit
def _period_jit(belief, warm_basis, ci, take, drift_t, outage_t, params,
                fault_key=None, es_belief=None, hi_key=None,
                hi_state=None, hi_t=None):
    """The host `FleetEngine.run_period` delegation target: the same
    period core `step` scans over, minus the arrival/state bookkeeping
    (the host engine owns its queue and stats).  ``fault_key`` replays
    one period of the fault stream (`fold_in(PRNGKey(fault_seed),
    period)` — the exact draw `step` makes), or None when chaos is
    disarmed.  ``es_belief`` threads the chaos-audited ES price table
    between host periods (None prices from the nominal `params.p_es`).
    ``hi_key``/``hi_state``/``hi_t`` replay one period of the HI stream
    and thread the learner state the same way (None while disarmed)."""
    return _period_impl(belief, warm_basis, ci, take, drift_t, outage_t,
                        params, fault_key=fault_key, es_belief=es_belief,
                        hi_key=hi_key, hi_state=hi_state, hi_t=hi_t)


def _rollout_impl(state, params, periods: int):
    def body(s, _):
        return _step_impl(s, params)
    return jax.lax.scan(body, state, None, length=periods)


_rollout_jit = partial(jax.jit, static_argnames=("periods",))(_rollout_impl)
# the donated variant consumes the input EngineState's buffers in place —
# at 100k devices the (D, R, R)-adjacent state leaves are the allocation
# high-water mark, and a rollout that donates them runs at half the peak
# memory of one that keeps the input alive
_rollout_donate = partial(jax.jit, static_argnames=("periods",),
                          donate_argnums=(0,))(_rollout_impl)


def _require_f64(tag: str, tree) -> None:
    """Reject float32 leaves loudly instead of computing with them.

    The engine is float64 end-to-end (the LP parity contract): every entry
    point wraps its jit in `enable_x64`, but that scope cannot UPCAST
    arrays that were already materialized as float32 — e.g. a state
    `device_put` outside any x64 scope while jax's global x64 mode is off.
    Silently running the rollout at single precision breaks the host
    bit-parity guarantees, so fail with the leaf's path instead."""
    for f in dataclasses.fields(tree):
        leaf = getattr(tree, f.name)
        if dataclasses.is_dataclass(leaf) and not isinstance(leaf, type):
            _require_f64(f"{tag}.{f.name}", leaf)   # e.g. params.faults
            continue
        dt = getattr(leaf, "dtype", None)
        if (dt is not None and jnp.issubdtype(dt, jnp.floating)
                and dt != jnp.float64):
            raise TypeError(
                f"{tag}.{f.name} is {dt} but the "
                f"engine is float64-only; build arrays as float64 and do "
                f"device transfers inside jax.experimental.enable_x64() "
                f"(with jax's global x64 mode off, an unscoped "
                f"device_put downcasts to float32)")


def _check_horizon(state: EngineState, params: EngineParams,
                   periods: int) -> None:
    if params.arrivals != "replay":
        return
    end = int(np.asarray(state.period)) + periods
    if end > params.counts.shape[0]:
        raise ValueError(
            f"replayed arrival trace covers {params.counts.shape[0]} "
            f"periods but the rollout needs {end}; presample a longer "
            f"horizon (EngineParams.from_config(..., horizon=)) or use "
            f"arrivals='poisson'")


def step(state: EngineState, params: EngineParams
         ) -> Tuple[EngineState, PeriodMetrics]:
    """One jitted period transition (float64, like the host LP path)."""
    from jax.experimental import enable_x64
    _require_f64("state", state)
    _require_f64("params", params)
    _check_horizon(state, params, 1)
    with enable_x64():
        return _step_jit(state, params)


def rollout(state: EngineState, params: EngineParams, periods: int,
            *, donate: bool = False
            ) -> Tuple[EngineState, PeriodMetrics]:
    """A whole fleet epoch as ONE `lax.scan` over the jitted step — zero
    per-period host round-trips.  Returns ``(final_state, metrics)`` with
    every `PeriodMetrics` field stacked to a (periods,) array.

    ``donate=True`` donates the input state's buffers to the scan (the
    caller must not reuse ``state`` afterwards) — at the 100k-device
    scale this halves peak memory, since the old and new fleet state
    never need to coexist."""
    from jax.experimental import enable_x64
    _require_f64("state", state)
    _require_f64("params", params)
    _check_horizon(state, params, periods)
    fn = _rollout_donate if donate else _rollout_jit
    with enable_x64():
        return fn(state, params, int(periods))


# --------------------------------------------------------------------------
# differentiation: pytree partition + rollout gradients
# --------------------------------------------------------------------------
# Placeholder for the non-selected half of a partitioned pytree.  None on
# purpose: jax treats None as an EMPTY subtree, so `jax.grad` over the
# diff half traces ONLY the float leaves (an opaque sentinel object would
# be rejected as "not a valid JAX type" the moment the half crosses a
# jit/grad boundary).  `combine_diff` re-materializes the placeholders as
# leaves via ``is_leaf`` when zipping the halves back together.
_NONDIFF = None


def partition_diff(tree):
    """Split a pytree into (diff, nondiff) halves by leaf dtype.

    Inexact (float) leaves keep their value in the ``diff`` half and
    become ``None`` in ``nondiff``; integer/bool/key leaves — warm basis
    labels, stream cursors, PRNG keys, fault counters — go the other
    way.  Both halves keep the ORIGINAL node structure, so ``jax.grad``
    over the diff half traces only continuous leaves (a naive grad over
    a full `EngineState` dies on the int32 bookkeeping) and
    `combine_diff` reassembles losslessly."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    isf = [jnp.issubdtype(getattr(l, "dtype", np.asarray(l).dtype),
                          jnp.inexact) for l in leaves]
    diff = treedef.unflatten(
        [l if f else _NONDIFF for l, f in zip(leaves, isf)])
    nondiff = treedef.unflatten(
        [_NONDIFF if f else l for l, f in zip(leaves, isf)])
    return diff, nondiff


def combine_diff(diff, nondiff):
    """Inverse of `partition_diff`: merge the two halves back into one
    pytree (each leaf comes from whichever half is not the ``None``
    placeholder).  ``is_leaf`` keeps the placeholders visible to the
    zip — without it each None is an empty subtree and the two halves
    would not share a structure."""
    return jax.tree_util.tree_map(
        lambda d, n: d if n is _NONDIFF else n, diff, nondiff,
        is_leaf=lambda x: x is _NONDIFF)


def _vag_impl(leaf_vals, state, params, periods: int, wrt: tuple):
    """Differentiable rollout objective: total served accuracy over the
    epoch as a function of the selected `EngineParams` leaves.

    The belief tables are re-rooted at the (differentiated) nominal
    tables — `_period_impl` PRICES from `state.p_ed`/`state.p_es_belief`,
    not the params leaves, so without the rebinding every cotangent
    w.r.t. ``p_es``/``base_p_ed`` would be zero.  With chaos disarmed
    (the `with_differentiable` contract) the rebinding is semantically
    what `init_state` does anyway."""
    params = dataclasses.replace(params, **dict(zip(wrt, leaf_vals)))
    state = dataclasses.replace(
        state, p_ed=jnp.asarray(params.base_p_ed, jnp.float64),
        p_es_belief=jnp.asarray(params.p_es, jnp.float64))
    _, metrics = _rollout_impl(state, params, periods)
    return jnp.sum(metrics.total_accuracy)


_vag_jit = partial(jax.jit, static_argnames=("periods", "wrt"))(
    jax.value_and_grad(_vag_impl))


def _grad_entry(state, params, periods, wrt):
    if not params.differentiable:
        raise ValueError(
            "rollout_grad/rollout_value_and_grad need "
            "params.with_differentiable() — with the flag off the "
            "forward trace is the hard (piecewise-constant) path and "
            "every gradient would be zero")
    _require_f64("state", state)
    _require_f64("params", params)
    _check_horizon(state, params, int(periods))
    wrt = tuple(wrt) if wrt is not None else tuple(params.grad_leaves)
    bad = [f for f in wrt if f not in GRAD_LEAVES]
    if bad:
        raise ValueError(f"wrt {bad} not differentiable; the continuous "
                         f"EngineParams knobs are {GRAD_LEAVES}")
    # the leaves are float64 already (checked above); materializing them
    # with jnp.asarray OUTSIDE an enable_x64 scope would downcast
    leaf_vals = tuple(getattr(params, f) for f in wrt)
    return leaf_vals, wrt


def rollout_value_and_grad(state: EngineState, params: EngineParams,
                           periods: int, *,
                           wrt: Optional[Tuple[str, ...]] = None):
    """``(value, grads)`` of the rolled-out TOTAL ACCURACY w.r.t. the
    named continuous `EngineParams` leaves (default: the params'
    ``grad_leaves`` aux — ES capacity ``p_es``, deadline ``T``, ladder
    mix ``acc``).  ``grads`` is a dict keyed by leaf name, each entry
    shaped like the leaf.

    The whole epoch runs as the same single `lax.scan` as `rollout`,
    with the LP differentiated implicitly at its converged basis and the
    rounding/admission stages smoothed per the params' ``smooth_mode``
    ("st": value == the hard rollout's served accuracy; "soft": value is
    the softened surrogate the finite-difference gates check).  Requires
    `EngineParams.with_differentiable`; sharded rollouts are not
    differentiable (run gradients on the single-host trace)."""
    from jax.experimental import enable_x64
    leaf_vals, wrt = _grad_entry(state, params, periods, wrt)
    with enable_x64():
        val, grads = _vag_jit(leaf_vals, state, params,
                              periods=int(periods), wrt=wrt)
    return val, dict(zip(wrt, grads))


def rollout_grad(state: EngineState, params: EngineParams, periods: int,
                 *, wrt: Optional[Tuple[str, ...]] = None):
    """`rollout_value_and_grad` without the value (same one compiled
    pass — `jax.value_and_grad` underneath)."""
    return rollout_value_and_grad(state, params, periods, wrt=wrt)[1]


# --------------------------------------------------------------------------
# sharding: device_put the fleet axis, run step/rollout under shard_map
# --------------------------------------------------------------------------
def fleet_mesh(n_shards: Optional[int] = None):
    """A 1-D mesh over the first ``n_shards`` local jax devices (all by
    default) with the ``"fleet"`` axis.  On CPU, spawn host platform
    devices with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
    BEFORE importing jax."""
    from jax.sharding import Mesh
    devices = jax.devices()
    n = n_shards if n_shards is not None else len(devices)
    if n > len(devices):
        raise ValueError(f"asked for {n} shards but only "
                         f"{len(devices)} jax devices exist")
    return Mesh(np.asarray(devices[:n]), (FLEET_AXIS,))


def _state_specs():
    from jax.sharding import PartitionSpec as P
    dev = P(FLEET_AXIS)
    return EngineState(period=P(), key=P(), p_ed=dev, pending=dev,
                       head=dev, warm_basis=dev, n_updates=dev,
                       pos=dev, cell=dev, cell_load=P(), p_es_belief=dev,
                       hi=HILearnerState(theta=dev, arm=dev, arms_sum=dev,
                                         arms_cnt=dev, es_sum=dev,
                                         es_cnt=dev, cum_regret=dev))


def _param_specs(params: EngineParams):
    """Spec pytree matching ``params``' structure (the static aux rides
    along so tree_map/shard_map can pair specs with leaves)."""
    from jax.sharding import PartitionSpec as P
    dev = P(FLEET_AXIS)
    fault_specs = FaultModel(
        **{f.name: P() for f in dataclasses.fields(FaultModel)})
    # the trace is (H, D, 2): replicated horizon axis, sharded fleet axis
    # (cells themselves are global — every shard sees all S of them).
    # Disarmed, the null model's (1, 1, 2) placeholder trace cannot split
    # over the fleet axis — replicate it instead.
    mobility_specs = MobilityModel(
        cell_xy=P(), cell_rate=P(), radius=P(), link_alpha=P(),
        walk_sigma=P(),
        trace=(P(None, FLEET_AXIS) if params.mobility_mode != "off"
               else P()))
    # armed HI never reaches the sharded entries (`_reject_hi_sharded`),
    # so the null model's placeholder leaves just replicate
    hi_specs = HIModel(
        **{f.name: P() for f in dataclasses.fields(HIModel)})
    return dataclasses.replace(
        params, classes=P(), base_p_ed=dev, p_es=dev, acc=dev, T=P(),
        rate=dev, class_probs=P(), drift=dev, outage=dev,
        counts=P(None, FLEET_AXIS), stream=dev, faults=fault_specs,
        mobility=mobility_specs, hi=hi_specs)


def _metric_specs():
    from jax.sharding import PartitionSpec as P
    return PeriodMetrics(**{f: P() for f in _METRIC_FIELDS})


def shard(state: EngineState, params: EngineParams, mesh
          ) -> Tuple[EngineState, EngineParams]:
    """`device_put` the stacked fleet axis across ``mesh``: every
    per-device leaf of the state and params — the same arrays a period's
    `FleetProblem` is gathered from — lands block-partitioned along
    ``"fleet"``; scalars and class tables are replicated.  The fleet size
    must divide the mesh."""
    from jax.experimental import enable_x64
    from jax.sharding import NamedSharding
    _reject_hi_sharded(params)
    _require_f64("state", state)
    _require_f64("params", params)
    D = params.n_devices
    n_shards = int(np.prod(mesh.devices.shape))
    if D % n_shards:
        raise ValueError(
            f"fleet size {D} does not divide the {n_shards}-device mesh")
    put = lambda tree, specs: jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs)
    with enable_x64():      # keep float64 leaves f64 across the device_put
        return put(state, _state_specs()), put(params, _param_specs(params))


@functools.lru_cache(maxsize=None)
def _sharded_fn(mesh, periods: Optional[int], params_aux: tuple,
                donate: bool = False):
    """Build (and cache) the shard_mapped step / rollout for a mesh.

    ``params_aux`` (the `EngineParams` static fields) is part of the cache
    key because the in_specs pytree must carry the same aux as the actual
    params being passed; ``donate`` keys the variant that consumes the
    input state's buffers."""
    from jax.experimental.shard_map import shard_map

    spec_params = _param_specs(
        EngineParams(**{f: None for f in _PARAM_LEAVES},
                     **dict(zip(_PARAM_AUX, params_aux))))
    if periods is None:
        fn = partial(_step_impl, axis_name=FLEET_AXIS)
    else:
        def fn(state, params):
            return jax.lax.scan(
                lambda s, _: _step_impl(s, params, axis_name=FLEET_AXIS),
                state, None, length=periods)
    mapped = shard_map(
        fn, mesh=mesh,
        in_specs=(_state_specs(), spec_params),
        out_specs=(_state_specs(), _metric_specs()),
        check_rep=False)
    return jax.jit(mapped, donate_argnums=(0,) if donate else ())


def _aux_of(params: EngineParams) -> tuple:
    return tuple(getattr(params, f) for f in _PARAM_AUX)


def _reject_diff_sharded(params: EngineParams) -> None:
    """The `with_differentiable` contract: gradients run on the
    single-host trace.  The smoothed pricing and the unconditional
    replan only exist on the ``axis_name is None`` branch of
    `_period_impl`, so a sharded "differentiable" rollout would silently
    run the hard forward — reject instead of letting the flag lie."""
    if params.differentiable:
        raise ValueError(
            "sharded entry points do not support differentiable params; "
            "disarm with with_differentiable(False) or run "
            "rollout_value_and_grad on the single-host trace")


def _reject_hi_sharded(params: EngineParams) -> None:
    """Armed HI carries learner state whose replay-trace slicing and
    per-arm bookkeeping have not been validated under `shard_map` yet —
    reject instead of silently diverging from the unsharded trajectory
    (the confidence stream itself already folds GLOBAL device ids, so
    this rung is small; see ROADMAP)."""
    if params.hi_armed:
        raise ValueError(
            "sharded entry points do not support armed HI "
            f"(hi_rule={params.hi_rule!r}); disarm with with_hi(None) or "
            "run the single-host rollout")


def step_sharded(state: EngineState, params: EngineParams, mesh
                 ) -> Tuple[EngineState, PeriodMetrics]:
    """`step` under `shard_map`: the fleet axis stays partitioned across
    the mesh; admission gathers the (D,) demand vector and metrics are
    psum-reduced, so the output matches the unsharded `step`."""
    from jax.experimental import enable_x64
    _reject_diff_sharded(params)
    _reject_hi_sharded(params)
    _require_f64("state", state)
    _require_f64("params", params)
    _check_horizon(state, params, 1)
    with enable_x64():
        return _sharded_fn(mesh, None, _aux_of(params))(state, params)


def rollout_sharded(state: EngineState, params: EngineParams,
                    periods: int, mesh, *, donate: bool = False
                    ) -> Tuple[EngineState, PeriodMetrics]:
    """`rollout` under `shard_map`: one scan, fleet axis sharded
    throughout — the ROADMAP's 10k+-device shape.  ``donate=True``
    consumes the input state's shards (see `rollout`)."""
    from jax.experimental import enable_x64
    _reject_diff_sharded(params)
    _reject_hi_sharded(params)
    _require_f64("state", state)
    _require_f64("params", params)
    _check_horizon(state, params, periods)
    with enable_x64():
        return _sharded_fn(mesh, int(periods), _aux_of(params),
                           donate)(state, params)
