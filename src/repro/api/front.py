"""`solve` / `solve_many`: the single front door over the solver registry.

Dispatch rules (the same table the legacy planner used, now in one place):

  * ``policy="auto"`` — identical-job problems route to the exact AMDP,
    heterogeneous ones to AMR²; a fleet is split by `identical_mask` and
    each side goes through its solver's batched path in one call.
  * ``policy=<name>`` — any registry entry (`repro.api.solver_names()`).
    ``policy="amdp"`` on heterogeneous jobs falls back to AMR² (the DP's
    precondition), mirroring the scalar planner.
  * ``backend`` — ``"jax"`` (fleet default) runs each batched solver as a
    handful of jitted calls; ``"numpy"`` (single-problem default) is the
    sequential per-device oracle path.  A fleet solve with a non-batched
    solver under ``backend="jax"`` raises instead of silently running
    sequentially under a misleading tag.
  * ``es_disabled=True`` — plan with offloading made infeasible (uniform
    huge p_es on real jobs): the backpressure / ES-outage replan path.
    Identical-job detection then looks at the *real* (non-phantom) jobs
    only, exactly like the legacy batched replan.

This front door is a HOST boundary: solutions come back as NumPy arrays
and nothing here is differentiable.  Capacity-planning gradients run on
the traced engine instead — `EngineParams.with_differentiable()` +
`repro.api.rollout_value_and_grad` differentiate a whole rolled-out
epoch (implicit-gradient simplex, smoothed rounding/admission) w.r.t.
the continuous knobs; see `repro.api.engine`.
"""
from __future__ import annotations

import inspect
import time
import warnings
from typing import Callable, Dict, List, Optional, Sequence, Union

import numpy as np

from ..core.problem import (ES_DISABLED_SENTINEL, ST_UNSOLVED, FleetProblem,
                            Problem, Solution)
from ..core.types import InstanceBatch, OffloadInstance
from . import solvers as _solvers          # noqa: F401  (populate registry)
from .registry import get_solver, solver_names, solvers

AnyProblem = Union[Problem, FleetProblem, OffloadInstance, InstanceBatch]


def batched_policies() -> "tuple[str, ...]":
    """Policies with a batched (one-jit-call-per-group) fleet path:
    ``auto`` plus every registry entry declaring ``batched=True``.
    Computed from the registry so new entries dispatch correctly."""
    return ("auto",) + tuple(n for n, info in solvers().items()
                             if info.batched)


def _fallback_name(policy: str) -> str:
    """The solver handling a fleet's non-identical rows: AMR² complements
    the ``auto``/``amdp`` identical-job split; any other named batched
    solver handles its whole fleet itself."""
    return "amr2" if policy in ("auto", "amdp") else policy


def _coerce(problem: AnyProblem) -> Union[Problem, FleetProblem]:
    if isinstance(problem, (Problem, FleetProblem)):
        return problem
    if isinstance(problem, OffloadInstance):
        return Problem.from_instance(problem)
    if isinstance(problem, InstanceBatch):
        return FleetProblem.from_batch(problem)
    raise TypeError(
        f"solve() wants a Problem/FleetProblem (or legacy OffloadInstance/"
        f"InstanceBatch); got {type(problem).__name__}")


def _filter_opts(fn: Callable, opts: Dict) -> Dict:
    """Options ``fn`` accepts.  Dispatch may reroute a problem to a solver
    other than the one named by ``policy`` (amdp→amr2 fallback, the auto
    split, the es-disabled rest path); solver-specific options — e.g. the
    DP's ``impl="pallas"`` — must not crash the rerouted call."""
    if not opts:                          # hot path: no introspection cost
        return opts
    params = inspect.signature(fn).parameters
    if any(p.kind is inspect.Parameter.VAR_KEYWORD
           for p in params.values()):
        return opts
    return {k: v for k, v in opts.items() if k in params}


def _validate_opts(policy: str, opts: Dict) -> None:
    """Typo guard: an explicitly named policy must accept every option on
    at least one of its entry points (``auto`` opts are best-effort — each
    dispatched solver takes the subset it understands)."""
    if policy == "auto" or not opts:
        return
    solver = get_solver(policy)
    accepted: set = set()
    for meth in ("solve_one", "solve_fleet"):
        fn = getattr(solver, meth, None)
        if fn is not None:
            accepted |= set(inspect.signature(fn).parameters)
    unknown = set(opts) - accepted
    if unknown:
        raise TypeError(
            f"solver {policy!r} does not accept option(s) "
            f"{sorted(unknown)}")


def _check_strict(sol: Solution, strict: bool) -> Solution:
    """Surface solver non-convergence (status "unsolved": LP iteration
    limit / unbounded) instead of silently returning a degraded plan."""
    n_bad = int((np.atleast_1d(sol.status) == ST_UNSOLVED).sum())
    if n_bad:
        msg = (f"{n_bad} problem(s) were not solved to optimality "
               f"(status 'unsolved': simplex iteration limit or unbounded "
               f"LP); raise maxiter, or pass strict=False to accept the "
               f"best-effort assignment")
        if strict:
            raise RuntimeError(msg)
        warnings.warn(msg, RuntimeWarning, stacklevel=3)
    return sol


def solve(problem: AnyProblem, *, policy: str = "auto",
          backend: str = None, es_disabled: bool = False,
          strict: bool = True, warm_start: Optional[np.ndarray] = None,
          **opts) -> Solution:
    """Plan one `Problem` or a whole `FleetProblem` through the registry.

    ``warm_start`` feeds an LP-backed solver (amr2/lp) the previous
    period's optimal simplex basis (`Solution.basis`) so the solve prices
    out of the old vertex instead of running two cold phases; devices whose
    basis row is -1 (or no longer valid) fall back to the cold solve.
    ``strict`` controls what happens when a solver fails to converge (e.g.
    a capped ``maxiter``): True (default) raises, False warns and returns
    the best-effort `Solution` with status "unsolved".

    Returns a `Solution`; ``solution.plan_seconds`` is the wall time of the
    whole call (fleet solves amortize internally)."""
    problem = _coerce(problem)
    if warm_start is not None:
        opts["warm_start"] = np.asarray(warm_start)
    _validate_opts(policy, opts)
    opts.setdefault("on_error", "mark")   # front door surfaces via strict
    if es_disabled and policy != "auto" \
            and not get_solver(policy).info.supports_es_disabled:
        raise ValueError(
            f"solver {policy!r} declares supports_es_disabled=False; "
            f"it cannot drive the backpressure/outage replan path")
    if isinstance(problem, FleetProblem):
        backend = backend or "jax"
        if es_disabled:
            return _check_strict(
                _solve_fleet_es_disabled(problem, policy, backend, **opts),
                strict)
        return _check_strict(_solve_fleet(problem, policy, backend, **opts),
                             strict)
    backend = backend or "numpy"
    if es_disabled:
        problem = problem.es_disabled()
    return _check_strict(_solve_one(problem, policy, backend, **opts),
                         strict)


# --------------------------------------------------------------------------
# single problem
# --------------------------------------------------------------------------
def _resolve_policy(problem: Problem, policy: str) -> str:
    if policy == "auto":
        policy = "amdp" if problem.is_identical() else "amr2"
    if policy == "amdp" and not problem.is_identical():
        policy = "amr2"                   # the DP's identical-jobs premise
    return policy


def _solve_one(problem: Problem, policy: str, backend: str,
               **opts) -> Solution:
    t0 = time.perf_counter()
    solver = get_solver(_resolve_policy(problem, policy))
    sol = solver.solve_one(problem, backend=backend,
                           **_filter_opts(solver.solve_one, opts))
    sol.plan_seconds = time.perf_counter() - t0
    return sol


# --------------------------------------------------------------------------
# fleet problem (the array-resident hot path)
# --------------------------------------------------------------------------
def _check_fleet_policy(policy: str, backend: str) -> None:
    if policy == "auto":
        return
    solver = get_solver(policy)           # unknown names raise here
    if backend == "jax" and not solver.info.batched:
        raise ValueError(
            f"policy={policy!r} has no batched path; pass backend='numpy' "
            f"for the sequential oracle (batched solvers: "
            f"{[n for n in solver_names() if get_solver(n).info.batched]})")


def _empty_solution(fleet: FleetProblem) -> Solution:
    return Solution(problem=fleet,
                    assignment=np.zeros((0, fleet.n), dtype=np.int64),
                    status=np.zeros(0, dtype=np.int64),
                    solver=np.empty(0, dtype=object))


def _take_rows(opts: Dict, rows: np.ndarray) -> Dict:
    """Opts for a row-subset dispatch: per-device option arrays (only
    ``warm_start`` today) are sliced to the subset's rows."""
    if opts.get("warm_start") is None:
        return opts
    sub = dict(opts)
    sub["warm_start"] = np.asarray(opts["warm_start"])[rows]
    return sub


def _solve_fleet(fleet: FleetProblem, policy: str, backend: str,
                 **opts) -> Solution:
    t0 = time.perf_counter()
    _check_fleet_policy(policy, backend)
    B, n = fleet.p_es.shape
    if B == 0:
        return _empty_solution(fleet)

    assignment = np.zeros((B, n), dtype=np.int64)
    status = np.zeros(B, dtype=np.int64)
    solver_tag = np.empty(B, dtype=object)
    basis: Optional[np.ndarray] = None
    lp_acc: Optional[np.ndarray] = None

    def _merge_basis(rows: np.ndarray, sub_basis: Optional[np.ndarray]
                     ) -> None:
        nonlocal basis
        if sub_basis is None:
            return
        if basis is None:       # -1 rows: devices another solver handled
            basis = np.full((B, sub_basis.shape[1]), -1, dtype=np.int64)
        basis[rows] = sub_basis

    def _merge_lp_acc(rows: np.ndarray, sub_acc) -> None:
        nonlocal lp_acc
        if sub_acc is None:
            return
        if lp_acc is None:      # NaN rows: no LP bound for those devices
            lp_acc = np.full(B, np.nan)
        lp_acc[rows] = np.atleast_1d(np.asarray(sub_acc, np.float64))

    if backend != "jax" or policy not in batched_policies():
        warm = opts.get("warm_start")
        for b in range(B):                # sequential oracle path
            o = opts
            if warm is not None:
                o = dict(opts)
                wb = np.asarray(warm)[b]
                if (wb >= 0).all():       # -1 rows: no basis for this device
                    o["warm_start"] = wb
                else:
                    del o["warm_start"]
            sol = _solve_one(fleet[b], policy, backend, **o)
            assignment[b] = sol.assignment
            status[b] = int(sol.status)
            solver_tag[b] = sol.solver
            if sol.basis is not None:
                _merge_basis(np.array([b]), np.asarray(sol.basis)[None])
            _merge_lp_acc(np.array([b]), sol.lp_accuracy)
        return Solution(problem=fleet, assignment=assignment, status=status,
                        solver=solver_tag, basis=basis, lp_accuracy=lp_acc,
                        plan_seconds=time.perf_counter() - t0)

    if policy in ("auto", "amdp"):
        ident = fleet.identical_mask()
    else:
        ident = np.zeros(B, dtype=bool)

    if ident.any():
        idxs = np.nonzero(ident)[0]
        amdp = get_solver("amdp")
        sub = amdp.solve_fleet(fleet.take(idxs),
                               **_filter_opts(amdp.solve_fleet,
                                              _take_rows(opts, idxs)))
        assignment[idxs] = sub.assignment
        status[idxs] = sub.status
        solver_tag[idxs] = "amdp"
        _merge_basis(idxs, sub.basis)
        _merge_lp_acc(idxs, sub.lp_accuracy)
    rest = np.nonzero(~ident)[0]
    sub = None
    if len(rest):
        name = _fallback_name(policy)
        solver = get_solver(name)
        sub = solver.solve_fleet(fleet.take(rest),
                                 **_filter_opts(solver.solve_fleet,
                                                _take_rows(opts, rest)))
        assignment[rest] = sub.assignment
        status[rest] = sub.status
        solver_tag[rest] = name
        _merge_basis(rest, sub.basis)
        _merge_lp_acc(rest, sub.lp_accuracy)
    out = Solution(problem=fleet, assignment=assignment, status=status,
                   solver=solver_tag, basis=basis, lp_accuracy=lp_acc,
                   plan_seconds=time.perf_counter() - t0)
    if sub is not None and len(rest) == B:
        # solver-attached extras (routed's cell/link_factor, the HI
        # entries' learner state) survive the front door when one solver
        # handled the whole fleet — per-row merging of opaque extras
        # across the auto/amdp split is not defined
        for extra in ("cell", "link_factor", "hi_state", "hi_theta"):
            if hasattr(sub, extra):
                setattr(out, extra, getattr(sub, extra))
    return out


def _solve_fleet_es_disabled(fleet: FleetProblem, policy: str, backend: str,
                             **opts) -> Solution:
    """ONE batched ES-disabled solve for a whole sub-fleet (backpressure /
    outage): real jobs get the uniform huge p_es sentinel, phantom padding
    stays free, and under ``auto``/``amdp`` devices whose *real* jobs share
    processing times route to the exact DP on their stripped instances —
    precisely the scalar planner's identical-job dispatch, since the
    crippled p_es is uniform."""
    mask = fleet.real_mask
    p_es = np.where(mask, ES_DISABLED_SENTINEL, 0.0)
    crippled = FleetProblem(p_ed=fleet.p_ed.copy(), p_es=p_es,
                            acc=fleet.acc.copy(), T=fleet.T.copy(),
                            real_mask=mask)
    if backend != "jax" or policy not in ("auto", "amdp"):
        return _solve_fleet(crippled, policy, backend, **opts)

    t0 = time.perf_counter()
    B, n = crippled.p_es.shape
    m = crippled.m
    k = mask.sum(axis=1)
    first = np.argmax(mask, axis=1)                 # first real job index
    ref_row = crippled.p_ed[np.arange(B), first]    # (B, m)
    hetero = (~np.isclose(crippled.p_ed, ref_row[:, None, :], rtol=1e-9)
              ).any(axis=2) & mask
    ident = (k > 0) & ~hetero.any(axis=1)

    assignment = np.zeros((B, n), dtype=np.int64)
    status = np.zeros(B, dtype=np.int64)
    solver_tag = np.empty(B, dtype=object)
    if ident.any():
        # stripped instances have differing real-job counts; amdp_batch
        # pads/buckets its DP grids internally, so feed it directly
        from ..core.amdp import amdp_batch
        from .solvers import _STATUS_CODE
        idxs = np.nonzero(ident)[0]
        insts = [crippled.instance(int(b), strip=True) for b in idxs]
        for b, sched in zip(idxs, amdp_batch(
                insts, **_filter_opts(amdp_batch, opts))):
            row = np.full(n, m, dtype=np.int64)     # phantoms: free ES
            row[mask[b]] = sched.assignment
            assignment[b] = row
            status[b] = _STATUS_CODE[sched.status]
            solver_tag[b] = "amdp"
    basis: Optional[np.ndarray] = None
    lp_acc: Optional[np.ndarray] = None
    rest = np.nonzero(~ident)[0]
    if len(rest):
        sub = _solve_fleet(crippled.take(rest), "amr2", "jax",
                           **_take_rows(opts, rest))
        assignment[rest] = sub.assignment
        status[rest] = sub.status
        solver_tag[rest] = np.atleast_1d(sub.solver)
        # keep the LP outputs flowing like the plain fleet path (amdp rows
        # stay -1/NaN), so warm-start chaining and the bound survive a
        # replan identically on every backend
        if sub.basis is not None:
            basis = np.full((B, sub.basis.shape[1]), -1, dtype=np.int64)
            basis[rest] = sub.basis
        if sub.lp_accuracy is not None:
            lp_acc = np.full(B, np.nan)
            lp_acc[rest] = np.atleast_1d(sub.lp_accuracy)
    return Solution(problem=crippled, assignment=assignment, status=status,
                    solver=solver_tag, basis=basis, lp_accuracy=lp_acc,
                    plan_seconds=time.perf_counter() - t0)


# --------------------------------------------------------------------------
# many single problems (mixed shapes): the object-path batcher
# --------------------------------------------------------------------------
def solve_many(problems: Sequence[AnyProblem], *, policy: str = "auto",
               backend: str = "jax", strict: bool = True,
               warm_start: Optional[Sequence] = None,
               **opts) -> List[Solution]:
    """Plan a sequence of (possibly mixed-shape) problems in as few solver
    calls as possible: identical-job problems batch through the vmapped DP
    regardless of shape, the rest group by (n, m) and run through their
    solver's batched path once per group.  Returns one `Solution` per
    problem, in input order; ``plan_seconds`` is the group's solve time
    amortized over its members.  An empty sequence returns ``[]``.

    ``warm_start`` is one basis (`Solution.basis`) or None per problem,
    aligned with ``problems``; each LP-backed group stacks its members'
    bases (missing ones become cold -1 rows).  ``strict`` mirrors
    `solve`: raise (default) or warn on "unsolved" solver statuses."""
    probs = [_coerce(p) for p in problems]
    if any(isinstance(p, FleetProblem) for p in probs):
        raise TypeError("solve_many wants single problems; pass a "
                        "FleetProblem to solve() instead")
    if warm_start is not None and len(warm_start) != len(probs):
        raise ValueError(
            f"warm_start must align with problems: got {len(warm_start)} "
            f"bases for {len(probs)} problems")
    if not probs:
        return []
    _validate_opts(policy, opts)
    opts.setdefault("on_error", "mark")
    _check_fleet_policy(policy, backend)

    def _done(sols: List[Solution]) -> List[Solution]:
        for s in sols:
            _check_strict(s, strict)
        return sols

    if backend != "jax" or policy not in batched_policies():
        out = []
        for i, p in enumerate(probs):
            o = opts
            if warm_start is not None and warm_start[i] is not None:
                o = {**opts, "warm_start": np.asarray(warm_start[i])}
            out.append(_solve_one(p, policy, backend, **o))
        return _done(out)

    sols: List[Solution] = [None] * len(probs)      # type: ignore
    amdp_idxs: List[int] = []
    groups: dict = {}
    for idx, p in enumerate(probs):
        if policy in ("auto", "amdp") and p.is_identical():
            amdp_idxs.append(idx)
        else:
            groups.setdefault((_fallback_name(policy), p.n, p.m),
                              []).append(idx)

    if amdp_idxs:                 # vmapped DP, grouped/bucketed inside
        from ..core.amdp import amdp_batch
        t0 = time.perf_counter()
        scheds = amdp_batch([probs[i].to_instance() for i in amdp_idxs],
                            **_filter_opts(amdp_batch, opts))
        dt = (time.perf_counter() - t0) / len(amdp_idxs)
        for i, sched in zip(amdp_idxs, scheds):
            sols[i] = Solution.from_schedule(sched, solver="amdp",
                                             plan_seconds=dt,
                                             problem=probs[i])

    for (name, n, m), idxs in groups.items():
        t0 = time.perf_counter()
        sub = FleetProblem.from_problems([probs[i] for i in idxs], pad_to=n)
        solver = get_solver(name)
        o = opts
        if warm_start is not None:
            bases = [warm_start[i] for i in idxs]
            have = [np.asarray(b) for b in bases if b is not None]
            if have:
                wb = np.full((len(idxs), have[0].shape[0]), -1,
                             dtype=np.int64)
                for row, b in enumerate(bases):
                    if b is not None:
                        wb[row] = np.asarray(b)
                o = {**opts, "warm_start": wb}
        fsol = solver.solve_fleet(sub,
                                  **_filter_opts(solver.solve_fleet, o))
        dt = (time.perf_counter() - t0) / len(idxs)
        for row, i in enumerate(idxs):
            sols[i] = Solution(
                problem=probs[i], assignment=fsol.assignment[row],
                status=np.int64(fsol.status[row]), solver=name,
                plan_seconds=dt,
                lp_accuracy=(None if fsol.lp_accuracy is None
                             else fsol.lp_accuracy[row]),
                n_fractional=(None if fsol.n_fractional is None
                              else fsol.n_fractional[row]),
                basis=(None if fsol.basis is None else fsol.basis[row]))
    return _done(sols)
