"""Solver registry: one named entry per planning algorithm.

Every solver the repo implements — the paper's AMR² and AMDP, the greedy
baseline, the beyond-paper dual scheduler, and the LP bound — registers
itself here with a declared capability set, and `repro.api.solve` is the
single front door that dispatches on those capabilities.  Adding a new
scenario/algorithm is a ``@register_solver`` entry, not another
``elif policy ==`` chain across the serving stack.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Protocol, runtime_checkable

from ..core.problem import FleetProblem, Problem, Solution


@dataclasses.dataclass(frozen=True)
class SolverInfo:
    """A registry entry's declared capabilities.

    ``batched``, ``supports_es_disabled``, and ``bound_only`` are enforced
    by the front door / engine; ``exact_on_identical`` is descriptive
    metadata — `solve`'s ``auto`` routing currently pairs the paper's
    AMDP/AMR² specifically (the DP's precondition is structural, not just
    a quality claim), it does not yet generalize over this flag."""
    name: str
    batched: bool                 # has a solve_fleet (vmapped/jitted) path
    exact_on_identical: bool      # optimal when all jobs share proc. times
    supports_es_disabled: bool    # usable for backpressure/outage replans
    bound_only: bool = False      # yields an upper bound, not a schedule
    warm_start: bool = False      # accepts warm_start= (Solution.basis)
    online: bool = False          # learns per-sample in-stream (no prior
    #                               accuracy knowledge; pair with
    #                               EngineParams.with_hi for rollouts)
    description: str = ""


@runtime_checkable
class Solver(Protocol):
    """What a registry entry must provide.

    ``solve_one`` plans a single `Problem`.  Batched solvers additionally
    implement ``solve_fleet`` over a same-shape `FleetProblem`; the front
    door never calls ``solve_fleet`` on a solver whose info says
    ``batched=False``.
    """
    info: SolverInfo

    def solve_one(self, problem: Problem, *, backend: str = "numpy",
                  **opts) -> Solution: ...

    def solve_fleet(self, fleet: FleetProblem, **opts) -> Solution: ...


_REGISTRY: Dict[str, Solver] = {}


def register_solver(name: str, *, batched: bool, exact_on_identical: bool,
                    supports_es_disabled: bool, bound_only: bool = False,
                    warm_start: bool = False, online: bool = False,
                    description: str = "") -> Callable:
    """Class decorator: instantiate and register a solver under ``name``."""
    def deco(cls):
        solver = cls()
        solver.info = SolverInfo(
            name=name, batched=batched,
            exact_on_identical=exact_on_identical,
            supports_es_disabled=supports_es_disabled,
            bound_only=bound_only, warm_start=warm_start, online=online,
            description=description)
        _REGISTRY[name] = solver
        return cls
    return deco


def get_solver(name: str) -> Solver:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown solver {name!r}; registered: "
            f"{sorted(_REGISTRY)} (or policy='auto')") from None


def solver_names() -> "list[str]":
    return sorted(_REGISTRY)


def solvers() -> Dict[str, SolverInfo]:
    """name -> capabilities, for introspection and the README table."""
    return {name: s.info for name, s in sorted(_REGISTRY.items())}


def solver_table() -> str:
    """The registry rendered as a markdown capability table."""
    rows = ["| solver | batched | exact on identical | es-disabled | "
            "warm-start | online | description |",
            "|--------|---------|--------------------|-------------|"
            "------------|--------|-------------|"]
    for name, info in solvers().items():
        rows.append(
            f"| `{name}` | {'yes' if info.batched else 'no'} "
            f"| {'yes' if info.exact_on_identical else 'no'} "
            f"| {'yes' if info.supports_es_disabled else 'no'} "
            f"| {'yes' if info.warm_start else 'no'} "
            f"| {'yes' if info.online else 'no'} "
            f"| {info.description}"
            f"{' (bound only)' if info.bound_only else ''} |")
    return "\n".join(rows)
