"""The registry entries: the paper's algorithms (and the beyond-paper
extras) wrapped behind the uniform `Solver` protocol.

Each entry reuses the existing core implementation unchanged — the scalar
NumPy oracles for ``solve_one``, the vmapped/jitted batched paths for
``solve_fleet`` — and declares its capabilities so `repro.api.solve` can
dispatch without policy-specific ``elif`` chains.  Batched entries
bucket-pad the fleet axis to a power of two internally (repeating the last
row) so fluctuating fleet sizes reuse O(log B) compiled programs.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..core.amdp import amdp, amdp_batch
from ..core.amr2 import (ST_INFEASIBLE, ST_UNSOLVED, amr2_batch_arrays,
                         build_lp_arrays_batch, round_relaxation,
                         solve_lp_relaxation)
from ..core.dual import dual_schedule, dual_schedule_batch_arrays
from ..core.greedy import greedy_rra
from ..core.lp import INFEASIBLE, OPTIMAL, solve_lp_batch
from ..core.problem import (ST_BOUND, SOLUTION_STATUS_NAMES, FleetProblem,
                            Problem, Solution)
from ..core.types import next_pow2
from .registry import register_solver

_STATUS_CODE = {name: code for code, name in enumerate(SOLUTION_STATUS_NAMES)}


def _pow2_rows(B: int) -> np.ndarray:
    """Row index vector padding a B-row batch to the next power of two by
    repeating the last row (the shared jit-trace-reuse bucketing)."""
    return np.concatenate(
        [np.arange(B), np.full(next_pow2(B) - B, B - 1, dtype=np.int64)])


@register_solver(
    "amr2", batched=True, exact_on_identical=False,
    supports_es_disabled=True, warm_start=True,
    description="LP-relax + round (paper Alg. 1–2): ≤2T makespan, "
                "≤2(a_max−a_min) accuracy gap")
class AMR2Solver:
    def solve_one(self, problem: Problem, *, backend: str = "numpy",
                  frac_tol: float = 1e-4, maxiter: Optional[int] = None,
                  warm_start: Optional[np.ndarray] = None,
                  on_error: str = "raise") -> Solution:
        inst = problem.to_instance()
        xbar, a_lp, status, basis = solve_lp_relaxation(
            inst, backend=backend, maxiter=maxiter, warm_basis=warm_start)
        sched = round_relaxation(inst, xbar, a_lp, status,
                                 frac_tol=frac_tol, on_error=on_error)
        sol = Solution.from_schedule(sched, solver="amr2", problem=problem)
        sol.basis = np.asarray(basis, np.int64)
        return sol

    def solve_fleet(self, fleet: FleetProblem, *, frac_tol: float = 1e-4,
                    maxiter: Optional[int] = None,
                    warm_start: Optional[np.ndarray] = None,
                    impl: str = "jnp", on_error: str = "raise") -> Solution:
        B = len(fleet)
        rows = _pow2_rows(B)
        sub = fleet.take(rows).to_batch()
        wb = None if warm_start is None else np.asarray(warm_start)[rows]
        assign, status, n_frac, lp_acc, basis = amr2_batch_arrays(
            sub, frac_tol=frac_tol, maxiter=maxiter, warm_basis=wb,
            impl=impl, on_error=on_error)
        lp_acc = lp_acc[:B].copy()
        lp_acc[(status[:B] == ST_INFEASIBLE)
               | (status[:B] == ST_UNSOLVED)] = np.nan   # no bound
        return Solution(problem=fleet, assignment=assign[:B],
                        status=status[:B],
                        solver=np.full(B, "amr2", dtype=object),
                        lp_accuracy=lp_acc, n_fractional=n_frac[:B],
                        basis=np.asarray(basis[:B], np.int64))


@register_solver(
    "routed", batched=True, exact_on_identical=False,
    supports_es_disabled=True, warm_start=True,
    description="geometry-aware amr2: route each lane to its best covered "
                "cell, price ES by the link factor, then delegate "
                "(core.mobility; uncovered lanes plan local-only)")
class RoutedSolver:
    """Multi-cell front-end over `AMR2Solver`: the host-level twin of the
    engine's traced routing pass.  Each fleet lane is assigned a serving
    cell from its position (`core.mobility.route_cells` semantics —
    nearest / min-response-time under the coverage radius), its ES column
    is scaled by the per-(device, cell) link factor, and uncovered lanes
    get the ES-disabled sentinel (local-only plans).  The LP itself is
    amr2 unchanged, so every paper guarantee (≤2T makespan, accuracy gap)
    holds per lane under the routed prices."""

    def solve_fleet(self, fleet: FleetProblem, *, positions: np.ndarray,
                    mobility, routing: str = "nearest",
                    frac_tol: float = 1e-4,
                    maxiter: Optional[int] = None,
                    warm_start: Optional[np.ndarray] = None,
                    impl: str = "jnp", on_error: str = "raise") -> Solution:
        from ..core.mobility import route_cells, validate_mobility
        from ..core.problem import ES_DISABLED_SENTINEL
        B = len(fleet)
        pos = np.asarray(positions, np.float64)
        if pos.shape != (B, 2):
            raise ValueError(
                f"positions must be ({B}, 2) to match the fleet; got "
                f"{pos.shape}")
        validate_mobility(mobility, n_devices=B,
                          n_servers=mobility.n_cells,    # 1 server / cell
                          mode="replay", routing=routing)
        cell, covered, link_factor = (
            np.asarray(a) for a in route_cells(
                pos, mobility, np.zeros(mobility.n_cells), routing))
        p_es = fleet.p_es * link_factor[:, None]
        p_es = np.where((~covered[:, None]) & fleet.real_mask,
                        ES_DISABLED_SENTINEL, p_es)
        routed = FleetProblem(p_ed=fleet.p_ed, p_es=p_es, acc=fleet.acc,
                              T=fleet.T, real_mask=fleet.real_mask)
        sol = AMR2Solver().solve_fleet(
            routed, frac_tol=frac_tol, maxiter=maxiter,
            warm_start=warm_start, impl=impl, on_error=on_error)
        # report against the CALLER's (unrouted) problem, tagged with the
        # routing outcome so serving layers can book per-cell admission
        sol.problem = fleet
        sol.solver = np.full(B, "routed", dtype=object)
        sol.cell = cell.astype(np.int64)
        sol.link_factor = link_factor
        return sol


class _HISolverBase:
    """Shared host front-end for the online hierarchical-inference rules
    (`core.hi`): one period of per-sample decisions from an observed
    confidence matrix, with the learner advanced IN-STREAM when the
    caller feeds back the realized outcomes.

    Unlike every offline entry, the decision needs no accuracy table —
    ``fleet.acc`` is consulted only for the regret metric the engine
    books, never by the rule itself.  The traced twin lives inside the
    engine's scan (`EngineParams.with_hi` + `rollout`); this entry is
    the single-period host mirror, `RoutedSolver`-style (solve_fleet
    only)."""

    rule = "fixed"

    def solve_fleet(self, fleet: FleetProblem, *, confidence: np.ndarray,
                    hi=None, state=None, observed_local=None,
                    observed_es=None, t: int = 0, seed: int = 0,
                    n_arms: int = 9, local_model: int = 0) -> Solution:
        """Decide this period's assignments from ``confidence`` (B, n).

        ``hi`` is a `core.hi.HIModel` (default: `HIModel.make()`),
        ``state`` the incoming `HILearnerState` (default: fresh at the
        model's ``theta0``).  Passing BOTH ``observed_local`` and
        ``observed_es`` (B, n) bool outcome matrices advances the
        learner; without them the period is decide-only and the state is
        returned unchanged.  The updated state and the served threshold
        ride on the returned Solution as ``sol.hi_state`` /
        ``sol.hi_theta``."""
        import jax as _jax
        from jax.experimental import enable_x64

        from ..core.hi import (HILearnerState, HIModel, hi_period,
                               validate_hi)
        B, n = fleet.p_es.shape
        m = fleet.p_ed.shape[2]
        hm = hi if hi is not None else HIModel.make()
        # the host mirror receives confidences directly (it never samples
        # the calibration curves), so spread's class count is its own
        validate_hi(hm, n_devices=B,
                    n_classes=np.asarray(hm.spread).shape[0], n_models=m,
                    rule=self.rule, stream="fold", n_arms=n_arms,
                    local_model=local_model)
        conf = np.asarray(confidence, np.float64)
        if conf.shape != (B, n):
            raise ValueError(
                f"confidence must be ({B}, {n}) to match the fleet; got "
                f"{conf.shape}")
        hst = state if state is not None else HILearnerState.init(
            B, n_arms, hm.theta0)
        have_obs = observed_local is not None and observed_es is not None
        cl = (np.asarray(observed_local, bool) if have_obs
              else np.zeros((B, n), bool))
        ces = (np.asarray(observed_es, bool) if have_obs
               else np.zeros((B, n), bool))
        acc_es = np.asarray(fleet.acc, np.float64)[:, m]
        with enable_x64():
            key = _jax.random.fold_in(_jax.random.PRNGKey(seed),
                                      np.int32(t))
            offload, theta_t, new_hst, _reg = hi_period(
                self.rule, hm, hst, conf, cl, ces, fleet.real_mask,
                acc_es, np.int32(t), key, n_arms)
        offload = np.asarray(offload)
        # phantoms follow the fleet convention: free ES columns
        assignment = np.where(offload | ~fleet.real_mask, m, local_model
                              ).astype(np.int64)
        sol = Solution(problem=fleet, assignment=assignment,
                       status=np.full(B, _STATUS_CODE["ok"], np.int64),
                       solver=np.full(B, self.info.name, dtype=object))
        # decide-only calls keep the incoming state: the update above ran
        # on all-False placeholder outcomes and must not be persisted
        sol.hi_state = (_jax.tree.map(np.asarray, new_hst) if have_obs
                        else hst)
        sol.hi_theta = np.asarray(theta_t)
        return sol


@register_solver(
    "hi_threshold", batched=True, exact_on_identical=False,
    supports_es_disabled=False, online=True,
    description="online hierarchical inference: offload sample j iff "
                "conf_j < theta, theta learned in-stream by OGD "
                "(arXiv 2304.00891); engine twin: "
                "EngineParams.with_hi(rule='threshold')")
class HIThresholdSolver(_HISolverBase):
    rule = "threshold"


@register_solver(
    "hi_bandit", batched=True, exact_on_identical=False,
    supports_es_disabled=False, online=True,
    description="online hierarchical inference: UCB over discretized "
                "thresholds (rule='ucb'; EXP3 via rule='exp3'); engine "
                "twin: EngineParams.with_hi(rule='ucb')")
class HIBanditSolver(_HISolverBase):
    rule = "ucb"

    def solve_fleet(self, fleet: FleetProblem, *,
                    confidence: np.ndarray, rule: str = "ucb", hi=None,
                    state=None, observed_local=None, observed_es=None,
                    t: int = 0, seed: int = 0, n_arms: int = 9,
                    local_model: int = 0) -> Solution:
        if rule not in ("ucb", "exp3"):
            raise ValueError(f"hi_bandit rule must be 'ucb' or 'exp3'; "
                             f"got {rule!r}")
        self.rule = rule
        return super().solve_fleet(
            fleet, confidence=confidence, hi=hi, state=state,
            observed_local=observed_local, observed_es=observed_es, t=t,
            seed=seed, n_arms=n_arms, local_model=local_model)


@register_solver(
    "amdp", batched=True, exact_on_identical=True,
    supports_es_disabled=True,
    description="exact pseudo-polynomial DP for identical jobs (paper §VI)")
class AMDPSolver:
    def solve_one(self, problem: Problem, *, backend: str = "numpy",
                  resolution: float = 1e-3, impl: str = "jnp") -> Solution:
        del backend                       # DP runs the same on every backend
        sched = amdp(problem.to_instance(), resolution=resolution,
                          impl=impl)
        return Solution.from_schedule(sched, solver="amdp", problem=problem)

    def solve_fleet(self, fleet: FleetProblem, *, resolution: float = 1e-3,
                    impl: str = "jnp") -> Solution:
        B = len(fleet)
        batch = fleet.to_batch()
        scheds = amdp_batch([batch[b] for b in range(B)],
                                 resolution=resolution, impl=impl)
        assignment = np.stack([s.assignment for s in scheds]) if B else \
            np.zeros((0, fleet.n), dtype=np.int64)
        status = np.array([_STATUS_CODE[s.status] for s in scheds],
                          dtype=np.int64)
        return Solution(problem=fleet, assignment=assignment, status=status,
                        solver=np.full(B, "amdp", dtype=object))


@register_solver(
    "dual", batched=True, exact_on_identical=False,
    supports_es_disabled=True,
    description="beyond-paper Lagrangian-dual bisection + density-greedy "
                "knapsack (no 2T guarantee; ~1% gap, near-free)")
class DualSolver:
    def solve_one(self, problem: Problem, *, backend: str = "numpy",
                  iters: int = 40) -> Solution:
        del backend                       # scalar path is NumPy-only
        sched = dual_schedule(problem.to_instance(), iters=iters)
        return Solution.from_schedule(sched, solver="dual", problem=problem)

    def solve_fleet(self, fleet: FleetProblem, *, iters: int = 40
                    ) -> Solution:
        B = len(fleet)
        sub = fleet.take(_pow2_rows(B)).to_batch()
        assign, status = dual_schedule_batch_arrays(sub, iters=iters)
        return Solution(problem=fleet, assignment=assign[:B],
                        status=status[:B],
                        solver=np.full(B, "dual", dtype=object))


@register_solver(
    "greedy", batched=False, exact_on_identical=False,
    supports_es_disabled=True,
    description="Greedy-RRA baseline (paper §VII): O(n), may violate T")
class GreedySolver:
    def solve_one(self, problem: Problem, *, backend: str = "numpy"
                  ) -> Solution:
        del backend                       # sequential-only (batched=False)
        sched = greedy_rra(problem.to_instance())
        return Solution.from_schedule(sched, solver="greedy", problem=problem)


@register_solver(
    "lp", batched=True, exact_on_identical=False,
    supports_es_disabled=False, bound_only=True, warm_start=True,
    description="LP relaxation A*_LP upper bound; assignment is the argmax "
                "of a possibly fractional optimum")
class LPBoundSolver:
    """Bound-only entry: `accuracy`'s integral counterpart is bounded above
    by ``lp_accuracy``; the argmax assignment need not satisfy the budgets."""

    def solve_one(self, problem: Problem, *, backend: str = "numpy",
                  maxiter: Optional[int] = None,
                  warm_start: Optional[np.ndarray] = None,
                  on_error: str = "raise") -> Solution:
        xbar, a_lp, status, basis = solve_lp_relaxation(
            problem.to_instance(), backend=backend, maxiter=maxiter,
            warm_basis=warm_start)
        if status == INFEASIBLE:
            return Solution(problem=problem,
                            assignment=np.argmin(problem.p_ed, axis=1),
                            status=np.int64(_STATUS_CODE["infeasible"]),
                            solver="lp")
        if status != OPTIMAL:
            if on_error != "mark":
                raise RuntimeError(f"LP relaxation failed (status={status})")
            return Solution(
                problem=problem,
                assignment=np.argmax(xbar, axis=1).astype(np.int64),
                status=np.int64(ST_UNSOLVED), solver="lp")
        return Solution(problem=problem,
                        assignment=np.argmax(xbar, axis=1).astype(np.int64),
                        status=np.int64(ST_BOUND), solver="lp",
                        lp_accuracy=np.float64(a_lp),
                        basis=np.asarray(basis, np.int64))

    def solve_fleet(self, fleet: FleetProblem, *,
                    maxiter: Optional[int] = None,
                    warm_start: Optional[np.ndarray] = None,
                    impl: str = "jnp", method: str = "tableau",
                    on_error: str = "raise") -> Solution:
        B = len(fleet)
        rows = _pow2_rows(B)
        sub = fleet.take(rows).to_batch()
        c, A_ub, b_ub, A_eq, b_eq = build_lp_arrays_batch(sub)
        wb = None if warm_start is None else np.asarray(warm_start)[rows]
        res = solve_lp_batch(c, A_ub, b_ub, A_eq, b_eq, maxiter=maxiter,
                             warm_basis=wb, impl=impl, method=method)
        xbar = res.x.reshape(len(sub), fleet.n, fleet.m + 1)[:B]
        st = np.asarray(res.status)[:B]
        bad = (st != OPTIMAL) & (st != INFEASIBLE)
        if bad.any() and on_error != "mark":
            raise RuntimeError(
                f"LP relaxation failed (status={int(st[bad][0])})")
        assignment = np.argmax(xbar, axis=2).astype(np.int64)
        infeas = st == INFEASIBLE
        if infeas.any():
            assignment[infeas] = np.argmin(fleet.p_ed[infeas], axis=2)
        status = np.where(infeas, _STATUS_CODE["infeasible"],
                          ST_BOUND).astype(np.int64)
        status[bad] = ST_UNSOLVED
        lp_acc = np.asarray(-res.fun, dtype=np.float64)[:B].copy()
        lp_acc[infeas | bad] = np.nan
        return Solution(problem=fleet, assignment=assignment, status=status,
                        solver=np.full(B, "lp", dtype=object),
                        lp_accuracy=lp_acc,
                        basis=np.asarray(res.basis[:B], np.int64))
