from .manager import (save, restore, latest_step, rotate, AsyncCheckpointer)

__all__ = ["save", "restore", "latest_step", "rotate", "AsyncCheckpointer"]
