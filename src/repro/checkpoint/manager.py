"""Sharded, manifest-based checkpointing with atomic publish, an async
writer thread, and elastic (re-sharding) restore.

Layout:
    <dir>/step_000123.tmp/          # staged
        manifest.json               # tree structure, shapes, dtypes, meta
        leaf_00000.npy ...          # one file per pytree leaf
    <dir>/step_000123/              # atomic rename on completion

Fault tolerance:
  * writes stage into `.tmp` and `os.replace` to publish — a crash mid-write
    never corrupts the latest checkpoint (restore scans only published dirs);
  * `keep` rotation, `latest_step`, resume returns (tree, meta);
  * restore is *elastic*: leaves are saved unsharded (gathered), so a
    restart may use any mesh/topology — each host re-shards on load (the
    1000-node story: survivors re-balance after losing a pod);
  * `AsyncCheckpointer` overlaps serialization with the next train step and
    guarantees completion order.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _flatten_with_paths(tree: PyTree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(directory: str, step: int, tree: PyTree,
         meta: Optional[Dict[str, Any]] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:09d}"
    tmp = os.path.join(directory, name + ".tmp")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, treedef = _flatten_with_paths(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "meta": meta or {},
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fn = f"leaf_{i:05d}.npy"
        # exotic dtypes (bfloat16/fp8) don't survive np.save/astype: store
        # raw bytes and record the logical dtype in the manifest
        raw = arr.dtype.kind == "V" or str(arr.dtype) not in (
            "float64", "float32", "float16", "int64", "int32", "int16",
            "int8", "uint64", "uint32", "uint16", "uint8", "bool")
        np.save(os.path.join(tmp, fn),
                np.frombuffer(arr.tobytes(), np.uint8) if raw else arr)
        manifest["leaves"].append(
            {"file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
             "raw": bool(raw)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)              # atomic publish
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(directory, d, "manifest.json"))]
    return max(steps) if steps else None


def restore(directory: str, step: int, like: PyTree, *,
            shardings: Optional[PyTree] = None
            ) -> Tuple[PyTree, Dict[str, Any]]:
    """Load into the structure of `like`; if `shardings` is given, each
    leaf is placed with jax.device_put on its (possibly new) sharding —
    the elastic-restore path."""
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_like, treedef = _flatten_with_paths(like)
    if len(leaves_like) != manifest["n_leaves"]:
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, expected "
            f"{len(leaves_like)} — incompatible tree")
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves_like))
    out = []
    for i, (spec, shd) in enumerate(zip(manifest["leaves"], shard_leaves)):
        arr = np.load(os.path.join(path, spec["file"]))
        want = leaves_like[i]
        if spec.get("raw"):
            arr = np.frombuffer(
                arr.tobytes(),
                dtype=jax.numpy.dtype(spec["dtype"])).reshape(spec["shape"])
        if tuple(arr.shape) != tuple(want.shape):
            raise ValueError(
                f"leaf {i}: shape {arr.shape} != expected {want.shape}")
        if arr.dtype != want.dtype:
            arr = np.asarray(jax.numpy.asarray(arr).astype(want.dtype))
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["meta"]


def rotate(directory: str, keep: int = 3) -> None:
    if not os.path.isdir(directory):
        return
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"),
                      ignore_errors=True)


class AsyncCheckpointer:
    """Serialize checkpoints on a worker thread; `wait()` drains before
    exit/preemption.  Keeps at most one pending save (newer supersedes)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self.q: "queue.Queue" = queue.Queue(maxsize=1)
        self.errors: list = []
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while True:
            item = self.q.get()
            if item is None:
                return
            step, tree, meta = item
            try:
                save(self.directory, step, tree, meta)
                rotate(self.directory, self.keep)
            except Exception as e:  # noqa: BLE001 — surfaced via .errors
                self.errors.append(e)

    def submit(self, step: int, tree: PyTree,
               meta: Optional[Dict[str, Any]] = None):
        # device_get NOW so the trainer can donate/overwrite buffers
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        self.q.put((step, host_tree, meta))

    def wait(self):
        """Drain pending saves and stop the worker (call before exit or on
        a preemption signal)."""
        self.q.put(None)
        self._thread.join()
        if self.errors:
            raise self.errors[0]
