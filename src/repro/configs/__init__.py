"""Assigned architecture registry: `get_config(arch_id)` / `--arch <id>`.

Each module defines CONFIG (full size, dry-run only) and SMOKE (reduced,
same family, runs a CPU forward/train step in tests).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

ARCHS: List[str] = [
    "granite_moe_3b_a800m",
    "granite_moe_1b_a400m",
    "internlm2_20b",
    "deepseek_coder_33b",
    "h2o_danube_1_8b",
    "gemma3_1b",
    "internvl2_76b",
    "whisper_base",
    "recurrentgemma_9b",
    "mamba2_130m",
    "paper_edge",          # the paper's own MobileNet-ladder analogue
]


def canon(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(arch: str):
    mod = importlib.import_module(f"repro.configs.{canon(arch)}")
    return mod.CONFIG


def get_smoke_config(arch: str):
    mod = importlib.import_module(f"repro.configs.{canon(arch)}")
    return mod.SMOKE


def all_archs() -> List[str]:
    return [a for a in ARCHS if a != "paper_edge"]
