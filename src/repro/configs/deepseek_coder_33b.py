"""deepseek-coder-33b [dense]: 62L d7168 56H (GQA kv=8) d_ff=19200,
vocab 32256 — llama-arch. [arXiv:2401.14196]"""
import dataclasses
from repro.models import dense_lm

CONFIG = dense_lm("deepseek-coder-33b", layers=62, d_model=7168, heads=56,
                  kv_heads=8, d_ff=19200, vocab=32256)

SMOKE = dataclasses.replace(
    CONFIG, name="deepseek-coder-smoke", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
    attn_impl="dense")
