"""gemma3-1b [dense]: 26L d1152 4H (GQA kv=1, head_dim 256) d_ff=6912,
vocab 262144 — 5:1 local(512):global pattern, 128k-class context.
[hf:google/gemma-3-1b-pt]

26 = 4 cycles of (L,L,L,L,L,G) + 2 unrolled tail local layers."""
import dataclasses
from repro.models import ModelConfig

_PAT = (("local", "swiglu"),) * 5 + (("global", "swiglu"),)
CONFIG = ModelConfig(
    name="gemma3-1b", family="dense", num_layers=26, d_model=1152,
    num_heads=4, num_kv_heads=1, head_dim=256, d_ff=6912, vocab_size=262144,
    pattern=_PAT, local_window=512, rope_theta=10_000.0,
    rope_theta_global=1_000_000.0)

SMOKE = dataclasses.replace(
    CONFIG, name="gemma3-smoke", num_layers=14, d_model=64, num_heads=4,
    num_kv_heads=1, head_dim=16, d_ff=128, vocab_size=512, local_window=8,
    attn_impl="dense")
