"""granite-moe-1b-a400m [moe]: 24L d1024 16H (GQA kv=8) d_ff=512/expert,
vocab 49155, 32 experts top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base]"""
import dataclasses
from repro.models import moe_lm

CONFIG = moe_lm("granite-moe-1b-a400m", layers=24, d_model=1024, heads=16,
                kv_heads=8, d_ff_expert=512, vocab=49155, n_experts=32,
                top_k=8)

SMOKE = dataclasses.replace(
    CONFIG, name="granite-moe-1b-smoke", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, head_dim=16, vocab_size=256, num_experts=4,
    experts_per_token=2, moe_d_ff=32, attn_impl="dense")
