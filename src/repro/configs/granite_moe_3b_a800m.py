"""granite-moe-3b-a800m [moe]: 32L d1536 24H (GQA kv=8) d_ff=512/expert,
vocab 49155, 40 experts top-8. [hf:ibm-granite/granite-3.0-*-base; hf]"""
import dataclasses
from repro.models import moe_lm

CONFIG = moe_lm("granite-moe-3b-a800m", layers=32, d_model=1536, heads=24,
                kv_heads=8, d_ff_expert=512, vocab=49155, n_experts=40,
                top_k=8)

SMOKE = dataclasses.replace(
    CONFIG, name="granite-moe-3b-smoke", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, head_dim=16, vocab_size=256, num_experts=8,
    experts_per_token=2, moe_d_ff=32, attn_impl="dense")
