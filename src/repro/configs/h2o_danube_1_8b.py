"""h2o-danube-1.8b [dense]: 24L d2560 32H (GQA kv=8) d_ff=6912, vocab 32000
— llama+mistral mix, sliding-window attention (W=4096) on every layer.
[arXiv:2401.16818]"""
import dataclasses
from repro.models import dense_lm

CONFIG = dense_lm("h2o-danube-1.8b", layers=24, d_model=2560, heads=32,
                  kv_heads=8, d_ff=6912, vocab=32000, mixer="swa",
                  window_size=4096)

SMOKE = dataclasses.replace(
    CONFIG, name="h2o-danube-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256, window_size=8,
    attn_impl="dense")
