"""internlm2-20b [dense]: 48L d6144 48H (GQA kv=8) d_ff=16384,
vocab 92544. [arXiv:2403.17297]"""
import dataclasses
from repro.models import dense_lm

CONFIG = dense_lm("internlm2-20b", layers=48, d_model=6144, heads=48,
                  kv_heads=8, d_ff=16384, vocab=92544)

SMOKE = dataclasses.replace(
    CONFIG, name="internlm2-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256, attn_impl="dense")
