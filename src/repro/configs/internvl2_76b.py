"""internvl2-76b [vlm]: 80L d8192 64H (GQA kv=8) d_ff=28672, vocab 128256 —
InternViT + LLM backbone. The ViT frontend is a STUB per the assignment:
input_specs feeds 256 precomputed patch embeddings that replace the first
256 token positions. [arXiv:2404.16821]"""
import dataclasses
from repro.models import dense_lm

CONFIG = dataclasses.replace(
    dense_lm("internvl2-76b", layers=80, d_model=8192, heads=64, kv_heads=8,
             d_ff=28672, vocab=128256),
    num_patches=256)
# 80L x 32k x b128 GQA-8 cache is 5.4 GiB/chip in bf16 — an fp8 cache is the
# standard way a 76B serves this shape on one v5e pod (DESIGN.md).
CONFIG = dataclasses.replace(CONFIG, family="vlm",
                             kv_cache_dtype="float8_e4m3fn")

SMOKE = dataclasses.replace(
    CONFIG, name="internvl2-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256, num_patches=4,
    attn_impl="dense")
