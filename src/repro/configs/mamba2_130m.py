"""mamba2-130m [ssm]: 24L d768 attn-free, SSD (state-space duality),
d_state=128, expand=2 (d_inner 1536), headdim 64 -> 24 ssm heads,
vocab 50280. [arXiv:2405.21060]"""
import dataclasses
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm", num_layers=24, d_model=768,
    num_heads=1, num_kv_heads=1, head_dim=64, d_ff=0, vocab_size=50280,
    pattern=(("ssd", "none"),), ssm_state=128, ssm_heads=24,
    ssm_head_dim=64, ssm_expand=2, conv_width=4, ssm_chunk=256)

SMOKE = dataclasses.replace(
    CONFIG, name="mamba2-smoke", num_layers=2, d_model=64, vocab_size=256,
    ssm_state=16, ssm_heads=4, ssm_head_dim=32, ssm_chunk=8)
