"""The paper's own setting transplanted: a MobileNet-alpha-style ladder of
LM variants for the ED tier plus the full model for the ES tier.  Used by
examples/serve_offload.py and the serving tests."""
import dataclasses
from repro.models import dense_lm

# "ResNet50 on the server" analogue: the full model
CONFIG = dense_lm("paper-edge-es", layers=8, d_model=512, heads=8,
                  kv_heads=4, d_ff=1536, vocab=2048)

# "MobileNet alpha ladder" analogue: ED-tier variants
ED_VARIANTS = (
    CONFIG.scaled(0.25),
    CONFIG.scaled(0.5),
)

SMOKE = dataclasses.replace(
    CONFIG, name="paper-edge-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256, attn_impl="dense")
