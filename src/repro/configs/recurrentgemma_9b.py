"""recurrentgemma-9b [hybrid]: 38L d4096 16H (MQA kv=1, head_dim 256)
d_ff=12288, vocab 256000, lru_width 4096, local attention window 2048 —
pattern (RG-LRU, RG-LRU, local-attn), 38 = 12 cycles of 3 + 2 tail (R,R).
[arXiv:2402.19427]"""
import dataclasses
from repro.models import ModelConfig

_PAT = (("rglru", "swiglu"), ("rglru", "swiglu"), ("local", "swiglu"))
CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid", num_layers=38, d_model=4096,
    num_heads=16, num_kv_heads=1, head_dim=256, d_ff=12288,
    vocab_size=256000, pattern=_PAT, local_window=2048, lru_width=4096)

SMOKE = dataclasses.replace(
    CONFIG, name="recurrentgemma-smoke", num_layers=8, d_model=64,
    num_heads=4, num_kv_heads=1, head_dim=16, d_ff=128, vocab_size=256,
    local_window=8, lru_width=64, attn_impl="dense")
