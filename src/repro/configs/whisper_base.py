"""whisper-base [audio]: enc-dec, 6+6L d512 8H d_ff=2048, vocab 51865 —
conv frontend is a STUB: input_specs feeds 1500 precomputed frame
embeddings (B, 1500, 512); encoder layers are non-causal ("enc"), decoder
layers are causal self-attn + cross-attn ("dec"). GELU FFNs as in the
original; RoPE stands in for Whisper's learned positions (decoder side).
[arXiv:2212.04356]"""
import dataclasses
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio", num_layers=6, d_model=512,
    num_heads=8, num_kv_heads=8, head_dim=64, d_ff=2048, vocab_size=51865,
    pattern=(("dec", "gelu"),), encoder_layers=6, encoder_seq=1500)

SMOKE = dataclasses.replace(
    CONFIG, name="whisper-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256, encoder_layers=2,
    encoder_seq=16, attn_impl="dense")
