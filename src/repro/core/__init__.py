"""The paper's contribution: offloading/assignment algorithms for inference
jobs under a makespan budget (Fresa & Champati, 2021).

`Problem`/`FleetProblem`/`Solution` are the pytree-registered API-level
values consumed by `repro.api`; `OffloadInstance`/`InstanceBatch` are the
validated NumPy containers the solver implementations work on."""
from .types import OffloadInstance, InstanceBatch, Schedule
from .problem import (Problem, FleetProblem, Solution,
                      SOLUTION_STATUS_NAMES, ES_DISABLED_SENTINEL)
from .lp import (solve_lp, solve_lp_batch, LPResult, BatchLPResult,
                 OPTIMAL, INFEASIBLE, UNBOUNDED)
from .amr2 import (amr2, amr2_batch, amr2_batch_arrays, solve_lp_relaxation,
                   fractional_jobs, solve_sub_ilp, algorithm2_case_tree,
                   build_lp_arrays, build_lp_arrays_batch, round_relaxation,
                   round_relaxation_batch)
from .amdp import amdp, amdp_batch, amdp_hetero_comm, solve_cckp
from .greedy import greedy_rra
from .oracle import brute_force
from .instances import (paper_instance, random_instance, identical_instance,
                        PAPER_ACC, PAPER_P_ED, PAPER_P_ES_PROC, PAPER_COMM)

__all__ = [
    "OffloadInstance", "InstanceBatch", "Schedule",
    "Problem", "FleetProblem", "Solution",
    "SOLUTION_STATUS_NAMES", "ES_DISABLED_SENTINEL",
    "solve_lp", "solve_lp_batch", "LPResult", "BatchLPResult",
    "OPTIMAL", "INFEASIBLE", "UNBOUNDED",
    "amr2", "amr2_batch", "amr2_batch_arrays", "solve_lp_relaxation",
    "fractional_jobs", "solve_sub_ilp", "algorithm2_case_tree",
    "build_lp_arrays", "build_lp_arrays_batch", "round_relaxation",
    "round_relaxation_batch",
    "amdp", "amdp_batch", "amdp_hetero_comm", "solve_cckp", "greedy_rra",
    "brute_force",
    "paper_instance", "random_instance", "identical_instance",
    "PAPER_ACC", "PAPER_P_ED", "PAPER_P_ES_PROC", "PAPER_COMM",
]
from .dual import (dual_schedule, dual_schedule_batch,  # noqa: E402
                   dual_schedule_batch_arrays)  # beyond-paper fast scheduler
__all__ += ["dual_schedule", "dual_schedule_batch",
            "dual_schedule_batch_arrays"]
from .mobility import (MobilityModel, admit_mask_segmented,  # noqa: E402
                       admit_mask_cells_np, route_cells,
                       validate_mobility)  # multi-cell mobility (PR 8)
__all__ += ["MobilityModel", "admit_mask_segmented", "admit_mask_cells_np",
            "route_cells", "validate_mobility"]
