"""AMDP — Accuracy Maximization using Dynamic Programming (paper §VI).

For identical jobs (p_{ij} = p_i):
  Lemma 3 : an optimal schedule sends n_c = floor(T / p_{m+1}) jobs to the ES.
  Lemma 4 : the remaining n_l = n - n_c jobs reduce to a Cardinality-
            Constrained Knapsack (CCKP) over m "item groups" with n_l copies.
  Thm 3   : greedy ES fill + exact CCKP DP is optimal for P_I.

The DP runs per-model as a (max,+) convolution over the count q of jobs given
to that model, carried on a (T+1) x (n_l+1) value grid — a `lax.scan` over q
inside a Python loop over the m models (m is small; per-model shift offsets
stay static so the scan body is a fixed-shape elementwise kernel).  Per-model
argmax-count tables make backtracking O(m).

`kernels/cckp_dp` provides the TPU Pallas version of the same per-model scan
(the paper reimplements this DP in C for speed on the Pi; we do the TPU-native
equivalent); `impl="pallas"` routes through it.

Times are integerized at `resolution` seconds with ceil() so integer
feasibility implies real feasibility.
"""
from __future__ import annotations

import math
from functools import partial
from typing import List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .types import InstanceBatch, OffloadInstance, Schedule, next_pow2

NEG = -1e30  # -inf stand-in that survives float32 arithmetic


@partial(jax.jit, static_argnames=("p_i", "n_steps"))
def _model_dp(y: jnp.ndarray, p_i: int, a_i: float, n_steps: int):
    """One CCKP group: Y'[t, k] = max_q Y[t - q*p_i, k - q] + q*a_i.

    Returns (Y', bestq) with bestq the argmax count table for backtracking.
    """

    def step(carry, q):
        best, bestq, s = carry
        val = s + q.astype(s.dtype) * a_i
        take = val > best
        best = jnp.where(take, val, best)
        bestq = jnp.where(take, q.astype(jnp.int32), bestq)
        s2 = jnp.full_like(s, NEG)
        if p_i > 0:
            s2 = s2.at[p_i:, 1:].set(s[:-p_i, :-1])
        else:
            s2 = s2.at[:, 1:].set(s[:, :-1])
        return (best, bestq, s2), None

    init = (jnp.full_like(y, NEG), jnp.zeros(y.shape, jnp.int32), y)
    (best, bestq, _), _ = jax.lax.scan(step, init, jnp.arange(n_steps))
    return best, bestq


def _model_dp_dyn(y: jnp.ndarray, p_i: jnp.ndarray, a_i: jnp.ndarray,
                  n_steps: int):
    """`_model_dp` with a *traced* shift p_i, so it vmaps across devices.

    The static-offset `s.at[p_i:, 1:].set(...)` shift becomes a row gather
    with a validity mask — same values, but the shift amount is data, which
    is what lets one jitted trace serve every device in a batch regardless
    of its integerized processing times.
    """
    T1 = y.shape[0]
    src = jnp.arange(T1) - p_i                     # row t reads row t - p_i

    def step(carry, q):
        best, bestq, s = carry
        val = s + q.astype(s.dtype) * a_i
        take = val > best
        best = jnp.where(take, val, best)
        bestq = jnp.where(take, q.astype(jnp.int32), bestq)
        down = jnp.where((src >= 0)[:, None],
                         s[jnp.clip(src, 0, T1 - 1)], NEG)
        s2 = jnp.full_like(s, NEG).at[:, 1:].set(down[:, :-1])
        return (best, bestq, s2), None

    init = (jnp.full_like(y, NEG), jnp.zeros(y.shape, jnp.int32), y)
    (best, bestq, _), _ = jax.lax.scan(step, init, jnp.arange(n_steps))
    return best, bestq


@partial(jax.jit, static_argnames=("n_steps", "m"))
def _batch_dp_jnp(y0, p_int, acc, *, n_steps: int, m: int):
    """CCKP DP over a (B, T1, K1) grid batch: Python loop over the m models
    (static, small), one vmapped dynamic-shift scan per model."""
    y = y0
    tables = []
    for i in range(m):
        y, bestq = jax.vmap(
            partial(_model_dp_dyn, n_steps=n_steps)
        )(y, p_int[:, i], acc[:, i])
        tables.append(bestq)
    return y, jnp.stack(tables)


@partial(jax.jit, static_argnames=("n_steps", "p_static"))
def _batch_dp_pallas(y0, acc, *, n_steps: int, p_static: Tuple[int, ...]):
    """Pallas-kernel variant: shift offsets must be static on TPU, so the
    whole batch shares one integerized p vector (callers subgroup by it) and
    the kernel is vmapped over the (grid, accuracy) batch axes only."""
    from ..kernels.cckp_dp import ops as _cckp_ops
    y = y0
    tables = []
    for i, p in enumerate(p_static):
        y, bestq = jax.vmap(
            lambda y1, a1, p=p: _cckp_ops.model_dp(y1, p, a1, n_steps)
        )(y, acc[:, i])
        tables.append(bestq)
    return y, jnp.stack(tables)


def solve_cckp(p: np.ndarray, a: np.ndarray, T_int: int, n_l: int,
               impl: str = "jnp") -> Tuple[Optional[np.ndarray], float]:
    """Exact CCKP: choose counts q_i >= 0, sum q_i == n_l,
    sum q_i * p_i <= T_int, maximizing sum q_i * a_i.

    Returns (counts (m,), value) or (None, -inf) when infeasible.
    """
    m = len(p)
    y = np.full((T_int + 1, n_l + 1), NEG, dtype=np.float32)
    y[:, 0] = 0.0
    y = jnp.asarray(y)
    tables = []
    if impl == "pallas":
        from ..kernels.cckp_dp import ops as _cckp_ops
        model_dp = _cckp_ops.model_dp
    else:
        model_dp = _model_dp
    for i in range(m):
        y, bestq = model_dp(y, int(p[i]), float(a[i]), n_l + 1)
        tables.append(np.asarray(bestq))
    yf = np.asarray(y)
    if yf[T_int, n_l] <= NEG / 2:
        return None, -math.inf
    counts = np.zeros(m, dtype=np.int64)
    t, k = T_int, n_l
    for i in range(m - 1, -1, -1):
        q = int(tables[i][t, k])
        counts[i] = q
        t -= q * int(p[i])
        k -= q
    assert k == 0 and t >= 0, "CCKP backtrack inconsistent"
    return counts, float(yf[T_int, n_l])


def amdp(inst: OffloadInstance, *, resolution: float = 1e-3,
         impl: str = "jnp") -> Schedule:
    """Optimal schedule for identical jobs (problem P_I)."""
    if not inst.is_identical():
        raise ValueError("AMDP requires identical jobs; use amr2() instead")
    n, m, T = inst.n, inst.m, inst.T
    p_ed = inst.p_ed[0]              # (m,)
    p_es = float(inst.p_es[0])

    # Lemma 3: greedy ES fill.
    n_c = n if p_es <= 0 else min(n, int(math.floor(T / p_es + 1e-12)))
    n_l = n - n_c
    assignment = np.full(n, inst.m, dtype=np.int64)   # default: ES
    if n_l == 0:
        return Schedule(assignment=assignment, instance=inst,
                        solver="amdp", status="ok")

    p_int = np.maximum(np.ceil(p_ed / resolution - 1e-9).astype(np.int64), 0)
    T_int = int(math.floor(T / resolution + 1e-9))
    counts, _ = solve_cckp(p_int, inst.acc[:m], T_int, n_l, impl=impl)
    if counts is None:
        # P_I infeasible: best effort — everything local on the fastest model.
        fastest = int(np.argmin(p_ed))
        assignment[:n_l] = fastest
        return Schedule(assignment=assignment, instance=inst,
                        solver="amdp", status="infeasible")

    j = 0
    for i in range(m):
        assignment[j: j + counts[i]] = i
        j += counts[i]
    assert j == n_l
    return Schedule(assignment=assignment, instance=inst, solver="amdp",
                    status="ok")


# --------------------------------------------------------------------------
# Batched AMDP — one vmapped DP for a whole fleet of identical-job devices
# --------------------------------------------------------------------------
def _integerize(inst: OffloadInstance, resolution: float):
    p_ed = inst.p_ed[0]
    p_int = np.maximum(
        np.ceil(p_ed / resolution - 1e-9).astype(np.int64), 0)
    T_int = int(math.floor(inst.T / resolution + 1e-9))
    return p_int, T_int


def amdp_batch(instances: Union[InstanceBatch, Sequence[OffloadInstance]], *,
               resolution: float = 1e-3, impl: str = "jnp"
               ) -> List[Schedule]:
    """AMDP over a fleet of identical-job instances.

    Devices share one (T1, K1) integerized value grid (padded to the group
    maximum and bucketed to powers of two so fluctuating arrival counts
    reuse O(log) compiled programs) and the per-model CCKP scan runs as ONE
    vmapped `lax.scan` per model across the whole batch — `impl="jnp"` uses
    the traced-shift scan, `impl="pallas"` routes through the
    `kernels/cckp_dp` TPU kernel (static shifts, so devices are subgrouped
    by their integerized p vector).  The O(m) backtrack stays on the host.

    Grid padding is exact: the DP recurrence is local in (t, k), so values
    at a device's own (T_int, n_l) corner are unaffected by extra rows,
    columns, or scan steps, and the batched assignments match the scalar
    `amdp` bit-for-bit (see tests/test_batched_solvers.py).
    """
    if isinstance(instances, InstanceBatch):
        insts = [instances[b] for b in range(len(instances))]
    else:
        insts = list(instances)
    scheds: List[Optional[Schedule]] = [None] * len(insts)

    groups: dict = {}
    for idx, inst in enumerate(insts):
        if not inst.is_identical():
            raise ValueError(
                "amdp_batch requires identical jobs; use amr2_batch()")
        n, m, T = inst.n, inst.m, inst.T
        p_es = float(inst.p_es[0])
        n_c = n if p_es <= 0 else min(n, int(math.floor(T / p_es + 1e-12)))
        n_l = n - n_c
        if n_l == 0:                       # Lemma 3: everything fits the ES
            scheds[idx] = Schedule(
                assignment=np.full(n, m, dtype=np.int64), instance=inst,
                solver="amdp", status="ok")
            continue
        p_int, T_int = _integerize(inst, resolution)
        key = (m, tuple(int(p) for p in p_int)) if impl == "pallas" else (m,)
        groups.setdefault(key, []).append((idx, p_int, T_int, n_l))

    for key, items in groups.items():
        m = key[0]
        T1 = next_pow2(max(it[2] for it in items) + 1)
        K1 = next_pow2(max(it[3] for it in items) + 1)
        Bp = next_pow2(len(items))         # batch-axis bucket (trace reuse)
        rows = items + [items[-1]] * (Bp - len(items))
        y0 = np.full((T1, K1), NEG, dtype=np.float32)
        y0[:, 0] = 0.0
        y0 = np.broadcast_to(y0, (Bp, T1, K1))
        p_mat = np.stack([r[1] for r in rows]).astype(np.int32)
        acc_mat = np.stack(
            [insts[r[0]].acc[:m] for r in rows]).astype(np.float32)
        if impl == "pallas":
            yf, tables = _batch_dp_pallas(
                jnp.asarray(np.ascontiguousarray(y0)), jnp.asarray(acc_mat),
                n_steps=K1, p_static=key[1])
        else:
            yf, tables = _batch_dp_jnp(
                jnp.asarray(np.ascontiguousarray(y0)), jnp.asarray(p_mat),
                jnp.asarray(acc_mat), n_steps=K1, m=m)
        yf = np.asarray(yf)
        tables = np.asarray(tables)

        for row, (idx, p_int, T_int, n_l) in enumerate(items):
            inst = insts[idx]
            n, T = inst.n, inst.T
            assignment = np.full(n, m, dtype=np.int64)
            if yf[row, T_int, n_l] <= NEG / 2:          # P_I infeasible
                assignment[:n_l] = int(np.argmin(inst.p_ed[0]))
                scheds[idx] = Schedule(assignment=assignment, instance=inst,
                                       solver="amdp", status="infeasible")
                continue
            counts = np.zeros(m, dtype=np.int64)
            t, k = T_int, n_l
            for i in range(m - 1, -1, -1):
                q = int(tables[i, row, t, k])
                counts[i] = q
                t -= q * int(p_int[i])
                k -= q
            assert k == 0 and t >= 0, "CCKP backtrack inconsistent"
            j = 0
            for i in range(m):
                assignment[j: j + counts[i]] = i
                j += counts[i]
            scheds[idx] = Schedule(assignment=assignment, instance=inst,
                                   solver="amdp", status="ok")
    return scheds  # type: ignore[return-value]


def amdp_hetero_comm(p_ed_models: np.ndarray, p_es_proc: float,
                     comm: np.ndarray, acc: np.ndarray, T: float, *,
                     resolution: float = 1e-3) -> Schedule:
    """Paper §VI remark: identical processing times but per-job comm times.

    Offload in increasing order of c_j until the ES budget is exhausted
    (optimal because swap-arguments apply when processing is identical),
    then CCKP the remainder.
    """
    comm = np.asarray(comm, dtype=np.float64)
    n = len(comm)
    m = len(p_ed_models)
    order = np.argsort(comm, kind="stable")
    es_total = 0.0
    offload = []
    for j in order:
        t = comm[j] + p_es_proc
        if es_total + t <= T + 1e-12:
            offload.append(j)
            es_total += t
        else:
            break
    offload = set(offload)
    local = [j for j in range(n) if j not in offload]

    p_es_full = comm + p_es_proc
    inst = OffloadInstance(
        p_ed=np.tile(p_ed_models, (n, 1)), p_es=p_es_full, acc=acc, T=T)
    assignment = np.full(n, m, dtype=np.int64)
    if local:
        n_l = len(local)
        p_int = np.maximum(
            np.ceil(np.asarray(p_ed_models) / resolution - 1e-9), 0
        ).astype(np.int64)
        T_int = int(math.floor(T / resolution + 1e-9))
        counts, _ = solve_cckp(p_int, np.asarray(acc)[:m], T_int, n_l)
        if counts is None:
            assignment[local] = int(np.argmin(p_ed_models))
            return Schedule(assignment=assignment, instance=inst,
                            solver="amdp_hetero", status="infeasible")
        k = 0
        for i in range(m):
            for _ in range(counts[i]):
                assignment[local[k]] = i
                k += 1
    return Schedule(assignment=assignment, instance=inst,
                    solver="amdp_hetero", status="ok")
