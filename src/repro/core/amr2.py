"""AMR^2 — Accuracy Maximization using LP-Relaxation and Rounding (paper §IV).

Pipeline (Algorithm 1):
  1. Solve the LP relaxation of P with a *basic* solver (simplex, `lp.py`).
     Lemma 1: a basic optimal solution has at most two fractional jobs.
  2. Keep the integer part of the LP solution verbatim.
  3. Round the <=2 fractional jobs:
       * one fractional  -> argmax_{i in M} { a_i : p_{i,j} <= T }   (line 4)
       * two fractional  -> exact 2-job sub-ILP (Algorithm 2 / Lemma 2);
         we solve it by exhaustive (m+1)^2 enumeration, which *is* optimal
         for the sub-ILP (the paper's case tree computes the same optimum).

Guarantees (validated in tests/test_amr2.py):
  Thm 1:  makespan(x†) <= 2T        whenever P is feasible.
  Thm 2:  A* <= A† + 2(a_{m+1} - a_1).
  Cor 1:  A* <= A† + (a_{m+1} - a_1) when all p_{(m+1)j} <= T.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from .lp import INFEASIBLE, OPTIMAL, solve_lp, solve_lp_batch
from .types import InstanceBatch, OffloadInstance, Schedule

_FRAC_TOL = 1e-4


# --------------------------------------------------------------------------
# LP relaxation of P
# --------------------------------------------------------------------------
def _unsolved(status: int) -> bool:
    """Statuses that mean the LP solver did not finish (iteration limit,
    unbounded — anything that is neither a solution nor an infeasibility
    certificate)."""
    return status not in (OPTIMAL, INFEASIBLE)


def build_lp_arrays(inst: OffloadInstance):
    """Variables x[j, i] flattened j-major, i in 0..m (i == m is the ES)."""
    n, m = inst.n, inst.m
    mp1 = m + 1
    nv = n * mp1
    c = -np.tile(inst.acc, n)                      # maximize -> minimize -A

    A_ub = np.zeros((2, nv))
    for j in range(n):
        A_ub[0, j * mp1: j * mp1 + m] = inst.p_ed[j]   # constraint (1): ED budget
        A_ub[1, j * mp1 + m] = inst.p_es[j]            # constraint (2): ES budget
    b_ub = np.array([inst.T, inst.T])

    A_eq = np.zeros((n, nv))
    for j in range(n):
        A_eq[j, j * mp1: (j + 1) * mp1] = 1.0          # constraint (3)
    b_eq = np.ones(n)
    return c, A_ub, b_ub, A_eq, b_eq


def solve_lp_relaxation(inst: OffloadInstance, *, backend: str = "numpy",
                        maxiter: Optional[int] = None,
                        warm_basis: Optional[np.ndarray] = None):
    """Returns (xbar (n, m+1), A*_LP, status, basis).

    ``warm_basis`` (the basis returned by a previous call on a
    structurally identical instance) starts the simplex from that vertex;
    see `solve_lp`."""
    c, A_ub, b_ub, A_eq, b_eq = build_lp_arrays(inst)
    res = solve_lp(c, A_ub, b_ub, A_eq, b_eq, backend=backend,
                   maxiter=maxiter, warm_basis=warm_basis)
    xbar = res.x.reshape(inst.n, inst.m + 1)
    return xbar, -res.fun, res.status, res.basis


# --------------------------------------------------------------------------
# Fractional-job bookkeeping (Lemma 1)
# --------------------------------------------------------------------------
def fractional_jobs(xbar: np.ndarray, tol: float = _FRAC_TOL) -> np.ndarray:
    """Indices j whose row has any entry strictly inside (tol, 1-tol)."""
    frac = (xbar > tol) & (xbar < 1.0 - tol)
    return np.nonzero(frac.any(axis=1))[0]


# --------------------------------------------------------------------------
# sub-ILP (Algorithm 2) — exact enumeration over (m+1)^2 assignments
# --------------------------------------------------------------------------
def solve_sub_ilp(inst: OffloadInstance, j1: int, j2: int
                  ) -> Optional[Tuple[int, int]]:
    """Optimal assignment of two jobs under fresh budgets T on ED and ES.

    Returns (i1, i2) or None when even the 2-job problem is infeasible.
    Vectorised over the (m+1) x (m+1) assignment grid.
    """
    m, T = inst.m, inst.T
    mp1 = m + 1
    # time contributed to the ED budget by assigning job -> model i (0 if ES)
    ed1 = np.concatenate([inst.p_ed[j1], [0.0]])       # (m+1,)
    ed2 = np.concatenate([inst.p_ed[j2], [0.0]])
    es1 = np.concatenate([np.zeros(m), [inst.p_es[j1]]])
    es2 = np.concatenate([np.zeros(m), [inst.p_es[j2]]])

    ed_load = ed1[:, None] + ed2[None, :]              # (m+1, m+1)
    es_load = es1[:, None] + es2[None, :]
    feas = (ed_load <= T + 1e-12) & (es_load <= T + 1e-12)
    if not feas.any():
        return None
    val = inst.acc[:, None] + inst.acc[None, :]
    val = np.where(feas, val, -np.inf)
    flat = int(np.argmax(val))
    return flat // mp1, flat % mp1


def algorithm2_case_tree(inst: OffloadInstance, j1: int, j2: int
                         ) -> Optional[Tuple[int, int]]:
    """The paper's literal Algorithm 2 case analysis (for cross-validation).

    Line 13's "models on the ES" is a typo for "on the ED" — with both
    p_{(m+1)j} > T neither job fits the ES budget.
    """
    m, T = inst.m, inst.T

    def best_fit(j):  # argmax_{i in M} {a_i : p_{ij} <= T}; None if empty
        ok = [i for i in range(m) if inst.p_ed[j, i] <= T]
        if inst.p_es[j] <= T:
            ok.append(m)
        if not ok:
            return None
        return max(ok, key=lambda i: inst.acc[i])

    def best_fit_ed(j):
        ok = [i for i in range(m) if inst.p_ed[j, i] <= T]
        if not ok:
            return None
        return max(ok, key=lambda i: inst.acc[i])

    if inst.p_es[j1] <= T or inst.p_es[j2] <= T:           # line 2
        if inst.p_es[j1] + inst.p_es[j2] <= T:             # line 3
            return m, m
        b1, b2 = best_fit_ed(j1), best_fit_ed(j2)
        a1 = -np.inf if b1 is None else inst.acc[b1]
        a2 = -np.inf if b2 is None else inst.acc[b2]
        if a1 >= a2 and b1 is not None and inst.p_es[j2] <= T:  # line 6
            return b1, m
        if b2 is not None and inst.p_es[j1] <= T:               # line 9
            return m, b2
        # degenerate corners the paper's tree leaves implicit
        return solve_sub_ilp(inst, j1, j2)
    # line 12: both exceed the ES budget -> both on the ED (line 13)
    best = None
    for i1 in range(m):
        for i2 in range(m):
            if inst.p_ed[j1, i1] + inst.p_ed[j2, i2] <= T:
                v = inst.acc[i1] + inst.acc[i2]
                if best is None or v > best[0]:
                    best = (v, i1, i2)
    if best is None:
        return None
    return best[1], best[2]


# --------------------------------------------------------------------------
# AMR^2 (Algorithm 1)
# --------------------------------------------------------------------------
def amr2(inst: OffloadInstance, *, backend: str = "numpy",
         frac_tol: float = _FRAC_TOL, maxiter: Optional[int] = None,
         warm_basis: Optional[np.ndarray] = None,
         on_error: str = "raise") -> Schedule:
    xbar, a_lp, status, _ = solve_lp_relaxation(
        inst, backend=backend, maxiter=maxiter, warm_basis=warm_basis)
    return round_relaxation(inst, xbar, a_lp, status, frac_tol=frac_tol,
                            on_error=on_error)


def round_relaxation(inst: OffloadInstance, xbar: np.ndarray, a_lp: float,
                     status: int, *, frac_tol: float = _FRAC_TOL,
                     solver: str = "amr2",
                     on_error: str = "raise") -> Schedule:
    """Algorithm 1 lines 2-11: turn a basic LP-relaxation solution into an
    integral schedule.  Shared by the scalar and vmapped-batch AMR^2 paths.

    A non-converged LP (iteration limit / unbounded — a capped ``maxiter``)
    must never be rounded as if optimal: ``on_error="raise"`` (default)
    raises, ``on_error="mark"`` returns a best-effort schedule tagged
    ``status="unsolved"`` so callers (the `repro.api` front door) can
    surface it per their ``strict`` setting."""
    if status == INFEASIBLE:
        # P infeasible (its relaxation already is): best-effort everything on
        # the fastest ED model so the caller still gets a schedule object.
        assignment = np.argmin(inst.p_ed, axis=1)
        return Schedule(assignment=assignment, instance=inst,
                        lp_accuracy=None, n_fractional=0,
                        status="infeasible", solver=solver)
    if status != OPTIMAL:
        if on_error != "mark":
            raise RuntimeError(
                f"LP relaxation did not converge (status={status})")
        return Schedule(assignment=np.argmax(xbar, axis=1).astype(np.int64),
                        instance=inst, lp_accuracy=None, n_fractional=0,
                        status="unsolved", solver=solver)

    frac = fractional_jobs(xbar, frac_tol)
    assignment = np.argmax(xbar, axis=1).astype(np.int64)
    sched_status = "ok"

    if len(frac) > 2:
        # Lemma 1 guarantees <=2 for an exact basic optimum; numerically we
        # keep the two most fractional rows and integer-round the rest.
        fractionality = 1.0 - xbar[frac].max(axis=1)
        order = frac[np.argsort(-fractionality)]
        frac = np.sort(order[:2])
        sched_status = "fallback"

    if len(frac) == 1:
        j = int(frac[0])
        i = _best_fit_any(inst, j)
        if i is None:                       # P was integrally infeasible
            i = int(np.argmin(inst.p_ed[j]))
            sched_status = "fallback"
        assignment[j] = i
    elif len(frac) == 2:
        j1, j2 = int(frac[0]), int(frac[1])
        pair = solve_sub_ilp(inst, j1, j2)
        if pair is None:                    # P was integrally infeasible
            pair = (int(np.argmin(inst.p_ed[j1])),
                    int(np.argmin(inst.p_ed[j2])))
            sched_status = "fallback"
        assignment[j1], assignment[j2] = pair

    return Schedule(assignment=assignment, instance=inst, lp_accuracy=a_lp,
                    n_fractional=int(len(frac)), status=sched_status,
                    solver=solver)


# --------------------------------------------------------------------------
# Batched AMR^2 — one vmapped LP solve for a whole fleet
# --------------------------------------------------------------------------
def build_lp_arrays_batch(batch: InstanceBatch):
    """Batched `build_lp_arrays`: (B, ...) arrays sharing the (n, m) shape."""
    B, n, m = batch.p_ed.shape
    mp1 = m + 1
    nv = n * mp1
    c = -np.tile(batch.acc, (1, n))                      # (B, nv)

    ed_rows = np.zeros((B, n, mp1))
    ed_rows[:, :, :m] = batch.p_ed                       # constraint (1)
    es_rows = np.zeros((B, n, mp1))
    es_rows[:, :, m] = batch.p_es                        # constraint (2)
    A_ub = np.stack([ed_rows.reshape(B, nv), es_rows.reshape(B, nv)], axis=1)
    b_ub = np.stack([batch.T, batch.T], axis=1)

    A_eq = np.broadcast_to(np.kron(np.eye(n), np.ones(mp1)), (B, n, nv))
    b_eq = np.ones((B, n))                               # constraint (3)
    return c, A_ub, b_ub, A_eq, b_eq


# status codes shared by the vectorized rounding and the fleet arrays path;
# the numbering matches `problem.SOLUTION_STATUS_NAMES` (3 is the api-level
# "bound" pseudo-status, never produced here)
ST_OK, ST_FALLBACK, ST_INFEASIBLE = 0, 1, 2
ST_UNSOLVED = 4
STATUS_NAMES = ("ok", "fallback", "infeasible", "bound", "unsolved")


def round_relaxation_batch(batch: InstanceBatch, xbar: np.ndarray,
                           status: np.ndarray, *,
                           frac_tol: float = _FRAC_TOL,
                           on_error: str = "raise"):
    """Vectorized `round_relaxation` across a whole batch.

    Algorithm 1's rounding cases run as array ops over the devices that hit
    them — one-fractional best-fit and the two-job sub-ILP enumeration both
    vectorize; only the rare numeric >2-fractional fallback drops to the
    scalar path.  Tie-breaks (first-max argmax everywhere) are identical to
    the scalar code, so assignments match it exactly.

    Returns ``(assignment (B, n) int64, sched_status (B,) int with
    ST_OK/ST_FALLBACK/ST_INFEASIBLE, n_fractional (B,) int)``.
    """
    B, n, mp1 = xbar.shape
    m = mp1 - 1
    status = np.asarray(status)
    bad = (status != OPTIMAL) & (status != INFEASIBLE)
    if bad.any() and on_error != "mark":
        raise RuntimeError(
            f"LP relaxation did not converge (status={int(status[bad][0])})")

    assignment = np.argmax(xbar, axis=2).astype(np.int64)
    sched_status = np.zeros(B, dtype=np.int64)
    n_frac = np.zeros(B, dtype=np.int64)
    sched_status[bad] = ST_UNSOLVED     # best-effort argmax, never rounded

    infeas = status == INFEASIBLE
    if infeas.any():
        assignment[infeas] = np.argmin(batch.p_ed[infeas], axis=2)
        sched_status[infeas] = ST_INFEASIBLE

    ok = ~infeas & ~bad
    frac_rows = (((xbar > frac_tol) & (xbar < 1.0 - frac_tol)).any(axis=2)
                 & ok[:, None])
    fc = frac_rows.sum(axis=1)
    n_frac[ok] = np.minimum(fc[ok], 2)

    many = ok & (fc > 2)              # numeric fallback: scalar path, rare
    for b in np.nonzero(many)[0]:
        sched = round_relaxation(batch[b], xbar[b], 0.0, OPTIMAL,
                                 frac_tol=frac_tol)
        assignment[b] = sched.assignment
        sched_status[b] = STATUS_NAMES.index(sched.status)
        n_frac[b] = sched.n_fractional

    one = ok & (fc == 1)              # Algorithm 1 line 4, vectorized
    if one.any():
        bs = np.nonzero(one)[0]
        js = np.argmax(frac_rows[bs], axis=1)
        Tb = batch.T[bs]
        feas = np.concatenate(
            [batch.p_ed[bs, js] <= Tb[:, None],
             (batch.p_es[bs, js] <= Tb)[:, None]], axis=1)   # (k, m+1)
        val = np.where(feas, batch.acc[bs], -np.inf)
        pick = np.argmax(val, axis=1)
        none = ~feas.any(axis=1)      # P integrally infeasible
        if none.any():
            pick[none] = np.argmin(batch.p_ed[bs[none], js[none]], axis=1)
            sched_status[bs[none]] = ST_FALLBACK
        assignment[bs, js] = pick

    two = ok & (fc == 2)              # Algorithm 2, vectorized enumeration
    if two.any():
        bs = np.nonzero(two)[0]
        k = len(bs)
        j1 = np.argmax(frac_rows[bs], axis=1)
        masked = frac_rows[bs].copy()
        masked[np.arange(k), j1] = False
        j2 = np.argmax(masked, axis=1)
        Tb = batch.T[bs]
        zed = np.zeros((k, 1))
        zes = np.zeros((k, m))
        ed1 = np.concatenate([batch.p_ed[bs, j1], zed], axis=1)  # (k, m+1)
        ed2 = np.concatenate([batch.p_ed[bs, j2], zed], axis=1)
        es1 = np.concatenate([zes, batch.p_es[bs, j1][:, None]], axis=1)
        es2 = np.concatenate([zes, batch.p_es[bs, j2][:, None]], axis=1)
        ed_load = ed1[:, :, None] + ed2[:, None, :]              # (k,m+1,m+1)
        es_load = es1[:, :, None] + es2[:, None, :]
        feas = ((ed_load <= Tb[:, None, None] + 1e-12)
                & (es_load <= Tb[:, None, None] + 1e-12))
        val = batch.acc[bs][:, :, None] + batch.acc[bs][:, None, :]
        val = np.where(feas, val, -np.inf)
        flat = np.argmax(val.reshape(k, -1), axis=1)
        i1, i2 = flat // mp1, flat % mp1
        none = ~feas.any(axis=(1, 2))
        if none.any():
            i1[none] = np.argmin(batch.p_ed[bs[none], j1[none]], axis=1)
            i2[none] = np.argmin(batch.p_ed[bs[none], j2[none]], axis=1)
            sched_status[bs[none]] = ST_FALLBACK
        assignment[bs, j1] = i1
        assignment[bs, j2] = i2

    return assignment, sched_status, n_frac


def amr2_batch_arrays(batch: InstanceBatch, *, frac_tol: float = _FRAC_TOL,
                      maxiter: Optional[int] = None,
                      warm_basis: Optional[np.ndarray] = None,
                      impl: str = "jnp", on_error: str = "raise"):
    """Array-level batched AMR^2 for the fleet hot path: ONE vmapped LP
    solve + vectorized rounding, no per-device Schedule objects.

    ``warm_basis`` (B, R) feeds the revised-simplex warm start — the basis
    each device's LP ended on last period (`solve_lp_batch`); rows of -1
    force a cold solve for that device.  ``impl="pallas"`` runs the warm
    pivots through the `kernels/simplex_pivot` kernel.

    Returns ``(assignment (B, n), sched_status (B,), n_fractional (B,),
    lp_accuracy (B,), basis (B, R))``."""
    c, A_ub, b_ub, A_eq, b_eq = build_lp_arrays_batch(batch)
    res = solve_lp_batch(c, A_ub, b_ub, A_eq, b_eq, maxiter=maxiter,
                         warm_basis=warm_basis, impl=impl)
    B, n = batch.p_es.shape
    xbar = res.x.reshape(B, n, batch.m + 1)
    assignment, sched_status, n_frac = round_relaxation_batch(
        batch, xbar, res.status, frac_tol=frac_tol, on_error=on_error)
    return assignment, sched_status, n_frac, -res.fun, res.basis


def build_lp_arrays_jnp(p_ed, p_es, acc, T):
    """Traceable `build_lp_arrays_batch` + `_canonicalize_batch` in one:
    canonicalised ``(A (B, R, C0), b (B, R), c_full (B, C0))`` with
    R = n + 2 rows (ED budget, ES budget, n assignment rows) and
    C0 = n(m+1) + 2 columns (variables + 2 slack).  ``b`` is already
    nonnegative (T > 0, assignment rhs = 1), so no row flips are needed —
    the output feeds `lp.simplex_batch_core` directly inside jit/scan."""
    import jax.numpy as jnp
    B, n, m = p_ed.shape
    mp1 = m + 1
    nv = n * mp1
    dtype = p_ed.dtype
    ed = jnp.zeros((B, n, mp1), dtype).at[:, :, :m].set(p_ed)
    es = jnp.zeros((B, n, mp1), dtype).at[:, :, m].set(p_es)
    eq = jnp.broadcast_to(
        jnp.asarray(np.kron(np.eye(n), np.ones(mp1)), dtype), (B, n, nv))
    slack = jnp.broadcast_to(
        jnp.asarray(np.concatenate([np.eye(2), np.zeros((n, 2))]), dtype),
        (B, n + 2, 2))
    A = jnp.concatenate([
        jnp.stack([ed.reshape(B, nv), es.reshape(B, nv)], axis=1),
        eq], axis=1)
    A = jnp.concatenate([A, slack], axis=2)
    Tb = jnp.broadcast_to(jnp.asarray(T, dtype).reshape(-1, 1), (B, 1))
    b = jnp.concatenate([Tb, Tb, jnp.ones((B, n), dtype)], axis=1)
    c_full = jnp.concatenate(
        [-jnp.tile(acc, (1, n)), jnp.zeros((B, 2), dtype)], axis=1)
    return A, b, c_full


def round_relaxation_jnp(p_ed, p_es, acc, T, xbar, status, *,
                         frac_tol: float = _FRAC_TOL):
    """Traceable `round_relaxation_batch`: Algorithm 1's rounding as pure
    jnp, usable inside `jax.jit` / `lax.scan` (the `repro.api.engine`
    period step).  Semantics match the NumPy batched path case for case —
    first-max argmaxes, the one-fractional best-fit, the two-job sub-ILP
    enumeration, and the infeasible / non-converged markings — except the
    rare >2-fractional numeric fallback, where the two most fractional
    rows are picked by a STABLE descending sort (NumPy's introsort leaves
    equal-fractionality ties unspecified; on real float data ties are
    measure-zero).

    Returns ``(assignment (B, n) int64-compatible ints, sched_status (B,),
    n_fractional (B,))``.
    """
    import jax.numpy as jnp
    B, n, mp1 = xbar.shape
    m = mp1 - 1
    status = jnp.asarray(status)
    bad = (status != OPTIMAL) & (status != INFEASIBLE)
    infeas = status == INFEASIBLE
    ok = ~infeas & ~bad

    assignment = jnp.argmax(xbar, axis=2).astype(jnp.int32)
    assignment = jnp.where(infeas[:, None],
                           jnp.argmin(p_ed, axis=2).astype(jnp.int32),
                           assignment)
    sched_status = jnp.where(bad, ST_UNSOLVED,
                             jnp.where(infeas, ST_INFEASIBLE, ST_OK)
                             ).astype(jnp.int32)

    frac_rows = (((xbar > frac_tol) & (xbar < 1.0 - frac_tol)).any(axis=2)
                 & ok[:, None])
    fc = frac_rows.sum(axis=1)
    n_frac = jnp.where(ok, jnp.minimum(fc, 2), 0).astype(jnp.int32)

    # candidate job pair: first two fractional rows (fc <= 2) or the two
    # most fractional rows (fc > 2, the scalar fallback's selection)
    j1_first = jnp.argmax(frac_rows, axis=1)
    masked = frac_rows.at[jnp.arange(B), j1_first].set(False)
    j2_first = jnp.argmax(masked, axis=1)
    fractionality = jnp.where(frac_rows, 1.0 - xbar.max(axis=2), -jnp.inf)
    top = jnp.argsort(-fractionality, axis=1)[:, :2]
    j1_many = jnp.min(top, axis=1)
    j2_many = jnp.max(top, axis=1)
    many = ok & (fc > 2)
    j1 = jnp.where(many, j1_many, j1_first)
    j2 = jnp.where(many, j2_many, j2_first)
    sched_status = jnp.where(many, ST_FALLBACK, sched_status)

    rows = jnp.arange(B)
    Tb = jnp.broadcast_to(jnp.asarray(T, xbar.dtype).reshape(-1), (B,))

    # ---- one fractional job: best-fit (Algorithm 1 line 4) -------------
    one = ok & (fc == 1)
    feas1 = jnp.concatenate(
        [p_ed[rows, j1] <= Tb[:, None],
         (p_es[rows, j1] <= Tb)[:, None]], axis=1)          # (B, m+1)
    val1 = jnp.where(feas1, acc, -jnp.inf)
    pick1 = jnp.argmax(val1, axis=1)
    none1 = ~feas1.any(axis=1)
    pick1 = jnp.where(none1, jnp.argmin(p_ed[rows, j1], axis=1), pick1)
    sched_status = jnp.where(one & none1, ST_FALLBACK, sched_status)
    assignment = jnp.where(
        (one[:, None]) & (jnp.arange(n)[None, :] == j1[:, None]),
        pick1[:, None].astype(jnp.int32), assignment)

    # ---- two (or >2, truncated) fractional jobs: sub-ILP ---------------
    two = ok & (fc >= 2)
    zed = jnp.zeros((B, 1), xbar.dtype)
    zes = jnp.zeros((B, m), xbar.dtype)
    ed1 = jnp.concatenate([p_ed[rows, j1], zed], axis=1)    # (B, m+1)
    ed2 = jnp.concatenate([p_ed[rows, j2], zed], axis=1)
    es1 = jnp.concatenate([zes, p_es[rows, j1][:, None]], axis=1)
    es2 = jnp.concatenate([zes, p_es[rows, j2][:, None]], axis=1)
    ed_load = ed1[:, :, None] + ed2[:, None, :]
    es_load = es1[:, :, None] + es2[:, None, :]
    feas2 = ((ed_load <= Tb[:, None, None] + 1e-12)
             & (es_load <= Tb[:, None, None] + 1e-12))
    val2 = acc[:, :, None] + acc[:, None, :]
    val2 = jnp.where(feas2, val2, -jnp.inf)
    flat = jnp.argmax(val2.reshape(B, -1), axis=1)
    i1, i2 = flat // mp1, flat % mp1
    none2 = ~feas2.reshape(B, -1).any(axis=1)
    i1 = jnp.where(none2, jnp.argmin(p_ed[rows, j1], axis=1), i1)
    i2 = jnp.where(none2, jnp.argmin(p_ed[rows, j2], axis=1), i2)
    sched_status = jnp.where(two & none2, ST_FALLBACK, sched_status)
    cols = jnp.arange(n)[None, :]
    assignment = jnp.where(two[:, None] & (cols == j1[:, None]),
                           i1[:, None].astype(jnp.int32), assignment)
    assignment = jnp.where(two[:, None] & (cols == j2[:, None]),
                           i2[:, None].astype(jnp.int32), assignment)
    return assignment, sched_status, n_frac


def soft_assignment_weights(xbar, *, tau: float = 0.25):
    """Smoothed twin of Algorithm 2's rounding: temperature-sharpened
    assignment weights ``w (B, n, m+1)`` from the LP relaxation ``xbar``.

    ``softmax(log(clip(xbar)) / tau)`` — at ``tau=1`` this is exactly
    ``xbar`` renormalized (softmax of a log is the identity on the
    simplex); as ``tau -> 0`` it hardens to the same argmax the hard
    rounding takes on integral rows.  Rows the LP left fractional (<= 2
    per lane, Lemma 1) keep mass on both candidates, which is what makes
    the relaxation differentiable where `round_relaxation_jnp`'s case
    tree is piecewise constant.  Gradients flow w.r.t. ``xbar`` only —
    the clip floor (1e-12) zeroes them where the LP put exactly no mass."""
    import jax
    import jax.numpy as jnp
    lx = jnp.log(jnp.clip(xbar, 1e-12, 1.0))
    return jax.nn.softmax(lx / tau, axis=2)


def straight_through_weights(xbar, assignment, *, tau: float = 0.25):
    """Straight-through twin: FORWARD is the exact one-hot of the hard
    Algorithm-2 ``assignment`` (including its sub-ILP fix-ups), BACKWARD
    is `soft_assignment_weights`' Jacobian — the classic ST estimator, so
    a differentiable rollout can keep the served accuracy numbers of the
    hard path while still producing a usable gradient signal."""
    import jax
    import jax.numpy as jnp
    soft = soft_assignment_weights(xbar, tau=tau)
    hard = jax.nn.one_hot(assignment, xbar.shape[2], dtype=xbar.dtype)
    return soft + jax.lax.stop_gradient(hard - soft)


def amr2_batch(batch: InstanceBatch, *,
               frac_tol: float = _FRAC_TOL) -> "list[Schedule]":
    """AMR^2 over a fleet of B same-shape instances.

    The expensive step — the basic LP-relaxation solve — runs as ONE jitted
    `vmap` over the batch (float64, so it matches the per-instance NumPy
    oracle to rounding-identical assignments); the rounding of at most two
    fractional jobs per instance is vectorized across the batch
    (`round_relaxation_batch`)."""
    assignment, sched_status, n_frac, lp_acc, _ = amr2_batch_arrays(
        batch, frac_tol=frac_tol)
    return [Schedule(assignment=assignment[b], instance=batch[b],
                     lp_accuracy=(None if sched_status[b] in
                                  (ST_INFEASIBLE, ST_UNSOLVED)
                                  else float(lp_acc[b])),
                     n_fractional=int(n_frac[b]),
                     status=STATUS_NAMES[sched_status[b]], solver="amr2")
            for b in range(len(batch))]


def _best_fit_any(inst: OffloadInstance, j: int) -> Optional[int]:
    """argmax_{i in M} { a_i : p_{ij} <= T } (Algorithm 1, line 4)."""
    ok = [i for i in range(inst.m) if inst.p_ed[j, i] <= inst.T]
    if inst.p_es[j] <= inst.T:
        ok.append(inst.m)
    if not ok:
        return None
    return int(max(ok, key=lambda i: inst.acc[i]))
