"""Beyond-paper: Lagrangian-dual fast scheduler.

AMR^2 costs O(n^3 (m+1)^3) via the LP; at serving-time scales (n ~ 10^3+
requests per plan period) the planner itself becomes the bottleneck the
paper reports (50 ms at n = 40 on the Pi).  This fast path exploits the
problem's two-knapsack structure directly:

  1. Dualize ONLY the ED budget with multiplier lam >= 0: each job's ED
     choice is argmax_i (a_i - lam * p_ij) — vectorized over (n, m).
  2. Given those ED fallbacks, the ES side is a 0/1 knapsack in the gains
     g_j = a_{m+1} - a_{i*(j)} with weights p_es_j and capacity T — solved
     by density-greedy (the classic 1/2-approx; near-exact here because
     items are tiny vs T).
  3. Bisect lam (log-scale, ~40 evals) to the smallest multiplier whose
     induced assignment meets the ED budget.

O(iters * n (m + log n)) total.  No worst-case 2T guarantee is claimed
(that's AMR^2's job); benchmarks/table_runtime.py measures the accuracy gap
vs AMR^2 (≈1% on paper-like instances) and the speedup (>100x at n=1024).
"""
from __future__ import annotations

from functools import partial
from typing import List, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from .types import InstanceBatch, OffloadInstance, Schedule


def _recover(inst: OffloadInstance, lam: float) -> np.ndarray:
    n, m, T = inst.n, inst.m, inst.T
    a = inst.acc
    score = a[None, :-1] - lam * inst.p_ed          # (n, m)
    ed_choice = np.argmax(score, axis=1)
    gain = a[-1] - a[ed_choice]                     # accuracy gain if offloaded
    density = gain / np.maximum(inst.p_es, 1e-12)
    order = np.argsort(-density, kind="stable")
    cum = np.cumsum(inst.p_es[order])
    take = order[(cum <= T + 1e-12)]
    # offloading a negative-gain job never helps accuracy, but it can
    # relieve the ED budget; the bisection prefers raising lam instead, so
    # only keep non-negative gains here.
    take = take[gain[take] >= 0]
    assign = ed_choice.copy()
    assign[take] = m
    return assign


def _ed_load(inst: OffloadInstance, assign: np.ndarray) -> float:
    on_ed = assign < inst.m
    if not on_ed.any():
        return 0.0
    j = np.nonzero(on_ed)[0]
    return float(inst.p_ed[j, assign[j]].sum())


def dual_schedule(inst: OffloadInstance, *, iters: int = 40) -> Schedule:
    T = inst.T
    # lam = 0: unconstrained ED choice (max accuracy). If feasible, done.
    assign = _recover(inst, 0.0)
    if _ed_load(inst, assign) <= T + 1e-12:
        return Schedule(assignment=assign, instance=inst, solver="dual",
                        status="ok")
    # log-scale bisection for the smallest feasible multiplier
    lo, hi = 0.0, float(inst.acc[-1] / max(np.min(inst.p_ed), 1e-9))
    best = None
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        cand = _recover(inst, mid)
        if _ed_load(inst, cand) <= T + 1e-12:
            best, hi = cand, mid
        else:
            lo = mid
    if best is None:
        # even the harshest multiplier failed (tiny T): everything on the
        # fastest models, best-effort like the paper's infeasible case
        cand = np.argmin(inst.p_ed, axis=1)
        return Schedule(assignment=cand, instance=inst, solver="dual",
                        status="fallback")
    return Schedule(assignment=best, instance=inst, solver="dual",
                    status="ok")


# --------------------------------------------------------------------------
# Batched jitted dual — one vmapped bisection for a whole fleet
# --------------------------------------------------------------------------
def _recover_jnp(p_ed, p_es, acc, T, lam):
    """jnp port of `_recover`, semantics-identical (first-max argmax, stable
    descending density order, prefix-sum knapsack fill, non-negative gains).

    The stable sort + cumsum + un-permute of the NumPy version is replaced
    by an O(n^2) pairwise-rank prefix sum: job j's inclusive prefix load is
    the p_es total over jobs at-or-before it in the stable descending
    density order — the same take/skip decisions without any sort, which is
    dramatically cheaper than a vmapped per-iteration argsort (n is the
    planning window, tens of jobs).  One caveat: the prefix loads are
    summed in matmul association order rather than cumsum order, so a
    take/skip decision could differ from the NumPy path only when a prefix
    load lands within float64 rounding of the knapsack boundary
    `T + 1e-12` — measure-zero on real latency data."""
    m = p_ed.shape[1]
    n = p_es.shape[0]
    score = acc[None, :-1] - lam * p_ed
    ed_choice = jnp.argmax(score, axis=1)
    gain = acc[-1] - acc[ed_choice]
    density = gain / jnp.maximum(p_es, 1e-12)
    idx = jnp.arange(n)
    # before[j, j'] = job j' sits at-or-before job j in the stable
    # descending-density order (ties broken by original index, as
    # np.argsort(kind="stable") does)
    before = ((density[None, :] > density[:, None])
              | ((density[None, :] == density[:, None])
                 & (idx[None, :] <= idx[:, None])))
    cum = before @ p_es                             # inclusive prefix load
    keep = (cum <= T + 1e-12) & (gain >= 0)
    return jnp.where(keep, m, ed_choice)


def _ed_load_jnp(p_ed, assign):
    m = p_ed.shape[1]
    picked = jnp.take_along_axis(
        p_ed, jnp.clip(assign, 0, m - 1)[:, None], axis=1)[:, 0]
    return jnp.sum(jnp.where(assign < m, picked, 0.0))


def _dual_one(p_ed, p_es, acc, T, iters: int):
    assign0 = _recover_jnp(p_ed, p_es, acc, T, jnp.zeros((), p_ed.dtype))
    feas0 = _ed_load_jnp(p_ed, assign0) <= T + 1e-12
    hi0 = acc[-1] / jnp.maximum(jnp.min(p_ed), 1e-9)

    def body(_, carry):
        lo, hi, best, has_best = carry
        mid = 0.5 * (lo + hi)
        cand = _recover_jnp(p_ed, p_es, acc, T, mid)
        feas = _ed_load_jnp(p_ed, cand) <= T + 1e-12
        best = jnp.where(feas, cand, best)
        lo = jnp.where(feas, lo, mid)
        hi = jnp.where(feas, mid, hi)
        return lo, hi, best, has_best | feas

    _, _, best, has_best = jax.lax.fori_loop(
        0, iters, body,
        (jnp.zeros_like(hi0), hi0, assign0, jnp.asarray(False)))
    fallback = jnp.argmin(p_ed, axis=1)
    assign = jnp.where(feas0, assign0,
                       jnp.where(has_best, best, fallback))
    status = jnp.where(feas0 | has_best, 0, 1)   # 0 ok, 1 fallback
    return assign, status


@partial(jax.jit, static_argnames=("iters",))
def _dual_batch_jit(p_ed, p_es, acc, T, *, iters: int):
    return jax.vmap(partial(_dual_one, iters=iters))(p_ed, p_es, acc, T)


def dual_schedule_batch_arrays(batch: InstanceBatch, *, iters: int = 40):
    """Raw-array batched dual: (assignment (B, n) int64, status (B,) int64
    with 0 = ok / 1 = fallback).  ONE jitted vmap call; runs in float64 (a
    local `enable_x64` scope, mirroring `solve_lp_batch`) so the bisection
    follows the NumPy `dual_schedule` oracle exactly away from knapsack
    boundaries (see `_recover_jnp` on the summation-order caveat); parity
    tests assert identical assignments on random instances."""
    from jax.experimental import enable_x64
    with enable_x64():
        assign, status = jax.tree_util.tree_map(
            np.asarray,
            _dual_batch_jit(jnp.asarray(batch.p_ed, jnp.float64),
                            jnp.asarray(batch.p_es, jnp.float64),
                            jnp.asarray(batch.acc, jnp.float64),
                            jnp.asarray(batch.T, jnp.float64), iters=iters))
    return assign.astype(np.int64), status.astype(np.int64)


def dual_schedule_batch(
        instances: Union[InstanceBatch, Sequence[OffloadInstance]], *,
        iters: int = 40) -> List[Schedule]:
    """`dual_schedule` over a fleet of same-shape instances, one jit call."""
    batch = instances if isinstance(instances, InstanceBatch) \
        else InstanceBatch.stack(list(instances))
    assign, status = dual_schedule_batch_arrays(batch, iters=iters)
    return [Schedule(assignment=assign[b], instance=batch[b], solver="dual",
                     status="ok" if status[b] == 0 else "fallback")
            for b in range(len(batch))]
