"""Beyond-paper: Lagrangian-dual fast scheduler.

AMR^2 costs O(n^3 (m+1)^3) via the LP; at serving-time scales (n ~ 10^3+
requests per plan period) the planner itself becomes the bottleneck the
paper reports (50 ms at n = 40 on the Pi).  This fast path exploits the
problem's two-knapsack structure directly:

  1. Dualize ONLY the ED budget with multiplier lam >= 0: each job's ED
     choice is argmax_i (a_i - lam * p_ij) — vectorized over (n, m).
  2. Given those ED fallbacks, the ES side is a 0/1 knapsack in the gains
     g_j = a_{m+1} - a_{i*(j)} with weights p_es_j and capacity T — solved
     by density-greedy (the classic 1/2-approx; near-exact here because
     items are tiny vs T).
  3. Bisect lam (log-scale, ~40 evals) to the smallest multiplier whose
     induced assignment meets the ED budget.

O(iters * n (m + log n)) total.  No worst-case 2T guarantee is claimed
(that's AMR^2's job); benchmarks/table_runtime.py measures the accuracy gap
vs AMR^2 (≈1% on paper-like instances) and the speedup (>100x at n=1024).
"""
from __future__ import annotations

import numpy as np

from .types import OffloadInstance, Schedule


def _recover(inst: OffloadInstance, lam: float) -> np.ndarray:
    n, m, T = inst.n, inst.m, inst.T
    a = inst.acc
    score = a[None, :-1] - lam * inst.p_ed          # (n, m)
    ed_choice = np.argmax(score, axis=1)
    gain = a[-1] - a[ed_choice]                     # accuracy gain if offloaded
    density = gain / np.maximum(inst.p_es, 1e-12)
    order = np.argsort(-density, kind="stable")
    cum = np.cumsum(inst.p_es[order])
    take = order[(cum <= T + 1e-12)]
    # offloading a negative-gain job never helps accuracy, but it can
    # relieve the ED budget; the bisection prefers raising lam instead, so
    # only keep non-negative gains here.
    take = take[gain[take] >= 0]
    assign = ed_choice.copy()
    assign[take] = m
    return assign


def _ed_load(inst: OffloadInstance, assign: np.ndarray) -> float:
    on_ed = assign < inst.m
    if not on_ed.any():
        return 0.0
    j = np.nonzero(on_ed)[0]
    return float(inst.p_ed[j, assign[j]].sum())


def dual_schedule(inst: OffloadInstance, *, iters: int = 40) -> Schedule:
    T = inst.T
    # lam = 0: unconstrained ED choice (max accuracy). If feasible, done.
    assign = _recover(inst, 0.0)
    if _ed_load(inst, assign) <= T + 1e-12:
        return Schedule(assignment=assign, instance=inst, solver="dual",
                        status="ok")
    # log-scale bisection for the smallest feasible multiplier
    lo, hi = 0.0, float(inst.acc[-1] / max(np.min(inst.p_ed), 1e-9))
    best = None
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        cand = _recover(inst, mid)
        if _ed_load(inst, cand) <= T + 1e-12:
            best, hi = cand, mid
        else:
            lo = mid
    if best is None:
        # even the harshest multiplier failed (tiny T): everything on the
        # fastest models, best-effort like the paper's infeasible case
        cand = np.argmin(inst.p_ed, axis=1)
        return Schedule(assignment=cand, instance=inst, solver="dual",
                        status="fallback")
    return Schedule(assignment=best, instance=inst, solver="dual",
                    status="ok")
