"""Traced fault injection + the graceful-degradation ladder.

The paper's AMR^2 guarantee (makespan <= 2T, accuracy within a constant of
optimal) assumes the plan executes as priced: the ES is up, links deliver
at the estimated rate, every offloaded sample returns in time.  The
engine's `drift`/`outage` schedules model only faults the planner can see
*in advance*; this module injects the mid-period surprises it cannot —
an ES crash after admission, link degradation during transmission,
straggler EDs, per-sample offload loss — and resolves them with a
deterministic degradation ladder, all as pure traced array ops so chaos
runs *inside* the one-`lax.scan` `rollout()` at full fleet throughput.

Vocabulary
----------
``FaultModel``
    A pytree of float64 scalars describing the fault distribution.  All
    leaves, no static aux, so swapping fault rates never retriggers a jit
    trace.  ``FaultModel.none()`` is the all-zero model; the engine keeps
    the chaos code path out of the trace entirely when it is null (the
    fault-free rollout is bitwise-identical to an engine without this
    module).
``sample_realization(key, fm, ...)``
    One period's concrete fault draw (`FaultRealization`).  The key is a
    *replayed* stream — `fold_in(PRNGKey(fault_seed), period)` — separate
    from the engine's arrival PRNG, so arming chaos never perturbs the
    arrival trajectory.  Per-device draws fold in the GLOBAL device id,
    so sharded and unsharded realizations agree (the `_arrivals` idiom).
``realize_execution(...)``
    The realized-execution pass: realized latencies diverge from the
    priced estimates under the drawn faults, failed offloads walk the
    ladder, and per-sample realized accuracies + deadline hits/misses
    come back as per-device counters the engine psum-reduces.

The degradation ladder (per offloaded sample)
---------------------------------------------
1. **Retry** with capped exponential backoff: up to ``max_retries``
   statically-unrolled masked rounds (no `lax.while_loop` — the trace
   stays scan/shard-compatible).  Round ``k`` costs one device-level
   backoff ``min(backoff_base * 2**(k-1), backoff_cap)`` plus the
   retransmission of every still-lost sample at the degraded link rate.
   A device only opens round ``k`` while its realized ES time is still
   under ``2T`` (the paper's makespan guarantee), so by construction the
   realized ES time never exceeds
   ``2T + backoff_cap + admitted_demand * link_factor``.
   An ES crash skips retries outright — the pool is down, retrying
   cannot help — and sends every offloaded sample straight to rung 2.
2. **Fall back locally**: the largest (max-accuracy) local model that
   still fits the device's residual deadline ``max(0, 2T - realized ED
   time)``, a greedy masked-argmax fill in job order over the realized
   per-device latency tables (`greedy_local_fill`).
3. **Drop**: accuracy 0, counted in ``n_dropped`` — never silently lost:
   ``n_offload_samples == n_offload_ok + n_fallback_local + n_dropped``
   holds per period by construction.

Everything is deterministic under a fixed key: same key + same model →
the same realization, the same ladder outcome, bit for bit.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "FaultModel", "FaultRealization", "RealizedExecution",
    "sample_realization", "greedy_local_fill", "realize_execution",
]


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Per-period fault distribution (pytree; every field is a float64
    scalar leaf — no static aux, so sweeping fault rates reuses one
    compiled rollout).

    Probabilities are per period: ``es_crash_prob`` for the whole pool
    (one Bernoulli draw, shared across shards), ``link_degrade_prob`` /
    ``straggler_prob`` per device, ``loss_rate`` per offloaded sample
    *per attempt* (so the chance a sample survives no attempt is
    ``loss_rate ** (max_retries + 1)`` — retries flatten the loss cliff).
    """

    es_crash_prob: np.ndarray       # () P[ES pool crashes mid-period]
    link_degrade_prob: np.ndarray   # () P[a device's link degrades]
    link_degrade_mag: np.ndarray    # () max extra slowdown (factor 1+mag*U)
    straggler_prob: np.ndarray      # () P[a device straggles this period]
    straggler_mult: np.ndarray      # () ED slowdown factor when straggling
    loss_rate: np.ndarray           # () P[an offload attempt is lost]
    backoff_base: np.ndarray        # () first-retry backoff (seconds)
    backoff_cap: np.ndarray         # () max per-round backoff (seconds)

    @classmethod
    def none(cls) -> "FaultModel":
        """The all-zero model: chaos disarmed, bitwise-invisible."""
        z = np.float64(0.0)
        return cls(es_crash_prob=z, link_degrade_prob=z,
                   link_degrade_mag=z, straggler_prob=z,
                   straggler_mult=np.float64(1.0), loss_rate=z,
                   backoff_base=z, backoff_cap=z)

    @classmethod
    def make(cls, *, es_crash_prob: float = 0.0,
             link_degrade_prob: float = 0.0, link_degrade_mag: float = 0.0,
             straggler_prob: float = 0.0, straggler_mult: float = 1.0,
             loss_rate: float = 0.0, backoff_base: float = 0.02,
             backoff_cap: float = 0.25) -> "FaultModel":
        """Keyword constructor with float64 coercion (the engine is
        float64-only) and range validation."""
        for name, v, lo, hi in (
                ("es_crash_prob", es_crash_prob, 0.0, 1.0),
                ("link_degrade_prob", link_degrade_prob, 0.0, 1.0),
                ("straggler_prob", straggler_prob, 0.0, 1.0),
                ("loss_rate", loss_rate, 0.0, 1.0)):
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name}={v} must be in [{lo}, {hi}]")
        if link_degrade_mag < 0:
            raise ValueError("link_degrade_mag must be >= 0")
        if straggler_mult < 1.0:
            raise ValueError("straggler_mult must be >= 1 (a slowdown)")
        if backoff_base < 0 or backoff_cap < 0:
            raise ValueError("backoff_base/backoff_cap must be >= 0")
        return cls(es_crash_prob=np.float64(es_crash_prob),
                   link_degrade_prob=np.float64(link_degrade_prob),
                   link_degrade_mag=np.float64(link_degrade_mag),
                   straggler_prob=np.float64(straggler_prob),
                   straggler_mult=np.float64(straggler_mult),
                   loss_rate=np.float64(loss_rate),
                   backoff_base=np.float64(backoff_base),
                   backoff_cap=np.float64(backoff_cap))

    def is_null(self) -> bool:
        """Host-side: no fault can ever fire under this model (the engine
        uses this to keep chaos out of the trace entirely)."""
        return (float(self.es_crash_prob) == 0.0
                and float(self.link_degrade_prob) == 0.0
                and float(self.straggler_prob) == 0.0
                and float(self.loss_rate) == 0.0)


_FAULT_FIELDS = tuple(f.name for f in dataclasses.fields(FaultModel))


def _fault_unflatten(aux, children):
    # bypass __init__ so tracers survive the round-trip (the `_register`
    # idiom in repro.api.engine)
    obj = object.__new__(FaultModel)
    for f, v in zip(_FAULT_FIELDS, children):
        object.__setattr__(obj, f, v)
    return obj


jax.tree_util.register_pytree_node(
    FaultModel,
    lambda fm: (tuple(getattr(fm, f) for f in _FAULT_FIELDS), None),
    _fault_unflatten)


class FaultRealization(NamedTuple):
    """One period's concrete fault draw."""

    es_crash: jnp.ndarray          # ()   bool — pool down mid-period
    link_factor: jnp.ndarray       # (D,) ES-transmission slowdown (>= 1)
    straggler_factor: jnp.ndarray  # (D,) ED slowdown (>= 1)
    lost: jnp.ndarray              # (D, n, A) per-attempt offload loss


class RealizedExecution(NamedTuple):
    """Realized walls, per-sample accuracy, and ladder counters — every
    counter is per-device so the engine's psum reductions apply."""

    acc: jnp.ndarray               # (D, n) realized per-sample accuracy
    ed_wall: jnp.ndarray           # (D,) realized ED time incl. fallback
    ed_audit: jnp.ndarray          # (D,) realized ED time excl. fallback
    es_wall: jnp.ndarray           # (D,) realized ES time incl. retries
    wall: jnp.ndarray              # (D,) realized device makespan
    n_offload: jnp.ndarray         # (D,) int32 admitted offloaded samples
    n_offload_ok: jnp.ndarray      # (D,) int32 completed via ES
    n_retries: jnp.ndarray         # (D,) int32 retry attempts
    n_fallback_local: jnp.ndarray  # (D,) int32 rung-2 local completions
    n_dropped: jnp.ndarray         # (D,) int32 rung-3 drops
    n_deadline_miss: jnp.ndarray   # (D,) int32 samples past the 2T bound


def sample_realization(key, fm: FaultModel, n_devices: int, n_jobs: int,
                       max_attempts: int,
                       axis_name: Optional[str] = None
                       ) -> FaultRealization:
    """Draw one period's faults from a replayed key.

    ``key`` must come from a stream independent of the engine's arrival
    PRNG (the engine folds a dedicated ``fault_seed`` by period), so the
    fault-free trajectory is untouched by arming chaos.  The pool-crash
    draw uses the replicated key directly — every shard sees the same
    crash — while device-level draws fold in the *global* device id, so
    an 8-shard and an unsharded run realize identical faults.
    """
    D, n = n_devices, n_jobs
    k_crash, k_dev = jax.random.split(key)
    es_crash = jax.random.bernoulli(k_crash, fm.es_crash_prob)
    offset = (jax.lax.axis_index(axis_name) * D if axis_name
              else jnp.int32(0))
    gid = offset + jnp.arange(D, dtype=jnp.int32)
    kd = jax.vmap(lambda g: jax.random.fold_in(k_dev, g))(gid)

    def _one_device(k):
        k_link, k_mag, k_strag, k_loss = jax.random.split(k, 4)
        u_link = jax.random.uniform(k_link, dtype=jnp.float64)
        u_mag = jax.random.uniform(k_mag, dtype=jnp.float64)
        u_strag = jax.random.uniform(k_strag, dtype=jnp.float64)
        u_loss = jax.random.uniform(k_loss, (n, max_attempts),
                                    dtype=jnp.float64)
        link = jnp.where(u_link < fm.link_degrade_prob,
                         1.0 + fm.link_degrade_mag * u_mag, 1.0)
        strag = jnp.where(u_strag < fm.straggler_prob,
                          fm.straggler_mult, 1.0)
        lost = u_loss < fm.loss_rate
        return link, strag, lost

    link_factor, straggler_factor, lost = jax.vmap(_one_device)(kd)
    return FaultRealization(es_crash=es_crash, link_factor=link_factor,
                            straggler_factor=straggler_factor, lost=lost)


def greedy_local_fill(lat_jobs, acc_local, budget, eligible):
    """Greedy local-only fill: for each eligible sample, in job order,
    pick the max-accuracy local model whose latency still fits the
    device's residual budget, and spend it.

    ``lat_jobs`` (D, n, m) per-sample local-model latencies, ``acc_local``
    (D, m) local accuracies, ``budget`` (D,) or scalar seconds,
    ``eligible`` (D, n) bool.  Returns ``(choice (D, n) int32 — model
    index, m = nothing fits —, fit (D, n) bool, time_used (D,))``.
    Argmax ties break to the lowest model index; job order (not
    accuracy order) keeps the scan one pass and deterministic.  Used for
    rung 2 of the ladder and for recovering `unsolved` LP lanes.
    """
    D, n, m = lat_jobs.shape
    res0 = jnp.broadcast_to(jnp.asarray(budget, jnp.float64), (D,))

    def body(res, xs):
        lat_j, elig_j = xs                      # (D, m), (D,)
        fits = lat_j <= res[:, None] + 1e-12
        score = jnp.where(fits, acc_local, -jnp.inf)
        pick = jnp.argmax(score, axis=1)
        any_fit = fits.any(axis=1)
        take = elig_j & any_fit
        spend = jnp.where(take, lat_j[jnp.arange(D), pick], 0.0)
        choice = jnp.where(take, pick, m).astype(jnp.int32)
        return res - spend, (choice, take)

    res, (choice, fit) = jax.lax.scan(
        body, res0, (jnp.moveaxis(lat_jobs, 1, 0),
                     jnp.moveaxis(eligible, 1, 0)))
    return (jnp.moveaxis(choice, 1, 0), jnp.moveaxis(fit, 1, 0),
            res0 - res)


def realize_execution(fm: FaultModel, real: FaultRealization, *,
                      mask, es_samp, acc_jobs, p_es_jobs, ed_wall,
                      lat_local, acc, T, max_retries: int
                      ) -> RealizedExecution:
    """Replay the plan through one period's fault realization and walk
    the degradation ladder for every failed offload.

    ``mask`` (D, n) real samples, ``es_samp`` (D, n) admitted offloaded
    samples, ``acc_jobs`` (D, n) planned per-sample accuracies,
    ``p_es_jobs`` (D, n) priced per-sample ES seconds, ``ed_wall`` (D,)
    the nominal (pre-straggler) realized ED time, ``lat_local``
    (D, n, m) *realized* local-model latencies (base x drift x injected
    straggler), ``acc`` (D, m+1) accuracy tables, ``T`` the period
    budget.  All zeros / identity factors reproduce the priced execution
    bit for bit (`x * 1.0` and `x + 0.0` are exact in float64).
    """
    D, n, m = lat_local.shape
    deadline = 2.0 * T                     # the paper's AMR^2 guarantee
    link = real.link_factor
    es_cost = jnp.where(es_samp, p_es_jobs, 0.0)        # priced seconds
    es_time = es_cost.sum(axis=1) * link                # first attempt
    failed = es_samp & (real.lost[:, :, 0] | real.es_crash)
    n_retries = jnp.zeros(D, jnp.int32)
    for k in range(1, max_retries + 1):
        backoff = jnp.minimum(fm.backoff_base * (2.0 ** (k - 1)),
                              fm.backoff_cap)
        can = (~real.es_crash) & (es_time < deadline) & failed.any(axis=1)
        attempt = failed & can[:, None]
        resend = jnp.where(attempt, es_cost, 0.0).sum(axis=1) * link
        es_time = es_time + jnp.where(can, backoff + resend, 0.0)
        n_retries = n_retries + attempt.sum(axis=1).astype(jnp.int32)
        failed = jnp.where(attempt, real.lost[:, :, k], failed)

    ed_real = ed_wall * real.straggler_factor
    residual = jnp.maximum(0.0, deadline - ed_real)
    choice, fit, fb_time = greedy_local_fill(lat_local, acc[:, :m],
                                             residual, failed)
    dropped = failed & ~fit
    ed_final = ed_real + fb_time
    ok_off = es_samp & ~failed

    acc_real = jnp.where(fit, acc[jnp.arange(D)[:, None],
                                  jnp.clip(choice, 0, m - 1)], acc_jobs)
    acc_real = jnp.where(dropped, 0.0, acc_real)

    on_ed = mask & ~es_samp
    late_ed = (ed_final > deadline)[:, None]
    late_es = (es_time > deadline)[:, None]
    miss = dropped | ((on_ed | fit) & late_ed) | (ok_off & late_es)

    count = lambda b: b.sum(axis=1).astype(jnp.int32)
    return RealizedExecution(
        acc=acc_real, ed_wall=ed_final, ed_audit=ed_real, es_wall=es_time,
        wall=jnp.maximum(ed_final, es_time),
        n_offload=count(es_samp), n_offload_ok=count(ok_off),
        n_retries=n_retries, n_fallback_local=count(fit),
        n_dropped=count(dropped), n_deadline_miss=count(miss))
