"""Greedy Round-Robin (Greedy-RRA) — the paper's §VII baseline.

Offload jobs from the start of the list to the ES until the budget T is met;
assign the remainder round-robin across the ED models until the ED budget T
is met; dump any leftovers on model 1 (the least accurate).  O(n); may
violate T — exactly as in the paper.
"""
from __future__ import annotations

import numpy as np

from .types import OffloadInstance, Schedule


def greedy_rra(inst: OffloadInstance) -> Schedule:
    n, m, T = inst.n, inst.m, inst.T
    assignment = np.zeros(n, dtype=np.int64)

    es_time = 0.0
    j = 0
    while j < n and es_time + inst.p_es[j] <= T + 1e-12:
        assignment[j] = inst.m
        es_time += inst.p_es[j]
        j += 1

    ed_time = 0.0
    k = 0
    while j < n:
        i = k % m
        if ed_time + inst.p_ed[j, i] <= T + 1e-12:
            assignment[j] = i
            ed_time += inst.p_ed[j, i]
            j += 1
            k += 1
        else:
            break

    # leftovers -> model 1 (index 0); this is where T gets violated
    assignment[j:] = 0
    return Schedule(assignment=assignment, instance=inst, solver="greedy_rra",
                    status="ok")
