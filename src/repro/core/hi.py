"""Online hierarchical inference: confidence-gated per-sample offloading
with in-rollout learning.

The paper's AMR^2 plans from a KNOWN accuracy table.  Moothedath &
Champati (arXiv 2304.00891) study the online twin of the same problem:
the ED runs its small local model on EVERY sample (that is the
"hierarchical" part), observes a confidence for the local prediction,
and must decide per sample — from that confidence alone, with no prior
knowledge of how accurate the ES model is — whether to ALSO offload.
Offloading buys the ES accuracy at a fixed per-sample cost ``beta``
(``offload_cost``: transmission + ES occupancy in accuracy units), so
under a perfectly calibrated confidence the clairvoyant per-sample rule
is a THRESHOLD: offload iff ``conf < theta*`` with ``theta* = acc_es -
beta``.  The learners below compete with that clairvoyant:

``"fixed"``
    Serve a constant threshold ``theta0`` (the sweepable baseline; a
    per-device ``theta0 = clip(acc_es - beta, 0, 1)`` IS the clairvoyant
    and accrues exactly zero regret).
``"threshold"``
    The paper's one-dimensional online learner: OGD on the threshold
    with a sigmoid-kernel surrogate gradient (the true per-sample loss
    is piecewise constant in ``theta``) and a ``lr / sqrt(t+1)`` step.
    The surrogate's stationary point is ``theta = a_hat_es - beta``
    where ``a_hat_es`` is the running ES-accuracy estimate built from
    the learner's own offloads (optimistic prior 1.0, so early periods
    explore the ES), hence the iterates converge to the clairvoyant
    threshold and the regret is sublinear on a replayed stream.
``"ucb"`` / ``"exp3"``
    Bandit baselines over ``n_arms`` discretized thresholds
    (`arm_grid`): one arm is pulled per device per period, rewarded
    with the period's mean realized per-sample reward.  They bracket
    the threshold learner the way the greedy/dual baselines bracket
    AMR^2.

Everything is pure traced array math in the `core.faults` idiom:

* ``HIModel`` — all-float64-leaf pytree (no static aux), so sweeping
  ``offload_cost``/``lr``/``theta0`` reuses ONE compiled rollout.
* ``HILearnerState`` — the learner's evolving state (threshold, per-arm
  statistics, ES-accuracy counts, cumulative regret), carried as an
  `EngineState` leaf so the whole learning trajectory runs inside the
  engine's single `lax.scan` with zero host sync.
* The confidence stream is REPLAYED — `fold_in(PRNGKey(hi_seed),
  period)` then per-device folds of the GLOBAL device id — independent
  of the arrival PRNG, so arming HI never perturbs arrivals and an
  8-shard and an unsharded run draw identical streams.  ``conf_trace``
  alternatively replays presampled uniforms (`presample_stream`
  produces a trace that reproduces the fold-keyed stream bit for bit).

Calibration: per-sample confidence is drawn as ``p = mu + spread_c *
(u**((1-mu)/mu) - mu)`` with ``mu`` the local model's table accuracy —
the power-law is the closed-form inverse-CDF choice with ``E[p] = mu``
exactly, and the mean-preserving per-class ``spread`` blend keeps it
exact for any spread in [0, 1] — and the local outcome is then Bernoulli
in that confidence, so ``P(correct | conf) == conf`` by construction
(perfect calibration, the regime where the threshold rule is optimal).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "HI_RULES", "HI_STREAMS", "EXP3_GAMMA",
    "HIModel", "HILearnerState",
    "arm_grid", "sample_confidence", "presample_stream", "hi_period",
    "validate_hi",
]

# decision rules an armed engine accepts ("off" is the aux default that
# keeps the subsystem out of the trace entirely)
HI_RULES = ("fixed", "threshold", "ucb", "exp3")
HI_STREAMS = ("fold", "replay")
# EXP3 exploration floor (uniform mixing weight); the learning rate is
# the model's ``explore`` leaf
EXP3_GAMMA = 0.1


@dataclasses.dataclass(frozen=True)
class HIModel:
    """Calibration curves + learner hyper-parameters (pytree; every field
    is a float64 leaf — no static aux, so sweeping costs/rates/thresholds
    reuses one compiled rollout, the `FaultModel` contract)."""

    spread: np.ndarray        # (c,) or (1,) per-class calibration spread
    offload_cost: np.ndarray  # () beta: per-sample cost of consulting ES
    lr: np.ndarray            # () OGD step size (decayed by 1/sqrt(t+1))
    tau: np.ndarray           # () surrogate sigmoid temperature
    theta0: np.ndarray        # () or (D,) initial / fixed threshold
    explore: np.ndarray       # () UCB bonus coefficient / EXP3 rate
    conf_trace: np.ndarray    # (H, D, n, 3) replayed uniforms; (1,1,1,3)
    #                           placeholder when the stream is fold-keyed

    @classmethod
    def none(cls) -> "HIModel":
        """The null model: HI disarmed, bitwise-invisible to the trace."""
        z = np.float64(0.0)
        return cls(spread=np.zeros(1, np.float64), offload_cost=z,
                   lr=z, tau=np.float64(1.0), theta0=np.float64(0.5),
                   explore=z, conf_trace=np.zeros((1, 1, 1, 3)))

    @classmethod
    def make(cls, *, spread=0.8, offload_cost: float = 0.15,
             lr: float = 0.2, tau: float = 0.05, theta0=0.5,
             explore: float = 0.5,
             conf_trace: Optional[np.ndarray] = None) -> "HIModel":
        """Keyword constructor with float64 coercion and range checks.
        ``spread`` is a scalar or per-class vector in [0, 1]; ``theta0``
        a scalar or per-device vector in [0, 1] (a per-device ``theta0 =
        clip(acc_es - beta, 0, 1)`` under rule "fixed" is the
        zero-regret clairvoyant)."""
        sp = np.atleast_1d(np.asarray(spread, np.float64))
        if sp.ndim != 1 or np.any(sp < 0) or np.any(sp > 1):
            raise ValueError("spread must be scalar or 1-D in [0, 1]")
        if not 0.0 <= float(offload_cost) < 1.0:
            raise ValueError("offload_cost must be in [0, 1)")
        if lr <= 0 or tau <= 0:
            raise ValueError("lr and tau must be > 0")
        th = np.asarray(theta0, np.float64)
        if np.any(th < 0) or np.any(th > 1) or th.ndim > 1:
            raise ValueError("theta0 must be scalar or 1-D in [0, 1]")
        if explore < 0:
            raise ValueError("explore must be >= 0")
        if conf_trace is None:
            tr = np.zeros((1, 1, 1, 3))
        else:
            tr = np.asarray(conf_trace, np.float64)
            if tr.ndim != 4 or tr.shape[3] != 3:
                raise ValueError(
                    f"conf_trace must be (periods, D, n, 3) uniforms; "
                    f"got {tr.shape}")
        return cls(spread=sp, offload_cost=np.float64(offload_cost),
                   lr=np.float64(lr), tau=np.float64(tau), theta0=th,
                   explore=np.float64(explore), conf_trace=tr)

    @classmethod
    def from_profiles(cls, p_ed, *, spread_range: Tuple[float, float]
                      = (0.35, 0.95), **kw) -> "HIModel":
        """Per-class calibration spreads sampled from the roofline/paper
        latency profiles: classes are ranked by their mean ED latency and
        the spread interpolates ``spread_range`` over that rank — slower
        (harder) classes produce confidences that swing further from the
        model's mean accuracy, i.e. carry more per-sample signal.
        ``p_ed`` is a (c, m) profile table or the engine's stacked
        (D, c, m) ``base_p_ed``; remaining kwargs go to `make`."""
        tbl = np.asarray(p_ed, np.float64)
        if tbl.ndim == 3:
            tbl = tbl.mean(axis=0)
        if tbl.ndim != 2:
            raise ValueError(f"p_ed must be (c, m) or (D, c, m); got "
                             f"shape {tbl.shape}")
        c = tbl.shape[0]
        lo, hi = spread_range
        if not 0.0 <= lo <= hi <= 1.0:
            raise ValueError("spread_range must satisfy 0 <= lo <= hi <= 1")
        if c == 1:
            sp = np.array([(lo + hi) / 2.0])
        else:
            rank = np.argsort(np.argsort(tbl.mean(axis=1)))
            sp = lo + (hi - lo) * rank / (c - 1)
        return cls.make(spread=sp, **kw)

    def is_null(self) -> bool:
        """Host-side: this model carries no confidence signal and no
        learner (the engine keeps HI out of the trace entirely)."""
        return (float(np.max(self.spread)) == 0.0
                and float(self.offload_cost) == 0.0
                and float(self.lr) == 0.0
                and float(self.explore) == 0.0)


_HI_FIELDS = tuple(f.name for f in dataclasses.fields(HIModel))


def _hi_unflatten(aux, children):
    obj = object.__new__(HIModel)
    for f, v in zip(_HI_FIELDS, children):
        object.__setattr__(obj, f, v)
    return obj


jax.tree_util.register_pytree_node(
    HIModel,
    lambda hm: (tuple(getattr(hm, f) for f in _HI_FIELDS), None),
    _hi_unflatten)


@dataclasses.dataclass(frozen=True)
class HILearnerState:
    """The learner's evolving state, one row per device — carried as an
    `EngineState` leaf so the whole trajectory lives inside the scan.
    Counts are float64 on purpose: they feed ratios/bonuses directly and
    keep every learner leaf a single dtype for the f64 discipline."""

    theta: jnp.ndarray       # (D,) current threshold
    arm: jnp.ndarray         # (D,) int32 last pulled arm (bandit rules)
    arms_sum: jnp.ndarray    # (D, K) per-arm reward sum (UCB) / EXP3 gains
    arms_cnt: jnp.ndarray    # (D, K) per-arm pull counts
    es_sum: jnp.ndarray      # (D,) observed ES-correct count
    es_cnt: jnp.ndarray      # (D,) observed offload count
    cum_regret: jnp.ndarray  # (D,) cumulative pseudo-regret vs theta*

    @classmethod
    def init(cls, n_devices: int, n_arms: int,
             theta0=0.5) -> "HILearnerState":
        D, K = n_devices, n_arms
        th = np.broadcast_to(np.asarray(theta0, np.float64), (D,)).copy()
        return cls(theta=th, arm=np.zeros(D, np.int32),
                   arms_sum=np.zeros((D, K)), arms_cnt=np.zeros((D, K)),
                   es_sum=np.zeros(D), es_cnt=np.zeros(D),
                   cum_regret=np.zeros(D))


_HI_STATE_FIELDS = tuple(f.name for f in dataclasses.fields(HILearnerState))


def _hi_state_unflatten(aux, children):
    obj = object.__new__(HILearnerState)
    for f, v in zip(_HI_STATE_FIELDS, children):
        object.__setattr__(obj, f, v)
    return obj


jax.tree_util.register_pytree_node(
    HILearnerState,
    lambda s: (tuple(getattr(s, f) for f in _HI_STATE_FIELDS), None),
    _hi_state_unflatten)


def arm_grid(n_arms: int) -> jnp.ndarray:
    """The bandit rules' discretized thresholds: K evenly spaced interior
    points of [0, 1] (K=9 gives 0.1 .. 0.9)."""
    return jnp.linspace(1.0 / (n_arms + 1), n_arms / (n_arms + 1.0),
                        n_arms, dtype=jnp.float64)


def _draw_uniforms(key, n_devices: int, n_jobs: int,
                   axis_name: Optional[str] = None,
                   gid_offset: Optional[int] = None) -> jnp.ndarray:
    """(D, n, 3) uniforms from per-device GLOBAL-id folds (the
    `sample_realization` idiom): channel 0 shapes the confidence,
    channel 1 the local Bernoulli outcome, channel 2 the ES outcome.
    ``gid_offset`` overrides the axis-derived offset for unit tests of
    the shard fold itself."""
    if gid_offset is None:
        offset = (jax.lax.axis_index(axis_name) * n_devices
                  if axis_name else jnp.int32(0))
    else:
        offset = jnp.int32(gid_offset)
    gid = offset + jnp.arange(n_devices, dtype=jnp.int32)
    kd = jax.vmap(lambda g: jax.random.fold_in(key, g))(gid)
    return jax.vmap(lambda k: jax.random.uniform(
        k, (n_jobs, 3), dtype=jnp.float64))(kd)


def sample_confidence(key, hm: HIModel, acc_local, acc_es, ci, *,
                      uniforms=None, axis_name: Optional[str] = None,
                      gid_offset: Optional[int] = None):
    """One period of the calibrated confidence stream.

    ``acc_local`` (D,) is the designated local model's table accuracy,
    ``acc_es`` (D,) the ES accuracy, ``ci`` (D, n) per-sample class
    indices.  ``uniforms`` replays a presampled (D, n, 3) slice instead
    of drawing from ``key`` (`HIModel.conf_trace` / `presample_stream`).
    Returns ``(conf, correct_local, correct_es)``, each (D, n): the
    confidence is exactly mean-``acc_local`` (see module docstring) and
    ``P(correct_local | conf) == conf`` — perfect calibration."""
    D, n = ci.shape
    u = _draw_uniforms(key, D, n, axis_name, gid_offset) \
        if uniforms is None else uniforms
    mu = jnp.clip(jnp.asarray(acc_local, jnp.float64), 1e-6, 1.0 - 1e-6)
    p_raw = u[..., 0] ** ((1.0 - mu) / mu)[:, None]
    sp = jnp.asarray(hm.spread, jnp.float64)
    spread_j = sp[ci] if sp.shape[0] > 1 else sp[0]
    conf = jnp.clip(mu[:, None] + spread_j * (p_raw - mu[:, None]),
                    0.0, 1.0)
    correct_local = u[..., 1] < conf
    correct_es = u[..., 2] < jnp.asarray(acc_es, jnp.float64)[:, None]
    return conf, correct_local, correct_es


def presample_stream(seed: int, n_devices: int, n_jobs: int,
                     periods: int) -> np.ndarray:
    """A replayed confidence trace ``(periods, D, n, 3)`` that reproduces
    the fold-keyed stream BIT FOR BIT: period ``t`` holds exactly the
    uniforms an armed engine with ``hi_seed=seed`` draws at period ``t``
    (fold the seed by period, split off the confidence key, fold global
    device ids).  Feeding it back via ``HIModel(conf_trace=...)`` +
    ``stream="replay"`` therefore pins replay == fold."""
    from jax.experimental import enable_x64
    out = np.zeros((periods, n_devices, n_jobs, 3))
    with enable_x64():
        base = jax.random.PRNGKey(seed)
        for t in range(periods):
            kc, _ka = jax.random.split(jax.random.fold_in(base, t))
            out[t] = np.asarray(_draw_uniforms(kc, n_devices, n_jobs))
    return out


def hi_period(rule: str, hm: HIModel, hst: HILearnerState, conf,
              correct_local, correct_es, mask, acc_es, t, key,
              n_arms: int, axis_name: Optional[str] = None):
    """One traced HI period: pick this period's threshold, decide per
    sample, feed the observations back into the learner, and account the
    pseudo-regret.

    ``conf``/``correct_local``/``correct_es`` come from
    `sample_confidence`, ``mask`` (D, n) marks real samples, ``acc_es``
    (D,) is the TRUE ES accuracy (used only for the regret metric — the
    learners never read it), ``t`` the period index (step-size decay and
    the UCB bonus), ``key`` the period's arm-draw key (EXP3 only).

    Returns ``(offload (D, n) bool — the INTENDED decisions, theta_t
    (D,), new_state, regret_inc (D,))``.  The regret increment is the
    expected pseudo-regret of the intended decisions against the
    clairvoyant threshold ``theta* = acc_es - beta`` given the realized
    confidences: per sample ``max(conf, acc_es - beta)`` minus the
    chosen side's expected reward — nonnegative, exactly zero for the
    clairvoyant, and deterministic given the stream."""
    if rule not in HI_RULES:
        raise ValueError(f"unknown HI rule {rule!r}; expected one of "
                         f"{HI_RULES}")
    D, _n = conf.shape
    beta = hm.offload_cost
    njobs = mask.sum(axis=1).astype(jnp.float64)
    has = njobs > 0
    tf = jnp.asarray(t, jnp.float64)
    probs = None

    # ---- this period's threshold per device -----------------------------
    if rule == "ucb":
        grid = arm_grid(n_arms)
        cnt = hst.arms_cnt
        mean = hst.arms_sum / jnp.maximum(cnt, 1.0)
        # untried arms get an infinite bonus: argmax sweeps the grid in
        # index order before any exploitation starts
        bonus = jnp.where(cnt > 0.0,
                          hm.explore * jnp.sqrt(jnp.log(tf + 2.0)
                                                / jnp.maximum(cnt, 1.0)),
                          jnp.inf)
        arm = jnp.argmax(mean + bonus, axis=1).astype(jnp.int32)
        theta_t = grid[arm]
    elif rule == "exp3":
        grid = arm_grid(n_arms)
        g = hm.explore * hst.arms_sum
        g = g - jnp.max(g, axis=1, keepdims=True)
        w = jnp.exp(g)
        probs = ((1.0 - EXP3_GAMMA) * w / w.sum(axis=1, keepdims=True)
                 + EXP3_GAMMA / n_arms)
        offset = (jax.lax.axis_index(axis_name) * D if axis_name
                  else jnp.int32(0))
        gid = offset + jnp.arange(D, dtype=jnp.int32)
        kd = jax.vmap(lambda gg: jax.random.fold_in(key, gg))(gid)
        u = jax.vmap(lambda k: jax.random.uniform(
            k, dtype=jnp.float64))(kd)
        cdf = jnp.cumsum(probs, axis=1)
        arm = jnp.minimum((u[:, None] >= cdf).sum(axis=1),
                          n_arms - 1).astype(jnp.int32)
        theta_t = grid[arm]
    else:                                       # "fixed" / "threshold"
        theta_t = hst.theta
        arm = hst.arm

    offload = mask & (conf < theta_t[:, None])

    # ---- learner updates from the period's observations -----------------
    # running ES-accuracy estimate with an optimistic Beta(1,1)-style
    # prior at 1.0: an untried ES looks perfect, so early thresholds
    # drift up and the learner explores offloading
    a_hat = (hst.es_sum + 1.0) / (hst.es_cnt + 1.0)
    new_es_sum = hst.es_sum + (offload & correct_es).sum(
        axis=1).astype(jnp.float64)
    new_es_cnt = hst.es_cnt + offload.sum(axis=1).astype(jnp.float64)

    if rule == "threshold":
        # sigmoid-kernel surrogate gradient of the per-sample threshold
        # loss: d/dtheta [sigma((theta-p)/tau) * cost_gap] — the kernel
        # concentrates at p == theta, so the stationary point is
        # theta = a_hat - beta (the clairvoyant threshold once a_hat
        # converges); E[correct_local | conf] == conf keeps the realized
        # outcome an unbiased plug-in for the local side's value
        z = (theta_t[:, None] - conf) / hm.tau
        sig = jax.nn.sigmoid(z)
        ker = sig * (1.0 - sig) / hm.tau
        gsamp = ker * (beta - a_hat[:, None]
                       + correct_local.astype(jnp.float64))
        gmean = jnp.where(mask, gsamp, 0.0).sum(axis=1) \
            / jnp.maximum(njobs, 1.0)
        step = hm.lr / jnp.sqrt(tf + 1.0)
        new_theta = jnp.where(
            has, jnp.clip(theta_t - step * gmean, 0.0, 1.0), theta_t)
    else:
        new_theta = theta_t

    if rule in ("ucb", "exp3"):
        # realized (observable) per-sample reward: the ES answer minus
        # the offload cost when consulted, else the local outcome
        r = jnp.where(offload, correct_es.astype(jnp.float64) - beta,
                      correct_local.astype(jnp.float64))
        r_mean = jnp.where(mask, r, 0.0).sum(axis=1) \
            / jnp.maximum(njobs, 1.0)
        onehot = (jnp.arange(n_arms, dtype=jnp.int32)[None, :]
                  == arm[:, None])
        upd = has[:, None] & onehot
        if rule == "ucb":
            new_sum = hst.arms_sum + jnp.where(upd, r_mean[:, None], 0.0)
        else:
            r01 = (r_mean + beta) / (1.0 + beta)    # EXP3 wants [0, 1]
            p_arm = jnp.take_along_axis(probs, arm[:, None],
                                        axis=1)[:, 0]
            ghat = r01 / jnp.maximum(p_arm, 1e-9)   # importance weight
            new_sum = hst.arms_sum + jnp.where(upd, ghat[:, None], 0.0)
        new_cnt = hst.arms_cnt + upd.astype(jnp.float64)
    else:
        new_sum, new_cnt = hst.arms_sum, hst.arms_cnt

    # ---- pseudo-regret vs the clairvoyant theta* = acc_es - beta --------
    r_es = jnp.asarray(acc_es, jnp.float64)[:, None] - beta
    chosen = jnp.where(offload, r_es, conf)
    regret_inc = jnp.where(mask, jnp.maximum(conf, r_es) - chosen,
                           0.0).sum(axis=1)

    new_hst = HILearnerState(
        theta=new_theta, arm=arm, arms_sum=new_sum, arms_cnt=new_cnt,
        es_sum=new_es_sum, es_cnt=new_es_cnt,
        cum_regret=hst.cum_regret + regret_inc)
    return offload, theta_t, new_hst, regret_inc


def validate_hi(hm: HIModel, *, n_devices: int, n_classes: int,
                n_models: int, rule: str, stream: str, n_arms: int,
                local_model: int, batch_max: Optional[int] = None) -> None:
    """Host-side arming validation (the `validate_mobility` twin): shape
    and range checks that a traced step could only fail on silently."""
    if rule not in HI_RULES:
        raise ValueError(f"unknown HI rule {rule!r}; expected one of "
                         f"{HI_RULES} (or disarm with with_hi(None))")
    if stream not in HI_STREAMS:
        raise ValueError(f"unknown HI stream {stream!r}; expected one of "
                         f"{HI_STREAMS}")
    sp = np.asarray(hm.spread)
    if sp.shape not in ((1,), (n_classes,)):
        raise ValueError(
            f"HIModel.spread has shape {sp.shape}; expected (1,) or one "
            f"entry per queue class ({n_classes},)")
    th = np.asarray(hm.theta0)
    if th.ndim not in (0, 1) or (th.ndim == 1
                                 and th.shape != (n_devices,)):
        raise ValueError(
            f"HIModel.theta0 has shape {th.shape}; expected a scalar or "
            f"one entry per device ({n_devices},)")
    if rule in ("ucb", "exp3") and n_arms < 2:
        raise ValueError(f"bandit rules need n_arms >= 2; got {n_arms}")
    if not 0 <= local_model < n_models:
        raise ValueError(
            f"hi_local={local_model} is not a local model index; the "
            f"fleet has {n_models} local models (0 .. {n_models - 1})")
    if stream == "replay":
        tr = np.asarray(hm.conf_trace)
        if tr.ndim != 4 or tr.shape[1] != n_devices or tr.shape[3] != 3:
            raise ValueError(
                f"stream='replay' needs conf_trace shaped (periods, "
                f"{n_devices}, batch_max, 3); got {tr.shape} "
                f"(presample_stream builds one)")
        if batch_max is not None and tr.shape[2] != batch_max:
            raise ValueError(
                f"conf_trace replays {tr.shape[2]} job slots per device "
                f"but the queue's batch_max is {batch_max}")
