"""Problem-instance generators.

`paper_instance` reproduces the paper's testbed numbers (§VII, Tables I/II,
Fig. 2): Raspberry-Pi MobileNets (alpha = 0.25 / 0.75) + server ResNet50,
ImageNet images of dimension 128/512/1024 with LAN communication times.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from .types import OffloadInstance

# --- paper constants (Tables I & II, Fig. 2) ------------------------------
PAPER_ACC = np.array([0.395, 0.559, 0.771])   # MobileNet .25 / .75, ResNet50
PAPER_DIMS = (128, 512, 1024)
# processing time (s) per image dimension
PAPER_P_ED = {128: (0.010, 0.040), 512: (0.011, 0.040), 1024: (0.011, 0.043)}
PAPER_P_ES_PROC = {128: 0.28, 512: 0.32, 1024: 0.38}
# communication + server-side reshape time (s), read off Fig. 2
PAPER_COMM = {128: 0.07, 512: 0.23, 1024: 0.70}


def paper_instance(n: int, T: float, seed: int = 0,
                   dims: Sequence[int] = PAPER_DIMS,
                   dim_probs: Optional[Sequence[float]] = None
                   ) -> OffloadInstance:
    """n ImageNet-style jobs with sizes sampled from `dims`."""
    rng = np.random.default_rng(seed)
    sizes = rng.choice(dims, size=n, p=dim_probs)
    p_ed = np.array([PAPER_P_ED[s] for s in sizes])
    p_es = np.array([PAPER_COMM[s] + PAPER_P_ES_PROC[s] for s in sizes])
    return OffloadInstance(p_ed=p_ed, p_es=p_es, acc=PAPER_ACC.copy(), T=T)


def random_instance(n: int, m: int, T: float, seed: int = 0, *,
                    p_lo: float = 1e-3, p_hi: float = 1.0,
                    es_speedup: float = 4.0, comm_lo: float = 0.01,
                    comm_hi: float = 0.5) -> OffloadInstance:
    """Random instance with accuracy increasing in model size (paper's
    monotone a_1 <= ... <= a_{m+1} convention)."""
    rng = np.random.default_rng(seed)
    # model "sizes" increasing -> processing times increasing, accuracy too
    base = np.sort(np.exp(rng.uniform(np.log(p_lo), np.log(p_hi), size=m)))
    jitter = np.exp(rng.normal(0.0, 0.15, size=(n, m)))
    p_ed = base[None, :] * jitter
    p_ed = np.sort(p_ed, axis=1)  # keep per-job monotonicity in model index
    es_proc = base[-1] / es_speedup * np.exp(rng.normal(0.0, 0.1, size=n))
    comm = rng.uniform(comm_lo, comm_hi, size=n)
    acc = np.sort(rng.uniform(0.3, 0.99, size=m + 1))
    return OffloadInstance(p_ed=p_ed, p_es=es_proc + comm, acc=acc, T=T)


def identical_instance(n: int, m: int, T: float, seed: int = 0
                       ) -> OffloadInstance:
    rng = np.random.default_rng(seed)
    base = np.sort(np.exp(rng.uniform(np.log(5e-3), np.log(0.5), size=m)))
    p_es = base[-1] / 3.0 + rng.uniform(0.05, 0.3)
    acc = np.sort(rng.uniform(0.3, 0.99, size=m + 1))
    return OffloadInstance(p_ed=np.tile(base, (n, 1)),
                           p_es=np.full(n, p_es), acc=acc, T=T)
