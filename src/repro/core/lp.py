"""Dense two-phase primal simplex, implemented twice from one design:

  * ``backend="jax"``   — fully jittable (`lax.while_loop` pivots, fixed-shape
    tableau).  This is the production path: the scheduler can run on-device
    next to the serving loop, and AMR^2 needs a *basic* optimal solution
    (Lemma 1 counts basic variables), which simplex — unlike interior-point —
    guarantees.
  * ``backend="numpy"`` — the same algorithm in float64 NumPy, used as the
    reference/oracle in tests and for very ill-conditioned instances.

Problem form:   minimize    c @ x
                subject to  A_ub @ x <= b_ub
                            A_eq @ x == b_eq
                            x >= 0

Phase 1 gives every row an artificial variable (initial basis), minimizes
their sum, and "drives out" artificials that linger in the basis at level 0
by prioritising their rows in the ratio test.  Phase 2 masks artificial
columns from ever re-entering.

Statuses: 0 optimal, 1 iteration limit, 2 infeasible, 3 unbounded.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .types import next_pow2

OPTIMAL, ITERATION_LIMIT, INFEASIBLE, UNBOUNDED = 0, 1, 2, 3


def _bucket_maxiter(maxiter: int) -> int:
    """Round a shape-derived default maxiter UP to a power of two.

    `maxiter` is a static argname of the jitted solvers, so leaving it as
    the raw `50 * (rows + 2)` makes every distinct padded job count retrace
    the (vmapped) simplex; bucketing keeps the trace-key count at O(log)
    — mirroring `plan_batch`'s batch-axis bucketing — and only ever raises
    the iteration budget."""
    return next_pow2(maxiter)


@dataclasses.dataclass
class LPResult:
    x: np.ndarray
    fun: float
    status: int
    niter: int
    basis: np.ndarray  # row -> basic variable index

    @property
    def success(self) -> bool:
        return self.status == OPTIMAL


@dataclasses.dataclass
class BatchLPResult:
    """`solve_lp_batch` output: leading batch axis on every field."""
    x: np.ndarray        # (B, nv)
    fun: np.ndarray      # (B,)
    status: np.ndarray   # (B,) int
    niter: np.ndarray    # (B,) int
    basis: np.ndarray    # (B, R) int

    def __len__(self) -> int:
        return self.x.shape[0]

    def __getitem__(self, b: int) -> LPResult:
        return LPResult(x=self.x[b], fun=float(self.fun[b]),
                        status=int(self.status[b]), niter=int(self.niter[b]),
                        basis=self.basis[b])


# --------------------------------------------------------------------------
# Canonicalisation shared by both backends
# --------------------------------------------------------------------------
def _canonicalize(c, A_ub, b_ub, A_eq, b_eq):
    c = np.asarray(c, dtype=np.float64)
    nv = c.shape[0]
    rows = []
    rhs = []
    n_ub = 0
    if A_ub is not None:
        A_ub = np.asarray(A_ub, dtype=np.float64)
        b_ub = np.asarray(b_ub, dtype=np.float64)
        n_ub = A_ub.shape[0]
        rows.append(np.concatenate([A_ub, np.eye(n_ub)], axis=1))
        rhs.append(b_ub)
    if A_eq is not None:
        A_eq = np.asarray(A_eq, dtype=np.float64)
        b_eq = np.asarray(b_eq, dtype=np.float64)
        pad = np.zeros((A_eq.shape[0], n_ub))
        rows.append(np.concatenate([A_eq, pad], axis=1))
        rhs.append(b_eq)
    A = np.concatenate(rows, axis=0)
    b = np.concatenate(rhs, axis=0)
    # b >= 0 by row flips
    neg = b < 0
    A[neg] *= -1.0
    b[neg] *= -1.0
    c_full = np.concatenate([c, np.zeros(n_ub)])
    return A, b, c_full, nv, n_ub


# --------------------------------------------------------------------------
# JAX backend
# --------------------------------------------------------------------------
def _simplex_phase(tableau, basis, art_start, *, maxiter: int,
                   tol: float = 1e-7):
    """Run pivots until optimal / maxiter / unbounded.

    tableau: (R+1, C+1); last row = objective (reduced costs | -obj value),
    last col = rhs.  basis: (R,) int32.  art_start: first artificial column
    (artificials may never enter; in phase 2 their rows get ratio priority
    so any basic artificial is driven out before it could turn positive).
    """
    R = tableau.shape[0] - 1
    C = tableau.shape[1] - 1
    cols = jnp.arange(C)
    rows = jnp.arange(R)

    def cond(state):
        tab, basis, it, status = state
        rc = tab[-1, :C]
        can_enter = (rc < -tol) & (cols < art_start)
        return (status == ITERATION_LIMIT) & jnp.any(can_enter) & (it < maxiter)

    def body(state):
        tab, basis, it, status = state
        rc = tab[-1, :C]
        enter_mask = (rc < -tol) & (cols < art_start)
        # Dantzig rule; Bland tie-break via index bias keeps cycling at bay
        # for the scale of instances we solve.
        score = jnp.where(enter_mask, rc, jnp.inf)
        j = jnp.argmin(score)

        col = tab[:R, j]
        rhsv = tab[:R, -1]
        pos = col > tol
        ratio = jnp.where(pos, rhsv / jnp.where(pos, col, 1.0), jnp.inf)
        # Drive-out rule: a basic artificial sitting at level ~0 with a
        # nonzero pivot coefficient gets ratio 0 so it leaves the basis
        # first (it must not be allowed to turn positive again).
        art_basic = ((basis >= art_start) & (jnp.abs(col) > tol)
                     & (rhsv <= tol))
        ratio = jnp.where(art_basic, 0.0, ratio)
        unbounded = ~jnp.any(ratio < jnp.inf)
        # lexicographic-ish tie-break: smallest basis index among min ratios
        rmin = jnp.min(ratio)
        tie = ratio <= rmin + jnp.maximum(jnp.abs(rmin) * 1e-9, 1e-12)
        r = jnp.argmin(jnp.where(tie, basis, jnp.iinfo(jnp.int32).max))

        piv = tab[r, j]
        piv_row = tab[r] / piv
        tab2 = tab - jnp.outer(tab[:, j], piv_row)
        tab2 = tab2.at[r].set(piv_row)
        basis2 = basis.at[r].set(j.astype(basis.dtype))

        tab2 = jnp.where(unbounded, tab, tab2)
        basis2 = jnp.where(unbounded, basis, basis2)
        status2 = jnp.where(unbounded, UNBOUNDED, status)
        return tab2, basis2, it + 1, status2

    init = (tableau, basis, jnp.array(0, jnp.int32),
            jnp.array(ITERATION_LIMIT, jnp.int32))
    tab, basis, it, status = jax.lax.while_loop(cond, body, init)
    rc = tab[-1, :C]
    done = ~jnp.any((rc < -tol) & (cols < art_start))
    status = jnp.where((status == ITERATION_LIMIT) & done, OPTIMAL, status)
    del rows
    return tab, basis, it, status


def _solve_core(A_j, b_j, c_j, nv, maxiter, tol):
    """Pure-jnp two-phase simplex on one canonicalised instance.

    Shapes are static given (R, C0), so this traces once per problem shape
    and is `jax.vmap`-able over a leading batch axis (see `solve_lp_batch`).
    """
    R, C0 = A_j.shape         # C0 = nv + n_slack
    C = C0 + R                # + artificials
    dtype = A_j.dtype
    tab = jnp.zeros((R + 1, C + 1), dtype)
    tab = tab.at[:R, :C0].set(A_j)
    tab = tab.at[:R, C0:C].set(jnp.eye(R, dtype=dtype))
    tab = tab.at[:R, -1].set(b_j)
    # phase-1 objective: sum of artificials, expressed in reduced-cost form
    tab = tab.at[-1, :].set(-jnp.sum(tab[:R, :], axis=0))
    tab = tab.at[-1, C0:C].set(0.0)
    basis = jnp.arange(C0, C, dtype=jnp.int32)

    tab, basis, it1, status1 = _simplex_phase(
        tab, basis, jnp.array(C0, jnp.int32), maxiter=maxiter, tol=tol)
    phase1_obj = tab[-1, -1]  # = -(sum of artificials)
    infeasible = phase1_obj < -max(tol, 1e-5) * (1.0 + jnp.abs(b_j).sum())

    # phase 2: swap in the real objective
    obj = jnp.zeros((C + 1,), dtype)
    obj = obj.at[:C0].set(c_j)
    # make reduced costs of basic columns zero
    cb = obj[basis]                       # cost of basic vars
    obj = obj - cb @ tab[:R, :]
    tab = tab.at[-1, :].set(obj)
    tab, basis, it2, status2 = _simplex_phase(
        tab, basis, jnp.array(C0, jnp.int32), maxiter=maxiter, tol=tol)

    x = jnp.zeros((C,), dtype).at[basis].set(tab[:R, -1])
    fun = -tab[-1, -1]
    status = jnp.where(infeasible, INFEASIBLE, status2)
    return x[:nv], fun, status, it1 + it2, basis


def _solve_jax(A, b, c_full, nv, n_slack, maxiter, tol):
    dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    return _solve_single_jit(jnp.asarray(A, dtype), jnp.asarray(b, dtype),
                             jnp.asarray(c_full, dtype), nv=nv,
                             maxiter=maxiter, tol=tol)


@partial(jax.jit, static_argnames=("nv", "maxiter", "tol"))
def _solve_single_jit(A_j, b_j, c_j, *, nv, maxiter, tol):
    return _solve_core(A_j, b_j, c_j, nv, maxiter, tol)


@partial(jax.jit, static_argnames=("nv", "maxiter", "tol"))
def _solve_batch_jit(A_j, b_j, c_j, *, nv, maxiter, tol):
    return jax.vmap(
        lambda A1, b1, c1: _solve_core(A1, b1, c1, nv, maxiter, tol)
    )(A_j, b_j, c_j)


# --------------------------------------------------------------------------
# NumPy backend (float64 reference)
# --------------------------------------------------------------------------
def _phase_np(tab, basis, art_start, maxiter, tol):
    R = tab.shape[0] - 1
    C = tab.shape[1] - 1
    it = 0
    while it < maxiter:
        rc = tab[-1, :C]
        enter = np.where((rc < -tol) & (np.arange(C) < art_start))[0]
        if enter.size == 0:
            return tab, basis, it, OPTIMAL
        j = enter[np.argmin(rc[enter])]
        col = tab[:R, j]
        rhs = tab[:R, -1]
        ratio = np.full(R, np.inf)
        pos = col > tol
        ratio[pos] = rhs[pos] / col[pos]
        art_basic = (basis >= art_start) & (np.abs(col) > tol) & (rhs <= tol)
        ratio[art_basic] = 0.0
        if not np.any(ratio < np.inf):
            return tab, basis, it, UNBOUNDED
        rmin = ratio.min()
        tie = ratio <= rmin + max(abs(rmin) * 1e-9, 1e-12)
        cand = np.where(tie)[0]
        r = cand[np.argmin(basis[cand])]
        piv = tab[r, j]
        tab[r] = tab[r] / piv
        for k in range(tab.shape[0]):
            if k != r and abs(tab[k, j]) > 0:
                tab[k] -= tab[k, j] * tab[r]
        basis[r] = j
        it += 1
    return tab, basis, it, ITERATION_LIMIT


def _solve_np(A, b, c_full, nv, n_slack, maxiter, tol):
    R, C0 = A.shape
    C = C0 + R
    tab = np.zeros((R + 1, C + 1))
    tab[:R, :C0] = A
    tab[:R, C0:C] = np.eye(R)
    tab[:R, -1] = b
    tab[-1, :] = -tab[:R, :].sum(axis=0)
    tab[-1, C0:C] = 0.0
    basis = np.arange(C0, C, dtype=np.int64)

    tab, basis, it1, st1 = _phase_np(tab, basis, C0, maxiter, tol)
    infeasible = tab[-1, -1] < -max(tol, 1e-8) * (1.0 + np.abs(b).sum())

    obj = np.zeros(C + 1)
    obj[:C0] = c_full
    obj = obj - obj[basis] @ tab[:R, :]
    tab[-1, :] = obj
    tab, basis, it2, st2 = _phase_np(tab, basis, C0, maxiter, tol)

    x = np.zeros(C)
    x[basis] = tab[:R, -1]
    fun = -tab[-1, -1]
    status = INFEASIBLE if infeasible else st2
    return x[:nv], fun, status, it1 + it2, basis


# --------------------------------------------------------------------------
# public API
# --------------------------------------------------------------------------
def solve_lp(c, A_ub=None, b_ub=None, A_eq=None, b_eq=None, *,
             backend: str = "numpy", maxiter: Optional[int] = None,
             tol: float = 1e-7) -> LPResult:
    """Minimize c@x s.t. A_ub x <= b_ub, A_eq x == b_eq, x >= 0."""
    A, b, c_full, nv, n_slack = _canonicalize(c, A_ub, b_ub, A_eq, b_eq)
    if maxiter is None:
        maxiter = 50 * (A.shape[0] + 2)
        if backend == "jax":          # static argname: bucket the trace key
            maxiter = _bucket_maxiter(maxiter)
    if backend == "jax":
        if not jax.config.jax_enable_x64:
            tol = max(tol, 1e-5)
        x, fun, status, niter, basis = jax.tree_util.tree_map(
            np.asarray,
            _solve_jax(A, b, c_full, nv, n_slack, maxiter, tol))
        return LPResult(x=np.asarray(x, np.float64), fun=float(fun),
                        status=int(status), niter=int(niter),
                        basis=np.asarray(basis))
    elif backend == "numpy":
        x, fun, status, niter, basis = _solve_np(A, b, c_full, nv, n_slack,
                                                 maxiter, tol)
        return LPResult(x=x, fun=float(fun), status=int(status),
                        niter=int(niter), basis=basis)
    raise ValueError(f"unknown backend {backend!r}")


def _canonicalize_batch(c, A_ub, b_ub, A_eq, b_eq):
    """Batched `_canonicalize`: every input carries a leading batch axis and
    all batch elements share constraint structure (shapes)."""
    c = np.asarray(c, dtype=np.float64)
    B, nv = c.shape
    rows = []
    rhs = []
    n_ub = 0
    if A_ub is not None:
        A_ub = np.asarray(A_ub, dtype=np.float64)
        b_ub = np.asarray(b_ub, dtype=np.float64)
        n_ub = A_ub.shape[1]
        eye = np.broadcast_to(np.eye(n_ub), (B, n_ub, n_ub))
        rows.append(np.concatenate([A_ub, eye], axis=2))
        rhs.append(b_ub)
    if A_eq is not None:
        A_eq = np.asarray(A_eq, dtype=np.float64)
        b_eq = np.asarray(b_eq, dtype=np.float64)
        pad = np.zeros((B, A_eq.shape[1], n_ub))
        rows.append(np.concatenate([A_eq, pad], axis=2))
        rhs.append(b_eq)
    A = np.concatenate(rows, axis=1)
    b = np.concatenate(rhs, axis=1)
    neg = b < 0
    A = np.where(neg[:, :, None], -A, A)
    b = np.where(neg, -b, b)
    c_full = np.concatenate([c, np.zeros((B, n_ub))], axis=1)
    return A, b, c_full, nv, n_ub


def solve_lp_batch(c, A_ub=None, b_ub=None, A_eq=None, b_eq=None, *,
                   maxiter: Optional[int] = None, tol: float = 1e-7
                   ) -> BatchLPResult:
    """Solve B structurally-identical LPs in one jitted `vmap` of the simplex.

    Inputs mirror `solve_lp` with a leading batch axis on every array.  Runs
    in float64 (via a local `enable_x64` scope) regardless of the global jax
    precision mode so the batched path stays bit-comparable with the NumPy
    oracle; the schedulable fleet sizes here make the 2x memory irrelevant.
    """
    A, b, c_full, nv, _ = _canonicalize_batch(c, A_ub, b_ub, A_eq, b_eq)
    if maxiter is None:
        maxiter = _bucket_maxiter(50 * (A.shape[1] + 2))
    from jax.experimental import enable_x64
    with enable_x64():
        x, fun, status, niter, basis = jax.tree_util.tree_map(
            np.asarray,
            _solve_batch_jit(jnp.asarray(A, jnp.float64),
                             jnp.asarray(b, jnp.float64),
                             jnp.asarray(c_full, jnp.float64),
                             nv=nv, maxiter=maxiter, tol=tol))
    return BatchLPResult(x=np.asarray(x, np.float64),
                         fun=np.asarray(fun, np.float64),
                         status=np.asarray(status, np.int64),
                         niter=np.asarray(niter, np.int64),
                         basis=np.asarray(basis))
