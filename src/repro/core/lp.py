"""Dense two-phase primal simplex, implemented twice from one design:

  * ``backend="jax"``   — fully jittable (`lax.while_loop` pivots, fixed-shape
    tableau).  This is the production path: the scheduler can run on-device
    next to the serving loop, and AMR^2 needs a *basic* optimal solution
    (Lemma 1 counts basic variables), which simplex — unlike interior-point —
    guarantees.
  * ``backend="numpy"`` — the same algorithm in float64 NumPy, used as the
    reference/oracle in tests and for very ill-conditioned instances.

Problem form:   minimize    c @ x
                subject to  A_ub @ x <= b_ub
                            A_eq @ x == b_eq
                            x >= 0

Phase 1 gives every row an artificial variable (initial basis), minimizes
their sum, and "drives out" artificials that linger in the basis at level 0
by prioritising their rows in the ratio test.  Phase 2 masks artificial
columns from ever re-entering.

Anti-cycling: the entering rule is Dantzig's (most negative reduced cost)
until ``bland_after`` consecutive degenerate (zero-improvement) pivots have
run, then Bland's rule (smallest eligible index) takes over until a
non-degenerate pivot resets the counter.  Together with the leaving
tie-break (smallest basic-variable index among min-ratio ties) this makes
every stall finite — Bland's theorem — in both backends.

Warm starts: consecutive fleet periods solve near-identical instances, so
`solve_lp` / `solve_lp_batch` accept the previous period's optimal basis
(``warm_basis``).  The warm path factors the basis once (one batched
``jnp.linalg.solve``), prices the full tableau out of it, skips phase 1
entirely when the basis is still primal feasible, and runs phase-2 pivots
from there — a revised-simplex start, typically 0–4 pivots instead of the
~R phase-1 + phase-2 pivots of a cold solve.  Lanes whose basis is rejected
(stale indices, singular/ill-conditioned factor, primal infeasible) fall
back to the existing two-phase path.  The batched pivot itself is a rank-1
update across the fleet dimension: ``impl="jnp"`` (default) uses the shared
`kernels/simplex_pivot/ref.py` update, ``impl="pallas"`` routes through the
`kernels/simplex_pivot` TPU kernel.

Reduced-tableau revised simplex (``method="revised"`` on
`simplex_batch_core` / `solve_lp_batch`): for the few-constraint /
many-column fleet LP (R = n+2 rows vs C0 = n(m+1)+2 columns) the dense
(R+1, C0+1) tableau is mostly dead weight — each lane only ever needs the
(R, R) basis inverse.  The revised path (`_revised_core`) carries exactly
that factor plus the basic solution, prices entering columns on demand
from the ORIGINAL column data (one BTRAN + a (R, C0) product per
iteration), and maintains the factor across pivots with product-form (eta)
rank-1 updates — `_batched_inverse` runs once per warm start, never per
pivot, and the C0-wide tableau is never materialized.  Selection rules,
warm/cold/rejection semantics, statuses and pivot counts match the tableau
path (the parity tests pin status/basis/niter exactly and x/fun to solver
tolerance); summation orders differ, so results are not bit-identical.

Iteration budget: ``maxiter`` caps the TWO-PHASE TOTAL — phase 2 resumes
phase 1's counter — so an explicit user cap is respected exactly (shape-
derived defaults are pow2-bucketed for trace reuse; user values never
are).

Statuses: 0 optimal, 1 iteration limit, 2 infeasible, 3 unbounded.  Phase-1
non-convergence propagates (a maxiter-capped phase 1 can neither certify
feasibility nor hand phase 2 a valid basis, so the result is reported as
ITERATION_LIMIT rather than silently "optimal").
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .types import next_pow2

OPTIMAL, ITERATION_LIMIT, INFEASIBLE, UNBOUNDED = 0, 1, 2, 3

# Consecutive degenerate pivots tolerated before the entering rule switches
# from Dantzig to Bland.  Degenerate stalls shorter than this are common and
# harmless; a genuine cycle never improves the objective, so it cannot
# outlive the switch.
BLAND_AFTER = 8


def _bucket_maxiter(maxiter: int) -> int:
    """Round a shape-derived default maxiter UP to a power of two.

    `maxiter` is a static argname of the jitted solvers, so leaving it as
    the raw `50 * (rows + 2)` makes every distinct padded job count retrace
    the (vmapped) simplex; bucketing keeps the trace-key count at O(log)
    — mirroring `plan_batch`'s batch-axis bucketing — and only ever raises
    the iteration budget."""
    return next_pow2(maxiter)


@dataclasses.dataclass
class LPResult:
    x: np.ndarray
    fun: float
    status: int
    niter: int
    basis: np.ndarray  # row -> basic variable index
    warm: bool = False  # True when a warm_basis start was accepted

    @property
    def success(self) -> bool:
        return self.status == OPTIMAL


@dataclasses.dataclass
class BatchLPResult:
    """`solve_lp_batch` output: leading batch axis on every field."""
    x: np.ndarray        # (B, nv)
    fun: np.ndarray      # (B,)
    status: np.ndarray   # (B,) int
    niter: np.ndarray    # (B,) int
    basis: np.ndarray    # (B, R) int
    warm: Optional[np.ndarray] = None  # (B,) bool: warm start accepted

    def __len__(self) -> int:
        return self.x.shape[0]

    def __getitem__(self, b: int) -> LPResult:
        return LPResult(x=self.x[b], fun=float(self.fun[b]),
                        status=int(self.status[b]), niter=int(self.niter[b]),
                        basis=self.basis[b],
                        warm=bool(self.warm[b]) if self.warm is not None
                        else False)


# --------------------------------------------------------------------------
# Canonicalisation shared by both backends
# --------------------------------------------------------------------------
def _canonicalize(c, A_ub, b_ub, A_eq, b_eq):
    c = np.asarray(c, dtype=np.float64)
    nv = c.shape[0]
    rows = []
    rhs = []
    n_ub = 0
    if A_ub is not None:
        A_ub = np.asarray(A_ub, dtype=np.float64)
        b_ub = np.asarray(b_ub, dtype=np.float64)
        n_ub = A_ub.shape[0]
        rows.append(np.concatenate([A_ub, np.eye(n_ub)], axis=1))
        rhs.append(b_ub)
    if A_eq is not None:
        A_eq = np.asarray(A_eq, dtype=np.float64)
        b_eq = np.asarray(b_eq, dtype=np.float64)
        pad = np.zeros((A_eq.shape[0], n_ub))
        rows.append(np.concatenate([A_eq, pad], axis=1))
        rhs.append(b_eq)
    A = np.concatenate(rows, axis=0)
    b = np.concatenate(rhs, axis=0)
    # b >= 0 by row flips
    neg = b < 0
    A[neg] *= -1.0
    b[neg] *= -1.0
    c_full = np.concatenate([c, np.zeros(n_ub)])
    return A, b, c_full, nv, n_ub


# --------------------------------------------------------------------------
# JAX backend
# --------------------------------------------------------------------------
def _simplex_phase(tableau, basis, art_start, *, maxiter: int,
                   tol: float = 1e-7, bland_after: int = BLAND_AFTER,
                   it0=None):
    """Run pivots until optimal / maxiter / unbounded.

    tableau: (R+1, C+1); last row = objective (reduced costs | -obj value),
    last col = rhs.  basis: (R,) int32.  art_start: first artificial column
    (artificials may never enter; in phase 2 their rows get ratio priority
    so any basic artificial is driven out before it could turn positive).
    ``it0`` (scalar int32) seeds the iteration counter: phase 2 resumes
    phase 1's count so ``maxiter`` caps the two-phase TOTAL — an explicit
    user cap is respected exactly, never doubled.  The returned count is
    cumulative.
    """
    R = tableau.shape[0] - 1
    C = tableau.shape[1] - 1
    cols = jnp.arange(C)

    def cond(state):
        tab, basis, it, status, degen = state
        rc = tab[-1, :C]
        can_enter = (rc < -tol) & (cols < art_start)
        return (status == ITERATION_LIMIT) & jnp.any(can_enter) & (it < maxiter)

    def body(state):
        tab, basis, it, status, degen = state
        rc = tab[-1, :C]
        enter_mask = (rc < -tol) & (cols < art_start)
        # Dantzig rule while pivots improve the objective; after
        # `bland_after` consecutive degenerate pivots switch to Bland's
        # smallest-index rule (with the smallest-basis-index leaving
        # tie-break below, Bland's theorem rules out cycling).
        score = jnp.where(enter_mask, rc, jnp.inf)
        j_dantzig = jnp.argmin(score)
        j_bland = jnp.argmax(enter_mask)          # first eligible index
        j = jnp.where(degen >= bland_after, j_bland, j_dantzig)

        col = tab[:R, j]
        rhsv = tab[:R, -1]
        pos = col > tol
        ratio = jnp.where(pos, rhsv / jnp.where(pos, col, 1.0), jnp.inf)
        # Drive-out rule: a basic artificial sitting at level ~0 with a
        # nonzero pivot coefficient gets ratio 0 so it leaves the basis
        # first (it must not be allowed to turn positive again).
        art_basic = ((basis >= art_start) & (jnp.abs(col) > tol)
                     & (rhsv <= tol))
        ratio = jnp.where(art_basic, 0.0, ratio)
        unbounded = ~jnp.any(ratio < jnp.inf)
        # lexicographic-ish tie-break: smallest basis index among min ratios
        rmin = jnp.min(ratio)
        tie = ratio <= rmin + jnp.maximum(jnp.abs(rmin) * 1e-9, 1e-12)
        r = jnp.argmin(jnp.where(tie, basis, jnp.iinfo(jnp.int32).max))

        piv = tab[r, j]
        piv_row = tab[r] / piv
        tab2 = tab - jnp.outer(tab[:, j], piv_row)
        tab2 = tab2.at[r].set(piv_row)
        basis2 = basis.at[r].set(j.astype(basis.dtype))

        tab2 = jnp.where(unbounded, tab, tab2)
        basis2 = jnp.where(unbounded, basis, basis2)
        status2 = jnp.where(unbounded, UNBOUNDED, status)
        degen2 = jnp.where(unbounded, degen,
                           jnp.where(rmin <= tol, degen + 1,
                                     jnp.zeros_like(degen)))
        return tab2, basis2, it + 1, status2, degen2

    init = (tableau, basis,
            jnp.array(0, jnp.int32) if it0 is None else it0,
            jnp.array(ITERATION_LIMIT, jnp.int32), jnp.array(0, jnp.int32))
    tab, basis, it, status, _ = jax.lax.while_loop(cond, body, init)
    rc = tab[-1, :C]
    done = ~jnp.any((rc < -tol) & (cols < art_start))
    status = jnp.where((status == ITERATION_LIMIT) & done, OPTIMAL, status)
    return tab, basis, it, status


def _solve_core(A_j, b_j, c_j, nv, maxiter, tol, bland_after=BLAND_AFTER):
    """Pure-jnp two-phase simplex on one canonicalised instance.

    Shapes are static given (R, C0), so this traces once per problem shape
    and is `jax.vmap`-able over a leading batch axis (see `solve_lp_batch`).
    """
    R, C0 = A_j.shape         # C0 = nv + n_slack
    C = C0 + R                # + artificials
    dtype = A_j.dtype
    tab = jnp.zeros((R + 1, C + 1), dtype)
    tab = tab.at[:R, :C0].set(A_j)
    tab = tab.at[:R, C0:C].set(jnp.eye(R, dtype=dtype))
    tab = tab.at[:R, -1].set(b_j)
    # phase-1 objective: sum of artificials, expressed in reduced-cost form
    tab = tab.at[-1, :].set(-jnp.sum(tab[:R, :], axis=0))
    tab = tab.at[-1, C0:C].set(0.0)
    basis = jnp.arange(C0, C, dtype=jnp.int32)

    tab, basis, it1, status1 = _simplex_phase(
        tab, basis, jnp.array(C0, jnp.int32), maxiter=maxiter, tol=tol,
        bland_after=bland_after)
    phase1_obj = tab[-1, -1]  # = -(sum of artificials)
    infeasible = phase1_obj < -max(tol, 1e-5) * (1.0 + jnp.abs(b_j).sum())

    # phase 2: swap in the real objective
    obj = jnp.zeros((C + 1,), dtype)
    obj = obj.at[:C0].set(c_j)
    # make reduced costs of basic columns zero
    cb = obj[basis]                       # cost of basic vars
    obj = obj - cb @ tab[:R, :]
    tab = tab.at[-1, :].set(obj)
    # phase 2 resumes phase 1's iteration count: one shared maxiter budget
    tab, basis, it2, status2 = _simplex_phase(
        tab, basis, jnp.array(C0, jnp.int32), maxiter=maxiter, tol=tol,
        bland_after=bland_after, it0=it1)

    x = jnp.zeros((C,), dtype).at[basis].set(tab[:R, -1])
    fun = -tab[-1, -1]
    # A capped phase 1 can neither certify infeasibility nor hand phase 2 a
    # valid starting basis: propagate its status instead of trusting the
    # phase-2 verdict built on top of it.
    status = jnp.where(status1 != OPTIMAL, status1,
                       jnp.where(infeasible, INFEASIBLE, status2))
    return x[:nv], fun, status, it2, basis


def _solve_jax(A, b, c_full, nv, n_slack, maxiter, tol, bland_after):
    dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    return _solve_single_jit(jnp.asarray(A, dtype), jnp.asarray(b, dtype),
                             jnp.asarray(c_full, dtype), nv=nv,
                             maxiter=maxiter, tol=tol,
                             bland_after=bland_after)


@partial(jax.jit, static_argnames=("nv", "maxiter", "tol", "bland_after"))
def _solve_single_jit(A_j, b_j, c_j, *, nv, maxiter, tol,
                      bland_after=BLAND_AFTER):
    return _solve_core(A_j, b_j, c_j, nv, maxiter, tol, bland_after)


@partial(jax.jit, static_argnames=("nv", "maxiter", "tol", "bland_after"))
def _solve_batch_jit(A_j, b_j, c_j, *, nv, maxiter, tol,
                     bland_after=BLAND_AFTER):
    return jax.vmap(
        lambda A1, b1, c1: _solve_core(A1, b1, c1, nv, maxiter, tol,
                                       bland_after)
    )(A_j, b_j, c_j)


# --------------------------------------------------------------------------
# Warm-started revised simplex (batched)
# --------------------------------------------------------------------------
def _pivot_update_batch(tabs, r, j, mask, impl: str):
    """One rank-1 pivot across the whole lane stack.

    ``impl="jnp"`` uses the shared reference update; ``impl="pallas"``
    routes through the `kernels/simplex_pivot` TPU kernel (interpret mode
    off-TPU, like `cckp_dp`)."""
    if impl == "pallas":
        from ..kernels.simplex_pivot import ops as _pivot_ops
        return _pivot_ops.pivot_update(tabs, r, j, mask)
    from ..kernels.simplex_pivot.ref import pivot_update_ref
    return pivot_update_ref(tabs, r, j, mask)


def _phase_batched(tabs, bases, art_start: int, *, maxiter: int, tol: float,
                   bland_after: int, impl: str, it0=None):
    """Masked batched simplex phase over stacked tableaus (B, R+1, C+1).

    Per-lane semantics match `_simplex_phase` (Dantzig entering with the
    Bland fallback, smallest-basis-index leaving tie-break, artificial
    drive-out) but every iteration pivots ALL still-active lanes at once —
    the rank-1 update runs across the fleet dimension in one call
    (`_pivot_update_batch`), which is what the `simplex_pivot` Pallas
    kernel accelerates.  ``it0`` (B,) int32 seeds the per-lane iteration
    counters (shared two-phase maxiter budget; see `_simplex_phase`)."""
    B, R1, C1 = tabs.shape
    R, C = R1 - 1, C1 - 1
    cols = jnp.arange(C)
    intmax = jnp.iinfo(jnp.int32).max

    def cond(state):
        tabs, bases, it, status, degen = state
        return jnp.any((status == ITERATION_LIMIT) & (it < maxiter))

    def body(state):
        tabs, bases, it, status, degen = state
        rc = tabs[:, -1, :C]                              # (B, C)
        enter_mask = (rc < -tol) & (cols[None, :] < art_start)
        has_enter = enter_mask.any(axis=1)
        running = status == ITERATION_LIMIT
        status = jnp.where(running & ~has_enter, OPTIMAL, status)
        active = running & has_enter & (it < maxiter)

        score = jnp.where(enter_mask, rc, jnp.inf)
        j_dantzig = jnp.argmin(score, axis=1)
        j_bland = jnp.argmax(enter_mask, axis=1)
        j = jnp.where(degen >= bland_after, j_bland,
                      j_dantzig).astype(jnp.int32)

        col = jnp.take_along_axis(tabs[:, :R, :], j[:, None, None],
                                  axis=2)[..., 0]         # (B, R)
        rhsv = tabs[:, :R, -1]
        pos = col > tol
        ratio = jnp.where(pos, rhsv / jnp.where(pos, col, 1.0), jnp.inf)
        art_basic = ((bases >= art_start) & (jnp.abs(col) > tol)
                     & (rhsv <= tol))
        ratio = jnp.where(art_basic, 0.0, ratio)
        unbounded = ~jnp.any(ratio < jnp.inf, axis=1)
        rmin = jnp.min(ratio, axis=1)
        tie = ratio <= (rmin + jnp.maximum(jnp.abs(rmin) * 1e-9,
                                           1e-12))[:, None]
        r = jnp.argmin(jnp.where(tie, bases, intmax),
                       axis=1).astype(jnp.int32)

        do_pivot = active & ~unbounded
        tabs = _pivot_update_batch(tabs, r, j, do_pivot, impl)
        is_r = jnp.arange(R)[None, :] == r[:, None]
        bases = jnp.where(do_pivot[:, None] & is_r, j[:, None], bases)
        status = jnp.where(active & unbounded, UNBOUNDED, status)
        degen = jnp.where(do_pivot,
                          jnp.where(rmin <= tol, degen + 1,
                                    jnp.zeros_like(degen)), degen)
        return tabs, bases, it + active.astype(it.dtype), status, degen

    init = (tabs, bases,
            jnp.zeros(B, jnp.int32) if it0 is None else it0,
            jnp.full(B, ITERATION_LIMIT, jnp.int32), jnp.zeros(B, jnp.int32))
    tabs, bases, it, status, _ = jax.lax.while_loop(cond, body, init)
    rc = tabs[:, -1, :C]
    done = ~((rc < -tol) & (cols[None, :] < art_start)).any(axis=1)
    status = jnp.where((status == ITERATION_LIMIT) & done, OPTIMAL, status)
    return tabs, bases, it, status


def _batched_inverse(Bmat):
    """Gauss-Jordan inverse with partial pivoting, vectorized across the
    lane axis: (B, R, R) -> (B, R, R).

    XLA:CPU's batched `jnp.linalg.solve` costs ~4 ms for 256 14x14 lanes
    (it serializes the per-lane LAPACK calls) — an R-step fori_loop of
    whole-batch rank-1 eliminations is ~5x cheaper at fleet sizes and is
    exactly the same shaped work as the simplex pivots that follow.
    Singular lanes come out inf/nan and are caught by the caller's
    residual check."""
    B, R, _ = Bmat.shape
    dtype = Bmat.dtype
    eye = jnp.broadcast_to(jnp.eye(R, dtype=dtype), (B, R, R))
    aug = jnp.concatenate([Bmat, eye], axis=2)             # (B, R, 2R)
    rows = jnp.arange(R)

    def body(k, aug):
        col = jax.lax.dynamic_index_in_dim(aug, k, axis=2, keepdims=False)
        cand = jnp.where(rows[None, :] >= k, jnp.abs(col), -1.0)
        p = jnp.argmax(cand, axis=1)                       # pivot row
        row_p = jnp.take_along_axis(aug, p[:, None, None], axis=1)[:, 0]
        row_k = jax.lax.dynamic_index_in_dim(aug, k, axis=1,
                                             keepdims=False)
        is_k = rows[None, :] == k
        is_p = rows[None, :] == p[:, None]
        aug = jnp.where(is_k[:, :, None], row_p[:, None, :], aug)
        aug = jnp.where((is_p & ~is_k)[:, :, None], row_k[:, None, :], aug)
        piv_row = jax.lax.dynamic_index_in_dim(aug, k, axis=1,
                                               keepdims=False)
        piv = jax.lax.dynamic_index_in_dim(piv_row, k, axis=1,
                                           keepdims=True)
        piv_row = piv_row / piv
        colv = jax.lax.dynamic_index_in_dim(aug, k, axis=2,
                                            keepdims=False)
        new = aug - colv[:, :, None] * piv_row[:, None, :]
        return jnp.where(is_k[:, :, None], piv_row[:, None, :], new)

    aug = jax.lax.fori_loop(0, R, body, aug)
    return aug[:, :, R:]


def _warm_init_reduced(A, b, basis0):
    """Factor each lane's previous basis and repair primal infeasibility,
    in REDUCED (basis-inverse) form.

    One batched factor (`_batched_inverse`) per lane; rows the basis
    leaves infeasible on the new data (negative transformed rhs) are
    sign-flipped — the flip is applied to the Binv ROW, which distributes
    exactly over the later ``Binv @ A`` / pricing products — and handed a
    VIRTUAL tableau-space artificial (basis label C0 + row, column never
    materialized), so phase 1 shrinks to ~#violated-rows repair pivots —
    zero when the basis is still feasible.

    Returns ``(Binv (B, R, R), rhs (B, R), bas (B, R) int32, ok (B,))``;
    lanes with ``ok`` False (out-of-range / -1 basis rows — a device that
    switched solver or sat out an outage — or a singular/ill-conditioned
    factor) hold garbage and must run cold.  Shared by `_warm_init` (the
    dense-tableau paths) and `_revised_core` so the accept thresholds and
    repair semantics cannot drift apart."""
    B, R, C0 = A.shape
    dtype = A.dtype
    bas = jnp.clip(basis0, 0, C0 - 1).astype(jnp.int32)
    in_range = (basis0 >= 0).all(axis=1) & (basis0 < C0).all(axis=1)

    Bmat = jnp.take_along_axis(A, bas[:, None, :], axis=2)     # (B, R, R)
    Binv = _batched_inverse(Bmat)
    resid = jnp.max(jnp.abs(Bmat @ Binv - jnp.eye(R, dtype=dtype)),
                    axis=(1, 2))
    rhs = (Binv @ b[..., None])[..., 0]                        # (B, R)

    # f32 (global x64 off, single-instance path) carries ~1e-7 relative
    # noise through the factor-solve: loosen the accept thresholds so a
    # basic variable sitting numerically at 0 does not bounce the basis
    feas_tol, resid_tol = (1e-9, 1e-6) if dtype == jnp.float64 \
        else (1e-5, 1e-3)
    ok = in_range & jnp.isfinite(resid) & (resid < resid_tol)

    # feasibility repair: flip violated rows; each flipped row's virtual
    # artificial goes basic (label C0 + row)
    flip = rhs < -feas_tol                                     # (B, R)
    sgn = jnp.where(flip, -1.0, 1.0)
    Binv = Binv * sgn[:, :, None]
    rhs = jnp.maximum(rhs * sgn, 0.0)      # clamp -feas_tol..0 dust to 0
    rows = jnp.arange(R, dtype=jnp.int32)
    bas = jnp.where(flip, C0 + rows[None, :], bas)
    return Binv, rhs, bas.astype(jnp.int32), ok


def _warm_init(A, b, basis0):
    """`_warm_init_reduced` expanded to dense-tableau form: the repaired
    factor prices the full tableau (``tabA = Binv @ A``) for the
    `_phase_batched` paths.  Because the repair sign-flips distribute
    exactly over the row sums (IEEE negation is exact), this is
    bit-identical to flipping the priced tableau's rows directly.

    Returns ``(tabA (B, R, C0), rhs (B, R), bas (B, R) int32, ok (B,))``;
    shared by `_warm_batch_jit` (host dispatch) and `simplex_batch_core`
    (the traced engine path)."""
    Binv, rhs, bas, ok = _warm_init_reduced(A, b, basis0)
    return Binv @ A, rhs, bas, ok


def _two_phase_virtual(tabA, rhs, bas, b, c_full, *, nv, maxiter, tol,
                       bland_after, impl, lane_mask=None):
    """Both simplex phases over virtual-artificial tableaus.

    Builds the (B, R+1, C0+1) tableau stack from per-lane rows/rhs and a
    basis whose artificial members are LABELS >= C0 (columns never
    materialized — they may never enter, and drive-out/pricing only read
    labels), runs phase 1 (minimize the sum of artificial-basis rows, in
    reduced-cost form), swaps in the real objective priced out over the
    resulting basis, runs phase 2, and extracts the solution by
    scatter-add (clipped virtual labels contribute 0, so they cannot
    clobber a real basic variable's slot).  ``lane_mask`` False zeroes a
    lane's tableau — no entering column, 0 pivots, garbage x.

    The ONE definition of the warm/cold two-phase pipeline, shared by
    `_warm_batch_jit` and `simplex_batch_core`: the phase-1 infeasibility
    certificate and status propagation live here only.

    Returns ``(x (B, nv), fun, status, niter, bases)``."""
    B, R, C0 = tabA.shape
    dtype = tabA.dtype
    tabs = jnp.zeros((B, R + 1, C0 + 1), dtype)
    tabs = tabs.at[:, :R, :C0].set(tabA)
    tabs = tabs.at[:, :R, -1].set(rhs)
    # phase-1 objective: -(sum of artificial-basis rows) — for a cold lane
    # (every row's basis virtual) this is `_solve_core`'s -sum(rows)
    art_row = (bas >= C0).astype(dtype)
    p1 = -jnp.einsum("br,brc->bc", art_row, tabs[:, :R, :])
    tabs = tabs.at[:, -1, :].set(p1)
    if lane_mask is not None:
        tabs = jnp.where(lane_mask[:, None, None], tabs, 0.0)

    tabs, bases, it1, status1 = _phase_batched(
        tabs, bas, C0, maxiter=maxiter, tol=tol, bland_after=bland_after,
        impl=impl)
    phase1_obj = tabs[:, -1, -1]           # = -(sum of basic artificials)
    infeasible = phase1_obj < -max(tol, 1e-5) * (
        1.0 + jnp.abs(b).sum(axis=1))

    # phase 2: swap in the real objective, priced out over the basis
    # (virtual artificial labels price at cost 0)
    obj = jnp.zeros((B, C0 + 1), dtype)
    obj = obj.at[:, :C0].set(c_full)
    cb = jnp.where(bases < C0,
                   jnp.take_along_axis(obj[:, :C0],
                                       jnp.clip(bases, 0, C0 - 1), axis=1),
                   0.0)                                        # (B, R)
    obj = obj - jnp.einsum("br,brc->bc", cb, tabs[:, :R, :])
    if lane_mask is not None:
        # keep masked lanes inert in phase 2 too: a real objective row on
        # a zeroed tableau would otherwise spend one "unbounded" pivot
        obj = jnp.where(lane_mask[:, None], obj, 0.0)
    tabs = tabs.at[:, -1, :].set(obj)
    # phase 2 resumes phase 1's per-lane counts: one shared maxiter budget
    tabs, bases, it2, status2 = _phase_batched(
        tabs, bases, C0, maxiter=maxiter, tol=tol, bland_after=bland_after,
        impl=impl, it0=it1)

    vals = jnp.where(bases < C0, tabs[:, :R, -1], 0.0)
    x = jnp.zeros((B, C0), dtype)
    x = x.at[jnp.arange(B)[:, None], jnp.clip(bases, 0, C0 - 1)].add(vals)
    fun = -tabs[:, -1, -1]
    status = jnp.where(status1 != OPTIMAL, status1,
                       jnp.where(infeasible, INFEASIBLE, status2))
    return x[:, :nv], fun, status, it2, bases


# --------------------------------------------------------------------------
# Reduced-tableau revised simplex (batched)
# --------------------------------------------------------------------------
def _reduced_pivot_batch(A, c_phase, Binv, xB, bas, use_bland, may_pivot,
                         lane_ok, art_cost, tol, impl: str):
    """One fused revised-simplex iteration across the whole lane stack.

    ``impl="jnp"`` uses the shared reference op; ``impl="pallas"`` routes
    through the fused `kernels/simplex_pivot.reduced_pivot` TPU kernel
    (interpret mode off-TPU, like the dense pivot)."""
    if impl == "pallas":
        from ..kernels.simplex_pivot import ops as _pivot_ops
        return _pivot_ops.reduced_pivot(A, c_phase, Binv, xB, bas,
                                        use_bland, may_pivot, lane_ok,
                                        art_cost=art_cost, tol=tol)
    from ..kernels.simplex_pivot.ref import reduced_pivot_ref
    return reduced_pivot_ref(A, c_phase, Binv, xB, bas, use_bland,
                             may_pivot, lane_ok, art_cost=art_cost,
                             tol=tol)


def _revised_phase(A, c_phase, Binv, xB, bas, *, art_cost: float,
                   maxiter: int, tol: float, bland_after: int, impl: str,
                   lane_ok, it0=None):
    """Masked batched simplex phase in REDUCED form: only the (R, R)
    basis-inverse factor and the basic solution are carried per lane;
    every iteration prices all C0 columns on demand out of the factor and
    applies the product-form (eta) rank-1 update — the C0-wide tableau of
    `_phase_batched` is never materialized.

    Per-lane selection rules (Dantzig entering with the Bland fallback,
    smallest-basis-index leaving tie-break, artificial drive-out) and the
    status/iteration bookkeeping match `_phase_batched`; ``art_cost`` is
    the phase cost of virtual artificial labels (1 in phase 1, 0 in
    phase 2) and ``it0`` seeds the per-lane counters (shared two-phase
    maxiter budget)."""
    from ..kernels.simplex_pivot.ref import price_reduced_ref
    B = A.shape[0]
    lane_ok = (jnp.ones(B, dtype=bool) if lane_ok is None
               else jnp.asarray(lane_ok, dtype=bool))

    def cond(state):
        Binv, xB, bas, it, status, degen = state
        return jnp.any((status == ITERATION_LIMIT) & (it < maxiter))

    def body(state):
        Binv, xB, bas, it, status, degen = state
        running = status == ITERATION_LIMIT
        Binv2, xB2, bas2, has_enter, unbounded, degen_piv = \
            _reduced_pivot_batch(A, c_phase, Binv, xB, bas,
                                 degen >= bland_after,
                                 running & (it < maxiter), lane_ok,
                                 art_cost, tol, impl)
        status = jnp.where(running & ~has_enter, OPTIMAL, status)
        active = running & has_enter & (it < maxiter)
        status = jnp.where(active & unbounded, UNBOUNDED, status)
        do_pivot = active & ~unbounded
        degen = jnp.where(do_pivot,
                          jnp.where(degen_piv, degen + 1,
                                    jnp.zeros_like(degen)), degen)
        return (Binv2, xB2, bas2, it + active.astype(it.dtype), status,
                degen)

    init = (Binv, xB, bas,
            jnp.zeros(B, jnp.int32) if it0 is None else it0,
            jnp.full(B, ITERATION_LIMIT, jnp.int32), jnp.zeros(B, jnp.int32))
    Binv, xB, bas, it, status, _ = jax.lax.while_loop(cond, body, init)
    rc = price_reduced_ref(A, c_phase, Binv, bas, art_cost)
    done = ~((rc < -tol) & lane_ok[:, None]).any(axis=1)
    status = jnp.where((status == ITERATION_LIMIT) & done, OPTIMAL, status)
    return Binv, xB, bas, it, status


def _revised_two_phase(A, b, c_full, Binv, xB, bas, *, nv, maxiter, tol,
                       bland_after, impl, lane_mask=None):
    """Both simplex phases in reduced form (`_two_phase_virtual`'s twin).

    Phase 1 minimizes the sum of basic virtual artificials (real columns
    cost 0, artificial labels cost 1), phase 2 prices the real objective;
    the infeasibility certificate reads the basic-artificial levels off
    ``xB`` directly (the reduced form of the tableau's phase-1 objective
    cell).  ``lane_mask`` False lanes never produce an entering column —
    0 pivots, OPTIMAL status, x = 0 — matching the zeroed-tableau
    contract.  Returns ``(x (B, nv), fun, status, niter, bases)``."""
    B, R, C0 = A.shape
    dtype = A.dtype
    Binv, xB, bas, it1, status1 = _revised_phase(
        A, jnp.zeros_like(c_full), Binv, xB, bas, art_cost=1.0,
        maxiter=maxiter, tol=tol, bland_after=bland_after, impl=impl,
        lane_ok=lane_mask)
    art_sum = jnp.sum(jnp.where(bas >= C0, xB, 0.0), axis=1)
    infeasible = art_sum > max(tol, 1e-5) * (1.0 + jnp.abs(b).sum(axis=1))
    if lane_mask is not None:
        infeasible = infeasible & lane_mask

    # phase 2 resumes phase 1's per-lane counts: one shared maxiter budget
    Binv, xB, bas, it2, status2 = _revised_phase(
        A, c_full, Binv, xB, bas, art_cost=0.0, maxiter=maxiter, tol=tol,
        bland_after=bland_after, impl=impl, lane_ok=lane_mask, it0=it1)

    vals = jnp.where(bas < C0, xB, 0.0)
    x = jnp.zeros((B, C0), dtype)
    x = x.at[jnp.arange(B)[:, None], jnp.clip(bas, 0, C0 - 1)].add(vals)
    cb = jnp.where(bas < C0,
                   jnp.take_along_axis(c_full, jnp.clip(bas, 0, C0 - 1),
                                       axis=1), 0.0)
    fun = jnp.sum(cb * vals, axis=1)
    if lane_mask is not None:
        fun = jnp.where(lane_mask, fun, 0.0)
    status = jnp.where(status1 != OPTIMAL, status1,
                       jnp.where(infeasible, INFEASIBLE, status2))
    return x[:, :nv], fun, status, it2, bas


def _revised_core(A, b, c_full, basis0, *, nv, maxiter, tol,
                  bland_after=BLAND_AFTER, impl="jnp", lane_mask=None):
    """Traceable warm-OR-cold batched revised simplex — the
    ``method="revised"`` body of `simplex_batch_core`, with the same
    start/rejection semantics: a cold lane's factor is the identity
    (xB = b, every row basic on its virtual artificial) and a warm lane
    reuses its repaired `_warm_init_reduced` factor; rejected lanes start
    cold in the same call.  Returns the `simplex_batch_core` tuple."""
    B, R, C0 = A.shape
    dtype = A.dtype
    rows = jnp.arange(R, dtype=jnp.int32)
    bas_c = jnp.broadcast_to(C0 + rows[None, :], (B, R)).astype(jnp.int32)
    eye = jnp.broadcast_to(jnp.eye(R, dtype=dtype), (B, R, R))

    if basis0 is None:
        warm_ok = jnp.zeros(B, dtype=bool)
        Binv, xB, bas = eye, b, bas_c
    else:
        Binv_w, rhs_w, bas_w, warm_ok = _warm_init_reduced(A, b, basis0)
        Binv = jnp.where(warm_ok[:, None, None], Binv_w, eye)
        xB = jnp.where(warm_ok[:, None], rhs_w, b)
        bas = jnp.where(warm_ok[:, None], bas_w, bas_c)

    x, fun, status, niter, bases = _revised_two_phase(
        A, b, c_full, Binv, xB, bas, nv=nv, maxiter=maxiter, tol=tol,
        bland_after=bland_after, impl=impl, lane_mask=lane_mask)
    return x, fun, status, niter, bases, warm_ok


@partial(jax.jit,
         static_argnames=("nv", "maxiter", "tol", "bland_after", "impl"))
def _revised_batch_jit(A_j, b_j, c_j, basis0, *, nv, maxiter, tol,
                       bland_after=BLAND_AFTER, impl="jnp"):
    """Jitted `_revised_core` for the `solve_lp_batch(method="revised")`
    host dispatch (warm and cold lanes resolve in ONE call — no separate
    rejected-subset re-solve)."""
    return _revised_core(A_j, b_j, c_j, basis0, nv=nv, maxiter=maxiter,
                         tol=tol, bland_after=bland_after, impl=impl)


@partial(jax.jit,
         static_argnames=("nv", "maxiter", "tol", "bland_after", "impl"))
def _warm_batch_jit(A_j, b_j, c_j, basis0, *, nv, maxiter, tol,
                    bland_after=BLAND_AFTER, impl="jnp"):
    """Revised-simplex warm start from a previous optimal basis
    (`_warm_init` + `_two_phase_virtual`).

    Returns ``(x, fun, status, niter, basis, ok)``; lanes with ``ok``
    False (out-of-range basis indices or a singular/ill-conditioned
    factor) hold garbage and must be re-solved by the cold two-phase
    path — `solve_lp_batch` dispatches them to `_solve_batch_jit` on a
    pow2-padded subset (`simplex_batch_core` is the traced alternative
    that runs them cold in the same call)."""
    tabA, rhs, bas, ok = _warm_init(A_j, b_j, basis0)
    # rejected lanes: zero tableau -> no entering column -> 0 pivots spent
    x, fun, status, niter, bases = _two_phase_virtual(
        tabA, rhs, bas, b_j, c_j, nv=nv, maxiter=maxiter, tol=tol,
        bland_after=bland_after, impl=impl, lane_mask=ok)
    return x, fun, status, niter, bases, ok


def simplex_batch_core(A, b, c_full, basis0, *, nv: int, maxiter: int,
                       tol: float = 1e-7, bland_after: int = BLAND_AFTER,
                       impl: str = "jnp", lane_mask=None,
                       method: str = "tableau"):
    """Traceable warm-OR-cold batched two-phase simplex (the scan path).

    Unlike `solve_lp_batch` — which accepts warm lanes via `_warm_batch_jit`
    and re-solves rejected lanes with a second host-dispatched cold call —
    this is ONE pure-jnp function usable inside `jax.jit` / `lax.scan` /
    `shard_map` (the `repro.api.engine` period step): every lane starts
    either from its previous basis (accepted: factor once, sign-flip and
    virtually repair infeasible rows) or from the cold all-artificial
    tableau (rejected / ``basis0`` rows of -1 / ``basis0=None``), and a
    single `_phase_batched` pass runs phase 1 + phase 2 for the whole
    stack.  A warm-feasible lane spends 0 phase-1 pivots; a cold lane runs
    the same pivots `_solve_core` would, so per-lane results are
    bit-comparable with the host `solve_lp_batch` dispatch.

    ALL artificials are virtual (basis LABELS >= C0, columns never
    materialized — the `_warm_batch_jit` trick extended to the cold path:
    a cold lane's initial basis is simply every row's virtual label and
    phase 1 minimizes -sum(rows), exactly `_solve_core`'s start): the
    tableau stays (R+1, C0+1) wide, ~40% less pivot traffic than
    materialized artificial columns, with identical pivot sequences —
    artificials may never enter, and the drive-out/pricing rules only read
    their labels.

    ``basis0=None`` skips the warm factorization entirely (every lane
    cold) — the engine's backpressure replan path.  ``lane_mask`` (B,)
    bool: lanes marked False get a zeroed tableau — no entering column, 0
    pivots, garbage x — for masked sub-batch solves without a host-side
    subset.

    ``method`` selects the pivot representation: ``"tableau"`` (default)
    is the dense (R+1, C0+1) path above, bit-compatible with the existing
    dispatch; ``"revised"`` carries only the (R, R) basis inverse per lane
    (`_revised_core`) — same warm/cold/rejection semantics and selection
    rules, entering columns priced on demand, eta-factor updates instead
    of wide-tableau pivots.  The paths agree on status/basis/pivot counts
    and to solver tolerance on x/fun (pinned by the parity tests), but not
    bit-for-bit — their floating-point summation orders differ.

    Expects canonicalised inputs (``b >= 0``; see `_canonicalize_batch`).
    Returns ``(x (B, nv), fun, status, niter, basis, warm_ok)``.
    """
    if method == "revised":
        return _revised_core(A, b, c_full, basis0, nv=nv, maxiter=maxiter,
                             tol=tol, bland_after=bland_after, impl=impl,
                             lane_mask=lane_mask)
    if method != "tableau":
        raise ValueError(f"unknown simplex method {method!r}; expected "
                         f"'tableau' or 'revised'")
    B, R, C0 = A.shape
    rows = jnp.arange(R, dtype=jnp.int32)
    # cold init: every row basic on its virtual artificial (`_solve_core`)
    bas_c = jnp.broadcast_to(C0 + rows[None, :], (B, R)).astype(jnp.int32)

    if basis0 is None:
        warm_ok = jnp.zeros(B, dtype=bool)
        tabA, rhs, bas = A, b, bas_c
    else:
        tabA_w, rhs_w, bas_w, warm_ok = _warm_init(A, b, basis0)
        # rejected lanes start cold IN the same call (the host dispatch
        # instead zeroes them and re-solves a pow2 subset; _warm_batch_jit)
        tabA = jnp.where(warm_ok[:, None, None], tabA_w, A)
        rhs = jnp.where(warm_ok[:, None], rhs_w, b)
        bas = jnp.where(warm_ok[:, None], bas_w, bas_c)

    x, fun, status, niter, bases = _two_phase_virtual(
        tabA, rhs, bas, b, c_full, nv=nv, maxiter=maxiter, tol=tol,
        bland_after=bland_after, impl=impl, lane_mask=lane_mask)
    return x, fun, status, niter, bases, warm_ok


# --------------------------------------------------------------------------
# Implicit differentiation: custom VJP at the converged basis
# --------------------------------------------------------------------------
class _ImplicitCfg(NamedTuple):
    """Hashable static config for `_simplex_implicit` (nondiff argnum 0)."""
    nv: int
    maxiter: int
    tol: float
    bland_after: int
    impl: str
    method: str


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _simplex_implicit(cfg: _ImplicitCfg, A, b, c_full, basis0, lane_mask):
    return simplex_batch_core(
        A, b, c_full, basis0, nv=cfg.nv, maxiter=cfg.maxiter, tol=cfg.tol,
        bland_after=cfg.bland_after, impl=cfg.impl, lane_mask=lane_mask,
        method=cfg.method)


def _simplex_implicit_fwd(cfg, A, b, c_full, basis0, lane_mask):
    # The pivot loops run UNdifferentiated (they are `lax.while_loop`s and
    # could not be reverse-differentiated anyway); only their *fixed point*
    # — the converged basis — feeds the backward pass.
    out = _simplex_implicit(cfg, A, b, c_full, basis0, lane_mask)
    _, _, status, _, bases, _ = out
    return out, (A, b, c_full, bases, status, basis0, lane_mask)


def _simplex_implicit_bwd(cfg, res, cts):
    from ..kernels.simplex_pivot.ref import kkt_vjp_ref
    gx, gfun = cts[0], cts[1]        # status/niter/bases/warm_ok: int/bool
    A, b, c_full, bases, status, basis0, lane_mask = res
    valid = status == OPTIMAL
    if lane_mask is not None:
        valid = valid & lane_mask
    A_bar, b_bar, c_bar = kkt_vjp_ref(
        A, b, c_full, bases, gx, gfun, valid, nv=cfg.nv)
    f0 = jax.dtypes.float0
    b0_bar = None if basis0 is None else np.zeros(basis0.shape, f0)
    lm_bar = None if lane_mask is None else np.zeros(lane_mask.shape, f0)
    return A_bar, b_bar, c_bar, b0_bar, lm_bar


_simplex_implicit.defvjp(_simplex_implicit_fwd, _simplex_implicit_bwd)


def simplex_batch_grad(A, b, c_full, basis0, *, nv: int, maxiter: int,
                       tol: float = 1e-7, bland_after: int = BLAND_AFTER,
                       impl: str = "jnp", lane_mask=None,
                       method: str = "tableau"):
    """`simplex_batch_core` with an implicit-function VJP attached.

    Forward pass is the SAME traced warm-or-cold two-phase simplex (bitwise
    identical outputs); the backward pass never differentiates the pivot
    loops.  Instead, at the converged basis ``B`` the optimum is locally
    ``x_B = B^{-1} b`` (active-set / KKT view), so cotangents w.r.t.
    ``(A, b, c_full)`` come from one adjoint (R, R) solve per lane
    (`kernels.simplex_pivot.ref.kkt_vjp_ref`) against the SAME basis factor
    the revised method carries.  Integer bookkeeping — ``basis0`` warm
    labels, ``lane_mask`` — gets symbolic-zero (float0) cotangents, and the
    ``status``/``niter``/``bases``/``warm_ok`` outputs are gradient fences:
    nothing differentiable flows through them.

    Caveats (documented, by design):
      * Non-OPTIMAL or masked lanes contribute exactly-zero cotangents
        (their basis is meaningless; the engine layer must not rely on
        gradients through failed lanes).
      * At a DEGENERATE optimal basis the optimum is not differentiable;
        the VJP returns the subgradient selected by the converged basis —
        fine for optimization, not for exact sensitivity audits.
      * The host-dispatched `solve_lp_batch` (NumPy boundary) is NOT
        covered: differentiable callers must stay on this traced path.
    """
    cfg = _ImplicitCfg(nv=nv, maxiter=maxiter, tol=tol,
                       bland_after=bland_after, impl=impl, method=method)
    return _simplex_implicit(cfg, A, b, c_full, basis0, lane_mask)


def _warm_np(A, b, c_full, nv, basis0, maxiter, tol, bland_after):
    """NumPy warm start: same algorithm as `_warm_batch_jit` (basis
    factorization, sign-flip + tableau-space-artificial feasibility
    repair, warm phase 1 + phase 2), one instance.  The oracle path keeps
    the artificial columns materialized — clarity over the batched path's
    virtual-label trick.  Returns an LPResult-tuple or None on basis
    rejection."""
    R, C0 = A.shape
    C = C0 + R
    basis0 = np.asarray(basis0)
    if basis0.shape != (R,) or (basis0 < 0).any() or (basis0 >= C0).any():
        return None
    Bmat = A[:, basis0]
    try:
        Binv = np.linalg.solve(Bmat, np.eye(R))
    except np.linalg.LinAlgError:
        return None
    resid = np.max(np.abs(Bmat @ Binv - np.eye(R)))
    if not np.isfinite(resid) or resid >= 1e-6:
        return None
    rhs = Binv @ b
    tabA = Binv @ A

    flip = rhs < -1e-9                       # feasibility-repair rows
    sgn = np.where(flip, -1.0, 1.0)
    tabA = tabA * sgn[:, None]
    rhs = np.maximum(rhs * sgn, 0.0)
    basis = basis0.astype(np.int64).copy()
    basis[flip] = C0 + np.nonzero(flip)[0]

    tab = np.zeros((R + 1, C + 1))
    tab[:R, :C0] = tabA
    tab[:R, C0:C] = np.eye(R)
    tab[:R, -1] = rhs
    tab[-1, :] = -tab[:R, :][flip].sum(axis=0)
    tab[-1, C0:C] = 0.0
    tab, basis, it1, st1 = _phase_np(tab, basis, C0, maxiter, tol,
                                     bland_after)
    infeasible = tab[-1, -1] < -max(tol, 1e-8) * (1.0 + np.abs(b).sum())

    obj = np.zeros(C + 1)
    obj[:C0] = c_full
    obj = obj - obj[basis] @ tab[:R, :]
    tab[-1, :] = obj
    tab, basis, it2, st2 = _phase_np(tab, basis, C0, maxiter, tol,
                                     bland_after, it0=it1)
    x = np.zeros(C)
    x[basis] = tab[:R, -1]
    if st1 != OPTIMAL:
        status = st1
    else:
        status = INFEASIBLE if infeasible else st2
    return x[:nv], -tab[-1, -1], status, it2, basis


# --------------------------------------------------------------------------
# NumPy backend (float64 reference)
# --------------------------------------------------------------------------
def _phase_np(tab, basis, art_start, maxiter, tol,
              bland_after=BLAND_AFTER, it0=0):
    """``it0`` seeds the iteration counter (cumulative across phases, so
    an explicit ``maxiter`` caps the two-phase total; see
    `_simplex_phase`).  Optimality is checked before the cap — matching
    the jax path's post-loop upgrade."""
    R = tab.shape[0] - 1
    C = tab.shape[1] - 1
    it = it0
    degen = 0
    while True:
        rc = tab[-1, :C]
        enter = np.where((rc < -tol) & (np.arange(C) < art_start))[0]
        if enter.size == 0:
            return tab, basis, it, OPTIMAL
        if it >= maxiter:
            return tab, basis, it, ITERATION_LIMIT
        if degen >= bland_after:
            j = enter[0]                  # Bland: smallest eligible index
        else:
            j = enter[np.argmin(rc[enter])]
        col = tab[:R, j]
        rhs = tab[:R, -1]
        ratio = np.full(R, np.inf)
        pos = col > tol
        ratio[pos] = rhs[pos] / col[pos]
        art_basic = (basis >= art_start) & (np.abs(col) > tol) & (rhs <= tol)
        ratio[art_basic] = 0.0
        if not np.any(ratio < np.inf):
            return tab, basis, it, UNBOUNDED
        rmin = ratio.min()
        tie = ratio <= rmin + max(abs(rmin) * 1e-9, 1e-12)
        cand = np.where(tie)[0]
        r = cand[np.argmin(basis[cand])]
        piv = tab[r, j]
        tab[r] = tab[r] / piv
        for k in range(tab.shape[0]):
            if k != r and abs(tab[k, j]) > 0:
                tab[k] -= tab[k, j] * tab[r]
        basis[r] = j
        degen = degen + 1 if rmin <= tol else 0
        it += 1


def _solve_np(A, b, c_full, nv, n_slack, maxiter, tol,
              bland_after=BLAND_AFTER):
    R, C0 = A.shape
    C = C0 + R
    tab = np.zeros((R + 1, C + 1))
    tab[:R, :C0] = A
    tab[:R, C0:C] = np.eye(R)
    tab[:R, -1] = b
    tab[-1, :] = -tab[:R, :].sum(axis=0)
    tab[-1, C0:C] = 0.0
    basis = np.arange(C0, C, dtype=np.int64)

    tab, basis, it1, st1 = _phase_np(tab, basis, C0, maxiter, tol,
                                     bland_after)
    infeasible = tab[-1, -1] < -max(tol, 1e-8) * (1.0 + np.abs(b).sum())

    obj = np.zeros(C + 1)
    obj[:C0] = c_full
    obj = obj - obj[basis] @ tab[:R, :]
    tab[-1, :] = obj
    tab, basis, it2, st2 = _phase_np(tab, basis, C0, maxiter, tol,
                                     bland_after, it0=it1)

    x = np.zeros(C)
    x[basis] = tab[:R, -1]
    fun = -tab[-1, -1]
    # mirror the jax path: an unconverged phase 1 invalidates both the
    # infeasibility certificate and the phase-2 result
    if st1 != OPTIMAL:
        status = st1
    else:
        status = INFEASIBLE if infeasible else st2
    return x[:nv], fun, status, it2, basis


# --------------------------------------------------------------------------
# public API
# --------------------------------------------------------------------------
def solve_lp(c, A_ub=None, b_ub=None, A_eq=None, b_eq=None, *,
             backend: str = "numpy", maxiter: Optional[int] = None,
             tol: float = 1e-7, warm_basis: Optional[np.ndarray] = None,
             bland_after: int = BLAND_AFTER) -> LPResult:
    """Minimize c@x s.t. A_ub x <= b_ub, A_eq x == b_eq, x >= 0.

    ``warm_basis`` (a previous `LPResult.basis` for a structurally
    identical instance) starts the solve from that basis, skipping phase 1
    when it is still feasible; a rejected basis falls back to the cold
    two-phase solve (``LPResult.warm`` reports which path ran)."""
    A, b, c_full, nv, n_slack = _canonicalize(c, A_ub, b_ub, A_eq, b_eq)
    if warm_basis is not None \
            and np.asarray(warm_basis).shape != (A.shape[0],):
        raise ValueError(
            f"warm_basis must be ({A.shape[0]},) — one basic column per "
            f"constraint row; got {np.asarray(warm_basis).shape}")
    if maxiter is None:
        maxiter = 50 * (A.shape[0] + 2)
        if backend == "jax":          # static argname: bucket the trace key
            maxiter = _bucket_maxiter(maxiter)
    if backend == "jax":
        if not jax.config.jax_enable_x64:
            tol = max(tol, 1e-5)
        if warm_basis is not None:       # shape validated above
            wb = np.asarray(warm_basis, np.int64)
            dtype = jnp.float64 if jax.config.jax_enable_x64 \
                else jnp.float32
            xw, funw, stw, itw, basw, okw = jax.tree_util.tree_map(
                np.asarray,
                _warm_batch_jit(jnp.asarray(A[None], dtype),
                                jnp.asarray(b[None], dtype),
                                jnp.asarray(c_full[None], dtype),
                                jnp.asarray(wb[None]),
                                nv=nv, maxiter=maxiter, tol=tol,
                                bland_after=bland_after))
            if bool(okw[0]):
                return LPResult(x=np.asarray(xw[0], np.float64),
                                fun=float(funw[0]), status=int(stw[0]),
                                niter=int(itw[0]),
                                basis=np.asarray(basw[0], np.int64),
                                warm=True)
        x, fun, status, niter, basis = jax.tree_util.tree_map(
            np.asarray,
            _solve_jax(A, b, c_full, nv, n_slack, maxiter, tol,
                       bland_after))
        return LPResult(x=np.asarray(x, np.float64), fun=float(fun),
                        status=int(status), niter=int(niter),
                        basis=np.asarray(basis))
    elif backend == "numpy":
        if warm_basis is not None:
            got = _warm_np(A, b, c_full, nv, warm_basis, maxiter, tol,
                           bland_after)
            if got is not None:
                x, fun, status, niter, basis = got
                return LPResult(x=x, fun=float(fun), status=int(status),
                                niter=int(niter), basis=basis, warm=True)
        x, fun, status, niter, basis = _solve_np(A, b, c_full, nv, n_slack,
                                                 maxiter, tol, bland_after)
        return LPResult(x=x, fun=float(fun), status=int(status),
                        niter=int(niter), basis=basis)
    raise ValueError(f"unknown backend {backend!r}")


def _canonicalize_batch(c, A_ub, b_ub, A_eq, b_eq):
    """Batched `_canonicalize`: every input carries a leading batch axis and
    all batch elements share constraint structure (shapes)."""
    c = np.asarray(c, dtype=np.float64)
    B, nv = c.shape
    rows = []
    rhs = []
    n_ub = 0
    if A_ub is not None:
        A_ub = np.asarray(A_ub, dtype=np.float64)
        b_ub = np.asarray(b_ub, dtype=np.float64)
        n_ub = A_ub.shape[1]
        eye = np.broadcast_to(np.eye(n_ub), (B, n_ub, n_ub))
        rows.append(np.concatenate([A_ub, eye], axis=2))
        rhs.append(b_ub)
    if A_eq is not None:
        A_eq = np.asarray(A_eq, dtype=np.float64)
        b_eq = np.asarray(b_eq, dtype=np.float64)
        pad = np.zeros((B, A_eq.shape[1], n_ub))
        rows.append(np.concatenate([A_eq, pad], axis=2))
        rhs.append(b_eq)
    A = np.concatenate(rows, axis=1)
    b = np.concatenate(rhs, axis=1)
    neg = b < 0
    A = np.where(neg[:, :, None], -A, A)
    b = np.where(neg, -b, b)
    c_full = np.concatenate([c, np.zeros((B, n_ub))], axis=1)
    return A, b, c_full, nv, n_ub


def solve_lp_batch(c, A_ub=None, b_ub=None, A_eq=None, b_eq=None, *,
                   maxiter: Optional[int] = None, tol: float = 1e-7,
                   warm_basis: Optional[np.ndarray] = None,
                   impl: str = "jnp", bland_after: int = BLAND_AFTER,
                   method: str = "tableau") -> BatchLPResult:
    """Solve B structurally-identical LPs in one jitted `vmap` of the simplex.

    Inputs mirror `solve_lp` with a leading batch axis on every array.  Runs
    in float64 (via a local `enable_x64` scope) regardless of the global jax
    precision mode so the batched path stays bit-comparable with the NumPy
    oracle; the schedulable fleet sizes here make the 2x memory irrelevant.

    ``warm_basis`` (B, R) starts every lane from that basis via the
    revised-simplex warm path; rejected lanes (stale / singular / primal
    infeasible bases — pass -1 rows to force a cold solve) are re-solved by
    the two-phase path in one extra jitted call over the rejected subset.
    ``impl="pallas"`` runs the batched pivot through the
    `kernels/simplex_pivot` TPU kernels.

    ``method="revised"`` dispatches to the reduced-tableau revised simplex
    (`simplex_batch_core`'s revised path): warm and cold lanes resolve in
    ONE jitted call, only (R, R) factors are carried, and the bucketed
    default maxiter / float64 scope / result contract are identical.  The
    default ``"tableau"`` keeps the existing dispatch bit-for-bit.
    """
    if method not in ("tableau", "revised"):
        raise ValueError(f"unknown simplex method {method!r}; expected "
                         f"'tableau' or 'revised'")
    A, b, c_full, nv, _ = _canonicalize_batch(c, A_ub, b_ub, A_eq, b_eq)
    if maxiter is None:
        maxiter = _bucket_maxiter(50 * (A.shape[1] + 2))
    from jax.experimental import enable_x64
    if method == "revised":
        basis0 = None
        if warm_basis is not None:
            wb = np.asarray(warm_basis, np.int64)
            if wb.shape != A.shape[:2]:
                raise ValueError(
                    f"warm_basis must be (B, R) = {A.shape[:2]}; "
                    f"got {wb.shape}")
            basis0 = jnp.asarray(wb)
        with enable_x64():
            x, fun, status, niter, basis, ok = jax.tree_util.tree_map(
                np.asarray,
                _revised_batch_jit(jnp.asarray(A, jnp.float64),
                                   jnp.asarray(b, jnp.float64),
                                   jnp.asarray(c_full, jnp.float64),
                                   basis0, nv=nv, maxiter=maxiter, tol=tol,
                                   bland_after=bland_after, impl=impl))
        return BatchLPResult(x=np.asarray(x, np.float64),
                             fun=np.asarray(fun, np.float64),
                             status=np.asarray(status, np.int64),
                             niter=np.asarray(niter, np.int64),
                             basis=np.asarray(basis, np.int64),
                             warm=np.asarray(ok, bool))
    with enable_x64():
        if warm_basis is not None:
            wb = np.asarray(warm_basis, np.int64)
            if wb.shape != A.shape[:2]:
                raise ValueError(
                    f"warm_basis must be (B, R) = {A.shape[:2]}; "
                    f"got {wb.shape}")
            x, fun, status, niter, basis, ok = jax.tree_util.tree_map(
                np.asarray,
                _warm_batch_jit(jnp.asarray(A, jnp.float64),
                                jnp.asarray(b, jnp.float64),
                                jnp.asarray(c_full, jnp.float64),
                                jnp.asarray(wb),
                                nv=nv, maxiter=maxiter, tol=tol,
                                bland_after=bland_after, impl=impl))
            x, fun = x.copy(), fun.copy()
            status, niter, basis = status.copy(), niter.copy(), basis.copy()
            cold = np.nonzero(~ok)[0]
            if len(cold):
                # pow2-pad the rejected subset (repeat the last row) so
                # fluctuating rejection counts reuse O(log B) traces
                sel = np.concatenate(
                    [cold, np.full(next_pow2(len(cold)) - len(cold),
                                   cold[-1], dtype=np.int64)])
                xc, func, stc, nitc, basc = jax.tree_util.tree_map(
                    np.asarray,
                    _solve_batch_jit(jnp.asarray(A[sel], jnp.float64),
                                     jnp.asarray(b[sel], jnp.float64),
                                     jnp.asarray(c_full[sel], jnp.float64),
                                     nv=nv, maxiter=maxiter, tol=tol,
                                     bland_after=bland_after))
                k = len(cold)
                x[cold], fun[cold] = xc[:k], func[:k]
                status[cold], niter[cold] = stc[:k], nitc[:k]
                basis[cold] = basc[:k]
            return BatchLPResult(x=np.asarray(x, np.float64),
                                 fun=np.asarray(fun, np.float64),
                                 status=np.asarray(status, np.int64),
                                 niter=np.asarray(niter, np.int64),
                                 basis=np.asarray(basis, np.int64),
                                 warm=np.asarray(ok, bool))
        x, fun, status, niter, basis = jax.tree_util.tree_map(
            np.asarray,
            _solve_batch_jit(jnp.asarray(A, jnp.float64),
                             jnp.asarray(b, jnp.float64),
                             jnp.asarray(c_full, jnp.float64),
                             nv=nv, maxiter=maxiter, tol=tol,
                             bland_after=bland_after))
    return BatchLPResult(x=np.asarray(x, np.float64),
                         fun=np.asarray(fun, np.float64),
                         status=np.asarray(status, np.int64),
                         niter=np.asarray(niter, np.int64),
                         basis=np.asarray(basis),
                         warm=np.zeros(len(x), dtype=bool))
