"""Multi-cell mobility: geometry, traced routing, and the segmented
per-cell admission scan.

The paper assumes one ED talking to one ES.  This module generalizes the
engine to S *cells* (base stations), each fronting ``servers_per_cell``
ES tiers, with devices moving through a 2-D plane:

``MobilityModel``
    A pytree describing the geometry and the motion: cell positions +
    per-cell nominal link rates, a coverage ``radius``, the
    distance->link-slowdown coefficient ``link_alpha``, and either a
    replayed position trace (``trace`` (H, D, 2) — the parity mode, same
    contract as the replayed arrival/fault streams) or a random walk
    (``walk_sigma`` steps drawn from a folded ``mobility_seed`` stream
    inside the traced step, per-device GLOBAL-id folds so sharded and
    unsharded walks agree).  All float64 leaves, no static aux: sweeping
    geometry reuses one compiled rollout.
``route_cells``
    The cheap traced routing pass: each device picks its serving cell
    under the coverage radius — ``"nearest"`` (min distance) or
    ``"min_time"`` (min estimated response: link factor x last period's
    cell load) — and gets a per-(device, chosen-cell) link factor that
    scales its ES latencies.  Out-of-coverage devices route to cell -1
    and are planned as if their ES link were in outage.
``admit_mask_segmented``
    The per-cell admission scan, with NO sequential pass at all.  The
    host pool's semantics — ascending demand (device id on ties),
    least-loaded server first-fit — have two exploitable structural
    properties *within a cell*:

      1. processing ascending demands least-loaded-first is equivalent
         to ROUND-ROBIN placement (induction on the cyclic load order:
         after placing items 0..i-1 of the ascending order on servers
         ``j mod k``, server ``i mod k`` is a least-loaded argmin; ties
         only permute equal loads, and admission depends only on the
         load multiset);
      2. rejections form a SUFFIX of the ascending order (loads never
         decrease and demands ascend, so once the least-loaded server
         cannot fit a demand it cannot fit any later one).

    So admission reduces to: lexsort by (cell, demand, id), place by
    position-mod-k, compute each server chain's inclusive running load
    with one global cumsum minus per-chain offsets, and admit exactly the
    devices before their cell's first capacity violation.  O(D log D)
    parallel sort/scan work instead of the O(D x servers) sequential
    `lax.scan` — the ROADMAP's "segmented/hierarchical admission scan"
    rung, and the entire 100k-device gap.  The global scan
    (`repro.api.engine.admit_mask_jnp`) is kept as the S=1 oracle;
    `admit_mask_cells_np` is the NumPy per-cell twin for tests.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "MobilityModel", "validate_mobility", "route_cells",
    "admit_mask_segmented", "admit_mask_pool", "admit_mask_cells_np",
    "ROUTING_MODES", "MOBILITY_MODES",
]

MOBILITY_MODES = ("off", "replay", "walk")
ROUTING_MODES = ("nearest", "min_time")

_MOBILITY_FIELDS = ("cell_xy", "cell_rate", "radius", "link_alpha",
                    "walk_sigma", "trace")


@dataclasses.dataclass(frozen=True)
class MobilityModel:
    """Cell geometry + device motion (pytree; every field a float64
    leaf, no static aux — sweeping geometry reuses one compiled rollout).

    ``trace`` carries the replayed positions ((H, D, 2); periods beyond H
    cycle).  In walk mode only ``trace[0]`` is read (the initial
    positions) and subsequent steps integrate ``walk_sigma`` Gaussian
    increments from the folded mobility stream.  ``radius=inf`` means
    every device is always covered and — because ``d / inf == 0`` —
    every link factor is EXACTLY 1.0, which is what makes the S=1
    reduction to the single-pool engine bitwise."""

    cell_xy: np.ndarray      # (S, 2) cell positions
    cell_rate: np.ndarray    # (S,) nominal link-rate multipliers (> 0)
    radius: np.ndarray       # ()   coverage radius (inf: always covered)
    link_alpha: np.ndarray   # ()   slowdown per unit normalized distance
    walk_sigma: np.ndarray   # ()   random-walk step stddev (walk mode)
    trace: np.ndarray        # (H, D, 2) replayed positions / initial pos

    @property
    def n_cells(self) -> int:
        return self.cell_xy.shape[0]

    @classmethod
    def none(cls) -> "MobilityModel":
        """The null geometry: one cell at the origin, infinite radius —
        carried by every `EngineParams` so the pytree structure is stable
        whether or not mobility is armed."""
        return cls(cell_xy=np.zeros((1, 2), np.float64),
                   cell_rate=np.ones(1, np.float64),
                   radius=np.float64(np.inf),
                   link_alpha=np.float64(0.0),
                   walk_sigma=np.float64(0.0),
                   trace=np.zeros((1, 1, 2), np.float64))

    @classmethod
    def make(cls, *, cell_xy, trace, cell_rate=None, radius=np.inf,
             link_alpha: float = 0.0,
             walk_sigma: float = 0.0) -> "MobilityModel":
        """Keyword constructor with float64 coercion.  ``trace`` is
        (H, D, 2) (walk mode passes (1, D, 2) initial positions)."""
        cell_xy = np.asarray(cell_xy, np.float64)
        trace = np.asarray(trace, np.float64)
        if cell_xy.ndim != 2 or cell_xy.shape[1] != 2:
            raise ValueError(f"cell_xy must be (S, 2); got {cell_xy.shape}")
        if trace.ndim != 3 or trace.shape[2] != 2:
            raise ValueError(f"trace must be (H, D, 2); got {trace.shape}")
        S = cell_xy.shape[0]
        rate = (np.ones(S, np.float64) if cell_rate is None
                else np.asarray(cell_rate, np.float64))
        return cls(cell_xy=cell_xy, cell_rate=rate,
                   radius=np.float64(radius),
                   link_alpha=np.float64(link_alpha),
                   walk_sigma=np.float64(walk_sigma), trace=trace)

    def is_null(self) -> bool:
        return (self.n_cells == 1 and self.trace.shape[1] == 1
                and not np.any(np.asarray(self.cell_xy))
                and np.isinf(np.asarray(self.radius)))


def _mobility_unflatten(aux, children):
    # bypass __init__ so tracers survive the round-trip (the `_register`
    # idiom in repro.api.engine)
    obj = object.__new__(MobilityModel)
    for f, v in zip(_MOBILITY_FIELDS, children):
        object.__setattr__(obj, f, v)
    return obj


jax.tree_util.register_pytree_node(
    MobilityModel,
    lambda mm: (tuple(getattr(mm, f) for f in _MOBILITY_FIELDS), None),
    _mobility_unflatten)


def validate_mobility(model: MobilityModel, *, n_devices: int,
                      n_servers: int, mode: str, routing: str) -> None:
    """The geometry guard `EngineParams.from_fleet`/`with_mobility` run:
    reject non-f64 leaves, non-positive link rates, and mismatched
    (D, S) shapes with named `ValueError`s instead of downstream NaN
    makespans."""
    if mode not in MOBILITY_MODES:
        raise ValueError(f"unknown mobility_mode {mode!r}; expected one "
                         f"of {MOBILITY_MODES}")
    if routing not in ROUTING_MODES:
        raise ValueError(f"unknown routing {routing!r}; expected one of "
                         f"{ROUTING_MODES}")
    if mode == "off":
        return
    for f in dataclasses.fields(MobilityModel):
        leaf = np.asarray(getattr(model, f.name))
        if leaf.dtype != np.float64:
            raise ValueError(
                f"mobility.{f.name} is {leaf.dtype} but the engine is "
                f"float64-only; build geometry arrays as float64")
    cell_xy = np.asarray(model.cell_xy)
    trace = np.asarray(model.trace)
    rate = np.asarray(model.cell_rate)
    S = cell_xy.shape[0]
    if cell_xy.ndim != 2 or cell_xy.shape[1] != 2:
        raise ValueError(f"mobility.cell_xy must be (S, 2); got "
                         f"{cell_xy.shape}")
    if rate.shape != (S,):
        raise ValueError(
            f"mobility.cell_rate must be ({S},) to match the "
            f"{S}-cell geometry; got {rate.shape}")
    if not np.all(rate > 0):
        raise ValueError(
            f"mobility.cell_rate must be strictly positive (a zero or "
            f"negative link rate prices an infinite/negative ES latency); "
            f"got min {rate.min()}")
    if trace.ndim != 3 or trace.shape[1] != n_devices \
            or trace.shape[2] != 2:
        raise ValueError(
            f"mobility.trace must be (H, {n_devices}, 2) for this "
            f"{n_devices}-device fleet; got {trace.shape}")
    r = float(np.asarray(model.radius))
    if not r > 0:
        raise ValueError(f"mobility.radius must be positive; got {r}")
    if float(np.asarray(model.link_alpha)) < 0:
        raise ValueError("mobility.link_alpha must be >= 0")
    if mode == "walk" and float(np.asarray(model.walk_sigma)) < 0:
        raise ValueError("mobility.walk_sigma must be >= 0")
    if n_servers % S:
        raise ValueError(
            f"n_servers={n_servers} must be divisible by the "
            f"{S}-cell geometry (servers_per_cell = n_servers // n_cells)")


# ---------------------------------------------------------------------------
# traced routing
# ---------------------------------------------------------------------------
def route_cells(pos, model: MobilityModel, load_frac, routing: str):
    """One traced routing pass: ``pos`` (D, 2) -> ``(cell (D,) int32,
    covered (D,) bool, link_factor (D,) f64)``.

    ``"nearest"`` picks the min-distance covered cell; ``"min_time"``
    weights each covered cell's link factor by ``1 + load_frac`` (last
    period's per-cell utilization — a one-period-stale response-time
    estimate, so routing stays a cheap pure map with no fixed point).
    The link factor of the chosen cell is
    ``(1 + link_alpha * dist / radius) / cell_rate`` — exactly 1.0 under
    an infinite radius with unit rates.  Uncovered devices get cell -1
    and factor 1.0 (their ES column is disabled upstream, the factor is
    never priced)."""
    diff = pos[:, None, :] - model.cell_xy[None, :, :]
    dist = jnp.sqrt(jnp.sum(diff * diff, axis=-1))        # (D, S)
    covered_per = dist <= model.radius
    lf = (1.0 + model.link_alpha * (dist / model.radius)) \
        / model.cell_rate[None, :]
    if routing == "nearest":
        score = dist
    else:                                                  # "min_time"
        score = lf * (1.0 + load_frac)[None, :]
    score = jnp.where(covered_per, score, jnp.inf)
    cell = jnp.argmin(score, axis=1).astype(jnp.int32)
    covered = covered_per.any(axis=1)
    link = jnp.take_along_axis(lf, cell[:, None].astype(jnp.int32),
                               axis=1)[:, 0]
    return (jnp.where(covered, cell, jnp.int32(-1)), covered,
            jnp.where(covered, link, 1.0))


# ---------------------------------------------------------------------------
# segmented per-cell admission (no sequential scan)
# ---------------------------------------------------------------------------
def admit_mask_segmented(demands, cell, T, n_cells: int,
                         servers_per_cell: int):
    """Per-cell first-fit admission as pure sort/cumsum work.

    ``demands`` (D,) ES seconds (<= 0: not offloading); ``cell`` (D,)
    int32 serving cell per device (-1: uncovered, never admitted).
    Returns ``(admitted (D,) bool, loads (n_cells, servers_per_cell))``
    with exactly the host pool's per-cell semantics: ascending demand
    (device id on ties), least-loaded server first — see the module
    docstring for why round-robin placement + suffix rejection make this
    exact.  Per-server loads may be permuted within a cell relative to
    the sequential scan when equal demands tie, but the admitted set and
    every per-cell load multiset match."""
    D = demands.shape[0]
    k = servers_per_cell
    active = (demands > 0) & (cell >= 0)
    eff = jnp.where(active, demands, jnp.inf)
    # segment id: inactive devices into phantom cell `n_cells`
    ckey = jnp.where(active, cell, jnp.int32(n_cells))
    # lexsort by (cell, demand, id): two stable argsorts
    ord1 = jnp.argsort(eff, stable=True)
    order = ord1[jnp.argsort(ckey[ord1], stable=True)]
    sc = ckey[order]                                   # ascending cells
    sd = jnp.where(active[order], demands[order], 0.0)
    # position within cell -> round-robin server chain
    seg_start = jnp.searchsorted(sc, jnp.arange(n_cells + 1,
                                                dtype=sc.dtype))
    pos = jnp.arange(D, dtype=jnp.int32) \
        - seg_start[jnp.clip(sc, 0, n_cells)].astype(jnp.int32)
    srv = pos % k
    gid = sc.astype(jnp.int32) * k + srv               # server-chain id
    # inclusive running load per chain: stable sort by chain, one global
    # cumsum, minus each chain's prefix offset
    ord3 = jnp.argsort(gid, stable=True)
    gsorted = gid[ord3]
    dsorted = sd[ord3]
    cums = jnp.cumsum(dsorted)
    n_groups = (n_cells + 1) * k
    start = jnp.searchsorted(gsorted, jnp.arange(n_groups,
                                                 dtype=gsorted.dtype))
    start_c = jnp.clip(start, 0, D - 1)
    base = jnp.where(start < D, cums[start_c] - dsorted[start_c], 0.0)
    inc3 = cums - base[gsorted]
    inc = jnp.zeros(D, demands.dtype).at[ord3].set(inc3)  # back to `order`
    fits = inc <= T + 1e-12
    # suffix rule: everything at/after the cell's first violation is out
    big = jnp.int32(D)
    viol_pos = jnp.where(active[order] & ~fits, pos, big)
    sc_c = jnp.clip(sc, 0, max(n_cells - 1, 0)).astype(jnp.int32)
    first_viol = jnp.full(max(n_cells, 1), big, jnp.int32).at[sc_c].min(
        jnp.where(sc < n_cells, viol_pos, big))
    adm_sorted = active[order] & fits & (pos < first_viol[sc_c])
    admitted = jnp.zeros(D, bool).at[order].set(adm_sorted)
    loads = jnp.zeros(max(n_cells, 1) * k, demands.dtype).at[
        jnp.clip(gid, 0, max(n_cells, 1) * k - 1)].add(
        jnp.where(adm_sorted, sd, 0.0))
    return admitted, loads.reshape(max(n_cells, 1), k)


def admit_mask_pool(demands, T, n_servers: int):
    """The ONE-CELL fast path of the segmented admission — bitwise-equal
    to the sequential `repro.api.engine.admit_mask_jnp` scan it retires
    from the S=1 hot path, in both the admitted mask AND the per-server
    loads.

    Why bitwise (not just set-equal like `admit_mask_segmented`): the
    sequential scan's argmin tie-break (FIRST least-loaded server) makes
    its placement EXACTLY round-robin on the physical server index.
    Induction over the ascending-demand order: after placing sorted items
    ``0..i-1`` on servers ``j mod k``, chain ``j``'s load is the
    fl-sum of ``(d_j, d_{j+k}, ...)`` which is termwise dominated by
    chain ``j+1``'s — and IEEE addition is monotone, so
    ``load_0 <= load_1 <= ... <= load_{k-1}`` holds in floating point,
    not just in exact arithmetic, and the first-index argmin lands on
    server ``i mod k`` exactly.  Rejections freeze the loads, so they
    form a suffix of the sorted order and the admitted prefix's chain
    sums are untouched by them.

    The per-chain running loads are therefore reproducible by a
    `lax.scan` over ROUNDS of a (ceil(D/k), k) demand matrix — each step
    one vectorized k-wide add, same per-chain fl-addition order as the
    old D-step scan, ``ceil(D/k)`` sequential steps instead of ``D`` —
    and the final loads are the per-chain MAX of admitted inclusive
    values (selection, no re-summation, hence no FP-order ambiguity).

    Returns ``(admitted (D,) bool, loads (n_servers,), inc (D,))`` where
    ``inc`` is each device's INCLUSIVE chain load at its placement slot
    (device order; 0 for non-offloaders).  ``inc`` is exactly the value
    the first-fit test compares against ``T + 1e-12`` — the
    differentiable-admission relaxation sigmoids it — and is
    differentiable w.r.t. ``demands`` through the (stop-graded) sort."""
    D = demands.shape[0]
    k = n_servers
    active = demands > 0
    eff = jnp.where(active, demands, jnp.inf)
    order = jnp.argsort(eff, stable=True)
    sd = jnp.where(active[order], demands[order], 0.0)
    rounds = -(-D // k)
    mat = jnp.concatenate(
        [sd, jnp.zeros(rounds * k - D, sd.dtype)]).reshape(rounds, k)

    def body(loads, row):
        new = loads + row
        return new, new

    _, incmat = jax.lax.scan(body, jnp.zeros(k, sd.dtype), mat)
    inc_sorted = incmat.reshape(rounds * k)[:D]
    fits = inc_sorted <= T + 1e-12
    posv = jnp.arange(D, dtype=jnp.int32)
    big = jnp.int32(D)
    first_viol = jnp.min(jnp.where(active[order] & ~fits, posv, big))
    adm_sorted = active[order] & fits & (posv < first_viol)
    admitted = jnp.zeros(D, bool).at[order].set(adm_sorted)
    loads = jnp.zeros(k, demands.dtype).at[posv % k].max(
        jnp.where(adm_sorted, inc_sorted, 0.0))
    inc = jnp.zeros(D, demands.dtype).at[order].set(inc_sorted)
    return admitted, loads, inc


def admit_mask_cells_np(demands, cell, T, n_cells: int,
                        servers_per_cell: int):
    """NumPy oracle for `admit_mask_segmented`: the host pool's
    sequential first-fit run independently inside each cell."""
    demands = np.asarray(demands, np.float64)
    cell = np.asarray(cell)
    D = len(demands)
    mask = np.zeros(D, bool)
    loads = np.zeros((max(n_cells, 1), servers_per_cell))
    eff = np.where((demands > 0) & (cell >= 0), demands, np.inf)
    order = np.argsort(eff, kind="stable")
    for d in order:
        if not np.isfinite(eff[d]):
            break                      # the +inf tail: non-offloaders
        need = float(demands[d])
        c = int(cell[d])
        slot = int(np.argmin(loads[c]))
        if loads[c, slot] + need <= T + 1e-12:
            loads[c, slot] += need
            mask[d] = True
    return mask, loads
