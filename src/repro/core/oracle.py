"""Exact ILP oracle by exhaustive enumeration — tests only (n <= ~10).

Enumerates all (m+1)^n assignments in vectorised chunks; returns the optimal
schedule of problem P or None when P is infeasible.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from .types import OffloadInstance, Schedule

_CHUNK = 1 << 18


def brute_force(inst: OffloadInstance) -> Optional[Schedule]:
    n, m, T = inst.n, inst.m, inst.T
    mp1 = m + 1
    total = mp1 ** n
    if total > 5e7:
        raise ValueError(f"brute_force: {total} assignments is too many")

    # p_all[j, i]: time of job j on machine-of-model i, split per tier.
    ed_t = np.concatenate([inst.p_ed, np.zeros((n, 1))], axis=1)  # (n, m+1)
    es_t = np.concatenate([np.zeros((n, m)), inst.p_es[:, None]], axis=1)

    best_val = -np.inf
    best_assign = None
    radix = mp1 ** np.arange(n)
    for start in range(0, total, _CHUNK):
        idx = np.arange(start, min(start + _CHUNK, total))
        digits = (idx[:, None] // radix[None, :]) % mp1        # (chunk, n)
        ed_load = np.take_along_axis(
            ed_t[None, :, :].repeat(len(idx), 0), digits[:, :, None], 2
        )[:, :, 0].sum(axis=1)
        es_load = np.take_along_axis(
            es_t[None, :, :].repeat(len(idx), 0), digits[:, :, None], 2
        )[:, :, 0].sum(axis=1)
        feas = (ed_load <= T + 1e-12) & (es_load <= T + 1e-12)
        if not feas.any():
            continue
        val = inst.acc[digits].sum(axis=1)
        val = np.where(feas, val, -np.inf)
        k = int(np.argmax(val))
        if val[k] > best_val:
            best_val = float(val[k])
            best_assign = digits[k].copy()

    if best_assign is None:
        return None
    return Schedule(assignment=best_assign.astype(np.int64), instance=inst,
                    solver="oracle", status="ok")
