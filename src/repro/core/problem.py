"""First-class problem/solution values for the unified solver API.

`OffloadInstance`/`InstanceBatch` (types.py) are the validated NumPy
containers the core solvers consume.  This module adds the *API-level*
values `repro.api` traffics in:

  * ``Problem``       — one device's offloading problem, a frozen dataclass
                        registered as a JAX pytree so it can be
                        ``device_put`` / vmapped / (later) sharded.
  * ``FleetProblem``  — B stacked, padded, same-shape problems plus the
                        ``real_mask`` marking which job slots are real
                        (phantom padding rows carry p = 0 on every tier).
                        Also a registered pytree: ``tree_flatten`` yields
                        the five arrays, so a whole fleet moves across
                        devices as one value (ROADMAP: sharded 10k-device
                        planning).
  * ``Solution``      — the uniform result every registry solver returns:
                        dense assignment(s), status/solver tags, timing,
                        and lazily computed accuracy/makespan metrics.

Conversions to the legacy containers (`to_instance`, `to_batch`) are cheap
views over the same arrays, so the registry solvers reuse the existing
core implementations unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Union

import jax
import numpy as np

from .types import InstanceBatch, OffloadInstance, Schedule, next_pow2

# Shares codes with core.amr2.STATUS_NAMES (ok/fallback/infeasible from the
# vectorized rounding path, "unsolved" for an LP that hit its iteration
# limit or went unbounded) plus the LP bound-only pseudo-status at 3.
SOLUTION_STATUS_NAMES = ("ok", "fallback", "infeasible", "bound", "unsolved")
ST_BOUND = 3
ST_UNSOLVED = 4

# Uniform huge ES sentinel: makes offloading infeasible for real jobs on the
# ES-disabled (backpressure / outage) paths, same trick as the legacy
# `replan_without_es`.
ES_DISABLED_SENTINEL = 1e9


def _register_pytree(cls, fields: "tuple[str, ...]") -> None:
    """Register a frozen dataclass whose listed fields are all leaves.

    Unflatten bypasses ``__init__`` (object.__new__ + setattr) so traced
    values survive a flatten/unflatten round-trip without hitting the
    NumPy validation in ``__post_init__``.
    """
    def flatten(obj):
        return tuple(getattr(obj, f) for f in fields), None

    def unflatten(_aux, children):
        obj = object.__new__(cls)
        for f, v in zip(fields, children):
            object.__setattr__(obj, f, v)
        return obj

    jax.tree_util.register_pytree_node(cls, flatten, unflatten)


@dataclasses.dataclass(frozen=True)
class Problem:
    """One device's offloading problem (the paper's P) as a pytree value."""

    p_ed: np.ndarray   # (n, m) float — per-job ED-model seconds
    p_es: np.ndarray   # (n,)  float — per-job total ES seconds (comm incl.)
    acc: np.ndarray    # (m+1,) float — model accuracies, acc[m] = ES
    T: float           # period budget

    def __post_init__(self):
        object.__setattr__(self, "p_ed", np.asarray(self.p_ed, np.float64))
        object.__setattr__(self, "p_es", np.asarray(self.p_es, np.float64))
        object.__setattr__(self, "acc", np.asarray(self.acc, np.float64))
        if self.p_ed.ndim != 2:
            raise ValueError("p_ed must be (n, m)")
        if self.p_es.shape != (self.n,):
            raise ValueError("p_es must be (n,)")
        if self.acc.shape != (self.m + 1,):
            raise ValueError("acc must be (m+1,)")

    @property
    def n(self) -> int:
        return self.p_ed.shape[0]

    @property
    def m(self) -> int:
        return self.p_ed.shape[1]

    @property
    def es_index(self) -> int:
        return self.m

    def is_identical(self, rtol: float = 1e-9) -> bool:
        return self.to_instance().is_identical(rtol=rtol)

    # ---- interop ---------------------------------------------------------
    @classmethod
    def from_instance(cls, inst: OffloadInstance) -> "Problem":
        return cls(p_ed=inst.p_ed, p_es=inst.p_es, acc=inst.acc,
                   T=float(inst.T))

    def to_instance(self) -> OffloadInstance:
        return OffloadInstance(p_ed=self.p_ed, p_es=self.p_es, acc=self.acc,
                               T=float(self.T))

    def es_disabled(self) -> "Problem":
        """The ES-disabled variant: offloading made infeasible for every
        job (the paper's m-model special case)."""
        return Problem(p_ed=self.p_ed.copy(),
                       p_es=np.full(self.n, ES_DISABLED_SENTINEL),
                       acc=self.acc.copy(), T=self.T)


@dataclasses.dataclass(frozen=True)
class FleetProblem:
    """B stacked same-shape problems + the real-job mask, as one pytree.

    Job slots where ``real_mask`` is False are phantom padding: p_ed and
    p_es are 0 (free on every tier, so they never distort the real jobs'
    trade-offs) and they are masked out of every `Solution` metric."""

    p_ed: np.ndarray       # (B, n, m) float
    p_es: np.ndarray       # (B, n)  float
    acc: np.ndarray        # (B, m+1) float
    T: np.ndarray          # (B,)  float
    real_mask: np.ndarray  # (B, n) bool

    def __post_init__(self):
        object.__setattr__(self, "p_ed", np.asarray(self.p_ed, np.float64))
        object.__setattr__(self, "p_es", np.asarray(self.p_es, np.float64))
        object.__setattr__(self, "acc", np.asarray(self.acc, np.float64))
        object.__setattr__(self, "T", np.asarray(self.T, np.float64))
        object.__setattr__(self, "real_mask",
                           np.asarray(self.real_mask, bool))
        if self.p_ed.ndim != 3:
            raise ValueError("p_ed must be (B, n, m)")
        B, n, m = self.p_ed.shape
        if self.p_es.shape != (B, n):
            raise ValueError("p_es must be (B, n)")
        if self.acc.shape != (B, m + 1):
            raise ValueError("acc must be (B, m+1)")
        if self.T.shape != (B,):
            raise ValueError("T must be (B,)")
        if self.real_mask.shape != (B, n):
            raise ValueError("real_mask must be (B, n)")

    def __len__(self) -> int:
        return self.p_ed.shape[0]

    @property
    def n(self) -> int:
        return self.p_ed.shape[1]

    @property
    def m(self) -> int:
        return self.p_ed.shape[2]

    def __getitem__(self, b: int) -> Problem:
        """Device b's (still padded) problem."""
        return Problem(p_ed=self.p_ed[b], p_es=self.p_es[b], acc=self.acc[b],
                       T=float(self.T[b]))

    def identical_mask(self, rtol: float = 1e-9) -> np.ndarray:
        """(B,) bool — `Problem.is_identical` vectorized over the batch
        (all job slots, phantoms included: the criterion the batched
        planner dispatch has always used)."""
        return self.to_batch().identical_mask(rtol=rtol)

    def take(self, rows: np.ndarray) -> "FleetProblem":
        """Row-subset (or row-repeat) view used for sub-batch dispatch."""
        return FleetProblem(p_ed=self.p_ed[rows], p_es=self.p_es[rows],
                            acc=self.acc[rows], T=self.T[rows],
                            real_mask=self.real_mask[rows])

    # ---- constructors ----------------------------------------------------
    @classmethod
    def from_arrays_unchecked(cls, p_ed, p_es, acc, T,
                              real_mask) -> "FleetProblem":
        """Construct WITHOUT `__post_init__` coercion/validation — for
        traced (jit/scan/shard_map) code where the fields are jax tracers,
        not NumPy arrays.  The pure-functional engine builds its period
        `FleetProblem` this way; everything downstream only relies on the
        pytree structure, so flatten/`device_put`/`shard_map` all work on
        the result exactly as on a validated instance."""
        obj = object.__new__(cls)
        for f, v in (("p_ed", p_ed), ("p_es", p_es), ("acc", acc),
                     ("T", T), ("real_mask", real_mask)):
            object.__setattr__(obj, f, v)
        return obj

    @classmethod
    def from_batch(cls, batch: InstanceBatch,
                   real_mask: Optional[np.ndarray] = None) -> "FleetProblem":
        if real_mask is None:
            real_mask = np.ones(batch.p_es.shape, dtype=bool)
        return cls(p_ed=batch.p_ed, p_es=batch.p_es, acc=batch.acc,
                   T=batch.T, real_mask=real_mask)

    @classmethod
    def from_problems(cls, problems: Sequence[Problem],
                      pad_to: Optional[int] = None) -> "FleetProblem":
        """Stack problems sharing one model count m, padding each job axis
        with phantom (p = 0) slots up to ``pad_to`` (default: the max job
        count, bucketed to a power of two for jit-trace reuse)."""
        problems = list(problems)
        if not problems:
            raise ValueError("cannot stack an empty problem list")
        m = problems[0].m
        for p in problems[1:]:
            if p.m != m:
                raise ValueError(
                    f"problems must share the model count m; got {p.m} "
                    f"vs {m}")
        n_pad = pad_to if pad_to is not None else next_pow2(
            max(p.n for p in problems))
        if any(p.n > n_pad for p in problems):
            raise ValueError(f"job count exceeds pad_to={n_pad}")
        B = len(problems)
        p_ed = np.zeros((B, n_pad, m))
        p_es = np.zeros((B, n_pad))
        mask = np.zeros((B, n_pad), dtype=bool)
        for b, p in enumerate(problems):
            p_ed[b, :p.n] = p.p_ed
            p_es[b, :p.n] = p.p_es
            mask[b, :p.n] = True
        return cls(p_ed=p_ed, p_es=p_es,
                   acc=np.stack([p.acc for p in problems]),
                   T=np.array([p.T for p in problems]), real_mask=mask)

    def to_batch(self) -> InstanceBatch:
        return InstanceBatch(p_ed=self.p_ed, p_es=self.p_es, acc=self.acc,
                             T=self.T)

    def instance(self, b: int, strip: bool = False) -> OffloadInstance:
        """Device b as a legacy OffloadInstance (``strip=True`` drops the
        phantom slots)."""
        if strip:
            keep = self.real_mask[b]
            return OffloadInstance(p_ed=self.p_ed[b][keep],
                                   p_es=self.p_es[b][keep],
                                   acc=self.acc[b], T=float(self.T[b]))
        return OffloadInstance(p_ed=self.p_ed[b], p_es=self.p_es[b],
                               acc=self.acc[b], T=float(self.T[b]))


_register_pytree(Problem, ("p_ed", "p_es", "acc", "T"))
_register_pytree(FleetProblem, ("p_ed", "p_es", "acc", "T", "real_mask"))


@dataclasses.dataclass
class Solution:
    """Uniform solver result for both single and fleet problems.

    ``assignment`` is (n,) for a `Problem` and (B, n) for a `FleetProblem`;
    ``status`` is an int code (or (B,) codes) into `SOLUTION_STATUS_NAMES`;
    ``solver`` is the registry name (or a (B,) object array of names when a
    dispatching policy mixed solvers across the fleet).  Metrics are
    computed on demand from the *current* assignment — they are not cached,
    so in-place assignment edits (e.g. the engine's backpressure rewrite)
    stay consistent."""

    problem: Union[Problem, FleetProblem]
    assignment: np.ndarray
    status: np.ndarray                 # () or (B,) int codes
    solver: Union[str, np.ndarray]
    plan_seconds: float = 0.0
    lp_accuracy: Optional[np.ndarray] = None    # A*_LP bound when available
    n_fractional: Optional[np.ndarray] = None
    # optimal simplex basis from LP-backed solvers (amr2/lp): (R,) or (B, R)
    # int, -1 rows for devices another solver handled.  Feed it back as
    # `solve(..., warm_start=solution.basis)` to warm-start the next period.
    basis: Optional[np.ndarray] = None
    # exact legacy Schedule(s) when the solver produced them (object paths)
    _schedules: Optional[List[Schedule]] = dataclasses.field(
        default=None, repr=False)
    _per_model: Optional[Dict[int, np.ndarray]] = dataclasses.field(
        default=None, repr=False)

    @property
    def is_fleet(self) -> bool:
        return self.assignment.ndim == 2

    # ---- status / solver tags -------------------------------------------
    @property
    def status_name(self) -> Union[str, List[str]]:
        if self.is_fleet:
            return [SOLUTION_STATUS_NAMES[int(s)] for s in
                    np.atleast_1d(self.status)]
        return SOLUTION_STATUS_NAMES[int(self.status)]

    @property
    def solver_name(self) -> str:
        """Scalar solver tag (fleet: unique name or 'mixed')."""
        if isinstance(self.solver, str):
            return self.solver
        names = {str(s) for s in np.atleast_1d(self.solver)}
        return names.pop() if len(names) == 1 else "mixed"

    # ---- derived metrics -------------------------------------------------
    def _mask(self) -> np.ndarray:
        if isinstance(self.problem, FleetProblem):
            return self.problem.real_mask
        return np.ones(self.assignment.shape, dtype=bool)

    @property
    def accuracy(self) -> Union[float, np.ndarray]:
        """Summed accuracy over real jobs (per device for fleets)."""
        p = self.problem
        if self.is_fleet:
            rows = np.arange(len(p))[:, None]
            acc_jobs = p.acc[rows, self.assignment]
            return np.where(self._mask(), acc_jobs, 0.0).sum(axis=1)
        return float(p.acc[self.assignment].sum())

    @property
    def ed_makespan(self) -> Union[float, np.ndarray]:
        p = self.problem
        m = p.m
        if self.is_fleet:
            on_ed = self._mask() & (self.assignment < m)
            picked = np.clip(self.assignment, 0, m - 1)[..., None]
            ed = np.take_along_axis(p.p_ed, picked, axis=2)[..., 0]
            return np.where(on_ed, ed, 0.0).sum(axis=1)
        on_ed = self.assignment < m
        if not on_ed.any():
            return 0.0
        j = np.nonzero(on_ed)[0]
        return float(p.p_ed[j, self.assignment[j]].sum())

    @property
    def es_makespan(self) -> Union[float, np.ndarray]:
        p = self.problem
        offl = self._mask() & (self.assignment == p.m)
        if self.is_fleet:
            return np.where(offl, p.p_es, 0.0).sum(axis=1)
        return float(p.p_es[offl].sum())

    @property
    def makespan(self) -> Union[float, np.ndarray]:
        return np.maximum(self.ed_makespan, self.es_makespan) \
            if self.is_fleet else max(self.ed_makespan, self.es_makespan)

    @property
    def violation(self) -> Union[float, np.ndarray]:
        if self.is_fleet:
            return np.maximum(0.0, self.makespan / self.problem.T - 1.0)
        return max(0.0, self.makespan / self.problem.T - 1.0)

    @property
    def per_model(self) -> Dict[int, np.ndarray]:
        """model index -> job ids (single-problem solutions only).  Cached:
        the executor reads it repeatedly, and single-problem assignments
        are never mutated in place (only fleet ones are, and those raise
        here)."""
        if self.is_fleet:
            raise ValueError("per_model is per-device; index a fleet "
                             "Solution via to_schedule(b)")
        if self._per_model is None:
            a = self.assignment
            self._per_model = {i: np.nonzero(a == i)[0]
                               for i in range(self.problem.m + 1)}
        return self._per_model

    # ---- legacy interop --------------------------------------------------
    def _lp_acc_at(self, b: Optional[int]) -> Optional[float]:
        """LP bound as a float-or-None (NaN marks 'no bound': LP infeasible
        rows in a batched solve)."""
        if self.lp_accuracy is None:
            return None
        v = float(np.atleast_1d(self.lp_accuracy)[b if b is not None else 0])
        return None if np.isnan(v) else v

    def to_schedule(self, b: Optional[int] = None) -> Schedule:
        """The device's legacy `Schedule` (pass ``b`` for fleet solutions)."""
        if self.is_fleet:
            if b is None:
                raise ValueError("fleet Solution: pass the device index b")
            if self._schedules is not None:
                return self._schedules[b]
            return Schedule(
                assignment=np.asarray(self.assignment[b]),
                instance=self.problem.instance(b),
                lp_accuracy=self._lp_acc_at(b),
                n_fractional=(None if self.n_fractional is None else
                              int(np.atleast_1d(self.n_fractional)[b])),
                status=SOLUTION_STATUS_NAMES[int(self.status[b])],
                solver=str(np.atleast_1d(self.solver)[b]
                           if not isinstance(self.solver, str)
                           else self.solver))
        if self._schedules is not None:
            return self._schedules[0]
        return Schedule(
            assignment=self.assignment,
            instance=self.problem.to_instance(),
            lp_accuracy=self._lp_acc_at(None),
            n_fractional=(None if self.n_fractional is None
                          else int(self.n_fractional)),
            status=SOLUTION_STATUS_NAMES[int(self.status)],
            solver=str(self.solver))

    def schedules(self) -> List[Schedule]:
        if not self.is_fleet:
            return [self.to_schedule()]
        return [self.to_schedule(b) for b in range(len(self.problem))]

    # ---- constructors ----------------------------------------------------
    @classmethod
    def from_schedule(cls, sched: Schedule, *, solver: str,
                      plan_seconds: float = 0.0,
                      problem: Optional[Problem] = None) -> "Solution":
        status = SOLUTION_STATUS_NAMES.index(sched.status) \
            if sched.status in SOLUTION_STATUS_NAMES else ST_BOUND
        return cls(problem=problem or Problem.from_instance(sched.instance),
                   assignment=sched.assignment,
                   status=np.int64(status), solver=solver,
                   plan_seconds=plan_seconds,
                   lp_accuracy=(None if sched.lp_accuracy is None
                                else np.float64(sched.lp_accuracy)),
                   n_fractional=(None if sched.n_fractional is None
                                 else np.int64(sched.n_fractional)),
                   _schedules=[sched])
