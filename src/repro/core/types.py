"""Problem/solution containers for the offloading problem `P` (paper §III).

Notation follows the paper:
  - n jobs, m models on the ED, one model (index m, 0-based; `m+1` in the
    paper's 1-based notation) on the ES.
  - ``p_ed[j, i]``  : processing time of job j on ED model i  (paper p_{ij}).
  - ``p_es[j]``     : *total* time of job j on the ES, communication included
                      (paper p_{(m+1)j} = c_j + p'_{(m+1)j}).
  - ``acc[i]``      : average test accuracy a_i, i = 0..m (acc[m] is the ES
                      model, the paper's a_{m+1}).
  - ``T``           : makespan budget for each of the two capacity
                      constraints (1) and (2).

Assignments are stored dense: ``assignment[j] in {0..m}`` where value ``m``
means "offload to the ES".
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

ES = -1  # sentinel alias: instance.es_index == m


def next_pow2(x: int) -> int:
    """Smallest power of two >= x.

    The shared bucketing primitive for jit-trace reuse: batch axes, DP grid
    extents, and shape-derived static args (e.g. simplex maxiter) are all
    rounded up with this so fluctuating sizes reuse O(log) compiled
    programs instead of retracing per distinct value."""
    return 1 << (max(int(x), 1) - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class OffloadInstance:
    """One instance of problem P."""

    p_ed: np.ndarray   # (n, m) float
    p_es: np.ndarray   # (n,)  float  (comm + server compute)
    acc: np.ndarray    # (m+1,) float, ascending on the ED part by convention
    T: float

    def __post_init__(self):
        object.__setattr__(self, "p_ed", np.asarray(self.p_ed, dtype=np.float64))
        object.__setattr__(self, "p_es", np.asarray(self.p_es, dtype=np.float64))
        object.__setattr__(self, "acc", np.asarray(self.acc, dtype=np.float64))
        if self.p_ed.ndim != 2:
            raise ValueError("p_ed must be (n, m)")
        if self.p_es.shape != (self.n,):
            raise ValueError("p_es must be (n,)")
        if self.acc.shape != (self.m + 1,):
            raise ValueError("acc must be (m+1,)")

    @property
    def n(self) -> int:
        return self.p_ed.shape[0]

    @property
    def m(self) -> int:
        return self.p_ed.shape[1]

    @property
    def es_index(self) -> int:
        return self.m

    def p(self, j: int, i: int) -> float:
        """Unified p_{ij} with i == m meaning the ES."""
        return float(self.p_es[j]) if i == self.m else float(self.p_ed[j, i])

    def is_identical(self, rtol: float = 1e-9) -> bool:
        """True when all jobs share processing times (paper §VI setting)."""
        return bool(
            np.allclose(self.p_ed, self.p_ed[:1], rtol=rtol)
            and np.allclose(self.p_es, self.p_es[:1], rtol=rtol)
        )


@dataclasses.dataclass(frozen=True)
class InstanceBatch:
    """Array-of-instances: B problems sharing (n, m), stored stacked so the
    batched planner can `jax.vmap` one LP solve over the whole fleet.

    Per-instance `T` and `acc` may differ (heterogeneous fleets); only the
    job/model *counts* must agree across the batch."""

    p_ed: np.ndarray   # (B, n, m) float
    p_es: np.ndarray   # (B, n)  float
    acc: np.ndarray    # (B, m+1) float
    T: np.ndarray      # (B,)  float

    def __post_init__(self):
        object.__setattr__(self, "p_ed", np.asarray(self.p_ed, np.float64))
        object.__setattr__(self, "p_es", np.asarray(self.p_es, np.float64))
        object.__setattr__(self, "acc", np.asarray(self.acc, np.float64))
        object.__setattr__(self, "T", np.asarray(self.T, np.float64))
        if self.p_ed.ndim != 3:
            raise ValueError("p_ed must be (B, n, m)")
        B, n, m = self.p_ed.shape
        if self.p_es.shape != (B, n):
            raise ValueError("p_es must be (B, n)")
        if self.acc.shape != (B, m + 1):
            raise ValueError("acc must be (B, m+1)")
        if self.T.shape != (B,):
            raise ValueError("T must be (B,)")

    @classmethod
    def stack(cls, instances: "list[OffloadInstance]") -> "InstanceBatch":
        if not instances:
            raise ValueError("cannot stack an empty instance list")
        n, m = instances[0].n, instances[0].m
        for inst in instances[1:]:
            if (inst.n, inst.m) != (n, m):
                raise ValueError(
                    f"instances must share (n, m); got ({inst.n}, {inst.m}) "
                    f"vs ({n}, {m})")
        return cls(p_ed=np.stack([i.p_ed for i in instances]),
                   p_es=np.stack([i.p_es for i in instances]),
                   acc=np.stack([i.acc for i in instances]),
                   T=np.array([i.T for i in instances]))

    def __len__(self) -> int:
        return self.p_ed.shape[0]

    def __getitem__(self, b: int) -> OffloadInstance:
        return OffloadInstance(p_ed=self.p_ed[b], p_es=self.p_es[b],
                               acc=self.acc[b], T=float(self.T[b]))

    def identical_mask(self, rtol: float = 1e-9) -> np.ndarray:
        """(B,) bool: `OffloadInstance.is_identical` vectorized over the
        batch — the single criterion every batched planner dispatch uses."""
        return (np.isclose(self.p_ed, self.p_ed[:, :1], rtol=rtol)
                .all(axis=(1, 2))
                & np.isclose(self.p_es, self.p_es[:, :1], rtol=rtol)
                .all(axis=1))

    @property
    def n(self) -> int:
        return self.p_ed.shape[1]

    @property
    def m(self) -> int:
        return self.p_ed.shape[2]


@dataclasses.dataclass
class Schedule:
    """A (possibly constraint-violating) solution to P."""

    assignment: np.ndarray          # (n,) int in [0, m]; m == ES
    instance: OffloadInstance
    lp_accuracy: Optional[float] = None    # A*_LP upper bound when available
    n_fractional: Optional[int] = None     # fractional jobs seen by AMR^2
    status: str = "ok"                     # ok | infeasible | fallback
    solver: str = ""

    # ---- derived metrics -------------------------------------------------
    @property
    def total_accuracy(self) -> float:
        return float(self.instance.acc[self.assignment].sum())

    @property
    def ed_makespan(self) -> float:
        inst = self.instance
        mask = self.assignment < inst.m
        if not mask.any():
            return 0.0
        j = np.nonzero(mask)[0]
        return float(inst.p_ed[j, self.assignment[j]].sum())

    @property
    def es_makespan(self) -> float:
        inst = self.instance
        mask = self.assignment == inst.m
        return float(inst.p_es[mask].sum())

    @property
    def makespan(self) -> float:
        # Both tiers run in parallel; makespan is the later finisher.
        return max(self.ed_makespan, self.es_makespan)

    @property
    def violation(self) -> float:
        """makespan / T - 1 (0 when within budget)."""
        return max(0.0, self.makespan / self.instance.T - 1.0)

    def counts(self) -> np.ndarray:
        """(m+1,) number of jobs per model."""
        return np.bincount(self.assignment, minlength=self.instance.m + 1)

    def summary(self) -> str:
        return (
            f"[{self.solver}] A={self.total_accuracy:.3f} "
            f"(LP bound {self.lp_accuracy if self.lp_accuracy is None else round(self.lp_accuracy, 3)}) "
            f"makespan ed={self.ed_makespan:.3f} es={self.es_makespan:.3f} "
            f"T={self.instance.T} viol={100 * self.violation:.1f}% status={self.status}"
        )
