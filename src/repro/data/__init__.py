from .pipeline import DataConfig, TokenPipeline, Prefetcher

__all__ = ["DataConfig", "TokenPipeline", "Prefetcher"]
