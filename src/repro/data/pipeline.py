"""Deterministic sharded data pipeline.

Synthetic-but-structured token streams (a mixture of Zipfian unigrams and
copy/induction motifs so a small LM has something learnable), packed to
fixed-length rows, sharded per data-parallel rank, with double-buffered
host prefetch.  Deterministic resume: the pipeline state is just
(seed, step), recorded in checkpoints — after a restart the stream
continues bit-identically.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    motif_frac: float = 0.3      # fraction of each row that is copy-motif
    zipf_a: float = 1.2


class TokenPipeline:
    """Stateless-per-step generator: batch(step) is a pure function of
    (config, step), so any rank can reproduce any step after preemption."""

    def __init__(self, cfg: DataConfig, *, rank: int = 0, world: int = 1):
        if cfg.global_batch % world:
            raise ValueError("global_batch must divide world size")
        self.cfg = cfg
        self.rank = rank
        self.world = world
        self.local_batch = cfg.global_batch // world

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rows = []
        for i in range(self.local_batch):
            row_idx = step * cfg.global_batch + self.rank * self.local_batch + i
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, row_idx]))
            row = self._row(rng)
            rows.append(row)
        return {"tokens": np.stack(rows).astype(np.int32)}

    def _row(self, rng: np.random.Generator) -> np.ndarray:
        cfg = self.cfg
        S = cfg.seq_len
        # zipf background (clipped into vocab)
        toks = rng.zipf(cfg.zipf_a, size=S)
        toks = np.minimum(toks, cfg.vocab_size - 1)
        # induction motif: pick a span, repeat it later (teaches copying)
        span = max(4, int(S * cfg.motif_frac / 2))
        if S >= 4 * span:
            src = rng.integers(0, S // 2 - span)
            dst = rng.integers(S // 2, S - span)
            toks[dst:dst + span] = toks[src:src + span]
        return toks

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread double buffering around any step-indexed source."""

    def __init__(self, pipeline: TokenPipeline, start_step: int = 0,
                 depth: int = 2):
        self.pipeline = pipeline
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.pipeline.batch_at(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
