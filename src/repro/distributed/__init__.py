from .sharding import (base_rules, decode_rules, spec_for, tree_shardings,
                       sharding_context, shard_activation,
                       validate_divisibility)

__all__ = [
    "base_rules", "decode_rules", "spec_for", "tree_shardings",
    "sharding_context", "shard_activation", "validate_divisibility",
]
