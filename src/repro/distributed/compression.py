"""Gradient compression with error feedback (int8 quantized all-reduce).

At 1000-node scale the DP gradient all-reduce crosses DCN; int8 with
per-tensor scales cuts those bytes 4x.  Classic error-feedback (Seide et
al.) keeps the quantization residual locally and re-adds it next step, so
convergence is preserved.

Usage: `tx = EFCompressor(); train_step = make_train_step(cfg, grad_tx=tx)`
— the compressor is a pure pytree transform, so it composes with pjit (the
quantize/dequantize are elementwise and shard like the grads).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_tree(grads: PyTree, error: Optional[PyTree] = None
                  ) -> Tuple[PyTree, PyTree]:
    """Returns (dequantized grads as would be seen post-all-reduce,
    new error-feedback residual)."""
    if error is None:
        error = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                             grads)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), corrected - deq

    pairs = jax.tree.map(one, grads, error)
    outer = jax.tree.structure(grads)
    inner = jax.tree.structure((0, 0))
    return jax.tree.transpose(outer, inner, pairs)


class EFCompressor:
    """Stateful wrapper holding the error-feedback residual between steps.

    For fully-jitted training loops prefer the functional `compress_tree`
    and thread the residual through the train state; this class is the
    convenience form for host-driven loops (examples/train_lm.py)."""

    def __init__(self):
        self.error: Optional[PyTree] = None

    def __call__(self, grads: PyTree) -> PyTree:
        out, self.error = compress_tree(grads, self.error)
        return out
