"""GPipe-style pipeline parallelism over a mesh "stage" axis.

For 1000+-node scale-out beyond what DP x TP covers, stages are laid out on
an extra mesh axis; microbatches stream through stages with
`jax.lax.ppermute` boundary transfers inside `shard_map`.  The schedule is
the classic GPipe fill-drain: T = M + S - 1 ticks for M microbatches over
S stages (bubble fraction (S-1)/(M+S-1)).

This module is deliberately self-contained (it pipelines any per-stage
`fn(params_stage, x) -> x`), with a correctness test on an 8-device host
mesh in tests/test_distributed.py.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(fn: Callable, params_stacked, x, *, mesh: Mesh,
                   stage_axis: str = "stage", microbatches: int = None):
    """Run ``y = fn_S(... fn_1(x))`` with stages sharded over `stage_axis`.

    params_stacked: pytree with leading dim = n_stages (sharded over the
    stage axis).  x: (B, ...) batch, split into `microbatches` chunks.
    Returns y with the same shape as x.
    """
    n_stages = mesh.shape[stage_axis]
    M = microbatches or n_stages
    B = x.shape[0]
    assert B % M == 0, "batch must divide microbatches"
    mb = B // M

    def per_stage(params_st, x_all):
        # params_st: this stage's params (leading dim 1); x_all: full batch
        # slice living on every stage (only stage 0's content matters).
        stage = jax.lax.axis_index(stage_axis)
        params_me = jax.tree.map(lambda p: p[0], params_st)
        T = M + n_stages - 1

        x_mb = x_all.reshape((M, mb) + x_all.shape[1:])
        out = jnp.zeros_like(x_mb)
        # current activation flowing through this stage
        cur = jnp.zeros((mb,) + x_all.shape[1:], x_all.dtype)

        def tick(t, state):
            cur, out = state
            # stage 0 ingests microbatch t (if in range)
            take = jnp.clip(t, 0, M - 1)
            fresh = x_mb[take]
            cur = jnp.where(stage == 0,
                            jnp.where(t < M, fresh, cur * 0), cur)
            # compute
            y = fn(params_me, cur)
            # emit: last stage writes microbatch t - (S-1) when valid
            emit_idx = t - (n_stages - 1)
            valid = (stage == n_stages - 1) & (emit_idx >= 0) & (emit_idx < M)
            out = jax.lax.cond(
                valid,
                lambda o: o.at[jnp.clip(emit_idx, 0, M - 1)].set(y),
                lambda o: o, out)
            # shift activations to the next stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            cur = jax.lax.ppermute(y, stage_axis, perm)
            return cur, out

        cur, out = jax.lax.fori_loop(0, T, tick, (cur, out))
        # only the last stage holds real outputs; broadcast them back
        out = jax.lax.psum(
            jnp.where(stage == n_stages - 1, out, jnp.zeros_like(out)),
            stage_axis)
        return out.reshape(x_all.shape)

    spec_params = jax.tree.map(lambda _: P(stage_axis), params_stacked)
    return shard_map(
        per_stage, mesh=mesh,
        in_specs=(spec_params, P()), out_specs=P(),
        check_rep=False,
    )(params_stacked, x)
