"""Logical-axis sharding (MaxText-style).

Params and activations are annotated with *logical* axis names; a rule table
maps logical names to mesh axes.  `sharding_context` installs (mesh, rules)
so model code can call `shard_activation` without threading mesh objects
through every layer.

Baseline rule tables are defined here; §Perf hillclimbs swap rules, nothing
else.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

_TLS = threading.local()

# --------------------------------------------------------------------------
# rule tables: logical axis -> mesh axis (str | tuple | None)
# --------------------------------------------------------------------------
def base_rules(multi_pod: bool = False, *, seq_shard: bool = False
               ) -> Dict[str, Any]:
    """Baseline sharding rules.

    - batch over ("pod","data") — DP across pods and the data axis.
    - params: "model"-sharded on their wide output dims (TP) and
      "data"-sharded on the embed dim (FSDP/ZeRO-style) so multi-10B params
      fit per-device HBM; XLA inserts the FSDP all-gathers.
    - experts: TP *inside* each expert (40/32 experts don't divide the
      16-way model axis; recorded in DESIGN.md).
    - kv_seq: decode-time KV cache sequence dim — sharded over "data" for
      the long-context shapes (flash-decode style partial-softmax combine
      is expressed by XLA as a reduce over the data axis).
    """
    dp = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": dp,
        "seq": "data" if seq_shard else None,
        "cache_batch": dp,
        # caches: kv-head counts (8/1) never divide the 16-way model axis ->
        # shard the cache along sequence instead (flash-decode layout)
        "cache_kv": None,
        "cache_seq": "model",
        "embed": "data",
        "vocab": "model",
        "in_vocab": "data",
        # in_embed stays unsharded: embed-dim sharding of the input table
        # trips an XLA SPMD gather bug inside the microbatch loop
        # (dynamic-slice 6144 vs shard 384); a V/16 x D slice is ~142 MB.
        "in_embed": None,
        "qkv": "model",
        "kv": "model",
        "heads": "model",
        "mlp": "model",
        "expert": None,
        "expert_mlp": "model",
        "moe_group": dp,
        "lru": "model",
        "lru_block": None,
        "lru_block2": None,
        "conv": None,
        # mamba2-130m: in_proj fused dim (2*di+2*N+H = 3352) and 24 ssm heads
        # don't divide the 16-way model axis -> replicated; TP rides on the
        # divisible d_inner (out_proj).  Recorded in DESIGN.md.
        "ssm_in": None,
        "ssm_conv": None,
        "ssm_inner": "model",
        "ssm_heads": None,
        "layers": None,
        # residual-stream activations shard over "model" on the embed dim
        # (sequence/activation parallelism): the per-layer saved residuals
        # under remat are the dominant train-time live buffers (~39 GiB/chip
        # for a 48L model when only batch-sharded — dry-run measured).
        "act_embed": "model",
        "act_heads": "model",
        "act_mlp": "model",
    }


def decode_rules(multi_pod: bool = False, *, long_context: bool = False
                 ) -> Dict[str, Any]:
    r = base_rules(multi_pod)
    if long_context:
        # batch=1: nothing else to shard — put every mesh axis on the
        # cache sequence dim.
        r["cache_batch"] = None
        r["cache_seq"] = (("pod", "data", "model") if multi_pod
                          else ("data", "model"))
        r["batch"] = None
    return r


# --------------------------------------------------------------------------
# logical axes -> PartitionSpec / NamedSharding
# --------------------------------------------------------------------------
def spec_for(axes: Optional[Tuple[Optional[str], ...]],
             rules: Dict[str, Any]) -> P:
    if axes is None:
        return P()
    parts = []
    used = set()
    for ax in axes:
        mesh_ax = rules.get(ax) if ax is not None else None
        # a mesh axis may appear at most once in a spec
        if mesh_ax is not None:
            key = tuple(mesh_ax) if isinstance(mesh_ax, (tuple, list)) \
                else (mesh_ax,)
            if any(k in used for k in key):
                mesh_ax = None
            else:
                used.update(key)
        parts.append(mesh_ax)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def tree_shardings(axes_tree: PyTree, mesh: Mesh, rules: Dict[str, Any]
                   ) -> PyTree:
    """Map a tree of logical-axis tuples to NamedShardings."""
    def _one(axes):
        if axes == ():          # empty structural container, not an axes leaf
            return ()
        return NamedSharding(mesh, spec_for(axes, rules))
    return jax.tree.map(_one, axes_tree,
                        is_leaf=lambda x: x is None or (
                            isinstance(x, tuple)
                            and all(e is None or isinstance(e, str)
                                    for e in x)))


def validate_divisibility(shape_tree: PyTree, axes_tree: PyTree, mesh: Mesh,
                          rules: Dict[str, Any]) -> None:
    """Raise early (with a useful message) if any sharded dim doesn't divide."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def _check(sds, axes):
        if axes is None or not hasattr(sds, "shape"):
            return
        for dim, ax in zip(sds.shape, axes):
            mesh_ax = rules.get(ax) if ax else None
            if mesh_ax is None:
                continue
            names = mesh_ax if isinstance(mesh_ax, (tuple, list)) \
                else (mesh_ax,)
            total = int(np.prod([sizes[nm] for nm in names]))
            if dim % total:
                raise ValueError(
                    f"dim {dim} (logical '{ax}') not divisible by mesh "
                    f"{names} (={total}) for leaf {sds.shape}/{axes}")

    jax.tree.map(_check, shape_tree, axes_tree,
                 is_leaf=lambda x: isinstance(x, tuple) and all(
                     e is None or isinstance(e, str) for e in x))


# --------------------------------------------------------------------------
# activation-sharding context
# --------------------------------------------------------------------------
@contextlib.contextmanager
def sharding_context(mesh: Mesh, rules: Dict[str, Any]):
    prev = getattr(_TLS, "ctx", None)
    _TLS.ctx = (mesh, rules)
    try:
        yield
    finally:
        _TLS.ctx = prev


def shard_activation(x, *logical_axes: Optional[str]):
    """Constrain an activation when a sharding context is installed (no-op
    in plain CPU smoke tests).  Axes whose dim doesn't divide the assigned
    mesh axes are silently dropped (e.g. 56 q-heads on a 16-wide model
    axis) — GSPMD then picks the layout for that dim."""
    ctx = getattr(_TLS, "ctx", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    eff = []
    for dim, ax in zip(x.shape, logical_axes):
        mesh_ax = rules.get(ax) if ax else None
        if mesh_ax is not None:
            names = mesh_ax if isinstance(mesh_ax, (tuple, list)) \
                else (mesh_ax,)
            if dim % int(np.prod([sizes[nm] for nm in names])):
                ax = None
        eff.append(ax)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec_for(tuple(eff), rules)))
