"""CCKP dynamic-program kernel (AMDP §VI-B) — TPU Pallas.

The paper reimplements this DP in C to hit <1 ms on a Raspberry Pi; this is
the TPU-native equivalent: the whole (T+1, K+1) value grid stays resident in
VMEM (a 4001x301 f32 grid is ~4.8 MB of the ~16 MB budget) and the q-loop
runs as a fori_loop of *static* (p_i, 1) shifts + elementwise max — pure VPU
work, no HBM round-trips per item.

One pallas_call handles one model group:
    Y'[t, k]   = max_q  Y[t - q*p, k - q] + q*a
    bestq[t,k] = argmax (for AMDP's O(m) backtrack)
`p` is a *static* kernel parameter (shift offsets must be static on TPU);
AMDP calls it once per model, so there are at most m compiled variants.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(y_ref, a_ref, out_ref, bestq_ref, s_ref, *, p: int,
            n_steps: int):
    T1, K1 = y_ref.shape
    s_ref[...] = y_ref[...]
    out_ref[...] = jnp.full((T1, K1), NEG, jnp.float32)
    bestq_ref[...] = jnp.zeros((T1, K1), jnp.int32)
    a = a_ref[0]

    def body(q, _):
        s = s_ref[...]
        val = s + q.astype(jnp.float32) * a
        best = out_ref[...]
        take = val > best
        out_ref[...] = jnp.where(take, val, best)
        bestq_ref[...] = jnp.where(take, q, bestq_ref[...])
        # shift s by (p, 1) with NEG fill — static offsets, pure VPU
        shifted = jnp.full((T1, K1), NEG, jnp.float32)
        if p > 0:
            if p < T1 and K1 > 1:
                shifted = shifted.at[p:, 1:].set(s[:T1 - p, :K1 - 1])
        else:
            if K1 > 1:
                shifted = shifted.at[:, 1:].set(s[:, :K1 - 1])
        s_ref[...] = shifted
        return ()

    jax.lax.fori_loop(0, n_steps, body, ())


@functools.partial(jax.jit, static_argnames=("p", "n_steps", "interpret"))
def cckp_model_dp(y: jnp.ndarray, a: jnp.ndarray, *, p: int, n_steps: int,
                  interpret: bool = True):
    """y: (T+1, K+1) f32 value grid; a: () accuracy of this model's items.
    Returns (y', bestq)."""
    T1, K1 = y.shape
    kernel = functools.partial(_kernel, p=p, n_steps=n_steps)
    return pl.pallas_call(
        kernel,
        grid=(),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T1, K1), jnp.float32),
            jax.ShapeDtypeStruct((T1, K1), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((T1, K1), jnp.float32)],
        interpret=interpret,
    )(y, a.reshape(1))
