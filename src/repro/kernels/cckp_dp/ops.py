"""jit'd wrapper exposing the kernel with core/amdp._model_dp's signature
(so `amdp(..., impl="pallas")` drops in)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .cckp_dp import cckp_model_dp


def model_dp(y: jnp.ndarray, p_i: int, a_i: float, n_steps: int):
    interpret = jax.default_backend() != "tpu"
    a = jnp.asarray(a_i, jnp.float32)
    return cckp_model_dp(y, a, p=int(p_i), n_steps=int(n_steps),
                         interpret=interpret)
