"""Pure-jnp oracle for the CCKP per-model DP (identical recurrence to
core/amdp._model_dp, restated here so the kernel test is self-contained)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def cckp_model_dp_ref(y: jnp.ndarray, a: float, *, p: int, n_steps: int):
    def step(carry, q):
        best, bestq, s = carry
        val = s + q.astype(jnp.float32) * a
        take = val > best
        best = jnp.where(take, val, best)
        bestq = jnp.where(take, q, bestq)
        s2 = jnp.full_like(s, NEG)
        if p > 0:
            s2 = s2.at[p:, 1:].set(s[:-p, :-1])
        else:
            s2 = s2.at[:, 1:].set(s[:, :-1])
        return (best, bestq, s2), None

    init = (jnp.full_like(y, NEG), jnp.zeros(y.shape, jnp.int32), y)
    (best, bestq, _), _ = jax.lax.scan(step, init, jnp.arange(n_steps))
    return best, bestq
