"""jax-version compatibility shared by the pallas kernels.

`pltpu.CompilerParams` was `pltpu.TPUCompilerParams` before jax 0.5; the
kernels import the alias from here so the next rename is a one-line fix
(same pattern as `launch/mesh.make_mesh` for `jax.sharding.AxisType`).
"""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams
