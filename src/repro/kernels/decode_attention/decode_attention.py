"""Flash-decode attention — TPU Pallas.

One new token against a long KV cache.  Grid (B*KH, nk) sweeps the cache
sequence; each step computes the G grouped query heads (packed as matmul
rows, so GQA groups feed the MXU together) against one KV tile, carrying
(m, l, acc) partials in VMEM scratch — the flash-decode combine.

Ring-buffer semantics are handled by a per-(batch, slot) validity mask the
wrapper precomputes (O(S) int32), so the kernel itself is position-agnostic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import CompilerParams as _CompilerParams

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, bk: int):
    j = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                                        # (G, d)
    k = k_ref[0]                                        # (bk, d)
    v = v_ref[0]
    ok = valid_ref[0] != 0                              # (bk,)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(ok[None, :], s, NEG)                  # (G, bk)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention_fwd(q, k, v, valid, *, bk: int = 512,
                         interpret: bool = True):
    """q: (BKH, G, D); k, v: (BKH, Sk, D); valid: (BKH, Sk) int32."""
    BKH, G, D = q.shape
    Sk = k.shape[1]
    bk = min(bk, Sk)
    nk = -(-Sk // bk)
    pk = nk * bk - Sk
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
        valid = jnp.pad(valid, ((0, 0), (0, pk)))

    kernel = functools.partial(_kernel, scale=D ** -0.5, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=(BKH, nk),
        in_specs=[
            pl.BlockSpec((1, G, D), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, bk, D), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bk), lambda b, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BKH, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, valid)
