"""Wrapper: ring-buffer KV cache decode via the flash-decode kernel.

Builds the per-slot validity mask (ring wrap + optional window) in O(S)
jnp, groups q heads by kv head, and calls the kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .decode_attention import decode_attention_fwd


def ring_validity(W: int, index, window: int = 0) -> jnp.ndarray:
    """(W,) int32 validity for a ring cache of size W at absolute `index`
    (the slot being written this step is index % W)."""
    slots = jnp.arange(W)
    slot = index % W
    abs_pos = jnp.where(slots <= slot, slots + (index // W) * W,
                        slots + (index // W - 1) * W)
    ok = (abs_pos >= 0) & (abs_pos <= index)
    if window:
        ok &= abs_pos > index - window
    return ok.astype(jnp.int32)


def decode_attention(q, ck, cv, index, *, window: int = 0):
    """q: (B, 1, H, D); ck, cv: (B, W, KH, D) ring caches (k roped at
    write).  Returns (B, 1, H, D)."""
    B, _, H, D = q.shape
    W, KH = ck.shape[1], ck.shape[2]
    G = H // KH
    interpret = jax.default_backend() != "tpu"
    qf = q[:, 0].reshape(B, KH, G, D).reshape(B * KH, G, D)
    kf = ck.transpose(0, 2, 1, 3).reshape(B * KH, W, D)
    vf = cv.transpose(0, 2, 1, 3).reshape(B * KH, W, D)
    valid = jnp.broadcast_to(ring_validity(W, index, window)[None],
                             (B * KH, W))
    o = decode_attention_fwd(qf, kf.astype(q.dtype), vf.astype(q.dtype),
                             valid, interpret=interpret)
    return o.reshape(B, KH, G, D).reshape(B, 1, H, D)
