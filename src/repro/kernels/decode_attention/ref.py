"""Pure-jnp oracle for flash-decode."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def decode_attention_ref(q, k, v, valid):
    """q: (BKH, G, D); k, v: (BKH, Sk, D); valid: (BKH, Sk) int32."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bgd,bkd->bgk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = jnp.where(valid[:, None, :] != 0, s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bgk,bkd->bgd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)
