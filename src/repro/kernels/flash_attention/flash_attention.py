"""Flash attention (forward) — TPU Pallas.

Grid (B*H, nq, nk), kv innermost/sequential; 128x128 MXU-aligned Q/KV tiles;
online-softmax accumulators (acc, m, l) live in VMEM scratch across the kv
sweep.  Causal/sliding-window masks are index-derived; blocks entirely
outside the mask are *structurally skipped* with pl.when (no MXU work).

GQA without materialising repeated KV: the kv BlockSpec index_map folds the
query-head index h to kv-head h // group so each q-head tile streams its own
group's KV tiles straight from HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import CompilerParams as _CompilerParams

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, mask_kind: str, window: int, bq: int, bk: int,
            sq: int, sk: int):
    i = pl.program_id(1)
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_first = i * bq
    q_last = q_first + bq - 1
    k_first = j * bk
    k_last = k_first + bk - 1

    live = jnp.bool_(True)
    if mask_kind in ("causal", "window"):
        live = live & (k_first <= q_last)
    if mask_kind == "window":
        live = live & (k_last > q_first - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0]                                   # (bq, d)
        k = k_ref[0]                                   # (bk, d)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        qp = q_first + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kp = k_first + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kp < sk                                  # kv padding
        if mask_kind in ("causal", "window"):
            mask &= kp <= qp
        if mask_kind == "window":
            mask &= kp > qp - window
        s = jnp.where(mask, s, NEG)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("mask_kind", "window", "group", "bq", "bk",
                     "interpret"))
def flash_attention_fwd(q, k, v, *, mask_kind: str = "causal",
                        window: int = 0, group: int = 1, bq: int = 128,
                        bk: int = 128, interpret: bool = True):
    """q: (BH, Sq, D); k, v: (B*KH, Sk, D) with BH = B*KH*group.
    D should be a multiple of 128 on real TPUs (ops.py pads)."""
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    nq = -(-Sq // bq)
    nk = -(-Sk // bk)
    pq = nq * bq - Sq
    pk = nk * bk - Sk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))

    kernel = functools.partial(
        _kernel, scale=D ** -0.5, mask_kind=mask_kind, window=window,
        bq=bq, bk=bk, sq=Sq, sk=Sk)
    out = pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D),
                         lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, bk, D),
                         lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, nq * bq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]
