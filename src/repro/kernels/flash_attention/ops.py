"""jit'd wrapper with the model-layer interface (repro.models.layers calls
this when cfg.attn_impl == "pallas")."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_fwd


def _pad_d(x, mult=128):
    d = x.shape[-1]
    pad = (-d) % mult
    if pad and jax.default_backend() == "tpu":
        x = jnp.pad(x, ((0, 0),) * (x.ndim - 1) + ((0, pad),))
    return x, d


def flash_attention(q, k, v, q_pos, k_pos, *, mask_kind: str,
                    window: int = 0):
    """q: (B, Sq, H, D); k, v: (B, Sk, H, D) (kv repeated to H by caller).
    Self-attention positions (arange) are assumed — the kernel derives
    masks from indices."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    interpret = jax.default_backend() != "tpu"
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, Sk, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, Sk, D)
    qf, d0 = _pad_d(qf)
    kf, _ = _pad_d(kf)
    vf, _ = _pad_d(vf)
    kind = "none" if mask_kind == "none" else (
        "window" if mask_kind == "window" else "causal")
    o = flash_attention_fwd(qf, kf, vf, mask_kind=kind, window=window,
                            group=1, interpret=interpret)
    o = o[..., :d0]
    return o.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
