"""Pure-jnp oracle: dense softmax attention with index masks."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def attention_ref(q, k, v, *, mask_kind: str = "causal", window: int = 0):
    """q: (BH, Sq, D); k, v: (BH, Sk, D) (kv already expanded to q heads)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    Sq, Sk = q.shape[1], k.shape[1]
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if mask_kind in ("causal", "window"):
        mask &= kp <= qp
    if mask_kind == "window":
        mask &= kp > qp - window
    s = jnp.where(mask, s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)
