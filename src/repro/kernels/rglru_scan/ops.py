"""Wrapper for the RG-LRU recurrence kernel."""
from __future__ import annotations

import jax

from .rglru_scan import rglru_scan_fwd


def rglru_scan(a, b):
    interpret = jax.default_backend() != "tpu"
    return rglru_scan_fwd(a, b, interpret=interpret)
