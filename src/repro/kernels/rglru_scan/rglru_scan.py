"""RG-LRU gated linear recurrence — TPU Pallas.

h_t = a_t * h_{t-1} + b_t, elementwise over the LRU width.  Grid
(B, nW, nS): width tiles are lane-parallel, the sequence runs innermost and
sequential with the (1, Wb) hidden state carried in VMEM scratch — so one
HBM pass over (a, b) produces the full hidden sequence.

ops.py computes the gates (sigmoid/softplus mixing, conv) in jnp — the
recurrence is the only part XLA cannot fuse into a single pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import CompilerParams as _CompilerParams


def _kernel(a_ref, b_ref, y_ref, h_ref, *, bs: int):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0]                     # (bs, Wb)
    b = b_ref[0]

    def body(t, h):
        h = a[t] * h + b[t]
        y_ref[0, t, :] = h
        return h

    h = jax.lax.fori_loop(0, bs, body, h_ref[0])
    h_ref[0] = h


@functools.partial(jax.jit, static_argnames=("bs", "bw", "interpret"))
def rglru_scan_fwd(a, b, *, bs: int = 128, bw: int = 512,
                   interpret: bool = True):
    """a, b: (B, S, W) f32. Returns the full hidden sequence (B, S, W)."""
    B, S, W = a.shape
    bs = min(bs, S)
    bw = min(bw, W)
    ns = -(-S // bs)
    nw = -(-W // bw)
    ps = ns * bs - S
    pw = nw * bw - W
    if ps or pw:
        a = jnp.pad(a, ((0, 0), (0, ps), (0, pw)))
        b = jnp.pad(b, ((0, 0), (0, ps), (0, pw)))

    y = pl.pallas_call(
        functools.partial(_kernel, bs=bs),
        grid=(B, nw, ns),
        in_specs=[
            pl.BlockSpec((1, bs, bw), lambda bb, w, s: (bb, s, w)),
            pl.BlockSpec((1, bs, bw), lambda bb, w, s: (bb, s, w)),
        ],
        out_specs=pl.BlockSpec((1, bs, bw), lambda bb, w, s: (bb, s, w)),
        out_shape=jax.ShapeDtypeStruct((B, ns * bs, nw * bw), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
    return y[:, :S, :W]
