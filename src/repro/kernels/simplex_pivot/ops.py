"""jit'd wrappers exposing the kernels with `core.lp`'s batched pivot
signatures (so both simplex paths drop them in as ``impl="pallas"``,
mirroring how `cckp_dp` is wired into AMDP)."""
from __future__ import annotations

import jax

from .simplex_pivot import reduced_pivot as _reduced_pivot
from .simplex_pivot import simplex_pivot


def pivot_update(tabs, r, j, mask):
    interpret = jax.default_backend() != "tpu"
    return simplex_pivot(tabs, r, j, mask, interpret=interpret)


def reduced_pivot(A, c_phase, Binv, xB, basis, use_bland, may_pivot,
                  lane_ok, *, art_cost, tol):
    interpret = jax.default_backend() != "tpu"
    return _reduced_pivot(A, c_phase, Binv, xB, basis, use_bland,
                          may_pivot, lane_ok, art_cost=float(art_cost),
                          tol=float(tol), interpret=interpret)
