"""jit'd wrapper exposing the kernel with `core.lp`'s batched pivot-update
signature (so the warm-started simplex drops it in as ``impl="pallas"``,
mirroring how `cckp_dp` is wired into AMDP)."""
from __future__ import annotations

import jax

from .simplex_pivot import simplex_pivot


def pivot_update(tabs, r, j, mask):
    interpret = jax.default_backend() != "tpu"
    return simplex_pivot(tabs, r, j, mask, interpret=interpret)
