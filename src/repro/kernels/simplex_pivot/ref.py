"""Pure-jnp reference for the batched simplex pivot (rank-1 tableau update).

This is both the oracle the Pallas kernel is tested against and the default
(``impl="jnp"``) implementation the warm-started fleet LP path uses — there
is ONE definition of the update, shared by `core.lp._phase_batched` and the
kernel tests.
"""
from __future__ import annotations

import jax.numpy as jnp


def pivot_update_ref(tabs: jnp.ndarray, r: jnp.ndarray, j: jnp.ndarray,
                     mask: jnp.ndarray) -> jnp.ndarray:
    """One simplex pivot on every active lane of a tableau stack.

    tabs: (B, R+1, C+1) tableaus (last row = reduced costs | -obj, last col
    = rhs); r, j: (B,) pivot row/column per lane; mask: (B,) bool — lanes
    with mask False pass through unchanged (their r/j may be garbage).

    Row/column selection uses `take_along_axis`: on XLA:CPU the gather
    lowering measures ~2x faster per pivot than the one-hot einsum
    formulation the Pallas kernel uses (one-hot is the right shape for the
    TPU VPU, gathers for CPU).
    """
    colv = jnp.take_along_axis(tabs, j[:, None, None], axis=2)[..., 0]
    prow = jnp.take_along_axis(tabs, r[:, None, None], axis=1)[:, 0, :]
    piv = jnp.take_along_axis(colv, r[:, None], axis=1)[:, 0]
    piv = jnp.where(mask, piv, 1.0)         # masked lanes: avoid 0-divide
    prow = prow / piv[:, None]
    new = tabs - colv[:, :, None] * prow[:, None, :]
    is_r = jnp.arange(tabs.shape[1])[None, :] == r[:, None]
    new = jnp.where(is_r[:, :, None], prow[:, None, :], new)
    return jnp.where(mask[:, None, None], new, tabs)
