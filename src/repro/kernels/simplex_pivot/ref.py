"""Pure-jnp references for the batched simplex pivot kernels.

Three ops live here, each the oracle its Pallas kernel is tested against
AND the default (``impl="jnp"``) implementation the fleet LP path uses —
there is ONE definition of each update, shared by `core.lp` and the kernel
tests:

  * `pivot_update_ref` — the dense rank-1 tableau update used by
    `core.lp._phase_batched` (the legacy full-tableau path).
  * `reduced_pivot_ref` — one FUSED revised-simplex iteration (BTRAN
    pricing + entering/leaving selection + product-form eta update of the
    basis-inverse factors) used by `core.lp._revised_phase`.  Only the
    (R, R) basis inverse and the basic solution are updated; entering
    columns are priced on demand from the original (R, C0) column data, so
    the C0-wide tableau is never materialized.
  * `basis_columns_ref` / `kkt_vjp_ref` — the per-lane basis gather and
    the KKT adjoint solve behind the implicit-gradient simplex
    (`core.lp` ``differentiable=True``): at a converged basis the optimum
    is ``x_B = B^{-1} b``, so the whole VJP is two (R, R) triangular-ish
    solves per lane against the same basis factor the revised method
    carries — no differentiation through the pivot loops.
"""
from __future__ import annotations

import jax.numpy as jnp


def pivot_update_ref(tabs: jnp.ndarray, r: jnp.ndarray, j: jnp.ndarray,
                     mask: jnp.ndarray) -> jnp.ndarray:
    """One simplex pivot on every active lane of a tableau stack.

    tabs: (B, R+1, C+1) tableaus (last row = reduced costs | -obj, last col
    = rhs); r, j: (B,) pivot row/column per lane; mask: (B,) bool — lanes
    with mask False pass through unchanged (their r/j may be garbage).

    Row/column selection uses `take_along_axis`: on XLA:CPU the gather
    lowering measures ~2x faster per pivot than the one-hot einsum
    formulation the Pallas kernel uses (one-hot is the right shape for the
    TPU VPU, gathers for CPU).
    """
    colv = jnp.take_along_axis(tabs, j[:, None, None], axis=2)[..., 0]
    prow = jnp.take_along_axis(tabs, r[:, None, None], axis=1)[:, 0, :]
    piv = jnp.take_along_axis(colv, r[:, None], axis=1)[:, 0]
    piv = jnp.where(mask, piv, 1.0)         # masked lanes: avoid 0-divide
    prow = prow / piv[:, None]
    new = tabs - colv[:, :, None] * prow[:, None, :]
    is_r = jnp.arange(tabs.shape[1])[None, :] == r[:, None]
    new = jnp.where(is_r[:, :, None], prow[:, None, :], new)
    return jnp.where(mask[:, None, None], new, tabs)


def price_reduced_ref(A, c_phase, Binv, basis, art_cost):
    """Reduced costs out of the basis-inverse factor (one BTRAN + pricing).

    A: (B, R, C0) original columns; c_phase: (B, C0) phase costs; Binv:
    (B, R, R); basis: (B, R) labels — entries >= C0 are VIRTUAL artificials
    (no column exists; they price at ``art_cost``: 1 in phase 1, 0 in
    phase 2).  Returns rc (B, C0)."""
    C0 = A.shape[2]
    cB = jnp.where(
        basis >= C0, jnp.asarray(art_cost, A.dtype),
        jnp.take_along_axis(c_phase, jnp.clip(basis, 0, C0 - 1), axis=1))
    y = jnp.einsum("br,brk->bk", cB, Binv)          # simplex multipliers
    return c_phase - jnp.einsum("bk,bkc->bc", y, A)


def reduced_pivot_ref(A, c_phase, Binv, xB, basis, use_bland, may_pivot,
                      lane_ok, *, art_cost: float, tol: float):
    """One fused revised-simplex iteration across the whole lane stack.

    Prices every column out of the current factor (`price_reduced_ref`),
    picks the entering column (Dantzig, or Bland's smallest index where
    ``use_bland``), runs the ratio test on the FTRAN-transformed entering
    column (with `core.lp`'s artificial drive-out rule and
    smallest-basis-index tie-break), and applies the product-form (eta)
    rank-1 update to ``[Binv | xB]`` — the revised-simplex replacement for
    the dense (R+1, C0+1) tableau pivot of `pivot_update_ref`.

    A: (B, R, C0); c_phase: (B, C0); Binv: (B, R, R); xB: (B, R) basic
    solution; basis: (B, R) labels (>= C0 virtual artificial);
    use_bland / may_pivot / lane_ok: (B,) bool — ``lane_ok`` False lanes
    never produce an entering column (the masked-lane contract), and the
    update is applied only where ``may_pivot & has_enter & ~unbounded``.

    Returns ``(Binv', xB', basis', has_enter, unbounded, degenerate)``
    with the three flags (B,) bool (``degenerate``: min ratio <= tol,
    meaningful only on lanes that pivoted).
    """
    B, R, C0 = A.shape
    dtype = A.dtype
    intmax = jnp.iinfo(jnp.int32).max

    rc = price_reduced_ref(A, c_phase, Binv, basis, art_cost)
    enter = (rc < -tol) & lane_ok[:, None]
    has_enter = enter.any(axis=1)
    score = jnp.where(enter, rc, jnp.inf)
    j_dantzig = jnp.argmin(score, axis=1)
    j_bland = jnp.argmax(enter, axis=1)             # first eligible index
    j = jnp.where(use_bland, j_bland, j_dantzig).astype(jnp.int32)
    j = jnp.where(has_enter, j, 0)                  # safe gather index

    # FTRAN: entering column in basis coordinates
    Aj = jnp.take_along_axis(A, j[:, None, None], axis=2)[..., 0]  # (B, R)
    d = jnp.einsum("brk,bk->br", Binv, Aj)
    pos = d > tol
    ratio = jnp.where(pos, xB / jnp.where(pos, d, 1.0), jnp.inf)
    art_basic = (basis >= C0) & (jnp.abs(d) > tol) & (xB <= tol)
    ratio = jnp.where(art_basic, 0.0, ratio)
    unbounded = ~jnp.any(ratio < jnp.inf, axis=1)
    rmin = jnp.min(ratio, axis=1)
    tie = ratio <= (rmin + jnp.maximum(jnp.abs(rmin) * 1e-9,
                                       1e-12))[:, None]
    r = jnp.argmin(jnp.where(tie, basis, intmax), axis=1).astype(jnp.int32)

    do = may_pivot & has_enter & ~unbounded
    # product-form update of the augmented factor [Binv | xB]
    F = jnp.concatenate([Binv, xB[..., None]], axis=2)     # (B, R, R+1)
    prow = jnp.take_along_axis(F, r[:, None, None], axis=1)[:, 0, :]
    piv = jnp.take_along_axis(d, r[:, None], axis=1)[:, 0]
    piv = jnp.where(do, piv, jnp.ones((), dtype))          # no 0-divide
    prow = prow / piv[:, None]
    Fnew = F - d[:, :, None] * prow[:, None, :]
    is_r = jnp.arange(R)[None, :] == r[:, None]
    Fnew = jnp.where(is_r[:, :, None], prow[:, None, :], Fnew)
    F = jnp.where(do[:, None, None], Fnew, F)
    basis = jnp.where(do[:, None] & is_r, j[:, None], basis)
    return (F[:, :, :R], F[:, :, R], basis.astype(jnp.int32),
            has_enter, unbounded, rmin <= tol)


def basis_columns_ref(A, basis):
    """Gather each lane's basis matrix out of the original column data.

    A: (B, R, C0); basis: (B, R) labels.  Labels >= C0 are VIRTUAL
    artificials (the `core.lp` convention: the column for label ``C0 + r``
    is the unit vector ``e_r``, never materialized) — they come back as
    unit columns here.  Returns ``(Bmat (B, R, R), real (B, R) bool)``
    with ``real`` marking non-artificial basis members.

    The sign a warm-repair flip gave an artificial's virtual column is
    deliberately dropped: the KKT adjoint zeroes artificial cotangent
    entries (`kkt_vjp_ref`), and flipping column ``j`` of ``Bmat`` only
    rescales the adjoint component that multiplies that zero.
    """
    B, R, C0 = A.shape
    real = basis < C0
    basJ = jnp.clip(basis, 0, C0 - 1)
    cols = jnp.take_along_axis(A, basJ[:, None, :], axis=2)     # (B, R, R)
    art_row = jnp.clip(basis - C0, 0, R - 1)
    unit = (jnp.arange(R)[None, :, None]
            == art_row[:, None, :]).astype(A.dtype)             # e_{b-C0}
    return jnp.where(real[:, None, :], cols, unit), real


def kkt_vjp_ref(A, b, c_full, basis, gx, gfun, valid, *, nv: int):
    """The implicit-function VJP of a converged simplex optimum.

    At an optimal basis ``B`` the active-set system is ``B x_B = b`` with
    every nonbasic variable pinned at 0, so (away from degenerate bases,
    where any subgradient is returned) the solution map is locally
    ``x_B = B^{-1} b`` and ``fun = c_B^T x_B``.  Given output cotangents
    ``gx`` (B, nv) and ``gfun`` (B,), one adjoint solve per lane yields
    every input cotangent:

        g_B   = gather(gx)[basis] + gfun * c_B          (artificials: 0)
        y     = B^{-T} g_B                              (KKT adjoint)
        b-bar = y
        A-bar = -y (x_B scattered to basic columns)^T   (rank-1 per lane)
        c-bar = gfun * x_B scattered to basic columns

    ``valid`` (B,) bool gates lanes whose basis is meaningful (status
    OPTIMAL, lane unmasked): invalid lanes get an identity factor BEFORE
    the solve — gating after it would leak ``NaN * 0`` from singular
    garbage factors — and exactly-zero cotangents.

    A: (B, R, C0); b: (B, R); c_full: (B, C0); basis: (B, R) labels
    (>= C0 virtual); gx: (B, nv); gfun: (B,).  Returns ``(A_bar, b_bar,
    c_bar)`` with the primal shapes.
    """
    B, R, C0 = A.shape
    dtype = A.dtype
    Bmat, real = basis_columns_ref(A, basis)
    eye = jnp.broadcast_to(jnp.eye(R, dtype=dtype), (B, R, R))
    Bsafe = jnp.where(valid[:, None, None], Bmat, eye)
    basJ = jnp.clip(basis, 0, C0 - 1)

    xB = jnp.linalg.solve(Bsafe, b[..., None])[..., 0]          # (B, R)
    gxp = jnp.concatenate(
        [gx, jnp.zeros((B, C0 - nv), dtype)], axis=1)           # slack: 0
    gB = jnp.take_along_axis(gxp, basJ, axis=1) \
        + gfun[:, None] * jnp.take_along_axis(c_full, basJ, axis=1)
    gB = jnp.where(real & valid[:, None], gB, 0.0)
    y = jnp.linalg.solve(jnp.swapaxes(Bsafe, 1, 2),
                         gB[..., None])[..., 0]                  # (B, R)

    w = jnp.where(real & valid[:, None], xB, 0.0)
    b_bar = jnp.where(valid[:, None], y, 0.0)
    lanes = jnp.arange(B)[:, None]
    wcol = jnp.zeros((B, C0), dtype).at[lanes, basJ].add(w)      # (B, C0)
    A_bar = -b_bar[:, :, None] * wcol[:, None, :]
    c_bar = jnp.zeros((B, C0), dtype).at[lanes, basJ].add(
        gfun[:, None] * w)
    return A_bar, b_bar, c_bar
