"""Batched simplex pivot kernel — TPU Pallas.

One simplex pivot is a rank-1 update of a dense tableau:

    tab' = tab - tab[:, j] (x) (tab[r, :] / tab[r, j]),   row r := tab[r]/piv

The warm-started fleet LP path (`core.lp._phase_batched`) performs this
across B device tableaus per iteration.  This kernel runs the whole stack in
one ``pallas_call`` — grid over lanes, each (R+1, C+1) tableau resident in
VMEM — with the per-lane pivot coordinates (r, j) and the active mask as
scalar-prefetch operands.  Dynamic row/column selection uses
broadcasted-iota one-hot masks (no gathers, pure VPU work) and inactive
lanes copy through unchanged, mirroring the jnp reference in ``ref.py``.

Like `cckp_dp`, the kernel runs in interpret mode off-TPU; fleet tableaus
are float64 on CPU (the LP parity contract), so on a real TPU the caller
must run the float32 LP mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, j_ref, mask_ref, tab_ref, out_ref):
    b = pl.program_id(0)
    tab = tab_ref[0]                       # (R1, C1) lane block
    R1, C1 = tab.shape
    r = r_ref[b]
    j = j_ref[b]
    active = mask_ref[b] != 0
    rows = jax.lax.broadcasted_iota(jnp.int32, (R1, C1), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (R1, C1), 1)
    is_r = rows == r
    is_j = cols == j
    piv = jnp.sum(jnp.where(is_r & is_j, tab, 0.0))
    piv = jnp.where(active, piv, jnp.ones((), tab.dtype))
    prow = jnp.sum(jnp.where(is_r, tab, 0.0), axis=0) / piv    # (C1,)
    colv = jnp.sum(jnp.where(is_j, tab, 0.0), axis=1)          # (R1,)
    upd = tab - colv[:, None] * prow[None, :]
    upd = jnp.where(is_r, prow[None, :], upd)
    out_ref[0] = jnp.where(active, upd, tab)


@functools.partial(jax.jit, static_argnames=("interpret",))
def simplex_pivot(tabs: jnp.ndarray, r: jnp.ndarray, j: jnp.ndarray,
                  mask: jnp.ndarray, *, interpret: bool = True):
    """Pivot every active lane of a (B, R+1, C+1) tableau stack.

    r, j: (B,) int pivot coordinates; mask: (B,) bool/int lane-active flags
    (inactive lanes pass through, their r/j may be garbage).
    """
    B, R1, C1 = tabs.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B,),
        in_specs=[pl.BlockSpec((1, R1, C1), lambda b, *_: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, R1, C1), lambda b, *_: (b, 0, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, R1, C1), tabs.dtype),
        interpret=interpret,
    )(r.astype(jnp.int32), j.astype(jnp.int32), mask.astype(jnp.int32),
      tabs)
