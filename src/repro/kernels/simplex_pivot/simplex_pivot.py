"""Batched simplex pivot kernels — TPU Pallas.

Two kernels, both gridded over the lane (device) axis with per-lane flags
as scalar-prefetch operands and every dynamic row/column selection done
with broadcasted-iota one-hot masks (no gathers, pure VPU work):

  * ``simplex_pivot`` — the dense rank-1 tableau update

        tab' = tab - tab[:, j] (x) (tab[r, :] / tab[r, j])

    that `core.lp._phase_batched` performs across B device tableaus per
    iteration; pivot coordinates (r, j) are chosen by the caller.

  * ``reduced_pivot`` — one FUSED revised-simplex iteration for
    `core.lp._revised_phase`: BTRAN pricing out of the (R, R) basis
    inverse, entering-column selection (Dantzig / Bland), the ratio test
    with the artificial drive-out rule, and the product-form (eta) rank-1
    update of ``[Binv | xB]`` — all in one kernel launch per iteration,
    with the original (R, C0) column data streamed per lane instead of a
    materialized C0-wide tableau.

Both mirror the jnp references in ``ref.py`` and, like `cckp_dp`, run in
interpret mode off-TPU; fleet factors are float64 on CPU (the LP parity
contract), so on a real TPU the caller must run the float32 LP mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, j_ref, mask_ref, tab_ref, out_ref):
    b = pl.program_id(0)
    tab = tab_ref[0]                       # (R1, C1) lane block
    R1, C1 = tab.shape
    r = r_ref[b]
    j = j_ref[b]
    active = mask_ref[b] != 0
    rows = jax.lax.broadcasted_iota(jnp.int32, (R1, C1), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (R1, C1), 1)
    is_r = rows == r
    is_j = cols == j
    piv = jnp.sum(jnp.where(is_r & is_j, tab, 0.0))
    piv = jnp.where(active, piv, jnp.ones((), tab.dtype))
    prow = jnp.sum(jnp.where(is_r, tab, 0.0), axis=0) / piv    # (C1,)
    colv = jnp.sum(jnp.where(is_j, tab, 0.0), axis=1)          # (R1,)
    upd = tab - colv[:, None] * prow[None, :]
    upd = jnp.where(is_r, prow[None, :], upd)
    out_ref[0] = jnp.where(active, upd, tab)


@functools.partial(jax.jit, static_argnames=("interpret",))
def simplex_pivot(tabs: jnp.ndarray, r: jnp.ndarray, j: jnp.ndarray,
                  mask: jnp.ndarray, *, interpret: bool = True):
    """Pivot every active lane of a (B, R+1, C+1) tableau stack.

    r, j: (B,) int pivot coordinates; mask: (B,) bool/int lane-active flags
    (inactive lanes pass through, their r/j may be garbage).
    """
    B, R1, C1 = tabs.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B,),
        in_specs=[pl.BlockSpec((1, R1, C1), lambda b, *_: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, R1, C1), lambda b, *_: (b, 0, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, R1, C1), tabs.dtype),
        interpret=interpret,
    )(r.astype(jnp.int32), j.astype(jnp.int32), mask.astype(jnp.int32),
      tabs)


def _reduced_kernel(bland_ref, may_ref, ok_ref, A_ref, c_ref, binv_ref,
                    xb_ref, bas_ref, binv_out, xb_out, bas_out, flag_out,
                    *, art_cost: float, tol: float):
    b = pl.program_id(0)
    A = A_ref[0]                           # (R, C0) original columns
    c = c_ref[0]                           # (C0,) phase costs
    Binv = binv_ref[0]                     # (R, R) basis inverse
    xB = xb_ref[0]                         # (R,) basic solution
    bas = bas_ref[0]                       # (R,) labels (>= C0 virtual)
    R, C0 = A.shape
    dtype = A.dtype
    use_bland = bland_ref[b] != 0
    may = may_ref[b] != 0
    ok = ok_ref[b] != 0
    inf = jnp.asarray(jnp.inf, dtype)
    intmax = jnp.iinfo(jnp.int32).max
    cols = jax.lax.broadcasted_iota(jnp.int32, (R, C0), 1)
    cols1 = cols[0]                        # (C0,) = arange(C0)
    rows1 = jax.lax.broadcasted_iota(jnp.int32, (R, R), 0)[:, 0]

    # BTRAN + pricing: rc = c - (cB Binv) A
    cB = jnp.sum(jnp.where(cols == bas[:, None], c[None, :], 0.0), axis=1)
    cB = jnp.where(bas >= C0, jnp.asarray(art_cost, dtype), cB)
    y = jnp.sum(cB[:, None] * Binv, axis=0)              # (R,)
    rc = c - jnp.sum(y[:, None] * A, axis=0)             # (C0,)

    enter = (rc < -tol) & ok
    has_enter = jnp.any(enter)
    score = jnp.where(enter, rc, inf)
    smin = jnp.min(score)
    j_dantzig = jnp.min(jnp.where(score == smin, cols1, C0))
    j_bland = jnp.min(jnp.where(enter, cols1, C0))
    j = jnp.where(use_bland, j_bland, j_dantzig)
    j = jnp.where(has_enter, j, 0)

    # FTRAN + ratio test (drive-out rule, smallest-basis-index tie-break)
    Aj = jnp.sum(jnp.where(cols1[None, :] == j, A, 0.0), axis=1)   # (R,)
    d = jnp.sum(Binv * Aj[None, :], axis=1)                        # (R,)
    pos = d > tol
    ratio = jnp.where(pos, xB / jnp.where(pos, d, 1.0), inf)
    art_basic = (bas >= C0) & (jnp.abs(d) > tol) & (xB <= tol)
    ratio = jnp.where(art_basic, 0.0, ratio)
    unbounded = ~jnp.any(ratio < inf)
    rmin = jnp.min(ratio)
    tie = ratio <= rmin + jnp.maximum(jnp.abs(rmin) * 1e-9, 1e-12)
    bmin = jnp.min(jnp.where(tie, bas, intmax))          # basis labels are
    r = jnp.min(jnp.where(tie & (bas == bmin), rows1, R))  # unique per lane

    do = may & has_enter & ~unbounded
    is_r = rows1 == r
    piv = jnp.sum(jnp.where(is_r, d, 0.0))
    piv = jnp.where(do, piv, jnp.ones((), dtype))
    brow = jnp.sum(jnp.where(is_r[:, None], Binv, 0.0), axis=0) / piv
    xr = jnp.sum(jnp.where(is_r, xB, 0.0)) / piv
    Binv2 = Binv - d[:, None] * brow[None, :]
    Binv2 = jnp.where(is_r[:, None], brow[None, :], Binv2)
    xB2 = jnp.where(is_r, xr, xB - d * xr)
    binv_out[0] = jnp.where(do, Binv2, Binv)
    xb_out[0] = jnp.where(do, xB2, xB)
    bas_out[0] = jnp.where(do & is_r, j, bas)
    flag_out[0] = jnp.stack([has_enter, unbounded,
                             rmin <= tol]).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("art_cost", "tol", "interpret"))
def reduced_pivot(A: jnp.ndarray, c_phase: jnp.ndarray, Binv: jnp.ndarray,
                  xB: jnp.ndarray, basis: jnp.ndarray,
                  use_bland: jnp.ndarray, may_pivot: jnp.ndarray,
                  lane_ok: jnp.ndarray, *, art_cost: float, tol: float,
                  interpret: bool = True):
    """One fused revised-simplex iteration on every lane of the stack.

    Signature and semantics match `ref.reduced_pivot_ref`: per lane, price
    all C0 columns out of the (R, R) basis inverse, select the pivot, and
    apply the eta update — lanes where ``may_pivot & has_enter &
    ~unbounded`` is False pass their factors through unchanged.  Returns
    ``(Binv', xB', basis', has_enter, unbounded, degenerate)``.
    """
    B, R, C0 = A.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B,),
        in_specs=[pl.BlockSpec((1, R, C0), lambda b, *_: (b, 0, 0)),
                  pl.BlockSpec((1, C0), lambda b, *_: (b, 0)),
                  pl.BlockSpec((1, R, R), lambda b, *_: (b, 0, 0)),
                  pl.BlockSpec((1, R), lambda b, *_: (b, 0)),
                  pl.BlockSpec((1, R), lambda b, *_: (b, 0))],
        out_specs=[pl.BlockSpec((1, R, R), lambda b, *_: (b, 0, 0)),
                   pl.BlockSpec((1, R), lambda b, *_: (b, 0)),
                   pl.BlockSpec((1, R), lambda b, *_: (b, 0)),
                   pl.BlockSpec((1, 3), lambda b, *_: (b, 0))],
    )
    binv2, xb2, bas2, flags = pl.pallas_call(
        functools.partial(_reduced_kernel, art_cost=art_cost, tol=tol),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, R, R), Binv.dtype),
                   jax.ShapeDtypeStruct((B, R), xB.dtype),
                   jax.ShapeDtypeStruct((B, R), jnp.int32),
                   jax.ShapeDtypeStruct((B, 3), jnp.int32)],
        interpret=interpret,
    )(use_bland.astype(jnp.int32), may_pivot.astype(jnp.int32),
      lane_ok.astype(jnp.int32), A, c_phase, Binv, xB,
      basis.astype(jnp.int32))
    return (binv2, xb2, bas2, flags[:, 0] != 0, flags[:, 1] != 0,
            flags[:, 2] != 0)
