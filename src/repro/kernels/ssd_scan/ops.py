"""Wrapper with the model-layer signature (layers.ssd_apply impl="pallas")."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .ssd_scan import ssd_scan_fwd


def ssd_scan(xs, dt, A, B_, C_, chunk: int):
    """xs: (B, S, H, P); dt: (B, S, H) f32; A: (H,) f32; B_, C_: (B, S, N).
    Returns (y (B,S,H,P) f32, final_state (B,H,P,N) f32)."""
    Bb, S, H, P = xs.shape
    interpret = jax.default_backend() != "tpu"
    xf = xs.transpose(0, 2, 1, 3).reshape(Bb * H, S, P)
    dtf = dt.transpose(0, 2, 1).reshape(Bb * H, S)
    Af = jnp.broadcast_to(A[None], (Bb, H)).reshape(Bb * H, 1)
    y, state = ssd_scan_fwd(xf, dtf, Af, B_, C_, heads=H, chunk=chunk,
                            interpret=interpret)
    y = y.reshape(Bb, H, S, P).transpose(0, 2, 1, 3)
    return y, state.reshape(Bb, H, P, state.shape[-1])
