"""Pure-jnp oracle: the exact sequential SSM recurrence (no chunking) —
the ground truth both the chunked jnp path and the kernel must match."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_sequential_ref(x, dt, A, B_, C_):
    """x: (B, S, H, P); dt: (B, S, H); A: (H,); B_, C_: (B, S, N).
    h_t = h_{t-1} * exp(dt A) + dt B_t x_t ; y_t = C_t . h_t
    Returns (y (B,S,H,P) f32, final state (B,H,P,N) f32)."""
    Bb, S, H, P = x.shape
    N = B_.shape[-1]

    def step(h, inp):
        xt, dtt, bt, ct = inp            # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(dtt * A)         # (B,H)
        h = h * decay[..., None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dtt, bt, xt)
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    h0 = jnp.zeros((Bb, H, P, N), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          B_.transpose(1, 0, 2).astype(jnp.float32),
          C_.transpose(1, 0, 2).astype(jnp.float32))
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3), h
