"""Mamba2 SSD (state-space duality) chunked scan — TPU Pallas.

Grid (B*H, n_chunks), chunks sequential; the (P, N) inter-chunk state lives
in VMEM scratch across the chunk sweep.  Per chunk: the intra-chunk
quadratic term runs as two MXU matmuls ((Q,N)x(N,Q) scores and the masked
(Q,Q)x(Q,P) apply), the state contribution as (N,Q)x(Q,P); decays are VPU
elementwise on cumulative dA.

B/C are per-(batch, group=1) and shared across heads — their BlockSpec
index_map folds the head axis (b // H) so nothing is materialised per head.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import CompilerParams as _CompilerParams


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, state_ref, *,
            q_len: int):
    c_idx = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(c_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)                    # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)                  # (Q,)
    A = a_ref[0, 0]                                     # ()
    B_ = b_ref[0].astype(jnp.float32)                   # (Q, N)
    C_ = c_ref[0].astype(jnp.float32)                   # (Q, N)

    dA = dt * A                                         # (Q,)
    cum = jnp.cumsum(dA)                                # (Q,)
    xdt = x * dt[:, None]                               # (Q, P)

    # intra-chunk: Y = (exp(segsum) ∘ (C B^T)) @ xdt
    seg = cum[:, None] - cum[None, :]                   # (Q, Q)
    qi = jax.lax.broadcasted_iota(jnp.int32, (q_len, q_len), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (q_len, q_len), 1)
    L = jnp.where(ki <= qi, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(C_, B_, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y = jax.lax.dot(L * scores, xdt,
                    preferred_element_type=jnp.float32)  # (Q, P)

    # inter-chunk: contribution of the carried state
    decay_from_start = jnp.exp(cum)                     # (Q,)
    y += (jax.lax.dot(C_, state_ref[...].T,
                      preferred_element_type=jnp.float32)
          * decay_from_start[:, None])                  # (Q, P)
    y_ref[0] = y.astype(y_ref.dtype)

    # state update: state' = state * exp(sum dA) + sum_k decay_k B_k x_k
    decay_to_end = jnp.exp(cum[-1] - cum)               # (Q,)
    new_contrib = jax.lax.dot_general(
        (xdt * decay_to_end[:, None]), B_, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)             # (P, N)
    state_ref[...] = state_ref[...] * jnp.exp(cum[-1]) + new_contrib

    @pl.when(c_idx == nc - 1)
    def _emit_state():
        st_ref[0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("heads", "chunk", "interpret"))
def ssd_scan_fwd(x, dt, A, B_, C_, *, heads: int, chunk: int = 256,
                 interpret: bool = True):
    """x: (BH, S, P); dt: (BH, S) (softplus already applied); A: (BH, 1);
    B_, C_: (B, S, N) shared across the `heads` per batch.
    Returns (y (BH, S, P), final_state (BH, P, N))."""
    BH, S, P = x.shape
    N = B_.shape[-1]
    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))

    kernel = functools.partial(_kernel, q_len=Q)
    y, state = pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q), lambda b, c: (b, c)),
            pl.BlockSpec((1, 1), lambda b, c: (b, 0)),
            pl.BlockSpec((1, Q, N), lambda b, c, h=heads: (b // h, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, c, h=heads: (b // h, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, P, N), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, nc * Q, P), jnp.float32),
            jax.ShapeDtypeStruct((BH, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A, B_, C_)
    return y[:, :S], state
