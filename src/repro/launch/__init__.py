from .mesh import make_production_mesh, make_host_mesh
from .steps import (make_train_step, make_eval_step, make_prefill_step,
                    make_decode_step, init_train_state)

__all__ = ["make_production_mesh", "make_host_mesh", "make_train_step",
           "make_eval_step", "make_prefill_step", "make_decode_step",
           "init_train_state"]
