import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell on the production mesh with ShapeDtypeStruct inputs (no device
allocation), print memory/cost analysis, extract roofline terms.

MUST stay the first two lines: jax locks the device count on first init.

Usage:
  python -m repro.launch.dryrun --arch internlm2-20b --shape train_4k
  python -m repro.launch.dryrun --all --out results/dryrun.jsonl
  python -m repro.launch.dryrun --arch ... --shape ... --multi-pod \
      --override q_block=4096 --override remat=full --seq-shard
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import all_archs, get_config
from repro.distributed.sharding import (base_rules, decode_rules,
                                        sharding_context, tree_shardings,
                                        validate_divisibility)
from repro.launch import hlo_cost, roofline
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (PERF_OVERRIDES, SHAPES, batch_axes,
                                cell_supported, input_specs,
                                shape_overrides)
from repro.launch.steps import (make_decode_step, make_prefill_step,
                                make_train_step)
from repro.models import param_axes
from repro.models.model import param_shapes
from repro.optim import AdamWState, adamw_init


def _coerce(cfg, key: str, val: str):
    cur = getattr(cfg, key)
    if isinstance(cur, bool):
        return val.lower() in ("1", "true", "yes")
    if isinstance(cur, int):
        return int(val)
    if isinstance(cur, float):
        return float(val)
    return val


def _parse_rule(v: str):
    if v.lower() in ("none", "null"):
        return None
    if "," in v:
        return tuple(v.split(","))
    return v


def build_cell(arch: str, shape: str, *, multi_pod: bool,
               overrides: Optional[Dict[str, str]] = None,
               rules_overrides: Optional[Dict[str, str]] = None,
               seq_shard: bool = False):
    cfg = get_config(arch)
    cfg = shape_overrides(cfg, shape)
    if overrides:
        cfg = dataclasses.replace(
            cfg, **{k: _coerce(cfg, k, v) for k, v in overrides.items()})
    info = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    if info["kind"] == "decode":
        rules = decode_rules(multi_pod, long_context=info.get("long", False))
    else:
        rules = base_rules(multi_pod, seq_shard=seq_shard)
    if rules_overrides:
        rules.update({k: _parse_rule(v) for k, v in rules_overrides.items()})
    return cfg, info, mesh, rules


def lower_cell(arch: str, shape: str, *, multi_pod: bool = False,
               overrides: Optional[Dict[str, str]] = None,
               rules_overrides: Optional[Dict[str, str]] = None,
               seq_shard: bool = False, verbose: bool = True
               ) -> Dict[str, Any]:
    cfg, info, mesh, rules = build_cell(
        arch, shape, multi_pod=multi_pod, overrides=overrides,
        rules_overrides=rules_overrides, seq_shard=seq_shard)
    chips = mesh.devices.size

    p_axes = param_axes(cfg)
    p_shapes = param_shapes(cfg)
    validate_divisibility(p_shapes, p_axes, mesh, rules)
    p_shard = tree_shardings(p_axes, mesh, rules)
    specs = input_specs(cfg, shape)
    b_axes = batch_axes(cfg, shape)

    t0 = time.time()
    with sharding_context(mesh, rules):
        if info["kind"] == "train":
            step = make_train_step(cfg)
            opt_shapes = jax.eval_shape(adamw_init, p_shapes)
            opt_axes = AdamWState(step=None, m=p_axes, v=p_axes)
            opt_shard = tree_shardings(opt_axes, mesh, rules)
            b_shard = tree_shardings(b_axes["batch"], mesh, rules)
            lowered = jax.jit(
                step,
                in_shardings=(p_shard, opt_shard, b_shard),
                out_shardings=(p_shard, opt_shard, None),
                donate_argnums=(0, 1),
            ).lower(p_shapes, opt_shapes, specs["batch"])
        elif info["kind"] == "prefill":
            step = make_prefill_step(cfg, max_seq=info["seq"])
            b_shard = tree_shardings(b_axes["batch"], mesh, rules)
            from repro.models import cache_axes
            c_axes = cache_axes(cfg, info["batch"], info["seq"])
            c_shard = tree_shardings(c_axes, mesh, rules)
            lowered = jax.jit(
                step, in_shardings=(p_shard, b_shard),
                out_shardings=((c_shard, None)),
            ).lower(p_shapes, specs["batch"])
        else:  # decode
            step = make_decode_step(cfg)
            from repro.models import cache_axes
            c_axes = cache_axes(cfg, info["batch"], info["seq"])
            c_shard = tree_shardings(c_axes, mesh, rules)
            t_shard = tree_shardings(b_axes["tokens"], mesh, rules)
            lowered = jax.jit(
                step, in_shardings=(p_shard, t_shard, c_shard),
                out_shardings=(None, c_shard), donate_argnums=(2,),
            ).lower(p_shapes, specs["tokens"], specs["cache"])
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    # xla cost_analysis counts while (scan) bodies ONCE — hlo_cost re-derives
    # flops/bytes/collective-bytes with trip-count multiplication.
    parsed = hlo_cost.analyze(hlo)

    flops_chip = float(parsed["flops"])
    bytes_chip = float(parsed["bytes"])
    coll = {"total": parsed["coll_bytes"],
            "per_kind": parsed["coll_by_kind"],
            "counts": parsed["coll_counts"]}
    terms = roofline.terms(flops_chip, bytes_chip, float(coll["total"]))
    mflops = roofline.model_flops(cfg, info)
    hlo_flops_global = flops_chip * chips

    rec = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "status": "ok",
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_per_chip": flops_chip, "bytes_per_chip": bytes_chip,
        "collective_bytes_per_chip": coll["total"],
        "collective_detail": coll,
        "xla_cost_analysis": {"flops": float(cost.get("flops", 0.0)),
                              "bytes": float(cost.get("bytes accessed", 0.0))},
        "unparsed_loops": parsed["unparsed_loops"],
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
        "terms": terms,
        "model_flops_global": mflops,
        "hlo_flops_global": hlo_flops_global,
        "useful_flop_ratio": (mflops / hlo_flops_global
                              if hlo_flops_global else 0.0),
        "overrides": {**(overrides or {}),
                      **{f"rule:{k}": str(v)
                         for k, v in (rules_overrides or {}).items()}},
        "seq_shard": seq_shard,
    }
    if verbose:
        peak_hbm = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                    - mem.alias_size_in_bytes + mem.temp_size_in_bytes)
        print(f"[{arch} x {shape} x {rec['mesh']}] compile {t_compile:.1f}s")
        print(f"  memory/chip: args {mem.argument_size_in_bytes/2**30:.2f} GiB"
              f" temp {mem.temp_size_in_bytes/2**30:.2f} GiB"
              f" (~peak {peak_hbm/2**30:.2f} GiB of 16 GiB HBM)")
        print(f"  flops/chip {flops_chip:.3e}  bytes/chip {bytes_chip:.3e}"
              f"  coll bytes/chip {coll['total']:.3e} {coll['counts']}")
        print(f"  terms: compute {terms['compute_s']*1e3:.2f} ms | memory "
              f"{terms['memory_s']*1e3:.2f} ms | collective "
              f"{terms['collective_s']*1e3:.2f} ms -> dominant "
              f"{terms['dominant']} (roofline frac "
              f"{terms['roofline_fraction']*100:.1f}%)")
        print(f"  MODEL_FLOPS/HLO_FLOPs = {rec['useful_flop_ratio']:.3f}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--perf", action="store_true",
                    help="apply the adopted §Perf overrides per cell")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg field override key=value (repeatable)")
    ap.add_argument("--rule", action="append", default=[],
                    help="sharding-rule override key=value (value: mesh "
                         "axis name, comma-tuple, or 'none')")
    args = ap.parse_args()
    overrides = dict(kv.split("=", 1) for kv in args.override)
    rules_overrides = dict(kv.split("=", 1) for kv in args.rule)

    if args.all:
        cells = [(a, s, mp) for a in all_archs() for s in SHAPES
                 for mp in ((False, True) if args.both_meshes else (False,))]
    else:
        meshes = (False, True) if args.both_meshes else (args.multi_pod,)
        cells = [(args.arch, args.shape, mp) for mp in meshes]

    done = set()
    if args.out and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                r = json.loads(line)
                if r.get("status") == "ok" and not r.get("overrides"):
                    done.add((r["arch"], r["shape"], r["mesh"]))

    failures = 0
    for arch, shape, mp in cells:
        mesh_name = "2x16x16" if mp else "16x16"
        ok, why = cell_supported(arch, shape)
        if not ok:
            rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                   "status": "skipped", "reason": why}
            print(f"[{arch} x {shape} x {mesh_name}] SKIP: {why}")
        elif (arch, shape, mesh_name) in done and not overrides:
            print(f"[{arch} x {shape} x {mesh_name}] cached, skipping")
            continue
        else:
            try:
                cell_over = dict(overrides)
                if args.perf:
                    cell_over.update(PERF_OVERRIDES.get(
                        (arch.replace("-", "_").replace(".", "_"), shape),
                        {}))
                rec = lower_cell(arch, shape, multi_pod=mp,
                                 overrides=cell_over,
                                 rules_overrides=rules_overrides,
                                 seq_shard=args.seq_shard)
            except Exception as e:  # noqa: BLE001 — report, keep sweeping
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "status": "error", "error": f"{type(e).__name__}: {e}"}
                failures += 1
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
