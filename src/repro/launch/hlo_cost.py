"""HLO-text cost model with while-loop trip-count multiplication.

XLA's `compiled.cost_analysis()` visits each instruction once, so a
`lax.scan` over L layers reports ~1/L of the real flops, and collectives
inside the scanned body are likewise undercounted.  This module re-derives
  flops / bytes-accessed / collective-bytes
from the *optimized per-device* HLO text, recursing into `while` bodies and
multiplying by the trip count parsed from the loop condition.

Conventions (documented for EXPERIMENTS.md):
  * dot flops = 2 * prod(output dims) * prod(contracting dims).
  * non-dot arithmetic ~ 1 flop per output element (softmax exp/log etc. —
    second-order next to the dots; fusions count their root output once).
  * bytes accessed are counted at top-level instruction boundaries
    (operands + output), matching XLA's fusion-aware accounting.
  * collective bytes = result-shape bytes of each collective op (per-device
    program => per-chip bytes), times the enclosing trip counts.
  * trip count: the constant compared against the induction variable in the
    while condition; falls back to 1 (and records the fallback).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*"
    r"([a-z][\w\-]*)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")


def _shape_elems_bytes(shape_text: str) -> Tuple[int, int]:
    """Total (elements, bytes) over every shape literal in the text."""
    elems = 0
    byts = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


@dataclasses.dataclass
class Instr:
    name: str
    shape: str           # result shape text (may be a tuple)
    opcode: str
    rest: str            # operand list + attributes
    is_root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    shapes: Dict[str, str]   # instr name -> result shape text


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        # tuple shapes embed /*index=5*/ comments whose '=' breaks parsing
        line = _COMMENT_RE.sub("", raw).rstrip()
        if cur is None:
            m = _COMP_RE.match(line.strip())
            if m:
                cur = Computation(m.group(1), [], {})
                if line.strip().startswith("ENTRY"):
                    entry = m.group(1)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, shape, opcode, rest = m.groups()
            cur.instrs.append(Instr(name, shape.strip(), opcode, rest,
                                    is_root="ROOT" in line.split("=")[0]))
            cur.shapes[name] = shape.strip()
    if cur is not None:
        comps[cur.name] = cur
    return comps, entry


_CALLED_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_SPLIT_RE = re.compile(r"%([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


_KNOWN_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _trip_count(cond: Computation) -> Tuple[int, bool]:
    """Largest integer constant in the while condition — for scan-lowered
    loops this is the trip count the induction variable is compared to.
    (Fallback when the while carries no known_trip_count backend_config.)"""
    best = None
    for ins in cond.instrs:
        if ins.opcode == "constant":
            m = _CONST_RE.search(ins.opcode + "(" + ins.rest)
            if m:
                v = int(m.group(1))
                best = v if best is None else max(best, v)
    if best is None or best <= 0:
        return 1, False
    return best, True


def _while_trip(ins: Instr, comps: Dict[str, Computation]
                ) -> Tuple[int, bool]:
    m = _KNOWN_TRIP_RE.search(ins.rest)
    if m:
        return int(m.group(1)), True
    c = _COND_RE.search(ins.rest)
    if c and c.group(1) in comps:
        return _trip_count(comps[c.group(1)])
    return 1, False


def _dot_flops(ins: Instr, shapes: Dict[str, str]) -> float:
    out_elems, _ = _shape_elems_bytes(ins.shape)
    m = _CONTRACT_RE.search(ins.rest)
    ops = _OPERANDS_SPLIT_RE.findall(ins.rest.split(")")[0])
    contract = 1
    if m and ops:
        lhs_shape = shapes.get(ops[0], "")
        sm = _SHAPE_RE.search(lhs_shape)
        if sm:
            dims = [int(d) for d in sm.group(2).split(",") if d]
            for idx in m.group(1).split(","):
                if idx and int(idx) < len(dims):
                    contract *= dims[int(idx)]
    return 2.0 * out_elems * contract


_SKIP_OPS = frozenset({
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "copy", "copy-start", "copy-done", "after-all", "partition-id",
    "replica-id", "custom-call", "get-dimension-size", "iota",
})


class HloCost:
    def __init__(self, text: str):
        self.comps, self.entry = parse_module(text)
        self._memo: Dict[str, Dict[str, float]] = {}
        self.unparsed_loops = 0

    def _dus_root_update_bytes(self, comp) -> Optional[float]:
        """If the fused computation's root is a dynamic-update-slice,
        return the update operand's byte size, else None."""
        if comp is None or not comp.instrs:
            return None
        root = next((i for i in comp.instrs if i.is_root), comp.instrs[-1])
        if root.opcode == "convert":
            # CPU f8 legalization: [DUS into an f16 shadow -> convert the
            # whole stack back to f8] as the fusion root.  On the TPU
            # target the DUS aliases in place in f8 — treat it as such.
            dus = next((i for i in comp.instrs
                        if i.opcode == "dynamic-update-slice"), None)
            if dus is None:
                return None
            root = dus
        if root.opcode != "dynamic-update-slice":
            return None
        ops = _OPERANDS_SPLIT_RE.findall(root.rest.split("),")[0])
        if len(ops) < 2:
            return None
        sh = comp.shapes.get(ops[1], "")
        b = _shape_elems_bytes(sh)[1]
        return float(b) if b else None

    def _fusion_sliced_discount(self, comp) -> float:
        """Operand bytes to discount when a fusion only gathers/slices a
        big parameter (e.g. an embedding-table fusion reads ~the slice)."""
        if comp is None:
            return 0.0
        disc = 0.0
        for ins in comp.instrs:
            if ins.opcode in ("gather", "dynamic-slice"):
                ops = _OPERANDS_SPLIT_RE.findall(ins.rest.split("),")[0])
                if not ops:
                    continue
                src = comp.shapes.get(ops[0], "")
                # only discount fusion *parameters* (external operands)
                if not any(i.name == ops[0] and i.opcode == "parameter"
                           for i in comp.instrs):
                    continue
                src_b = _shape_elems_bytes(src)[1]
                out_b = _shape_elems_bytes(ins.shape)[1]
                disc += max(0.0, src_b - 2.0 * out_b)
        return disc

    def _fusion_flops(self, comp: Computation) -> float:
        """Flops inside a fused computation: dots exact, elementwise ~1/elem
        on each instruction's output."""
        fl = 0.0
        for ins in comp.instrs:
            if ins.opcode == "dot":
                fl += _dot_flops(ins, comp.shapes)
            elif ins.opcode in ("fusion", "call"):
                m = _CALLED_RE.search(ins.rest)
                if m and m.group(1) in self.comps:
                    fl += self._fusion_flops(self.comps[m.group(1)])
            elif ins.opcode not in _SKIP_OPS:
                elems, _ = _shape_elems_bytes(ins.shape)
                fl += elems
        return fl

    def cost(self, comp_name: Optional[str] = None) -> Dict[str, float]:
        comp_name = comp_name or self.entry
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps[comp_name]
        tot = {"flops": 0.0, "bytes": 0.0, "coll_bytes": 0.0}
        coll_by_kind = {}
        counts = {}
        for ins in comp.instrs:
            op = ins.opcode
            out_elems, out_bytes = _shape_elems_bytes(ins.shape)
            # operand bytes via the per-computation symbol table
            opnd_bytes = 0
            for nm in _OPERANDS_SPLIT_RE.findall(ins.rest.split("),")[0]):
                sh = comp.shapes.get(nm)
                if sh:
                    opnd_bytes += _shape_elems_bytes(sh)[1]
            if op == "while":
                m = re.search(r"body=%?([\w.\-]+)", ins.rest)
                c = _COND_RE.search(ins.rest)
                body = (self.cost(m.group(1))
                        if m and m.group(1) in self.comps else {})
                trip, ok = _while_trip(ins, self.comps)
                if not ok:
                    self.unparsed_loops += 1
                cond_cost = (self.cost(c.group(1)) if c and c.group(1)
                             in self.comps else {})
                for k in tot:
                    tot[k] += trip * (body.get(k, 0.0)
                                      + cond_cost.get(k, 0.0))
                for k, v in body.get("_coll_by_kind", {}).items():
                    coll_by_kind[k] = coll_by_kind.get(k, 0.0) + trip * v
                for k, v in body.get("_coll_counts", {}).items():
                    counts[k] = counts.get(k, 0.0) + trip * v
            elif op in ("fusion",):
                m = _CALLED_RE.search(ins.rest)
                called = self.comps.get(m.group(1)) if m else None
                if called is not None:
                    tot["flops"] += self._fusion_flops(called)
                # in-place loop-carried updates: a fusion whose root is a
                # dynamic-update-slice aliases its big operand — traffic is
                # the updated slice, not the whole (L, ...) stacked buffer
                # (counting the buffer made 32k-decode look 30x more
                # memory-bound than it is).
                dus = self._dus_root_update_bytes(called)
                if dus is not None and dus < out_bytes:
                    tot["bytes"] += 2 * dus + (opnd_bytes - out_bytes
                                               if opnd_bytes > out_bytes
                                               else 0)
                else:
                    disc = self._fusion_sliced_discount(called)
                    tot["bytes"] += out_bytes + max(0.0, opnd_bytes - disc)
            elif op in ("call", "conditional", "async-start"):
                m = _CALLED_RE.search(ins.rest)
                if m and m.group(1) in self.comps:
                    sub = self.cost(m.group(1))
                    for k in tot:
                        tot[k] += sub.get(k, 0.0)
                    for k, v in sub.get("_coll_by_kind", {}).items():
                        coll_by_kind[k] = coll_by_kind.get(k, 0.0) + v
                    for k, v in sub.get("_coll_counts", {}).items():
                        counts[k] = counts.get(k, 0.0) + v
            elif op in ("slice", "dynamic-slice", "gather"):
                # traffic ~ the slice moved (out read + write), NOT the
                # full operand: counting a (L, ...) stacked cache as read
                # per layer-loop slice inflated decode bytes ~30x.
                tot["bytes"] += 2 * out_bytes
            elif op in ("dynamic-update-slice", "scatter"):
                # read-modify-write of the update region; the big operand
                # aliases in place.
                upd = min((b for b in (
                    _shape_elems_bytes(comp.shapes.get(nm, ""))[1]
                    for nm in _OPERANDS_SPLIT_RE.findall(
                        ins.rest.split("),")[0])) if b > 0),
                    default=out_bytes)
                tot["bytes"] += 2 * upd
            elif op == "dot":
                tot["flops"] += _dot_flops(ins, comp.shapes)
                tot["bytes"] += out_bytes + opnd_bytes
            elif any(op == k or op == k + "-start" for k in COLLECTIVES):
                kind = op[:-6] if op.endswith("-start") else op
                tot["coll_bytes"] += out_bytes
                tot["bytes"] += out_bytes + opnd_bytes
                coll_by_kind[kind] = coll_by_kind.get(kind, 0.0) + out_bytes
                counts[kind] = counts.get(kind, 0.0) + 1
            elif op.endswith("-done"):
                continue
            elif op in _SKIP_OPS:
                continue
            else:
                tot["flops"] += out_elems
                tot["bytes"] += out_bytes + opnd_bytes
        tot["_coll_by_kind"] = coll_by_kind
        tot["_coll_counts"] = counts
        self._memo[comp_name] = tot
        return tot


def analyze(hlo_text: str) -> Dict[str, float]:
    h = HloCost(hlo_text)
    c = h.cost()
    return {
        "flops": c["flops"], "bytes": c["bytes"],
        "coll_bytes": c["coll_bytes"],
        "coll_by_kind": dict(c["_coll_by_kind"]),
        "coll_counts": {k: int(v) for k, v in c["_coll_counts"].items()},
        "unparsed_loops": h.unparsed_loops,
    }
