"""Production mesh factory.

Single pod : (16, 16)      axes ("data", "model")        = 256 chips (v5e pod)
Multi-pod  : (2, 16, 16)   axes ("pod", "data", "model") = 512 chips

A function, not a module constant: importing this module never touches JAX
device state (the dry-run sets XLA_FLAGS *before* any jax import)."""
from __future__ import annotations

import jax


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    """`jax.make_mesh` across jax versions: `AxisType`/`axis_types` only
    exist on newer jax; older releases use Auto-equivalent semantics, so
    omitting the kwarg there is behaviour-preserving."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(n: int = 1, axes=("data", "model")) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    ndev = len(jax.devices())
    n = min(n, ndev)
    return make_mesh((n, 1), axes)
