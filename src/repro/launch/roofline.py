"""Roofline-term derivation from a compiled dry-run artifact.

Hardware constants (TPU v5e target):
  197 TFLOP/s bf16 per chip | 819 GB/s HBM | ~50 GB/s/link ICI.

Terms (seconds; per-chip quantities — XLA's post-SPMD module is the
per-device program, so cost_analysis/HLO text are already per chip):
  compute    = flops_per_chip / peak
  memory     = bytes_accessed_per_chip / hbm_bw
  collective = collective_bytes_per_chip / ici_bw

MODEL_FLOPS (analytic "useful" flops, global):
  train_4k    : 6 * N_active * tokens
  prefill_32k : 2 * N_active * tokens
  decode      : 2 * N_active * batch  (+ KV-cache reads are memory, not flops)
with N_active = active params excluding embed/unembed tables.
"""
from __future__ import annotations

import re
from typing import Any, Dict

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # B/s / chip
ICI_BW = 50e9             # B/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum output-shape bytes of every collective op in (per-device) HLO.

    Post-optimisation HLO lines look like
      %x = bf16[4,128]{1,0} all-reduce(%y), replica_groups=...
    (possibly a tuple output, possibly `-start`).  We parse every shape
    literal between `=` and the op name — i.e. the op's result shape(s) —
    and skip `-done` halves of async pairs so nothing double-counts.
    """
    per_kind = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        _, _, rhs = s.partition("=")
        for kind in _COLLECTIVES:
            m = re.search(rf"\b{kind}(-start)?\(", rhs)
            if m is None or f"{kind}-done" in rhs:
                continue
            per_kind[kind] += _shape_bytes(rhs[:m.start()])
            counts[kind] += 1
            break
    total = sum(per_kind.values())
    return {"total": total, "per_kind": per_kind, "counts": counts}


def terms(flops_per_chip: float, bytes_per_chip: float,
          coll_bytes_per_chip: float) -> Dict[str, float]:
    compute = flops_per_chip / PEAK_FLOPS
    memory = bytes_per_chip / HBM_BW
    coll = coll_bytes_per_chip / ICI_BW
    dominant = max(("compute", compute), ("memory", memory),
                   ("collective", coll), key=lambda kv: kv[1])[0]
    step = max(compute, memory, coll)
    return {
        "compute_s": compute, "memory_s": memory, "collective_s": coll,
        "dominant": dominant, "step_lower_bound_s": step,
        # fraction of the step the chip would spend at peak flops if the
        # dominant term were fully overlapped with the others
        "roofline_fraction": compute / step if step > 0 else 0.0,
    }


def model_flops(cfg, shape_info: Dict[str, Any]) -> float:
    emb = 2 * cfg.padded_vocab * cfg.d_model
    n_active = cfg.active_param_count() - emb
    B, S = shape_info["batch"], shape_info["seq"]
    kind = shape_info["kind"]
    if kind == "train":
        return 6.0 * n_active * B * S
    if kind == "prefill":
        return 2.0 * n_active * B * S
    return 2.0 * n_active * B          # decode: one token per sequence
