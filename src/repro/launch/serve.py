"""Serving launcher: period-T tiered serving with the paper's scheduler.

CPU demo form (reduced ladder, real latencies):
  PYTHONPATH=src python -m repro.launch.serve --periods 4 --n 16 \
      [--policy auto|amr2|amdp|dual|greedy] [--t-factor 0.8]

On a fleet the same runtime takes the assigned-arch ladders (e.g.
gemma3-1b + scaled variants on the ED tier, internvl2-76b on the ES pod)
with roofline-derived profiles; this entry point wires the reduced
configs so the loop is runnable anywhere.
"""
from __future__ import annotations

import argparse

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--periods", type=int, default=4)
    ap.add_argument("--n", type=int, default=16)
    ap.add_argument("--policy", default="auto")
    ap.add_argument("--t-factor", type=float, default=0.8)
    ap.add_argument("--train-steps", type=int, default=20)
    ap.add_argument("--fail-period", type=int, default=-1,
                    help="simulate an ES outage in this period")
    args = ap.parse_args(argv)

    import sys, os
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))), "examples"))
    from serve_offload import build_models, make_apply  # noqa: E402
    from repro.data.pipeline import DataConfig, TokenPipeline
    from repro.serving import ServingRuntime, TierProfile, measure_latency
    from repro.configs.paper_edge import CONFIG as ES_CFG

    models = build_models(train_steps=args.train_steps)
    applies = [make_apply(c, p) for c, p in models]
    pipe = TokenPipeline(DataConfig(vocab_size=ES_CFG.vocab_size,
                                    seq_len=64, global_batch=max(args.n, 16),
                                    seed=7))
    test_jobs = [pipe.batch_at(0)["tokens"][i] for i in range(8)]
    accs = [float(np.mean(app(test_jobs))) for app in applies]
    lats = [measure_latency(lambda a=app: a(test_jobs[:1]), (), iters=8)
            for app in applies]
    profile = TierProfile(
        name="ladder", p_ed=np.array([[lats[0], lats[1]]]),
        p_es=np.array([lats[2] * 1.2]), acc=np.array(accs), classes=[64])

    T = args.n * lats[1] * args.t_factor
    rt = ServingRuntime(profile, applies[:2], applies[2], T=T,
                        policy=args.policy)
    for period in range(args.periods):
        jobs = [pipe.batch_at(10 + period)["tokens"][i]
                for i in range(args.n)]
        s = rt.run_period(jobs, np.full(args.n, 64),
                          es_fail=(period == args.fail_period))
        print(f"[serve] period {period}: {s.policy} A={s.total_accuracy:.2f}"
              f" pred={s.predicted_makespan:.3f}s wall={s.wall_makespan:.3f}s"
              f" viol={100 * s.violation:.0f}%"
              f"{' REPLANNED' if s.replanned else ''}")


if __name__ == "__main__":
    main()
