"""Assigned input-shape grid + ShapeDtypeStruct stand-ins per cell.

Shapes (LM grid — seq_len x global_batch):
  train_4k    : seq 4096,    batch 256   (training;      lowers train_step)
  prefill_32k : seq 32768,   batch 32    (inference;     lowers prefill_step)
  decode_32k  : seq 32768,   batch 128   (decode w/ KV cache; serve_step)
  long_500k   : seq 524288,  batch 1     (long-context decode; serve_step)

`long_500k` requires sub-quadratic attention — skipped for the pure
full-attention archs (internlm2, deepseek-coder, internvl2, whisper; see
DESIGN.md §Shape-grid skips), run for SSM/hybrid/window archs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import ModelConfig, cache_axes, cache_specs, param_axes
from ..models.model import param_shapes

# Adopted §Perf hillclimb winners (EXPERIMENTS.md): applied by
# `dryrun --perf`, recorded separately from the paper-faithful baseline.
PERF_OVERRIDES: Dict[tuple, Dict[str, str]] = {
    ("deepseek_coder_33b", "prefill_32k"): {"q_block": "4096",
                                            "attn_chunk": "512"},
    ("internlm2_20b", "prefill_32k"): {"q_block": "4096",
                                       "attn_chunk": "512"},
    ("internvl2_76b", "prefill_32k"): {"q_block": "4096",
                                       "attn_chunk": "512"},
    # internvl2 decode fp8 cache is already the shipping config (fits HBM)
}


SHAPES: Dict[str, Dict[str, Any]] = {
    "train_4k":    dict(kind="train",   seq=4096,    batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768,   batch=32),
    "decode_32k":  dict(kind="decode",  seq=32768,   batch=128),
    "long_500k":   dict(kind="decode",  seq=524288,  batch=1, long=True),
}

# archs whose every layer is unwindowed full attention -> long_500k skipped
FULL_ATTENTION_ARCHS = frozenset({
    "internlm2_20b", "deepseek_coder_33b", "internvl2_76b", "whisper_base",
})


def cell_supported(arch: str, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and arch.replace("-", "_") in \
            FULL_ATTENTION_ARCHS:
        return False, "long_500k needs sub-quadratic attention (DESIGN.md)"
    return True, ""


def shape_overrides(cfg: ModelConfig, shape: str) -> ModelConfig:
    """Per-shape config adjustments (lowering hygiene, not architecture):
    big shapes force chunked attention; whisper's decoder seq follows the
    grid while its encoder stays at 1500 stub frames."""
    info = SHAPES[shape]
    over = {}
    if info["kind"] in ("train", "prefill") and info["seq"] > 2048:
        over["attn_impl"] = "chunked"
    if info["kind"] in ("prefill", "decode"):
        # serving runs bf16 weights (standard practice; halves HBM)
        over["param_dtype"] = "bfloat16"
    if info["kind"] == "train" and cfg.remat == "none":
        # without remat the 4k-seq activation footprint exceeds HBM (the
        # dry-run memory_analysis proves it); full remat is the baseline,
        # the remat policy is a §Perf hillclimb knob
        over["remat"] = "full"
    if info["kind"] == "train" and not cfg.logit_chunk:
        # sequence-chunked loss: (B, S, V) f32 logits (+ cotangents) never
        # materialise whole
        over["logit_chunk"] = 512
    if info["kind"] == "train" and cfg.microbatches == 1:
        # grad accumulation halves the activation peak (16 GiB HBM budget);
        # microbatch count is a §Perf knob.  The 33B/76B-class models need 4.
        over["microbatches"] = 4 if cfg.d_model >= 7168 else 2
    return dataclasses.replace(cfg, **over) if over else cfg


def input_specs(cfg: ModelConfig, shape: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell —
    weak-type-correct, shardable, no device allocation."""
    info = SHAPES[shape]
    B, S = info["batch"], info["seq"]
    dt = jnp.dtype(cfg.dtype)
    i32 = jnp.int32

    def tok(b, s):
        return jax.ShapeDtypeStruct((b, s), i32)

    if info["kind"] in ("train", "prefill"):
        batch = {"tokens": tok(B, S)}
        if cfg.num_patches:
            batch["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_patches, cfg.d_model), dt)
        if cfg.is_encdec:
            batch["audio_feats"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), dt)
        return {"batch": batch}

    # decode: one new token against a seq-S cache
    return {"tokens": tok(B, 1), "cache": cache_specs(cfg, B, S)}


def batch_axes(cfg: ModelConfig, shape: str) -> Dict[str, Any]:
    """Logical axes for the input batch (mirrors input_specs)."""
    info = SHAPES[shape]
    if info["kind"] in ("train", "prefill"):
        axes = {"tokens": ("batch", "seq")}
        if cfg.num_patches:
            axes["patch_embeds"] = ("batch", None, "act_embed")
        if cfg.is_encdec:
            axes["audio_feats"] = ("batch", None, "act_embed")
        return {"batch": axes}
    return {"tokens": ("batch", None),
            "cache": cache_axes(cfg, info["batch"], info["seq"])}
