"""pjit-able step factories: train / prefill / decode.

Each factory closes over the (static) ModelConfig and returns a pure
function of arrays, suitable for `jax.jit(...).lower(...)` with
ShapeDtypeStructs (dry-run) or real buffers (examples/tests)."""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..models import ModelConfig, decode_step, loss_fn, prefill
from ..optim import adamw_init, adamw_update

PyTree = Any


def make_train_step(cfg: ModelConfig, *, lr=3e-4, impl: str = "jnp",
                    grad_tx: Optional[Callable] = None):
    """(params, opt_state, batch) -> (params, opt_state, loss).

    `grad_tx` is an optional gradient transform hook (e.g. the int8
    error-feedback compressor in distributed/compression.py)."""

    M = max(1, cfg.microbatches)

    def train_step(params, opt_state, batch):
        if M == 1:
            loss, grads = jax.value_and_grad(
                lambda p: loss_fn(p, batch, cfg, impl=impl))(params)
        else:
            # gradient accumulation: activation peak scales with B/M while
            # the optimizer still sees the full global batch
            mb = jax.tree.map(
                lambda x: x.reshape((M, x.shape[0] // M) + x.shape[1:]),
                batch)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, b):
                acc_l, acc_g = carry
                l, g = jax.value_and_grad(
                    lambda p: loss_fn(p, b, cfg, impl=impl))(params)
                acc_g = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), acc_g, g)
                return (acc_l + l, acc_g), None

            (loss, grads), _ = jax.lax.scan(body, (0.0, g0), mb)
            loss = loss / M
            grads = jax.tree.map(lambda g: g / M, grads)
        if grad_tx is not None:
            grads = grad_tx(grads)
        params, opt_state = adamw_update(grads, opt_state, params, lr=lr)
        return params, opt_state, loss

    return train_step


def make_eval_step(cfg: ModelConfig, *, impl: str = "jnp"):
    def eval_step(params, batch):
        return loss_fn(params, batch, cfg, impl=impl)
    return eval_step


def make_prefill_step(cfg: ModelConfig, max_seq: int, *, impl: str = "jnp"):
    """(params, batch) -> (cache, last-token logits)."""

    def prefill_step(params, batch):
        return prefill(params, batch, cfg, max_seq=max_seq, impl=impl)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    """(params, tokens (B,1), cache) -> (logits, new cache)."""

    def serve_step(params, tokens, cache):
        return decode_step(params, tokens, cache, cfg)

    return serve_step


def init_train_state(cfg: ModelConfig, params):
    return adamw_init(params)
