"""Fault-tolerant training driver.

Ties together: config registry -> pjit'd train step (optionally compressed
grads) -> deterministic data pipeline -> async manifest checkpoints ->
preemption handling -> straggler detection.

Restart semantics: `--resume` picks up the latest published checkpoint
(params, optimizer, data cursor) and continues bit-identically — the data
pipeline is a pure function of (seed, step).  A preemption (SIGTERM or the
--preempt-file sentinel, which makes it testable) triggers a synchronous
final save and exit code 42 so a supervisor can reschedule.

Elastic: the checkpoint stores unsharded leaves; on restart with a
different device count the restore path re-shards (see checkpoint.manager).

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch internlm2-20b \
      --smoke --steps 20 --global-batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import os
import signal
import sys
import time

import jax
import numpy as np

from repro.checkpoint import manager as ckpt
from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.distributed.compression import compress_tree
from repro.distributed.sharding import (base_rules, sharding_context,
                                        tree_shardings)
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models import init_params, param_axes
from repro.optim import adamw_init, cosine_schedule


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--preempt-file", default=None,
                    help="touch this file to simulate a preemption")
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh(len(jax.devices()))
    rules = base_rules(False)
    key = jax.random.key(args.seed)

    lr = cosine_schedule(args.lr, warmup=max(args.steps // 20, 1),
                         total=args.steps)
    grad_tx = None
    ef_error = {"v": None}
    if args.compress_grads:
        def grad_tx(g):  # noqa: E306
            out, ef_error["v"] = compress_tree(g, ef_error["v"])
            return out
    step_fn = make_train_step(cfg, lr=lr, grad_tx=grad_tx)

    p_shard = tree_shardings(param_axes(cfg), mesh, rules)
    with sharding_context(mesh, rules):
        step_jit = jax.jit(step_fn, donate_argnums=(0, 1))

        params = init_params(cfg, key)
        opt = adamw_init(params)
        start_step = 0
        if args.resume and args.ckpt_dir:
            latest = ckpt.latest_step(args.ckpt_dir)
            if latest is not None:
                (params, opt), meta = ckpt.restore(
                    args.ckpt_dir, latest, (params, opt))
                start_step = int(meta["step"]) + 1
                print(f"[train] resumed from step {latest} "
                      f"(data cursor {start_step})")

        pipe = TokenPipeline(DataConfig(
            vocab_size=cfg.vocab_size, seq_len=args.seq,
            global_batch=args.global_batch, seed=args.seed))
        writer = (ckpt.AsyncCheckpointer(args.ckpt_dir)
                  if args.ckpt_dir else None)

        preempted = {"flag": False}

        def _sig(_s, _f):
            preempted["flag"] = True
        signal.signal(signal.SIGTERM, _sig)

        ema = None
        losses = []
        for step in range(start_step, args.steps):
            t0 = time.time()
            batch = {k: jax.numpy.asarray(v)
                     for k, v in pipe.batch_at(step).items()}
            params, opt, loss = step_jit(params, opt, batch)
            losses.append(float(loss))
            dt = time.time() - t0
            ema = dt if ema is None else 0.9 * ema + 0.1 * dt
            if dt > args.straggler_factor * ema and step > start_step + 3:
                print(f"[train] straggler tick at step {step}: "
                      f"{dt:.2f}s vs ema {ema:.2f}s — at fleet scale this "
                      f"triggers re-profiling/eviction")
            if step % args.log_every == 0:
                print(f"[train] step {step} loss {float(loss):.4f} "
                      f"({dt:.2f}s)")
            if writer and step % args.ckpt_every == 0 and step > start_step:
                writer.submit(step, (params, opt), {"step": step})
            if args.preempt_file and os.path.exists(args.preempt_file):
                preempted["flag"] = True
            if preempted["flag"]:
                print(f"[train] preemption at step {step}: saving + exiting")
                if writer:
                    writer.wait()
                if args.ckpt_dir:
                    ckpt.save(args.ckpt_dir, step, (params, opt),
                              {"step": step})
                sys.exit(42)

        if writer:
            writer.submit(args.steps - 1, (params, opt),
                          {"step": args.steps - 1})
            writer.wait()
        print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
        return losses


if __name__ == "__main__":
    main()
