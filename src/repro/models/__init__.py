from .config import ModelConfig, dense_lm, moe_lm, pad_vocab
from .model import (init_params, param_axes, param_shapes, forward, loss_fn,
                    logits_from_h, prefill, decode_step, init_cache,
                    cache_specs, cache_axes)

__all__ = [
    "ModelConfig", "dense_lm", "moe_lm", "pad_vocab",
    "init_params", "param_axes", "param_shapes", "forward", "loss_fn",
    "logits_from_h", "prefill", "decode_step", "init_cache", "cache_specs",
    "cache_axes",
]
