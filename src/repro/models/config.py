"""Unified model configuration covering all assigned architecture families.

A model is a stack of layers described by a repeating ``pattern`` of
(mixer, ffn) pairs; ``num_layers = n_cycles * len(pattern) + tail`` where the
tail layers (pattern prefix) are unrolled and the cycles are scanned
(`lax.scan` over stacked params) so HLO size is O(pattern), not O(depth).

mixer kinds : full | swa | local | enc | dec | rglru | ssd
ffn kinds   : swiglu | gelu | moe | none
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

Layer = Tuple[str, str]  # (mixer, ffn)


def pad_vocab(v: int, multiple: int = 128) -> int:
    return ((v + multiple - 1) // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | vlm | audio | hybrid | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    pattern: Tuple[Layer, ...]        # repeating per-layer (mixer, ffn)

    # attention
    window_size: int = 4096           # for "swa"
    local_window: int = 512           # for "local"
    rope_theta: float = 10_000.0
    rope_theta_global: float = 1_000_000.0

    # moe
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_groups: int = 32              # dispatch groups (aligned with DP)

    # ssm (mamba2 SSD)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    ssm_chunk: int = 256

    # rg-lru (recurrentgemma)
    lru_width: int = 0

    # encoder-decoder (whisper): encoder layers use pattern ("enc","gelu")
    encoder_layers: int = 0
    encoder_seq: int = 0              # precomputed frame embeddings (stub)

    # vlm stub frontend
    num_patches: int = 0              # precomputed patch embeddings (stub)

    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    kv_cache_dtype: str = "bfloat16"  # fp8 halves cache HBM + read bw
    score_dtype: str = "float32"      # attention score emit dtype
    attn_impl: str = "auto"           # auto | dense | chunked | pallas
    attn_chunk: int = 512
    q_block: int = 0                  # >0: causal q-block chunking (structural
                                      # flop halving; see EXPERIMENTS §Perf)
    remat: str = "none"               # none | full | dots
    logit_chunk: int = 0              # >0: sequence-chunked loss
    microbatches: int = 1             # grad-accumulation steps per batch

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab_size)

    @property
    def d_inner(self) -> int:         # ssd inner width
        return self.ssm_expand * self.d_model

    @property
    def cycles_and_tail(self) -> Tuple[int, int]:
        p = len(self.pattern)
        return self.num_layers // p, self.num_layers % p

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def scaled(self, width_mult: float, depth_mult: float = 1.0
               ) -> "ModelConfig":
        """MobileNet-alpha-style variant ladder (paper §III-A: the m ED
        models are instantiations of the same DNN at different sizes)."""
        def r128(x):
            return max(128, int(x * width_mult) // 128 * 128)

        p = len(self.pattern)
        nl = max(p, int(self.num_layers * depth_mult) // p * p)
        return dataclasses.replace(
            self, name=f"{self.name}-w{width_mult:g}",
            num_layers=nl,
            d_model=r128(self.d_model),
            d_ff=r128(self.d_ff) if self.d_ff else 0,
            moe_d_ff=r128(self.moe_d_ff) if self.moe_d_ff else 0,
            lru_width=r128(self.lru_width) if self.lru_width else 0,
            num_heads=max(1, int(self.num_heads * width_mult)),
            num_kv_heads=max(1, min(self.num_kv_heads,
                                    int(self.num_heads * width_mult))),
        )

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once; see
        benchmarks/roofline.py MODEL_FLOPS)."""
        d = self.d_model
        n = self.padded_vocab * d                       # embed
        n += self.padded_vocab * d                      # unembed (untied)
        enc = self.encoder_layers
        for li in range(self.num_layers + enc):
            mixer, ffn = self.layer_kind(li)
            if mixer in ("full", "swa", "local", "enc", "dec"):
                n += d * self.num_heads * self.head_dim * 2      # q, o
                n += d * self.num_kv_heads * self.head_dim * 2   # k, v
                if mixer == "dec":
                    n += d * self.num_heads * self.head_dim * 2
                    n += d * self.num_kv_heads * self.head_dim * 2
            elif mixer == "rglru":
                w = self.lru_width
                n += d * w * 2 + w * d + 3 * w           # in x2, out, gates
                n += w * self.conv_width
            elif mixer == "ssd":
                di = self.d_inner
                n += d * (2 * di + 2 * self.ssm_state + self.ssm_heads)
                n += di * d + di * self.conv_width + 2 * self.ssm_heads
            if ffn in ("swiglu",):
                n += 3 * d * self.d_ff
            elif ffn == "gelu":
                n += 2 * d * self.d_ff
            elif ffn == "moe":
                n += d * self.num_experts
                n += self.num_experts * 3 * d * self.moe_d_ff
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts)."""
        if not self.num_experts:
            return self.param_count()
        full = self.param_count()
        dead = (self.num_experts - self.experts_per_token) * \
            3 * self.d_model * self.moe_d_ff * self.num_layers
        return full - dead

    def layer_kind(self, li: int) -> Layer:
        """(mixer, ffn) of decoder layer li (encoder layers are all enc)."""
        if li >= self.num_layers:  # encoder layers appended after decoder
            return ("enc", "gelu")
        return self.pattern[li % len(self.pattern)]


# ---------------------------------------------------------------------------
# family constructors
# ---------------------------------------------------------------------------
def dense_lm(name, layers, d_model, heads, kv_heads, d_ff, vocab, *,
             head_dim=None, mixer="full", **kw) -> ModelConfig:
    return ModelConfig(
        name=name, family=kw.pop("family", "dense"), num_layers=layers,
        d_model=d_model, num_heads=heads, num_kv_heads=kv_heads,
        head_dim=head_dim or d_model // heads, d_ff=d_ff, vocab_size=vocab,
        pattern=((mixer, "swiglu"),), **kw)


def moe_lm(name, layers, d_model, heads, kv_heads, d_ff_expert, vocab,
           n_experts, top_k, **kw) -> ModelConfig:
    return ModelConfig(
        name=name, family="moe", num_layers=layers, d_model=d_model,
        num_heads=heads, num_kv_heads=kv_heads, head_dim=d_model // heads,
        d_ff=0, vocab_size=vocab, pattern=(("full", "moe"),),
        num_experts=n_experts, experts_per_token=top_k,
        moe_d_ff=d_ff_expert, **kw)
