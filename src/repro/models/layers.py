"""Layer zoo: attention (full/SWA/local/enc/dec-cross, GQA), SwiGLU/GELU/MoE
FFNs, RG-LRU recurrent block, Mamba2 SSD block — each with a paired decode
step operating on an explicit cache pytree.

Numerics: params live in ``param_dtype`` (f32), compute runs in ``dtype``
(bf16 target), and every reduction that needs it (softmax, recurrent state,
MoE gates, losses) accumulates in f32.

Attention has three interchangeable implementations:
  * dense    — materialises (Sq, Sk) scores; reference + smoke tests.
  * chunked  — `lax.scan` over KV chunks with online-softmax accumulators
               (flash-attention math at the jnp level) so big shapes lower
               without an S^2 buffer; optional q-block causal scheduling
               structurally skips fully-masked work (see EXPERIMENTS §Perf).
  * pallas   — `repro.kernels.flash_attention` (TPU target).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from ..distributed.sharding import shard_activation

PyTree = Any
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------
@jax.custom_vjp
def _grad_bf16(x):
    return x


def _grad_bf16_fwd(x):
    return x, None


def _grad_bf16_bwd(_, g):
    return (g.astype(jnp.bfloat16),)


_grad_bf16.defvjp(_grad_bf16_fwd, _grad_bf16_bwd)


def grad_dtype_barrier(x):
    """Identity forward; casts the cotangent to bf16 on the way back.

    JAX cotangents follow einsum promotion rules, not primal dtypes: the
    f32 flash accumulator's backward chain promotes dq/dk/dv — and then the
    whole residual-stream gradient — to f32, doubling every backward
    (B,S,D) all-gather/matmul (dry-run: 7x 384 MiB f32 gathers per layer).
    A barrier at each block boundary pins the cotangents back to bf16."""
    if x.dtype == jnp.bfloat16:
        return _grad_bf16(x)
    return x


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    # squares in the compute dtype, accumulation in f32: `x.astype(f32)`
    # would materialise an f32 (B,S,D) that GSPMD then all-gathers in f32
    # for the following projection (dry-run: 7x 384 MiB f32 gathers/layer);
    # bf16 squares + f32 reduce keep the gathered operand bf16 at a ~0.4%
    # variance-estimate error.
    dt = x.dtype
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True,
                   dtype=jnp.float32)
    inv = jax.lax.rsqrt(var + eps).astype(dt)
    return x * inv * (1.0 + scale.astype(dt))


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D) rotated at `positions` (broadcastable to (..., S))."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq       # (..., S, half)
    # angles in f32 (positions up to 512k), application in the compute dtype:
    # f32 rotation makes every projection-backward dot f32 at (B*S, D) —
    # dry-run measured multiple 1.5 GiB/chip f32 buffers from exactly this.
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def _mask(kind: str, q_pos, k_pos, window: int):
    """(Sq, Sk) boolean mask from absolute positions."""
    q = q_pos[:, None]
    k = k_pos[None, :]
    if kind == "causal":
        return k <= q
    if kind == "window":                  # causal sliding window
        return (k <= q) & (k > q - window)
    if kind == "none":
        return jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def _dense_attention(q, k, v, q_pos, k_pos, mask_kind, window):
    """q,k,v: (B,S,H,D) — KV already repeated to H heads (GQA flattened so
    the head axis shards cleanly; (KH, G) split dims defeat GSPMD)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    m = _mask(mask_kind, q_pos, k_pos, window)
    s = jnp.where(m[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)
    return o


def _chunked_attention(q, k, v, q_pos, k_pos, mask_kind, window, chunk,
                       score_dtype=jnp.float32):
    """Online-softmax scan over KV chunks. Shapes as in _dense_attention."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    nchunk = -(-Sk // chunk)
    pad = nchunk * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=-10**9)
    kc = k.reshape(B, nchunk, chunk, H, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nchunk, chunk, H, D).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(nchunk, chunk)
    scale = D ** -0.5

    def step(carry, xs):
        acc, mx, den = carry
        kb, vb, pb = xs
        # score einsum emits `score_dtype`: f32 (default, flash-standard)
        # keeps softmax exact but makes the attention *cotangents* f32 —
        # every backward all-gather/matmul on the (B,S,D) path pays 2x
        # bytes.  bf16 scores trade ~2-3 mantissa bits for bf16 cotangents
        # (§Perf hillclimb knob; the TPU pallas kernel keeps f32 in VMEM
        # where it costs nothing).
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kb,
                       preferred_element_type=score_dtype
                       ).astype(jnp.float32) * scale
        s = shard_activation(s, "batch", "act_heads", None, None)
        m = _mask(mask_kind, q_pos, pb, window)
        s = jnp.where(m[None, None], s, NEG_INF)
        bmx = jnp.maximum(mx, s.max(axis=-1))
        corr = jnp.exp(mx - bmx)
        p = jnp.exp(s - bmx[..., None])
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(vb.dtype), vb).astype(jnp.float32)
        den = den * corr + p.sum(axis=-1)
        return (acc, bmx, den), None

    acc0 = jnp.zeros((B, H, Sq, D), jnp.float32)
    mx0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    den0 = jnp.zeros((B, H, Sq), jnp.float32)
    # checkpoint the step: without it scan-backward saves every chunk's
    # (Sq, chunk) score block — the full S^2 residual flash attention exists
    # to avoid (dry-run showed 100+ GiB/chip at 4k train without this).
    (acc, _, den), _ = jax.lax.scan(jax.checkpoint(step),
                                    (acc0, mx0, den0), (kc, vc, pc))
    o = acc / jnp.maximum(den[..., None], 1e-30)
    return o.transpose(0, 2, 1, 3).astype(q.dtype)   # (B,Sq,H,D)


def attention(q, k, v, q_pos, k_pos, *, mask_kind, window, cfg: ModelConfig):
    """GQA attention dispatcher. q: (B,Sq,H,D) -> (B,Sq,H,D).

    KV heads are repeated up to H before the score einsums: a flattened
    head axis is the only layout GSPMD can shard on the model axis (the
    (KH, G) factorisation has no divisible dim on a 16-wide mesh axis)."""
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    qg = grad_dtype_barrier(shard_activation(q, "batch", None, "act_heads",
                                             None))
    k = grad_dtype_barrier(shard_activation(k, "batch", None, "act_heads",
                                            None))
    v = grad_dtype_barrier(shard_activation(v, "batch", None, "act_heads",
                                            None))
    impl = cfg.attn_impl
    if impl == "auto":
        impl = "dense" if (Sq * k.shape[1] <= 2048 * 2048) else "chunked"
    if impl == "pallas":
        from ..kernels.flash_attention import ops as fa_ops
        o = fa_ops.flash_attention(qg, k, v, q_pos, k_pos,
                                   mask_kind=mask_kind, window=window)
    elif impl == "dense":
        o = _dense_attention(qg, k, v, q_pos, k_pos, mask_kind, window)
    elif impl == "chunked":
        if cfg.q_block and mask_kind in ("causal", "window") and Sq > cfg.q_block:
            o = _qblock_attention(qg, k, v, q_pos, k_pos, mask_kind, window,
                                  cfg)
        else:
            o = _chunked_attention(qg, k, v, q_pos, k_pos, mask_kind, window,
                                   cfg.attn_chunk,
                                   score_dtype=jnp.dtype(cfg.score_dtype))
    else:
        raise ValueError(impl)
    return o.reshape(B, Sq, H, D)


def _qblock_attention(qg, k, v, q_pos, k_pos, mask_kind, window, cfg):
    """Causal/windowed attention with static per-q-block KV ranges: q block i
    only scans KV prefix (causal) or its window band — the *structural* flop
    reduction measured in §Perf (HLO flops drop ~2x causal, ~S/W windowed)."""
    B, Sq, H, D = qg.shape
    qb = cfg.q_block
    nq = Sq // qb
    outs = []
    for i in range(nq):
        qs, qe = i * qb, (i + 1) * qb
        if mask_kind == "causal":
            ks, ke = 0, qe
        else:  # window
            ks, ke = max(0, qs - window), qe
        o = _chunked_attention(qg[:, qs:qe], k[:, ks:ke], v[:, ks:ke],
                               q_pos[qs:qe], k_pos[ks:ke], mask_kind, window,
                               min(cfg.attn_chunk, ke - ks),
                               score_dtype=jnp.dtype(cfg.score_dtype))
        outs.append(o)
    return jnp.concatenate(outs, axis=1)


# ---------------------------------------------------------------------------
# attention block (params + apply + decode)
# ---------------------------------------------------------------------------
def attn_param_defs(cfg: ModelConfig, cross: bool = False):
    D, H, KH, Hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    defs = {
        "norm": ((D,), ("embed",)),
        "wq": ((D, H * Hd), ("embed", "qkv")),
        "wk": ((D, KH * Hd), ("embed", "kv")),
        "wv": ((D, KH * Hd), ("embed", "kv")),
        "wo": ((H * Hd, D), ("qkv", "embed")),
    }
    if cross:
        defs.update({
            "xnorm": ((D,), ("embed",)),
            "xwq": ((D, H * Hd), ("embed", "qkv")),
            "xwk": ((D, KH * Hd), ("embed", "kv")),
            "xwv": ((D, KH * Hd), ("embed", "kv")),
            "xwo": ((H * Hd, D), ("qkv", "embed")),
        })
    return defs


def _proj_qkv(x, p, cfg, prefix=""):
    B, S, _ = x.shape
    H, KH, Hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = x.dtype
    q = (x @ p[prefix + "wq"].astype(dt)).reshape(B, S, H, Hd)
    k = (x @ p[prefix + "wk"].astype(dt)).reshape(B, S, KH, Hd)
    v = (x @ p[prefix + "wv"].astype(dt)).reshape(B, S, KH, Hd)
    return q, k, v


def _mixer_spec(mixer: str, cfg: ModelConfig):
    """(mask_kind, window, theta) for a self-attention mixer."""
    if mixer == "full":
        return "causal", 0, cfg.rope_theta
    if mixer == "swa":
        return "window", cfg.window_size, cfg.rope_theta
    if mixer == "local":
        return "window", cfg.local_window, cfg.rope_theta
    if mixer == "global":
        return "causal", 0, cfg.rope_theta_global
    if mixer == "enc":
        return "none", 0, cfg.rope_theta
    if mixer == "dec":
        return "causal", 0, cfg.rope_theta
    raise ValueError(mixer)


def attn_apply(p, x, mixer, cfg: ModelConfig, positions,
               enc_out: Optional[jnp.ndarray] = None,
               want_cache: bool = False, max_seq: int = 0):
    """Full-sequence self (+optional cross) attention block."""
    mask_kind, window, theta = _mixer_spec(mixer, cfg)
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q, k, v = _proj_qkv(h, p, cfg)
    if mixer != "enc":                      # encoder uses no RoPE-on-frames
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
    cache = (attn_prefill_cache(p, (k, v), mixer, cfg, max_seq)
             if want_cache else None)
    o = attention(q, k, v, positions, positions, mask_kind=mask_kind,
                  window=window, cfg=cfg)
    x = x + o.reshape(x.shape[0], x.shape[1], -1) @ p["wo"].astype(x.dtype)
    if mixer == "dec" and enc_out is not None:
        h = rms_norm(x, p["xnorm"], cfg.norm_eps)
        B, S, _ = h.shape
        H, KH, Hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        q = (h @ p["xwq"].astype(h.dtype)).reshape(B, S, H, Hd)
        k = (enc_out @ p["xwk"].astype(h.dtype)).reshape(B, -1, KH, Hd)
        v = (enc_out @ p["xwv"].astype(h.dtype)).reshape(B, -1, KH, Hd)
        epos = jnp.arange(enc_out.shape[1])
        o = attention(q, k, v, positions, epos, mask_kind="none", window=0,
                      cfg=cfg)
        x = x + o.reshape(B, S, -1) @ p["xwo"].astype(x.dtype)
    return x, cache


def attn_cache_len(mixer: str, cfg: ModelConfig, max_seq: int) -> int:
    mask_kind, window, _ = _mixer_spec(mixer, cfg)
    return min(max_seq, window) if mask_kind == "window" else max_seq


def attn_decode(p, x, cache, mixer, cfg: ModelConfig, index,
                enc_out: Optional[jnp.ndarray] = None):
    """One-token decode. x: (B,1,D); cache: {"k","v"}: (B,W,KH,Hd) ring
    buffers (RoPE pre-applied at write); `index` — absolute position."""
    mask_kind, window, theta = _mixer_spec(mixer, cfg)
    W = cache["k"].shape[1]
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q, k, v = _proj_qkv(h, p, cfg)
    pos = jnp.full((1,), index, jnp.int32)
    q = rope(q, pos, theta)
    k = rope(k, pos, theta)
    slot = index % W
    # one-hot masked write, NOT dynamic_update_slice: a dus at a traced
    # index on the sequence-sharded cache makes the SPMD partitioner
    # replicate the whole cache per chip ("involuntary full remat" — 8+ GiB
    # at the 32k shapes).  The masked write is elementwise and stays sharded.
    hot = (jnp.arange(W) == slot)[None, :, None, None]
    ck = jnp.where(hot, k.astype(cache["k"].dtype), cache["k"])
    cv = jnp.where(hot, v.astype(cache["v"].dtype), cache["v"])
    # absolute position of each ring slot
    slots = jnp.arange(W)
    wraps = (index // W) - (slots > slot)
    abs_pos = jnp.where(slots <= slot, slots + (index // W) * W,
                        slots + (index // W - 1) * W)
    del wraps
    valid = (abs_pos >= 0) & (abs_pos <= index)
    if mask_kind == "window":
        valid &= abs_pos > index - window
    B, _, H, Hd = q.shape
    KH = ck.shape[2]
    G = H // KH
    # grouped-GQA einsum, NOT kv-repeat: repeating the cache to H heads
    # materialises G x the cache (17 GiB/chip at 32k decode, measured).
    # The cache is sequence-sharded (flash-decode): the softmax reductions
    # over the sharded k axis become per-shard partials + a small combine.
    qg = q.reshape(B, 1, KH, G, Hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, ck.astype(q.dtype),
                   preferred_element_type=jnp.float32) * (Hd ** -0.5)
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", pattn.astype(x.dtype),
                   cv.astype(x.dtype))
    x = x + o.reshape(B, 1, -1) @ p["wo"].astype(x.dtype)
    if mixer == "dec" and enc_out is not None:
        h = rms_norm(x, p["xnorm"], cfg.norm_eps)
        H, KH2, Hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        q2 = (h @ p["xwq"].astype(h.dtype)).reshape(B, 1, H, Hd)
        k2 = (enc_out @ p["xwk"].astype(h.dtype)).reshape(B, -1, KH2, Hd)
        v2 = (enc_out @ p["xwv"].astype(h.dtype)).reshape(B, -1, KH2, Hd)
        epos = jnp.arange(enc_out.shape[1])
        o2 = attention(q2, k2, v2, jnp.full((1,), index), epos,
                       mask_kind="none", window=0, cfg=cfg)
        x = x + o2.reshape(B, 1, -1) @ p["xwo"].astype(x.dtype)
    return x, {"k": ck, "v": cv}


def attn_prefill_cache(p, x_normed_kv: Tuple[jnp.ndarray, jnp.ndarray],
                       mixer, cfg, max_seq: int):
    """Build a ring cache from full-sequence K,V (RoPE already applied)."""
    k, v = x_normed_kv
    B, S, KH, Hd = k.shape
    W = attn_cache_len(mixer, cfg, max_seq)
    cdt = jnp.dtype(cfg.kv_cache_dtype)
    k = k.astype(cdt)
    v = v.astype(cdt)
    ck = jnp.zeros((B, W, KH, Hd), cdt)
    cv = jnp.zeros((B, W, KH, Hd), cdt)
    take = min(S, W)
    ksrc, vsrc = k[:, -take:], v[:, -take:]
    slots = (jnp.arange(take) + (S - take)) % W
    ck = ck.at[:, slots].set(ksrc)
    cv = cv.at[:, slots].set(vsrc)
    return {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# FFNs
# ---------------------------------------------------------------------------
def ffn_param_defs(cfg: ModelConfig, kind: str):
    D = cfg.d_model
    if kind == "swiglu":
        F = cfg.d_ff
        return {"fnorm": ((D,), ("embed",)),
                "wi_gate": ((D, F), ("embed", "mlp")),
                "wi_up": ((D, F), ("embed", "mlp")),
                "wo_ffn": ((F, D), ("mlp", "embed"))}
    if kind == "gelu":
        F = cfg.d_ff
        return {"fnorm": ((D,), ("embed",)),
                "wi": ((D, F), ("embed", "mlp")),
                "wo_ffn": ((F, D), ("mlp", "embed"))}
    if kind == "moe":
        E, F = cfg.num_experts, cfg.moe_d_ff
        return {"fnorm": ((D,), ("embed",)),
                "router": ((D, E), ("embed", "expert")),
                "we_gate": ((E, D, F), ("expert", "embed", "expert_mlp")),
                "we_up": ((E, D, F), ("expert", "embed", "expert_mlp")),
                "we_down": ((E, F, D), ("expert", "expert_mlp", "embed"))}
    if kind == "none":
        return {}
    raise ValueError(kind)


def ffn_apply(p, x, kind, cfg: ModelConfig):
    if kind == "none":
        return x
    dt = x.dtype
    h = rms_norm(x, p["fnorm"], cfg.norm_eps)
    if kind == "swiglu":
        g = jax.nn.silu(h @ p["wi_gate"].astype(dt))
        u = h @ p["wi_up"].astype(dt)
        return x + (g * u) @ p["wo_ffn"].astype(dt)
    if kind == "gelu":
        u = jax.nn.gelu(h @ p["wi"].astype(dt))
        return x + u @ p["wo_ffn"].astype(dt)
    if kind == "moe":
        return x + moe_apply(p, h, cfg)
    raise ValueError(kind)


def moe_apply(p, h, cfg: ModelConfig):
    """Top-k routed experts with capacity-bounded scatter dispatch.

    Dispatch is scatter/gather-based (positions via a cumsum over the
    assignment one-hot), not a (B,S,E,C) einsum — the one-hot dispatch
    tensor would be ~10^14 elements at the 32k shapes.  Overflowed tokens
    (> capacity) are dropped, standard Switch-style."""
    B, S, D = h.shape
    E, K, F = cfg.num_experts, cfg.experts_per_token, cfg.moe_d_ff
    dt = h.dtype
    N = B * S
    x = h.reshape(N, D)
    logits = (x @ p["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, K)                  # (N,K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    if S == 1:
        # decode path: gather each token's K expert weights directly —
        # no capacity/drops, flops = exactly the active experts.
        wg = p["we_gate"][idx].astype(dt)                 # (N,K,D,F)
        wu = p["we_up"][idx].astype(dt)
        wd = p["we_down"][idx].astype(dt)                 # (N,K,F,D)
        g = jax.nn.silu(jnp.einsum("nd,nkdf->nkf", x, wg))
        u = jnp.einsum("nd,nkdf->nkf", x, wu)
        y = jnp.einsum("nkf,nkfd->nkd", g * u, wd)
        y = (y * gates[..., None].astype(dt)).sum(axis=1)
        return y.reshape(B, S, D)

    # Grouped dispatch: tokens split into `moe_groups` groups aligned with
    # the DP sharding; each group scatters into its own (E, Cg, D) buffer
    # with group-local capacity, so buffers shard over the data axes instead
    # of replicating a global-capacity buffer per chip (dry-run measured
    # 30+ GiB/chip without grouping at the 32k-prefill shapes).
    Gr = min(cfg.moe_groups, N)
    while N % Gr:
        Gr //= 2
    Nl = N // Gr
    cap = int(math.ceil(Nl * K / E * cfg.capacity_factor))
    cap = max(cap, K)
    xg = x.reshape(Gr, Nl, D)
    idx_g = idx.reshape(Gr, Nl, K)
    gates_g = gates.reshape(Gr, Nl, K)
    xg = shard_activation(xg, "moe_group", None, None)

    def one_group(xl, idxl, gatesl):
        e_flat = idxl.reshape(Nl * K)
        onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.float32)  # (NlK, E)
        pos = jnp.cumsum(onehot, axis=0) - onehot
        pos_in_e = jnp.take_along_axis(
            pos, e_flat[:, None], axis=1)[:, 0].astype(jnp.int32)
        keep = pos_in_e < cap
        slot = jnp.where(keep, pos_in_e, cap)                 # overflow slot
        x_rep = jnp.repeat(xl, K, axis=0)                     # (NlK, D)
        buf = jnp.zeros((E, cap + 1, D), dt)
        # scatter-SET, not add: slots are unique by construction (position-
        # in-expert), and XLA promotes bf16 scatter-add to f32 — which then
        # poisons every downstream expert matmul/collective to f32 (dry-run
        # measured 2x collective bytes).  Overflow-slot collisions don't
        # matter: that slot is sliced off.
        buf = buf.at[e_flat, slot].set(x_rep, mode="drop",
                                       unique_indices=True)
        buf = buf[:, :cap]                                    # (E, Cg, D)
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf,
                                   p["we_gate"].astype(dt)))
        u = jnp.einsum("ecd,edf->ecf", buf, p["we_up"].astype(dt))
        y_e = jnp.einsum("ecf,efd->ecd", g * u, p["we_down"].astype(dt))
        y_e = jnp.concatenate([y_e, jnp.zeros((E, 1, D), dt)], axis=1)
        y_tok = y_e[e_flat, slot]                             # (NlK, D)
        y_tok = y_tok * (gatesl.reshape(Nl * K, 1).astype(dt) *
                         keep[:, None].astype(dt))
        return y_tok.reshape(Nl, K, D).sum(axis=1)

    y = jax.vmap(one_group)(xg, idx_g, gates_g)
    return y.reshape(B, S, D)


# ---------------------------------------------------------------------------
# RG-LRU block (RecurrentGemma recurrent block)
# ---------------------------------------------------------------------------
def rglru_param_defs(cfg: ModelConfig):
    D, W, H = cfg.d_model, cfg.lru_width, cfg.num_heads
    bw = W // H
    return {"norm": ((D,), ("embed",)),
            "wx": ((D, W), ("embed", "lru")),
            "wy": ((D, W), ("embed", "lru")),
            "conv_w": ((cfg.conv_width, W), ("conv", "lru")),
            "gate_a": ((H, bw, bw), ("heads", "lru_block", "lru_block2")),
            "gate_x": ((H, bw, bw), ("heads", "lru_block", "lru_block2")),
            "a_param": ((W,), ("lru",)),
            "wout": ((W, D), ("lru", "embed"))}


_LRU_C = 8.0


def _rglru_gates(p, x):
    """x: (..., W) -> log_a (recurrence log-coeff) and gated input."""
    H, bw, _ = p["gate_a"].shape
    xs = x.reshape(x.shape[:-1] + (H, bw)).astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("...hb,hbc->...hc", xs,
                                  p["gate_a"].astype(jnp.float32)))
    i = jax.nn.sigmoid(jnp.einsum("...hb,hbc->...hc", xs,
                                  p["gate_x"].astype(jnp.float32)))
    r = r.reshape(x.shape)
    i = i.reshape(x.shape)
    log_a = -_LRU_C * jax.nn.softplus(p["a_param"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    gated = mult * (i * x.astype(jnp.float32))
    return a, gated


def _causal_conv(x, w, state=None):
    """Depthwise causal conv. x: (B,S,W); w: (K,W). Returns y and the new
    conv state (last K-1 inputs)."""
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
            for i in range(K))
    return y, xp[:, -(K - 1):]


def rglru_apply(p, x, cfg: ModelConfig, want_cache: bool = False):
    """Full-sequence recurrent block via associative scan."""
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    dt = x.dtype
    u = h @ p["wx"].astype(dt)                       # (B,S,W)
    ygate = jax.nn.gelu(h @ p["wy"].astype(dt))
    u, conv_state = _causal_conv(u, p["conv_w"])
    a, b = _rglru_gates(p, u)                        # f32 (B,S,W)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, hseq = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (hseq.astype(dt) * ygate) @ p["wout"].astype(dt)
    cache = ({"state": hseq[:, -1], "conv": conv_state}
             if want_cache else None)
    return x + y, cache


def rglru_decode(p, x, cache, cfg: ModelConfig, index):
    """x: (B,1,D); cache: {"state": (B,W) f32, "conv": (B,K-1,W)}."""
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    dt = x.dtype
    u = h @ p["wx"].astype(dt)
    ygate = jax.nn.gelu(h @ p["wy"].astype(dt))
    u, conv_state = _causal_conv(u, p["conv_w"], state=cache["conv"])
    a, b = _rglru_gates(p, u)                        # (B,1,W)
    state = a[:, 0] * cache["state"] + b[:, 0]
    y = (state[:, None].astype(dt) * ygate) @ p["wout"].astype(dt)
    return x + y, {"state": state, "conv": conv_state}



# ---------------------------------------------------------------------------
# Mamba2 SSD block
# ---------------------------------------------------------------------------
def ssd_param_defs(cfg: ModelConfig):
    D = cfg.d_model
    di = cfg.d_inner
    N, H = cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * N
    return {"norm": ((D,), ("embed",)),
            "in_proj": ((D, 2 * di + 2 * N + H), ("embed", "ssm_in")),
            "conv_w": ((cfg.conv_width, conv_dim), ("conv", "ssm_conv")),
            "A_log": ((H,), ("ssm_heads",)),
            "D_skip": ((H,), ("ssm_heads",)),
            "dt_bias": ((H,), ("ssm_heads",)),
            "gnorm": ((di,), ("ssm_inner",)),
            "out_proj": ((di, D), ("ssm_inner", "embed"))}


def _ssd_inputs(p, x, cfg: ModelConfig, conv_state=None):
    """Shared in-proj + conv + split for prefill/full/decode."""
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    P = di // H
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    zxbcdt = h @ p["in_proj"].astype(x.dtype)
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * N], axis=-1)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], state=conv_state)
    xbc = jax.nn.silu(xbc)
    xs, B_, C_ = jnp.split(xbc, [di, di + N], axis=-1)
    B, S = x.shape[0], x.shape[1]
    xs = xs.reshape(B, S, H, P)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))         # (H,)
    return z, xs, B_, C_, dt, A, new_conv


def _segsum(x):
    """x: (..., Q) -> (..., Q, Q) lower-triangular cumulative sums."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_scan_chunked(xs, dt, A, B_, C_, chunk):
    """Chunked SSD (Mamba2 Alg. 1) in pure jnp.

    xs: (B,S,H,P); dt: (B,S,H); A: (H,); B_,C_: (B,S,N) (single group).
    Returns y (B,S,H,P) and final state (B,H,P,N).
    """
    Bb, S, H, P = xs.shape
    N = B_.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        # dt=0 padding is inert: decay exp(0)=1 and xdt=0, so the state
        # carries through unchanged; padded y rows are sliced off.
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q
    xs_c = xs.reshape(Bb, nc, Q, H, P)
    dt_c = dt.reshape(Bb, nc, Q, H)
    B_c = B_.reshape(Bb, nc, Q, N).astype(jnp.float32)
    C_c = C_.reshape(Bb, nc, Q, N).astype(jnp.float32)
    dA = dt_c * A                                          # (B,nc,Q,H)
    xdt = xs_c.astype(jnp.float32) * dt_c[..., None]

    # intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))         # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcqn,bckn->bcqk", C_c, B_c)
    Y = jnp.einsum("bchqk,bcqk,bckhp->bcqhp", L, scores, xdt)

    # chunk states
    dA_cum = jnp.cumsum(dA, axis=2)                        # (B,nc,Q,H)
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)  # (B,nc,Q,H)
    states = jnp.einsum("bckn,bckh,bckhp->bchpn", B_c, decay_to_end, xdt)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])             # (B,nc,H)

    def scan_step(carry, xs_):
        dec, st_new = xs_
        out = carry
        carry = carry * dec[:, :, None, None] + st_new
        return carry, out

    init = jnp.zeros((Bb, H, P, N), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        scan_step, init,
        (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)     # (B,nc,H,P,N)

    decay_from_start = jnp.exp(dA_cum)                     # (B,nc,Q,H)
    Y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", C_c, prev_states,
                       decay_from_start)
    y = (Y + Y_off).reshape(Bb, S, H, P)
    if pad:
        y = y[:, :S - pad]
    return y, final_state


def ssd_apply(p, x, cfg: ModelConfig, impl: str = "jnp",
              want_cache: bool = False):
    z, xs, B_, C_, dt, A, conv_state = _ssd_inputs(p, x, cfg)
    if impl == "pallas":
        from ..kernels.ssd_scan import ops as ssd_ops
        y, final_state = ssd_ops.ssd_scan(xs, dt, A, B_, C_, cfg.ssm_chunk)
    else:
        y, final_state = ssd_scan_chunked(xs, dt, A, B_, C_, cfg.ssm_chunk)
    y = y + xs.astype(jnp.float32) * p["D_skip"].astype(jnp.float32)[:, None]
    y = y.reshape(x.shape[0], x.shape[1], cfg.d_inner)
    y = rms_norm(y.astype(x.dtype) * jax.nn.silu(z), p["gnorm"], cfg.norm_eps)
    cache = ({"state": final_state, "conv": conv_state}
             if want_cache else None)
    return x + y @ p["out_proj"].astype(x.dtype), cache


def ssd_decode(p, x, cache, cfg: ModelConfig, index):
    """cache: {"state": (B,H,P,N) f32, "conv": (B,K-1,conv_dim)}."""
    z, xs, B_, C_, dt, A, conv_state = _ssd_inputs(
        p, x, cfg, conv_state=cache["conv"])
    Bb = x.shape[0]
    H, P = cfg.ssm_heads, cfg.d_inner // cfg.ssm_heads
    N = cfg.ssm_state
    xs1 = xs[:, 0].astype(jnp.float32)                     # (B,H,P)
    dt1 = dt[:, 0]                                         # (B,H)
    B1 = B_[:, 0].astype(jnp.float32)                      # (B,N)
    C1 = C_[:, 0].astype(jnp.float32)
    dA = jnp.exp(dt1 * A)                                  # (B,H)
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt1, B1, xs1)
    state = cache["state"] * dA[..., None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", state, C1)
    y = y + xs1 * p["D_skip"].astype(jnp.float32)[:, None]
    y = y.reshape(Bb, 1, cfg.d_inner)
    y = rms_norm(y.astype(x.dtype) * jax.nn.silu(z), p["gnorm"], cfg.norm_eps)
    return x + y @ p["out_proj"].astype(x.dtype), \
        {"state": state, "conv": conv_state}



# ---------------------------------------------------------------------------
# block dispatcher
# ---------------------------------------------------------------------------
def block_param_defs(cfg: ModelConfig, mixer: str, ffn: str):
    if mixer == "rglru":
        defs = rglru_param_defs(cfg)
    elif mixer == "ssd":
        defs = ssd_param_defs(cfg)
    else:
        defs = attn_param_defs(cfg, cross=(mixer == "dec"))
    defs = dict(defs)
    defs.update(ffn_param_defs(cfg, ffn))
    return defs


def block_apply(p, x, mixer, ffn, cfg: ModelConfig, positions,
                enc_out=None, impl: str = "jnp", want_cache: bool = False,
                max_seq: int = 0):
    """Returns (x, cache) — cache is None unless want_cache (prefill)."""
    if mixer == "rglru":
        x, cache = rglru_apply(p, x, cfg, want_cache=want_cache)
    elif mixer == "ssd":
        x, cache = ssd_apply(p, x, cfg, impl=impl, want_cache=want_cache)
    else:
        x, cache = attn_apply(p, x, mixer, cfg, positions, enc_out=enc_out,
                              want_cache=want_cache, max_seq=max_seq)
    return ffn_apply(p, x, ffn, cfg), cache


def block_decode(p, x, cache, mixer, ffn, cfg: ModelConfig, index,
                 enc_out=None):
    if mixer == "rglru":
        x, cache = rglru_decode(p, x, cache, cfg, index)
    elif mixer == "ssd":
        x, cache = ssd_decode(p, x, cache, cfg, index)
    else:
        x, cache = attn_decode(p, x, cache, mixer, cfg, index,
                               enc_out=enc_out)
    return ffn_apply(p, x, ffn, cfg), cache
