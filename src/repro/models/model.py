"""Unified scan-over-layers LM covering all assigned architectures.

Layout: ``num_layers = n_cycles * len(pattern) + tail``.  The cycles are a
single `lax.scan` over stacked per-cycle params (HLO size O(|pattern|), so an
80-layer model compiles as fast as a 2-layer one); the tail (pattern prefix
remainder, e.g. gemma3's 26 = 4*6 + 2) is unrolled.

Entry points:
  init_params / param_axes      — param pytree + logical-axis pytree
  forward / loss_fn             — training path (next-token CE)
  prefill                       — forward + KV/state cache construction
  decode_step                   — one-token serve step on the cache
  init_cache / cache_axes       — cache pytree (zeros / ShapeDtypeStructs)
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.sharding import shard_activation
from .config import ModelConfig
from . import layers
from .layers import (attn_cache_len, block_apply, block_decode,
                     block_param_defs, rms_norm)

PyTree = Any


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------
def _init_leaf(key, shape, dtype):
    fan_in = shape[0] if len(shape) == 1 else int(np.prod(shape[:-1]))
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return jax.random.normal(key, shape, dtype) * scale


def _block_params(key, defs, n_stack, dtype):
    out = {}
    for i, (name, (shape, _axes)) in enumerate(sorted(defs.items())):
        k = jax.random.fold_in(key, i)
        full = (n_stack,) + shape if n_stack else shape
        out[name] = _init_leaf(k, full, dtype)
    return out


def _block_axes(defs, stacked: bool):
    return {name: (("layers",) + axes if stacked else axes)
            for name, (shape, axes) in sorted(defs.items())}


def init_params(cfg: ModelConfig, key: jax.Array) -> PyTree:
    pd = jnp.dtype(cfg.param_dtype)
    n_cycles, tail = cfg.cycles_and_tail
    keys = jax.random.split(key, 8)
    V, D = cfg.padded_vocab, cfg.d_model
    params: Dict[str, Any] = {
        "embed": _init_leaf(keys[0], (V, D), pd),
        "unembed": _init_leaf(keys[1], (D, V), pd),
        "final_norm": jnp.zeros((D,), pd),
    }
    blocks = []
    for k, (mixer, ffn) in enumerate(cfg.pattern):
        defs = block_param_defs(cfg, mixer, ffn)
        blocks.append(_block_params(jax.random.fold_in(keys[2], k), defs,
                                    n_cycles, pd))
    params["blocks"] = tuple(blocks)
    tails = []
    for t in range(tail):
        mixer, ffn = cfg.pattern[t]
        defs = block_param_defs(cfg, mixer, ffn)
        tails.append(_block_params(jax.random.fold_in(keys[3], t), defs,
                                   0, pd))
    params["tail"] = tuple(tails)
    if cfg.is_encdec:
        defs = block_param_defs(cfg, "enc", "gelu")
        params["encoder"] = _block_params(keys[4], defs, cfg.encoder_layers,
                                          pd)
        params["enc_pos"] = _init_leaf(keys[5], (cfg.encoder_seq, D), pd)
        params["enc_norm"] = jnp.zeros((D,), pd)
    return params


def param_axes(cfg: ModelConfig) -> PyTree:
    n_cycles, tail = cfg.cycles_and_tail
    axes: Dict[str, Any] = {
        # input table gets its own axes: a gather from a vocab@model-sharded
        # table makes GSPMD replicate the full table per chip (dry-run
        # measured ~12 GiB depth-independent temp); vocab@data + embed@model
        # caps it at a V/16 slice.
        "embed": ("in_vocab", "in_embed"),
        "unembed": ("embed", "vocab"),
        "final_norm": ("embed",),
    }
    axes["blocks"] = tuple(
        _block_axes(block_param_defs(cfg, m, f), stacked=n_cycles > 0)
        for (m, f) in cfg.pattern)
    axes["tail"] = tuple(
        _block_axes(block_param_defs(cfg, *cfg.pattern[t]), stacked=False)
        for t in range(tail))
    if cfg.is_encdec:
        axes["encoder"] = _block_axes(block_param_defs(cfg, "enc", "gelu"),
                                      stacked=True)
        axes["enc_pos"] = (None, "embed")
        axes["enc_norm"] = ("embed",)
    return axes


def param_shapes(cfg: ModelConfig) -> PyTree:
    """ShapeDtypeStruct pytree without allocating anything."""
    return jax.eval_shape(
        lambda: init_params(cfg, jax.random.key(0)))


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def _embed_inputs(params, batch, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    if cfg.num_patches and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(dt)
        x = jnp.concatenate([pe, x[:, cfg.num_patches:]], axis=1)
    return x


def _encode(params, batch, cfg: ModelConfig, impl):
    """Whisper-style encoder over precomputed frame embeddings (stub)."""
    dt = jnp.dtype(cfg.dtype)
    feats = batch["audio_feats"].astype(dt)
    x = feats + params["enc_pos"].astype(dt)[None]
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(x, lp):
        x, _ = block_apply(lp, x, "enc", "gelu", cfg, positions, impl=impl)
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    raise ValueError(cfg.remat)


def forward(params, batch, cfg: ModelConfig, *, impl: str = "jnp"
            ) -> jnp.ndarray:
    """Returns final hidden states (B, S, D) — logits via `logits_from_h`
    (kept separate so the loss can tile over the vocab)."""
    x = _embed_inputs(params, batch, cfg)
    # batch/seq only here: an act_embed(model) constraint directly on the
    # gather output trips an SPMD partitioner bug inside the microbatch loop
    x = shard_activation(x, "batch", "seq", None)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    enc_out = _encode(params, batch, cfg, impl) if cfg.is_encdec else None
    n_cycles, tail = cfg.cycles_and_tail

    def cycle(x, cycle_params):
        for k, (mixer, ffn) in enumerate(cfg.pattern):
            x, _ = block_apply(cycle_params[k], x, mixer, ffn, cfg,
                               positions, enc_out=enc_out, impl=impl)
            x = shard_activation(x, "batch", "seq", "act_embed")
            x = layers.grad_dtype_barrier(x)
        return x, None

    if n_cycles > 0:
        x, _ = jax.lax.scan(_maybe_remat(cycle, cfg), x, params["blocks"])
    for t in range(tail):
        mixer, ffn = cfg.pattern[t]
        x, _ = block_apply(params["tail"][t], x, mixer, ffn, cfg, positions,
                           enc_out=enc_out, impl=impl)
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def logits_from_h(params, h, cfg: ModelConfig) -> jnp.ndarray:
    logits = (h @ params["unembed"].astype(h.dtype)).astype(jnp.float32)
    # mask vocab padding
    pad = cfg.padded_vocab - cfg.vocab_size
    if pad:
        mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(mask, logits, layers.NEG_INF)
    return logits


def _xent(logits, labels, valid):
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    losses = (lse - gold) * valid
    return losses.sum(), valid.sum()


def loss_fn(params, batch, cfg: ModelConfig, *, impl: str = "jnp"
            ) -> jnp.ndarray:
    h = forward(params, batch, cfg, impl=impl)
    tokens = batch["tokens"]
    labels = tokens[:, 1:]
    valid = jnp.ones(labels.shape, jnp.float32)
    if cfg.logit_chunk:
        # chunk over sequence so (B,S,V) logits never materialise at once
        B, Sm1 = labels.shape
        C = cfg.logit_chunk
        n = Sm1 // C
        hc = h[:, :n * C].reshape(B, n, C, -1).transpose(1, 0, 2, 3)
        lc = labels[:, :n * C].reshape(B, n, C).transpose(1, 0, 2)

        def step(carry, xs):
            hh, ll = xs
            s, c = _xent(logits_from_h(params, hh, cfg), ll,
                         jnp.ones(ll.shape, jnp.float32))
            return (carry[0] + s, carry[1] + c), None

        # checkpoint: per-chunk logits are recomputed in bwd instead of all
        # chunks' (B, C, V) f32 blocks staying live.
        (tot, cnt), _ = jax.lax.scan(jax.checkpoint(step), (0.0, 0.0),
                                     (hc, lc))
        if Sm1 % C:
            s, c = _xent(logits_from_h(params, h[:, n * C:-1], cfg),
                         labels[:, n * C:], valid[:, n * C:])
            tot, cnt = tot + s, cnt + c
        return tot / jnp.maximum(cnt, 1.0)
    logits = logits_from_h(params, h[:, :-1], cfg)
    tot, cnt = _xent(logits, labels, valid)
    return tot / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------
def _block_cache_shape(cfg: ModelConfig, mixer: str, B: int, max_seq: int):
    dt = jnp.dtype(cfg.dtype)
    KH, Hd = cfg.num_kv_heads, cfg.head_dim
    if mixer == "rglru":
        return {"state": ((B, cfg.lru_width), jnp.float32,
                          ("cache_batch", "lru")),
                "conv": ((B, cfg.conv_width - 1, cfg.lru_width), dt,
                         ("cache_batch", None, "lru"))}
    if mixer == "ssd":
        H = cfg.ssm_heads
        P = cfg.d_inner // H
        return {"state": ((B, H, P, cfg.ssm_state), jnp.float32,
                          ("cache_batch", "ssm_heads", None, None)),
                "conv": ((B, cfg.conv_width - 1, cfg.d_inner
                          + 2 * cfg.ssm_state), dt,
                         ("cache_batch", None, "ssm_conv"))}
    W = attn_cache_len(mixer, cfg, max_seq)
    cdt = jnp.dtype(cfg.kv_cache_dtype)
    return {"k": ((B, W, KH, Hd), cdt,
                  ("cache_batch", "cache_seq", "cache_kv", None)),
            "v": ((B, W, KH, Hd), cdt,
                  ("cache_batch", "cache_seq", "cache_kv", None))}


def _cache_tree(cfg: ModelConfig, B: int, max_seq: int, make_leaf):
    n_cycles, tail = cfg.cycles_and_tail
    blocks = []
    for k, (mixer, _f) in enumerate(cfg.pattern):
        shapes = _block_cache_shape(cfg, mixer, B, max_seq)
        blocks.append({name: make_leaf((n_cycles,) + shp, dt, ("layers",) + ax)
                       for name, (shp, dt, ax) in shapes.items()})
    tails = []
    for t in range(tail):
        mixer, _f = cfg.pattern[t]
        shapes = _block_cache_shape(cfg, mixer, B, max_seq)
        tails.append({name: make_leaf(shp, dt, ax)
                      for name, (shp, dt, ax) in shapes.items()})
    cache = {"blocks": tuple(blocks), "tail": tuple(tails),
             "index": make_leaf((), jnp.int32, None)}
    if cfg.is_encdec:
        cache["enc_out"] = make_leaf((B, cfg.encoder_seq, cfg.d_model),
                                     jnp.dtype(cfg.dtype),
                                     ("cache_batch", None, "act_embed"))
    return cache


def init_cache(cfg: ModelConfig, B: int, max_seq: int) -> PyTree:
    return _cache_tree(cfg, B, max_seq,
                       lambda shp, dt, ax: jnp.zeros(shp, dt))


def cache_specs(cfg: ModelConfig, B: int, max_seq: int) -> PyTree:
    return _cache_tree(cfg, B, max_seq,
                       lambda shp, dt, ax: jax.ShapeDtypeStruct(shp, dt))


def cache_axes(cfg: ModelConfig, B: int, max_seq: int) -> PyTree:
    return _cache_tree(cfg, B, max_seq, lambda shp, dt, ax: ax)


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------
def prefill(params, batch, cfg: ModelConfig, max_seq: int, *,
            impl: str = "jnp") -> Tuple[PyTree, jnp.ndarray]:
    """Run the full prompt, build the cache, return (cache, last logits)."""
    x = _embed_inputs(params, batch, cfg)
    # batch/seq only here: an act_embed(model) constraint directly on the
    # gather output trips an SPMD partitioner bug inside the microbatch loop
    x = shard_activation(x, "batch", "seq", None)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    enc_out = _encode(params, batch, cfg, impl) if cfg.is_encdec else None
    n_cycles, tail = cfg.cycles_and_tail

    def cycle(x, cycle_params):
        caches = []
        for k, (mixer, ffn) in enumerate(cfg.pattern):
            x, c = block_apply(cycle_params[k], x, mixer, ffn, cfg,
                               positions, enc_out=enc_out, impl=impl,
                               want_cache=True, max_seq=max_seq)
            caches.append(c)
        return x, tuple(caches)

    blocks_cache = ()
    if n_cycles > 0:
        x, blocks_cache = jax.lax.scan(cycle, x, params["blocks"])
    tail_caches = []
    for t in range(tail):
        mixer, ffn = cfg.pattern[t]
        x, c = block_apply(params["tail"][t], x, mixer, ffn, cfg, positions,
                           enc_out=enc_out, impl=impl, want_cache=True,
                           max_seq=max_seq)
        tail_caches.append(c)
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_h(params, h[:, -1:], cfg)
    cache = {"blocks": blocks_cache, "tail": tuple(tail_caches),
             "index": jnp.asarray(S, jnp.int32)}
    if cfg.is_encdec:
        cache["enc_out"] = enc_out
    return cache, logits


def decode_step(params, tokens, cache, cfg: ModelConfig
                ) -> Tuple[jnp.ndarray, PyTree]:
    """One new token per sequence. tokens: (B, 1) -> (logits, new cache)."""
    dt = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    x = shard_activation(x, "batch", None, "act_embed")
    index = cache["index"]
    enc_out = cache.get("enc_out")
    n_cycles, tail = cfg.cycles_and_tail

    def cycle(x, xs):
        cycle_params, cycle_cache = xs
        new = []
        for k, (mixer, ffn) in enumerate(cfg.pattern):
            x, c = block_decode(cycle_params[k], x, cycle_cache[k], mixer,
                                ffn, cfg, index, enc_out=enc_out)
            new.append(c)
        return x, tuple(new)

    new_blocks = ()
    if n_cycles > 0:
        x, new_blocks = jax.lax.scan(cycle, x,
                                     (params["blocks"], cache["blocks"]))
    new_tail = []
    for t in range(tail):
        mixer, ffn = cfg.pattern[t]
        x, c = block_decode(params["tail"][t], x, cache["tail"][t], mixer,
                            ffn, cfg, index, enc_out=enc_out)
        new_tail.append(c)
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_h(params, h, cfg)
    new_cache = {"blocks": new_blocks, "tail": tuple(new_tail),
                 "index": index + 1}
    if cfg.is_encdec:
        new_cache["enc_out"] = enc_out
    return logits, new_cache
