from .adamw import (AdamWState, adamw_init, adamw_update, cosine_schedule,
                    global_norm)
from .adafactor import AdafactorState, adafactor_init, adafactor_update

__all__ = ["AdamWState", "adamw_init", "adamw_update", "cosine_schedule",
           "global_norm", "AdafactorState", "adafactor_init",
           "adafactor_update"]
