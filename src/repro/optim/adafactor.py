"""Adafactor (Shazeer & Stern, 2018) — factored second moments.

For the 76B-class train cells AdamW's m+v cost 8 bytes/param; Adafactor's
row/column factorisation cuts the second moment to ~2/sqrt(d) of that,
freeing ~4 bytes/param of HBM (≈1.2 GiB/chip for internvl2-76b on the
256-chip pod).  Matches the standard formulation: factored v for >=2-D
params, full v for vectors; update clipping by RMS; no first moment.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdafactorState(NamedTuple):
    step: jnp.ndarray
    vr: PyTree      # row second moments   (or full v for <2-D params)
    vc: PyTree      # column second moments (dummy scalar for <2-D)


def _factored(shape) -> bool:
    return len(shape) >= 2


def adafactor_init(params: PyTree) -> AdafactorState:
    def vr_like(p):
        return (jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p.shape)
                else jnp.zeros(p.shape, jnp.float32))

    def vc_like(p):
        return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                if _factored(p.shape) else jnp.zeros((), jnp.float32))

    return AdafactorState(step=jnp.zeros((), jnp.int32),
                          vr=jax.tree.map(vr_like, params),
                          vc=jax.tree.map(vc_like, params))


def adafactor_update(grads: PyTree, state: AdafactorState, params: PyTree,
                     *, lr, decay: float = 0.8, eps: float = 1e-30,
                     clip_threshold: float = 1.0,
                     weight_decay: float = 0.0):
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else lr
    beta = 1.0 - step.astype(jnp.float32) ** (-decay)

    def upd(p, g, vr, vc):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if _factored(p.shape):
            vr2 = beta * vr + (1 - beta) * g2.mean(axis=-1)
            vc2 = beta * vc + (1 - beta) * g2.mean(axis=-2)
            r = vr2 / jnp.maximum(vr2.mean(axis=-1, keepdims=True), eps)
            u = g / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc2)[..., None, :]
                     + eps)
        else:
            vr2 = beta * vr + (1 - beta) * g2
            vc2 = vc
            u = g / (jnp.sqrt(vr2) + eps)
        rms_u = jnp.sqrt(jnp.mean(u * u) + eps)
        u = u / jnp.maximum(1.0, rms_u / clip_threshold)
        p2 = p.astype(jnp.float32) - lr_t * (
            u + weight_decay * p.astype(jnp.float32))
        return p2.astype(p.dtype), vr2, vc2

    flat = jax.tree.map(upd, params, grads, state.vr, state.vc)
    new_p, new_vr, new_vc = jax.tree.transpose(
        jax.tree.structure(params), jax.tree.structure((0, 0, 0)), flat)
    return new_p, AdafactorState(step=step, vr=new_vr, vc=new_vc)
