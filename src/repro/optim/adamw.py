"""AdamW (decoupled weight decay) as pure pytree functions — optimizer
state shards exactly like params (ZeRO-style: the same logical axes apply),
so `tree_shardings(param_axes)` covers m and v too."""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: PyTree
    v: PyTree


def adamw_init(params: PyTree) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def adamw_update(grads: PyTree, state: AdamWState, params: PyTree, *,
                 lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, grad_clip: float = 1.0):
    """Returns (new_params, new_state). `lr` may be a scalar or a callable
    step -> scalar (schedule)."""
    step = state.step + 1
    if callable(lr):
        lr_t = lr(step)
    else:
        lr_t = lr

    if grad_clip:
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)

    b1t = 1.0 - b1 ** step.astype(jnp.float32)
    b2t = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mh = m2 / b1t
        vh = v2 / b2t
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(
            jnp.float32)
        p2 = p.astype(jnp.float32) - lr_t * delta
        return p2.astype(p.dtype), m2, v2

    flat = jax.tree.map(upd, params, grads, state.m, state.v)
    # tree_transpose, not an is_leaf-on-tuple trick: a model whose params
    # tree itself contains 3-tuples (e.g. a 3-entry layer pattern) would be
    # silently mangled by shape-based leaf detection.
    new_params, new_m, new_v = jax.tree.transpose(
        jax.tree.structure(params), jax.tree.structure((0, 0, 0)), flat)
    return new_params, AdamWState(step=step, m=new_m, v=new_v)


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 *
                         (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr
