"""Serving layer: tier profiles, the period loop, and the fleet engine.

Planning entry points live in `repro.api` (`solve`, `solve_many`, the
solver registry); the legacy `plan*` names below are deprecation shims
kept importable for external callers.
"""
from .profile import (TierProfile, measure_profiles, measure_latency,
                      comm_time, roofline_profile)
from .planner import (FleetPlan, Plan, plan, plan_batch, plan_batch_arrays,
                      replan_without_es, replan_without_es_batch)
from .executor import (EXEC_DROPPED, EXEC_FALLBACK_LOCAL, EXEC_OK_ED,
                       EXEC_OK_ES, ExecutionReport, execute)
from .runtime import ServingRuntime, PeriodStats, audit_profile
from .queue import RequestQueue
from .fleet import (DeviceSpec, EdgeServerPool, FleetConfig, FleetEngine,
                    FleetPeriodStats, UnsolvedPeriodError, make_fleet,
                    paper_style_profile, roofline_style_profile)
from .faults import (FaultModel, FaultRealization, greedy_local_fill,
                     realize_execution, sample_realization)
from .hi import (HILearnerState, HIModel, arm_grid, hi_period,
                 presample_stream, sample_confidence)
from . import engine_v2  # pure-functional EngineState/step/rollout/shard

__all__ = [
    # profiles
    "TierProfile", "measure_profiles", "measure_latency", "comm_time",
    "roofline_profile",
    # deprecated planner shims (see repro.api)
    "FleetPlan", "Plan", "plan", "plan_batch", "plan_batch_arrays",
    "replan_without_es", "replan_without_es_batch",
    # execution + single-device runtime
    "ExecutionReport", "execute",
    "EXEC_OK_ED", "EXEC_OK_ES", "EXEC_FALLBACK_LOCAL", "EXEC_DROPPED",
    "ServingRuntime", "PeriodStats", "audit_profile",
    # traffic + fleet engine
    "RequestQueue",
    "DeviceSpec", "EdgeServerPool", "FleetConfig", "FleetEngine",
    "FleetPeriodStats", "UnsolvedPeriodError", "make_fleet",
    "paper_style_profile", "roofline_style_profile",
    # chaos: fault injection + the degradation ladder
    "FaultModel", "FaultRealization", "sample_realization",
    "greedy_local_fill", "realize_execution",
    # online hierarchical inference (confidence-gated offloading)
    "HIModel", "HILearnerState", "arm_grid", "sample_confidence",
    "presample_stream", "hi_period",
    # pure-functional engine (EngineState pytree + step/rollout/shard)
    "engine_v2",
]
