from .profile import TierProfile, measure_profiles, measure_latency, comm_time
from .planner import Plan, plan, replan_without_es
from .executor import ExecutionReport, execute
from .runtime import ServingRuntime, PeriodStats

__all__ = ["TierProfile", "measure_profiles", "measure_latency", "comm_time",
           "Plan", "plan", "replan_without_es", "ExecutionReport", "execute",
           "ServingRuntime", "PeriodStats"]
