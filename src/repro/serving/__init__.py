from .profile import (TierProfile, measure_profiles, measure_latency,
                      comm_time, roofline_profile)
from .planner import (FleetPlan, Plan, plan, plan_batch, plan_batch_arrays,
                      replan_without_es, replan_without_es_batch)
from .executor import ExecutionReport, execute
from .runtime import ServingRuntime, PeriodStats, audit_profile
from .queue import RequestQueue
from .fleet import (DeviceSpec, EdgeServerPool, FleetEngine, FleetPeriodStats,
                    make_fleet, paper_style_profile, roofline_style_profile)

__all__ = ["TierProfile", "measure_profiles", "measure_latency", "comm_time",
           "roofline_profile",
           "FleetPlan", "Plan", "plan", "plan_batch", "plan_batch_arrays",
           "replan_without_es", "replan_without_es_batch",
           "ExecutionReport", "execute",
           "ServingRuntime", "PeriodStats", "audit_profile",
           "RequestQueue",
           "DeviceSpec", "EdgeServerPool", "FleetEngine", "FleetPeriodStats",
           "make_fleet", "paper_style_profile", "roofline_style_profile"]
