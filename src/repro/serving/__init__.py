from .profile import (TierProfile, measure_profiles, measure_latency,
                      comm_time, roofline_profile)
from .planner import Plan, plan, plan_batch, replan_without_es
from .executor import ExecutionReport, execute
from .runtime import ServingRuntime, PeriodStats, audit_profile
from .queue import RequestQueue
from .fleet import (DeviceSpec, EdgeServerPool, FleetEngine, FleetPeriodStats,
                    make_fleet, paper_style_profile, roofline_style_profile)

__all__ = ["TierProfile", "measure_profiles", "measure_latency", "comm_time",
           "roofline_profile",
           "Plan", "plan", "plan_batch", "replan_without_es",
           "ExecutionReport", "execute",
           "ServingRuntime", "PeriodStats", "audit_profile",
           "RequestQueue",
           "DeviceSpec", "EdgeServerPool", "FleetEngine", "FleetPeriodStats",
           "make_fleet", "paper_style_profile", "roofline_style_profile"]
