"""`repro.serving.engine_v2` — the serving-layer name for the
pure-functional fleet engine.

The implementation lives in `repro.api.engine` (it is solver-registry
territory: the traced period core is built from `lp.simplex_batch_core` /
`amr2.round_relaxation_jnp` / `dual._dual_one`); this module re-exports it
under the serving namespace so engine code reads naturally next to
`FleetEngine`:

    from repro.serving import engine_v2
    params = engine_v2.EngineParams.from_config(cfg, horizon=64)
    state, metrics = engine_v2.rollout(engine_v2.init_state(params),
                                       params, periods=64)

`FleetEngine.run_period` delegates to the same jitted period core on the
jax backend, so the two surfaces stay trajectory-identical by
construction.
"""
from ..api.engine import (EngineParams, EngineState, PeriodMetrics,
                          TRACEABLE_POLICIES, admit_mask_jnp, fleet_mesh,
                          init_state, rollout, rollout_sharded, shard,
                          step, step_sharded)

__all__ = [
    "EngineParams", "EngineState", "PeriodMetrics", "TRACEABLE_POLICIES",
    "admit_mask_jnp", "fleet_mesh", "init_state",
    "step", "rollout", "shard", "step_sharded", "rollout_sharded",
]
