"""Tiered plan execution.

Executes a planning result — a `repro.api.Solution` or a legacy `Plan` —
against real model apply fns (ED ladder + ES), tracking per-tier clocks
with *measured* wall time — the quantity Fig. 6 of the paper compares
against the predicted makespan.  Jobs routed to the same model run as one
batched call (DESIGN.md records this deviation: the ILP's budget semantics
are unchanged, p_ij is per-job amortized batch latency).

`es_fail=True` simulates an ES-tier outage mid-period: offloaded jobs
bounce and the runtime replans them onto the ED ladder (paper's m-model
special case) within the remaining budget.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from ..api import Problem, solve

# per-sample execution status codes (ExecutionReport.status).  A sample
# starts DROPPED and is promoted as its result lands, so a short apply-fn
# output (or a job no tier ever ran) is *visible* in the report instead
# of silently missing from `results` — consistent with the fleet engine's
# `n_dropped` ladder metric.
EXEC_OK_ED = 0           # completed on the planned ED-ladder model
EXEC_OK_ES = 1           # completed on the ES tier
EXEC_FALLBACK_LOCAL = 2  # ES failed; completed via the ED-only replan
EXEC_DROPPED = 3         # no tier produced a result for this sample
EXEC_STATUS_NAMES = ("ok_ed", "ok_es", "fallback_local", "dropped")


@dataclasses.dataclass
class ExecutionReport:
    predicted_makespan: float
    ed_wall: float
    es_wall: float
    results: Dict[int, object]
    replanned: bool = False
    # (n,) int32 EXEC_* code per sample; None only for reports built by
    # legacy callers that never ran `execute`
    status: Optional[np.ndarray] = None

    @property
    def wall_makespan(self) -> float:
        return max(self.ed_wall, self.es_wall)

    @property
    def n_dropped(self) -> int:
        """Samples that fell through execution with no result — the
        audit-facing count (0 when every job landed)."""
        if self.status is None:
            return 0
        return int((self.status == EXEC_DROPPED).sum())


def _instance_of(plan_):
    """The planned instance, for a legacy `Plan` or an api `Solution`."""
    if hasattr(plan_, "schedule"):            # legacy Plan
        return plan_.schedule.instance
    return plan_.problem.to_instance()        # api Solution


def _predicted_makespan(plan_) -> float:
    if hasattr(plan_, "schedule"):
        return plan_.predicted_makespan
    return float(plan_.makespan)


def execute(plan_, apply_ed: List[Callable], apply_es: Callable,
            jobs: List[object], *, es_fail: bool = False,
            comm_simulator: Optional[Callable] = None) -> ExecutionReport:
    """``plan_`` is a `repro.api.Solution` (preferred) or a legacy
    `serving.Plan`; both expose the ``per_model`` routing this needs."""
    m = len(apply_ed)
    results: Dict[int, object] = {}
    ed_wall = 0.0
    es_wall = 0.0
    replanned = False
    # every sample starts DROPPED; landing a result promotes it (a short
    # apply-fn output leaves its tail samples visibly dropped)
    status = np.full(len(jobs), EXEC_DROPPED, dtype=np.int32)

    def _land(ids, out, code):
        nonlocal results
        for j, r in zip(ids, out):
            results[int(j)] = r
            status[int(j)] = code

    es_ids = plan_.per_model.get(m, np.array([], np.int64))
    if len(es_ids):
        if es_fail:
            # ES unreachable: replan the bounced jobs on the ED ladder
            inst = _instance_of(plan_)
            sub = Problem(p_ed=inst.p_ed[es_ids], p_es=inst.p_es[es_ids],
                          acc=inst.acc, T=inst.T)
            fb = solve(sub, es_disabled=True)
            replanned = True
            for i in range(m):
                ids = es_ids[fb.per_model.get(i, np.array([], np.int64))]
                if len(ids):
                    t0 = time.perf_counter()
                    out = apply_ed[i]([jobs[j] for j in ids])
                    ed_wall += time.perf_counter() - t0
                    _land(ids, out, EXEC_FALLBACK_LOCAL)
        else:
            if comm_simulator is not None:
                es_wall += comm_simulator(es_ids)
            t0 = time.perf_counter()
            out = apply_es([jobs[j] for j in es_ids])
            es_wall += time.perf_counter() - t0
            _land(es_ids, out, EXEC_OK_ES)

    for i in range(m):
        ids = plan_.per_model.get(i, np.array([], np.int64))
        if len(ids):
            t0 = time.perf_counter()
            out = apply_ed[i]([jobs[j] for j in ids])
            ed_wall += time.perf_counter() - t0
            _land(ids, out, EXEC_OK_ED)

    return ExecutionReport(
        predicted_makespan=_predicted_makespan(plan_),
        ed_wall=ed_wall, es_wall=es_wall, results=results,
        replanned=replanned, status=status)
