"""`repro.serving.faults` — the serving-layer name for the traced fault
model and degradation ladder.

The implementation lives in `repro.core.faults` (it is pure-numerics
territory: the ladder is array math over the same latency tables
`core.amr2`/`core.lp` price, with no serving dependencies — which also
keeps `repro.api.engine`, which consumes it inside the traced period
step, free of an import cycle through this package).  This module
re-exports it under the serving namespace so chaos config reads
naturally next to `FleetEngine` (the `engine_v2` idiom):

    from repro.serving import faults
    fm = faults.FaultModel.make(loss_rate=0.1, straggler_prob=0.05)
    eng = FleetEngine.from_config(dataclasses.replace(cfg, faults=fm))

`FaultModel.none()` is the all-zero model; a rollout carrying it is
bitwise-identical to one with chaos disarmed.
"""
from ..core.faults import (FaultModel, FaultRealization, RealizedExecution,
                           greedy_local_fill, realize_execution,
                           sample_realization)

__all__ = [
    "FaultModel", "FaultRealization", "RealizedExecution",
    "sample_realization", "greedy_local_fill", "realize_execution",
]
