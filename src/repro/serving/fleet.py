"""Fleet-scale serving engine: N edge devices, a small ES pool, an
array-resident period loop that costs a handful of jitted/vectorized calls
regardless of fleet size.

The paper's deployment model is one ED offloading to one ES under a period
budget T (§III-C).  This engine runs N copies of that formulation
simultaneously and couples them through the resources the paper abstracts
away:

  * **Arrivals** — every device drains its own `RequestQueue` backlog each
    period (Poisson or trace), up to the planning-window cap.
  * **Planning** — devices live as *stacked arrays* per shape group
    (belief/base latency profiles, accuracies): padded-instance assembly is
    one masked gather per group into a `FleetProblem`, and the group plans
    via `repro.api.solve` — vmapped AMR^2 / AMDP / dual solvers from the
    registry, no per-device Schedule objects on the hot path.
  * **ES capacity** — the pool offers `n_servers x T` seconds of service per
    period.  Each server's admitted offload demand must fit in T (the
    paper's constraint (2), per server).  Devices that lose the admission
    race are *backpressured*: they replan ED-only in ONE batched
    ES-disabled solve (`api.solve(..., es_disabled=True)`) instead of a
    Python loop of scalar replans.
  * **Stragglers** — each device's true speed drifts (`DeviceSpec.drift`);
    the engine audits measured vs predicted ED wall time with the same EMA
    rule as the single-device runtime (`runtime.audit_profile`), vectorized
    across the fleet, so the next period's p_ij reflect the degraded device.
  * **Outages** — `DeviceSpec.outage` marks periods where a device's ES link
    is down; its instance is planned ED-only from the start.

`run_period_reference()` keeps the PR-1 per-device implementation (padding,
stripping, sequential backpressure replans, per-device audit) as the
benchmark baseline and parity oracle for the vectorized loop.

Padding uses phantom jobs with p_ed = 0 AND p_es = 0: free everywhere, so
the LP gives each phantom the max-accuracy (ES) assignment integrally at
zero budget cost, real-job tradeoffs are untouched, and phantoms are
stripped/masked before any accounting.  Phantom offload times must stay
*small* — a huge sentinel (e.g. 1e9) mixed into the same ES-budget row as
real sub-second p_es wrecks the simplex row scaling and silently voids the
constraint; only real jobs on the outage / backpressure paths use the
uniform huge sentinel (the same trick as `replan_without_es`).
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..api import solve, solve_many
from ..core.faults import FaultModel
from ..core.instances import (PAPER_ACC, PAPER_COMM, PAPER_P_ED,
                              PAPER_P_ES_PROC)
from ..core.problem import ES_DISABLED_SENTINEL, FleetProblem, Problem
from ..core.types import OffloadInstance, Schedule
from .profile import TierProfile, roofline_profile
from .queue import RequestQueue
from .runtime import audit_profile

# ES-link down: uniform huge p_es, the same sentinel the api's es_disabled
# path applies to real jobs
_OUTAGE_ES = ES_DISABLED_SENTINEL


class UnsolvedPeriodError(RuntimeError):
    """A period's LP left ``n_unsolved`` lanes uncertified under
    ``strict="raise"``.

    Carries the failing ``period`` index and ``partial_stats`` — every
    `FleetPeriodStats` the engine completed *before* the failure — so a
    multi-period `run()` no longer discards the whole trajectory when one
    late period trips the iteration cap.  (`FleetEngine.history` holds
    the same records; the exception copies them for callers that lost
    the engine reference.)  The traced core has already re-planned the
    unsolved lanes with the greedy local-only fallback, so
    ``strict="warn"`` can book the period and continue instead."""

    def __init__(self, message: str, *, period: int, n_unsolved: int,
                 partial_stats: List["FleetPeriodStats"]):
        super().__init__(message)
        self.period = period
        self.n_unsolved = n_unsolved
        self.partial_stats = partial_stats


@dataclasses.dataclass
class DeviceSpec:
    """Static description of one edge device in the fleet.

    `profile` is the device's *believed* latency profile (the planner's
    starting point); `drift` holds the true per-period ED slowdown factors
    relative to that profile (cycled, 1.0 = nominal), and `outage` flags
    periods where the device's ES link is unreachable."""
    profile: TierProfile
    drift: Optional[np.ndarray] = None
    outage: Optional[np.ndarray] = None
    name: str = ""

    def drift_at(self, period: int) -> float:
        if self.drift is None or len(self.drift) == 0:
            return 1.0
        return float(self.drift[period % len(self.drift)])

    def outage_at(self, period: int) -> bool:
        if self.outage is None or len(self.outage) == 0:
            return False
        return bool(self.outage[period % len(self.outage)])


@dataclasses.dataclass
class _DeviceState:
    spec: DeviceSpec
    profile: TierProfile        # current belief (EMA-updated on stragglers)
    n_updates: int = 0


class _ShapeGroup:
    """Array-resident view of every device sharing one (classes, m) shape:
    stacked belief/base latency tables so one period's padded-instance
    assembly, pricing, and audit are whole-group array ops."""

    def __init__(self, ids: Sequence[int], states: Sequence[_DeviceState]):
        self.ids = np.asarray(ids, dtype=np.int64)
        self.classes = np.asarray(states[0].profile.classes)
        self.p_ed = np.stack([st.profile.p_ed for st in states]
                             ).astype(np.float64)          # belief (D, c, m)
        self.p_es = np.stack([st.profile.p_es for st in states]
                             ).astype(np.float64)          # (D, c)
        self.acc = np.stack([st.profile.acc for st in states]
                            ).astype(np.float64)           # (D, m+1)
        self.base_p_ed = np.stack([st.spec.profile.p_ed for st in states]
                                  ).astype(np.float64)     # truth (D, c, m)
        # last period's optimal simplex bases (D, R) for LP-backed policies
        # (-1 rows: device was planned by a non-LP solver); fed back as
        # `solve(..., warm_start=)` so consecutive periods price out of the
        # previous vertex instead of re-running two cold simplex phases
        self.warm_basis: Optional[np.ndarray] = None

    @property
    def m(self) -> int:
        return self.p_ed.shape[2]


def _ed_time_under(profile: TierProfile, job_classes: np.ndarray,
                   assignment: np.ndarray) -> float:
    """ED-tier time of a schedule priced with `profile`'s latencies."""
    if len(job_classes) == 0:
        return 0.0
    ci = np.searchsorted(np.asarray(profile.classes), job_classes)
    mask = assignment < profile.p_ed.shape[1]
    if not mask.any():
        return 0.0
    return float(profile.p_ed[ci[mask], assignment[mask]].sum())


@dataclasses.dataclass
class FleetPeriodStats:
    period: int
    n_devices: int
    n_jobs: int                 # real (non-phantom) jobs planned
    plan_seconds: float         # wall time spent planning the whole fleet
    total_accuracy: float
    mean_job_accuracy: float
    n_violations: int           # devices whose wall makespan exceeded T
    worst_violation: float      # max over devices of makespan/T - 1
    n_offloading: int           # devices that planned ES work
    n_backpressured: int        # devices bumped off the ES pool
    n_outage: int
    n_straggler_updates: int
    es_utilization: float       # admitted demand / (n_servers * T)
    backlog: int                # jobs still queued after this period
    # realized execution (chaos; see repro.serving.faults) — fault-free
    # periods report n_offload_ok == n_offload_samples, zero ladder
    # counters, and realized_makespan == the priced fleet makespan
    n_offload_samples: int = 0  # admitted offloaded samples this period
    n_offload_ok: int = 0       # of those, completed via the ES
    n_deadline_miss: int = 0    # samples past the 2T realized deadline
    n_retries: int = 0          # ladder rung 1: retransmission attempts
    n_fallback_local: int = 0   # ladder rung 2: local-model completions
    n_dropped: int = 0          # ladder rung 3: accuracy-0 drops
    realized_makespan: float = 0.0  # max realized device wall (seconds)
    n_es_audit_updates: int = 0  # ES-latency beliefs EMA-inflated (chaos)
    # online hierarchical inference (repro.serving.hi) — every sample
    # runs the local model, so n_hi_offloaded + n_hi_local_final ==
    # n_jobs per period; exact zeros while HI is disarmed
    n_hi_offloaded: int = 0      # samples that consulted the ES
    n_hi_local_final: int = 0    # samples served by the local model alone
    hi_regret: float = 0.0       # fleet cumulative pseudo-regret vs theta*


class EdgeServerPool:
    """A pool of `n_servers` ES tiers, each offering T seconds per period.

    Admission is a greedy heuristic — ascending demand, least-loaded server
    first — so small demands are favoured and every admitted server load
    respects the paper's constraint (2).  It is NOT optimal bin packing:
    adversarial demand sets can admit one device fewer than an exact
    packing would."""

    def __init__(self, n_servers: int):
        if n_servers <= 0:
            raise ValueError("n_servers must be positive")
        self.n_servers = n_servers

    def admit(self, demands: Dict[int, float], T: float):
        """demands: device id -> ES seconds requested.  Returns
        (admitted ids, per-server loads).

        Iteration order is (demand, device-id)-sorted — never dict
        insertion order — so admission is deterministic for any way the
        caller assembled the dict, and identical to the vectorized
        `admit_mask` / traced `repro.api.engine` admission scan
        (regression-pinned in tests/test_engine_v2.py)."""
        loads = np.zeros(self.n_servers)
        admitted: List[int] = []
        for dev in sorted(demands, key=lambda d: (demands[d], d)):
            need = demands[dev]
            slot = int(np.argmin(loads))
            if loads[slot] + need <= T + 1e-12:
                loads[slot] += need
                admitted.append(dev)
        return admitted, loads

    def admit_mask(self, demands: np.ndarray, T: float):
        """Dense-array admission: ``demands`` is (D,) ES seconds per device
        (<= 0 marks "not offloading").  Returns ``(admitted (D,) bool,
        per-server loads)`` with exactly the `admit` ordering semantics —
        ascending demand, device id on ties, least-loaded server first.
        This is the NumPy twin of the traced admission scan the
        pure-functional engine runs (`repro.api.engine.admit_mask_jnp`)."""
        demands = np.asarray(demands, dtype=np.float64)
        eff = np.where(demands > 0, demands, np.inf)
        order = np.argsort(eff, kind="stable")       # ties -> id order
        loads = np.zeros(self.n_servers)
        mask = np.zeros(len(demands), dtype=bool)
        for d in order:
            need = float(demands[d])
            if need <= 0:        # the +inf tail: non-offloaders
                break
            slot = int(np.argmin(loads))
            if loads[slot] + need <= T + 1e-12:
                loads[slot] += need
                mask[d] = True
        return mask, loads


def _padded_instance(profile: TierProfile, job_classes: np.ndarray, T: float,
                     n_total: int, *, disable_es: bool) -> OffloadInstance:
    """Device instance padded with phantom jobs to the fleet-wide job count."""
    k = len(job_classes)
    if k > n_total:
        raise ValueError(f"{k} jobs exceed planning window {n_total}")
    m = profile.p_ed.shape[1]
    p_ed = np.zeros((n_total, m))
    p_es = np.zeros(n_total)        # phantoms: free ES, stripped later
    if k:
        ci = np.searchsorted(np.asarray(profile.classes), job_classes)
        p_ed[:k] = profile.p_ed[ci]
        p_es[:k] = _OUTAGE_ES if disable_es else profile.p_es[ci]
    return OffloadInstance(p_ed=p_ed, p_es=p_es, acc=profile.acc.copy(), T=T)


def _strip_phantoms(padded: Schedule, k: int) -> Schedule:
    """Schedule over the first k (real) jobs of a padded instance."""
    inst = padded.instance
    real = OffloadInstance(p_ed=inst.p_ed[:k], p_es=inst.p_es[:k],
                           acc=inst.acc, T=inst.T)
    return Schedule(assignment=padded.assignment[:k].copy(), instance=real,
                    lp_accuracy=None, n_fractional=padded.n_fractional,
                    status=padded.status, solver=padded.solver)


@dataclasses.dataclass
class FleetConfig:
    """Declarative fleet-engine construction: the policy, the backpressure
    behaviour (ES pool size), the traffic model, and the fleet composition
    in one value — `FleetEngine.from_config` is the one-call equivalent of
    the `make_fleet` + `RequestQueue` + `FleetEngine` recipe.

    Pass ``devices`` to use an explicit fleet; otherwise a heterogeneous
    `make_fleet(n_devices, ...)` fleet is generated from ``seed`` and the
    composition fractions below."""

    # engine
    n_devices: int
    T: float
    n_servers: int = 1
    policy: str = "auto"
    backend: str = "jax"
    straggler_threshold: float = 1.5
    ema: float = 0.5
    # False forces the legacy host period pipeline even where the
    # engine-v2 delegation would apply (benchmark baselines, debugging)
    delegate: bool = True
    # chaos: fault injection + degradation ladder (engine-v2 delegation
    # only; see repro.serving.faults).  None/FaultModel.none() disarms.
    faults: Optional[FaultModel] = None
    max_retries: int = 2
    fault_seed: int = 0
    # multi-cell mobility (pure-functional engine only — the host period
    # pipeline has no position state; see repro.core.mobility).  None
    # disarms; `EngineParams.from_config` picks these up for rollouts.
    mobility: Optional[object] = None       # core.mobility.MobilityModel
    mobility_mode: str = "replay"
    routing: str = "nearest"
    mobility_seed: int = 0
    # online hierarchical inference (engine-v2 delegation only; see
    # repro.serving.hi).  None disarms; armed, ``hi_rule`` picks the
    # per-sample decision rule and the confidence gate replaces the LP
    # plan.  `EngineParams.from_config` picks these up for rollouts.
    hi: Optional[object] = None             # core.hi.HIModel
    hi_rule: str = "threshold"
    hi_stream: str = "fold"
    hi_arms: int = 9
    hi_seed: int = 0
    hi_local: int = 0
    # "raise" (default): an uncertified-LP period raises
    # UnsolvedPeriodError (carrying partial stats); "warn": warn and book
    # the period — its unsolved lanes were re-planned local-only by the
    # traced core
    strict: str = "raise"
    # traffic (RequestQueue)
    classes: Sequence[int] = (128, 512, 1024)
    rate: float = 10.0
    batch_max: int = 12
    trace: Optional[np.ndarray] = None
    class_probs: Optional[Sequence[float]] = None
    # fleet composition (make_fleet) — ignored when `devices` is given
    devices: Optional[Sequence[DeviceSpec]] = None
    roofline_frac: float = 0.5
    straggler_frac: float = 0.25
    outage_frac: float = 0.1
    drift_mag: float = 3.0
    horizon: int = 64
    seed: int = 0

    def build_devices(self) -> List[DeviceSpec]:
        if self.devices is not None:
            if len(self.devices) != self.n_devices:
                raise ValueError(
                    f"config names {self.n_devices} devices but "
                    f"{len(self.devices)} DeviceSpecs were given")
            return list(self.devices)
        return make_fleet(self.n_devices, classes=self.classes,
                          roofline_frac=self.roofline_frac,
                          straggler_frac=self.straggler_frac,
                          outage_frac=self.outage_frac,
                          drift_mag=self.drift_mag, horizon=self.horizon,
                          seed=self.seed)

    def build_queue(self) -> RequestQueue:
        return RequestQueue(self.n_devices, self.classes, rate=self.rate,
                            batch_max=self.batch_max, seed=self.seed,
                            trace=self.trace, class_probs=self.class_probs)


class FleetEngine:
    """Drives the whole fleet, one period at a time."""

    @classmethod
    def from_config(cls, config: FleetConfig) -> "FleetEngine":
        """Build the engine a `FleetConfig` describes (same fleet, queue,
        and policy as the equivalent manual construction)."""
        if config.mobility is not None \
                and not getattr(config.mobility, "is_null", lambda: True)():
            # positions/cells/handover live in the traced EngineState scan;
            # there is no host twin of the routing + segmented admission
            raise ValueError(
                "multi-cell mobility runs on the pure-functional engine "
                "only: build EngineParams.from_config(config) and use "
                "repro.api.engine.rollout / rollout_sharded instead of "
                "FleetEngine")
        return cls(config.build_devices(), config.build_queue(),
                   n_servers=config.n_servers, T=config.T,
                   policy=config.policy, backend=config.backend,
                   straggler_threshold=config.straggler_threshold,
                   ema=config.ema, delegate=config.delegate,
                   faults=config.faults, max_retries=config.max_retries,
                   fault_seed=config.fault_seed, strict=config.strict,
                   hi=config.hi, hi_rule=config.hi_rule,
                   hi_stream=config.hi_stream, hi_arms=config.hi_arms,
                   hi_seed=config.hi_seed, hi_local=config.hi_local)

    def __init__(self, devices: Sequence[DeviceSpec], queue: RequestQueue, *,
                 n_servers: int = 1, T: float, policy: str = "auto",
                 backend: str = "jax", straggler_threshold: float = 1.5,
                 ema: float = 0.5, delegate: bool = True,
                 faults: Optional[FaultModel] = None, max_retries: int = 2,
                 fault_seed: int = 0, strict: str = "raise",
                 hi: Optional[object] = None, hi_rule: str = "threshold",
                 hi_stream: str = "fold", hi_arms: int = 9,
                 hi_seed: int = 0, hi_local: int = 0):
        if queue.n_devices != len(devices):
            raise ValueError("queue.n_devices must match the fleet size")
        if strict not in ("raise", "warn"):
            raise ValueError(f"strict={strict!r}; expected 'raise' or "
                             f"'warn'")
        if policy != "auto":
            from ..api import get_solver
            info = get_solver(policy).info        # also rejects unknowns
            if info.bound_only:
                raise ValueError(
                    f"policy={policy!r} is a bound-only solver; its "
                    f"assignments need not satisfy the budgets, so it "
                    f"cannot drive the serving engine")
            if backend == "jax" and not info.batched:
                # fail at construction, not deep inside period 0 after
                # arrivals were already dequeued
                raise ValueError(
                    f"policy={policy!r} has no batched path; construct "
                    f"the engine with backend='numpy' for the sequential "
                    f"oracle loop")
        for d, spec in enumerate(devices):
            cls = np.asarray(spec.profile.classes)
            if cls.size > 1 and np.any(np.diff(cls) <= 0):
                # the searchsorted pricing below silently returns wrong
                # rows on an unsorted class table
                raise ValueError(
                    f"device {d} ({spec.profile.name}) profile classes "
                    f"{cls.tolist()} must be strictly ascending")
            missing = set(np.asarray(queue.classes).tolist()) \
                - set(cls.tolist())
            if missing:
                # searchsorted would silently price these as a neighbouring
                # class (or index past the table); fail loudly instead.
                raise ValueError(
                    f"device {d} ({spec.profile.name}) has no profile entry "
                    f"for queue classes {sorted(missing)}")
        self.devices = [_DeviceState(spec=d, profile=d.profile)
                        for d in devices]
        self.queue = queue
        self.pool = EdgeServerPool(n_servers)
        self.T = T
        self.policy = policy
        self.backend = backend
        self.straggler_threshold = straggler_threshold
        self.ema = ema
        self.strict = strict
        self.history: List[FleetPeriodStats] = []
        self._period = 0
        # ---- array residency: stack per-device profiles by shape group ---
        by_key: Dict[tuple, List[int]] = {}
        for d, st in enumerate(self.devices):
            key = (tuple(np.asarray(st.profile.classes).tolist()),
                   st.profile.p_ed.shape[1])
            by_key.setdefault(key, []).append(d)
        self._groups = [_ShapeGroup(ids, [self.devices[d] for d in ids])
                        for ids in by_key.values()]
        self._dev_slot: Dict[int, tuple] = {}    # device -> (group, row)
        for g in self._groups:
            for row, d in enumerate(g.ids):
                self._dev_slot[int(d)] = (g, row)
        # ---- engine-v2 delegation (PR 5): on the jax backend with a
        # traceable policy and a single shape group, `run_period` runs the
        # SAME jitted period core the pure-functional engine scans over
        # (`repro.api.engine._period_jit`) — one fused traced call per
        # period instead of the solve/admit/replan/audit host pipeline.
        # `self._v2_params` is None when any precondition fails (numpy
        # backend, auto/amdp policy, mixed profile shapes) or the caller
        # passed ``delegate=False``, and the host loop below runs
        # unchanged.
        self._v2_params = None
        from ..api import engine as _engine_v2
        if delegate and backend == "jax" \
                and policy in _engine_v2.TRACEABLE_POLICIES \
                and len(self._groups) == 1:
            self._v2_params = _engine_v2.EngineParams.from_fleet(
                devices, queue, T=T, n_servers=n_servers, policy=policy,
                horizon=1, arrivals="poisson",   # arrivals come from the
                #             host queue; the mode only gates presampling
                straggler_threshold=straggler_threshold, ema=ema,
                faults=faults, max_retries=max_retries,
                fault_seed=fault_seed)
            g = self._groups[0]
            self._v2_lut = np.searchsorted(np.asarray(g.classes),
                                           np.asarray(queue.classes))
            # arrival-value -> queue-class-index mapping that stays
            # correct when queue.classes is NOT sorted (searchsorted on
            # the raw table would silently mis-price every job there)
            qcls = np.asarray(queue.classes)
            self._v2_qorder = np.argsort(qcls, kind="stable")
            self._v2_qsorted = qcls[self._v2_qorder]
            # chaos-audited ES-latency belief (mirrors the scan's
            # EngineState.p_es_belief leaf; == p_es until the realized-
            # execution audit inflates rows)
            self._v2_es_belief = np.array(
                np.asarray(self._v2_params.p_es), dtype=np.float64)
            if hi is not None:
                # arm online hierarchical inference on the delegated
                # params (validates interplay: chaos must be disarmed)
                # and mirror the scan's EngineState.hi learner leaf
                self._v2_params = self._v2_params.with_hi(
                    hi, rule=hi_rule, stream=hi_stream, n_arms=hi_arms,
                    hi_seed=hi_seed, local_model=hi_local)
                self._v2_hi_state = _engine_v2.HILearnerState.init(
                    len(devices), hi_arms, hi.theta0)
        if faults is not None and not faults.is_null() \
                and self._v2_params is None:
            # the ladder lives in the traced period core; there is no
            # host twin of the realized-execution pass to fall back to
            raise ValueError(
                "fault injection needs the engine-v2 delegation (jax "
                "backend, amr2/dual policy, one profile shape group, "
                "delegate=True); this engine would run the host period "
                "pipeline")
        if hi is not None and self._v2_params is None:
            # the confidence gate + learner live in the traced period
            # core; there is no host twin of the per-sample decision pass
            raise ValueError(
                "online hierarchical inference needs the engine-v2 "
                "delegation (jax backend, amr2/dual policy, one profile "
                "shape group, delegate=True); this engine would run the "
                "host period pipeline")

    # ------------------------------------------------------------------
    def run(self, periods: int) -> List[FleetPeriodStats]:
        """Run ``periods`` periods.  Under ``strict="raise"``, a period
        with uncertified LP lanes raises `UnsolvedPeriodError` — the
        completed periods' stats survive on the exception's
        ``partial_stats`` (and on ``self.history``)."""
        return [self.run_period() for _ in range(periods)]

    # ------------------------------------------------------------------
    # vectorized period loop (the hot path)
    # ------------------------------------------------------------------
    def run_period(self) -> FleetPeriodStats:
        if self._v2_params is not None:
            return self._run_period_v2()
        return self._run_period_host()

    def _run_period_v2(self) -> FleetPeriodStats:
        """Delegate the period to the pure-functional engine's jitted core
        (`repro.api.engine._period_jit`): the host side only polls the
        queue, hands over padded class-index arrays, and books the stats —
        plan/admit/replan/price/audit are one traced call.  `run()` then
        produces bit-identical trajectories to `engine.rollout` on a
        replayed arrival trace (the same core scanned)."""
        import time as _time

        from jax.experimental import enable_x64

        from ..api.engine import _period_jit

        t = self._period
        self._period += 1
        arrivals = self.queue.poll(t)
        D = len(self.devices)
        g = self._groups[0]
        params = self._v2_params
        n_pad = self.queue.batch_max
        take = np.fromiter((len(a) for a in arrivals), dtype=np.int32,
                           count=D)
        ci = np.zeros((D, n_pad), dtype=np.int32)
        for d, a in enumerate(arrivals):
            if len(a):
                ci[d, :len(a)] = self._v2_qorder[
                    np.searchsorted(self._v2_qsorted, a)]
        outage = np.fromiter((st.spec.outage_at(t) for st in self.devices),
                             dtype=bool, count=D)
        drift = np.fromiter((st.spec.drift_at(t) for st in self.devices),
                            dtype=np.float64, count=D)
        belief = np.ascontiguousarray(g.p_ed[:, self._v2_lut, :])
        warm = (np.asarray(g.warm_basis, np.int32)
                if g.warm_basis is not None
                else np.full((D, params.n_basis_rows), -1, np.int32))
        if t > 0:
            # a basis optimal for last period's LP is stale when the ES
            # column set changed underneath it (outage flip): cold-start
            # those lanes instead of warm-factoring the wrong problem
            prev = np.fromiter(
                (st.spec.outage_at(t - 1) for st in self.devices),
                dtype=bool, count=D)
            warm = np.where((prev != outage)[:, None], np.int32(-1), warm)

        t0 = _time.perf_counter()
        with enable_x64():
            fault_key = None
            if params.chaos:
                # the exact per-period draw step() makes inside the scan:
                # fold the dedicated fault seed by period index
                import jax as _jax
                fault_key = _jax.random.fold_in(
                    _jax.random.PRNGKey(params.fault_seed), np.int32(t))
            hi_key = hi_state = hi_t = None
            if params.hi_armed:
                # same idiom for the confidence stream: the exact
                # per-period fold step() makes, plus the learner state
                # threaded between host periods like the ES belief
                import jax as _jax
                hi_key = _jax.random.fold_in(
                    _jax.random.PRNGKey(params.hi_seed), np.int32(t))
                hi_state = self._v2_hi_state
                hi_t = np.int32(t)
            (_belief2, new_warm, upd, factor, new_es_belief, _cload,
             new_hi, m) = _period_jit(belief, warm, ci, take, drift,
                                      outage, params, fault_key,
                                      es_belief=self._v2_es_belief,
                                      hi_key=hi_key, hi_state=hi_state,
                                      hi_t=hi_t)
        self._v2_es_belief = np.asarray(new_es_belief, dtype=np.float64)
        if params.hi_armed:
            import jax as _jax
            self._v2_hi_state = _jax.tree.map(np.asarray, new_hi)
        m = {k: np.asarray(v) for k, v in m.items()}
        plan_seconds = _time.perf_counter() - t0
        if int(m["n_unsolved"]):
            # mirror api.solve's strict=True default: never silently
            # serve best-effort roundings of a non-converged LP.  The
            # traced core has already re-planned the unsolved lanes with
            # the greedy local-only fallback, so "warn" mode can book the
            # period; "raise" keeps the completed periods on the error.
            msg = (f"period {t}: {int(m['n_unsolved'])} device plan(s) "
                   f"were not solved to optimality (simplex iteration "
                   f"limit or unbounded LP); raise maxiter — the lanes "
                   f"were served by the greedy local-only fallback")
            if self.strict == "warn":
                warnings.warn(msg, RuntimeWarning, stacklevel=3)
            else:
                raise UnsolvedPeriodError(
                    msg, period=t, n_unsolved=int(m["n_unsolved"]),
                    partial_stats=list(self.history))

        if self.policy == "amr2":   # LP-backed: carry the warm bases
            g.warm_basis = np.asarray(new_warm, np.int64)
        upd = np.asarray(upd)
        if upd.any():
            factor = np.asarray(factor)
            g.p_ed[upd] *= factor[upd, None, None]
            for r in np.nonzero(upd)[0]:
                st = self.devices[int(g.ids[r])]
                st.profile = dataclasses.replace(
                    st.profile, p_ed=g.p_ed[r].copy())
                st.n_updates += 1

        n_jobs = int(m["n_jobs"])
        total_acc = float(m["total_accuracy"])
        stats = FleetPeriodStats(
            period=t, n_devices=D, n_jobs=n_jobs,
            plan_seconds=plan_seconds, total_accuracy=total_acc,
            mean_job_accuracy=total_acc / n_jobs if n_jobs else 0.0,
            n_violations=int(m["n_violations"]),
            worst_violation=float(m["worst_violation"]),
            n_offloading=int(m["n_offloading"]),
            n_backpressured=int(m["n_backpressured"]),
            n_outage=int(m["n_outage"]),
            n_straggler_updates=int(m["n_straggler_updates"]),
            es_utilization=float(m["es_utilization"]),
            backlog=self.queue.backlog,
            n_offload_samples=int(m["n_offload_samples"]),
            n_offload_ok=int(m["n_offload_ok"]),
            n_deadline_miss=int(m["n_deadline_miss"]),
            n_retries=int(m["n_retries"]),
            n_fallback_local=int(m["n_fallback_local"]),
            n_dropped=int(m["n_dropped"]),
            realized_makespan=float(m["realized_makespan"]),
            n_es_audit_updates=int(m["n_es_audit_updates"]),
            n_hi_offloaded=int(m["n_hi_offloaded"]),
            n_hi_local_final=int(m["n_hi_local_final"]),
            hi_regret=float(m["hi_regret"]))
        self.history.append(stats)
        return stats

    def _run_period_host(self) -> FleetPeriodStats:
        """The pre-v2 host period pipeline (numpy backend, auto/amdp
        dispatch, mixed shape groups): batched api solves + host
        admission/audit bookkeeping."""
        t = self._period
        self._period += 1
        arrivals = self.queue.poll(t)
        n_pad = self.queue.batch_max
        D_all = len(self.devices)
        outage = np.fromiter((st.spec.outage_at(t) for st in self.devices),
                             dtype=bool, count=D_all)
        drift = np.fromiter((st.spec.drift_at(t) for st in self.devices),
                            dtype=np.float64, count=D_all)

        plan_seconds = 0.0
        staged = []                   # (group, fleet_problem, base, assign)
        es_demand_all = np.zeros(D_all)
        stale_all = None
        if t > 0:
            prev = np.fromiter(
                (st.spec.outage_at(t - 1) for st in self.devices),
                dtype=bool, count=D_all)
            stale_all = prev != outage     # ES column set changed: the
            #                                carried basis labels a
            #                                different LP — cold-start
        for g in self._groups:
            fp, base = self._assemble(g, arrivals, outage, n_pad)
            warm = {}
            if self.backend == "jax" and g.warm_basis is not None:
                wb = np.asarray(g.warm_basis)
                if stale_all is not None:
                    wb = np.where(stale_all[g.ids][:, None], -1, wb)
                warm["warm_start"] = wb
            sol = solve(fp, policy=self.policy, backend=self.backend,
                        **warm)
            if sol.basis is not None:   # LP-backed rows warm the next period
                g.warm_basis = np.asarray(sol.basis)
            else:
                # e.g. the policy switched to a non-LP solver ("auto"
                # dispatching every lane to the DP): drop the stale carry
                # rather than hand it to a later LP period
                g.warm_basis = None
            plan_seconds += sol.plan_seconds
            assign = sol.assignment
            es_demand_all[g.ids] = sol.es_makespan
            staged.append((g, fp, base, assign))

        # --- ES capacity: admit offload demand server by server ----------
        offl_mask = es_demand_all > 0
        admitted_mask, loads = self.pool.admit_mask(es_demand_all, self.T)
        bumped = np.nonzero(offl_mask & ~admitted_mask)[0].tolist()
        n_offloading = int(offl_mask.sum())

        # --- backpressure: ONE batched ES-disabled replan per group ------
        for g, fp, base, assign in staged:
            rows = np.nonzero(np.isin(g.ids, bumped))[0]
            if not len(rows):
                continue
            if self.backend == "jax":
                fb = solve(fp.take(rows), policy=self.policy,
                           es_disabled=True)
                plan_seconds += fb.plan_seconds
                assign[rows] = fb.assignment
            else:                     # sequential oracle path (PR-1 exact)
                t0 = time.perf_counter()
                mask = fp.real_mask
                for r in rows:
                    k = int(mask[r].sum())
                    stripped = Problem(
                        p_ed=fp.p_ed[r, :k], p_es=fp.p_es[r, :k],
                        acc=fp.acc[r], T=self.T)
                    fbp = solve(stripped, policy=self.policy,
                                backend="numpy", es_disabled=True)
                    assign[r, :k] = fbp.assignment
                plan_seconds += time.perf_counter() - t0

        # --- vectorized pricing, accounting, and straggler audit ---------
        n_jobs = 0
        total_acc = 0.0
        worst_viol = 0.0
        n_viol = 0
        n_updates = 0
        n_off_samples = 0
        realized_makespan = 0.0
        for g, fp, base, assign in staged:
            m = g.m
            mask = fp.real_mask
            n_jobs += int(mask.sum())
            # fault-free realized execution (host twin of the engine-v2
            # fields): every admitted offload completes via the ES
            n_off_samples += int((mask & (assign == m)).sum())
            acc_jobs = fp.acc[np.arange(len(g.ids))[:, None], assign]
            total_acc += float(np.where(mask, acc_jobs, 0.0).sum())

            on_ed = mask & (assign < m)
            picked = np.clip(assign, 0, m - 1)[..., None]
            ed_pred = np.where(
                on_ed, np.take_along_axis(fp.p_ed, picked, axis=2)[..., 0],
                0.0).sum(axis=1)
            # ground truth: the device's BASE latencies times its true
            # drift.  Pricing with the (EMA-updated) belief instead would
            # make the audit see the raw drift factor forever and inflate
            # the belief geometrically; against the base, it converges.
            ed_wall = np.where(
                on_ed, np.take_along_axis(base, picked, axis=2)[..., 0],
                0.0).sum(axis=1) * drift[g.ids]
            es_wall = np.where(admitted_mask[g.ids], es_demand_all[g.ids],
                               0.0)
            wall = np.maximum(ed_wall, es_wall)
            realized_makespan = max(realized_makespan,
                                    float(wall.max(initial=0.0)))
            viol = np.maximum(0.0, wall / self.T - 1.0)
            worst_viol = max(worst_viol, float(viol.max(initial=0.0)))
            n_viol += int((viol > 0).sum())

            ratio = ed_wall / np.maximum(ed_pred, 1e-9)
            upd = (ed_pred > 0) & (ratio > self.straggler_threshold)
            if upd.any():
                factor = (1 - self.ema) + self.ema * ratio
                g.p_ed[upd] *= factor[upd, None, None]
                for r in np.nonzero(upd)[0]:
                    st = self.devices[int(g.ids[r])]
                    st.profile = dataclasses.replace(
                        st.profile, p_ed=g.p_ed[r].copy())
                    st.n_updates += 1
                n_updates += int(upd.sum())

        stats = FleetPeriodStats(
            period=t, n_devices=D_all, n_jobs=n_jobs,
            plan_seconds=plan_seconds, total_accuracy=total_acc,
            mean_job_accuracy=total_acc / n_jobs if n_jobs else 0.0,
            n_violations=n_viol, worst_violation=worst_viol,
            n_offloading=n_offloading, n_backpressured=len(bumped),
            n_outage=int(outage.sum()), n_straggler_updates=n_updates,
            es_utilization=float(loads.sum()) / (self.pool.n_servers * self.T),
            backlog=self.queue.backlog,
            n_offload_samples=n_off_samples, n_offload_ok=n_off_samples,
            realized_makespan=realized_makespan)
        self.history.append(stats)
        return stats

    def _assemble(self, g: _ShapeGroup, arrivals, outage: np.ndarray,
                  n_pad: int):
        """One group's padded `FleetProblem` as masked array gathers: no
        per-device instance objects, one searchsorted + fancy-index per
        group.  Returns (fleet problem, base ED latencies)."""
        D = len(g.ids)
        lens = np.fromiter((len(arrivals[d]) for d in g.ids),
                           dtype=np.int64, count=D)
        mask = np.arange(n_pad)[None, :] < lens[:, None]
        cls = np.full((D, n_pad), g.classes[0],
                      dtype=np.asarray(self.queue.classes).dtype)
        if lens.sum():
            cls[mask] = np.concatenate(
                [arrivals[d] for d in g.ids if len(arrivals[d])])
        ci = np.searchsorted(g.classes, cls)
        rows = np.arange(D)[:, None]
        p_ed = g.p_ed[rows, ci]
        p_es = g.p_es[rows, ci]
        base = g.base_p_ed[rows, ci]
        p_ed[~mask] = 0.0
        p_es[~mask] = 0.0
        base[~mask] = 0.0
        p_es[outage[g.ids][:, None] & mask] = _OUTAGE_ES
        fp = FleetProblem(p_ed=p_ed, p_es=p_es, acc=g.acc.copy(),
                          T=np.full(D, self.T), real_mask=mask)
        return fp, base

    # ------------------------------------------------------------------
    # PR-1 per-device reference loop (benchmark baseline + parity oracle)
    # ------------------------------------------------------------------
    def run_period_reference(self) -> FleetPeriodStats:
        """The pre-vectorization period loop: per-device padding/stripping,
        sequential backpressure replans, per-device audit.  Kept as the
        oracle the array-resident `run_period` is tested against and as the
        baseline `benchmarks/fleet_bench.py` measures speedup over."""
        t = self._period
        self._period += 1
        arrivals = self.queue.poll(t)
        n_pad = self.queue.batch_max
        outages = [st.spec.outage_at(t) for st in self.devices]

        padded = [_padded_instance(st.profile, arrivals[d], self.T, n_pad,
                                   disable_es=outages[d])
                  for d, st in enumerate(self.devices)]
        sols = solve_many([Problem.from_instance(p) for p in padded],
                          policy=self.policy, backend=self.backend)
        plan_seconds = sum(s.plan_seconds for s in sols)
        scheds = [_strip_phantoms(s.to_schedule(), len(arrivals[d]))
                  for d, s in enumerate(sols)]

        # --- ES capacity: admit offload demand server by server ----------
        demands = {d: s.es_makespan for d, s in enumerate(scheds)
                   if s.es_makespan > 0}
        admitted, loads = self.pool.admit(demands, self.T)
        bumped = sorted(set(demands) - set(admitted))
        for d in bumped:  # backpressure: replan ED-only (few devices)
            fb = solve(Problem.from_instance(scheds[d].instance),
                       policy=self.policy, es_disabled=True)
            scheds[d] = fb.to_schedule()
            plan_seconds += fb.plan_seconds

        # --- simulated execution + straggler audit -----------------------
        n_jobs = 0
        total_acc = 0.0
        worst_viol = 0.0
        n_viol = 0
        n_updates = 0
        n_off_samples = 0
        realized_makespan = 0.0
        for d, st in enumerate(self.devices):
            sched = scheds[d]
            n_jobs += sched.instance.n
            total_acc += sched.total_accuracy
            n_off_samples += int(
                (sched.assignment == sched.instance.p_ed.shape[1]).sum())
            ed_wall = _ed_time_under(st.spec.profile, arrivals[d],
                                     sched.assignment) * st.spec.drift_at(t)
            es_wall = 0.0 if d in bumped else sched.es_makespan
            wall = max(ed_wall, es_wall)
            realized_makespan = max(realized_makespan, wall)
            viol = max(0.0, wall / self.T - 1.0)
            worst_viol = max(worst_viol, viol)
            n_viol += viol > 0
            new_profile, updated = audit_profile(
                st.profile, sched.ed_makespan, ed_wall,
                threshold=self.straggler_threshold, ema=self.ema)
            if updated:
                st.profile = new_profile
                st.n_updates += 1
                n_updates += 1
                g, row = self._dev_slot[d]      # keep the stacks in sync
                g.p_ed[row] = new_profile.p_ed

        stats = FleetPeriodStats(
            period=t, n_devices=len(self.devices), n_jobs=n_jobs,
            plan_seconds=plan_seconds, total_accuracy=total_acc,
            mean_job_accuracy=total_acc / n_jobs if n_jobs else 0.0,
            n_violations=n_viol, worst_violation=worst_viol,
            n_offloading=len(demands), n_backpressured=len(bumped),
            n_outage=int(sum(outages)), n_straggler_updates=n_updates,
            es_utilization=float(loads.sum()) / (self.pool.n_servers * self.T),
            backlog=self.queue.backlog,
            n_offload_samples=n_off_samples, n_offload_ok=n_off_samples,
            realized_makespan=realized_makespan)
        self.history.append(stats)
        return stats

    # ------------------------------------------------------------------
    def summary(self) -> Dict[str, float]:
        h = self.history
        if not h:
            return {}
        jobs = sum(s.n_jobs for s in h)
        return {
            "periods": len(h),
            "jobs": jobs,
            "mean_job_accuracy": (sum(s.total_accuracy for s in h) / jobs
                                  if jobs else 0.0),
            "violation_rate": sum(s.n_violations for s in h) / (
                len(h) * len(self.devices)),
            "backpressure_rate": sum(s.n_backpressured for s in h) / (
                len(h) * len(self.devices)),
            "plan_seconds_per_period": (sum(s.plan_seconds for s in h)
                                        / len(h)),
            "devices_per_second": (len(self.devices) * len(h)
                                   / max(sum(s.plan_seconds for s in h),
                                         1e-12)),
            "straggler_updates": sum(s.n_straggler_updates for s in h),
            "final_backlog": h[-1].backlog,
        }


# --------------------------------------------------------------------------
# Heterogeneous fleet construction
# --------------------------------------------------------------------------
def paper_style_profile(rng: np.random.Generator,
                        classes: Sequence[int] = (128, 512, 1024)
                        ) -> TierProfile:
    """The paper's Raspberry-Pi/ResNet50 testbed numbers with per-device
    jitter — one 'measured' device in the fleet."""
    jit_ed = rng.uniform(0.8, 1.3, size=(len(classes), 2))
    jit_es = rng.uniform(0.9, 1.2, size=len(classes))
    p_ed = np.array([PAPER_P_ED[c] for c in classes]) * jit_ed
    p_es = np.array([PAPER_COMM[c] + PAPER_P_ES_PROC[c]
                     for c in classes]) * jit_es
    return TierProfile(name="paper-jittered", p_ed=p_ed, p_es=p_es,
                       acc=PAPER_ACC.copy(), classes=list(classes))


def roofline_style_profile(rng: np.random.Generator,
                           classes: Sequence[int] = (128, 512, 1024)
                           ) -> TierProfile:
    """A roofline-derived device: LM-ladder latencies from analytic
    compute/memory terms instead of testbed measurements, scaled so they
    land in the same regime as the paper's numbers."""
    dims = np.asarray(classes, np.float64)
    flops = 4e9 * (dims / dims[0])                  # per-request useful flops
    acts = 6e7 * (dims / dims[0])                   # activation traffic bytes
    payload = 3.0 * dims ** 2                       # image-ish upload bytes
    derate = rng.uniform(0.7, 1.4)
    return roofline_profile(
        "roofline", list(classes),
        flops_per_class=flops, bytes_per_class=acts,
        model_scales=(0.25, 0.75), acc=(0.42, 0.58, 0.78),
        payload_bytes=payload,
        ed_peak_flops=1.2e12 * derate, ed_hbm_bw=40e9 * derate,
        link_gbps=0.08)


def make_fleet(n_devices: int, *, classes: Sequence[int] = (128, 512, 1024),
               roofline_frac: float = 0.5, straggler_frac: float = 0.25,
               outage_frac: float = 0.1, drift_mag: float = 3.0,
               horizon: int = 64, seed: int = 0) -> List[DeviceSpec]:
    """A heterogeneous fleet mixing paper-style and roofline-derived devices,
    with `straggler_frac` of them drifting to `drift_mag x` slowdown partway
    through the horizon and `outage_frac` suffering ES-link outages."""
    rng = np.random.default_rng(seed)
    specs: List[DeviceSpec] = []
    for d in range(n_devices):
        if rng.uniform() < roofline_frac:
            prof = roofline_style_profile(rng, classes)
        else:
            prof = paper_style_profile(rng, classes)
        drift = None
        if rng.uniform() < straggler_frac:
            onset = rng.integers(1, max(2, horizon // 2))
            drift = np.ones(horizon)
            drift[onset:] = drift_mag
        outage = None
        if rng.uniform() < outage_frac:
            outage = rng.uniform(size=horizon) < 0.2
        specs.append(DeviceSpec(profile=prof, drift=drift, outage=outage,
                                name=f"dev{d}"))
    return specs
