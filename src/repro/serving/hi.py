"""`repro.serving.hi` — the serving-layer name for online hierarchical
inference (confidence-gated per-sample offloading with in-rollout
learning).

The implementation lives in `repro.core.hi` (pure-numerics territory:
calibrated confidence streams, the threshold/bandit learners, and the
regret accounting are array math with no serving dependencies — which
also keeps `repro.api.engine`, which consumes it inside the traced
period step, free of an import cycle through this package).  This module
re-exports it under the serving namespace so HI config reads naturally
next to `FleetEngine` (the `faults`/`engine_v2` idiom):

    from repro.serving import hi
    hm = hi.HIModel.from_profiles(profile.p_ed, offload_cost=0.15)
    eng = FleetEngine.from_config(
        dataclasses.replace(cfg, hi=hm, hi_rule="threshold"))

`HIModel.none()` is the null model; a rollout carrying it with
``hi_rule="off"`` is bitwise-identical to one without the subsystem.
"""
from ..core.hi import (EXP3_GAMMA, HI_RULES, HI_STREAMS, HILearnerState,
                       HIModel, arm_grid, hi_period, presample_stream,
                       sample_confidence, validate_hi)

__all__ = [
    "HI_RULES", "HI_STREAMS", "EXP3_GAMMA",
    "HIModel", "HILearnerState",
    "arm_grid", "sample_confidence", "presample_stream", "hi_period",
    "validate_hi",
]
