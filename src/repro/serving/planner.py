"""Batch planner: the paper's algorithms as serving policies.

Policy selection:
  * identical jobs      -> AMDP   (optimal, pseudo-poly; paper §VI)
  * heterogeneous jobs  -> AMR^2  (2T / 2(a_max - a_min) guarantees; §IV-V)
  * `policy=` override  -> greedy (baseline) | dual (beyond-paper fast
                           Lagrangian scheduler) | lp (bound only)

Fleet scale: `plan_batch` plans N devices per period.  Same-shape instances
share ONE vmapped, jitted LP solve (`core.amr2.amr2_batch`) instead of N
sequential simplex runs — the per-device NumPy path stays available as the
oracle (`backend="numpy"`).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from ..core import (InstanceBatch, OffloadInstance, Schedule, amdp, amr2,
                    amr2_batch, greedy_rra)
from ..core.dual import dual_schedule


@dataclasses.dataclass
class Plan:
    schedule: Schedule
    per_model: Dict[int, np.ndarray]   # model index -> job ids
    plan_seconds: float
    policy: str

    @property
    def predicted_makespan(self) -> float:
        return self.schedule.makespan


def plan(instance: OffloadInstance, *, policy: str = "auto",
         backend: str = "numpy") -> Plan:
    t0 = time.perf_counter()
    if policy == "auto":
        policy = "amdp" if instance.is_identical() else "amr2"
    if policy == "amdp" and not instance.is_identical():
        policy = "amr2"
    if policy == "amr2":
        sched = amr2(instance, backend=backend)
    elif policy == "amdp":
        sched = amdp(instance)
    elif policy == "greedy":
        sched = greedy_rra(instance)
    elif policy == "dual":
        sched = dual_schedule(instance)
    else:
        raise ValueError(policy)
    return _wrap(sched, time.perf_counter() - t0, policy)


def _wrap(sched: Schedule, plan_seconds: float, policy: str) -> Plan:
    per_model = {i: np.nonzero(sched.assignment == i)[0]
                 for i in range(sched.instance.m + 1)}
    return Plan(schedule=sched, per_model=per_model,
                plan_seconds=plan_seconds, policy=policy)


def plan_batch(instances: Union[InstanceBatch, Sequence[OffloadInstance]], *,
               policy: str = "auto", backend: str = "jax") -> List[Plan]:
    """Plan a whole fleet's period in as few solver calls as possible.

    With ``backend="jax"`` and an AMR^2-compatible policy, instances are
    grouped by (n, m) shape and each group is planned by ONE jitted
    `jax.vmap` LP solve — a uniform fleet is a single jit call per period.
    ``policy="auto"`` keeps the scalar planner's dispatch: identical-job
    instances still go to the exact AMDP (per device — the DP has no
    batched path yet) and only the heterogeneous rest is vmapped.
    ``policy="amdp"`` and ``backend="numpy"`` fall back to the sequential
    per-device path, which doubles as the oracle the vmapped path is
    tested against.

    Returns one Plan per instance, in input order.  `plan_seconds` on each
    Plan is the group's solve time amortised over its members.
    """
    if isinstance(instances, InstanceBatch):
        insts = [instances[b] for b in range(len(instances))]
    else:
        insts = list(instances)
    if not insts:
        return []
    if backend != "jax" or policy not in ("auto", "amr2"):
        return [plan(i, policy=policy, backend=backend) for i in insts]

    plans: List[Optional[Plan]] = [None] * len(insts)
    groups: Dict[tuple, List[int]] = {}
    for idx, inst in enumerate(insts):
        if policy == "auto" and inst.is_identical():
            plans[idx] = plan(inst, policy="auto", backend=backend)
            continue
        groups.setdefault((inst.n, inst.m), []).append(idx)
    for idxs in groups.values():
        t0 = time.perf_counter()
        group = [insts[i] for i in idxs]
        # Pad the batch axis up to a power of two (repeating the last
        # instance) so a fluctuating group size — zero-arrival or
        # identical-job devices peel off to the scalar path above — reuses
        # one of O(log B) compiled programs instead of retracing the
        # vmapped simplex for every distinct B.
        bucket = 1 << (len(group) - 1).bit_length()
        batch = InstanceBatch.stack(group + [group[-1]] * (bucket - len(group)))
        scheds = amr2_batch(batch)[:len(group)]
        dt = (time.perf_counter() - t0) / len(idxs)
        for i, sched in zip(idxs, scheds):
            plans[i] = _wrap(sched, dt, "amr2")
    return plans  # type: ignore[return-value]


def replan_without_es(instance: OffloadInstance, **kw) -> Plan:
    """ES-tier failure: the paper's m-model special case — force every job
    onto the ED ladder by making offloading infeasible (p_es >> T)."""
    crippled = OffloadInstance(
        p_ed=instance.p_ed.copy(),
        p_es=np.full(instance.n, 1e9),
        acc=instance.acc.copy(), T=instance.T)
    return plan(crippled, **kw)
