"""DEPRECATED planner entry points — thin shims over `repro.api`.

The four parallel entry points this module used to implement (`plan`,
`plan_batch`, `plan_batch_arrays`, `replan_without_es`/`_batch`) are now
one front door: ``repro.api.solve`` (single problem or `FleetProblem`)
and ``repro.api.solve_many`` (mixed-shape sequences), dispatching through
the solver registry.  Migration map:

  ==============================  =====================================
  legacy                          `repro.api`
  ==============================  =====================================
  ``plan(inst, policy=...)``      ``solve(Problem.from_instance(inst),
                                  policy=...)``
  ``plan_batch(insts)``           ``solve_many(insts)``
  ``plan_batch_arrays(batch)``    ``solve(FleetProblem.from_batch(batch))``
  ``replan_without_es(inst)``     ``solve(inst, es_disabled=True)``
  ``replan_without_es_batch(b)``  ``solve(FleetProblem.from_batch(b,
                                  real_mask), es_disabled=True)``
  ==============================  =====================================

Each shim emits a ``DeprecationWarning`` once per process and delegates;
behaviour (dispatch table, bucketing, timings, return types) is unchanged.
Repo-internal call sites use `repro.api` directly — CI runs the fleet
example with these warnings promoted to errors for internal frames.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from .. import api
from ..core.problem import FleetProblem, Problem, Solution
from ..core.types import InstanceBatch, OffloadInstance, Schedule

_WARNED: set = set()


def _deprecated(name: str, replacement: str) -> None:
    if name in _WARNED:
        return
    _WARNED.add(name)
    warnings.warn(
        f"repro.serving.{name} is deprecated; use {replacement} "
        f"(see repro.api)", DeprecationWarning, stacklevel=3)


def _reset_deprecation_warnings() -> None:
    """Test hook: make every shim warn again."""
    _WARNED.clear()


def _reject_bound_only(policy: str) -> None:
    """The legacy planner never produced bound-only pseudo-schedules
    (``plan(policy="lp")`` raised ValueError); keep that contract — legacy
    callers sweeping policy names must not silently receive assignments
    that need not satisfy the budgets.  New code wanting the LP bound uses
    ``api.solve(..., policy="lp")`` explicitly."""
    if policy != "auto" and api.get_solver(policy).info.bound_only:
        raise ValueError(
            f"policy {policy!r} is bound-only and was never a legacy "
            f"planner policy; call repro.api.solve(..., policy={policy!r}) "
            f"for the bound")


@dataclasses.dataclass
class Plan:
    """Legacy single-device planning result (wraps a core `Schedule`)."""
    schedule: Schedule
    plan_seconds: float
    policy: str
    # model index -> job ids; computed lazily — the fleet path never reads
    # it, and eagerly materializing it costs m+1 np.nonzero scans per device
    # per period.
    _per_model: Optional[Dict[int, np.ndarray]] = dataclasses.field(
        default=None, repr=False)

    @property
    def per_model(self) -> Dict[int, np.ndarray]:
        if self._per_model is None:
            a = self.schedule.assignment
            self._per_model = {i: np.nonzero(a == i)[0]
                               for i in range(self.schedule.instance.m + 1)}
        return self._per_model

    @property
    def predicted_makespan(self) -> float:
        return self.schedule.makespan


@dataclasses.dataclass
class FleetPlan:
    """Legacy stacked planning result for one same-shape device batch."""
    assignment: np.ndarray    # (B, n) int64
    status: np.ndarray        # (B,) int: ST_OK / ST_FALLBACK / ST_INFEASIBLE
    solver: np.ndarray        # (B,) str
    plan_seconds: float


def _to_plan(sol: Solution) -> Plan:
    return Plan(schedule=sol.to_schedule(), plan_seconds=sol.plan_seconds,
                policy=sol.solver_name)


def _to_fleet_plan(sol: Solution) -> FleetPlan:
    return FleetPlan(assignment=sol.assignment,
                     status=np.asarray(sol.status),
                     solver=np.atleast_1d(sol.solver),
                     plan_seconds=sol.plan_seconds)


def plan(instance: OffloadInstance, *, policy: str = "auto",
         backend: str = "numpy") -> Plan:
    """Deprecated: use ``repro.api.solve``."""
    _deprecated("plan", "api.solve(problem, policy=...)")
    _reject_bound_only(policy)
    return _to_plan(api.solve(Problem.from_instance(instance),
                              policy=policy, backend=backend))


def plan_batch(instances: Union[InstanceBatch, Sequence[OffloadInstance]], *,
               policy: str = "auto", backend: str = "jax") -> List[Plan]:
    """Deprecated: use ``repro.api.solve_many`` (or ``solve`` on a
    `FleetProblem` for the array-level fleet path)."""
    _deprecated("plan_batch", "api.solve_many(problems, policy=...)")
    _reject_bound_only(policy)
    if isinstance(instances, InstanceBatch):
        insts = [instances[b] for b in range(len(instances))]
    else:
        insts = list(instances)
    if not insts:
        return []
    sols = api.solve_many([Problem.from_instance(i) for i in insts],
                          policy=policy, backend=backend)
    return [_to_plan(s) for s in sols]


def plan_batch_arrays(batch: InstanceBatch, *, policy: str = "auto",
                      backend: str = "jax") -> FleetPlan:
    """Deprecated: use ``repro.api.solve`` on a `FleetProblem`."""
    _deprecated("plan_batch_arrays",
                "api.solve(FleetProblem.from_batch(batch), policy=...)")
    _reject_bound_only(policy)
    return _to_fleet_plan(api.solve(FleetProblem.from_batch(batch),
                                    policy=policy, backend=backend))


def replan_without_es(instance: OffloadInstance, **kw) -> Plan:
    """Deprecated: use ``repro.api.solve(..., es_disabled=True)``."""
    _deprecated("replan_without_es", "api.solve(problem, es_disabled=True)")
    return _to_plan(api.solve(Problem.from_instance(instance),
                              es_disabled=True, **kw))


def replan_without_es_batch(batch: InstanceBatch, *,
                            real_mask: Optional[np.ndarray] = None,
                            policy: str = "auto",
                            backend: str = "jax") -> FleetPlan:
    """Deprecated: use ``repro.api.solve`` on a `FleetProblem` with
    ``es_disabled=True``."""
    _deprecated("replan_without_es_batch",
                "api.solve(FleetProblem.from_batch(batch, real_mask), "
                "es_disabled=True)")
    _reject_bound_only(policy)
    fp = FleetProblem.from_batch(batch, real_mask=real_mask)
    return _to_fleet_plan(api.solve(fp, policy=policy, backend=backend,
                                    es_disabled=True))
