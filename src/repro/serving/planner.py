"""Batch planner: the paper's algorithms as serving policies.

Policy selection:
  * identical jobs      -> AMDP   (optimal, pseudo-poly; paper §VI)
  * heterogeneous jobs  -> AMR^2  (2T / 2(a_max - a_min) guarantees; §IV-V)
  * `policy=` override  -> greedy (baseline) | dual (beyond-paper fast
                           Lagrangian scheduler) | lp (bound only)

Fleet scale: `plan_batch` plans N devices per period.  With
``backend="jax"`` every policy with a batched solver runs as a handful of
jitted calls per period instead of N sequential solves:

  ============  ==========================  ===========================
  policy        scalar path (oracle)        batched path (one jit/group)
  ============  ==========================  ===========================
  amr2 / auto   NumPy simplex + rounding    `amr2_batch` (vmapped LP +
                                            vectorized rounding)
  amdp / auto   per-device CCKP DP          `amdp_batch` (vmapped DP;
                                            `impl="pallas"` kernel route)
  dual          NumPy bisection             `dual_schedule_batch` (vmapped
                                            jitted bisection)
  greedy        per-device greedy           (no batched path)
  ============  ==========================  ===========================

The per-device NumPy path stays available as the oracle
(`backend="numpy"`).  `plan_batch_arrays` is the array-level variant the
fleet engine uses: it takes an `InstanceBatch` and returns stacked
assignments without materializing per-device Plan/Schedule objects.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from ..core import (InstanceBatch, OffloadInstance, Schedule, amdp,
                    amdp_batch, amr2, amr2_batch, amr2_batch_arrays,
                    greedy_rra)
from ..core.amr2 import ST_FALLBACK, STATUS_NAMES
from ..core.dual import dual_schedule, dual_schedule_batch_arrays
from ..core.types import next_pow2

_BATCHED_POLICIES = ("auto", "amr2", "amdp", "dual")


@dataclasses.dataclass
class Plan:
    schedule: Schedule
    plan_seconds: float
    policy: str
    # model index -> job ids; computed lazily — the fleet path never reads
    # it, and eagerly materializing it costs m+1 np.nonzero scans per device
    # per period.
    _per_model: Optional[Dict[int, np.ndarray]] = dataclasses.field(
        default=None, repr=False)

    @property
    def per_model(self) -> Dict[int, np.ndarray]:
        if self._per_model is None:
            a = self.schedule.assignment
            self._per_model = {i: np.nonzero(a == i)[0]
                               for i in range(self.schedule.instance.m + 1)}
        return self._per_model

    @property
    def predicted_makespan(self) -> float:
        return self.schedule.makespan


def plan(instance: OffloadInstance, *, policy: str = "auto",
         backend: str = "numpy") -> Plan:
    t0 = time.perf_counter()
    if policy == "auto":
        policy = "amdp" if instance.is_identical() else "amr2"
    if policy == "amdp" and not instance.is_identical():
        policy = "amr2"
    if policy == "amr2":
        sched = amr2(instance, backend=backend)
    elif policy == "amdp":
        sched = amdp(instance)
    elif policy == "greedy":
        sched = greedy_rra(instance)
    elif policy == "dual":
        sched = dual_schedule(instance)
    else:
        raise ValueError(policy)
    return _wrap(sched, time.perf_counter() - t0, policy)


def _wrap(sched: Schedule, plan_seconds: float, policy: str) -> Plan:
    return Plan(schedule=sched, plan_seconds=plan_seconds, policy=policy)


def _bucket_pad(group: "list") -> "list":
    """Pad a group up to a power-of-two size by repeating its last element
    so a fluctuating group size reuses one of O(log B) compiled programs."""
    return group + [group[-1]] * (next_pow2(len(group)) - len(group))


def plan_batch(instances: Union[InstanceBatch, Sequence[OffloadInstance]], *,
               policy: str = "auto", backend: str = "jax") -> List[Plan]:
    """Plan a whole fleet's period in as few solver calls as possible.

    With ``backend="jax"`` instances are grouped by (n, m) shape and each
    group runs through the policy's batched solver (see the module policy
    table) — one jitted call per shape group.  ``policy="auto"`` keeps the
    scalar planner's dispatch: identical-job instances go to the exact AMDP
    — now via the vmapped `amdp_batch` instead of per-device scalar solves
    — and the heterogeneous rest to the vmapped AMR^2.  ``backend="numpy"``
    falls back to the sequential per-device path, which doubles as the
    oracle the batched paths are tested against.

    Returns one Plan per instance, in input order.  `plan_seconds` on each
    Plan is the group's solve time amortised over its members.
    """
    if isinstance(instances, InstanceBatch):
        insts = [instances[b] for b in range(len(instances))]
    else:
        insts = list(instances)
    if not insts:
        return []
    if backend != "jax" or policy not in _BATCHED_POLICIES:
        return [plan(i, policy=policy, backend=backend) for i in insts]

    plans: List[Optional[Plan]] = [None] * len(insts)
    amdp_idxs: List[int] = []
    amr2_groups: Dict[tuple, List[int]] = {}
    dual_groups: Dict[tuple, List[int]] = {}
    for idx, inst in enumerate(insts):
        if policy == "dual":
            dual_groups.setdefault((inst.n, inst.m), []).append(idx)
        elif policy in ("auto", "amdp") and inst.is_identical():
            amdp_idxs.append(idx)
        else:
            amr2_groups.setdefault((inst.n, inst.m), []).append(idx)

    if amdp_idxs:                     # vmapped DP, grouped/bucketed inside
        t0 = time.perf_counter()
        scheds = amdp_batch([insts[i] for i in amdp_idxs])
        dt = (time.perf_counter() - t0) / len(amdp_idxs)
        for i, sched in zip(amdp_idxs, scheds):
            plans[i] = _wrap(sched, dt, "amdp")

    for idxs in amr2_groups.values():
        t0 = time.perf_counter()
        group = _bucket_pad([insts[i] for i in idxs])
        scheds = amr2_batch(InstanceBatch.stack(group))[:len(idxs)]
        dt = (time.perf_counter() - t0) / len(idxs)
        for i, sched in zip(idxs, scheds):
            plans[i] = _wrap(sched, dt, "amr2")

    for idxs in dual_groups.values():
        t0 = time.perf_counter()
        group = _bucket_pad([insts[i] for i in idxs])
        batch = InstanceBatch.stack(group)
        assign, status = dual_schedule_batch_arrays(batch)
        dt = (time.perf_counter() - t0) / len(idxs)
        for k, i in enumerate(idxs):
            sched = Schedule(assignment=assign[k], instance=insts[i],
                             solver="dual",
                             status="ok" if status[k] == 0 else "fallback")
            plans[i] = _wrap(sched, dt, "dual")
    return plans  # type: ignore[return-value]


# --------------------------------------------------------------------------
# Array-level fleet path — no per-device Plan/Schedule objects
# --------------------------------------------------------------------------
@dataclasses.dataclass
class FleetPlan:
    """Stacked planning result for one same-shape device batch."""
    assignment: np.ndarray    # (B, n) int64
    status: np.ndarray        # (B,) int: ST_OK / ST_FALLBACK / ST_INFEASIBLE
    solver: np.ndarray        # (B,) str
    plan_seconds: float


_SCALAR_STATUS = {name: code for code, name in enumerate(STATUS_NAMES)}


def plan_batch_arrays(batch: InstanceBatch, *, policy: str = "auto",
                      backend: str = "jax") -> FleetPlan:
    """`plan_batch` for the fleet hot path: one `InstanceBatch` in, stacked
    assignment arrays out.  ``backend="jax"`` dispatches whole sub-batches
    to the batched solvers (identical-job devices to `amdp_batch`, the rest
    to `amr2_batch_arrays` / `dual_schedule_batch_arrays`); the per-device
    Python cost is O(1) apart from the O(m) AMDP backtracks.
    ``backend="numpy"`` runs the scalar per-device oracle."""
    t0 = time.perf_counter()
    B, n = batch.p_es.shape
    m = batch.m
    assignment = np.zeros((B, n), dtype=np.int64)
    status = np.zeros(B, dtype=np.int64)
    solver = np.empty(B, dtype=object)

    if backend != "jax" or policy not in _BATCHED_POLICIES:
        for b in range(B):            # sequential oracle path
            p = plan(batch[b], policy=policy, backend=backend)
            assignment[b] = p.schedule.assignment
            status[b] = _SCALAR_STATUS.get(p.schedule.status, ST_FALLBACK)
            solver[b] = p.schedule.solver
        return FleetPlan(assignment=assignment, status=status, solver=solver,
                         plan_seconds=time.perf_counter() - t0)

    if policy in ("auto", "amdp"):
        ident = batch.identical_mask()
    else:
        ident = np.zeros(B, dtype=bool)

    rest = np.nonzero(~ident)[0]
    if ident.any():
        idxs = np.nonzero(ident)[0]
        scheds = amdp_batch([batch[int(b)] for b in idxs])
        for b, sched in zip(idxs, scheds):
            assignment[b] = sched.assignment
            status[b] = _SCALAR_STATUS[sched.status]
            solver[b] = "amdp"
    if len(rest):
        rows = np.concatenate(
            [rest, np.repeat(rest[-1:], next_pow2(len(rest)) - len(rest))])
        sub = InstanceBatch(p_ed=batch.p_ed[rows], p_es=batch.p_es[rows],
                            acc=batch.acc[rows], T=batch.T[rows])
        if policy == "dual":
            assign, st = dual_schedule_batch_arrays(sub)
            assignment[rest] = assign[:len(rest)]
            status[rest] = st[:len(rest)]
            solver[rest] = "dual"
        else:
            assign, st, _, _ = amr2_batch_arrays(sub)
            assignment[rest] = assign[:len(rest)]
            status[rest] = st[:len(rest)]
            solver[rest] = "amr2"
    return FleetPlan(assignment=assignment, status=status, solver=solver,
                     plan_seconds=time.perf_counter() - t0)


def replan_without_es(instance: OffloadInstance, **kw) -> Plan:
    """ES-tier failure: the paper's m-model special case — force every job
    onto the ED ladder by making offloading infeasible (p_es >> T)."""
    crippled = OffloadInstance(
        p_ed=instance.p_ed.copy(),
        p_es=np.full(instance.n, 1e9),
        acc=instance.acc.copy(), T=instance.T)
    return plan(crippled, **kw)


def replan_without_es_batch(batch: InstanceBatch, *,
                            real_mask: Optional[np.ndarray] = None,
                            policy: str = "auto",
                            backend: str = "jax") -> FleetPlan:
    """Batched `replan_without_es`: ONE ES-disabled batched solve for every
    bumped device instead of a Python loop of scalar replans.

    `real_mask` (B, n) marks real jobs; phantom padding keeps p_es = 0 (free
    everywhere, stripped later) while real jobs get the uniform huge
    sentinel that makes offloading infeasible.

    Policy dispatch mirrors the scalar `replan_without_es` (which plans the
    *stripped* crippled instance): under ``auto``/``amdp``, devices whose
    real jobs share processing times route to the exact `amdp_batch` on
    their stripped instances — the crippled p_es is uniform, so this is
    precisely the scalar planner's identical-job dispatch — and only the
    heterogeneous rest goes through the batched AMR^2."""
    if real_mask is None:
        real_mask = np.ones(batch.p_es.shape, dtype=bool)
    p_es = np.where(real_mask, 1e9, 0.0)
    crippled = InstanceBatch(p_ed=batch.p_ed.copy(), p_es=p_es,
                             acc=batch.acc.copy(), T=batch.T.copy())
    if backend != "jax" or policy not in ("auto", "amdp"):
        return plan_batch_arrays(crippled, policy=policy, backend=backend)

    t0 = time.perf_counter()
    B, n = crippled.p_es.shape
    m = crippled.m
    k = real_mask.sum(axis=1)
    first = np.argmax(real_mask, axis=1)            # first real job index
    ref_row = crippled.p_ed[np.arange(B), first]    # (B, m)
    hetero = (~np.isclose(crippled.p_ed, ref_row[:, None, :], rtol=1e-9)
              ).any(axis=2) & real_mask
    ident = (k > 0) & ~hetero.any(axis=1)

    assignment = np.zeros((B, n), dtype=np.int64)
    status = np.zeros(B, dtype=np.int64)
    solver = np.empty(B, dtype=object)
    if ident.any():
        idxs = np.nonzero(ident)[0]
        stripped = [OffloadInstance(
            p_ed=crippled.p_ed[b][real_mask[b]],
            p_es=crippled.p_es[b][real_mask[b]],
            acc=crippled.acc[b], T=float(crippled.T[b]))
            for b in idxs]
        for b, sched in zip(idxs, amdp_batch(stripped)):
            row = np.full(n, m, dtype=np.int64)     # phantoms: free ES
            row[real_mask[b]] = sched.assignment
            assignment[b] = row
            status[b] = _SCALAR_STATUS[sched.status]
            solver[b] = "amdp"
    rest = np.nonzero(~ident)[0]
    if len(rest):
        sub = InstanceBatch(p_ed=crippled.p_ed[rest],
                            p_es=crippled.p_es[rest],
                            acc=crippled.acc[rest], T=crippled.T[rest])
        fp = plan_batch_arrays(sub, policy="amr2", backend="jax")
        assignment[rest] = fp.assignment
        status[rest] = fp.status
        solver[rest] = fp.solver
    return FleetPlan(assignment=assignment, status=status, solver=solver,
                     plan_seconds=time.perf_counter() - t0)
