"""Batch planner: the paper's algorithms as serving policies.

Policy selection:
  * identical jobs      -> AMDP   (optimal, pseudo-poly; paper §VI)
  * heterogeneous jobs  -> AMR^2  (2T / 2(a_max - a_min) guarantees; §IV-V)
  * `policy=` override  -> greedy (baseline) | dual (beyond-paper fast
                           Lagrangian scheduler) | lp (bound only)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from ..core import (OffloadInstance, Schedule, amdp, amr2, greedy_rra)
from ..core.dual import dual_schedule


@dataclasses.dataclass
class Plan:
    schedule: Schedule
    per_model: Dict[int, np.ndarray]   # model index -> job ids
    plan_seconds: float
    policy: str

    @property
    def predicted_makespan(self) -> float:
        return self.schedule.makespan


def plan(instance: OffloadInstance, *, policy: str = "auto",
         backend: str = "numpy") -> Plan:
    t0 = time.perf_counter()
    if policy == "auto":
        policy = "amdp" if instance.is_identical() else "amr2"
    if policy == "amdp" and not instance.is_identical():
        policy = "amr2"
    if policy == "amr2":
        sched = amr2(instance, backend=backend)
    elif policy == "amdp":
        sched = amdp(instance)
    elif policy == "greedy":
        sched = greedy_rra(instance)
    elif policy == "dual":
        sched = dual_schedule(instance)
    else:
        raise ValueError(policy)
    dt = time.perf_counter() - t0
    per_model = {i: np.nonzero(sched.assignment == i)[0]
                 for i in range(instance.m + 1)}
    return Plan(schedule=sched, per_model=per_model, plan_seconds=dt,
                policy=policy)


def replan_without_es(instance: OffloadInstance, **kw) -> Plan:
    """ES-tier failure: the paper's m-model special case — force every job
    onto the ED ladder by making offloading infeasible (p_es >> T)."""
    crippled = OffloadInstance(
        p_ed=instance.p_ed.copy(),
        p_es=np.full(instance.n, 1e9),
        acc=instance.acc.copy(), T=instance.T)
    return plan(crippled, **kw)
