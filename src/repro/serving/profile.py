"""Latency/accuracy profiling for the tier ladder.

Mirrors the paper's methodology (§VII-B): run each (model, size-class)
30 times, take the *median* (robust to cold starts), and treat comm time as
total minus compute.  Two sources:

  * `measure_profiles` — wall-clock medians of jitted apply fns (the CPU
    example path; on a real fleet this is the same code against TPU tiers).
  * `roofline_profiles` — analytic per-request step time from the dry-run
    roofline terms (the TPU-target path: max of compute/memory/collective
    terms at the serving batch), used when hardware isn't attached.

Comm time for offloading to the ES tier: request payload bytes / link GB/s
(the paper's c_j; ICI/DCN instead of LAN).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Sequence

import numpy as np

from ..core.types import OffloadInstance


@dataclasses.dataclass
class TierProfile:
    """p_ij generator: per-model seconds for each job size-class."""
    name: str
    # per size-class processing seconds on each ED model: (n_class, m)
    p_ed: np.ndarray
    # per size-class total ES seconds (comm + compute): (n_class,)
    p_es: np.ndarray
    acc: np.ndarray               # (m+1,)
    classes: Sequence[int]        # size-class labels (e.g. seq lengths)

    def instance(self, job_classes: np.ndarray, T: float) -> OffloadInstance:
        ci = np.searchsorted(np.asarray(self.classes), job_classes)
        return OffloadInstance(p_ed=self.p_ed[ci], p_es=self.p_es[ci],
                               acc=self.acc.copy(), T=T)


def measure_latency(fn: Callable, args, iters: int = 30) -> float:
    fn(*args)                      # compile / warm
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        _block(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _block(x):
    try:
        import jax
        jax.block_until_ready(x)
    except Exception:  # noqa: BLE001 — non-jax outputs
        pass


def measure_profiles(apply_fns: Dict[str, Callable], sample_batches,
                     accs: Dict[str, float], es_name: str,
                     comm_seconds: Sequence[float], classes: Sequence[int],
                     iters: int = 30) -> TierProfile:
    """apply_fns: model name -> fn(batch); the last name `es_name` is the
    ES-tier model.  comm_seconds: per size-class upload time."""
    ed_names = [n for n in apply_fns if n != es_name]
    p_ed = np.zeros((len(classes), len(ed_names)))
    p_es = np.zeros(len(classes))
    for c, batch in enumerate(sample_batches):
        for j, n in enumerate(ed_names):
            p_ed[c, j] = measure_latency(apply_fns[n], (batch,), iters)
        p_es[c] = comm_seconds[c] + measure_latency(
            apply_fns[es_name], (batch,), iters)
    acc = np.array([accs[n] for n in ed_names] + [accs[es_name]])
    order = np.argsort(acc[:-1])
    return TierProfile(name="measured", p_ed=p_ed[:, order],
                       p_es=p_es, acc=np.concatenate([acc[:-1][order],
                                                      acc[-1:]]),
                       classes=classes)


def comm_time(payload_bytes: float, link_gbps: float = 50.0) -> float:
    """The paper's c_j on a TPU fleet: payload over ICI/DCN."""
    return payload_bytes / (link_gbps * 1e9)


def roofline_profile(name: str, classes: Sequence[int], *,
                     flops_per_class: Sequence[float],
                     bytes_per_class: Sequence[float],
                     model_scales: Sequence[float],
                     acc: Sequence[float],
                     payload_bytes: Sequence[float],
                     ed_peak_flops: float = 2e12,
                     ed_hbm_bw: float = 60e9,
                     es_peak_flops: float = 197e12,
                     es_hbm_bw: float = 819e9,
                     link_gbps: float = 50.0) -> TierProfile:
    """Analytic TierProfile from roofline terms (no hardware attached).

    Mirrors `launch/roofline.terms`: a request's step time on a tier is the
    max of its compute and memory terms.  The ED ladder holds width-scaled
    variants of the full model (`model_scales`, ascending, matching the
    `paper_edge` alpha-ladder idiom); the ES tier runs the full model on
    server silicon (TPU v5e constants by default).  Offload time adds the
    paper's c_j as payload over the ICI/DCN link.
    """
    f = np.asarray(flops_per_class, np.float64)
    by = np.asarray(bytes_per_class, np.float64)
    scales = np.asarray(model_scales, np.float64)
    if len(f) != len(classes) or len(by) != len(classes):
        raise ValueError("per-class terms must match `classes`")
    if len(acc) != len(scales) + 1:
        raise ValueError("acc must have one entry per ED model plus the ES")
    # width scaling: flops ~ scale^2, activation bytes ~ scale
    p_ed = np.maximum(f[:, None] * scales[None, :] ** 2 / ed_peak_flops,
                      by[:, None] * scales[None, :] / ed_hbm_bw)
    es_step = np.maximum(f / es_peak_flops, by / es_hbm_bw)
    comm = np.array([comm_time(p, link_gbps) for p in payload_bytes])
    return TierProfile(name=name, p_ed=p_ed, p_es=es_step + comm,
                       acc=np.asarray(acc, np.float64), classes=list(classes))
