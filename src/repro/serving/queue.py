"""Request-arrival queue for the fleet engine.

The paper plans one period at a time: at the period boundary the ED looks at
the jobs that arrived during the last T seconds and solves P over them
(§III-C).  At fleet scale every device has its own arrival process; this
module models them as independent Poisson streams (or a replayed trace) with
a per-device FIFO backlog, so bursts beyond the per-period planning window
(`batch_max`) carry over instead of being dropped — the queueing behaviour
hierarchical-inference serving systems have to absorb.
"""
from __future__ import annotations

from collections import deque
from typing import Callable, List, Optional, Sequence, Union

import numpy as np


class RequestQueue:
    """Per-device FIFO backlog fed by Poisson or trace-driven arrivals.

    Parameters
    ----------
    n_devices:   fleet size.
    classes:     job size-class labels requests are drawn from (must match
                 the devices' `TierProfile.classes`).
    rate:        mean arrivals per device per period — scalar or (n_devices,)
                 for heterogeneous load.  Ignored when `trace` is given.
    batch_max:   planning-window cap: at most this many jobs are released to
                 a device's planner each period; the rest stay queued.
    trace:       optional (periods, n_devices) arrival-count array replayed
                 cyclically instead of Poisson sampling.
    class_probs: optional sampling distribution over `classes`.
    """

    def __init__(self, n_devices: int, classes: Sequence[int], *,
                 rate: Union[float, Sequence[float]] = 8.0,
                 batch_max: int = 16, seed: int = 0,
                 trace: Optional[np.ndarray] = None,
                 class_probs: Optional[Sequence[float]] = None):
        if batch_max <= 0:
            raise ValueError("batch_max must be positive")
        self.n_devices = n_devices
        self.classes = np.asarray(classes)
        self.batch_max = batch_max
        self.rate = np.broadcast_to(np.asarray(rate, np.float64),
                                    (n_devices,))
        self.trace = None if trace is None else np.asarray(trace)
        if self.trace is not None and self.trace.shape[1] != n_devices:
            raise ValueError("trace must be (periods, n_devices)")
        self.class_probs = class_probs
        self.seed = seed
        self._rng = np.random.default_rng(seed)
        self._backlog: List[deque] = [deque() for _ in range(n_devices)]
        self.total_arrived = 0
        self.total_released = 0

    def _arrival_counts(self, period: int) -> np.ndarray:
        if self.trace is not None:
            if self.trace.shape[0] == 0:
                # an empty trace means "no arrivals ever", not a crash:
                # every period yields zero-arrival (empty real_mask) rows
                return np.zeros(self.n_devices, dtype=np.int64)
            return self.trace[period % self.trace.shape[0]]
        return self._rng.poisson(self.rate)

    def presample(self, periods: int):
        """Replay the arrival process for ``periods`` periods from the
        queue's initial seed WITHOUT touching live state: the exact counts
        and per-device class streams a fresh queue with this configuration
        would produce from ``poll(0) .. poll(periods - 1)``.

        This is how the pure-functional engine (`repro.api.engine`) gets
        bit-identical arrivals to the host loop: the (periods, n_devices)
        counts and the per-device arrival-ordered class streams become
        `EngineParams` arrays, and the scanned `step` releases
        ``min(backlog, batch_max)`` jobs off each stream — the same FIFO
        the deque implements.

        Returns ``(counts (periods, n_devices) int64, stream (n_devices,
        S) int32)`` where ``stream[d, k]`` is the CLASS-TABLE INDEX (into
        ``self.classes``) of device d's k-th arrival and S is the max
        total arrivals of any device (shorter streams are 0-padded; the
        padding is never dereferenced because releases never outrun
        arrivals).
        """
        rng = np.random.default_rng(self.seed)
        counts = np.zeros((periods, self.n_devices), dtype=np.int64)
        streams: List[List[int]] = [[] for _ in range(self.n_devices)]
        lut = {int(c): i for i, c in enumerate(self.classes)}
        for t in range(periods):
            if self.trace is not None:
                if self.trace.shape[0]:
                    counts[t] = self.trace[t % self.trace.shape[0]]
            else:
                counts[t] = rng.poisson(self.rate)
            for d in range(self.n_devices):
                k = int(counts[t, d])
                if k:            # poll() skips the rng call when k == 0
                    fresh = rng.choice(self.classes, size=k,
                                       p=self.class_probs)
                    streams[d].extend(lut[int(c)] for c in fresh)
        S = max((len(s) for s in streams), default=0)
        stream = np.zeros((self.n_devices, max(S, 1)), dtype=np.int32)
        for d, s in enumerate(streams):
            stream[d, :len(s)] = s
        return counts, stream

    def poll(self, period: int) -> List[np.ndarray]:
        """Admit this period's arrivals, then release up to `batch_max` jobs
        per device (oldest first).  Returns one job-class array per device."""
        counts = self._arrival_counts(period)
        released: List[np.ndarray] = []
        for d in range(self.n_devices):
            k = int(counts[d])
            if k:
                fresh = self._rng.choice(self.classes, size=k,
                                         p=self.class_probs)
                self._backlog[d].extend(fresh.tolist())
                self.total_arrived += k
            take = min(len(self._backlog[d]), self.batch_max)
            out = np.array([self._backlog[d].popleft() for _ in range(take)],
                           dtype=self.classes.dtype)
            self.total_released += take
            released.append(out)
        return released

    @property
    def backlog(self) -> int:
        """Jobs admitted but not yet released to any planner."""
        return sum(len(q) for q in self._backlog)

    def per_device_backlog(self) -> np.ndarray:
        return np.array([len(q) for q in self._backlog])
