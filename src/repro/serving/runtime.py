"""Period-T serving loop (the paper's deployment model, §III-C).

Every period: drain the request queue, build the OffloadInstance from the
current TierProfile, plan (AMR^2 / AMDP / dual), execute across the tiers,
then *audit*: if measured per-model latency drifts from the profile by more
than `straggler_threshold`, the profile is re-measured (EMA update) so the
next period's p_ij reflect the degraded tier — the straggler-mitigation
loop.  An ES outage inside a period triggers the fallback replan.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from ..core.types import OffloadInstance
from .executor import ExecutionReport, execute
from .planner import Plan, plan
from .profile import TierProfile


@dataclasses.dataclass
class PeriodStats:
    n_jobs: int
    policy: str
    predicted_makespan: float
    wall_makespan: float
    total_accuracy: float
    plan_seconds: float
    violation: float
    replanned: bool
    profile_updated: bool


class ServingRuntime:
    def __init__(self, profile: TierProfile, apply_ed: List[Callable],
                 apply_es: Callable, *, T: float, policy: str = "auto",
                 straggler_threshold: float = 1.5, ema: float = 0.5):
        self.profile = profile
        self.apply_ed = apply_ed
        self.apply_es = apply_es
        self.T = T
        self.policy = policy
        self.straggler_threshold = straggler_threshold
        self.ema = ema
        self.history: List[PeriodStats] = []

    def run_period(self, jobs: List[object], job_classes: np.ndarray, *,
                   es_fail: bool = False) -> PeriodStats:
        inst = self.profile.instance(job_classes, self.T)
        p = plan(inst, policy=self.policy)
        report = execute(p, self.apply_ed, self.apply_es, jobs,
                         es_fail=es_fail)
        updated = self._audit(p, report, job_classes)
        stats = PeriodStats(
            n_jobs=len(jobs), policy=p.policy,
            predicted_makespan=p.predicted_makespan,
            wall_makespan=report.wall_makespan,
            total_accuracy=p.schedule.total_accuracy,
            plan_seconds=p.plan_seconds,
            violation=max(0.0, report.wall_makespan / self.T - 1.0),
            replanned=report.replanned, profile_updated=updated)
        self.history.append(stats)
        return stats

    def _audit(self, p: Plan, report: ExecutionReport,
               job_classes: np.ndarray) -> bool:
        """Straggler detection: compare measured tier wall time against the
        profile's prediction; EMA-update the profile on drift."""
        pred_ed = p.schedule.ed_makespan
        if pred_ed <= 0 or report.replanned:
            return False
        ratio = report.ed_wall / max(pred_ed, 1e-9)
        if ratio > self.straggler_threshold:
            self.profile = dataclasses.replace(
                self.profile,
                p_ed=self.profile.p_ed * (
                    (1 - self.ema) + self.ema * ratio))
            return True
        return False
