"""Period-T serving loop (the paper's deployment model, §III-C).

Every period: drain the request queue, build the OffloadInstance from the
current TierProfile, plan (AMR^2 / AMDP / dual), execute across the tiers,
then *audit*: if measured per-model latency drifts from the profile by more
than `straggler_threshold`, the profile is re-measured (EMA update) so the
next period's p_ij reflect the degraded tier — the straggler-mitigation
loop.  An ES outage inside a period triggers the fallback replan.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from ..api import Problem, Solution, solve
from ..core.types import OffloadInstance
from .executor import ExecutionReport, execute
from .profile import TierProfile


def audit_profile(profile: TierProfile, predicted_ed: float,
                  measured_ed: float, *, threshold: float = 1.5,
                  ema: float = 0.5):
    """Shared straggler audit (single-device runtime AND fleet engine).

    When measured ED wall time drifts past ``threshold x`` the profile's
    prediction, return a profile whose p_ed is EMA-rescaled toward the
    observed slowdown: ``p_ed * ((1 - ema) + ema * ratio)``.

    Returns ``(profile, updated)``; the input profile is never mutated.
    """
    if predicted_ed <= 0:
        return profile, False
    ratio = measured_ed / max(predicted_ed, 1e-9)
    if ratio <= threshold:
        return profile, False
    scaled = dataclasses.replace(
        profile, p_ed=profile.p_ed * ((1 - ema) + ema * ratio))
    return scaled, True


@dataclasses.dataclass
class PeriodStats:
    n_jobs: int
    policy: str
    predicted_makespan: float
    wall_makespan: float
    total_accuracy: float
    plan_seconds: float
    violation: float
    replanned: bool
    profile_updated: bool
    # samples that fell through execution with no result (short apply-fn
    # output, unrouted job) — see executor.EXEC_DROPPED; consistent with
    # the fleet engine's n_dropped ladder metric
    n_dropped: int = 0


class ServingRuntime:
    def __init__(self, profile: TierProfile, apply_ed: List[Callable],
                 apply_es: Callable, *, T: float, policy: str = "auto",
                 straggler_threshold: float = 1.5, ema: float = 0.5):
        self.profile = profile
        self.apply_ed = apply_ed
        self.apply_es = apply_es
        self.T = T
        self.policy = policy
        self.straggler_threshold = straggler_threshold
        self.ema = ema
        self.history: List[PeriodStats] = []

    def run_period(self, jobs: List[object], job_classes: np.ndarray, *,
                   es_fail: bool = False) -> PeriodStats:
        inst = self.profile.instance(job_classes, self.T)
        sol = solve(Problem.from_instance(inst), policy=self.policy)
        report = execute(sol, self.apply_ed, self.apply_es, jobs,
                         es_fail=es_fail)
        updated = self._audit(sol, report, job_classes)
        stats = PeriodStats(
            n_jobs=len(jobs), policy=sol.solver_name,
            predicted_makespan=float(sol.makespan),
            wall_makespan=report.wall_makespan,
            total_accuracy=float(sol.accuracy),
            plan_seconds=sol.plan_seconds,
            violation=max(0.0, report.wall_makespan / self.T - 1.0),
            replanned=report.replanned, profile_updated=updated,
            n_dropped=report.n_dropped)
        self.history.append(stats)
        return stats

    def _audit(self, sol: Solution, report: ExecutionReport,
               job_classes: np.ndarray) -> bool:
        """Straggler detection: compare measured tier wall time against the
        profile's prediction; EMA-update the profile on drift.  Replanned
        periods are skipped — their measured walls reflect the fallback
        schedule, not the profile being audited.

        ``sol`` is an api `Solution` (or a legacy `Plan`, for callers still
        on the shims)."""
        if report.replanned:
            return False
        predicted_ed = (sol.schedule.ed_makespan if hasattr(sol, "schedule")
                        else float(sol.ed_makespan))
        self.profile, updated = audit_profile(
            self.profile, predicted_ed, report.ed_wall,
            threshold=self.straggler_threshold, ema=self.ema)
        return updated
