"""Tier-1 collection shim for optional `hypothesis`.

Five test modules use hypothesis property tests.  When the package is
installed (see requirements-dev.txt) they run for real; when it is absent
(minimal containers) this conftest installs a stub module BEFORE test
collection so the modules still import — every `@given` test then skips
with an explicit reason instead of breaking collection for the whole suite.
"""
from __future__ import annotations

import sys
import types


def _install_hypothesis_stub() -> None:
    import pytest

    hyp = types.ModuleType("hypothesis")
    strategies = types.ModuleType("hypothesis.strategies")

    class _Strategy:
        """Placeholder for any `st.<strategy>(...)` call."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    def _any_strategy(*args, **kwargs):
        return _Strategy()

    # st.integers, st.floats, st.lists, ... all resolve to stub strategies
    strategies.__getattr__ = lambda name: _any_strategy  # PEP 562

    def given(*_args, **_kwargs):
        def decorate(fn):
            # Zero-arg wrapper: pytest must not see the hypothesis-injected
            # parameters (e.g. `seed`) or it would demand fixtures for them.
            def skipper():
                pytest.skip("hypothesis not installed (see "
                            "requirements-dev.txt)")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            skipper.__module__ = fn.__module__
            skipper.pytestmark = list(getattr(fn, "pytestmark", []))
            return skipper
        return decorate

    def settings(*_args, **_kwargs):
        def decorate(fn):
            return fn
        return decorate

    hyp.given = given
    hyp.settings = settings
    hyp.assume = lambda condition: True
    hyp.strategies = strategies
    hyp.HealthCheck = _Strategy()
    hyp.example = lambda *a, **k: (lambda fn: fn)
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strategies


try:
    import hypothesis  # noqa: F401  (real package present: nothing to do)
except ModuleNotFoundError:
    _install_hypothesis_stub()
