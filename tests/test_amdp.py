"""AMDP / CCKP — optimality (Theorem 3) and structure (Lemma 3) tests."""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (amdp, amdp_hetero_comm, brute_force, solve_cckp,
                        OffloadInstance)

RES = 1e-2  # times in these tests are exact multiples of the resolution


def _identical_int_instance(seed, n=None, m=None, T=None):
    """Identical jobs with times that are exact multiples of RES so DP
    integerization is lossless and brute force is an exact oracle."""
    rng = np.random.default_rng(seed)
    n = n or int(rng.integers(2, 9))
    m = m or int(rng.integers(1, 4))
    p_ed = rng.integers(1, 30, size=m).astype(np.float64) * RES
    p_ed.sort()
    p_es = float(rng.integers(5, 40)) * RES
    acc = np.sort(rng.uniform(0.2, 0.99, size=m + 1))
    T = T if T is not None else float(rng.integers(10, 120)) * RES
    return OffloadInstance(p_ed=np.tile(p_ed, (n, 1)),
                           p_es=np.full(n, p_es), acc=acc, T=T)


# ------------------------------------------------------------- Theorem 3 --
@pytest.mark.parametrize("seed", range(15))
def test_amdp_matches_brute_force(seed):
    inst = _identical_int_instance(seed)
    opt = brute_force(inst)
    sched = amdp(inst, resolution=RES)
    if opt is None:
        assert sched.status == "infeasible"
        return
    assert sched.status == "ok"
    assert sched.total_accuracy == pytest.approx(opt.total_accuracy, abs=1e-9)
    assert sched.ed_makespan <= inst.T + 1e-9
    assert sched.es_makespan <= inst.T + 1e-9


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 100_000))
def test_amdp_optimal_property(seed):
    inst = _identical_int_instance(seed)
    opt = brute_force(inst)
    sched = amdp(inst, resolution=RES)
    if opt is None:
        assert sched.status == "infeasible"
    else:
        assert sched.total_accuracy == pytest.approx(opt.total_accuracy,
                                                     abs=1e-9)


# --------------------------------------------------------------- Lemma 3 --
@pytest.mark.parametrize("seed", range(8))
def test_lemma3_es_count(seed):
    inst = _identical_int_instance(seed)
    sched = amdp(inst, resolution=RES)
    if sched.status != "ok":
        return
    n_c = min(inst.n, int(math.floor(inst.T / inst.p_es[0] + 1e-12)))
    assert int((sched.assignment == inst.m).sum()) == n_c


# ------------------------------------------------------------------ CCKP --
def _cckp_brute(p, a, T_int, n_l):
    m = len(p)
    best = -math.inf
    bestc = None

    def rec(i, rem, t, v, counts):
        nonlocal best, bestc
        if i == m:
            if rem == 0 and v > best:
                best, bestc = v, counts.copy()
            return
        for q in range(rem + 1):
            tt = t + q * p[i]
            if tt > T_int:
                break
            counts.append(q)
            rec(i + 1, rem - q, tt, v + q * a[i], counts)
            counts.pop()

    rec(0, n_l, 0, 0.0, [])
    return bestc, best


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 100_000), m=st.integers(1, 4),
       n_l=st.integers(1, 8), T_int=st.integers(1, 60))
def test_cckp_dp_vs_brute(seed, m, n_l, T_int):
    rng = np.random.default_rng(seed)
    p = rng.integers(1, 12, size=m).astype(np.int64)
    a = rng.uniform(0.1, 1.0, size=m)
    counts, val = solve_cckp(p, a, T_int, n_l)
    bc, bv = _cckp_brute(list(p), list(a), T_int, n_l)
    if bc is None:
        assert counts is None
    else:
        assert counts is not None
        assert val == pytest.approx(bv, abs=1e-5)
        assert counts.sum() == n_l
        assert (counts * p).sum() <= T_int


# -------------------------------------------------- heterogeneous comm ---
def test_amdp_hetero_comm_orders_by_comm():
    p_ed = np.array([0.02, 0.05])
    acc = np.array([0.4, 0.6, 0.9])
    comm = np.array([0.5, 0.1, 0.3, 0.9, 0.05])
    sched = amdp_hetero_comm(p_ed, p_es_proc=0.2, comm=comm, acc=acc, T=1.0)
    offloaded = set(np.nonzero(sched.assignment == 2)[0])
    # ES budget 1.0 fits comm 0.05+0.2, 0.1+0.2, 0.3+0.2 = 1.05 > 1 -> only 2
    assert offloaded == {4, 1}
    assert sched.es_makespan <= 1.0 + 1e-9
    assert sched.ed_makespan <= 1.0 + 1e-9


def test_amdp_all_offload_when_es_fast():
    inst = OffloadInstance(p_ed=np.tile([0.1], (4, 1)), p_es=np.full(4, 0.01),
                           acc=np.array([0.5, 0.9]), T=1.0)
    sched = amdp(inst)
    assert (sched.assignment == 1).all()


def test_amdp_rejects_non_identical():
    inst = OffloadInstance(p_ed=np.array([[0.1], [0.2]]),
                           p_es=np.array([0.1, 0.1]),
                           acc=np.array([0.5, 0.9]), T=1.0)
    with pytest.raises(ValueError):
        amdp(inst)
