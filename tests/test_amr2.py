"""AMR^2 — validates the paper's Lemma 1, Theorems 1 & 2, Corollary 1,
plus optimality of the sub-ILP solver against the literal Algorithm 2."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (amr2, algorithm2_case_tree, brute_force,
                        fractional_jobs, greedy_rra, paper_instance,
                        random_instance, solve_lp_relaxation, solve_sub_ilp,
                        OffloadInstance)


def _small_instances():
    out = []
    for seed in range(12):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(3, 8))
        m = int(rng.integers(1, 4))
        T = float(rng.uniform(0.2, 2.0))
        out.append(random_instance(n, m, T, seed=seed))
    for seed, T in [(0, 0.5), (1, 1.0), (2, 2.0), (3, 4.0)]:
        out.append(paper_instance(6, T=T, seed=seed))
    return out


SMALL = _small_instances()


# -------------------------------------------------------------- Lemma 1 ---
@pytest.mark.parametrize("seed", range(10))
def test_lemma1_at_most_two_fractional(seed):
    inst = random_instance(20, 3, T=1.0, seed=seed)
    xbar, _, status, _ = solve_lp_relaxation(inst)
    if status != 0:
        pytest.skip("infeasible relaxation")
    assert len(fractional_jobs(xbar)) <= 2


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 30),
       m=st.integers(1, 5))
def test_lemma1_property(seed, n, m):
    rng = np.random.default_rng(seed)
    inst = random_instance(n, m, T=float(rng.uniform(0.1, 4.0)), seed=seed)
    xbar, _, status, _ = solve_lp_relaxation(inst)
    if status != 0:
        return
    assert len(fractional_jobs(xbar)) <= 2
    # and the relaxation respects its own constraints
    assert np.allclose(xbar.sum(axis=1), 1.0, atol=1e-5)


# ---------------------------------------------------------- Theorem 1/2 ---
@pytest.mark.parametrize("idx", range(len(SMALL)))
def test_theorems_vs_oracle(idx):
    inst = SMALL[idx]
    opt = brute_force(inst)
    sched = amr2(inst)
    if opt is None:
        return  # P infeasible; theorems are conditioned on feasibility
    # Theorem 1: makespan <= 2T
    assert sched.ed_makespan <= 2 * inst.T + 1e-9
    assert sched.es_makespan <= 2 * inst.T + 1e-9
    # Theorem 2: A* <= A† + 2(a_{m+1} - a_1)
    gap = 2 * (inst.acc[-1] - inst.acc[0])
    assert opt.total_accuracy <= sched.total_accuracy + gap + 1e-6
    # LP upper bound dominates the optimum
    assert sched.lp_accuracy is not None
    assert sched.lp_accuracy >= opt.total_accuracy - 1e-6


@pytest.mark.parametrize("idx", range(len(SMALL)))
def test_corollary1(idx):
    inst = SMALL[idx]
    if not np.all(inst.p_es <= inst.T):
        pytest.skip("corollary precondition: all ES times within T")
    opt = brute_force(inst)
    if opt is None:
        return
    sched = amr2(inst)
    gap = inst.acc[-1] - inst.acc[0]
    assert opt.total_accuracy <= sched.total_accuracy + gap + 1e-6


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_theorem1_property(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 7))
    m = int(rng.integers(1, 3))
    inst = random_instance(n, m, T=float(rng.uniform(0.2, 3.0)), seed=seed)
    opt = brute_force(inst)
    if opt is None:
        return
    sched = amr2(inst)
    assert max(sched.ed_makespan, sched.es_makespan) <= 2 * inst.T + 1e-9
    assert (opt.total_accuracy
            <= sched.total_accuracy + 2 * (inst.acc[-1] - inst.acc[0]) + 1e-6)


# -------------------------------------------------------------- sub-ILP ---
@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000), m=st.integers(1, 5))
def test_sub_ilp_enumeration_is_optimal_vs_case_tree(seed, m):
    """Where the paper's Algorithm-2 case tree yields an assignment, the
    enumerated sub-ILP must achieve at least the same accuracy; both must be
    feasible under the fresh per-tier budgets."""
    inst = random_instance(2, m, T=float(np.random.default_rng(seed).uniform(0.05, 2.0)),
                           seed=seed)
    enum = solve_sub_ilp(inst, 0, 1)
    tree = algorithm2_case_tree(inst, 0, 1)
    if enum is None:
        assert tree is None
        return

    def check(pair):
        i1, i2 = pair
        ed = (inst.p_ed[0, i1] if i1 < inst.m else 0.0) + \
             (inst.p_ed[1, i2] if i2 < inst.m else 0.0)
        es = (inst.p_es[0] if i1 == inst.m else 0.0) + \
             (inst.p_es[1] if i2 == inst.m else 0.0)
        assert ed <= inst.T + 1e-9 and es <= inst.T + 1e-9
        return inst.acc[i1] + inst.acc[i2]

    v_enum = check(enum)
    if tree is not None:
        v_tree = check(tree)
        assert v_enum >= v_tree - 1e-9


# ------------------------------------------------------------ greedy cmp --
def test_amr2_beats_greedy_on_paper_instances():
    """Paper §VII: AMR^2's total accuracy exceeds Greedy-RRA (on average by
    ~40%); we assert it is never materially worse across the paper grid."""
    wins, total = 0, 0
    for T in (0.5, 1.0, 2.0, 4.0):
        for seed in range(5):
            inst = paper_instance(30, T=T, seed=seed)
            a = amr2(inst).total_accuracy
            g = greedy_rra(inst).total_accuracy
            total += 1
            wins += a >= g - 1e-9
    assert wins == total


def test_infeasible_instance_flagged():
    inst = OffloadInstance(p_ed=np.full((3, 2), 10.0), p_es=np.full(3, 10.0),
                           acc=np.array([0.3, 0.5, 0.9]), T=1.0)
    sched = amr2(inst)
    assert sched.status in ("infeasible", "fallback")


# ---------------------------------------------------------------------------
# round_relaxation_jnp: the traced rounding vs the NumPy batched rounding
# ---------------------------------------------------------------------------
def test_round_relaxation_jnp_matches_numpy_batched():
    """The traced rounding must reproduce `round_relaxation_batch` case
    for case — zero/one/two fractional rows, infeasible and unsolved
    status codes — on real LP outputs across many instances."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.core.amr2 import (round_relaxation_batch,
                                 round_relaxation_jnp)
    from repro.core.lp import INFEASIBLE as LP_INFEASIBLE
    from repro.core.lp import ITERATION_LIMIT
    from repro.core.types import InstanceBatch
    from repro.core import solve_lp_relaxation

    insts = [random_instance(6, 2, T=float(0.3 + 0.2 * s), seed=100 + s)
             for s in range(10)]
    batch = InstanceBatch.stack(insts)
    xbar = np.zeros((len(insts), 6, 3))
    status = np.zeros(len(insts), dtype=np.int64)
    for i, inst in enumerate(insts):
        xb, _, st, _ = solve_lp_relaxation(inst, backend="numpy")
        xbar[i], status[i] = xb, st
    # exercise the non-OPTIMAL paths too
    status[3] = LP_INFEASIBLE
    status[7] = ITERATION_LIMIT
    ref_assign, ref_status, ref_nf = round_relaxation_batch(
        batch, xbar, status, on_error="mark")
    with enable_x64():
        got = jax.jit(round_relaxation_jnp)(
            jnp.asarray(batch.p_ed), jnp.asarray(batch.p_es),
            jnp.asarray(batch.acc), jnp.asarray(batch.T),
            jnp.asarray(xbar), jnp.asarray(status))
    assign, sched_status, nf = [np.asarray(o) for o in got]
    np.testing.assert_array_equal(assign, ref_assign)
    np.testing.assert_array_equal(sched_status, ref_status)
    np.testing.assert_array_equal(nf, ref_nf)
    # the suite exercised at least one fractional lane
    assert (ref_nf > 0).any()


def test_round_relaxation_jnp_forced_fractional_rows():
    """Hand-built xbar rows force the one- and two-fractional branches
    (including the infeasible-pair fallback)."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.core.amr2 import (round_relaxation_batch,
                                 round_relaxation_jnp)
    from repro.core.types import InstanceBatch

    insts = [random_instance(4, 2, T=0.8, seed=s) for s in range(4)]
    # lane 3: nothing fits -> rounding falls back to argmin p_ed
    tiny = insts[3]
    insts[3] = OffloadInstance(p_ed=tiny.p_ed + 10.0, p_es=tiny.p_es + 10.0,
                               acc=tiny.acc, T=tiny.T)
    batch = InstanceBatch.stack(insts)
    xbar = np.zeros((4, 4, 3))
    xbar[:, :, 0] = 1.0                     # integral base
    xbar[1, 2] = [0.5, 0.5, 0.0]           # one fractional row
    xbar[2, 0] = [0.4, 0.6, 0.0]           # two fractional rows
    xbar[2, 3] = [0.0, 0.3, 0.7]
    xbar[3, 1] = [0.5, 0.5, 0.0]           # fractional AND infeasible fit
    xbar[3, 2] = [0.9, 0.0, 0.1]
    status = np.zeros(4, dtype=np.int64)
    ref_assign, ref_status, ref_nf = round_relaxation_batch(
        batch, xbar, status)
    with enable_x64():
        got = jax.jit(round_relaxation_jnp)(
            jnp.asarray(batch.p_ed), jnp.asarray(batch.p_es),
            jnp.asarray(batch.acc), jnp.asarray(batch.T),
            jnp.asarray(xbar), jnp.asarray(status))
    assign, sched_status, nf = [np.asarray(o) for o in got]
    np.testing.assert_array_equal(assign, ref_assign)
    np.testing.assert_array_equal(sched_status, ref_status)
    np.testing.assert_array_equal(nf, ref_nf)
    assert ref_nf.tolist() == [0, 1, 2, 2]
