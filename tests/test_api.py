"""`repro.api`: registry capabilities, solve() parity with the scalar
oracles and the legacy shims, pytree round-trips, FleetConfig construction,
and the deprecation contract of the old planner entry points."""
import warnings

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import api
from repro.core import (InstanceBatch, identical_instance, paper_instance,
                        random_instance)
from repro.serving import (FleetConfig, FleetEngine, RequestQueue, make_fleet,
                           planner)

# one (B, n, m) shape shared across the jax-path tests -> a single jit trace
N, M = 6, 2
T = 1.5


def _hetero(seed, n=N):
    return paper_instance(n, T=T, seed=seed)


def _ident(seed, n=N):
    return identical_instance(n, M, T=1.0 + 0.1 * (seed % 5), seed=seed)


def _problems(insts):
    return [api.Problem.from_instance(i) for i in insts]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_lists_all_solvers():
    assert api.solver_names() == ["amdp", "amr2", "dual", "greedy",
                                  "hi_bandit", "hi_threshold", "lp",
                                  "routed"]
    infos = api.solvers()
    assert infos["amdp"].exact_on_identical
    assert not infos["greedy"].batched
    assert infos["lp"].bound_only and not infos["lp"].supports_es_disabled
    for name in ("amr2", "amdp", "dual", "lp"):
        assert infos[name].batched
        assert not infos[name].online
    for name in ("hi_threshold", "hi_bandit"):
        assert infos[name].online and infos[name].batched
        assert not infos[name].supports_es_disabled
    # the table renders one row per solver
    assert api.solver_table().count("\n") == len(infos) + 1


def test_unknown_policy_raises():
    with pytest.raises(ValueError, match="unknown solver"):
        api.solve(_problems([_hetero(0)])[0], policy="simulated-annealing")


def test_solve_rejects_foreign_types():
    with pytest.raises(TypeError, match="solve\\(\\) wants"):
        api.solve(np.zeros((3, 2)))


# ---------------------------------------------------------------------------
# single-problem solve: every policy, parity with the scalar planner
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy,solver", [
    ("auto", "amr2"), ("amr2", "amr2"), ("amdp", "amr2"),  # amdp falls back
    ("dual", "dual"), ("greedy", "greedy")])
def test_solve_single_policies(policy, solver):
    sol = api.solve(_problems([_hetero(1)])[0], policy=policy)
    assert sol.solver == solver
    assert sol.plan_seconds > 0
    sched = sol.to_schedule()
    assert sched.total_accuracy == pytest.approx(float(sol.accuracy))
    assert sched.makespan == pytest.approx(float(sol.makespan))


def test_solve_auto_routes_identical_to_amdp():
    sol = api.solve(_problems([_ident(0)])[0])
    assert sol.solver == "amdp" and sol.status_name == "ok"


def test_lp_is_an_upper_bound():
    from repro.core import brute_force
    inst = _hetero(2)
    p = _problems([inst])[0]
    bound = api.solve(p, policy="lp")
    exact = brute_force(inst)                   # feasible integral optimum
    assert bound.status_name == "bound"
    assert float(bound.lp_accuracy) >= exact.total_accuracy - 1e-9


# ---------------------------------------------------------------------------
# fleet solve: batched vs sequential oracle, es_disabled, empty input
# ---------------------------------------------------------------------------
def test_solve_fleet_matches_sequential_oracle():
    fp = api.FleetProblem.from_batch(
        InstanceBatch.stack([_hetero(10 + s) for s in range(4)]))
    for policy in ("auto", "dual"):
        fast = api.solve(fp, policy=policy, backend="jax")
        slow = api.solve(fp, policy=policy, backend="numpy")
        np.testing.assert_array_equal(fast.assignment, slow.assignment)
        np.testing.assert_array_equal(fast.status, slow.status)


def test_solve_fleet_auto_mixes_solvers():
    insts = [_ident(3), _hetero(3)]
    fp = api.FleetProblem.from_batch(InstanceBatch.stack(insts))
    sol = api.solve(fp)
    assert list(sol.solver) == ["amdp", "amr2"]
    assert sol.solver_name == "mixed"


def test_solve_fleet_es_disabled_keeps_everything_local():
    insts = [_hetero(20 + s) for s in range(4)]
    fp = api.FleetProblem.from_batch(InstanceBatch.stack(insts))
    sol = api.solve(fp, es_disabled=True)
    assert (sol.assignment < fp.m).all()
    for b, inst in enumerate(insts):
        ed = float(inst.p_ed[np.arange(inst.n), sol.assignment[b]].sum())
        assert ed <= inst.T + 1e-9


def test_solve_greedy_jax_backend_raises():
    fp = api.FleetProblem.from_batch(
        InstanceBatch.stack([_hetero(0), _hetero(1)]))
    with pytest.raises(ValueError, match="no batched path"):
        api.solve(fp, policy="greedy")          # fleet default backend: jax
    with pytest.raises(ValueError, match="no batched path"):
        api.solve_many(_problems([_hetero(0)]), policy="greedy",
                       backend="jax")
    seq = api.solve(fp, policy="greedy", backend="numpy")
    assert set(np.atleast_1d(seq.solver)) == {"greedy"}


def test_solver_opts_survive_dispatch_rerouting():
    """Solver-specific options must not crash when dispatch reroutes to a
    different solver (amdp→amr2 fallback, auto split, es-disabled rest)."""
    het = _problems([_hetero(0)])[0]
    assert api.solve(het, policy="amdp", impl="jnp").solver == "amr2"
    mix = api.FleetProblem.from_batch(
        InstanceBatch.stack([_ident(0), _hetero(0)]))
    assert list(api.solve(mix, policy="amdp", impl="jnp").solver) == \
        ["amdp", "amr2"]
    api.solve(mix, policy="amdp", impl="jnp", es_disabled=True)
    with pytest.raises(TypeError, match="does not accept"):
        api.solve(het, policy="amr2", imp="pallas")     # typo'd option


def test_capability_flags_are_enforced():
    het = _problems([_hetero(0)])[0]
    with pytest.raises(ValueError, match="supports_es_disabled"):
        api.solve(het, policy="lp", es_disabled=True)
    with pytest.raises(ValueError, match="bound-only"):
        FleetEngine.from_config(FleetConfig(n_devices=2, T=1.0,
                                            policy="lp"))


def test_new_registry_entry_gets_batched_dispatch():
    """The advertised extension path: a @register_solver entry with
    solve_fleet must be dispatched through it (not the sequential loop,
    not rerouted to amr2) without any front-door edits."""
    from repro.api.registry import _REGISTRY

    calls = {"fleet": 0}

    @api.register_solver("test-echo", batched=True,
                         exact_on_identical=False,
                         supports_es_disabled=True,
                         description="test-only")
    class EchoSolver:
        def solve_one(self, problem, *, backend="numpy"):
            return api.Solution(problem=problem,
                                assignment=np.zeros(problem.n, np.int64),
                                status=np.int64(0), solver="test-echo")

        def solve_fleet(self, fleet):
            calls["fleet"] += 1
            return api.Solution(
                problem=fleet,
                assignment=np.zeros((len(fleet), fleet.n), np.int64),
                status=np.zeros(len(fleet), np.int64),
                solver=np.full(len(fleet), "test-echo", object))

    try:
        assert "test-echo" in api.batched_policies()
        fp = api.FleetProblem.from_batch(
            InstanceBatch.stack([_hetero(0), _hetero(1)]))
        sol = api.solve(fp, policy="test-echo", backend="jax")
        assert calls["fleet"] == 1                  # batched path, once
        assert set(np.atleast_1d(sol.solver)) == {"test-echo"}
        sols = api.solve_many(_problems([_hetero(0), _hetero(1)]),
                              policy="test-echo", backend="jax")
        assert calls["fleet"] == 2
        assert all(s.solver == "test-echo" for s in sols)
    finally:
        _REGISTRY.pop("test-echo", None)


def test_shims_reject_bound_only_policy():
    """Legacy planner contract: plan(policy="lp") raised ValueError and
    still must — bound-only pseudo-schedules never flow through the shims."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        with pytest.raises(ValueError, match="bound-only"):
            planner.plan(_hetero(0), policy="lp")
        with pytest.raises(ValueError, match="bound-only"):
            planner.plan_batch([_hetero(0)], policy="lp")


def test_solve_empty_inputs():
    assert api.solve_many([]) == []
    empty = api.FleetProblem(p_ed=np.zeros((0, N, M)),
                             p_es=np.zeros((0, N)),
                             acc=np.zeros((0, M + 1)), T=np.zeros(0),
                             real_mask=np.zeros((0, N), bool))
    sol = api.solve(empty)
    assert sol.assignment.shape == (0, N)
    sol_es = api.solve(empty, es_disabled=True)
    assert sol_es.assignment.shape == (0, N)


# ---------------------------------------------------------------------------
# hypothesis: registry output bit-matches the legacy entry points
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 100_000))
def test_registry_matches_legacy_property(seed):
    """Two properties per policy, bit-for-bit: (a) the batched registry
    path (`solve_many`, backend="jax") reproduces the *scalar oracle* path
    (`plan(..., backend="numpy")` → the per-device NumPy/DP solvers), the
    genuinely independent implementation pair; (b) the legacy shims
    (`plan_batch`/`replan_without_es_batch`) stay faithful delegates —
    same assignments, solver tags, and status codes as calling the
    registry directly."""
    insts = [_hetero(seed + i) for i in range(3)] + [_ident(seed)]
    probs = _problems(insts)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        for policy in ("auto", "amr2", "amdp", "dual", "greedy"):
            backend = "numpy" if policy == "greedy" else "jax"
            legacy = planner.plan_batch(insts, policy=policy,
                                        backend=backend)
            sols = api.solve_many(probs, policy=policy, backend=backend)
            for sol, pl in zip(sols, legacy):
                assert sol.solver_name == pl.policy
                np.testing.assert_array_equal(sol.assignment,
                                              pl.schedule.assignment)
            # scalar path parity: one-off solves match the batch
            for sol, inst in zip(sols, insts):
                one = planner.plan(inst, policy=policy, backend="numpy")
                np.testing.assert_array_equal(sol.assignment,
                                              one.schedule.assignment)
        # batched ES-disabled replan parity
        batch = InstanceBatch.stack(insts[:3])
        legacy_fp = planner.replan_without_es_batch(batch, policy="auto")
        sol = api.solve(api.FleetProblem.from_batch(batch), policy="auto",
                        es_disabled=True)
        np.testing.assert_array_equal(sol.assignment, legacy_fp.assignment)
        np.testing.assert_array_equal(np.asarray(sol.status),
                                      legacy_fp.status)


# ---------------------------------------------------------------------------
# pytree registration (acceptance criterion)
# ---------------------------------------------------------------------------
def test_problem_pytree_roundtrip():
    p = _problems([_hetero(0)])[0]
    leaves, treedef = jax.tree_util.tree_flatten(p)
    assert len(leaves) == 4
    q = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(q, api.Problem)
    np.testing.assert_array_equal(q.p_ed, p.p_ed)
    np.testing.assert_array_equal(q.p_es, p.p_es)
    assert q.T == p.T


def test_fleet_problem_pytree_roundtrip():
    fp = api.FleetProblem.from_batch(
        InstanceBatch.stack([_hetero(s) for s in range(3)]))
    leaves, treedef = jax.tree_util.tree_flatten(fp)
    assert len(leaves) == 5                     # incl. real_mask
    fq = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(fq, api.FleetProblem)
    for f in ("p_ed", "p_es", "acc", "T", "real_mask"):
        np.testing.assert_array_equal(getattr(fq, f), getattr(fp, f))
    # pytree-ness is what makes the fleet shardable: tree_map must work
    doubled = jax.tree_util.tree_map(lambda x: x, fp)
    assert isinstance(doubled, api.FleetProblem)


def test_fleet_problem_pack_pads_with_phantoms():
    probs = _problems([_hetero(0, n=4), _hetero(1, n=6)])
    fp = api.FleetProblem.from_problems(probs)
    assert fp.n == 8                            # next_pow2(6)
    assert fp.real_mask.sum() == 10
    assert (fp.p_es[~fp.real_mask] == 0).all()
    with pytest.raises(ValueError, match="share the model count"):
        api.FleetProblem.from_problems(
            [probs[0], api.Problem(p_ed=np.ones((2, 3)), p_es=np.ones(2),
                                   acc=np.linspace(0.1, 0.9, 4), T=1.0)])


# ---------------------------------------------------------------------------
# FleetConfig / FleetEngine.from_config (acceptance criterion)
# ---------------------------------------------------------------------------
def test_from_config_reproduces_manual_construction():
    cfg = FleetConfig(n_devices=6, T=1.2, n_servers=1, rate=8.0,
                      batch_max=8, seed=3, horizon=8, backend="numpy")
    via_config = FleetEngine.from_config(cfg)
    manual = FleetEngine(
        make_fleet(6, seed=3, horizon=8),
        RequestQueue(6, (128, 512, 1024), rate=8.0, batch_max=8, seed=3),
        n_servers=1, T=1.2, backend="numpy")
    for _ in range(3):
        sv, sr = via_config.run_period(), manual.run_period()
        for f in ("n_jobs", "n_violations", "n_offloading",
                  "n_backpressured", "n_outage", "n_straggler_updates",
                  "backlog"):
            assert getattr(sv, f) == getattr(sr, f), f
        assert sv.total_accuracy == pytest.approx(sr.total_accuracy,
                                                  abs=1e-9)


def test_from_config_explicit_devices_and_mismatch():
    specs = make_fleet(4, seed=0)
    cfg = FleetConfig(n_devices=4, T=1.0, devices=specs)
    eng = FleetEngine.from_config(cfg)
    assert len(eng.devices) == 4
    with pytest.raises(ValueError, match="DeviceSpecs"):
        FleetEngine.from_config(
            FleetConfig(n_devices=3, T=1.0, devices=specs))


# ---------------------------------------------------------------------------
# deprecation contract of the legacy shims
# ---------------------------------------------------------------------------
def test_shims_warn_exactly_once():
    insts = [_hetero(0) for _ in range(2)]
    batch = InstanceBatch.stack(insts)
    cases = [
        ("plan", lambda: planner.plan(insts[0])),
        ("plan_batch", lambda: planner.plan_batch(insts, backend="numpy")),
        ("plan_batch_arrays",
         lambda: planner.plan_batch_arrays(batch, backend="numpy")),
        ("replan_without_es", lambda: planner.replan_without_es(insts[0])),
        ("replan_without_es_batch",
         lambda: planner.replan_without_es_batch(batch, backend="numpy")),
    ]
    for name, fn in cases:
        planner._reset_deprecation_warnings()
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            fn()
            fn()
        dep = [w for w in caught
               if issubclass(w.category, DeprecationWarning)
               and f"repro.serving.{name} is deprecated" in str(w.message)]
        assert len(dep) == 1, (name, [str(w.message) for w in caught])
    planner._reset_deprecation_warnings()


def test_shim_results_keep_legacy_types():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        p = planner.plan(_hetero(0))
        assert isinstance(p, planner.Plan)
        ids = np.sort(np.concatenate(list(p.per_model.values())))
        np.testing.assert_array_equal(ids, np.arange(N))
        fp = planner.plan_batch_arrays(
            InstanceBatch.stack([_hetero(0), _hetero(1)]))
        assert isinstance(fp, planner.FleetPlan)
        assert fp.assignment.shape == (2, N)
        assert set(fp.solver) == {"amr2"}
