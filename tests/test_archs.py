"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step with shape + finiteness asserts, one gradient step, and exact
prefill+decode vs teacher-forced forward consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, get_smoke_config
from repro.models import (decode_step, forward, init_params, logits_from_h,
                          loss_fn, prefill)

ARCHS = all_archs()


def _batch(cfg, key, B=2, S=12):
    batch = {"tokens": jax.random.randint(jax.random.fold_in(key, 1),
                                          (B, S), 0, cfg.vocab_size)}
    if cfg.num_patches:
        batch["patch_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2), (B, cfg.num_patches, cfg.d_model),
            jnp.bfloat16)
    if cfg.is_encdec:
        batch["audio_feats"] = jax.random.normal(
            jax.random.fold_in(key, 3), (B, cfg.encoder_seq, cfg.d_model),
            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.key(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)
    h = forward(params, batch, cfg)
    assert h.shape == (2, 12, cfg.d_model)
    logits = logits_from_h(params, h, cfg)
    assert logits.shape == (2, 12, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits[..., :cfg.vocab_size]).all())
    # padded vocab region is masked out
    if cfg.padded_vocab > cfg.vocab_size:
        assert float(logits[..., cfg.vocab_size:].max()) < -1e20


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_decreases_loss(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.key(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)

    @jax.jit
    def step(p):
        loss, g = jax.value_and_grad(lambda q: loss_fn(q, batch, cfg))(p)
        p2 = jax.tree.map(lambda w, gg: w - 0.5 * gg, p, g)
        return loss, p2

    l0, params = step(params)
    assert bool(jnp.isfinite(l0))
    for _ in range(3):
        l1, params = step(params)
    assert bool(jnp.isfinite(l1))
    assert float(l1) < float(l0)   # memorizing one batch must make progress


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops
    key = jax.random.key(0)
    params = init_params(cfg, key)
    B, S, EXTRA = 2, 12, 4
    full = _batch(cfg, key, B, S + EXTRA)
    pre = dict(full)
    pre["tokens"] = full["tokens"][:, :S]
    ref = logits_from_h(params, forward(params, full, cfg), cfg)
    cache, lg = prefill(params, pre, cfg, max_seq=S + EXTRA)
    tol = 0.05 if cfg.num_experts else 1e-3
    if "float8" in cfg.kv_cache_dtype:
        tol = 0.6        # fp8 KV quantisation noise (internvl2 serving cfg)
    assert float(jnp.abs(lg[:, 0] - ref[:, S - 1]).max()) <= tol
    for t in range(EXTRA):
        lg, cache = decode_step(params, full["tokens"][:, S + t:S + t + 1],
                                cache, cfg)
        assert float(jnp.abs(lg[:, 0] - ref[:, S + t]).max()) <= tol
    assert int(cache["index"]) == S + EXTRA


def test_chunked_attention_matches_dense():
    cfg = dataclasses.replace(get_smoke_config("internlm2_20b"),
                              attn_impl="chunked", attn_chunk=4)
    cfg_d = dataclasses.replace(cfg, attn_impl="dense")
    key = jax.random.key(1)
    params = init_params(cfg, key)
    batch = _batch(cfg, key, B=2, S=16)
    h1 = forward(params, batch, cfg)
    h2 = forward(params, batch, cfg_d)
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32), atol=6e-2)


def test_qblock_attention_matches_dense():
    cfg = dataclasses.replace(get_smoke_config("h2o_danube_1_8b"),
                              attn_impl="chunked", attn_chunk=4, q_block=4)
    cfg_d = dataclasses.replace(cfg, attn_impl="dense", q_block=0)
    key = jax.random.key(1)
    params = init_params(cfg, key)
    batch = _batch(cfg, key, B=2, S=16)
    h1 = forward(params, batch, cfg)
    h2 = forward(params, batch, cfg_d)
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32), atol=6e-2)


def test_scaled_variant_ladder():
    from repro.configs import get_config
    cfg = get_config("internlm2_20b")   # analytic only, nothing allocated
    small = cfg.scaled(0.5)
    assert small.d_model <= cfg.d_model
    assert small.param_count() < cfg.param_count()


def test_moe_active_params_less_than_total():
    cfg = get_smoke_config("granite_moe_3b_a800m")
    assert cfg.active_param_count() < cfg.param_count()
