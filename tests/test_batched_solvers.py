"""Batched-solver parity: `amdp_batch` vs the scalar CCKP DP and
`dual_schedule_batch` vs the NumPy Lagrangian oracle — both must reproduce
the per-device solvers bit-for-bit (same integerization, same tie-breaks),
plus the `plan_batch` dual-policy routing."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (InstanceBatch, OffloadInstance, amdp, amdp_batch,
                        dual_schedule, dual_schedule_batch, paper_instance,
                        random_instance)
from repro.serving import plan_batch

RES = 1e-2  # identical-job times are exact multiples -> lossless DP grids
M = 2       # fixed model count: every amdp_batch call shares one jit trace


def _ident(seed, n=None):
    """Identical jobs with integer-multiple times (as in test_amdp)."""
    rng = np.random.default_rng(seed)
    n = n or int(rng.integers(2, 9))
    p_ed = np.sort(rng.integers(1, 30, size=M).astype(np.float64)) * RES
    p_es = float(rng.integers(5, 40)) * RES
    acc = np.sort(rng.uniform(0.2, 0.99, size=M + 1))
    T = float(rng.integers(10, 120)) * RES
    return OffloadInstance(p_ed=np.tile(p_ed, (n, 1)),
                           p_es=np.full(n, p_es), acc=acc, T=T)


# ---------------------------------------------------------------------------
# amdp_batch vs scalar amdp
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", range(4))
def test_amdp_batch_matches_scalar(seed):
    insts = [_ident(seed * 10 + i) for i in range(5)]
    scheds = amdp_batch(insts, resolution=RES)
    for sched, inst in zip(scheds, insts):
        ref = amdp(inst, resolution=RES)
        assert sched.status == ref.status
        assert sched.solver == "amdp"
        np.testing.assert_array_equal(sched.assignment, ref.assignment)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100_000))
def test_amdp_batch_parity_property(seed):
    insts = [_ident(seed + i) for i in range(4)]
    scheds = amdp_batch(insts, resolution=RES)
    for sched, inst in zip(scheds, insts):
        np.testing.assert_array_equal(
            sched.assignment, amdp(inst, resolution=RES).assignment)


def test_amdp_batch_pallas_matches_scalar():
    """impl="pallas" routes through the cckp_dp kernel (interpret mode off
    TPU) with devices subgrouped by their static integerized p vector."""
    shared = _ident(3, n=6)
    other = _ident(11, n=6)           # different p -> different subgroup
    insts = [shared, shared, other]
    scheds = amdp_batch(insts, resolution=RES, impl="pallas")
    for sched, inst in zip(scheds, insts):
        np.testing.assert_array_equal(
            sched.assignment, amdp(inst, resolution=RES).assignment)


def test_amdp_batch_rejects_heterogeneous():
    with pytest.raises(ValueError, match="identical"):
        amdp_batch([paper_instance(6, T=1.5, seed=0)])


def test_amdp_batch_accepts_instance_batch_and_all_es():
    # p_es tiny -> Lemma 3 sends everything to the ES without touching the DP
    inst = OffloadInstance(p_ed=np.tile([0.1], (4, 1)),
                           p_es=np.full(4, 0.01),
                           acc=np.array([0.5, 0.9]), T=1.0)
    batch = InstanceBatch.stack([inst, inst])
    for sched in amdp_batch(batch):
        assert (sched.assignment == 1).all()
        assert sched.status == "ok"


# ---------------------------------------------------------------------------
# dual_schedule_batch vs NumPy dual_schedule
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", range(4))
def test_dual_batch_matches_numpy_oracle(seed):
    insts = [random_instance(10, 3, T=0.4 + 0.2 * b, seed=seed * 7 + b)
             for b in range(5)]
    scheds = dual_schedule_batch(insts)
    for sched, inst in zip(scheds, insts):
        ref = dual_schedule(inst)
        assert sched.status == ref.status
        np.testing.assert_array_equal(sched.assignment, ref.assignment)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100_000))
def test_dual_batch_parity_property(seed):
    insts = [random_instance(10, 3, T=0.3 + 0.3 * b, seed=seed + b)
             for b in range(4)]
    for sched, inst in zip(dual_schedule_batch(insts), insts):
        ref = dual_schedule(inst)
        assert sched.status == ref.status
        np.testing.assert_array_equal(sched.assignment, ref.assignment)


def test_dual_batch_fallback_branch_matches():
    """Tiny T: even the harshest multiplier fails -> fastest-model fallback,
    same as the NumPy path."""
    insts = [random_instance(10, 3, T=1e-6, seed=s) for s in range(4)]
    for sched, inst in zip(dual_schedule_batch(insts), insts):
        ref = dual_schedule(inst)
        assert sched.status == ref.status == "fallback"
        np.testing.assert_array_equal(sched.assignment, ref.assignment)


# ---------------------------------------------------------------------------
# plan_batch policy routing for the new batched paths
# ---------------------------------------------------------------------------
def test_plan_batch_dual_policy_matches_oracle():
    insts = [paper_instance(10, T=1.2, seed=s) for s in range(5)]
    plans = plan_batch(insts, policy="dual", backend="jax")
    oracle = plan_batch(insts, policy="dual", backend="numpy")
    for p, o in zip(plans, oracle):
        assert p.policy == "dual" and p.schedule.solver == "dual"
        np.testing.assert_array_equal(p.schedule.assignment,
                                      o.schedule.assignment)


def test_plan_batch_auto_routes_identical_through_amdp_batch():
    mix = [_ident(1, n=6), _ident(2, n=6), paper_instance(6, T=1.5, seed=0)]
    plans = plan_batch(mix, policy="auto", backend="jax")
    assert [p.policy for p in plans] == ["amdp", "amdp", "amr2"]
    for p, inst in zip(plans[:2], mix[:2]):
        np.testing.assert_array_equal(
            p.schedule.assignment, amdp(inst, resolution=1e-3).assignment)
