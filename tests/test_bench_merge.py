"""`benchmarks/fleet_bench._record` merge semantics — regression for the
key-clobbering bug: recording one section slice (one device count, one
policy) used to ASSIGN the section dict, dropping every previously
recorded sibling key both in-process and (via the rewrite) on disk, so a
partial bench rerun silently shrank BENCH_fleet.json and
`scripts/check_bench_keys.py --verify` failed on unrelated keys."""
import importlib
import json
import os
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture
def fb(tmp_path, monkeypatch):
    """A fresh fleet_bench module writing to a throwaway JSON file."""
    if REPO not in sys.path:
        monkeypatch.syspath_prepend(REPO)
    import benchmarks.fleet_bench as mod
    mod = importlib.reload(mod)
    monkeypatch.setattr(mod, "_JSON_PATH", str(tmp_path / "bench.json"))
    monkeypatch.setattr(mod, "_RESULTS", {})
    return mod


def _keys(doc, prefix=""):
    out = set()
    for k, v in doc.items():
        p = f"{prefix}/{k}" if prefix else k
        out.add(p)
        if isinstance(v, dict):
            out |= _keys(v, p)
    return out


def test_merge_is_recursive_and_sibling_preserving(fb):
    old = {"64": {"amr2": {"a": 1}, "dual": {"b": 2}}, "256": {"c": 3}}
    new = {"64": {"amr2": {"a": 9, "extra": 4}}}
    got = fb._merge(old, new)
    assert got == {"64": {"amr2": {"a": 9, "extra": 4}, "dual": {"b": 2}},
                   "256": {"c": 3}}
    # leaves (non-dicts) are replaced, not merged
    assert fb._merge({"x": {"y": 1}}, {"x": 5}) == {"x": 5}
    assert fb._merge(None, {"x": 1}) == {"x": 1}


def test_record_preserves_sibling_keys_in_process(fb):
    fb._record("scale", {"256": {"amr2": {"devices_per_s": 100.0}}})
    fb._record("scale", {"16384": {"amr2": {"devices_per_s": 90.0}}})
    # the second call must not clobber the first size's entry
    assert set(fb._RESULTS["scale"]) == {"256", "16384"}
    doc = json.load(open(fb._JSON_PATH))
    assert set(doc["scale"]) == {"256", "16384"}


def test_record_merges_into_existing_document_on_disk(fb):
    with open(fb._JSON_PATH, "w") as fh:
        json.dump({"scale": {"1024": {"auto": {"x": 1}}},
                   "parity": {"64": {"ok": True}}}, fh)
    fb._record("scale", {"1024": {"amr2": {"y": 2}}})
    doc = json.load(open(fb._JSON_PATH))
    # old format key ('auto') and other sections survive a partial rerun
    assert doc["scale"]["1024"] == {"auto": {"x": 1}, "amr2": {"y": 2}}
    assert doc["parity"] == {"64": {"ok": True}}
    before = _keys({"scale": {"1024": {"auto": {"x": 1}}},
                    "parity": {"64": {"ok": True}}})
    assert before <= _keys(doc)       # the check_bench_keys invariant


def test_record_scalar_sections_still_assign(fb):
    fb._record("note", "hello")
    fb._record("note", "world")
    assert json.load(open(fb._JSON_PATH))["note"] == "world"


def test_record_survives_corrupt_document(fb):
    fb._record("scale", {"8": {"amr2": {"z": 1}}})
    with open(fb._JSON_PATH, "w") as fh:
        fh.write("{not json")
    # rewrite can't read the disk doc; the in-process accumulator (which
    # MERGES, not assigns) still carries the earlier slice forward
    fb._record("scale", {"16": {"amr2": {"z": 2}}})
    doc = json.load(open(fb._JSON_PATH))
    assert set(doc["scale"]) == {"8", "16"}
