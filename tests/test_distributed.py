"""Distribution substrate: sharding rules, checkpoint round-trip + elastic
restore, grad compression, data-pipeline determinism, pipeline parallelism
(subprocess with 8 host devices so this process keeps 1 device)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.distributed.compression import compress_tree, quantize_int8
from repro.distributed.sharding import base_rules, decode_rules, spec_for

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


# ------------------------------------------------------------- sharding --
def test_spec_for_drops_duplicate_axes():
    rules = base_rules(True)
    s = spec_for(("batch", "seq", "embed"), rules)
    # batch gets (pod, data); embed's 'data' must be dropped (already used)
    flat = []
    for e in s:
        if isinstance(e, (tuple, list)):
            flat.extend(e)
        elif e:
            flat.append(e)
    assert len(flat) == len(set(flat))


def test_decode_rules_long_context():
    r = decode_rules(True, long_context=True)
    assert r["batch"] is None
    assert r["cache_seq"] == ("pod", "data", "model")


# ----------------------------------------------------------- checkpoint --
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": (jnp.ones((4,), jnp.bfloat16), jnp.asarray(3, jnp.int32))}
    ckpt.save(str(tmp_path), 7, tree, {"step": 7})
    assert ckpt.latest_step(str(tmp_path)) == 7
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    out, meta = ckpt.restore(str(tmp_path), 7, like)
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_atomicity_partial_write_ignored(tmp_path):
    tree = {"a": jnp.ones((2,))}
    ckpt.save(str(tmp_path), 1, tree)
    # simulate a crashed write
    os.makedirs(tmp_path / "step_000000002.tmp")
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_checkpoint_rotation(tmp_path):
    tree = {"a": jnp.ones((2,))}
    for s in range(5):
        ckpt.save(str(tmp_path), s, tree)
    ckpt.rotate(str(tmp_path), keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 4
    assert ckpt.restore(str(tmp_path), 3, tree)[0] is not None
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path), 0, tree)


def test_async_checkpointer(tmp_path):
    w = ckpt.AsyncCheckpointer(str(tmp_path))
    for s in (1, 2, 3):
        w.submit(s, {"x": jnp.full((3,), s)}, {"step": s})
    w.wait()
    assert ckpt.latest_step(str(tmp_path)) == 3


def test_elastic_restore_resharding(tmp_path):
    """Save from a 1-device layout, restore with explicit shardings (the
    path a different-topology restart takes)."""
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    ckpt.save(str(tmp_path), 0, tree)
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("data",))
    shd = {"w": jax.NamedSharding(mesh, jax.sharding.PartitionSpec("data"))}
    out, _ = ckpt.restore(str(tmp_path), 0, tree, shardings=shd)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))
    assert out["w"].sharding == shd["w"]


# ----------------------------------------------------------- compression --
def test_quantize_int8_bounded_error():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(64,)) * 3)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(x) - np.asarray(q, np.float32) * float(s))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_error_feedback_converges():
    """Sum of EF-compressed grads converges to the sum of true grads."""
    rng = np.random.default_rng(0)
    true_sum = np.zeros(32)
    comp_sum = np.zeros(32)
    err = None
    for _ in range(50):
        g = {"w": jnp.asarray(rng.normal(size=32), jnp.float32)}
        out, err = compress_tree(g, err)
        true_sum += np.asarray(g["w"])
        comp_sum += np.asarray(out["w"])
    resid = np.abs(true_sum - comp_sum).max()
    scale = np.abs(true_sum).max()
    assert resid <= 0.05 * scale + np.abs(np.asarray(err["w"])).max() + 1e-3


# ------------------------------------------------------------------ data --
def test_pipeline_deterministic_and_sharded():
    cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=8, seed=1)
    full = TokenPipeline(cfg).batch_at(3)["tokens"]
    shards = [TokenPipeline(cfg, rank=r, world=4).batch_at(3)["tokens"]
              for r in range(4)]
    np.testing.assert_array_equal(np.concatenate(shards), full)
    again = TokenPipeline(cfg).batch_at(3)["tokens"]
    np.testing.assert_array_equal(full, again)


def test_prefetcher():
    from repro.data.pipeline import Prefetcher
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=2, seed=0)
    pf = Prefetcher(TokenPipeline(cfg), start_step=5)
    step, batch = pf.next()
    assert step == 5 and batch["tokens"].shape == (2, 16)
    pf.close()


# ------------------------------------------- multi-device (subprocess) ---
def _run_subprocess(code: str):
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          env=env, capture_output=True, text=True,
                          timeout=560)


def test_pipeline_parallel_8dev():
    r = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_apply
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4,), ("stage",))
        S, B, D = 4, 8, 16
        key = jax.random.key(0)
        Ws = jax.random.normal(key, (S, D, D)) * 0.3
        x = jax.random.normal(jax.random.fold_in(key, 1), (B, D))
        def fn(W, h):
            return jnp.tanh(h @ W)
        y = pipeline_apply(fn, Ws, x, mesh=mesh, microbatches=4)
        ref = x
        for i in range(S):
            ref = jnp.tanh(ref @ Ws[i])
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   atol=1e-5)
        print("PIPELINE_OK")
    """)
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr


def test_train_step_sharded_8dev():
    r = _run_subprocess("""
        import jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.distributed.sharding import (base_rules, sharding_context,
                                                tree_shardings)
        from repro.launch.steps import make_train_step
        from repro.models import init_params, param_axes
        from repro.optim import adamw_init
        cfg = get_smoke_config("internlm2_20b")
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4, 2), ("data", "model"))
        rules = base_rules(False)
        p_shard = tree_shardings(param_axes(cfg), mesh, rules)
        with sharding_context(mesh, rules):
            params = init_params(cfg, jax.random.key(0))
            params = jax.device_put(params, p_shard)
            opt = adamw_init(params)
            step = jax.jit(make_train_step(cfg, lr=1e-2),
                           donate_argnums=(0, 1))
            batch = {"tokens": jax.random.randint(
                jax.random.key(1), (8, 32), 0, cfg.vocab_size)}
            l0 = None
            for i in range(3):
                params, opt, loss = step(params, opt, batch)
                l0 = l0 or float(loss)
            assert float(loss) < l0
        print("SHARDED_TRAIN_OK", l0, float(loss))
    """)
    assert "SHARDED_TRAIN_OK" in r.stdout, r.stdout + r.stderr
