"""Pure-functional engine (`repro.api.engine`): EngineState pytree,
`step`/`rollout` scan semantics, sharding, admission determinism, and the
queue replay/edge-case regressions."""
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import engine as E
from repro.serving import (DeviceSpec, EdgeServerPool, FleetConfig,
                           FleetEngine, RequestQueue, TierProfile)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _config(n_devices=8, *, policy="amr2", seed=5, horizon=40, rate=9.0,
            n_servers=2, straggler_frac=0.25, outage_frac=0.1,
            batch_max=8):
    return FleetConfig(n_devices=n_devices, T=1.2, n_servers=n_servers,
                       policy=policy, backend="jax", rate=rate,
                       batch_max=batch_max, horizon=horizon, seed=seed,
                       straggler_frac=straggler_frac,
                       outage_frac=outage_frac)


INT_FIELDS = ("n_jobs", "n_violations", "n_offloading", "n_backpressured",
              "n_outage", "n_straggler_updates", "backlog")
FLOAT_FIELDS = ("total_accuracy", "mean_job_accuracy", "worst_violation",
                "es_utilization")


def _assert_matches_stats(metrics, stats, *, exact_floats=True):
    """Stacked `PeriodMetrics` vs a list of `FleetPeriodStats`."""
    assert int(np.asarray(metrics.period)[-1]) == stats[-1].period
    for i, s in enumerate(stats):
        for f in INT_FIELDS:
            assert int(np.asarray(getattr(metrics, f))[i]) == \
                getattr(s, f), (i, f)
        for f in FLOAT_FIELDS:
            a = float(np.asarray(getattr(metrics, f))[i])
            b = getattr(s, f)
            if exact_floats:
                assert a == b, (i, f, a, b)
            else:
                assert a == pytest.approx(b, rel=1e-9, abs=1e-9), (i, f)


# ---------------------------------------------------------------------------
# rollout (scan) vs the Python-loop engine: the acceptance-criteria pin
# ---------------------------------------------------------------------------
def test_rollout_bitwise_matches_python_loop_engine_32_periods():
    """`rollout` (one lax.scan) over >= 32 periods must be BIT-identical
    to `FleetEngine.run(periods)` — the per-period Python loop — on the
    replayed arrival trace, including drift/outage schedules, straggler
    audits, and the warm-basis trajectory."""
    periods = 36
    cfg = _config(8, seed=0, horizon=periods + 2)
    eng = FleetEngine.from_config(cfg)
    assert eng._v2_params is not None      # jax/amr2: delegation active
    params = E.EngineParams.from_config(cfg, horizon=periods + 2)
    state, metrics = E.rollout(E.init_state(params), params, periods)
    stats = eng.run(periods)
    _assert_matches_stats(metrics, stats, exact_floats=True)
    # warm-basis and belief trajectories landed in the same place
    np.testing.assert_array_equal(np.asarray(state.warm_basis),
                                  np.asarray(eng._groups[0].warm_basis))
    beliefs = np.stack([d.profile.p_ed for d in eng.devices])
    np.testing.assert_array_equal(np.asarray(state.p_ed),
                                  beliefs[:, eng._v2_lut, :])
    assert int(np.asarray(metrics.n_backpressured).sum()) > 0
    assert int(np.asarray(metrics.n_straggler_updates).sum()) > 0


def test_step_sequence_equals_rollout_scan():
    """Scanning `step` and looping jitted `step` is the same computation:
    the final EngineState pytrees must be exactly equal leaf-for-leaf."""
    cfg = _config(6, horizon=12)
    params = E.EngineParams.from_config(cfg, horizon=12)
    s_loop = E.init_state(params)
    for _ in range(8):
        s_loop, _ = E.step(s_loop, params)
    s_scan, _ = E.rollout(E.init_state(params), params, 8)
    for f in ("period", "key", "p_ed", "pending", "head", "warm_basis",
              "n_updates"):
        np.testing.assert_array_equal(np.asarray(getattr(s_loop, f)),
                                      np.asarray(getattr(s_scan, f)), f)


def test_rollout_matches_reference_loop():
    """rollout vs the PR-1 per-device `run_period_reference` oracle
    (numpy scalar solvers).  Drift-free fleet: the EMA audit's feedback
    loop converges exactly onto its own threshold, where numpy-vs-XLA
    summation-order ulps can flip the update decision — everything else
    (queue, admission, planning, outage, backpressure, backlog) is
    covered."""
    periods = 5
    cfg = _config(6, horizon=periods + 2, straggler_frac=0.0)
    params = E.EngineParams.from_config(cfg, horizon=periods + 2)
    _, metrics = E.rollout(E.init_state(params), params, periods)
    ref = FleetEngine.from_config(
        FleetConfig(**{**cfg.__dict__, "backend": "numpy",
                       "policy": "amr2"}))
    stats = [ref.run_period_reference() for _ in range(periods)]
    _assert_matches_stats(metrics, stats, exact_floats=False)


@given(seed=st.integers(0, 2**16), n_devices=st.integers(2, 6),
       rate=st.floats(2.0, 14.0), n_servers=st.integers(1, 3))
@settings(max_examples=5, deadline=None)
def test_rollout_trajectory_parity_hypothesis(seed, n_devices, rate,
                                              n_servers):
    """Property pin: for random fleets/traffic, `rollout` (scan) ==
    `FleetEngine.run` (Python loop, delegated core) bit-for-bit AND ==
    `run_period_reference` (sequential numpy oracle) to float tolerance
    on accuracy / makespan-violation / backlog / warm-basis
    trajectories."""
    periods = 4
    cfg = _config(n_devices, seed=seed, horizon=periods + 2, rate=rate,
                  n_servers=n_servers, straggler_frac=0.0)
    params = E.EngineParams.from_config(cfg, horizon=periods + 2)
    state, metrics = E.rollout(E.init_state(params), params, periods)

    eng = FleetEngine.from_config(cfg)
    stats = eng.run(periods)
    _assert_matches_stats(metrics, stats, exact_floats=True)
    np.testing.assert_array_equal(np.asarray(state.warm_basis),
                                  np.asarray(eng._groups[0].warm_basis))

    ref = FleetEngine.from_config(
        FleetConfig(**{**cfg.__dict__, "backend": "numpy"}))
    ref_stats = [ref.run_period_reference() for _ in range(periods)]
    _assert_matches_stats(metrics, ref_stats, exact_floats=False)


def test_dual_policy_rollout_runs_and_delegates():
    cfg = _config(6, policy="dual", horizon=8, straggler_frac=0.0)
    eng = FleetEngine.from_config(cfg)
    assert eng._v2_params is not None
    params = E.EngineParams.from_config(cfg, horizon=8)
    state, metrics = E.rollout(E.init_state(params), params, 6)
    stats = eng.run(6)
    _assert_matches_stats(metrics, stats, exact_floats=True)
    # dual carries no basis: the warm state stays cold
    assert (np.asarray(state.warm_basis) == -1).all()


# ---------------------------------------------------------------------------
# array-native Poisson arrivals (jax.random)
# ---------------------------------------------------------------------------
def test_poisson_mode_conserves_jobs():
    cfg = _config(5, horizon=4, straggler_frac=0.0, rate=6.0)
    params = E.EngineParams.from_config(cfg, horizon=4, arrivals="poisson")
    state, metrics = E.rollout(E.init_state(params), params, 10)
    jobs = np.asarray(metrics.n_jobs)
    backlog = np.asarray(metrics.backlog)
    assert (jobs >= 0).all() and (backlog >= 0).all()
    assert jobs.sum() > 0
    # released jobs never exceed the per-device planning window
    assert jobs.max() <= params.n_devices * params.batch_max
    # different seeds draw different traffic
    s2, m2 = E.rollout(E.init_state(params, seed=1), params, 10)
    assert not np.array_equal(np.asarray(m2.n_jobs), jobs)


def test_poisson_zero_rate_means_zero_jobs():
    cfg = _config(4, horizon=4, rate=0.0, straggler_frac=0.0)
    params = E.EngineParams.from_config(cfg, horizon=4, arrivals="poisson")
    _, metrics = E.rollout(E.init_state(params), params, 6)
    assert int(np.asarray(metrics.n_jobs).sum()) == 0
    assert int(np.asarray(metrics.backlog)[-1]) == 0


def test_unsorted_queue_classes_price_correctly():
    """Regression: the delegated run_period maps arrival values to class
    indices via an argsort-indirected searchsorted, so an UNSORTED queue
    class table prices identically to the host pipeline (a raw
    searchsorted on the unsorted table silently mis-priced every job)."""
    prof = TierProfile(name="t", p_ed=np.array([[0.02, 0.08],
                                                [0.01, 0.04]]),
                       p_es=np.array([0.5, 0.35]),
                       acc=np.array([0.4, 0.56, 0.77]), classes=[64, 512])

    def build(delegate):
        specs = [DeviceSpec(profile=prof) for _ in range(3)]
        q = RequestQueue(3, (512, 64), rate=6.0, batch_max=5, seed=2)
        return FleetEngine(specs, q, n_servers=1, T=0.5, backend="jax",
                           policy="amr2", delegate=delegate)

    v2, host = build(True), build(False)
    assert v2._v2_params is not None and host._v2_params is None
    for period in range(3):
        sv, sh = v2.run_period(), host.run_period()
        assert sv.n_jobs == sh.n_jobs
        assert sv.total_accuracy == pytest.approx(sh.total_accuracy,
                                                  abs=1e-9), period


def test_unsolved_plans_are_surfaced_not_silently_rounded():
    """PR-4 strict semantics survive the delegation: an LP that hits its
    iteration cap raises from run_period, and rollout reports it in
    PeriodMetrics.n_unsolved instead of serving best-effort roundings
    silently."""
    import dataclasses

    cfg = _config(4, horizon=4, straggler_frac=0.0, outage_frac=0.0)
    eng = FleetEngine.from_config(cfg)
    assert eng._v2_params is not None
    eng._v2_params = dataclasses.replace(eng._v2_params, maxiter=1)
    with pytest.raises(RuntimeError, match="not solved to optimality"):
        eng.run_period()

    params = dataclasses.replace(
        E.EngineParams.from_config(cfg, horizon=4), maxiter=1)
    _, metrics = E.rollout(E.init_state(params), params, 3)
    assert int(np.asarray(metrics.n_unsolved).sum()) > 0
    # generous default cap: a normal config reports zero unsolved
    ok = E.EngineParams.from_config(cfg, horizon=4)
    _, m2 = E.rollout(E.init_state(ok), ok, 3)
    assert int(np.asarray(m2.n_unsolved).sum()) == 0


# ---------------------------------------------------------------------------
# params validation + replay-horizon guard
# ---------------------------------------------------------------------------
def test_replay_horizon_guard():
    cfg = _config(4, horizon=6)
    params = E.EngineParams.from_config(cfg, horizon=6)
    state = E.init_state(params)
    with pytest.raises(ValueError, match="presample a longer horizon"):
        E.rollout(state, params, 7)
    state, _ = E.rollout(state, params, 6)      # exactly the horizon: fine
    with pytest.raises(ValueError, match="presample a longer horizon"):
        E.step(state, params)


def test_params_reject_untraceable_policy_and_mixed_shapes():
    cfg = _config(4)
    with pytest.raises(ValueError, match="no traceable batched path"):
        E.EngineParams.from_config(cfg, horizon=4, policy="amdp")
    # "auto" resolves to the LP path instead of raising
    assert E.EngineParams.from_config(cfg, horizon=4,
                                      policy="auto").policy == "amr2"
    prof_a = TierProfile(name="a", p_ed=np.array([[0.01, 0.04]]),
                         p_es=np.array([0.3]),
                         acc=np.array([0.4, 0.5, 0.7]), classes=[64])
    prof_b = TierProfile(name="b", p_ed=np.array([[0.01, 0.04],
                                                  [0.02, 0.05]]),
                         p_es=np.array([0.3, 0.4]),
                         acc=np.array([0.4, 0.5, 0.7]), classes=[64, 128])
    queue = RequestQueue(2, (64,), rate=4.0, batch_max=4, seed=0)
    with pytest.raises(ValueError, match="single shape group"):
        E.EngineParams.from_fleet(
            [DeviceSpec(profile=prof_a), DeviceSpec(profile=prof_b)],
            queue, T=0.5)
    # unsorted profile class tables would silently mis-price via the
    # searchsorted re-indexing: rejected up front (FleetEngine's guard)
    unsorted = TierProfile(name="u", p_ed=np.array([[0.01, 0.04],
                                                    [0.02, 0.05]]),
                           p_es=np.array([0.3, 0.4]),
                           acc=np.array([0.4, 0.5, 0.7]),
                           classes=[128, 64])
    q2 = RequestQueue(1, (64,), rate=4.0, batch_max=4, seed=0)
    with pytest.raises(ValueError, match="strictly ascending"):
        E.EngineParams.from_fleet([DeviceSpec(profile=unsorted)], q2,
                                  T=0.5)


# ---------------------------------------------------------------------------
# queue replay + trace edge cases (satellite regressions)
# ---------------------------------------------------------------------------
def test_presample_replays_poll_exactly():
    def build():
        return RequestQueue(3, (128, 512), rate=7.0, batch_max=5, seed=9)
    counts, stream = build().presample(6)
    q = build()
    heads = np.zeros(3, dtype=int)
    classes = np.asarray(q.classes)
    for t in range(6):
        released = q.poll(t)
        for d, r in enumerate(released):
            got = classes[stream[d, heads[d]:heads[d] + len(r)]]
            np.testing.assert_array_equal(got, r, f"period {t} device {d}")
            heads[d] += len(r)
    assert counts.sum() == q.total_arrived


def test_empty_trace_yields_empty_rows_not_skipped_devices():
    """Regression: an EMPTY trace (0 periods) or all-zero arrival rows
    must produce empty per-device arrays / empty `real_mask` rows — every
    engine path runs, nothing crashes, nothing is skipped."""
    empty = RequestQueue(3, (64,), trace=np.zeros((0, 3), dtype=int),
                         batch_max=4, seed=0)
    released = empty.poll(0)
    assert len(released) == 3 and all(len(r) == 0 for r in released)
    counts, stream = empty.presample(4)
    assert counts.shape == (4, 3) and counts.sum() == 0

    prof = TierProfile(name="t", p_ed=np.array([[0.01, 0.04]]),
                       p_es=np.array([0.35]),
                       acc=np.array([0.4, 0.56, 0.77]), classes=[64])
    specs = [DeviceSpec(profile=prof) for _ in range(3)]
    for backend in ("jax", "numpy"):
        q = RequestQueue(3, (64,), trace=np.zeros((0, 3), dtype=int),
                         batch_max=4, seed=0)
        eng = FleetEngine(specs, q, n_servers=1, T=0.5, backend=backend,
                          policy="amr2")
        s = eng.run_period()
        assert s.n_jobs == 0 and s.n_offloading == 0 and s.backlog == 0
    # the pure engine's B=0-arrivals periods: zero-count trace rows
    cfg = FleetConfig(n_devices=3, T=0.5, n_servers=1, policy="amr2",
                      batch_max=4, horizon=4, seed=0, devices=specs,
                      classes=(64,), trace=np.zeros((2, 3), dtype=int),
                      straggler_frac=0.0, outage_frac=0.0)
    params = E.EngineParams.from_config(cfg, horizon=4)
    _, metrics = E.rollout(E.init_state(params), params, 4)
    assert int(np.asarray(metrics.n_jobs).sum()) == 0
    assert (np.asarray(metrics.total_accuracy) == 0).all()


# ---------------------------------------------------------------------------
# ES-pool admission: determinism + vectorized parity (satellite)
# ---------------------------------------------------------------------------
def test_admit_is_insertion_order_invariant():
    """Regression: admission must depend only on (demand, device id) —
    never on how the caller's dict was assembled."""
    rng = np.random.default_rng(0)
    demands = {int(d): float(v) for d, v in
               enumerate(rng.uniform(0.1, 0.9, size=12))}
    demands[3] = demands[7] = 0.4          # an exact tie, id-broken
    pool = EdgeServerPool(2)
    ref_admitted, ref_loads = pool.admit(demands, T=1.0)
    for seed in range(5):
        keys = list(demands)
        np.random.default_rng(seed).shuffle(keys)
        shuffled = {k: demands[k] for k in keys}
        admitted, loads = pool.admit(shuffled, T=1.0)
        assert admitted == ref_admitted
        np.testing.assert_array_equal(loads, ref_loads)


def test_admit_mask_matches_admit_and_traced_scan():
    rng = np.random.default_rng(1)
    dense = rng.uniform(0.0, 0.9, size=16)
    dense[rng.uniform(size=16) < 0.4] = 0.0      # non-offloaders
    pool = EdgeServerPool(3)
    demands = {d: float(v) for d, v in enumerate(dense) if v > 0}
    admitted, loads = pool.admit(demands, T=1.0)
    mask, mloads = pool.admit_mask(dense, T=1.0)
    assert sorted(np.nonzero(mask)[0].tolist()) == sorted(admitted)
    np.testing.assert_allclose(mloads, loads, rtol=0, atol=0)

    import jax.numpy as jnp
    from jax.experimental import enable_x64
    with enable_x64():
        jmask, jloads = E.admit_mask_jnp(jnp.asarray(dense, jnp.float64),
                                         jnp.float64(1.0), 3)
    np.testing.assert_array_equal(np.asarray(jmask), mask)
    np.testing.assert_array_equal(np.asarray(jloads), mloads)


# ---------------------------------------------------------------------------
# sharding: shard_map step parity on host-platform devices (subprocess —
# the flag must be set before jax initialises)
# ---------------------------------------------------------------------------
def test_sharded_step_matches_unsharded_subprocess():
    env = dict(os.environ)
    env.update({
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
        "SHARD_SMOKE_DEVICES": "16", "SHARD_SMOKE_SHARDS": "8",
        "SHARD_SMOKE_PERIODS": "4",
        "PYTHONPATH": os.path.join(REPO, "src") + os.pathsep
        + env.get("PYTHONPATH", ""),
    })
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts",
                                      "smoke_shard_rollout.py")],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "[shard-smoke] ok" in proc.stdout


# ---------------------------------------------------------------------------
# pytree plumbing
# ---------------------------------------------------------------------------
def test_engine_pytrees_roundtrip():
    import jax
    cfg = _config(3, horizon=4)
    params = E.EngineParams.from_config(cfg, horizon=4)
    state = E.init_state(params)
    for tree in (params, state):
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
        for a, b in zip(leaves, jax.tree_util.tree_leaves(rebuilt)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # static solver config rides the treedef, not the leaves
    assert params.policy == "amr2" and params.arrivals == "replay"
    rebuilt = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(params),
        jax.tree_util.tree_leaves(params))
    assert rebuilt.policy == "amr2"
    assert rebuilt.batch_max == params.batch_max


# ---------------------------------------------------------------------------
# reduced-tableau LP method, buffer donation, dtype guard, plan chunking
# ---------------------------------------------------------------------------
def test_rollout_lp_method_revised_matches_tableau():
    """The engine on `lp_method="revised"` must replay the tableau
    engine's trajectory: same integer metrics, same warm-basis carry,
    accuracies to fp noise.  The carried basis is compared as a label
    SET per device: the two representations reach the same optimal
    vertex but may order its rows differently (the leaving-row slot
    depends on the pivot sequence, which differs between the dense
    tableau and the reduced factor on degenerate ties)."""
    cfg = _config(8, horizon=10)
    pt = E.EngineParams.from_config(cfg, horizon=10)
    pr = E.EngineParams.from_config(cfg, horizon=10, lp_method="revised")
    assert pt.lp_method == "tableau" and pr.lp_method == "revised"
    st, mt = E.rollout(E.init_state(pt), pt, 6)
    sr, mr = E.rollout(E.init_state(pr), pr, 6)
    for f in INT_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(mr, f)),
                                      np.asarray(getattr(mt, f)), f)
    np.testing.assert_allclose(np.asarray(mr.total_accuracy),
                               np.asarray(mt.total_accuracy), atol=1e-12)
    np.testing.assert_array_equal(np.sort(np.asarray(sr.warm_basis), -1),
                                  np.sort(np.asarray(st.warm_basis), -1))


def test_from_fleet_rejects_unknown_lp_method():
    cfg = _config(4, horizon=6)
    with pytest.raises(ValueError, match="lp_method"):
        E.EngineParams.from_config(cfg, horizon=6, lp_method="dense")


def test_rollout_donate_is_bitwise_invisible():
    """`donate=True` consumes the input state's buffers in place (its own
    jit cache entry) — the results must be BIT-identical to the
    non-donated rollout."""
    cfg = _config(6, horizon=8)
    params = E.EngineParams.from_config(cfg, horizon=8)
    s0, m0 = E.rollout(E.init_state(params), params, 5)
    s1, m1 = E.rollout(E.init_state(params), params, 5, donate=True)
    for f in _STATE_FIELDS_TEST:
        np.testing.assert_array_equal(np.asarray(getattr(s0, f)),
                                      np.asarray(getattr(s1, f)), f)
    for f in INT_FIELDS + FLOAT_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(m0, f)),
                                      np.asarray(getattr(m1, f)), f)


_STATE_FIELDS_TEST = ("period", "key", "p_ed", "pending", "head",
                      "warm_basis", "n_updates", "pos", "cell",
                      "cell_load", "p_es_belief")


def test_engine_rejects_float32_state_and_params():
    """The f64 guard: a float32 leaf (e.g. a `device_put` outside any
    enable_x64 scope with global x64 off) must raise, naming the leaf,
    instead of silently running the rollout at single precision."""
    import dataclasses

    cfg = _config(4, horizon=6)
    params = E.EngineParams.from_config(cfg, horizon=6)
    state = E.init_state(params)
    bad_state = dataclasses.replace(
        state, p_ed=np.asarray(state.p_ed, np.float32))
    with pytest.raises(TypeError, match=r"state\.p_ed.*float32"):
        E.step(bad_state, params)
    bad_params = dataclasses.replace(
        params, acc=np.asarray(params.acc, np.float32))
    with pytest.raises(TypeError, match=r"params\.acc.*float32"):
        E.rollout(state, bad_params, 2)


def test_plan_lane_chunking_is_bitwise_invisible(monkeypatch):
    """`_plan` over lane chunks (`_PLAN_LANE_CHUNK`) must return exactly
    what the flat plan returns — warm, cold, and non-divisible (flat
    fallback) alike.  The chunking is purely a cache-blocking transform;
    any numerical difference is a bug."""
    import dataclasses

    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.core.problem import FleetProblem

    cfg = _config(16, horizon=6)
    params = E.EngineParams.from_config(cfg, horizon=6)
    state = E.init_state(params)
    with enable_x64():
        ci, take, *_ = E._arrivals(state, params)
        D, n = 16, params.batch_max
        mask = jnp.arange(n)[None, :] < take[:, None]
        rows = jnp.arange(D)[:, None]
        cic = jnp.clip(ci, 0, params.p_es.shape[1] - 1)
        fp = FleetProblem.from_arrays_unchecked(
            jnp.where(mask[..., None], jnp.asarray(state.p_ed)[rows, cic],
                      0.0),
            jnp.where(mask, jnp.asarray(params.p_es)[rows, cic], 0.0),
            jnp.asarray(params.acc), jnp.broadcast_to(params.T, (D,)),
            mask)
        wb = jnp.asarray(state.warm_basis)
        monkeypatch.setattr(E, "_PLAN_LANE_CHUNK", 0)
        flat = E._plan(params, fp, wb)
        flat_cold = E._plan(params, fp, None)
        for chunk in (4, 8, 5):          # 5 does not divide 16: flat path
            monkeypatch.setattr(E, "_PLAN_LANE_CHUNK", chunk)
            for ref, got in ((flat, E._plan(params, fp, wb)),
                             (flat_cold, E._plan(params, fp, None))):
                for r, g in zip(ref, got):
                    np.testing.assert_array_equal(np.asarray(r),
                                                  np.asarray(g))


# ---------------------------------------------------------------------------
# stale warm-basis invalidation (outage flip) — regression
# ---------------------------------------------------------------------------
class _Captured(Exception):
    pass


def test_step_cold_starts_warm_basis_on_outage_flip(monkeypatch):
    """An outage edge swaps a device's ES columns for the disabled
    sentinel, so last period's optimal basis labels a DIFFERENT LP.
    `step` must mask exactly the flipped devices' warm rows to -1 before
    handing them to the period core (regression: they used to be
    warm-factored against the wrong problem)."""
    import dataclasses

    cfg = _config(6, horizon=4, outage_frac=0.0)
    params = E.EngineParams.from_config(cfg, horizon=4)
    outage = np.zeros((6, params.outage.shape[1]), bool)
    outage[0, 1] = True            # device 0 flips ON at t=1
    outage[1, :] = True            # device 1 always out: no edge
    outage[2, 0] = True            # device 2 flips OFF at t=1
    params = dataclasses.replace(params, outage=outage)
    wb = np.tile(np.arange(params.n_basis_rows, dtype=np.int32), (6, 1))
    state = dataclasses.replace(E.init_state(params),
                                period=np.int32(1), warm_basis=wb)
    captured = {}

    def spy(belief, warm, *a, **k):
        captured["warm"] = np.asarray(warm)
        raise _Captured

    monkeypatch.setattr(E, "_period_impl", spy)
    with pytest.raises(_Captured):
        E._step_impl(state, params)
    got = captured["warm"]
    assert (got[0] == -1).all() and (got[2] == -1).all()
    np.testing.assert_array_equal(got[[1, 3, 4, 5]], wb[[1, 3, 4, 5]])


def test_step_keeps_warm_basis_at_period_zero(monkeypatch):
    """t=0 has no previous period: the (t-1) % H wraparound row must not
    fabricate a flip and throw away a caller-provided basis."""
    import dataclasses

    cfg = _config(4, horizon=4, outage_frac=0.0)
    params = E.EngineParams.from_config(cfg, horizon=4)
    outage = np.zeros((4, params.outage.shape[1]), bool)
    outage[1, -1] = True           # differs from t=0 only via wraparound
    params = dataclasses.replace(params, outage=outage)
    wb = np.tile(np.arange(params.n_basis_rows, dtype=np.int32), (4, 1))
    state = dataclasses.replace(E.init_state(params), warm_basis=wb)
    captured = {}

    def spy(belief, warm, *a, **k):
        captured["warm"] = np.asarray(warm)
        raise _Captured

    monkeypatch.setattr(E, "_period_impl", spy)
    with pytest.raises(_Captured):
        E._step_impl(state, params)
    np.testing.assert_array_equal(captured["warm"], wb)
