"""Chaos subsystem: `FaultModel` pytree, the traced degradation ladder
(retry -> local fallback -> drop), engine/fleet wiring, strict-mode
unsolved-period semantics, and the executor's per-sample status audit."""
import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import engine as E
from repro.serving import (EXEC_DROPPED, EXEC_FALLBACK_LOCAL, EXEC_OK_ED,
                           EXEC_OK_ES, FaultModel, FleetConfig, FleetEngine,
                           TierProfile, UnsolvedPeriodError, execute, plan,
                           greedy_local_fill, realize_execution,
                           sample_realization)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LADDER_INTS = ("n_offload_samples", "n_offload_ok", "n_deadline_miss",
               "n_retries", "n_fallback_local", "n_dropped")


def _config(n_devices=8, *, policy="amr2", seed=5, horizon=40, rate=9.0,
            n_servers=2, straggler_frac=0.25, outage_frac=0.1,
            batch_max=8, **extra):
    return FleetConfig(n_devices=n_devices, T=1.2, n_servers=n_servers,
                       policy=policy, backend="jax", rate=rate,
                       batch_max=batch_max, horizon=horizon, seed=seed,
                       straggler_frac=straggler_frac,
                       outage_frac=outage_frac, **extra)


_HARSH = dict(es_crash_prob=0.08, link_degrade_prob=0.25,
              link_degrade_mag=0.6, straggler_prob=0.2,
              straggler_mult=1.8, loss_rate=0.15)


# ---------------------------------------------------------------------------
# FaultModel: construction, validation, pytree plumbing
# ---------------------------------------------------------------------------
def test_fault_model_none_is_null_and_make_validates():
    assert FaultModel.none().is_null()
    assert not FaultModel.make(loss_rate=0.1).is_null()
    # backoff-only models are still null: no fault can ever fire
    assert FaultModel.make(backoff_base=0.1, backoff_cap=0.5).is_null()
    with pytest.raises(ValueError, match="loss_rate"):
        FaultModel.make(loss_rate=1.5)
    with pytest.raises(ValueError, match="es_crash_prob"):
        FaultModel.make(es_crash_prob=-0.1)
    with pytest.raises(ValueError, match="straggler_mult"):
        FaultModel.make(straggler_prob=0.5, straggler_mult=0.5)
    with pytest.raises(ValueError, match="link_degrade_mag"):
        FaultModel.make(link_degrade_mag=-1.0)
    with pytest.raises(ValueError, match="backoff"):
        FaultModel.make(backoff_base=-0.01)


def test_fault_model_pytree_roundtrip_all_leaves():
    import jax
    fm = FaultModel.make(**_HARSH)
    leaves, treedef = jax.tree_util.tree_flatten(fm)
    assert len(leaves) == len(dataclasses.fields(FaultModel))
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    for f in dataclasses.fields(FaultModel):
        assert float(getattr(rebuilt, f.name)) == \
            float(getattr(fm, f.name)), f.name


# ---------------------------------------------------------------------------
# greedy_local_fill vs a NumPy oracle
# ---------------------------------------------------------------------------
def _fill_oracle(lat, accl, budget, elig):
    D, n, m = lat.shape
    choice = np.full((D, n), m, np.int32)
    fit = np.zeros((D, n), bool)
    used = np.zeros(D)
    for d in range(D):
        res = float(budget[d])
        for j in range(n):
            if not elig[d, j]:
                continue
            fits = lat[d, j] <= res + 1e-12
            if not fits.any():
                continue
            pick = int(np.argmax(np.where(fits, accl[d], -np.inf)))
            choice[d, j] = pick
            fit[d, j] = True
            res -= lat[d, j, pick]
            used[d] += lat[d, j, pick]
    return choice, fit, used


@given(seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_greedy_local_fill_matches_numpy_oracle(seed):
    from jax.experimental import enable_x64
    rng = np.random.default_rng(seed)
    D, n, m = rng.integers(1, 5), rng.integers(1, 7), rng.integers(1, 4)
    lat = rng.uniform(0.05, 0.8, size=(D, n, m))
    accl = rng.uniform(0.2, 0.9, size=(D, m))
    budget = rng.uniform(0.0, 1.5, size=D)
    elig = rng.uniform(size=(D, n)) < 0.6
    with enable_x64():
        choice, fit, used = greedy_local_fill(lat, accl, budget, elig)
    c0, f0, u0 = _fill_oracle(lat, accl, budget, elig)
    np.testing.assert_array_equal(np.asarray(choice), c0)
    np.testing.assert_array_equal(np.asarray(fit), f0)
    np.testing.assert_allclose(np.asarray(used), u0, atol=1e-12)
    # spend never exceeds the budget
    assert (np.asarray(used) <= budget + 1e-9).all()


# ---------------------------------------------------------------------------
# realize_execution: the ladder's documented invariants (hypothesis)
# ---------------------------------------------------------------------------
def _random_period(rng, fm, seed, *, max_retries):
    """A random planned period + its fault realization (x64 required)."""
    import jax
    import jax.numpy as jnp
    D, n, m = 3, 5, 2
    mask = rng.uniform(size=(D, n)) < 0.8
    es_samp = mask & (rng.uniform(size=(D, n)) < 0.5)
    acc = np.concatenate(
        [np.sort(rng.uniform(0.3, 0.8, size=(D, m)), axis=1),
         rng.uniform(0.8, 0.95, size=(D, 1))], axis=1)
    acc_jobs = np.where(es_samp, acc[:, [m]],
                        acc[:, 0][:, None]) * mask
    p_es_jobs = rng.uniform(0.05, 0.4, size=(D, n))
    lat_local = rng.uniform(0.02, 0.5, size=(D, n, m))
    ed_wall = rng.uniform(0.0, 1.0, size=D)
    real = sample_realization(jax.random.PRNGKey(seed), fm, D, n,
                              max_retries + 1)
    rx = realize_execution(
        fm, real, mask=jnp.asarray(mask), es_samp=jnp.asarray(es_samp),
        acc_jobs=jnp.asarray(acc_jobs), p_es_jobs=jnp.asarray(p_es_jobs),
        ed_wall=jnp.asarray(ed_wall), lat_local=jnp.asarray(lat_local),
        acc=jnp.asarray(acc), T=jnp.float64(1.0), max_retries=max_retries)
    demand = (p_es_jobs * es_samp).sum(axis=1)
    return rx, real, demand, es_samp


@given(seed=st.integers(0, 2**16), loss=st.floats(0.0, 1.0),
       crash=st.floats(0.0, 1.0), max_retries=st.integers(0, 3))
@settings(max_examples=10, deadline=None)
def test_ladder_invariants_hypothesis(seed, loss, crash, max_retries):
    """For random plans and fault draws: (a) retry attempts are bounded
    by max_retries per sample, (b) the realized ES time respects the
    documented 2T + backoff_cap + demand*link bound, (c) the local
    fallback fits the residual deadline (ed_wall <= max(ed_audit, 2T)),
    (d) every admitted offload is accounted for exactly once, and (e)
    the pass is deterministic under a fixed key."""
    from jax.experimental import enable_x64
    rng = np.random.default_rng(seed)
    fm = FaultModel.make(loss_rate=loss, es_crash_prob=crash,
                         link_degrade_prob=0.3, link_degrade_mag=0.5,
                         straggler_prob=0.3, straggler_mult=2.0)
    with enable_x64():
        rx, real, demand, es_samp = _random_period(
            rng, fm, seed, max_retries=max_retries)
        rx2, *_ = _random_period(np.random.default_rng(seed), fm, seed,
                                 max_retries=max_retries)
    n_off = np.asarray(rx.n_offload)
    # (a) bounded retries
    assert (np.asarray(rx.n_retries) <= max_retries * n_off).all()
    # (b) realized ES wall bound (deadline = 2T, T = 1.0)
    cap = float(fm.backoff_cap)
    bound = 2.0 + cap + demand * np.asarray(real.link_factor)
    assert (np.asarray(rx.es_wall) <= bound + 1e-9).all()
    # (c) fallback fits the residual deadline
    assert (np.asarray(rx.ed_wall)
            <= np.maximum(np.asarray(rx.ed_audit), 2.0) + 1e-9).all()
    # (d) accounting identity, per device
    np.testing.assert_array_equal(
        n_off, np.asarray(rx.n_offload_ok) + np.asarray(rx.n_fallback_local)
        + np.asarray(rx.n_dropped))
    # (e) deterministic under a fixed key
    for f, a in zip(rx._fields, rx):
        np.testing.assert_array_equal(np.asarray(a),
                                      np.asarray(getattr(rx2, f)), f)


def test_null_realization_reproduces_priced_execution():
    """All-identity factors + no losses: the realized pass must equal the
    priced plan bit for bit (the armed-null engine pin relies on it)."""
    from jax.experimental import enable_x64
    rng = np.random.default_rng(3)
    with enable_x64():
        rx, real, demand, es_samp = _random_period(
            rng, FaultModel.none(), 3, max_retries=2)
    assert not bool(np.asarray(real.es_crash))
    assert (np.asarray(real.link_factor) == 1.0).all()
    np.testing.assert_array_equal(np.asarray(rx.es_wall), demand)
    assert int(np.asarray(rx.n_retries).sum()) == 0
    assert int(np.asarray(rx.n_dropped).sum()) == 0
    np.testing.assert_array_equal(np.asarray(rx.n_offload),
                                  np.asarray(rx.n_offload_ok))


def test_es_crash_skips_retries_and_walks_the_ladder():
    """A certain pool crash: no retry can help — zero retries, every
    offloaded sample lands on rung 2 or rung 3."""
    from jax.experimental import enable_x64
    fm = FaultModel.make(es_crash_prob=1.0, loss_rate=0.0)
    with enable_x64():
        rx, real, _, es_samp = _random_period(
            np.random.default_rng(0), fm, 0, max_retries=3)
    assert bool(np.asarray(real.es_crash))
    assert int(np.asarray(rx.n_retries).sum()) == 0
    assert int(np.asarray(rx.n_offload_ok).sum()) == 0
    np.testing.assert_array_equal(
        np.asarray(rx.n_offload),
        np.asarray(rx.n_fallback_local) + np.asarray(rx.n_dropped))


# ---------------------------------------------------------------------------
# engine wiring: the armed-null bitwise pin + chaos accounting
# ---------------------------------------------------------------------------
def test_armed_null_fault_model_is_bitwise_invisible():
    """chaos=True with the all-zero FaultModel must trace the realized-
    execution pass and still reproduce the fault-free rollout BIT for
    BIT — identity factors and zero losses are exact in float64."""
    periods = 6
    cfg = _config(6, horizon=periods + 2)
    base = E.EngineParams.from_config(cfg, horizon=periods + 2)
    assert not base.chaos
    armed = dataclasses.replace(base, faults=FaultModel.none(), chaos=True)
    s0, m0 = E.rollout(E.init_state(base), base, periods)
    s1, m1 = E.rollout(E.init_state(armed), armed, periods)
    for f in [x.name for x in dataclasses.fields(type(m0))]:
        np.testing.assert_array_equal(np.asarray(getattr(m0, f)),
                                      np.asarray(getattr(m1, f)), f)
    for f in ("period", "key", "p_ed", "pending", "head", "warm_basis",
              "n_updates"):
        np.testing.assert_array_equal(np.asarray(getattr(s0, f)),
                                      np.asarray(getattr(s1, f)), f)


def test_chaos_rollout_accounting_and_makespan_bound():
    periods = 8
    cfg = _config(8, horizon=periods + 2)
    base = E.EngineParams.from_config(cfg, horizon=periods + 2)
    params = base.with_faults(FaultModel.make(**_HARSH), fault_seed=11)
    assert params.chaos
    _, m = E.rollout(E.init_state(params), params, periods)
    n_off = np.asarray(m.n_offload_samples)
    # admitted == completed + fallback + dropped, every period
    np.testing.assert_array_equal(
        n_off, np.asarray(m.n_offload_ok) + np.asarray(m.n_fallback_local)
        + np.asarray(m.n_dropped))
    # the ladder actually fired under a harsh model
    assert int(np.asarray(m.n_retries).sum()) \
        + int(np.asarray(m.n_fallback_local).sum()) \
        + int(np.asarray(m.n_dropped).sum()) > 0
    # realized makespan respects 2T + backoff cap + one retransmission
    # of the worst admitted per-device demand at the worst link factor
    T = float(np.asarray(base.T))
    demand_cap = float(np.asarray(params.p_es).max()) * base.batch_max
    worst_link = 1.0 + float(params.faults.link_degrade_mag)
    bound = 2.0 * T + float(params.faults.backoff_cap) \
        + demand_cap * worst_link
    assert (np.asarray(m.realized_makespan) <= bound + 1e-9).all()
    # arming chaos must not perturb the arrival trajectory
    _, m0 = E.rollout(E.init_state(base), base, periods)
    for f in ("n_jobs", "backlog", "n_outage"):
        np.testing.assert_array_equal(np.asarray(getattr(m, f)),
                                      np.asarray(getattr(m0, f)), f)


def test_chaos_deterministic_and_seed_sensitive():
    periods = 5
    cfg = _config(6, horizon=periods + 2)
    fm = FaultModel.make(**_HARSH)
    p1 = E.EngineParams.from_config(cfg, horizon=periods + 2) \
        .with_faults(fm, fault_seed=1)
    _, a = E.rollout(E.init_state(p1), p1, periods)
    _, b = E.rollout(E.init_state(p1), p1, periods)
    for f in LADDER_INTS + ("total_accuracy", "realized_makespan"):
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), f)
    p2 = p1.with_faults(fm, fault_seed=2)
    _, c = E.rollout(E.init_state(p2), p2, periods)
    assert any(not np.array_equal(np.asarray(getattr(a, f)),
                                  np.asarray(getattr(c, f)))
               for f in LADDER_INTS)


def test_fleet_run_matches_rollout_under_chaos():
    """The delegated Python-loop FleetEngine replays the same folded
    fault stream as the scanned rollout — ladder counters bit-equal."""
    periods = 6
    cfg = _config(6, horizon=periods + 2,
                  faults=FaultModel.make(**_HARSH), fault_seed=4)
    eng = FleetEngine.from_config(cfg)
    assert eng._v2_params is not None and eng._v2_params.chaos
    params = E.EngineParams.from_config(cfg, horizon=periods + 2)
    _, metrics = E.rollout(E.init_state(params), params, periods)
    stats = eng.run(periods)
    assert int(np.asarray(metrics.n_dropped).sum()) \
        + int(np.asarray(metrics.n_fallback_local).sum()) > 0
    for i, s in enumerate(stats):
        for f in LADDER_INTS + ("n_jobs", "n_violations", "backlog"):
            assert int(np.asarray(getattr(metrics, f))[i]) == \
                getattr(s, f), (i, f)
        for f in ("total_accuracy", "realized_makespan"):
            assert float(np.asarray(getattr(metrics, f))[i]) == \
                getattr(s, f), (i, f)


def test_fleet_faults_require_delegation():
    cfg = _config(4, horizon=4, faults=FaultModel.make(loss_rate=0.1))
    with pytest.raises(ValueError, match="delegation"):
        FleetEngine.from_config(
            FleetConfig(**{**cfg.__dict__, "backend": "numpy"}))
    # a null model on a host-path engine is fine (chaos disarmed)
    host = FleetEngine.from_config(
        FleetConfig(**{**cfg.__dict__, "backend": "numpy",
                       "faults": FaultModel.none()}))
    assert host._v2_params is None
    host.run_period()


def test_from_fleet_rejects_negative_max_retries():
    cfg = _config(4, horizon=4, max_retries=-1)
    with pytest.raises(ValueError, match="max_retries"):
        E.EngineParams.from_config(cfg, horizon=4)


# ---------------------------------------------------------------------------
# strict-mode unsolved periods: partial stats + warn path (satellite)
# ---------------------------------------------------------------------------
def test_unsolved_period_error_carries_partial_stats():
    cfg = _config(4, horizon=6, straggler_frac=0.0, outage_frac=0.0)
    eng = FleetEngine.from_config(cfg)
    assert eng._v2_params is not None
    eng.run_period()                       # period 0 solves fine
    eng._v2_params = dataclasses.replace(eng._v2_params, maxiter=1)
    with pytest.raises(UnsolvedPeriodError,
                       match="not solved to optimality") as ei:
        eng.run_period()
    err = ei.value
    assert err.period == 1
    assert err.n_unsolved > 0
    assert len(err.partial_stats) == 1     # the solved period survives
    assert err.partial_stats[0].period == 0


def test_unsolved_strict_warn_serves_greedy_fallback():
    cfg = _config(4, horizon=6, straggler_frac=0.0, outage_frac=0.0,
                  strict="warn")
    eng = FleetEngine.from_config(cfg)
    eng._v2_params = dataclasses.replace(eng._v2_params, maxiter=1)
    with pytest.warns(RuntimeWarning, match="greedy local-only fallback"):
        stats = eng.run(3)
    assert len(stats) == 3                 # the run completes
    assert sum(s.n_jobs for s in stats) > 0
    with pytest.raises(ValueError, match="strict"):
        FleetEngine.from_config(
            FleetConfig(**{**cfg.__dict__, "strict": "loose"}))


def test_unsolved_lanes_recovered_not_garbage():
    """Under maxiter=1 every lane goes unsolved; the greedy local-only
    recovery must still produce sane metrics: nonnegative accuracy, no
    offloading from unsolved lanes beyond the LP's said-so, and the
    accounting identity intact."""
    periods = 3
    cfg = _config(4, horizon=periods + 2, straggler_frac=0.0,
                  outage_frac=0.0)
    params = dataclasses.replace(
        E.EngineParams.from_config(cfg, horizon=periods + 2), maxiter=1)
    _, m = E.rollout(E.init_state(params), params, periods)
    assert int(np.asarray(m.n_unsolved).sum()) > 0
    assert (np.asarray(m.total_accuracy) >= 0).all()
    np.testing.assert_array_equal(
        np.asarray(m.n_offload_samples),
        np.asarray(m.n_offload_ok) + np.asarray(m.n_fallback_local)
        + np.asarray(m.n_dropped))


# ---------------------------------------------------------------------------
# sharded chaos parity (subprocess — XLA flag must precede jax init)
# ---------------------------------------------------------------------------
def test_sharded_chaos_rollout_matches_unsharded_subprocess():
    env = dict(os.environ)
    env.update({
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
        "SHARD_SMOKE_DEVICES": "16", "SHARD_SMOKE_SHARDS": "8",
        "SHARD_SMOKE_PERIODS": "4", "SHARD_SMOKE_CHAOS": "1",
        "PYTHONPATH": os.path.join(REPO, "src") + os.pathsep
        + env.get("PYTHONPATH", ""),
    })
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts",
                                      "smoke_shard_rollout.py")],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "[shard-smoke] ok" in proc.stdout


# ---------------------------------------------------------------------------
# executor: per-sample status audit (satellite bugfix)
# ---------------------------------------------------------------------------
def _profile():
    return TierProfile(
        name="t", p_ed=np.array([[0.01, 0.04]]), p_es=np.array([0.35]),
        acc=np.array([0.4, 0.56, 0.77]), classes=[64])


def _applies(m=2, short_on=None):
    def make_ed(i):
        def f(jobs):
            out = [0.5] * len(jobs)
            return out[:-1] if i == short_on and len(out) else out
        return f
    return [make_ed(i) for i in range(m)], lambda jobs: [0.9] * len(jobs)


def test_executor_status_codes_cover_every_sample():
    prof = _profile()
    inst = prof.instance(np.full(12, 64), T=1.0)
    p = plan(inst)
    assert len(p.per_model[2]) > 0          # some jobs offloaded
    apply_ed, apply_es = _applies()
    rep = execute(p, apply_ed, apply_es, list(range(12)))
    assert rep.status is not None and len(rep.status) == 12
    assert rep.n_dropped == 0
    on_es = set(p.per_model[2].tolist())
    for j in range(12):
        want = EXEC_OK_ES if j in on_es else EXEC_OK_ED
        assert rep.status[j] == want, j
    # es_fail: bounced jobs land as FALLBACK_LOCAL, never dropped
    rep2 = execute(p, apply_ed, apply_es, list(range(12)), es_fail=True)
    assert rep2.replanned and rep2.n_dropped == 0
    assert (rep2.status[sorted(on_es)] == EXEC_FALLBACK_LOCAL).all()


def test_executor_short_output_is_audited_not_silently_lost():
    """Regression: an apply fn returning fewer results than jobs used to
    leave the tail samples silently missing from `results`; they now
    surface as EXEC_DROPPED with a nonzero audit count."""
    from repro.serving import replan_without_es
    prof = _profile()
    inst = prof.instance(np.full(8, 64), T=10.0)
    p = replan_without_es(inst)         # ED-only: the victim model runs
    victim = max((i for i, ids in p.per_model.items()
                  if i < 2 and len(ids)),
                 key=lambda i: len(p.per_model[i]))
    apply_ed, apply_es = _applies(short_on=victim)
    rep = execute(p, apply_ed, apply_es, list(range(8)))
    assert rep.n_dropped == 1
    assert len(rep.results) == 8 - 1
    assert (rep.status == EXEC_DROPPED).sum() == 1
