"""Fleet engine + vmapped batch planner: parity with the per-device NumPy
oracle, queue/backlog accounting, ES-capacity backpressure, padding."""
import numpy as np
import pytest

from repro.core import (InstanceBatch, OffloadInstance, amr2, amr2_batch,
                        paper_instance, random_instance, solve_lp,
                        solve_lp_batch)
from repro.serving import (DeviceSpec, EdgeServerPool, FleetEngine,
                           RequestQueue, TierProfile, make_fleet, plan,
                           plan_batch, replan_without_es,
                           replan_without_es_batch)
from repro.serving.fleet import _padded_instance, _strip_phantoms

# one (B, n, m) shape shared across the jax-path tests -> a single jit trace
B, N, M = 6, 6, 2
T = 1.5


def _fleet_instances(seed=0):
    return [paper_instance(N, T=T, seed=seed + s) for s in range(B)]


# ---------------------------------------------------------------------------
# InstanceBatch container
# ---------------------------------------------------------------------------
def test_instance_batch_stack_roundtrip():
    insts = _fleet_instances()
    batch = InstanceBatch.stack(insts)
    assert len(batch) == B and (batch.n, batch.m) == (N, M)
    got = batch[3]
    np.testing.assert_array_equal(got.p_ed, insts[3].p_ed)
    np.testing.assert_array_equal(got.p_es, insts[3].p_es)
    assert got.T == insts[3].T


def test_instance_batch_rejects_mixed_shapes():
    with pytest.raises(ValueError):
        InstanceBatch.stack([paper_instance(4, T=T), paper_instance(5, T=T)])
    with pytest.raises(ValueError):
        InstanceBatch.stack([])


# ---------------------------------------------------------------------------
# batched LP + batched AMR^2 vs the sequential NumPy oracle
# ---------------------------------------------------------------------------
def test_solve_lp_batch_matches_scalar_numpy():
    rng = np.random.default_rng(0)
    n, mc, nb = 8, 3, 5
    c = rng.normal(size=(nb, n))
    A_ub = rng.uniform(0, 1, size=(nb, mc, n))
    b_ub = rng.uniform(1, 3, size=(nb, mc))
    A_eq = np.ones((nb, 1, n))
    b_eq = np.ones((nb, 1))
    res = solve_lp_batch(c, A_ub, b_ub, A_eq, b_eq)
    for b in range(nb):
        ref = solve_lp(c[b], A_ub[b], b_ub[b], A_eq[b], b_eq[b],
                       backend="numpy")
        assert int(res.status[b]) == ref.status
        assert res.fun[b] == pytest.approx(ref.fun, abs=1e-8)


def test_amr2_batch_matches_numpy_oracle():
    insts = _fleet_instances(seed=10)
    scheds = amr2_batch(InstanceBatch.stack(insts))
    for sched, inst in zip(scheds, insts):
        oracle = amr2(inst)                     # per-device NumPy simplex
        assert sched.total_accuracy == pytest.approx(
            oracle.total_accuracy, abs=1e-6)
        assert sched.makespan <= 2 * inst.T + 1e-9          # Thm 1
        np.testing.assert_array_equal(sched.assignment, oracle.assignment)


def test_amr2_batch_heterogeneous_T_and_acc():
    insts = [random_instance(N, M, T=1.0 + 0.3 * s, seed=s)
             for s in range(B)]
    scheds = amr2_batch(InstanceBatch.stack(insts))
    for sched, inst in zip(scheds, insts):
        assert sched.total_accuracy == pytest.approx(
            amr2(inst).total_accuracy, abs=1e-6)


# ---------------------------------------------------------------------------
# plan_batch: grouping, fallbacks, ordering
# ---------------------------------------------------------------------------
def test_plan_batch_preserves_order_and_matches_oracle():
    insts = _fleet_instances(seed=20)
    plans = plan_batch(insts, backend="jax")
    oracle = plan_batch(insts, backend="numpy")
    assert len(plans) == len(insts)
    for p, o in zip(plans, oracle):
        assert p.policy == "amr2"
        assert p.schedule.total_accuracy == pytest.approx(
            o.schedule.total_accuracy, abs=1e-6)


def test_plan_batch_groups_mixed_shapes():
    mixed = [paper_instance(N, T=T, seed=1), paper_instance(N + 2, T=T,
                                                            seed=2),
             paper_instance(N, T=T, seed=3)]
    plans = plan_batch(mixed, backend="jax")
    for p, inst in zip(plans, mixed):
        assert len(p.schedule.assignment) == inst.n
        assert p.schedule.total_accuracy == pytest.approx(
            amr2(inst).total_accuracy, abs=1e-6)


def test_plan_batch_auto_keeps_amdp_dispatch():
    from repro.core import identical_instance
    mix = [identical_instance(N, M, T=1.0, seed=0),
           paper_instance(N, T=T, seed=0)]
    plans = plan_batch(mix, policy="auto")
    assert plans[0].policy == "amdp"    # identical jobs: exact DP, as plan()
    assert plans[1].policy == "amr2"


def test_plan_batch_bucketing_matches_oracle():
    # group sizes inside one power-of-two bucket share a trace AND results
    insts = _fleet_instances(seed=40)
    for g in (B - 1, B):                # 5 and 6 both bucket to 8
        for p, inst in zip(plan_batch(insts[:g]), insts[:g]):
            assert p.schedule.total_accuracy == pytest.approx(
                amr2(inst).total_accuracy, abs=1e-6)


def test_plan_batch_greedy_needs_numpy_backend():
    """Greedy has no batched path: the jax backend refuses loudly instead
    of silently running the sequential loop under a misleading tag."""
    insts = _fleet_instances(seed=30)
    with pytest.raises(ValueError, match="no batched path"):
        plan_batch(insts, policy="greedy", backend="jax")
    plans = plan_batch(insts, policy="greedy", backend="numpy")
    assert all(p.policy == "greedy" for p in plans)
    assert plan_batch([], backend="jax") == []


# ---------------------------------------------------------------------------
# request queue
# ---------------------------------------------------------------------------
def test_queue_backlog_conservation_and_cap():
    q = RequestQueue(3, (128, 512), rate=20.0, batch_max=4, seed=0)
    released = q.poll(0)
    assert all(len(r) <= 4 for r in released)
    assert q.total_arrived == q.total_released + q.backlog
    # heavy load: backlog drains oldest-first in later periods
    before = q.backlog
    q.poll(1)
    assert q.total_arrived == q.total_released + q.backlog
    assert before > 0


def test_queue_trace_mode_is_deterministic():
    trace = np.array([[2, 0], [1, 3]])
    q = RequestQueue(2, (128,), batch_max=8, trace=trace, seed=1)
    r0 = q.poll(0)
    assert [len(r) for r in r0] == [2, 0]
    r1 = q.poll(1)
    assert [len(r) for r in r1] == [1, 3]
    r2 = q.poll(2)                      # trace cycles
    assert [len(r) for r in r2] == [2, 0]


# ---------------------------------------------------------------------------
# ES pool admission
# ---------------------------------------------------------------------------
def test_pool_admits_within_capacity():
    pool = EdgeServerPool(2)
    demands = {0: 0.9, 1: 0.8, 2: 0.3, 3: 0.2}
    admitted, loads = pool.admit(demands, T=1.0)
    assert np.all(loads <= 1.0 + 1e-12)
    total = sum(demands[d] for d in admitted)
    assert total == pytest.approx(loads.sum())
    # ascending-demand first-fit: the two small demands always make it
    assert {2, 3} <= set(admitted)


def test_pool_bumps_excess_demand():
    pool = EdgeServerPool(1)
    admitted, loads = pool.admit({0: 0.9, 1: 0.9}, T=1.0)
    assert len(admitted) == 1 and loads[0] == pytest.approx(0.9)


# ---------------------------------------------------------------------------
# phantom padding
# ---------------------------------------------------------------------------
def _profile():
    return TierProfile(
        name="t", p_ed=np.array([[0.01, 0.04]]), p_es=np.array([0.35]),
        acc=np.array([0.4, 0.56, 0.77]), classes=[64])


def test_padding_is_invisible_to_the_real_schedule():
    prof = _profile()
    classes = np.full(4, 64)
    padded = _padded_instance(prof, classes, T, n_total=N, disable_es=False)
    assert padded.n == N
    real = prof.instance(classes, T)
    plain = plan(real, policy="amr2")
    pad_plan = plan(padded, policy="amr2")
    stripped = _strip_phantoms(pad_plan.schedule, 4)
    assert stripped.total_accuracy == pytest.approx(
        plain.schedule.total_accuracy, abs=1e-6)
    assert stripped.es_makespan == pytest.approx(
        plain.schedule.es_makespan, abs=1e-9)
    # phantoms are free on every tier: zero contribution to either budget
    phantom_assign = pad_plan.schedule.assignment[4:]
    phantom_cost = sum(padded.p(j, int(i))
                       for j, i in enumerate(phantom_assign, start=4))
    assert phantom_cost == 0.0


def test_padding_keeps_lp_conditioning():
    """Regression: a huge phantom p_es sentinel next to sub-second real p_es
    used to wipe out the ES budget row in the simplex (everything offloaded,
    es_makespan >> 2T).  Phantoms must not distort the real schedule."""
    prof = _profile()
    classes = np.full(8, 64)            # 8 * 0.35s of ES demand vs T = 1.5
    padded = _padded_instance(prof, classes, T, n_total=12, disable_es=False)
    stripped = _strip_phantoms(plan(padded, policy="amr2").schedule, 8)
    plain = plan(prof.instance(classes, T), policy="amr2").schedule
    assert stripped.es_makespan <= 2 * T + 1e-9             # Thm 1 holds
    assert stripped.total_accuracy == pytest.approx(
        plain.total_accuracy, abs=1e-6)
    np.testing.assert_array_equal(stripped.assignment, plain.assignment)


def test_padding_zero_jobs_and_outage():
    prof = _profile()
    empty = _padded_instance(prof, np.array([], dtype=int), T, n_total=N,
                             disable_es=False)
    assert empty.n == N and (empty.p_es == 0).all()
    outage = _padded_instance(prof, np.full(3, 64), T, n_total=N,
                              disable_es=True)
    assert (outage.p_es[:3] > T).all()  # ES infeasible -> planned ED-only
    assert (outage.p_es[3:] == 0).all()


# ---------------------------------------------------------------------------
# fleet engine end-to-end (numpy backend: no extra jit shapes in tier-1)
# ---------------------------------------------------------------------------
def _engine(n_devices=4, n_servers=1, rate=6.0, seed=0, specs=None, **kw):
    if specs is None:
        specs = [DeviceSpec(profile=_profile()) for _ in range(n_devices)]
    q = RequestQueue(len(specs), (64,), rate=rate, batch_max=N, seed=seed)
    return FleetEngine(specs, q, n_servers=n_servers, T=0.5,
                       backend="numpy", **kw)


def test_fleet_accounts_every_released_job():
    eng = _engine()
    stats = eng.run(3)
    released = eng.queue.total_released
    assert sum(s.n_jobs for s in stats) == released
    assert all(s.n_devices == 4 for s in stats)
    assert eng.summary()["periods"] == 3


def test_fleet_backpressure_replans_onto_ed():
    # one tiny server, lots of offload demand -> somebody must be bumped
    eng = _engine(n_devices=6, n_servers=1, rate=6.0, seed=2)
    stats = eng.run(3)
    assert sum(s.n_backpressured for s in stats) > 0
    assert all(s.es_utilization <= 1.0 + 1e-9 for s in stats)


def test_fleet_outage_device_never_offloads():
    specs = [DeviceSpec(profile=_profile(), outage=np.array([True]))
             for _ in range(2)]
    eng = _engine(specs=specs)
    s = eng.run_period()
    assert s.n_outage == 2
    assert s.n_offloading == 0          # ES disabled fleet-wide this period


def test_fleet_straggler_triggers_ema_update():
    specs = [DeviceSpec(profile=_profile(), drift=np.array([4.0]))]
    eng = _engine(specs=specs, rate=6.0, straggler_threshold=1.5, ema=0.5)
    s = eng.run_period()
    assert s.n_straggler_updates == 1
    dev = eng.devices[0]
    np.testing.assert_allclose(
        dev.profile.p_ed, _profile().p_ed * (0.5 + 0.5 * 4.0), rtol=1e-9)
    assert dev.n_updates == 1


def test_fleet_straggler_audit_converges_under_sustained_drift():
    """Regression: measured ED wall must be priced with the device's BASE
    profile, not the drifting belief — otherwise the audit sees the raw
    drift factor every period and the belief diverges geometrically."""
    base = _profile()
    specs = [DeviceSpec(profile=base, drift=np.array([3.0]))]
    eng = _engine(specs=specs, rate=6.0, straggler_threshold=1.5, ema=0.5)
    eng.run(8)
    ratio = eng.devices[0].profile.p_ed / base.p_ed
    assert np.all(ratio <= 3.0 + 1e-9)          # bounded by the true drift
    # once belief/truth is within threshold the audit stops firing
    assert all(s.n_straggler_updates == 0 for s in eng.history[3:])


def test_fleet_requires_matching_queue():
    with pytest.raises(ValueError):
        FleetEngine([DeviceSpec(profile=_profile())],
                    RequestQueue(2, (64,)), T=0.5)


def test_fleet_rejects_bad_class_tables():
    with pytest.raises(ValueError, match="no profile entry"):
        FleetEngine([DeviceSpec(profile=_profile())],
                    RequestQueue(1, (64, 128)), T=0.5)
    unsorted = TierProfile(
        name="u", p_ed=np.array([[0.01, 0.04], [0.02, 0.05]]),
        p_es=np.array([0.35, 0.4]), acc=np.array([0.4, 0.56, 0.77]),
        classes=[512, 128])
    with pytest.raises(ValueError, match="ascending"):
        FleetEngine([DeviceSpec(profile=unsorted)],
                    RequestQueue(1, (128, 512)), T=0.5)


def test_batched_backpressure_replan_matches_sequential():
    """The single batched ES-disabled solve must match the sequential
    `replan_without_es` loop device-for-device."""
    insts = _fleet_instances(seed=50)
    batch = InstanceBatch.stack(insts)
    fp = replan_without_es_batch(batch, policy="amr2")
    for b, inst in enumerate(insts):
        ref = replan_without_es(inst, policy="amr2")
        assert (fp.assignment[b] < inst.m).all()        # everything on ED
        got_acc = float(inst.acc[fp.assignment[b]].sum())
        assert got_acc == pytest.approx(
            ref.schedule.total_accuracy, abs=1e-6)
        ed = float(inst.p_ed[np.arange(inst.n), fp.assignment[b]].sum())
        assert ed == pytest.approx(ref.schedule.ed_makespan, abs=1e-9)


def test_batched_backpressure_replan_with_phantom_padding():
    """Phantom rows keep p_es = 0 (not the huge sentinel) and real-job
    decisions match the stripped sequential replan."""
    insts = _fleet_instances(seed=60)
    k = N - 2                          # last two jobs of each row = phantoms
    p_ed = np.stack([i.p_ed for i in insts])
    p_es = np.stack([i.p_es for i in insts])
    p_ed[:, k:] = 0.0
    p_es[:, k:] = 0.0
    batch = InstanceBatch(p_ed=p_ed, p_es=p_es,
                          acc=np.stack([i.acc for i in insts]),
                          T=np.array([i.T for i in insts]))
    mask = np.zeros((B, N), dtype=bool)
    mask[:, :k] = True
    fp = replan_without_es_batch(batch, real_mask=mask, policy="amr2")
    for b, inst in enumerate(insts):
        stripped = OffloadInstance(p_ed=inst.p_ed[:k], p_es=inst.p_es[:k],
                                   acc=inst.acc, T=inst.T)
        ref = replan_without_es(stripped, policy="amr2")
        assert (fp.assignment[b, :k] < inst.m).all()
        got_acc = float(inst.acc[fp.assignment[b, :k]].sum())
        assert got_acc == pytest.approx(
            ref.schedule.total_accuracy, abs=1e-6)


def test_batched_replan_auto_routes_identical_through_amdp():
    """Under policy="auto" the batched replan must keep the scalar
    dispatch: identical-job devices get the exact DP, bit-identical to the
    sequential `replan_without_es`."""
    from repro.core import identical_instance
    insts = [identical_instance(N, M, T=1.0 + 0.1 * s, seed=s)
             for s in range(B)]
    batch = InstanceBatch.stack(insts)
    fp = replan_without_es_batch(batch, policy="auto")
    assert all(s == "amdp" for s in fp.solver)
    for b, inst in enumerate(insts):
        ref = replan_without_es(inst, policy="auto")
        assert ref.schedule.solver == "amdp"
        np.testing.assert_array_equal(fp.assignment[b],
                                      ref.schedule.assignment)


def test_vectorized_engine_matches_reference_loop_jax():
    """Jax-backend engine parity: single-class arrivals make every bumped
    device's stripped instance identical-job, so this exercises the
    batched AMDP replan dispatch against the reference loop."""
    def build():
        specs = [DeviceSpec(profile=_profile()) for _ in range(4)]
        q = RequestQueue(4, (64,), rate=6.0, batch_max=N, seed=2)
        return FleetEngine(specs, q, n_servers=1, T=0.5, backend="jax")

    vec, ref = build(), build()
    for period in range(3):
        sv = vec.run_period()
        sr = ref.run_period_reference()
        for f in ("n_jobs", "n_violations", "n_offloading",
                  "n_backpressured", "n_outage", "n_straggler_updates",
                  "backlog"):
            assert getattr(sv, f) == getattr(sr, f), (period, f)
        assert sv.total_accuracy == pytest.approx(sr.total_accuracy,
                                                  abs=1e-6)
    assert sum(s.n_backpressured for s in vec.history) > 0


def test_vectorized_engine_matches_reference_loop():
    """The array-resident `run_period` must reproduce the PR-1 per-device
    reference loop stat-for-stat (numpy backend: both sides use the same
    scalar solvers, so the comparison isolates the vectorized assembly,
    admission, pricing, and audit bookkeeping)."""
    def build():
        specs = make_fleet(6, seed=3, horizon=8)
        q = RequestQueue(6, (128, 512, 1024), rate=8.0, batch_max=8, seed=3)
        return FleetEngine(specs, q, n_servers=1, T=1.2, backend="numpy")

    vec, ref = build(), build()
    for period in range(4):
        sv = vec.run_period()
        sr = ref.run_period_reference()
        for f in ("n_jobs", "n_violations", "n_offloading",
                  "n_backpressured", "n_outage", "n_straggler_updates",
                  "backlog", "n_devices"):
            assert getattr(sv, f) == getattr(sr, f), (period, f)
        assert sv.total_accuracy == pytest.approx(sr.total_accuracy,
                                                  abs=1e-9)
        assert sv.worst_violation == pytest.approx(sr.worst_violation,
                                                   abs=1e-9)
        assert sv.es_utilization == pytest.approx(sr.es_utilization,
                                                  abs=1e-12)
    # the straggler audits must have produced identical beliefs
    for dv, dr in zip(vec.devices, ref.devices):
        np.testing.assert_allclose(dv.profile.p_ed, dr.profile.p_ed,
                                   rtol=1e-12)


def test_run_period_delegation_matches_host_pipeline():
    """`run_period` on the jax backend now delegates to the engine-v2
    jitted period core; the legacy host pipeline (api solves + host
    admission/audit) must produce the same trajectories — ints exact,
    floats to summation-order tolerance."""
    def build(delegate):
        specs = make_fleet(6, seed=4, horizon=8, straggler_frac=0.0)
        q = RequestQueue(6, (128, 512, 1024), rate=8.0, batch_max=8,
                         seed=4)
        return FleetEngine(specs, q, n_servers=1, T=1.2, backend="jax",
                           policy="amr2", delegate=delegate)

    v2, host = build(True), build(False)    # delegate vs legacy pipeline
    assert v2._v2_params is not None
    assert host._v2_params is None
    for period in range(3):
        sv = v2.run_period()
        sh = host.run_period()
        for f in ("n_jobs", "n_violations", "n_offloading",
                  "n_backpressured", "n_outage", "n_straggler_updates",
                  "backlog"):
            assert getattr(sv, f) == getattr(sh, f), (period, f)
        assert sv.total_accuracy == pytest.approx(sh.total_accuracy,
                                                  abs=1e-9)
        assert sv.worst_violation == pytest.approx(sh.worst_violation,
                                                   abs=1e-12)
    for dv, dh in zip(v2.devices, host.devices):
        np.testing.assert_allclose(dv.profile.p_ed, dh.profile.p_ed,
                                   rtol=1e-12)
    np.testing.assert_array_equal(v2._groups[0].warm_basis,
                                  host._groups[0].warm_basis)


def test_engine_jax_dual_policy_runs():
    specs = [DeviceSpec(profile=_profile()) for _ in range(4)]
    q = RequestQueue(4, (64,), rate=6.0, batch_max=N, seed=1)
    eng = FleetEngine(specs, q, n_servers=1, T=0.5, backend="jax",
                      policy="dual")
    stats = eng.run(2)
    assert all(s.n_jobs >= 0 for s in stats)
    assert eng.summary()["periods"] == 2


def test_make_fleet_is_heterogeneous():
    specs = make_fleet(12, seed=0, roofline_frac=0.5)
    names = {s.profile.name for s in specs}
    assert {"paper-jittered", "roofline"} <= names
    assert all(s.profile.p_ed.shape[1] == 2 for s in specs)
