"""Differentiable serving stack: implicit-gradient simplex, smoothed
rounding/admission twins, the S=1 pool-admission bitwise pin, the pytree
partition helper, and finite-difference gates on jax.grad-able rollouts.

FD gates probe at JITTERED base points: the ladder generator's p_es
values land exactly on LP vertex boundaries where the optimum has only
one-sided derivatives (the implicit VJP returns the subgradient of the
converged basis; central FD averages the two sides).  A ~1e-3 nudge
moves the base into a linearity region where both must agree to 1e-4.
"""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp

from repro.api import engine as E
from repro.core.mobility import admit_mask_pool
from repro.serving import FleetConfig

RTOL = 1e-4
ATOL = 1e-6            # absolute floor for ~zero gradients


def _config(n_devices=8, *, seed=0, horizon=6, n_servers=2, rate=9.0):
    return FleetConfig(n_devices=n_devices, T=1.2, n_servers=n_servers,
                       policy="amr2", backend="jax", rate=rate,
                       batch_max=8, horizon=horizon, seed=seed,
                       straggler_frac=0.25, outage_frac=0.1)


def _diff_params(seed, *, smooth_mode="soft", jitter=True):
    params = E.EngineParams.from_config(
        _config(seed=seed), horizon=6).with_differentiable(
            smooth_mode=smooth_mode)
    if jitter:
        rng = np.random.default_rng(1000 + seed)
        arr = np.asarray(params.p_es, np.float64)
        nudge = (rng.uniform(1e-3, 3e-3, size=arr.shape)
                 * rng.choice([-1.0, 1.0], size=arr.shape))
        params = dataclasses.replace(params, p_es=arr + nudge)
    return params


def _value(params, periods=4):
    _, m = E.rollout(E.init_state(params), params, periods)
    return float(np.sum(np.asarray(m.total_accuracy)))


def _fd_leaf(params, leaf, idx, eps=1e-5, periods=4):
    base = np.asarray(getattr(params, leaf), np.float64)
    flat = np.atleast_1d(base).ravel()
    up, dn = flat.copy(), flat.copy()
    up[idx] += eps
    dn[idx] -= eps
    shape = np.shape(base)
    mk = lambda f: dataclasses.replace(
        params, **{leaf: f.reshape(shape) if shape else float(f[0])})
    return (_value(mk(up), periods) - _value(mk(dn), periods)) / (2 * eps)


def _assert_close(fd, an, label):
    if abs(fd - an) < ATOL:
        return
    rel = abs(fd - an) / max(abs(fd), abs(an))
    assert rel < RTOL, f"{label}: fd={fd!r} analytic={an!r} rel={rel:.3e}"


# ---------------------------------------------------------------------------
# LP layer: the implicit-function VJP of the converged simplex optimum
# ---------------------------------------------------------------------------
def _lp_batch(seed, nb=4, n=6, mc=3):
    rng = np.random.default_rng(seed)
    c = rng.normal(size=(nb, n))
    A_ub = rng.uniform(0, 1, size=(nb, mc, n))
    b_ub = rng.uniform(1, 3, size=(nb, mc))
    A_eq = np.ones((nb, 1, n))
    b_eq = np.ones((nb, 1))
    return c, A_ub, b_ub, A_eq, b_eq


def _canon(seed, **kw):
    from repro.core.lp import _canonicalize_batch
    A, b, cf, nv, _ = _canonicalize_batch(*_lp_batch(seed, **kw))
    return np.asarray(A), np.asarray(b), np.asarray(cf), nv


@pytest.mark.parametrize("method", ["tableau", "revised"])
def test_lp_grad_forward_bitwise_matches_core(method):
    """simplex_batch_grad's forward pass IS simplex_batch_core — same
    pivots, same outputs, bit for bit (the VJP only attaches a backward
    rule)."""
    from jax.experimental import enable_x64

    from repro.core.lp import simplex_batch_core, simplex_batch_grad
    A, b, cf, nv = _canon(0)
    with enable_x64():
        args = (jnp.asarray(A), jnp.asarray(b), jnp.asarray(cf), None)
        kw = dict(nv=nv, maxiter=200, method=method)
        ref = simplex_batch_core(*args, **kw)
        out = simplex_batch_grad(*args, **kw)
    for r, o in zip(ref, out):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(o))


def _lp_fd_probe(seed, n_probes=3, eps=1e-6):
    """FD-check d/d(b, c) of a random linear functional of (x, fun)."""
    from jax.experimental import enable_x64

    from repro.core.lp import OPTIMAL, simplex_batch_grad
    A, b, cf, nv = _canon(seed)
    rng = np.random.default_rng(seed + 77)
    wx = rng.normal(size=(A.shape[0], nv))
    wf = rng.normal(size=A.shape[0])

    with enable_x64():
        def loss(b_, c_):
            x, fun, status, *_ = simplex_batch_grad(
                jnp.asarray(A), b_, c_, None, nv=nv, maxiter=200)
            ok = (status == OPTIMAL)[:, None]
            return (jnp.sum(jnp.where(ok, wx * x[:, :nv], 0.0))
                    + jnp.sum(jnp.where(ok[:, 0], wf * fun, 0.0)))

        lval = jax.jit(jax.value_and_grad(loss, argnums=(0, 1)))
        val, (gb, gc) = lval(jnp.asarray(b), jnp.asarray(cf))
        val, gb, gc = float(val), np.asarray(gb), np.asarray(gc)

        fl = jax.jit(loss)
        for arr, g, name in ((b, gb, "b"), (cf, gc, "c")):
            flat = arr.ravel()
            for idx in rng.choice(flat.size, size=n_probes, replace=False):
                up, dn = flat.copy(), flat.copy()
                up[idx] += eps
                dn[idx] -= eps
                pert = lambda f: (jnp.asarray(f.reshape(arr.shape)
                                              if name == "b" else b),
                                  jnp.asarray(f.reshape(arr.shape)
                                              if name == "c" else cf))
                fd = (float(fl(*pert(up))) - float(fl(*pert(dn)))) \
                    / (2 * eps)
                _assert_close(fd, g.ravel()[idx],
                              f"seed={seed} {name}[{idx}]")


@pytest.mark.parametrize("seed", [0, 1])
def test_lp_implicit_vjp_matches_fd(seed):
    _lp_fd_probe(seed)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=10, max_value=2000))
def test_lp_implicit_vjp_matches_fd_hypothesis(seed):
    _lp_fd_probe(seed, n_probes=1)


def test_lp_masked_lane_cotangents_zero():
    """Masked lanes carry garbage tableaus — their input cotangents must
    be EXACTLY zero, not NaN-contaminated."""
    from jax.experimental import enable_x64

    from repro.core.lp import simplex_batch_grad
    A, b, cf, nv = _canon(3)
    mask = np.array([True, False, True, False])
    with enable_x64():
        def loss(b_):
            x, fun, *_ = simplex_batch_grad(
                jnp.asarray(A), b_, jnp.asarray(cf), None, nv=nv,
                maxiter=200, lane_mask=jnp.asarray(mask))
            return jnp.sum(jnp.where(jnp.asarray(mask), fun, 0.0))

        gb = np.asarray(jax.jit(jax.grad(loss))(jnp.asarray(b)))
    np.testing.assert_array_equal(gb[~mask], 0.0)
    assert np.all(np.isfinite(gb))


def test_lp_grad_int_outputs_are_fences():
    """status/niter/basis outputs must yield float0/zero cotangents, and
    differentiating THROUGH them must not be attempted by jax (they are
    integer outputs — grad of the float outputs alone must trace)."""
    from jax.experimental import enable_x64

    from repro.core.lp import simplex_batch_grad
    A, b, cf, nv = _canon(5)
    with enable_x64():
        # warm restart from the converged basis, THEN differentiate: the
        # basis0 int input gets a symbolic-zero cotangent internally.
        _, _, _, _, bases, _ = simplex_batch_grad(
            jnp.asarray(A), jnp.asarray(b), jnp.asarray(cf), None,
            nv=nv, maxiter=200)

        def loss(b_):
            _, fun, *_ = simplex_batch_grad(
                jnp.asarray(A), b_, jnp.asarray(cf), bases, nv=nv,
                maxiter=200)
            return jnp.sum(fun)

        gb = np.asarray(jax.jit(jax.grad(loss))(jnp.asarray(b)))
    assert np.all(np.isfinite(gb)) and np.any(gb != 0.0)


# ---------------------------------------------------------------------------
# S=1 admission: round-robin pool scan == sequential first-fit, bitwise
# ---------------------------------------------------------------------------
def _pool_case(rng, D, k):
    kind = rng.integers(0, 4)
    if kind == 0:        # heavy ties
        d = rng.choice([0.3, 0.6, 0.6, 1.2], size=D)
    elif kind == 1:      # near-capacity chains
        d = rng.uniform(0.35, 0.65, size=D)
    elif kind == 2:      # tiny demands, deep chains
        d = rng.uniform(1e-3, 0.05, size=D)
    else:                # mixed with non-offloaders
        d = rng.uniform(-0.2, 0.9, size=D)
    d[rng.random(D) < 0.2] = 0.0
    return d


@pytest.mark.parametrize("D,k", [(8, 2), (7, 3), (16, 1), (3, 5), (24, 4)])
def test_admit_pool_bitwise_matches_sequential(D, k):
    T = 1.2
    for rep in range(4):
        rng = np.random.default_rng(100 * D + 10 * k + rep)
        d = jnp.asarray(_pool_case(rng, D, k), jnp.float64)
        m_ref, l_ref = E.admit_mask_jnp(d, T, k)
        m_new, l_new, inc = admit_mask_pool(d, T, k)
        np.testing.assert_array_equal(np.asarray(m_ref),
                                      np.asarray(m_new))
        np.testing.assert_array_equal(np.asarray(l_ref),
                                      np.asarray(l_new))
        # inc is the inclusive chain load the first-fit compares vs T:
        # admitted devices must satisfy it, by the same <= as the scan.
        inc = np.asarray(inc)
        assert np.all(inc[np.asarray(m_new)] <= T + 1e-12)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       D=st.integers(min_value=1, max_value=24),
       k=st.integers(min_value=1, max_value=6))
def test_admit_pool_bitwise_hypothesis(seed, D, k):
    rng = np.random.default_rng(seed)
    d = jnp.asarray(_pool_case(rng, D, k), jnp.float64)
    m_ref, l_ref = E.admit_mask_jnp(d, 1.2, k)
    m_new, l_new, _ = admit_mask_pool(d, 1.2, k)
    np.testing.assert_array_equal(np.asarray(m_ref), np.asarray(m_new))
    np.testing.assert_array_equal(np.asarray(l_ref), np.asarray(l_new))


# ---------------------------------------------------------------------------
# engine: smoothed twins, FD gates, forward pins
# ---------------------------------------------------------------------------
def test_st_forward_matches_hard_rollout():
    """smooth_mode='st' is a straight-through twin: the FORWARD value is
    the hard rollout's served accuracy (backward is softened).  Only the
    contraction order differs (one-hot einsum vs where-select), so allow
    roundoff but nothing more."""
    params = _diff_params(0, smooth_mode="st", jitter=False)
    hard = dataclasses.replace(params, differentiable=False)
    val, grads = E.rollout_value_and_grad(
        E.init_state(params), params, 4)
    np.testing.assert_allclose(float(val), _value(hard, 4),
                               rtol=0, atol=1e-9)
    assert set(grads) == set(params.grad_leaves)
    for f, g in grads.items():
        assert np.shape(np.asarray(g)) == np.shape(
            np.asarray(getattr(params, f))), f


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_rollout_grad_matches_fd(seed):
    """The acceptance gate: jax.grad of rolled-out total accuracy w.r.t.
    ES capacity (p_es), deadline (T), and ladder mix (acc) matches
    central finite differences to rtol 1e-4 (soft mode, jittered base —
    see module docstring)."""
    params = _diff_params(seed, smooth_mode="soft")
    val, grads = E.rollout_value_and_grad(
        E.init_state(params), params, 4, wrt=("p_es", "T", "acc"))
    assert np.isfinite(float(val))
    rng = np.random.default_rng(seed + 55)

    g_es = np.asarray(grads["p_es"], np.float64).ravel()
    for idx in rng.choice(g_es.size, size=2, replace=False):
        _assert_close(_fd_leaf(params, "p_es", idx), g_es[idx],
                      f"seed={seed} p_es[{idx}]")

    _assert_close(_fd_leaf(params, "T", 0),
                  float(np.asarray(grads["T"])), f"seed={seed} T")

    g_acc = np.asarray(grads["acc"], np.float64).ravel()
    idx = int(rng.integers(g_acc.size))
    _assert_close(_fd_leaf(params, "acc", idx), g_acc[idx],
                  f"seed={seed} acc[{idx}]")


def test_rollout_grad_default_wrt_and_nonzero():
    params = _diff_params(0, smooth_mode="soft")
    grads = E.rollout_grad(E.init_state(params), params, 4)
    assert set(grads) == set(params.grad_leaves)
    norms = {f: float(jnp.linalg.norm(jnp.asarray(g, jnp.float64)))
             for f, g in grads.items()}
    assert all(np.isfinite(v) for v in norms.values())
    assert norms["p_es"] > 0 and norms["acc"] > 0


# ---------------------------------------------------------------------------
# partition helper: grad over the float half of a mixed pytree
# ---------------------------------------------------------------------------
def test_partition_diff_regression():
    """The bug this helper fixes: jax.grad over a full EngineState dies
    on the int32/uint32 bookkeeping leaves.  Partitioned, the same
    objective differentiates, and combine_diff round-trips bitwise."""
    params = E.EngineParams.from_config(_config(), horizon=6)
    state = E.init_state(params)

    with pytest.raises(TypeError):
        jax.grad(lambda s: jnp.sum(s.p_ed))(state)

    diff, nondiff = E.partition_diff(state)
    back = E.combine_diff(diff, nondiff)
    for f in E._STATE_FIELDS:
        for a, b in zip(jax.tree.leaves(getattr(back, f)),
                        jax.tree.leaves(getattr(state, f))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b), f)

    g = jax.grad(
        lambda d: jnp.sum(E.combine_diff(d, nondiff).p_ed))(diff)
    np.testing.assert_array_equal(np.asarray(g.p_ed),
                                  np.ones_like(np.asarray(state.p_ed)))
    # int leaves stayed in the nondiff half: sentinel in the diff tree
    assert diff.pending is E._NONDIFF and diff.key is E._NONDIFF


def test_partition_diff_keeps_f64():
    """partition_diff must not silently downcast f64 leaves (jnp.asarray
    outside an enable_x64 scope would)."""
    params = E.EngineParams.from_config(_config(), horizon=6)
    diff, _ = E.partition_diff(E.init_state(params))
    assert diff.p_ed.dtype == jnp.float64


# ---------------------------------------------------------------------------
# validators
# ---------------------------------------------------------------------------
def test_with_differentiable_validators():
    params = E.EngineParams.from_config(_config(), horizon=6)
    with pytest.raises(ValueError, match="smooth_mode"):
        params.with_differentiable(smooth_mode="gumbel")
    with pytest.raises(ValueError, match="must be > 0"):
        params.with_differentiable(smooth_tau=0.0)
    with pytest.raises(ValueError, match="not differentiable"):
        params.with_differentiable(grad_leaves=("warm_basis",))
    with pytest.raises(ValueError, match="chaos"):
        from repro.core.faults import FaultModel
        params.with_faults(FaultModel.make(es_crash_prob=0.1),
                           fault_seed=1).with_differentiable()
    # armed HI is discrete per-sample gating: the relaxation must refuse
    with pytest.raises(ValueError, match="HI disarmed"):
        from repro.core.hi import HIModel
        params.with_hi(HIModel.make(),
                       rule="threshold").with_differentiable()

    # disarm round-trips to a hard-path params value
    off = params.with_differentiable().with_differentiable(False)
    assert not off.differentiable


def test_grad_entry_requires_flag():
    params = E.EngineParams.from_config(_config(), horizon=6)
    with pytest.raises(ValueError, match="with_differentiable"):
        E.rollout_grad(E.init_state(params), params, 2)
    armed = params.with_differentiable()
    with pytest.raises(ValueError, match="not differentiable"):
        E.rollout_grad(E.init_state(armed), armed, 2, wrt=("stream",))
