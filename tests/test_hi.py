"""Online hierarchical inference: `HIModel`/`HILearnerState` pytrees, the
calibrated confidence stream, the traced decision rules, engine/fleet
wiring (armed-null pin, replay == fold, run == rollout parity), the
regret accounting, and the registry's online solvers."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax

from repro import api
from repro.api import engine as E
from repro.core.hi import (HILearnerState, HIModel, _draw_uniforms,
                           presample_stream, sample_confidence,
                           validate_hi)
from repro.serving import FleetConfig, FleetEngine


def _config(n_devices=8, *, policy="amr2", seed=5, horizon=40, rate=9.0,
            n_servers=2, batch_max=8, **extra):
    return FleetConfig(n_devices=n_devices, T=1.2, n_servers=n_servers,
                       policy=policy, backend="jax", rate=rate,
                       batch_max=batch_max, horizon=horizon, seed=seed,
                       straggler_frac=0.25, outage_frac=0.1, **extra)


def _armed(params, rule="threshold", *, hm=None, **kw):
    hm = HIModel.make() if hm is None else hm
    return params.with_hi(hm, rule=rule, **kw)


def _theta_star(params):
    """(D,) clairvoyant threshold: clip(acc_es - beta, 0, 1)."""
    beta = float(np.asarray(params.hi.offload_cost))
    return np.clip(np.asarray(params.acc)[:, params.m] - beta, 0.0, 1.0)


# ---------------------------------------------------------------------------
# HIModel: construction, validation, pytree plumbing
# ---------------------------------------------------------------------------
def test_hi_model_none_is_null_and_make_validates():
    assert HIModel.none().is_null()
    assert not HIModel.make().is_null()
    with pytest.raises(ValueError, match="spread"):
        HIModel.make(spread=1.5)
    with pytest.raises(ValueError, match="offload_cost"):
        HIModel.make(offload_cost=1.0)
    with pytest.raises(ValueError, match="lr and tau"):
        HIModel.make(lr=0.0)
    with pytest.raises(ValueError, match="theta0"):
        HIModel.make(theta0=-0.1)
    with pytest.raises(ValueError, match="conf_trace"):
        HIModel.make(conf_trace=np.zeros((2, 4, 8)))
    # pytree round-trip keeps leaves bit-for-bit
    hm = HIModel.make(spread=[0.2, 0.9], theta0=0.4)
    leaves, tree = jax.tree_util.tree_flatten(hm)
    back = jax.tree_util.tree_unflatten(tree, leaves)
    for a, b in zip(leaves, jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_from_profiles_ranks_spread_by_latency():
    """Slower (higher mean-latency) classes must get the larger spreads,
    and the (D, c, m) stacked table reduces like the (c, m) one."""
    p_ed = np.array([[0.3, 0.2], [0.1, 0.05], [0.6, 0.5]])
    hm = HIModel.from_profiles(p_ed, spread_range=(0.2, 0.8))
    assert hm.spread.shape == (3,)
    order = np.argsort(p_ed.mean(axis=1))
    assert np.all(np.diff(hm.spread[order]) > 0)
    assert hm.spread.min() == 0.2 and hm.spread.max() == 0.8
    stacked = np.broadcast_to(p_ed, (5, 3, 2))
    np.testing.assert_array_equal(
        HIModel.from_profiles(stacked, spread_range=(0.2, 0.8)).spread,
        hm.spread)
    with pytest.raises(ValueError, match="spread_range"):
        HIModel.from_profiles(p_ed, spread_range=(0.9, 0.2))


def test_validate_hi_errors():
    hm = HIModel.make()
    kw = dict(n_devices=4, n_classes=3, n_models=2, stream="fold",
              n_arms=9, local_model=0)
    with pytest.raises(ValueError, match="unknown HI rule"):
        validate_hi(hm, rule="softmax", **kw)
    with pytest.raises(ValueError, match="unknown HI stream"):
        validate_hi(hm, rule="fixed", **{**kw, "stream": "mmap"})
    with pytest.raises(ValueError, match="n_arms"):
        validate_hi(hm, rule="ucb", **{**kw, "n_arms": 1})
    with pytest.raises(ValueError, match="local model"):
        validate_hi(hm, rule="fixed", **{**kw, "local_model": 2})
    with pytest.raises(ValueError, match="spread"):
        validate_hi(HIModel.make(spread=[0.5, 0.5]), rule="fixed", **kw)
    with pytest.raises(ValueError, match="theta0"):
        validate_hi(HIModel.make(theta0=[0.5, 0.5]), rule="fixed", **kw)
    with pytest.raises(ValueError, match="conf_trace"):
        validate_hi(hm, rule="fixed", **{**kw, "stream": "replay"})
    with pytest.raises(ValueError, match="batch_max"):
        validate_hi(HIModel.make(conf_trace=np.zeros((2, 4, 6, 3))),
                    rule="fixed", **{**kw, "stream": "replay"},
                    batch_max=8)


# ---------------------------------------------------------------------------
# the calibrated confidence stream
# ---------------------------------------------------------------------------
def test_confidence_is_mean_preserving_and_calibrated():
    """E[conf] == acc_local and P(correct | conf) == conf (binned), for
    both tight and wide spreads; ES outcomes are Bernoulli(acc_es)."""
    from jax.experimental import enable_x64
    D, n = 4, 20_000
    acc_local = np.array([0.55, 0.7, 0.8, 0.92])
    acc_es = np.array([0.9, 0.85, 0.95, 0.97])
    hm = HIModel.make(spread=0.8)
    ci = np.zeros((D, n), np.int32)
    with enable_x64():
        conf, cl, ces = sample_confidence(
            jax.random.PRNGKey(3), hm, acc_local, acc_es, ci)
    conf, cl, ces = (np.asarray(x) for x in (conf, cl, ces))
    np.testing.assert_allclose(conf.mean(axis=1), acc_local, atol=0.01)
    np.testing.assert_allclose(cl.mean(axis=1), acc_local, atol=0.02)
    np.testing.assert_allclose(ces.mean(axis=1), acc_es, atol=0.02)
    # calibration: within a confidence bin, the local hit-rate is the bin
    for d in range(D):
        for lo in (0.3, 0.5, 0.7):
            sel = (conf[d] >= lo) & (conf[d] < lo + 0.2)
            if sel.sum() > 500:
                assert abs(cl[d, sel].mean() - conf[d, sel].mean()) < 0.05
    # spread really spreads: wider spread -> wider confidence swings
    with enable_x64():
        conf0, _, _ = sample_confidence(
            jax.random.PRNGKey(3), HIModel.make(spread=0.1), acc_local,
            acc_es, ci)
    assert np.std(np.asarray(conf0)) < np.std(conf)


def test_draw_uniforms_gid_offset_matches_global_slice():
    """A shard drawing with its global-id offset reproduces exactly its
    rows of the full-fleet draw — the 8-shard-safe fold contract."""
    from jax.experimental import enable_x64
    D, n, S = 4, 6, 3
    with enable_x64():
        key = jax.random.PRNGKey(11)
        full = np.asarray(_draw_uniforms(key, S * D, n))
        for s in range(S):
            shard = np.asarray(
                _draw_uniforms(key, D, n, gid_offset=s * D))
            np.testing.assert_array_equal(shard, full[s * D:(s + 1) * D])


def test_presample_stream_replays_the_fold_keyed_draws():
    """`presample_stream` must reproduce the armed engine's per-period
    uniforms bit for bit (fold seed by t, split off the confidence key,
    fold global device ids)."""
    from jax.experimental import enable_x64
    tr = presample_stream(7, 3, 5, periods=4)
    assert tr.shape == (4, 3, 5, 3)
    with enable_x64():
        base = jax.random.PRNGKey(7)
        for t in range(4):
            kc, _ = jax.random.split(jax.random.fold_in(base, t))
            np.testing.assert_array_equal(
                tr[t], np.asarray(_draw_uniforms(kc, 3, 5)))


# ---------------------------------------------------------------------------
# arming / interplay validators
# ---------------------------------------------------------------------------
def test_with_hi_validates_and_disarms():
    params = E.EngineParams.from_config(_config(), horizon=6)
    assert not params.hi_armed
    armed = _armed(params)
    assert armed.hi_armed and armed.hi_rule == "threshold"
    off = armed.with_hi(None)
    assert not off.hi_armed and off.hi.is_null()
    with pytest.raises(ValueError, match="unknown HI rule"):
        _armed(params, rule="softmax")
    with pytest.raises(ValueError, match="local model"):
        _armed(params, local_model=params.m)


def test_hi_and_other_subsystems_are_mutually_exclusive():
    from repro.core.faults import FaultModel
    from repro.core.mobility import MobilityModel
    params = E.EngineParams.from_config(_config(), horizon=8)
    armed = _armed(params)
    fm = FaultModel.make(es_crash_prob=0.1)
    trace = np.zeros((8, params.n_devices, 2))
    mob = MobilityModel.make(cell_xy=np.zeros((1, 2)), trace=trace)
    # arming HI second
    with pytest.raises(ValueError, match="chaos disarmed"):
        _armed(params.with_faults(fm, fault_seed=1))
    with pytest.raises(ValueError, match="mobility off"):
        _armed(params.with_mobility(mob))
    with pytest.raises(ValueError, match="differentiable"):
        _armed(params.with_differentiable())
    # arming HI first
    with pytest.raises(ValueError, match="HI disarmed"):
        armed.with_faults(fm, fault_seed=1)
    with pytest.raises(ValueError, match="HI disarmed"):
        armed.with_mobility(mob)
    with pytest.raises(ValueError, match="HI disarmed"):
        armed.with_differentiable()


def test_sharded_entry_points_reject_armed_hi():
    params = E.EngineParams.from_config(_config(), horizon=6)
    armed = _armed(params)
    state = E.init_state(armed)
    for call in (lambda: E.shard(state, armed, None),
                 lambda: E.step_sharded(state, armed, None),
                 lambda: E.rollout_sharded(state, armed, 2, None)):
        with pytest.raises(ValueError, match="sharded entry points"):
            call()


# ---------------------------------------------------------------------------
# engine wiring: the armed-null pin and the arrival-stream invariant
# ---------------------------------------------------------------------------
def test_hi_off_rollout_is_bitwise_pinned():
    """Disarming via `with_hi(None)` (a round-trip through arming) must
    reproduce the default rollout BIT for BIT on every metric and state
    leaf: the subsystem is invisible while ``hi_rule == "off"``."""
    periods = 10
    params = E.EngineParams.from_config(_config(), horizon=periods + 2)
    round_trip = _armed(params).with_hi(None)
    s0, m0 = E.rollout(E.init_state(params), params, periods)
    s1, m1 = E.rollout(E.init_state(round_trip), round_trip, periods)
    for f in E._METRIC_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(m0, f)),
                                      np.asarray(getattr(m1, f)), f)
    for f in E._STATE_FIELDS:
        for a, b in zip(jax.tree.leaves(getattr(s0, f)),
                        jax.tree.leaves(getattr(s1, f))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b), f)
    # the HI counters are exact zeros while disarmed
    for f in ("n_hi_offloaded", "n_hi_local_final", "hi_regret"):
        assert np.asarray(getattr(m0, f)).sum() == 0, f


def test_arming_hi_leaves_arrivals_untouched():
    """The confidence stream folds its own seed: arming must not perturb
    the arrival PRNG, backlog, or per-period job counts."""
    periods = 10
    params = E.EngineParams.from_config(_config(), horizon=periods + 2)
    armed = _armed(params)
    s0, m0 = E.rollout(E.init_state(params), params, periods)
    s1, m1 = E.rollout(E.init_state(armed), armed, periods)
    np.testing.assert_array_equal(np.asarray(s0.key), np.asarray(s1.key))
    np.testing.assert_array_equal(np.asarray(s0.head),
                                  np.asarray(s1.head))
    np.testing.assert_array_equal(np.asarray(m0.n_jobs),
                                  np.asarray(m1.n_jobs))


def test_armed_rollout_is_deterministic_and_seed_sensitive():
    periods = 8
    params = E.EngineParams.from_config(_config(), horizon=periods + 2)
    armed = _armed(params, hi_seed=3)
    _, m0 = E.rollout(E.init_state(armed), armed, periods)
    _, m1 = E.rollout(E.init_state(armed), armed, periods)
    for f in ("total_accuracy", "n_hi_offloaded", "hi_regret"):
        np.testing.assert_array_equal(np.asarray(getattr(m0, f)),
                                      np.asarray(getattr(m1, f)), f)
    other = _armed(params, hi_seed=4)
    _, m2 = E.rollout(E.init_state(other), other, periods)
    assert not np.array_equal(np.asarray(m0.hi_regret),
                              np.asarray(m2.hi_regret))


def test_replay_stream_equals_fold_stream():
    """`presample_stream` fed back via ``stream="replay"`` pins the
    replayed rollout bitwise to the fold-keyed one."""
    periods = 8
    cfg = _config()
    params = E.EngineParams.from_config(cfg, horizon=periods + 2)
    fold = _armed(params, hi_seed=5)
    tr = presample_stream(5, params.n_devices, params.batch_max,
                          periods + 2)
    replay = params.with_hi(HIModel.make(conf_trace=tr), rule="threshold",
                            stream="replay", hi_seed=5)
    sf, mf = E.rollout(E.init_state(fold), fold, periods)
    sr, mr = E.rollout(E.init_state(replay), replay, periods)
    for f in E._METRIC_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(mf, f)),
                                      np.asarray(getattr(mr, f)), f)
    np.testing.assert_array_equal(np.asarray(sf.hi.theta),
                                  np.asarray(sr.hi.theta))


@pytest.mark.parametrize("rule", ["fixed", "threshold", "ucb", "exp3"])
def test_accounting_identity_every_period(rule):
    """Every admitted sample is served exactly once: n_hi_offloaded +
    n_hi_local_final == n_jobs, per period, for every rule."""
    periods = 10
    params = E.EngineParams.from_config(_config(), horizon=periods + 2)
    armed = _armed(params, rule=rule)
    _, m = E.rollout(E.init_state(armed), armed, periods)
    off = np.asarray(m.n_hi_offloaded)
    loc = np.asarray(m.n_hi_local_final)
    np.testing.assert_array_equal(off + loc, np.asarray(m.n_jobs))
    assert np.asarray(m.hi_regret).min() >= 0.0
    # cumulative regret is nondecreasing over the horizon
    assert np.all(np.diff(np.asarray(m.hi_regret)) >= -1e-12)


def test_run_matches_rollout_bitwise_with_hi():
    """The Python-loop `FleetEngine.run` and the scanned `rollout` follow
    the same armed trajectory bit for bit — counters, accuracy, regret,
    and the learner state."""
    periods = 12
    hm = HIModel.make()
    cfg = _config(hi=hm, hi_rule="threshold", hi_seed=2)
    eng = FleetEngine.from_config(cfg)
    assert eng._v2_params is not None
    params = E.EngineParams.from_config(cfg, horizon=40).with_hi(
        hm, rule="threshold", hi_seed=2)
    state, metrics = E.rollout(E.init_state(params), params, periods)
    stats = eng.run(periods)
    for i, s in enumerate(stats):
        assert int(np.asarray(metrics.n_hi_offloaded)[i]) == \
            s.n_hi_offloaded, i
        assert int(np.asarray(metrics.n_hi_local_final)[i]) == \
            s.n_hi_local_final, i
        assert float(np.asarray(metrics.hi_regret)[i]) == s.hi_regret, i
        assert float(np.asarray(metrics.total_accuracy)[i]) == \
            s.total_accuracy, i
    np.testing.assert_array_equal(np.asarray(state.hi.theta),
                                  np.asarray(eng._v2_hi_state.theta))


# ---------------------------------------------------------------------------
# learning: the clairvoyant floor, convergence, and the bandit baselines
# ---------------------------------------------------------------------------
def test_clairvoyant_fixed_threshold_has_zero_regret():
    """rule="fixed" with per-device theta0 = clip(acc_es - beta, 0, 1)
    IS the clairvoyant: its pseudo-regret is exactly 0.0."""
    periods = 12
    params = E.EngineParams.from_config(_config(), horizon=periods + 2)
    beta = 0.15
    theta_star = np.clip(
        np.asarray(params.acc)[:, params.m] - beta, 0.0, 1.0)
    armed = params.with_hi(HIModel.make(theta0=theta_star,
                                        offload_cost=beta), rule="fixed")
    _, m = E.rollout(E.init_state(armed), armed, periods)
    assert float(np.asarray(m.hi_regret)[-1]) == 0.0


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2**16))
def test_threshold_learner_converges_sublinearly(hi_seed):
    """The OGD learner on a replayed stream: the final threshold lands
    near theta* = acc_es - beta and the cumulative regret is sublinear
    (second-half increment < first-half increment)."""
    periods = 48
    params = E.EngineParams.from_config(_config(), horizon=periods + 2)
    armed = _armed(params, hi_seed=hi_seed)
    state, m = E.rollout(E.init_state(armed), armed, periods)
    theta_star = _theta_star(armed)
    err = np.abs(np.asarray(state.hi.theta) - theta_star)
    assert err.mean() < 0.1, (np.asarray(state.hi.theta), theta_star)
    reg = np.asarray(m.hi_regret)
    first = reg[periods // 2 - 1] - reg[0]
    second = reg[-1] - reg[periods // 2 - 1]
    assert second < first, (first, second)


def test_threshold_learner_beats_miscalibrated_fixed():
    """At a 32-period horizon the learner's cumulative regret undercuts a
    fixed rule whose threshold starts equally wrong (theta0 = 0.5 shared;
    theta* sits near 0.6 for these fleets)."""
    periods = 32
    params = E.EngineParams.from_config(_config(), horizon=periods + 2)
    fixed = _armed(params, rule="fixed")
    learn = _armed(params, rule="threshold")
    _, mf = E.rollout(E.init_state(fixed), fixed, periods)
    _, ml = E.rollout(E.init_state(learn), learn, periods)
    assert float(np.asarray(ml.hi_regret)[-1]) < \
        float(np.asarray(mf.hi_regret)[-1])


@pytest.mark.parametrize("rule", ["ucb", "exp3"])
def test_bandit_rules_learn_and_stay_on_the_grid(rule):
    """Bandits pull arms from `arm_grid`, book one pull per device per
    period, and accrue regret no worse than linear-in-periods times the
    worst single-period regret."""
    periods = 16
    params = E.EngineParams.from_config(_config(), horizon=periods + 2)
    armed = _armed(params, rule=rule, n_arms=5)
    state, m = E.rollout(E.init_state(armed), armed, periods)
    cnt = np.asarray(state.hi.arms_cnt)
    assert cnt.shape == (params.n_devices, 5)
    np.testing.assert_allclose(cnt.sum(axis=1), periods)
    grid = np.linspace(1.0 / 6.0, 5.0 / 6.0, 5)
    on_grid = np.isclose(np.asarray(state.hi.theta)[:, None],
                         np.concatenate([grid, [0.5]])[None, :])
    assert on_grid.any(axis=1).all()
    assert float(np.asarray(m.hi_regret)[-1]) > 0.0


# ---------------------------------------------------------------------------
# the registry's online solvers (the host mirror of `hi_period`)
# ---------------------------------------------------------------------------
def _host_fleet(rng, D=4, n=8, M=3):
    p_ed = rng.uniform(0.05, 0.2, (D, n, M)).cumsum(axis=2)[:, :, ::-1]
    return api.FleetProblem(
        p_ed=p_ed.copy(), p_es=rng.uniform(0.01, 0.05, (D, n)),
        acc=np.sort(rng.uniform(0.5, 0.95, (D, M + 1)), axis=1),
        T=np.ones(D), real_mask=np.ones((D, n), bool))


def test_online_solvers_registered_with_capability():
    infos = api.solvers()
    for name in ("hi_threshold", "hi_bandit"):
        assert infos[name].online and infos[name].batched
    assert not infos["amr2"].online


def test_hi_threshold_solver_decides_and_learns():
    rng = np.random.default_rng(0)
    fleet = _host_fleet(rng)
    conf = rng.uniform(0.3, 0.95, (4, 8))
    hm = HIModel.make()
    sol = api.solve(fleet, policy="hi_threshold", confidence=conf, hi=hm)
    assign = np.asarray(sol.assignment)
    # decide-only: threshold rule at theta0 gates on conf < 0.5
    np.testing.assert_array_equal(assign == fleet.m, conf < 0.5)
    np.testing.assert_array_equal(np.asarray(sol.hi_theta), 0.5)
    # feeding back observations advances the learner state
    st0 = HILearnerState.init(4, 9, hm.theta0)
    sol2 = api.solve(fleet, policy="hi_threshold", confidence=conf, hi=hm,
                     state=st0,
                     observed_local=(rng.random((4, 8)) < 0.7),
                     observed_es=(rng.random((4, 8)) < 0.9))
    assert not np.allclose(np.asarray(sol2.hi_state.theta),
                           np.asarray(st0.theta))


def test_hi_bandit_solver_rules_and_validation():
    rng = np.random.default_rng(1)
    fleet = _host_fleet(rng)
    conf = rng.uniform(0.3, 0.95, (4, 8))
    hm = HIModel.make()
    for rule in ("ucb", "exp3"):
        sol = api.solve(fleet, policy="hi_bandit", confidence=conf,
                        hi=hm, rule=rule)
        theta = np.asarray(sol.hi_theta)
        grid = np.linspace(0.1, 0.9, 9)
        assert np.isclose(theta[:, None], grid[None, :]).any(axis=1).all(), \
            rule
        assign = np.asarray(sol.assignment)
        np.testing.assert_array_equal(assign == fleet.m,
                                      conf < theta[:, None])
    with pytest.raises(ValueError, match="ucb.*exp3"):
        api.solve(fleet, policy="hi_bandit", confidence=conf, hi=hm,
                  rule="thompson")
