"""The HLO cost parser is load-bearing for the roofline deliverable —
unit-test it against known-flop programs and crafted HLO snippets."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_cost


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile().as_text()


def test_scan_trip_multiplication():
    def f(x, w):
        def body(c, wi):
            return c @ wi, None
        y, _ = jax.lax.scan(body, x, w)
        return y

    txt = _compile(f, jax.ShapeDtypeStruct((128, 128), jnp.bfloat16),
                   jax.ShapeDtypeStruct((8, 128, 128), jnp.bfloat16))
    r = hlo_cost.analyze(txt)
    expect = 8 * 2 * 128 ** 3
    assert expect * 0.95 <= r["flops"] <= expect * 1.15
    assert r["unparsed_loops"] == 0


def test_nested_scan():
    def g(x, w):
        def outer(c, _):
            def inner(c2, wi):
                return c2 @ wi, None
            c, _ = jax.lax.scan(inner, c, w)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    txt = _compile(g, jax.ShapeDtypeStruct((128, 128), jnp.bfloat16),
                   jax.ShapeDtypeStruct((8, 128, 128), jnp.bfloat16))
    r = hlo_cost.analyze(txt)
    expect = 3 * 8 * 2 * 128 ** 3
    assert expect * 0.95 <= r["flops"] <= expect * 1.15


def test_gather_counts_slice_not_operand():
    # embedding-style gather from a big table: traffic ~ slice, not table
    def f(table, idx):
        return jnp.take(table, idx, axis=0)

    txt = _compile(f, jax.ShapeDtypeStruct((50000, 256), jnp.float32),
                   jax.ShapeDtypeStruct((8,), jnp.int32))
    r = hlo_cost.analyze(txt)
    table_bytes = 50000 * 256 * 4
    assert r["bytes"] < table_bytes / 10    # far below a full-table read


def test_shape_bytes_tuple_and_comments():
    line = "(f32[2,3]{1,0}, bf16[4]{0}, pred[], s32[5])"
    elems, b = hlo_cost._shape_elems_bytes(line)
    assert b == 2 * 3 * 4 + 4 * 2 + 1 + 5 * 4


def test_collectives_trip_multiplied():
    # all-reduce inside a while body with known_trip_count=4
    snippet = """
HloModule m

%body (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %p = (s32[], f32[64]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[64]{0} get-tuple-element(%p), index=1
  %ar = f32[64]{0} all-reduce(%x), replica_groups={}, to_apply=%add
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[64]) tuple(%i2, %ar)
}

%cond (p: (s32[], f32[64])) -> pred[] {
  %p = (s32[], f32[64]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(4)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[64]) -> f32[64] {
  %a = f32[64]{0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[64]) tuple(%z, %a)
  %w = (s32[], f32[64]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[64]{0} get-tuple-element(%w), index=1
}
"""
    r = hlo_cost.analyze(snippet)
    assert r["coll_bytes"] == 4 * 64 * 4      # 4 trips x 64 f32
    assert r["coll_counts"].get("all-reduce") == 4
